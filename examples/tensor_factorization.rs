//! Tensor-factorization workload (§8.4):
//!
//!     cargo run --release --example tensor_factorization
//!
//! Runs MTTKRP — `einsum("ijk,jf,kf->if")`, the closed-form ALS update for
//! CP tensor decomposition — first for real on a small tensor (numerics
//! checked against the dense reference), then at a paper-scale shape in
//! modeled time, comparing LSHS with the Dask-like round-robin baseline
//! and the paper's preferred 16x1x1 node grid against a cubic grid.

use anyhow::Result;
use nums::api::{ops, Policy};
use nums::prelude::*;
use nums::util::fmt::{human_bytes, human_secs};

fn main() -> Result<()> {
    // ---- real execution: correctness on a small tensor ----
    let mut sess = Session::new(SessionConfig::real_small(4, 2));
    let x = nums::tensor::random_tensor3(&mut sess, &[16, 12, 8], &[4, 2, 2]);
    let b = nums::tensor::random_factor(&mut sess, 12, 10, 2);
    let c = nums::tensor::random_factor(&mut sess, 8, 10, 2);
    let (out, rep) = ops::mttkrp(&mut sess, &x, &b, &c)?;
    let want = nums::tensor::mttkrp_dense(
        &sess.fetch(&x)?,
        &sess.fetch(&b)?,
        &sess.fetch(&c)?,
    );
    let err = sess.fetch(&out)?.max_abs_diff(&want);
    println!(
        "real MTTKRP 16x12x8 r=10: {} tasks, max |err| vs dense = {err:.3e}",
        rep.tasks
    );
    assert!(err < 1e-9);

    // ---- paper-scale modeled runs (Fig. 13a shape) ----
    println!("\nmodeled MTTKRP, I=J=K=1024, F=100, 16 nodes x 32 workers:");
    for (name, policy, grid) in [
        ("LSHS, 16x1x1 grid (paper's best)", Policy::Lshs, NodeGrid::new(&[16, 1, 1])),
        ("LSHS, cubic-ish grid", Policy::Lshs, NodeGrid::new(&[4, 2, 2])),
        ("round-robin (Dask-like)", Policy::RoundRobin, NodeGrid::new(&[16, 1, 1])),
    ] {
        let cfg = SessionConfig::paper_sim(16, 32).with_policy(policy).with_node_grid(grid);
        let mut sess = Session::new(cfg);
        let x = sess.zeros(&[1024, 1024, 1024], &[16, 4, 4]);
        let b = sess.zeros(&[1024, 100], &[4, 1]);
        let c = sess.zeros(&[1024, 100], &[4, 1]);
        let mut g = Graph::new();
        build::mttkrp(&mut g, &x, &b, &c);
        let (_, rep) = sess.run(&mut g)?;
        println!(
            "  {name:34} modeled {:>9}  traffic {:>10}  ({} tasks)",
            human_secs(rep.sim.makespan),
            human_bytes(rep.sim.transfer_bytes as f64),
            rep.tasks
        );
    }
    println!("(expect LSHS+16x1x1 fastest: the j/k contraction stays node-local, Fig. 13a)");
    Ok(())
}
