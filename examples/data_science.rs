//! Data-science pipeline (§8.6, Table 3): load CSV → train → predict.
//!
//!     cargo run --release --example data_science [-- --rows 100000]
//!
//! Compares the "Python stack" shape (serial CSV parse + single-thread
//! Newton) against NumS (parallel byte-range CSV reader + distributed
//! Newton with automatic partitioning) on a synthetic HIGGS-like dataset.

use anyhow::Result;
use nums::prelude::*;
use nums::util::cli::Args;
use nums::util::fmt::human_secs;
use nums::util::Stopwatch;

fn main() -> Result<()> {
    let args = Args::from_env();
    let rows = args.usize_or("rows", 100_000);
    let steps = args.usize_or("steps", 6);
    let path = std::env::temp_dir().join("nums_higgs_example.csv");
    println!("generating HIGGS-like CSV: {rows} rows x 28 features ...");
    nums::io::higgs::generate_csv(&path, rows, 0x4163)?;
    let fsize = std::fs::metadata(&path)?.len();
    println!("file: {:.1} MiB", fsize as f64 / (1 << 20) as f64);

    // ---- serial baseline (Pandas + sklearn stand-in) ----
    let sw = Stopwatch::start();
    let dense = nums::io::csv::read_csv_serial(&path)?;
    let t_load_serial = sw.secs();
    let (x_dense, y_dense) = nums::io::higgs::split_label(&dense);
    let sw = Stopwatch::start();
    let serial = nums::glm::newton_fit_serial(&x_dense, &y_dense, steps, 1e-8)?;
    let t_train_serial = sw.secs();
    let sw = Stopwatch::start();
    let acc_serial = nums::glm::serial::accuracy_serial(&x_dense, &y_dense, &serial.beta)?;
    let t_pred_serial = sw.secs();

    // ---- NumS pipeline ----
    let mut sess = Session::new(SessionConfig::real_small(1, 8)); // one fat node
    let sw = Stopwatch::start();
    let (raw, nrows, ncols) = nums::io::csv::read_csv_parallel(&mut sess, &path, 8)?;
    let t_load = sw.secs();
    // split label column on the driver (cheap) and scatter row-wise
    let dense2 = sess.fetch(&raw)?;
    let (x2, y2) = nums::io::higgs::split_label(&dense2);
    let q = 8;
    let x = sess.scatter2(&x2, &[q, 1]);
    let y = sess.scatter2(&y2, &[q, 1]);
    let sw = Stopwatch::start();
    let fit = nums::glm::newton_fit(&mut sess, &x, &y, steps, 1e-8)?;
    let t_train = sw.secs();
    let sw = Stopwatch::start();
    let acc = nums::glm::accuracy(&mut sess, &x, &y, &fit.beta)?;
    let t_pred = sw.secs();

    println!("\nTable-3 shape ({} rows x {} cols):", nrows, ncols);
    println!("{:<14} {:>10} {:>10} {:>10} {:>10}", "stack", "load", "train", "predict", "total");
    println!(
        "{:<14} {:>10} {:>10} {:>10} {:>10}",
        "serial(py-ish)",
        human_secs(t_load_serial),
        human_secs(t_train_serial),
        human_secs(t_pred_serial),
        human_secs(t_load_serial + t_train_serial + t_pred_serial)
    );
    println!(
        "{:<14} {:>10} {:>10} {:>10} {:>10}",
        "NumS",
        human_secs(t_load),
        human_secs(t_train),
        human_secs(t_pred),
        human_secs(t_load + t_train + t_pred)
    );
    println!("accuracy: serial {acc_serial:.4} vs NumS {acc:.4}");
    let err = sess.fetch(&fit.beta)?.max_abs_diff(&serial.beta);
    println!("beta max |diff| = {err:.3e} (same optimum)");
    std::fs::remove_file(&path).ok();
    Ok(())
}
