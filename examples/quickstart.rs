//! Quickstart: the NumPy-like API in ~40 lines.
//!
//!     cargo run --release --example quickstart
//!
//! Creates distributed arrays on a simulated 4-node cluster, runs
//! element-wise and linear-algebra expressions through LSHS, and gathers
//! results. Kernels execute through the AOT PJRT artifacts when shapes
//! match the manifest (build them with `make artifacts`), falling back to
//! the native backend otherwise.

use anyhow::Result;
use nums::api::ops;
use nums::prelude::*;

fn main() -> Result<()> {
    // a 4-node x 4-worker Ray-mode cluster, LSHS scheduling, real execution
    let mut sess = Session::new(SessionConfig::real_small(4, 4));
    println!("cluster: {} nodes, policy={}, backend={}",
             sess.topo.nodes, sess.policy_name(), sess.backend.name());

    // creation ops execute immediately with the hierarchical layout (§4)
    let a = sess.randn(&[256, 256], &[4, 4]);
    let b = sess.ones(&[256, 256], &[4, 4]);

    // element-wise: zero communication under LSHS (App. A.1)
    let (c, rep) = ops::add(&mut sess, &a, &b)?;
    println!("A+B: {} tasks, {} transfers (expect 0)", rep.tasks, rep.transfers);

    // matrix multiply: recursive block matmul + locality-paired reductions
    let (d, rep) = ops::matmul(&mut sess, &a, &b)?;
    println!("A@B: {} tasks, modeled {:.1} ms", rep.tasks, rep.sim.makespan * 1e3);

    // lazy transpose fuses into the contraction (§6): Aᵀ@B -> Gram kernels
    let (e, rep) = ops::matmul(&mut sess, &a.t(), &b)?;
    println!("AᵀB: {} tasks via fused-gram blocks", rep.tasks);

    // reductions
    let (s, _) = ops::sum_all(&mut sess, &c)?;
    let total = sess.fetch_scalar(&s)?;
    println!("sum(A+B) = {total:.3}");

    // gather and check against the dense math
    let (da, db_, dd) = (sess.fetch(&a)?, sess.fetch(&b)?, sess.fetch(&d)?);
    let manual = nums::linalg::dense::matmul(&da, &db_);
    println!("A@B max |err| vs dense = {:.3e}", dd.max_abs_diff(&manual));
    let de = sess.fetch(&e)?;
    let manual_t = nums::linalg::dense::matmul(&da.transposed(), &db_);
    println!("AᵀB max |err| vs dense = {:.3e}", de.max_abs_diff(&manual_t));

    let (pjrt, native) = sess.backend.counters();
    println!("kernel executions: {pjrt} via PJRT artifacts, {native} native fallback");
    Ok(())
}
