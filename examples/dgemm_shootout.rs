//! DGEMM shootout (§8.2, Fig. 10): NumS recursive matmul under LSHS vs
//! SUMMA (the SLATE/ScaLAPACK algorithm) on the same modeled network.
//!
//!     cargo run --release --example dgemm_shootout
//!
//! Also runs a small real matmul through the full stack (PJRT artifacts)
//! to keep the numerics honest.

use anyhow::Result;
use nums::api::ops;
use nums::prelude::*;
use nums::util::fmt::human_secs;

fn main() -> Result<()> {
    // ---- real correctness run ----
    let mut sess = Session::new(SessionConfig::real_small(4, 4));
    let a = sess.randn(&[256, 256], &[2, 2]);
    let b = sess.randn(&[256, 256], &[2, 2]);
    let (c, rep) = ops::matmul(&mut sess, &a, &b)?;
    let dense = nums::linalg::dense::matmul(&sess.fetch(&a)?, &sess.fetch(&b)?);
    println!(
        "real 256^2 matmul (128^2 blocks through PJRT): {} tasks, err {:.2e}",
        rep.tasks,
        sess.fetch(&c)?.max_abs_diff(&dense)
    );

    // ---- modeled weak scaling: 2 GB on 1 node ... 32 GB on 16 (Fig. 10) ----
    println!("\nmodeled DGEMM weak scaling (f64, paper testbed):");
    println!("{:>6} {:>6} {:>12} {:>12} {:>12}", "nodes", "GB", "NumS-LSHS", "SUMMA", "ratio");
    for (nodes, gb) in [(1usize, 2usize), (4, 8), (16, 32)] {
        // n x n f64 matrix of `gb` gigabytes
        let n = (((gb as f64) * 1e9 / 8.0).sqrt()) as usize;
        let summa = nums::summa::Summa::new(nodes, n).run(
            NetParams::mpi_testbed(),
            ComputeParams::mpi_testbed(),
            32,
        );
        let side = (nodes as f64).sqrt().round() as usize;
        let cfg = SessionConfig::paper_sim(nodes, 32)
            .with_node_grid(NodeGrid::new(&[side.max(1), nodes / side.max(1)]));
        let mut sess = Session::new(cfg);
        let g = (2 * side).max(2);
        let a = sess.zeros(&[n, n], &[g, g]);
        let b = sess.zeros(&[n, n], &[g, g]);
        let mut graph = Graph::new();
        build::matmul(&mut graph, &a, &b);
        let (_, rep) = sess.run(&mut graph)?;
        println!(
            "{nodes:>6} {gb:>6} {:>12} {:>12} {:>11.2}x",
            human_secs(rep.sim.makespan),
            human_secs(summa.report.makespan),
            rep.sim.makespan / summa.report.makespan
        );
    }
    println!("(paper: NumS competitive with SLATE at 16 nodes; SUMMA wins on memory)");
    Ok(())
}
