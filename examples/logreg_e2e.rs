//! END-TO-END driver (the full-system validation run, recorded in
//! EXPERIMENTS.md):
//!
//!     cargo run --release --example logreg_e2e [-- --n 32768 --d 32 --steps 10]
//!
//! Full-system logistic regression on a real synthetic workload:
//! 1. sample the paper's bimodal-Gaussian classification data (§8.5) into
//!    row blocks shaped exactly like the AOT `newton_block_4096x32`
//!    artifact, so the hot path runs through PJRT;
//! 2. fit with distributed Newton through LSHS on a 4-node simulated
//!    cluster (real block numerics, real per-node byte counters);
//! 3. log the loss curve, accuracy, per-node loads;
//! 4. repeat with the Ray-default (bottom-up) scheduler and report the
//!    LSHS ablation — the §8.5 "2x net, 4x mem, 10x time" shape.

use anyhow::Result;
use nums::api::Policy;
use nums::prelude::*;
use nums::util::cli::Args;
use nums::util::fmt::{human_bytes, human_secs};

fn fit_with(policy: Policy, n: usize, d: usize, q: usize, steps: usize) -> Result<(f64, u64)> {
    let label = format!("{policy:?}");
    let cfg = SessionConfig::real_small(4, 4)
        .with_policy(policy)
        .with_seed(0xE2E);
    let mut sess = Session::new(cfg);
    let (x, y) = nums::glm::classification_data(&mut sess, n, d, q, 0xDA7A);

    let t0 = std::time::Instant::now();
    let res = nums::glm::newton_fit(&mut sess, &x, &y, steps, 1e-10)?;
    let wall = t0.elapsed().as_secs_f64();

    println!("\n=== policy: {label} ===");
    println!("loss curve:");
    for (i, l) in res.losses.iter().enumerate() {
        println!("  step {i:2}  loss {l:14.6}  ||g|| {:.3e}", res.grad_norms[i]);
    }
    let acc = nums::glm::accuracy(&mut sess, &x, &y, &res.beta)?;
    let snap = sess.stores.snapshot();
    println!("accuracy           : {acc:.4}");
    println!("iterations         : {}", res.iters);
    println!("wall time          : {}", human_secs(wall));
    println!("modeled cluster t  : {}", human_secs(res.sim_secs()));
    println!("inter-node traffic : {}", human_bytes(res.transfer_bytes() as f64));
    println!("per-node (peak mem | net in | net out):");
    for (node, (_, peak, nin, nout)) in snap.iter().enumerate() {
        println!(
            "  node {node}: {:>12} | {:>12} | {:>12}",
            human_bytes(*peak as f64),
            human_bytes(*nin as f64),
            human_bytes(*nout as f64)
        );
    }
    let (pjrt, native) = sess.backend.counters();
    println!("kernels            : {pjrt} PJRT, {native} native");
    let peak = snap.iter().map(|s| s.1).max().unwrap_or(0);
    let _ = peak;
    Ok((res.sim_secs(), res.transfer_bytes()))
}

fn main() -> Result<()> {
    let args = Args::from_env();
    let d = args.usize_or("d", 32);
    let q = args.usize_or("q", 8);
    let n = args.usize_or("n", q * 4096); // 4096-row blocks hit the AOT artifact
    let steps = args.usize_or("steps", 10);
    println!("end-to-end logistic regression: n={n} d={d} blocks={q} steps={steps}");

    let (t_lshs, b_lshs) = fit_with(Policy::Lshs, n, d, q, steps)?;
    let (t_bu, b_bu) = fit_with(Policy::BottomUp, n, d, q, steps)?;

    println!("\n=== LSHS ablation (Fig. 15 shape) ===");
    println!(
        "modeled time : LSHS {} vs bottom-up {}  ({:.1}x)",
        human_secs(t_lshs),
        human_secs(t_bu),
        t_bu / t_lshs.max(1e-12)
    );
    println!(
        "net traffic  : LSHS {} vs bottom-up {}  ({:.1}x)",
        human_bytes(b_lshs as f64),
        human_bytes(b_bu as f64),
        b_bu as f64 / (b_lshs as f64).max(1.0)
    );
    Ok(())
}
