//! Fig. 9 — microbenchmark ablation: {X+Y, X@y, Xᵀ@y, Xᵀ@Y, X@Yᵀ, sum}
//! across systems {Ray+LSHS, Ray w/o LSHS, Dask+LSHS, Dask w/o LSHS
//! (≈ Dask Arrays)} and partition counts, on paper-shape arrays over a
//! 16-node × 32-worker modeled cluster.
//!
//! Expected shape (paper §8.1): LSHS flat & fast everywhere; the Dask-like
//! round-robin competitive only when partitions divide the worker count;
//! Ray-without-LSHS concentrated and slow.

use nums::api::{ops, Policy, RunReport, Session, SessionConfig};
use nums::bench::harness::print_series;
use nums::prelude::*;

type OpFn = fn(&mut Session, &DistArray, &DistArray) -> anyhow::Result<(DistArray, RunReport)>;

fn xty(s: &mut Session, x: &DistArray, y: &DistArray) -> anyhow::Result<(DistArray, RunReport)> {
    ops::matmul(s, &x.t(), y)
}
fn xyt(s: &mut Session, x: &DistArray, y: &DistArray) -> anyhow::Result<(DistArray, RunReport)> {
    ops::matmul(s, x, &y.t())
}
fn add(s: &mut Session, x: &DistArray, y: &DistArray) -> anyhow::Result<(DistArray, RunReport)> {
    ops::add(s, x, y)
}
fn sum0(s: &mut Session, x: &DistArray, _y: &DistArray) -> anyhow::Result<(DistArray, RunReport)> {
    ops::sum_axis(s, x, 0)
}

fn systems() -> Vec<(&'static str, Policy, SystemMode)> {
    vec![
        ("Ray+LSHS", Policy::Lshs, SystemMode::Ray),
        ("Ray w/o LSHS", Policy::BottomUp, SystemMode::Ray),
        ("Dask+LSHS", Policy::Lshs, SystemMode::Dask),
        ("Dask RR (DaskArrays)", Policy::RoundRobin, SystemMode::Dask),
    ]
}

/// Run `op` on [rows, d] operands partitioned into q row blocks.
fn run_case(
    policy: Policy,
    mode: SystemMode,
    rows: usize,
    d: usize,
    q: usize,
    op: OpFn,
) -> f64 {
    let cfg = SessionConfig::paper_sim(16, 32)
        .with_policy(policy)
        .with_mode(mode);
    let mut sess = Session::new(cfg);
    let x = sess.zeros(&[rows, d], &[q, 1]);
    let y = sess.zeros(&[rows, d], &[q, 1]);
    let (_, rep) = op(&mut sess, &x, &y).unwrap();
    rep.sim.makespan
}

/// X @ y: y is a [d,1] single-block vector.
fn run_matvec(policy: Policy, mode: SystemMode, rows: usize, d: usize, q: usize) -> f64 {
    let cfg = SessionConfig::paper_sim(16, 32)
        .with_policy(policy)
        .with_mode(mode);
    let mut sess = Session::new(cfg);
    let x = sess.zeros(&[rows, d], &[q, 1]);
    let y = sess.zeros(&[d, 1], &[1, 1]);
    let (_, rep) = ops::matmul(&mut sess, &x, &y).unwrap();
    rep.sim.makespan
}

/// Xᵀ @ y with y partitioned like X's rows.
fn run_tn_vec(policy: Policy, mode: SystemMode, rows: usize, d: usize, q: usize) -> f64 {
    let cfg = SessionConfig::paper_sim(16, 32)
        .with_policy(policy)
        .with_mode(mode);
    let mut sess = Session::new(cfg);
    let x = sess.zeros(&[rows, d], &[q, 1]);
    let y = sess.zeros(&[rows, 1], &[q, 1]);
    let (_, rep) = ops::matmul(&mut sess, &x.t(), &y).unwrap();
    rep.sim.makespan
}

fn series(title: &str, f: impl Fn(Policy, SystemMode, usize) -> f64, parts: &[usize]) {
    let xs: Vec<String> = parts.iter().map(|p| p.to_string()).collect();
    let rows: Vec<(String, Vec<f64>)> = systems()
        .into_iter()
        .map(|(name, policy, mode)| {
            (
                name.to_string(),
                parts
                    .iter()
                    .map(|&q| f(policy.clone(), mode, q))
                    .collect(),
            )
        })
        .collect();
    print_series(title, "partitions", &xs, &rows);
}

fn main() {
    // 64 GB-shape operands (2^27 x 64 f64) — modeled time, phantom blocks.
    let rows = 1usize << 27;
    let d = 64usize;
    let parts: Vec<usize> = vec![16, 32, 48, 64, 96, 128];

    series("Fig 9: X + Y [modeled s]", |p, m, q| run_case(p, m, rows, d, q, add), &parts);
    series("Fig 9: X @ y [modeled s]", |p, m, q| run_matvec(p, m, rows, d, q), &parts);
    series("Fig 9: Xᵀ @ y [modeled s]", |p, m, q| run_tn_vec(p, m, rows, d, q), &parts);
    series("Fig 9: Xᵀ @ Y [modeled s]", |p, m, q| run_case(p, m, rows, d, q, xty), &parts);
    // outer product: smaller rows so the n x n output stays sane
    series(
        "Fig 9: X @ Yᵀ [modeled s] (2^18 x 2048 operands)",
        |p, m, q| run_case(p, m, 1 << 18, 2048, q, xyt),
        &parts,
    );
    series("Fig 9: sum(X, 0) [modeled s]", |p, m, q| run_case(p, m, rows, d, q, sum0), &parts);
}
