//! Fig. 9 — microbenchmark ablation: {X+Y, X@y, Xᵀ@y, Xᵀ@Y, X@Yᵀ, sum}
//! across systems {Ray+LSHS, Ray w/o LSHS, Dask+LSHS, Dask w/o LSHS
//! (≈ Dask Arrays)} and partition counts, on paper-shape arrays over a
//! 16-node × 32-worker modeled cluster.
//!
//! Expected shape (paper §8.1): LSHS flat & fast everywhere; the Dask-like
//! round-robin competitive only when partitions divide the worker count;
//! Ray-without-LSHS concentrated and slow.
//!
//! Extended sections (this repo's perf work): the element-wise-chain
//! fusion ablation (fusion on/off over modeled cluster + real execution),
//! the naive/blocked/SIMD dense matmul kernel shootout, the
//! contraction-epilogue fusion ablation (Scale/Neg folded into
//! `ScaledMatmul` writeback), the work-stealing
//! ablation (a deliberately skewed plan with stealing on/off, per-node
//! steal counters included), the memory-manager and
//! communication-overlap ablations, the plan↔runtime feedback
//! ablation (`SessionConfig::feedback` on/off over skewed layouts), and
//! the plan-cache ablation (`SessionConfig::plan_cache` on/off over a
//! repeated-topology GLM, with per-run search-time and simulation-count
//! records).
//! Results are also written machine-readably to `BENCH_fig09.json` so
//! future PRs have a perf trajectory to diff against.
//!
//! `cargo bench --bench fig09_micro -- --smoke` runs a bounded-size
//! variant for CI: same sections, small shapes, still emits the JSON.

use std::sync::Arc;

use nums::api::{ops, Policy, RunReport, Session, SessionConfig};
use nums::bench::harness::{
    emit_json, feedback_summary, glm_mem_run, max_peak_bytes, mem_summary, planning_summary,
    prefetch_summary, print_series, produce_fold_plan, steal_summary, PerfRecord,
};
use nums::exec::{Plan, RealExecutor, Task};
use nums::linalg::dense;
use nums::prelude::*;
use nums::store::{MemoryManager, StoreSet};
use nums::util::Stopwatch;

type OpFn = fn(&mut Session, &DistArray, &DistArray) -> anyhow::Result<(DistArray, RunReport)>;

fn xty(s: &mut Session, x: &DistArray, y: &DistArray) -> anyhow::Result<(DistArray, RunReport)> {
    ops::matmul(s, &x.t(), y)
}
fn xyt(s: &mut Session, x: &DistArray, y: &DistArray) -> anyhow::Result<(DistArray, RunReport)> {
    ops::matmul(s, x, &y.t())
}
fn add(s: &mut Session, x: &DistArray, y: &DistArray) -> anyhow::Result<(DistArray, RunReport)> {
    ops::add(s, x, y)
}
fn sum0(s: &mut Session, x: &DistArray, _y: &DistArray) -> anyhow::Result<(DistArray, RunReport)> {
    ops::sum_axis(s, x, 0)
}

fn systems() -> Vec<(&'static str, Policy, SystemMode)> {
    vec![
        ("Ray+LSHS", Policy::Lshs, SystemMode::Ray),
        ("Ray w/o LSHS", Policy::BottomUp, SystemMode::Ray),
        ("Dask+LSHS", Policy::Lshs, SystemMode::Dask),
        ("Dask RR (DaskArrays)", Policy::RoundRobin, SystemMode::Dask),
    ]
}

/// Run `op` on [rows, d] operands partitioned into q row blocks.
fn run_case(
    policy: Policy,
    mode: SystemMode,
    rows: usize,
    d: usize,
    q: usize,
    op: OpFn,
) -> f64 {
    let cfg = SessionConfig::paper_sim(16, 32)
        .with_policy(policy)
        .with_mode(mode);
    let mut sess = Session::new(cfg);
    let x = sess.zeros(&[rows, d], &[q, 1]);
    let y = sess.zeros(&[rows, d], &[q, 1]);
    let (_, rep) = op(&mut sess, &x, &y).unwrap();
    rep.sim.makespan
}

/// X @ y: y is a [d,1] single-block vector.
fn run_matvec(policy: Policy, mode: SystemMode, rows: usize, d: usize, q: usize) -> f64 {
    let cfg = SessionConfig::paper_sim(16, 32)
        .with_policy(policy)
        .with_mode(mode);
    let mut sess = Session::new(cfg);
    let x = sess.zeros(&[rows, d], &[q, 1]);
    let y = sess.zeros(&[d, 1], &[1, 1]);
    let (_, rep) = ops::matmul(&mut sess, &x, &y).unwrap();
    rep.sim.makespan
}

/// Xᵀ @ y with y partitioned like X's rows.
fn run_tn_vec(policy: Policy, mode: SystemMode, rows: usize, d: usize, q: usize) -> f64 {
    let cfg = SessionConfig::paper_sim(16, 32)
        .with_policy(policy)
        .with_mode(mode);
    let mut sess = Session::new(cfg);
    let x = sess.zeros(&[rows, d], &[q, 1]);
    let y = sess.zeros(&[rows, 1], &[q, 1]);
    let (_, rep) = ops::matmul(&mut sess, &x.t(), &y).unwrap();
    rep.sim.makespan
}

fn series(title: &str, f: impl Fn(Policy, SystemMode, usize) -> f64, parts: &[usize]) {
    let xs: Vec<String> = parts.iter().map(|p| p.to_string()).collect();
    let rows: Vec<(String, Vec<f64>)> = systems()
        .into_iter()
        .map(|(name, policy, mode)| {
            (
                name.to_string(),
                parts
                    .iter()
                    .map(|&q| f(policy.clone(), mode, q))
                    .collect(),
            )
        })
        .collect();
    print_series(title, "partitions", &xs, &rows);
}

/// The 6-step chain used by the fusion ablation:
/// `-( sigmoid((-X · 0.5) + Y) · Z )`.
fn chain_steps() -> Vec<EwStep> {
    vec![
        EwStep::Neg,
        EwStep::Scale(0.5),
        EwStep::Bin(BinOp::Add),
        EwStep::Sigmoid,
        EwStep::Bin(BinOp::Mul),
        EwStep::Neg,
    ]
}

/// Fusion ablation: the same 6-op chain with fusion on/off, on the
/// modeled paper cluster (task counts + modeled seconds) and on a real
/// local session (wall seconds).
fn chain_ablation(records: &mut Vec<PerfRecord>, smoke: bool) {
    let steps = chain_steps();
    println!("## Fig 9 (ext): elementwise-chain fusion ablation (6-op chain)");

    // modeled: 64 GB-shape operands over 16 nodes x 32 workers
    let (rows, d, q) = (1usize << 27, 64usize, 64usize);
    for fusion in [false, true] {
        let cfg = SessionConfig::paper_sim(16, 32).with_fusion(fusion);
        let mut sess = Session::new(cfg);
        let x = sess.zeros(&[rows, d], &[q, 1]);
        let y = sess.zeros(&[rows, d], &[q, 1]);
        let z = sess.zeros(&[rows, d], &[q, 1]);
        let (_, rep) = ops::ew_chain(&mut sess, &x, &[&y, &z], &steps).unwrap();
        println!(
            "  sim  fusion={fusion:<5} tasks={:<4} fused_ops={:<4} modeled={:.4}s transfers={}",
            rep.tasks, rep.fused_ops, rep.sim.makespan, rep.transfers
        );
        records.push(PerfRecord {
            op: format!("ew_chain6_sim_fusion_{fusion}"),
            bytes: (rows as u64) * (d as u64) * 8 * 3,
            secs: rep.sim.makespan,
            gflops: 0.0,
        });
    }

    // real execution: moderate shapes, actual kernels and wall-clock
    let m = if smoke { 1usize << 10 } else { 1usize << 12 };
    for fusion in [false, true] {
        let cfg = SessionConfig::real_small(2, 4).with_fusion(fusion);
        let mut sess = Session::new(cfg);
        let x = sess.randn(&[m, 256], &[8, 1]);
        let y = sess.randn(&[m, 256], &[8, 1]);
        let z = sess.randn(&[m, 256], &[8, 1]);
        let sw = Stopwatch::start();
        let (_, rep) = ops::ew_chain(&mut sess, &x, &[&y, &z], &steps).unwrap();
        let secs = sw.secs();
        println!(
            "  real fusion={fusion:<5} tasks={:<4} wall={:.4}s",
            rep.tasks, secs
        );
        records.push(PerfRecord {
            op: format!("ew_chain6_real_fusion_{fusion}"),
            bytes: (m * 256 * 8 * 3) as u64,
            secs,
            gflops: 0.0,
        });
    }
}

/// Blocked/register-tiled/parallel matmul vs the seed's naive triple loop
/// on one 1024x1024 f64 block. (Standalone `dense::matmul` gets the
/// whole-host budget from `ExecContext::host_default()` — the real
/// sessions above no longer leak their per-worker budgets into this
/// timing, because there is no global parallelism state.)
fn kernel_shootout(records: &mut Vec<PerfRecord>, smoke: bool) {
    let n = if smoke { 256usize } else { 1024usize };
    let mut rng = Rng::seed_from_u64(0x909);
    let mut av = vec![0.0; n * n];
    rng.fill_normal(&mut av);
    let mut bv = vec![0.0; n * n];
    rng.fill_normal(&mut bv);
    let a = Block::from_vec(&[n, n], av);
    let b = Block::from_vec(&[n, n], bv);
    let flops = 2.0 * (n as f64).powi(3);
    println!("## Fig 9 (ext): dense matmul kernel, one {n}x{n} block");
    let mut secs_of = |name: &str, f: fn(&Block, &Block) -> Block| -> f64 {
        let _ = f(&a, &b); // warmup
        let sw = Stopwatch::start();
        let out = f(&a, &b);
        let secs = sw.secs();
        assert_eq!(out.shape, vec![n, n]);
        let g = flops / secs / 1e9;
        println!("  {name:<16} {secs:.4}s  {g:8.2} GFLOP/s");
        records.push(PerfRecord {
            op: format!("{name}_{n}"),
            bytes: (3 * n * n * 8) as u64,
            secs,
            gflops: g,
        });
        secs
    };
    let blocked = secs_of("matmul_blocked", dense::matmul);
    let naive = secs_of("matmul_naive", dense::matmul_naive);
    // packed-panel AVX2+FMA tier (degrades to scalar where unavailable —
    // the row then just duplicates matmul_blocked)
    let simd = secs_of("matmul_simd", |a, b| {
        dense::matmul_tier(
            a,
            b,
            1.0,
            ExecContext::host_default().kernel_threads,
            KernelTier::simd_if_available(),
        )
    });
    println!(
        "  blocked/naive speedup: {:.2}x, simd/blocked: {:.2}x (simd tier: {})",
        naive / blocked,
        blocked / simd,
        KernelTier::simd_if_available().name()
    );
}

/// Contraction-epilogue fusion ablation (the PR 6 satellite): `-2·(X@W)`
/// built as an explicit Scale∘Matmul graph, run with fusion off (separate
/// Scale tasks) and on (the Scale folds into `ScaledMatmul`, α applied in
/// the C-writeback — see `graph::fuse::fuse_epilogues`). Strict sessions
/// keep the fold bit-exact, which the arm asserts; a third relaxed arm
/// times the same folded plan on the SIMD tier.
fn epilogue_ablation(records: &mut Vec<PerfRecord>, smoke: bool) {
    println!("## Fig 9 (ext): contraction-epilogue fusion ablation (-2·(X@W))");
    let m = if smoke { 512usize } else { 2048usize };
    let (k, n, q) = (256usize, 128usize, 4usize);
    let build_graph = |sess: &mut Session| -> (DistArray, Graph) {
        let x = sess.randn(&[m, k], &[q, 1]);
        let w = sess.randn(&[k, n], &[1, 1]);
        let mut g = Graph::new();
        let roots: Vec<(usize, usize)> = (0..q)
            .map(|i| {
                let la = g.leaf(x.obj_at(&[i, 0]), &x.grid.block_shape(&[i, 0]));
                let lw = g.leaf(w.obj_at(&[0, 0]), &[k, n]);
                let mm = g.op(Kernel::Matmul, vec![(la, 0), (lw, 0)]);
                (g.op(Kernel::Scale(-2.0), vec![(mm, 0)]), 0)
            })
            .collect();
        g.add_output(ArrayGrid::new(&[m, n], &[q, 1]), roots);
        (x, g)
    };
    let mut outs: Vec<Block> = Vec::new();
    for (label, fusion, strict) in [
        ("unfused/strict", false, true),
        ("folded/strict", true, true),
        ("folded/simd", true, false),
    ] {
        let cfg = SessionConfig::real_small(2, 2)
            .with_fusion(fusion)
            .with_strict_kernels(strict);
        let mut sess = Session::new(cfg);
        let (_, mut g) = build_graph(&mut sess);
        let sw = Stopwatch::start();
        let (arrs, rep) = sess.run(&mut g).unwrap();
        let secs = sw.secs();
        println!(
            "  {label:<15} tasks={:<3} fused_ops={:<2} wall={secs:.4}s",
            rep.tasks, rep.fused_ops
        );
        outs.push(sess.fetch(&arrs[0]).unwrap());
        records.push(PerfRecord {
            op: format!("scaled_matmul_{}", label.replace('/', "_")),
            bytes: ((m * k + k * n + m * n) * 8) as u64,
            secs,
            gflops: 2.0 * (m * k * n) as f64 / secs / 1e9,
        });
    }
    assert_eq!(
        outs[0].max_abs_diff(&outs[1]),
        0.0,
        "epilogue fold must be bit-exact on the strict tier"
    );
}

/// Work-stealing ablation: K independent matmuls all *targeted* at node 0
/// of a 4-node topology (a deliberately skewed layout). Without stealing,
/// node 0's two workers serialize the whole queue while six other workers
/// idle; with stealing, idle nodes pull ready tasks from node 0's deque /
/// the overflow and pay the input transfers. Outputs are asserted
/// bit-identical across the two runs, and the per-node steal counters go
/// into `BENCH_fig09.json` (bytes = steal_bytes, gflops = tasks stolen).
fn stealing_ablation(records: &mut Vec<PerfRecord>, smoke: bool) {
    let nodes = 4usize;
    let n = if smoke { 96usize } else { 256usize };
    let k_tasks = if smoke { 16usize } else { 48usize };
    println!(
        "## Fig 9 (ext): work-stealing ablation ({k_tasks} independent {n}x{n} matmuls, \
         all targeted at node 0 of {nodes} nodes x 2 workers)"
    );
    let mut rng = Rng::seed_from_u64(0x57EA);
    let operands: Vec<(Block, Block)> = (0..k_tasks)
        .map(|_| {
            let mut av = vec![0.0; n * n];
            rng.fill_normal(&mut av);
            let mut bv = vec![0.0; n * n];
            rng.fill_normal(&mut bv);
            (Block::from_vec(&[n, n], av), Block::from_vec(&[n, n], bv))
        })
        .collect();
    let plan = Plan {
        tasks: (0..k_tasks)
            .map(|i| Task {
                kernel: Kernel::Matmul,
                inputs: vec![(2 * i) as u64, (2 * i + 1) as u64],
                in_shapes: vec![vec![n, n], vec![n, n]],
                outputs: vec![(1000 + i as u64, vec![n, n])],
                target: 0,
                transfers: vec![],
            })
            .collect(),
    };
    let mut walls = Vec::new();
    let mut outputs: Vec<Vec<Block>> = Vec::new();
    for stealing in [false, true] {
        let topo = Topology::new(nodes, 2, SystemMode::Ray);
        let mut exec =
            RealExecutor::new(topo, Arc::new(Backend::native())).with_stealing(stealing);
        exec.threads_per_node = 2;
        let stores = StoreSet::new(nodes);
        for (i, (a, b)) in operands.iter().enumerate() {
            stores.put(0, (2 * i) as u64, Arc::new(a.clone()));
            stores.put(0, (2 * i + 1) as u64, Arc::new(b.clone()));
        }
        let rep = exec.run(&plan, &stores).unwrap();
        println!(
            "  stealing={stealing:<5} wall={:.4}s  {}",
            rep.wall_secs,
            steal_summary(&rep)
        );
        walls.push(rep.wall_secs);
        outputs.push(
            (0..k_tasks)
                .map(|i| stores.fetch(1000 + i as u64).unwrap().as_ref().clone())
                .collect(),
        );
        records.push(PerfRecord {
            op: format!("skewed_matmul_stealing_{stealing}"),
            bytes: (3 * n * n * 8 * k_tasks) as u64,
            secs: rep.wall_secs,
            gflops: 2.0 * (n as f64).powi(3) * k_tasks as f64 / rep.wall_secs / 1e9,
        });
        for (nid, s) in rep.node_stats.iter().enumerate() {
            records.push(PerfRecord {
                op: format!("skewed_matmul_stealing_{stealing}_node{nid}_steals"),
                bytes: s.steal_bytes,
                secs: 0.0,
                gflops: s.tasks_stolen as f64,
            });
        }
    }
    for (o0, o1) in outputs[0].iter().zip(&outputs[1]) {
        assert_eq!(
            o0.max_abs_diff(o1),
            0.0,
            "stealing must not change numerics"
        );
    }
    println!(
        "  outputs bit-identical; stealing speedup: {:.2}x",
        walls[0] / walls[1]
    );
}

/// Memory-manager ablation (the §8.1 memory-load axis, real execution):
/// (a) a multi-iteration GLM with lifetime GC on/off — per-node peak
/// bytes show what refcount release buys; (b) a skewed matmul plan under
/// a tight per-node byte budget — spill/read-back traffic vs unlimited.
/// Peaks and spill counters land in `BENCH_fig09.json` (bytes = peak or
/// spilled bytes) instead of ad-hoc prints.
fn memory_ablation(records: &mut Vec<PerfRecord>, smoke: bool) {
    println!("## Fig 9 (ext): memory-manager ablation (lifetime GC + spill)");
    let (rows, d, q, steps) = if smoke { (256, 8, 4, 2) } else { (1024, 16, 8, 3) };
    for gc in [false, true] {
        let (secs, last) = glm_mem_run(2, 2, rows, d, q, steps, gc);
        let last = &last;
        println!("  glm gc={gc:<5} wall={secs:.4}s  {}", mem_summary(last));
        records.push(PerfRecord {
            op: format!("glm_newton{steps}_mem_gc_{gc}"),
            bytes: max_peak_bytes(last),
            secs,
            gflops: 0.0,
        });
        for (nid, &(_, peak, _, _)) in last.store_snapshot.iter().enumerate() {
            records.push(PerfRecord {
                op: format!("glm_newton{steps}_mem_gc_{gc}_node{nid}_peak"),
                bytes: peak,
                secs: 0.0,
                gflops: 0.0,
            });
        }
    }

    // budgeted spill: K producer blocks that all stay live until a late
    // fold consumes them — under a 4-block budget the cold producers page
    // out to disk and come back for the adds (spill AND read-back
    // traffic). Same topology the executor's budget test verifies.
    let n = if smoke { 64usize } else { 256usize };
    let k_prod = 10usize;
    let block_bytes = (n * n * 8) as u64;
    let mut rng = Rng::seed_from_u64(0x5B11);
    let mut sv = vec![0.0; n * n];
    rng.fill_normal(&mut sv);
    let seed_block = Block::from_vec(&[n, n], sv);
    let (plan, _final_out) = produce_fold_plan(k_prod, n);
    for budget in [None, Some(4 * block_bytes)] {
        let topo = Topology::new(1, 1, SystemMode::Ray);
        let mut exec = RealExecutor::new(topo, Arc::new(Backend::native()))
            .with_memory(MemoryManager::new(1, budget, true));
        exec.threads_per_node = 1;
        let stores = StoreSet::new(1);
        stores.put(0, 1, Arc::new(seed_block.clone()));
        let sw = Stopwatch::start();
        let rep = exec.run(&plan, &stores).unwrap();
        let secs = sw.secs();
        let label = match budget {
            None => "unlimited".to_string(),
            Some(bb) => format!("{}B", bb),
        };
        println!(
            "  produce-fold budget={label:<10} wall={secs:.4}s  {}",
            mem_summary(&rep)
        );
        let spilled: u64 = rep.mem_stats.iter().map(|m| m.spilled_bytes).sum();
        records.push(PerfRecord {
            op: format!(
                "produce{k_prod}_fold_budget_{}",
                budget.map_or("unlimited".to_string(), |b| format!("{b}B"))
            ),
            bytes: spilled,
            secs,
            gflops: 0.0,
        });
    }
}

/// Communication-overlap ablation (the PR 4 tentpole): prefetch on/off on
/// two communication-heavy layouts. (a) Cross-node matmul pipeline: every
/// input lives on node 0 but the tasks are spread over all nodes, so each
/// remote task must move two blocks before it can run — with prefetching
/// the transfer threads move them while earlier kernels compute. (b) A
/// skewed GLM fit on a real 2-node session (LSHS placement, stealing on).
/// Outputs are asserted bit-identical across both modes, and the per-node
/// `(prefetch, hits, demand, async-spill)` counters land in
/// `BENCH_fig09.json` (bytes = prefetch_bytes, gflops = hits).
fn overlap_ablation(records: &mut Vec<PerfRecord>, smoke: bool) {
    let nodes = 4usize;
    let n = if smoke { 96usize } else { 256usize };
    let k_tasks = if smoke { 12usize } else { 40usize };
    println!(
        "## Fig 9 (ext): communication-overlap ablation ({k_tasks} cross-node {n}x{n} \
         matmuls, inputs on node 0, tasks over {nodes} nodes)"
    );
    let mut rng = Rng::seed_from_u64(0x0E1A);
    let operands: Vec<(Block, Block)> = (0..k_tasks)
        .map(|_| {
            let mut av = vec![0.0; n * n];
            rng.fill_normal(&mut av);
            let mut bv = vec![0.0; n * n];
            rng.fill_normal(&mut bv);
            (Block::from_vec(&[n, n], av), Block::from_vec(&[n, n], bv))
        })
        .collect();
    let plan = Plan {
        tasks: (0..k_tasks)
            .map(|i| Task {
                kernel: Kernel::Matmul,
                inputs: vec![(2 * i) as u64, (2 * i + 1) as u64],
                in_shapes: vec![vec![n, n], vec![n, n]],
                outputs: vec![(1000 + i as u64, vec![n, n])],
                target: i % nodes,
                transfers: vec![],
            })
            .collect(),
    };
    let mut walls = Vec::new();
    let mut outputs: Vec<Vec<Block>> = Vec::new();
    for prefetch in [false, true] {
        let topo = Topology::new(nodes, 1, SystemMode::Ray);
        // stealing off isolates the overlap effect: placement is fixed,
        // only *when* the bytes move changes
        let mut exec = RealExecutor::new(topo, Arc::new(Backend::native()))
            .with_stealing(false)
            .with_prefetch(prefetch);
        exec.threads_per_node = 1;
        let stores = StoreSet::new(nodes);
        for (i, (a, b)) in operands.iter().enumerate() {
            stores.put(0, (2 * i) as u64, Arc::new(a.clone()));
            stores.put(0, (2 * i + 1) as u64, Arc::new(b.clone()));
        }
        let rep = exec.run(&plan, &stores).unwrap();
        println!(
            "  prefetch={prefetch:<5} wall={:.4}s  {}",
            rep.wall_secs,
            prefetch_summary(&rep)
        );
        walls.push(rep.wall_secs);
        outputs.push(
            (0..k_tasks)
                .map(|i| stores.fetch(1000 + i as u64).unwrap().as_ref().clone())
                .collect(),
        );
        records.push(PerfRecord {
            op: format!("xnode_matmul_prefetch_{prefetch}"),
            bytes: (3 * n * n * 8 * k_tasks) as u64,
            secs: rep.wall_secs,
            gflops: 2.0 * (n as f64).powi(3) * k_tasks as f64 / rep.wall_secs / 1e9,
        });
        for (nid, p) in rep.prefetch_stats.iter().enumerate() {
            records.push(PerfRecord {
                op: format!("xnode_matmul_prefetch_{prefetch}_node{nid}"),
                bytes: p.prefetch_bytes,
                secs: 0.0,
                gflops: p.prefetch_hits as f64,
            });
        }
    }
    for (o0, o1) in outputs[0].iter().zip(&outputs[1]) {
        assert_eq!(o0.max_abs_diff(o1), 0.0, "prefetch must not change numerics");
    }
    println!(
        "  outputs bit-identical; prefetch speedup: {:.2}x",
        walls[0] / walls[1]
    );

    // (b) skewed GLM on a real session: LSHS placement, real kernels
    let (rows, d, q, steps) = if smoke { (512, 8, 4, 2) } else { (2048, 16, 8, 3) };
    let mut betas: Vec<Block> = Vec::new();
    for prefetch in [false, true] {
        let cfg = SessionConfig::real_small(2, 2).with_prefetch(prefetch);
        let mut sess = Session::new(cfg);
        let (x, y) = nums::glm::classification_data(&mut sess, rows, d, q, 15);
        let sw = Stopwatch::start();
        let res = nums::glm::newton_fit(&mut sess, &x, &y, steps, 0.0).unwrap();
        let secs = sw.secs();
        let last = res.reports.last().and_then(|r| r.real.clone()).expect("real mode");
        println!(
            "  glm  prefetch={prefetch:<5} wall={secs:.4}s  {}",
            prefetch_summary(&last)
        );
        betas.push(sess.fetch(&res.beta).unwrap());
        records.push(PerfRecord {
            op: format!("glm_newton{steps}_prefetch_{prefetch}"),
            bytes: (rows * d * 8) as u64,
            secs,
            gflops: 0.0,
        });
    }
    assert_eq!(
        betas[0].max_abs_diff(&betas[1]),
        0.0,
        "prefetch must not change GLM numerics"
    );
}

/// Plan↔runtime feedback ablation (the PR 5 tentpole): identical skewed
/// workloads with `SessionConfig::feedback` on/off. (a) Skewed GLM — X
/// and y created entirely on node 0 of a 2-node real session
/// (`Session::create_at`), then a multi-step Newton fit. Every iteration
/// re-plans: with feedback on, the second and later plans see the
/// steal/demand bytes and replica copies earlier runs produced (the
/// ClusterState absorbed them), spread placement, and commit transfers
/// the prefetcher can overlap — so hot-path demand pulls shrink. With
/// feedback off the planner keeps placing everything on node 0 and
/// thieves re-pay demand pulls for fresh intermediates every iteration.
/// (b) Cross-node matmul — the same skewed-operand matmul expression run
/// twice in one session; run 2's plan differs only through feedback.
/// Per-node `steal_bytes`/`demand_pull_bytes` land in BENCH_fig09.json
/// (bytes = demand, gflops = steal bytes). Returns the acceptance
/// violation, if any, instead of panicking — the caller fails the bench
/// only after `BENCH_fig09.json` is safely on disk, so one unlucky
/// timing race cannot discard every other section's perf records.
fn feedback_ablation(records: &mut Vec<PerfRecord>, smoke: bool) -> Option<String> {
    println!("## Fig 9 (ext): plan↔runtime feedback ablation (skewed layouts)");
    // (a) skewed GLM on 2 nodes: all creation blocks on node 0
    let (rows, d, q, steps) = if smoke { (512, 8, 8, 3) } else { (2048, 16, 8, 4) };
    let mut demand_sums = Vec::new();
    for feedback in [false, true] {
        let cfg = SessionConfig::real_small(2, 2).with_feedback(feedback);
        let mut sess = Session::new(cfg);
        let x = sess.randn_at(&[rows, d], &[q, 1], 0);
        let y = sess.create_at(&[rows, 1], &[q, 1], 0, |rng, bs, _| {
            (0..bs.iter().product::<usize>())
                .map(|_| if rng.bool(0.5) { 1.0 } else { 0.0 })
                .collect()
        });
        let sw = Stopwatch::start();
        let res = nums::glm::newton_fit(&mut sess, &x, &y, steps, 1e-6).unwrap();
        let secs = sw.secs();
        let reals: Vec<_> = res.reports.iter().filter_map(|r| r.real.as_ref()).collect();
        // run 1 plans before any feedback exists, so it is identical
        // across the toggle — the ablation counts everything after it
        let demand: u64 = reals
            .iter()
            .skip(1)
            .map(|r| r.feedback.total_demand_bytes())
            .sum();
        let steal: u64 = reals
            .iter()
            .skip(1)
            .map(|r| r.feedback.total_steal_bytes())
            .sum();
        println!(
            "  glm  feedback={feedback:<5} wall={secs:.4}s  demand(after run 1)={demand} B  \
             steal={steal} B"
        );
        println!("       last run: {}", feedback_summary(reals.last().unwrap()));
        records.push(PerfRecord {
            op: format!("skewed_glm_feedback_{feedback}"),
            bytes: demand,
            secs,
            gflops: steal as f64,
        });
        for (nid, f) in reals.last().unwrap().feedback.nodes.iter().enumerate() {
            records.push(PerfRecord {
                op: format!("skewed_glm_feedback_{feedback}_node{nid}"),
                bytes: f.demand_pull_bytes,
                secs: 0.0,
                gflops: f.steal_bytes as f64,
            });
        }
        demand_sums.push(demand);
    }
    let mut violation = None;
    if demand_sums[0] == 0 {
        println!("  (no steal/demand traffic observed — skewed GLM arm degenerate on this host)");
    } else if demand_sums[1] < demand_sums[0] {
        println!(
            "  feedback cut demand pulls {} B -> {} B ({:.1}%)",
            demand_sums[0],
            demand_sums[1],
            100.0 * (1.0 - demand_sums[1] as f64 / demand_sums[0] as f64)
        );
    } else if smoke {
        // the smoke workload is tiny and steal/demand counters are
        // timing-dependent: record the regression loudly, don't fail CI
        println!(
            "  WARNING: smoke run saw no demand-pull improvement (on {} B >= off {} B)",
            demand_sums[1], demand_sums[0]
        );
    } else {
        violation = Some(format!(
            "feedback must strictly reduce demand-pull bytes on the skewed GLM arm \
             (on {} B !< off {} B)",
            demand_sums[1], demand_sums[0]
        ));
    }

    // (b) cross-node matmul: skewed operands, same expression twice
    let m = if smoke { 256usize } else { 512usize };
    for feedback in [false, true] {
        let cfg = SessionConfig::real_small(2, 2).with_feedback(feedback);
        let mut sess = Session::new(cfg);
        let x = sess.randn_at(&[m, m], &[2, 2], 0);
        let yv = sess.randn_at(&[m, m], &[2, 2], 0);
        let (_, rep1) = ops::matmul(&mut sess, &x, &yv).unwrap();
        let (_, rep2) = ops::matmul(&mut sess, &x, &yv).unwrap();
        let (r1, r2) = (rep1.real.unwrap(), rep2.real.unwrap());
        println!(
            "  mm   feedback={feedback:<5} run1 demand={} B | run2 demand={} B, plan transfers={}",
            r1.feedback.total_demand_bytes(),
            r2.feedback.total_demand_bytes(),
            rep2.transfers,
        );
        println!("       run2: {}", feedback_summary(&r2));
        for (nid, f) in r2.feedback.nodes.iter().enumerate() {
            records.push(PerfRecord {
                op: format!("xnode_matmul_feedback_{feedback}_node{nid}_run2"),
                bytes: f.demand_pull_bytes,
                secs: 0.0,
                gflops: f.steal_bytes as f64,
            });
        }
    }
    violation
}

/// Plan-cache ablation (the PR 7 tentpole): the same Newton GLM fit with
/// `SessionConfig::plan_cache` on/off on a real 2-node session (stealing
/// off for placement determinism). Every iteration submits the same two
/// graph topologies over the same block layout — the hierarchical-layout
/// pins make each iteration's beta land on the same target — so with the
/// cache on, every run from iteration 2 onward rebinds the memoized plan
/// instead of re-running the LSHS local search: `plan_cache_hit == true`
/// and `simulations == 0`. Per-run `search_secs`/`simulations`/
/// `decisions` land in `BENCH_fig09.json` (bytes = simulations,
/// gflops = decisions), so planning cost finally has numbers to diff.
/// The two fits agree to roundoff (not bitwise across the toggle: the
/// frontier-sampling RNG is session-lifetime state, so even two *fresh*
/// schedules of the same graph may pick different reduce pairings — the
/// bit-identity guarantee is cached-vs-oracle, covered by
/// `tests/plan_cache.rs`).
fn plan_cache_ablation(records: &mut Vec<PerfRecord>, smoke: bool) {
    println!("## Fig 9 (ext): plan-cache ablation (repeated-topology GLM)");
    let (rows, d, q, steps) = if smoke { (256, 8, 4, 3) } else { (1024, 16, 8, 4) };
    let mut betas: Vec<Block> = Vec::new();
    for cache in [false, true] {
        let cfg = SessionConfig::real_small(2, 2)
            .with_stealing(false)
            .with_plan_cache(cache);
        let mut sess = Session::new(cfg);
        let (x, y) = nums::glm::classification_data(&mut sess, rows, d, q, 15);
        let sw = Stopwatch::start();
        let res = nums::glm::newton_fit(&mut sess, &x, &y, steps, 0.0).unwrap();
        let secs = sw.secs();
        let search: f64 = res.reports.iter().map(|r| r.search_secs).sum();
        let sims: u64 = res.reports.iter().map(|r| r.simulations).sum();
        println!("  glm cache={cache:<5} wall={secs:.4}s search={search:.6}s sims={sims}");
        for (i, rep) in res.reports.iter().enumerate() {
            println!("    run{i}: {}", planning_summary(rep));
        }
        if cache {
            assert!(res.reports[0].simulations > 0, "iteration 1 must search");
            // reports 0/1 are iteration 1's two graphs (cold); from
            // iteration 2 on, both graphs replay memoized plans
            for rep in &res.reports[2..] {
                assert!(rep.plan_cache_hit, "iteration >= 2 must hit the cache");
                assert_eq!(rep.simulations, 0, "a hit skips the local search");
            }
        } else {
            assert!(
                res.reports.iter().all(|r| !r.plan_cache_hit),
                "cache off must never report a hit"
            );
        }
        betas.push(sess.fetch(&res.beta).unwrap());
        records.push(PerfRecord {
            op: format!("glm_newton{steps}_plan_cache_{cache}"),
            bytes: sims,
            secs: search,
            gflops: 0.0,
        });
        for (i, rep) in res.reports.iter().enumerate() {
            records.push(PerfRecord {
                op: format!("glm_newton{steps}_plan_cache_{cache}_run{i}"),
                bytes: rep.simulations,
                secs: rep.search_secs,
                gflops: rep.decisions as f64,
            });
        }
    }
    let scale = betas[0].buf().iter().fold(1.0f64, |m, v| m.max(v.abs()));
    let rel = betas[0].max_abs_diff(&betas[1]) / scale;
    assert!(
        rel < 1e-7,
        "plan-cache toggle changed GLM numerics beyond roundoff: rel {rel:e}"
    );
    println!("  betas agree across the toggle (rel diff {rel:.2e})");
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    // 64 GB-shape operands (2^27 x 64 f64) — modeled time, phantom blocks.
    let rows = 1usize << 27;
    let d = 64usize;
    let parts: Vec<usize> = if smoke {
        vec![16, 64]
    } else {
        vec![16, 32, 48, 64, 96, 128]
    };

    series("Fig 9: X + Y [modeled s]", |p, m, q| run_case(p, m, rows, d, q, add), &parts);
    series("Fig 9: X @ y [modeled s]", |p, m, q| run_matvec(p, m, rows, d, q), &parts);
    series("Fig 9: Xᵀ @ y [modeled s]", |p, m, q| run_tn_vec(p, m, rows, d, q), &parts);
    series("Fig 9: Xᵀ @ Y [modeled s]", |p, m, q| run_case(p, m, rows, d, q, xty), &parts);
    // outer product: smaller rows so the n x n output stays sane
    series(
        "Fig 9: X @ Yᵀ [modeled s] (2^18 x 2048 operands)",
        |p, m, q| run_case(p, m, 1 << 18, 2048, q, xyt),
        &parts,
    );
    series("Fig 9: sum(X, 0) [modeled s]", |p, m, q| run_case(p, m, rows, d, q, sum0), &parts);

    let mut records = Vec::new();
    chain_ablation(&mut records, smoke);
    kernel_shootout(&mut records, smoke);
    epilogue_ablation(&mut records, smoke);
    stealing_ablation(&mut records, smoke);
    memory_ablation(&mut records, smoke);
    overlap_ablation(&mut records, smoke);
    plan_cache_ablation(&mut records, smoke);
    let feedback_violation = feedback_ablation(&mut records, smoke);
    emit_json("BENCH_fig09.json", &records).expect("write BENCH_fig09.json");
    println!("wrote BENCH_fig09.json ({} records)", records.len());
    // fail only after the perf trajectory is safely on disk
    if let Some(msg) = feedback_violation {
        panic!("{msg}");
    }
}
