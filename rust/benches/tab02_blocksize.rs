//! Table 2 — DGEMM block-size tuning: for each data size (2 GB on 1 node …
//! 32 GB on 16 nodes) sweep the block dimension and report the optimum per
//! system, reproducing the table's "NumS prefers much larger blocks than
//! ScaLAPACK/SLATE" structure.

use nums::prelude::*;
use nums::util::fmt::render_table;

fn nums_time(nodes: usize, n: usize, g: usize) -> f64 {
    let cfg = nums::api::SessionConfig::paper_sim(nodes, 32)
        .with_node_grid(NodeGrid::square_ish(nodes));
    let mut sess = nums::api::Session::new(cfg);
    let a = sess.zeros(&[n, n], &[g, g]);
    let b = sess.zeros(&[n, n], &[g, g]);
    let mut graph = Graph::new();
    build::matmul(&mut graph, &a, &b);
    let (_, rep) = sess.run(&mut graph).unwrap();
    rep.sim.makespan
}

fn main() {
    let cases = [(1usize, 2usize), (2, 4), (4, 8), (8, 16), (16, 32)];
    let mut rows = Vec::new();
    for (nodes, gb) in cases {
        let n = (((gb as f64) * 1e9 / 8.0).sqrt()) as usize;
        // NumS: sweep block grid counts, pick the best
        let mut best = (0usize, f64::INFINITY);
        for g in [2usize, 4, 8, 16, 32] {
            if g * g < nodes || g > 64 {
                continue;
            }
            let t = nums_time(nodes, n, g);
            if t < best.1 {
                best = (n / g, t);
            }
        }
        // SUMMA side: block dim fixed by the process grid; report both the
        // per-node and per-worker block dimension the algorithm implies
        let side = (nodes as f64).sqrt().round().max(1.0) as usize;
        let summa_block = n / (side * side.max(1));
        rows.push(vec![
            format!("{gb} GB / {nodes} nodes"),
            format!("{n}"),
            format!("{}", best.0),
            format!("{:.3}", best.1),
            format!("{summa_block}"),
        ]);
    }
    println!("## Table 2: DGEMM block-size tuning (modeled)");
    println!(
        "{}",
        render_table(
            &["case", "matrix n", "NumS best block", "NumS best time [s]", "SUMMA block"],
            &rows
        )
    );
    println!("(paper: NumS optimum ~4-6x larger than ScaLAPACK/SLATE block sizes)");
}
