//! Fig. 14 — logistic regression fitting time on 16 nodes, size sweep:
//! (a) Newton: NumS (LSHS, tree-reduced fused blocks) vs Dask ML
//!     (driver-side aggregation) vs NumS-without-LSHS;
//! (b) L-BFGS (10 steps, history 10): NumS vs Spark MLlib (static
//!     schedule, heavier per-task overhead).
//!
//! `cargo bench --bench fig14_logreg -- --smoke` instead runs the CI
//! plan-cache check: iteration >= 2 of a sim-mode Newton fit must replay
//! the memoized plan (`plan_cache_hit`, zero candidate simulations).

use nums::api::{Policy, Session, SessionConfig};
use nums::bench::harness::{planning_summary, print_series};
use nums::glm::data::classification_data;
use nums::glm::{lbfgs_fit, newton_fit, newton_fit_driver_agg};
use nums::prelude::*;

/// `--smoke` (CI): a bounded sim-mode Newton fit exercising the plan
/// cache across iterations. Each Newton iteration submits the same two
/// graph topologies over the same block layout, so iteration 1 pays the
/// LSHS local search and every later iteration must replay the memoized
/// plan: `plan_cache_hit == true` with strictly fewer candidate
/// simulations than iteration 1 (exactly zero).
fn smoke() {
    let mut sess = Session::new(SessionConfig::paper_sim(4, 4));
    let (x, y) = classification_data(&mut sess, 1 << 14, 16, 8, 3);
    let res = newton_fit(&mut sess, &x, &y, 3, 0.0).unwrap();
    for (i, rep) in res.reports.iter().enumerate() {
        println!("run{i}: {}", planning_summary(rep));
    }
    // reports 0/1 are iteration 1's two graphs; 2/3 are iteration 2's
    let it1 = &res.reports[0];
    let it2 = &res.reports[2];
    assert!(
        !it1.plan_cache_hit && it1.simulations > 0,
        "iteration 1 must run the local search"
    );
    assert!(it2.plan_cache_hit, "iteration 2 must hit the plan cache");
    assert!(
        it2.simulations < it1.simulations,
        "a hit must simulate strictly less than the cold iteration \
         ({} !< {})",
        it2.simulations,
        it1.simulations
    );
    assert_eq!(it2.simulations, 0, "a hit replays; it never simulates");
    let (hits, misses, stale) = sess.plan_cache_stats();
    println!("plan cache: {hits} hits / {misses} misses / {stale} stale re-plans");
    assert!(
        hits >= 4,
        "both graphs of iterations 2 and 3 must hit, got {hits}"
    );
    println!("fig14 smoke: iteration-2 plan-cache hit verified");
}

fn main() {
    if std::env::args().any(|a| a == "--smoke") {
        smoke();
        return;
    }
    let d = 256usize;
    let sizes_gb = [64usize, 128, 256, 512, 1024];
    let steps = 2; // per-iteration cost is the comparison; keep runs fast

    // ---- (a) Newton ----
    let mut xs = Vec::new();
    let (mut nums_t, mut dask_t, mut nolshs_t) = (Vec::new(), Vec::new(), Vec::new());
    for &gb in &sizes_gb {
        let rows = (gb as f64 * 1e9 / (d as f64 * 8.0)) as usize;
        let q = (gb / 2).max(16); // 2 GB blocks (§8.5)
        xs.push(format!("{gb}GB"));

        let mut sess = Session::new(SessionConfig::paper_sim(16, 32));
        let (x, y) = classification_data(&mut sess, rows, d, q, 1);
        nums_t.push(newton_fit(&mut sess, &x, &y, steps, 0.0).unwrap().sim_secs());

        let mut sess = Session::new(SessionConfig::paper_sim(16, 32));
        let (x, y) = classification_data(&mut sess, rows, d, q, 1);
        dask_t.push(
            newton_fit_driver_agg(&mut sess, &x, &y, steps)
                .unwrap()
                .sim_secs(),
        );

        let mut sess =
            Session::new(SessionConfig::paper_sim(16, 32).with_policy(Policy::BottomUp));
        let (x, y) = classification_data(&mut sess, rows, d, q, 1);
        nolshs_t.push(newton_fit(&mut sess, &x, &y, steps, 0.0).unwrap().sim_secs());
    }
    print_series(
        "Fig 14a: logistic regression, Newton [modeled s]",
        "size",
        &xs,
        &[
            ("NumS (LSHS)".into(), nums_t.clone()),
            ("Dask ML (driver agg)".into(), dask_t.clone()),
            ("NumS w/o LSHS".into(), nolshs_t),
        ],
    );
    println!(
        "NumS vs Dask-ML at 1 TB: {:.2}x (paper: ~2x)",
        dask_t.last().unwrap() / nums_t.last().unwrap()
    );

    // ---- (b) L-BFGS ----
    let mut xs = Vec::new();
    let (mut nums_t, mut spark_t) = (Vec::new(), Vec::new());
    for &gb in &sizes_gb {
        let rows = (gb as f64 * 1e9 / (d as f64 * 8.0)) as usize;
        let q = (gb / 2).max(16);
        xs.push(format!("{gb}GB"));

        let mut sess = Session::new(SessionConfig::paper_sim(16, 32));
        let (x, y) = classification_data(&mut sess, rows, d, q, 2);
        nums_t.push(lbfgs_fit(&mut sess, &x, &y, 10, 10, 0.0).unwrap().sim_secs());

        // Spark: same static algorithm, heavier task overhead, no γ
        let mut cfg = SessionConfig::paper_sim(16, 32);
        cfg.net = NetParams {
            gamma: 2e-4, // JVM task-launch latency >= Ray dispatch
            ..NetParams::paper_testbed()
        };
        cfg.compute = ComputeParams {
            task_overhead: 2e-3,
            ..ComputeParams::paper_testbed()
        };
        let mut sess = Session::new(cfg);
        let (x, y) = classification_data(&mut sess, rows, d, q, 2);
        spark_t.push(lbfgs_fit(&mut sess, &x, &y, 10, 10, 0.0).unwrap().sim_secs());
    }
    print_series(
        "Fig 14b: logistic regression, L-BFGS 10 steps [modeled s]",
        "size",
        &xs,
        &[
            ("NumS (LSHS)".into(), nums_t.clone()),
            ("Spark MLlib".into(), spark_t.clone()),
        ],
    );
    println!(
        "NumS vs Spark at 1 TB: {:.2}x (paper: up to 2x)",
        spark_t.last().unwrap() / nums_t.last().unwrap()
    );
}
