//! Fig. 14 — logistic regression fitting time on 16 nodes, size sweep:
//! (a) Newton: NumS (LSHS, tree-reduced fused blocks) vs Dask ML
//!     (driver-side aggregation) vs NumS-without-LSHS;
//! (b) L-BFGS (10 steps, history 10): NumS vs Spark MLlib (static
//!     schedule, heavier per-task overhead).

use nums::api::{Policy, Session, SessionConfig};
use nums::bench::harness::print_series;
use nums::glm::data::classification_data;
use nums::glm::{lbfgs_fit, newton_fit, newton_fit_driver_agg};
use nums::prelude::*;

fn main() {
    let d = 256usize;
    let sizes_gb = [64usize, 128, 256, 512, 1024];
    let steps = 2; // per-iteration cost is the comparison; keep runs fast

    // ---- (a) Newton ----
    let mut xs = Vec::new();
    let (mut nums_t, mut dask_t, mut nolshs_t) = (Vec::new(), Vec::new(), Vec::new());
    for &gb in &sizes_gb {
        let rows = (gb as f64 * 1e9 / (d as f64 * 8.0)) as usize;
        let q = (gb / 2).max(16); // 2 GB blocks (§8.5)
        xs.push(format!("{gb}GB"));

        let mut sess = Session::new(SessionConfig::paper_sim(16, 32));
        let (x, y) = classification_data(&mut sess, rows, d, q, 1);
        nums_t.push(newton_fit(&mut sess, &x, &y, steps, 0.0).unwrap().sim_secs());

        let mut sess = Session::new(SessionConfig::paper_sim(16, 32));
        let (x, y) = classification_data(&mut sess, rows, d, q, 1);
        dask_t.push(
            newton_fit_driver_agg(&mut sess, &x, &y, steps)
                .unwrap()
                .sim_secs(),
        );

        let mut sess =
            Session::new(SessionConfig::paper_sim(16, 32).with_policy(Policy::BottomUp));
        let (x, y) = classification_data(&mut sess, rows, d, q, 1);
        nolshs_t.push(newton_fit(&mut sess, &x, &y, steps, 0.0).unwrap().sim_secs());
    }
    print_series(
        "Fig 14a: logistic regression, Newton [modeled s]",
        "size",
        &xs,
        &[
            ("NumS (LSHS)".into(), nums_t.clone()),
            ("Dask ML (driver agg)".into(), dask_t.clone()),
            ("NumS w/o LSHS".into(), nolshs_t),
        ],
    );
    println!(
        "NumS vs Dask-ML at 1 TB: {:.2}x (paper: ~2x)",
        dask_t.last().unwrap() / nums_t.last().unwrap()
    );

    // ---- (b) L-BFGS ----
    let mut xs = Vec::new();
    let (mut nums_t, mut spark_t) = (Vec::new(), Vec::new());
    for &gb in &sizes_gb {
        let rows = (gb as f64 * 1e9 / (d as f64 * 8.0)) as usize;
        let q = (gb / 2).max(16);
        xs.push(format!("{gb}GB"));

        let mut sess = Session::new(SessionConfig::paper_sim(16, 32));
        let (x, y) = classification_data(&mut sess, rows, d, q, 2);
        nums_t.push(lbfgs_fit(&mut sess, &x, &y, 10, 10, 0.0).unwrap().sim_secs());

        // Spark: same static algorithm, heavier task overhead, no γ
        let mut cfg = SessionConfig::paper_sim(16, 32);
        cfg.net = NetParams {
            gamma: 2e-4, // JVM task-launch latency >= Ray dispatch
            ..NetParams::paper_testbed()
        };
        cfg.compute = ComputeParams {
            task_overhead: 2e-3,
            ..ComputeParams::paper_testbed()
        };
        let mut sess = Session::new(cfg);
        let (x, y) = classification_data(&mut sess, rows, d, q, 2);
        spark_t.push(lbfgs_fit(&mut sess, &x, &y, 10, 10, 0.0).unwrap().sim_secs());
    }
    print_series(
        "Fig 14b: logistic regression, L-BFGS 10 steps [modeled s]",
        "size",
        &xs,
        &[
            ("NumS (LSHS)".into(), nums_t.clone()),
            ("Spark MLlib".into(), spark_t.clone()),
        ],
    );
    println!(
        "NumS vs Spark at 1 TB: {:.2}x (paper: up to 2x)",
        spark_t.last().unwrap() / nums_t.last().unwrap()
    );
}
