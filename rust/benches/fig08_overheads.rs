//! Fig. 8 — control overhead (γ) and RFC overhead.
//!
//! (a) Control overhead: modeled + measured driver-side cost of allocating
//!     a 1024-dim vector as p blocks on a 16-node/1024-worker cluster —
//!     the γ·p dispatch term dominates as p grows (paper Fig. 8a).
//! (b) RFC overhead: `-x` on one block, PJRT execution (object-store write
//!     included) vs a direct native call (the NumPy baseline) — the R(n)
//!     constant of Fig. 8b.

use nums::bench::harness::print_series;
use nums::prelude::*;
use nums::util::Stopwatch;

fn fig8a() {
    let net = NetParams::paper_testbed();
    let mut xs = Vec::new();
    let mut modeled = Vec::new();
    let mut sched_wall = Vec::new();
    for p in [4usize, 16, 64, 256, 1024] {
        let cfg = nums::api::SessionConfig::paper_sim(16, 64);
        let mut sess = nums::api::Session::new(cfg);
        let x = sess.zeros(&[1024, 1], &[p.min(1024), 1]);
        // dispatch-only workload: one unary op per block
        let sw = Stopwatch::start();
        let (_, rep) = nums::api::ops::neg(&mut sess, &x).unwrap();
        let wall = sw.secs();
        xs.push(format!("{p}"));
        modeled.push(rep.sim.dispatch_time.max(net.gamma * p as f64));
        sched_wall.push(wall);
    }
    print_series(
        "Fig 8a: control overhead — allocate 1024-dim vector as p blocks (16 nodes, 1024 workers)",
        "blocks",
        &xs,
        &[
            ("modeled dispatch gamma*p [s]".into(), modeled),
            ("measured driver wall [s]".into(), sched_wall),
        ],
    );
}

fn fig8b() {
    let backend_pjrt = Backend::pjrt(nums::runtime::Manifest::default_dir()).ok();
    let mut xs = Vec::new();
    let mut pjrt_t = Vec::new();
    let mut native_t = Vec::new();
    let mut rng = Rng::seed_from_u64(8);
    for n in [64usize, 256] {
        let mut v = vec![0.0; n * n];
        rng.fill_normal(&mut v);
        let x = Block::from_vec(&[n, n], v);
        let trials = 50;
        // native "NumPy" call
        let sw = Stopwatch::start();
        for _ in 0..trials {
            nums::runtime::native::execute(&Kernel::Neg, &[&x]).unwrap();
        }
        native_t.push(sw.secs() / trials as f64);
        // PJRT RFC (literal copies model the object-store round trip)
        if let Some(b) = &backend_pjrt {
            let ctx = ExecContext::host_default();
            b.execute(&Kernel::Neg, &[&x], &ctx).unwrap(); // warmup compile
            let sw = Stopwatch::start();
            for _ in 0..trials {
                b.execute(&Kernel::Neg, &[&x], &ctx).unwrap();
            }
            pjrt_t.push(sw.secs() / trials as f64);
        } else {
            pjrt_t.push(f64::NAN);
        }
        xs.push(format!("{n}x{n}"));
    }
    print_series(
        "Fig 8b: RFC overhead — neg(x) per call (runtime+store vs direct native)",
        "block",
        &xs,
        &[
            ("PJRT RFC [s]".into(), pjrt_t.clone()),
            ("native direct [s]".into(), native_t.clone()),
            (
                "overhead [s]".into(),
                pjrt_t
                    .iter()
                    .zip(&native_t)
                    .map(|(a, b)| a - b)
                    .collect(),
            ),
        ],
    );
}

fn main() {
    fig8a();
    fig8b();
}
