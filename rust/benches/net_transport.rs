//! Measured (not modeled) per-transfer transport baselines — the
//! fig15-style arm for the block-carrier layer. One deliberately skewed
//! matmul pipeline (every input on node 0, every task on node 1) is run
//! on each transport with per-transfer metrics on; the carriers' own
//! `TransferRecord`s — real bytes over real wall time, over real
//! `/dev/shm` files and real loopback sockets for the non-default
//! transports — land in `BENCH_net.json` as per-transfer latency and
//! bandwidth. `cargo bench --bench net_transport -- --smoke` runs a
//! reduced size and additionally asserts cross-transport bit identity.

use std::collections::HashMap;
use std::net::{SocketAddr, TcpListener};
use std::sync::Arc;

use nums::exec::{Plan, RealExecutor, Task};
use nums::net::{serve_node, InProcessTransport, ShmTransport, TcpTransport, Transport};
use nums::prelude::*;
use nums::store::StoreSet;

/// Skewed pipeline: `k` matmuls, inputs seeded on node 0, all targeted
/// at node 1 — every input block crosses the wire exactly once.
fn skewed_plan(n: usize, k: usize) -> (Plan, HashMap<u64, Block>) {
    let mut rng = Rng::seed_from_u64(0xBE7);
    let mut seeds = HashMap::new();
    for i in 0..2 * k as u64 {
        let mut v = vec![0.0; n * n];
        rng.fill_normal(&mut v);
        seeds.insert(i, Block::from_vec(&[n, n], v));
    }
    let plan = Plan {
        tasks: (0..k)
            .map(|i| Task {
                kernel: Kernel::Matmul,
                inputs: vec![(2 * i) as u64, (2 * i + 1) as u64],
                in_shapes: vec![vec![n, n], vec![n, n]],
                outputs: vec![(1000 + i as u64, vec![n, n])],
                target: 1,
                transfers: vec![],
            })
            .collect(),
    };
    (plan, seeds)
}

fn in_thread_daemons(nodes: usize) -> Vec<SocketAddr> {
    (0..nodes)
        .map(|_| {
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            let addr = listener.local_addr().unwrap();
            std::thread::spawn(move || serve_node(listener));
            addr
        })
        .collect()
}

struct Row {
    transport: &'static str,
    transfers: usize,
    bytes: u64,
    mean_us: f64,
    max_us: f64,
    gb_per_s: f64,
    wall_secs: f64,
}

/// Run the skewed pipeline on `transport`, returning the measured row
/// and the output bits (for the smoke identity check).
fn run_one(
    label: &'static str,
    transport: Arc<dyn Transport>,
    n: usize,
    k: usize,
) -> (Row, Vec<u64>) {
    let (plan, seeds) = skewed_plan(n, k);
    let stores = StoreSet::with_transport(2, transport);
    for (obj, b) in &seeds {
        stores.put(0, *obj, Arc::new(b.clone()));
    }
    let topo = Topology::new(2, 2, SystemMode::Ray);
    let mut exec = RealExecutor::new(topo, Arc::new(Backend::native()))
        .with_stealing(false)
        .with_prefetch(true);
    exec.threads_per_node = 2;
    let t0 = std::time::Instant::now();
    exec.run(&plan, &stores).expect("bench run");
    let wall_secs = t0.elapsed().as_secs_f64();
    let mut bits = Vec::new();
    for i in 0..k {
        let out = stores.fetch(1000 + i as u64).expect("output");
        bits.extend(out.buf().iter().map(|v| v.to_bits()));
    }
    let records = stores.transport().records();
    stores.transport().shutdown();
    let transfers = records.len();
    let bytes: u64 = records.iter().map(|r| r.bytes).sum();
    let total_secs: f64 = records.iter().map(|r| r.secs).sum();
    let mean_us = if transfers > 0 {
        1e6 * total_secs / transfers as f64
    } else {
        0.0
    };
    let max_us = records.iter().map(|r| 1e6 * r.secs).fold(0.0, f64::max);
    let gb_per_s = if total_secs > 0.0 {
        bytes as f64 / total_secs / 1e9
    } else {
        0.0
    };
    (
        Row { transport: label, transfers, bytes, mean_us, max_us, gb_per_s, wall_secs },
        bits,
    )
}

fn emit(path: &str, rows: &[Row]) {
    let mut s = String::from("[\n");
    for (i, r) in rows.iter().enumerate() {
        s.push_str(&format!(
            "  {{\"transport\": \"{}\", \"transfers\": {}, \"bytes\": {}, \
             \"mean_us\": {:.3}, \"max_us\": {:.3}, \"gb_per_s\": {:.4}, \
             \"wall_secs\": {:.6}}}{}\n",
            r.transport,
            r.transfers,
            r.bytes,
            r.mean_us,
            r.max_us,
            r.gb_per_s,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    s.push_str("]\n");
    std::fs::write(path, &s).expect("write BENCH_net.json");
    // the hand-rolled writer must stay parseable by the repo's reader
    nums::util::json::parse(&s).expect("BENCH_net.json round-trips");
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (n, k) = if smoke { (64usize, 8usize) } else { (256usize, 32usize) };
    println!(
        "net transport baselines: {k} matmuls of {n}x{n} blocks, all inputs shipped node0 -> node1"
    );

    let mut rows = Vec::new();

    let (row, inproc_bits) = run_one(
        "in-process",
        Arc::new(InProcessTransport::with_metrics()),
        n,
        k,
    );
    rows.push(row);

    let (row, shm_bits) =
        run_one("shm", Arc::new(ShmTransport::new().expect("/dev/shm dir")), n, k);
    rows.push(row);

    // prefer real node processes (the launcher path); fall back to
    // in-thread daemons if spawning is unavailable in this environment
    let bin = std::path::PathBuf::from(env!("CARGO_BIN_EXE_nums"));
    let (tcp, tcp_label): (TcpTransport, &'static str) = match TcpTransport::launch(2, &bin) {
        Ok(t) => (t, "tcp"),
        Err(e) => {
            println!("tcp launcher unavailable ({e}); using in-thread daemons");
            (TcpTransport::connect(in_thread_daemons(2)), "tcp-inthread")
        }
    };
    let (row, tcp_bits) = run_one(tcp_label, Arc::new(tcp), n, k);
    rows.push(row);

    for r in &rows {
        println!(
            "  {:<12} {:>4} transfers  {:>12} B  mean {:>9.1} us  max {:>9.1} us  {:>7.3} GB/s  (wall {:.3}s)",
            r.transport, r.transfers, r.bytes, r.mean_us, r.max_us, r.gb_per_s, r.wall_secs
        );
    }
    emit("BENCH_net.json", &rows);
    println!("wrote BENCH_net.json ({} transports)", rows.len());

    // measured means measured: the carriers with a wire in them must
    // have clocked real time on every record
    for r in &rows {
        assert!(r.transfers > 0, "{}: skewed pipeline must transfer", r.transport);
        if r.transport != "in-process" {
            assert!(r.mean_us > 0.0, "{}: transfers must take time", r.transport);
        }
    }
    if smoke {
        assert_eq!(inproc_bits, shm_bits, "shm diverged from in-process");
        assert_eq!(inproc_bits, tcp_bits, "tcp diverged from in-process");
        println!("smoke: all transports bit-identical ({} output words)", inproc_bits.len());
    }
}
