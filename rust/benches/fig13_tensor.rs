//! Fig. 13 — tensor algebra on 16 nodes × 32 workers:
//! (a) MTTKRP (einsum ijk,jf,kf->if, F=100), NumS fused terms + LSHS vs
//!     the Dask-Arrays pairwise einsum (materializes the F×-larger
//!     intermediate) under round-robin scheduling — the paper's 20× gap
//!     at 4 TB;
//! (b) tensor double contraction — roughly a tie (no node grid helps, §8.4).

use nums::api::{ops, Policy, Session, SessionConfig};
use nums::bench::harness::print_series;
use nums::prelude::*;

fn cube_side(bytes: f64) -> usize {
    (bytes / 8.0).powf(1.0 / 3.0) as usize
}

fn main() {
    let f = 100usize;
    let sizes_gb = [8usize, 64, 512, 4096]; // up to 4 TB (Fig. 13 x-axis)

    // ---- (a) MTTKRP ----
    let mut xs = Vec::new();
    let mut nums_t = Vec::new();
    let mut dask_t = Vec::new();
    for &gb in &sizes_gb {
        let side = cube_side(gb as f64 * 1e9);
        xs.push(format!("{gb}GB"));

        // NumS: fused MTTKRP terms, 16x1x1 node grid, partitioned along i/j/k
        let cfg = SessionConfig::paper_sim(16, 32)
            .with_node_grid(NodeGrid::new(&[16, 1, 1]));
        let mut sess = Session::new(cfg);
        let x = sess.zeros(&[side, side, side], &[16, 4, 4]);
        let b = sess.zeros(&[side, f], &[4, 1]);
        let c = sess.zeros(&[side, f], &[4, 1]);
        let (_, rep) = ops::mttkrp(&mut sess, &x, &b, &c).unwrap();
        nums_t.push(rep.sim.makespan);

        // Dask Arrays: pairwise einsum (materializing) + round-robin
        let cfg = SessionConfig::paper_sim(16, 32)
            .with_policy(Policy::RoundRobin)
            .with_mode(SystemMode::Dask);
        let mut sess = Session::new(cfg);
        let x = sess.zeros(&[side, side, side], &[16, 4, 4]);
        let b = sess.zeros(&[side, f], &[4, 1]);
        let c = sess.zeros(&[side, f], &[4, 1]);
        let mut g = Graph::new();
        build::mttkrp_naive(&mut g, &x, &b, &c);
        let (_, rep) = sess.run(&mut g).unwrap();
        dask_t.push(rep.sim.makespan);
    }
    print_series(
        "Fig 13a: MTTKRP, F=100 [modeled s]",
        "X size",
        &xs,
        &[
            ("NumS (fused + LSHS)".into(), nums_t.clone()),
            ("Dask Arrays (pairwise einsum)".into(), dask_t.clone()),
        ],
    );
    println!(
        "speedup at 4 TB: {:.1}x (paper: ~20x, Dask excluded from their figure)",
        dask_t.last().unwrap() / nums_t.last().unwrap()
    );

    // ---- (b) double contraction ----
    let mut xs = Vec::new();
    let mut nums_t = Vec::new();
    let mut dask_t = Vec::new();
    for &gb in &sizes_gb[..3] {
        let side = cube_side(gb as f64 * 1e9);
        xs.push(format!("{gb}GB"));
        // paper's best: 1x16x1 node grid, balanced j/k partitioning
        let cfg = SessionConfig::paper_sim(16, 32)
            .with_node_grid(NodeGrid::new(&[1, 16, 1]));
        let mut sess = Session::new(cfg);
        let x = sess.zeros(&[side, side, side], &[2, 16, 2]);
        let y = sess.zeros(&[side, side, f], &[16, 2, 1]);
        let (_, rep) = ops::tensordot(&mut sess, &x, &y).unwrap();
        nums_t.push(rep.sim.makespan);

        let cfg = SessionConfig::paper_sim(16, 32)
            .with_policy(Policy::RoundRobin)
            .with_mode(SystemMode::Dask);
        let mut sess = Session::new(cfg);
        let x = sess.zeros(&[side, side, side], &[2, 16, 2]);
        let y = sess.zeros(&[side, side, f], &[16, 2, 1]);
        let (_, rep) = ops::tensordot(&mut sess, &x, &y).unwrap();
        dask_t.push(rep.sim.makespan);
    }
    print_series(
        "Fig 13b: double contraction [modeled s] (paper: NumS ≈ Dask)",
        "X size",
        &xs,
        &[
            ("NumS (LSHS, 1x16x1)".into(), nums_t),
            ("Dask Arrays".into(), dask_t),
        ],
    );
}
