//! Fig. 15 + §8.5 summary — per-node memory & network-in traces over one
//! Newton iteration of a 128 GB logistic regression problem on 16 nodes,
//! LSHS vs Ray-without-LSHS. Dumps plot-ready TSV traces to target/ and
//! prints the paper's headline ratios (network 2×, memory 4×, time 10×).
//!
//! The final section replays the memory story on the *real* executor at
//! reduced scale: a multi-iteration Newton fit with the memory manager's
//! lifetime GC on/off, reporting actual per-node peak bytes (and any
//! spill traffic) via `bench::harness::mem_summary` — the measured
//! counterpart of the modeled Fig. 15 curves.

use nums::api::{Policy, Session, SessionConfig};
use nums::bench::harness::{glm_mem_run, max_peak_bytes, mem_summary, timing_breakdown};
use nums::exec::{FaultPlan, NodeLossMode, RecoveryStats};
use nums::glm::data::{classification_data, feature, row_class};
use nums::glm::newton_fit;
use nums::graph::DistArray;
use nums::grid::ArrayGrid;
use nums::metrics::{summarize_trace, trace_to_tsv};
use nums::util::fmt::{human_bytes, human_secs};

struct Outcome {
    time: f64,
    max_net: f64,
    max_mem: f64,
    balance: f64,
}

fn run(policy: Policy, label: &str) -> Outcome {
    let d = 256usize;
    let gb = 128usize;
    let rows = (gb as f64 * 1e9 / (d as f64 * 8.0)) as usize;
    let q = 64; // 2 GB blocks
    let mut cfg = SessionConfig::paper_sim(16, 32).with_policy(policy);
    cfg.record_trace = true;
    let mut sess = Session::new(cfg);
    let (x, y) = classification_data(&mut sess, rows, d, q, 15);
    let res = newton_fit(&mut sess, &x, &y, 1, 0.0).unwrap();
    let rep = &res.reports[0];
    let summary = summarize_trace(&rep.sim.events, 16);

    // dump the trace for plotting
    let path = format!("target/fig15_{label}.tsv");
    std::fs::write(&path, trace_to_tsv(&rep.sim.events)).ok();

    println!("\n=== {label} ===");
    println!("modeled iteration time : {}", human_secs(res.sim_secs()));
    println!("max node peak memory   : {}", human_bytes(summary.max_peak_mem as f64));
    println!("mean node peak memory  : {}", human_bytes(summary.mean_peak_mem));
    println!("max node net-in        : {}", human_bytes(summary.max_net_in as f64));
    println!("memory balance ratio   : {:.2} (1.0 = perfectly clustered curves)", summary.mem_balance_ratio);
    println!("trace written          : {path}");
    Outcome {
        time: res.sim_secs(),
        max_net: summary.max_net_in as f64,
        max_mem: summary.max_peak_mem as f64,
        balance: summary.mem_balance_ratio,
    }
}

/// Real-executor memory ablation: lifetime GC on/off over a 3-iteration
/// Newton fit on a small real cluster (the shared `glm_mem_run` arm, so
/// this section and fig09's memory ablation measure the same protocol).
/// Returns max per-node peak bytes.
fn run_real_memory(gc: bool) -> u64 {
    let (_, last) = glm_mem_run(4, 2, 2048, 16, 16, 3, gc);
    println!("  gc={gc:<5} {}", mem_summary(&last));
    max_peak_bytes(&last)
}

/// Same bimodal classification data as `classification_data`, but every
/// X/y block is created on `target` — the deliberately skewed placement
/// that makes the real traced arm interesting: the plan must ship blocks
/// off node 0, stealing migrates work toward idle nodes, and the
/// divergence report has something to reconcile.
fn skewed_classification_data(
    sess: &mut Session,
    n: usize,
    d: usize,
    q: usize,
    seed: u64,
    target: usize,
) -> (DistArray, DistArray) {
    let xg = ArrayGrid::new(&[n, d], &[q, 1]);
    let xgrid = xg.clone();
    let x = sess.create_at(&[n, d], &[q, 1], target, move |_, bs, coords| {
        let r0 = xgrid.block_offset(0, coords[0]);
        let mut out = Vec::with_capacity(bs[0] * bs[1]);
        for i in 0..bs[0] {
            for j in 0..bs[1] {
                out.push(feature(seed, r0 + i, j));
            }
        }
        out
    });
    let y = sess.create_at(&[n, 1], &[q, 1], target, move |_, bs, coords| {
        let r0 = xg.block_offset(0, coords[0]);
        (0..bs[0])
            .map(|i| if row_class(seed, r0 + i) { 1.0 } else { 0.0 })
            .collect()
    });
    (x, y)
}

/// Recovery arm: the same skewed GLM under a seeded fault plan — rate
/// faults at every site plus one survivable whole-node loss — against
/// its fault-free twin. Proves the bit-identity contract at benchmark
/// scale and measures the recovery overhead (retries, recomputed bytes,
/// added wall time). Returns the JSON fragment for `BENCH_fig15.json`.
fn run_real_recovery(smoke: bool) -> String {
    let nodes = 4usize;
    let (rows, d, q, steps) = if smoke {
        (512, 8, 8, 1)
    } else {
        (4096, 32, 16, 2)
    };
    let fit = |fault: Option<FaultPlan>| {
        // explicit rate-0 default so the fault-free baseline stays
        // fault-free even if NUMS_FAULT_* is armed in the environment
        let cfg = SessionConfig::real_small(nodes, 2)
            .with_fault_plan(fault.unwrap_or_else(|| FaultPlan::new(0, 0.0)));
        let mut sess = Session::new(cfg);
        let (x, y) = skewed_classification_data(&mut sess, rows, d, q, 15, 0);
        let t0 = std::time::Instant::now();
        let res = newton_fit(&mut sess, &x, &y, steps, 0.0).unwrap();
        let secs = t0.elapsed().as_secs_f64();
        let beta = sess.fetch(&res.beta).unwrap();
        let bits: Vec<u64> = beta.into_vec().iter().map(|v| v.to_bits()).collect();
        let mut stats = RecoveryStats::default();
        for rep in &res.reports {
            let r = rep.real.as_ref().expect("real mode");
            stats.retries += r.recovery_stats.retries;
            stats.backoff_secs += r.recovery_stats.backoff_secs;
            stats.recomputed_tasks += r.recovery_stats.recomputed_tasks;
            stats.recomputed_bytes += r.recovery_stats.recomputed_bytes;
            stats.node_losses_survived += r.recovery_stats.node_losses_survived;
        }
        (bits, secs, stats)
    };

    let (clean_bits, clean_secs, clean_stats) = fit(None);
    assert!(clean_stats.is_zero(), "fault-free run must report no recovery work");
    let plan = FaultPlan::new(9, 0.3).with_node_loss(1, 4, NodeLossMode::Survivable);
    let (chaos_bits, chaos_secs, stats) = fit(Some(plan));
    let identical = chaos_bits == clean_bits;

    println!("\n=== recovery arm (rate 0.3 faults + survivable loss of node 1) ===");
    println!("fault-free fit         : {}", human_secs(clean_secs));
    println!(
        "chaos fit              : {} ({:.2}x overhead)",
        human_secs(chaos_secs),
        chaos_secs / clean_secs.max(1e-12)
    );
    println!(
        "recovery work          : {} retries ({} backoff), {} tasks / {} recomputed, {} node loss(es) survived",
        stats.retries,
        human_secs(stats.backoff_secs),
        stats.recomputed_tasks,
        human_bytes(stats.recomputed_bytes as f64),
        stats.node_losses_survived
    );
    println!(
        "bit-identical result   : {}",
        if identical { "yes" } else { "NO — CONTRACT VIOLATED" }
    );
    if smoke {
        assert!(identical, "chaos fit must be bit-identical to the fault-free fit");
        assert_eq!(stats.node_losses_survived, 1, "the scheduled loss must fire");
        assert!(stats.retries > 0, "rate 0.3 must inject transient faults");
    }
    format!(
        "  \"recovery\": {{\"clean_secs\": {:.9}, \"chaos_secs\": {:.9}, \
         \"overhead_ratio\": {:.6}, \"retries\": {}, \"backoff_secs\": {:.9}, \
         \"recomputed_tasks\": {}, \"recomputed_bytes\": {}, \
         \"node_losses_survived\": {}, \"bit_identical\": {}}}\n",
        clean_secs,
        chaos_secs,
        chaos_secs / clean_secs.max(1e-12),
        stats.retries,
        stats.backoff_secs,
        stats.recomputed_tasks,
        stats.recomputed_bytes,
        stats.node_losses_survived,
        identical
    )
}

/// The tentpole's real-executor arm: a skewed GLM fit with tracing on.
/// Folds the run's spans/events into per-node *measured* load series
/// (same `summarize_trace`/`trace_to_tsv` machinery as the modeled
/// curves above), prints the plan-vs-actual divergence report, and emits
/// the machine-readable rollup into `BENCH_fig15.json`.
fn run_real_traced(smoke: bool) {
    let nodes = 4usize;
    let (rows, d, q, steps) = if smoke {
        (512, 8, 8, 1)
    } else {
        (4096, 32, 16, 2)
    };
    let cfg = SessionConfig::real_small(nodes, 2).with_tracing(true);
    let mut sess = Session::new(cfg);
    let (x, y) = skewed_classification_data(&mut sess, rows, d, q, 15, 0);
    let res = newton_fit(&mut sess, &x, &y, steps, 0.0).unwrap();
    let rep = res.reports.last().expect("at least one run");
    let real = rep.real.as_ref().expect("real mode");
    let tr = rep.trace().expect("tracing was on");

    println!("\n=== real traced run (skewed placement: all blocks born on node 0) ===");
    let summary = summarize_trace(&tr.series_events, nodes);
    println!("tasks traced           : {} spans ({} dropped)", tr.spans.len(), tr.dropped_spans);
    println!("max node peak memory   : {}", human_bytes(summary.max_peak_mem as f64));
    println!("max node net-in        : {}", human_bytes(summary.max_net_in as f64));
    println!("memory balance ratio   : {:.2}", summary.mem_balance_ratio);
    let path = "target/fig15_real.tsv";
    std::fs::write(path, trace_to_tsv(&tr.series_events)).ok();
    println!("measured trace written : {path}");
    println!("{}", tr.divergence.summary());
    let breakdown = timing_breakdown(rep);
    println!("timing: {}", breakdown.summary());

    let recovery_json = run_real_recovery(smoke);

    // Machine-readable rollup: per-node measured series summary, the
    // divergence reconciliation, the recovery-overhead arm, and the
    // uniform timing breakdown.
    // Hand-rolled (no serde offline); shape checked by the --smoke arm
    // and the runtime_trace round-trip test.
    let mut s = String::from("{\n  \"bench\": \"fig15_real_traced\",\n");
    s.push_str(&format!(
        "  \"spans\": {}, \"dropped_spans\": {}, \"migrated_tasks\": {},\n",
        tr.spans.len(),
        tr.dropped_spans,
        tr.divergence.migrated_tasks()
    ));
    s.push_str(&format!(
        "  \"timing\": {{\"plan_secs\": {:.9}, \"search_secs\": {:.9}, \"exec_secs\": {:.9}, \
         \"io_secs\": {:.9}, \"io_bytes\": {}, \"plan_cache_hit\": {}}},\n",
        breakdown.plan_secs,
        breakdown.search_secs,
        breakdown.exec_secs,
        breakdown.io_secs,
        breakdown.io_bytes,
        breakdown.plan_cache_hit
    ));
    s.push_str("  \"nodes\": [\n");
    let series = nums::metrics::per_node_series(&tr.series_events, nodes);
    for (i, nd) in tr.divergence.nodes.iter().enumerate() {
        let se = &series[i];
        s.push_str(&format!(
            "    {{\"node\": {}, \"peak_mem\": {}, \"net_in\": {}, \"points\": {}, \
             \"planned_tasks\": {}, \"observed_tasks\": {}, \"planned_in\": {}, \
             \"observed_in\": {}, \"prefetch_in\": {}, \"demand_in\": {}, \
             \"spilled\": {}, \"readback\": {}}}{}\n",
            nd.node,
            se.peak_mem(),
            se.final_net_in(),
            se.t.len(),
            nd.planned_tasks,
            nd.observed_tasks,
            nd.planned_in_bytes,
            nd.observed_in_bytes,
            nd.prefetch_in_bytes,
            nd.demand_in_bytes,
            nd.spilled_bytes,
            nd.readback_bytes,
            if i + 1 < nodes { "," } else { "" }
        ));
    }
    s.push_str("  ],\n");
    s.push_str(&recovery_json);
    s.push_str("}\n");
    std::fs::write("BENCH_fig15.json", &s).expect("write BENCH_fig15.json");
    println!("rollup written         : BENCH_fig15.json");

    if smoke {
        // CI smoke assertions: the invariants the trace suite proves at
        // unit scale must also hold on this end-to-end workload.
        assert_eq!(tr.spans.len(), real.tasks, "one span per executed task");
        assert_eq!(tr.dropped_spans, 0, "ring must not wrap at this scale");
        for nd in &tr.divergence.nodes {
            assert_eq!(
                nd.observed_in_bytes,
                nd.prefetch_in_bytes + nd.demand_in_bytes,
                "node {}: every inbound byte is prefetch or demand",
                nd.node
            );
        }
        let parsed = nums::util::json::parse(&s).expect("rollup must be valid JSON");
        let arr = parsed.get("nodes").and_then(|v| v.as_arr()).expect("nodes array");
        assert_eq!(arr.len(), nodes);
        let rec = parsed.get("recovery").expect("recovery arm in rollup");
        assert_eq!(
            rec.get("bit_identical").and_then(|v| v.as_bool()),
            Some(true),
            "rollup must record the proven bit-identity"
        );
        println!("--smoke OK: {} spans reconciled across {nodes} nodes", tr.spans.len());
    }
}

fn main() {
    if std::env::args().any(|a| a == "--smoke") {
        run_real_traced(true);
        return;
    }
    let lshs = run(Policy::Lshs, "lshs");
    let nolshs = run(Policy::BottomUp, "no_lshs");

    println!("\n=== §8.5 headline ratios (no-LSHS / LSHS) ===");
    println!(
        "network load : {} vs {} (paper: 2x; here LSHS moves ~nothing because data is \
         pre-resident, so we report absolutes)",
        nums::util::fmt::human_bytes(nolshs.max_net),
        nums::util::fmt::human_bytes(lshs.max_net),
    );
    println!(
        "memory       : {:.1}x   (paper: 4x)",
        nolshs.max_mem / lshs.max_mem.max(1.0)
    );
    println!(
        "exec time    : {:.1}x   (paper: 10x)",
        nolshs.time / lshs.time.max(1e-12)
    );
    println!(
        "balance      : LSHS {:.2} vs no-LSHS {:.2} (lower = denser clustering)",
        lshs.balance, nolshs.balance
    );

    println!("\n=== real-executor memory ablation (lifetime GC, 3 Newton iterations) ===");
    let peak_nogc = run_real_memory(false);
    let peak_gc = run_real_memory(true);
    println!(
        "max node peak: {} (no GC) vs {} (GC)  ->  {:.2}x less memory",
        human_bytes(peak_nogc as f64),
        human_bytes(peak_gc as f64),
        peak_nogc as f64 / peak_gc.max(1) as f64
    );

    run_real_traced(false);
}
