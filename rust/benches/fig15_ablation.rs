//! Fig. 15 + §8.5 summary — per-node memory & network-in traces over one
//! Newton iteration of a 128 GB logistic regression problem on 16 nodes,
//! LSHS vs Ray-without-LSHS. Dumps plot-ready TSV traces to target/ and
//! prints the paper's headline ratios (network 2×, memory 4×, time 10×).
//!
//! The final section replays the memory story on the *real* executor at
//! reduced scale: a multi-iteration Newton fit with the memory manager's
//! lifetime GC on/off, reporting actual per-node peak bytes (and any
//! spill traffic) via `bench::harness::mem_summary` — the measured
//! counterpart of the modeled Fig. 15 curves.

use nums::api::{Policy, Session, SessionConfig};
use nums::bench::harness::{glm_mem_run, max_peak_bytes, mem_summary};
use nums::glm::data::classification_data;
use nums::glm::newton_fit;
use nums::metrics::{summarize_trace, trace_to_tsv};
use nums::util::fmt::{human_bytes, human_secs};

struct Outcome {
    time: f64,
    max_net: f64,
    max_mem: f64,
    balance: f64,
}

fn run(policy: Policy, label: &str) -> Outcome {
    let d = 256usize;
    let gb = 128usize;
    let rows = (gb as f64 * 1e9 / (d as f64 * 8.0)) as usize;
    let q = 64; // 2 GB blocks
    let mut cfg = SessionConfig::paper_sim(16, 32).with_policy(policy);
    cfg.record_trace = true;
    let mut sess = Session::new(cfg);
    let (x, y) = classification_data(&mut sess, rows, d, q, 15);
    let res = newton_fit(&mut sess, &x, &y, 1, 0.0).unwrap();
    let rep = &res.reports[0];
    let summary = summarize_trace(&rep.sim.events, 16);

    // dump the trace for plotting
    let path = format!("target/fig15_{label}.tsv");
    std::fs::write(&path, trace_to_tsv(&rep.sim.events)).ok();

    println!("\n=== {label} ===");
    println!("modeled iteration time : {}", human_secs(res.sim_secs()));
    println!("max node peak memory   : {}", human_bytes(summary.max_peak_mem as f64));
    println!("mean node peak memory  : {}", human_bytes(summary.mean_peak_mem));
    println!("max node net-in        : {}", human_bytes(summary.max_net_in as f64));
    println!("memory balance ratio   : {:.2} (1.0 = perfectly clustered curves)", summary.mem_balance_ratio);
    println!("trace written          : {path}");
    Outcome {
        time: res.sim_secs(),
        max_net: summary.max_net_in as f64,
        max_mem: summary.max_peak_mem as f64,
        balance: summary.mem_balance_ratio,
    }
}

/// Real-executor memory ablation: lifetime GC on/off over a 3-iteration
/// Newton fit on a small real cluster (the shared `glm_mem_run` arm, so
/// this section and fig09's memory ablation measure the same protocol).
/// Returns max per-node peak bytes.
fn run_real_memory(gc: bool) -> u64 {
    let (_, last) = glm_mem_run(4, 2, 2048, 16, 16, 3, gc);
    println!("  gc={gc:<5} {}", mem_summary(&last));
    max_peak_bytes(&last)
}

fn main() {
    let lshs = run(Policy::Lshs, "lshs");
    let nolshs = run(Policy::BottomUp, "no_lshs");

    println!("\n=== §8.5 headline ratios (no-LSHS / LSHS) ===");
    println!(
        "network load : {} vs {} (paper: 2x; here LSHS moves ~nothing because data is \
         pre-resident, so we report absolutes)",
        nums::util::fmt::human_bytes(nolshs.max_net),
        nums::util::fmt::human_bytes(lshs.max_net),
    );
    println!(
        "memory       : {:.1}x   (paper: 4x)",
        nolshs.max_mem / lshs.max_mem.max(1.0)
    );
    println!(
        "exec time    : {:.1}x   (paper: 10x)",
        nolshs.time / lshs.time.max(1e-12)
    );
    println!(
        "balance      : LSHS {:.2} vs no-LSHS {:.2} (lower = denser clustering)",
        lshs.balance, nolshs.balance
    );

    println!("\n=== real-executor memory ablation (lifetime GC, 3 Newton iterations) ===");
    let peak_nogc = run_real_memory(false);
    let peak_gc = run_real_memory(true);
    println!(
        "max node peak: {} (no GC) vs {} (GC)  ->  {:.2}x less memory",
        human_bytes(peak_nogc as f64),
        human_bytes(peak_gc as f64),
        peak_nogc as f64 / peak_gc.max(1) as f64
    );
}
