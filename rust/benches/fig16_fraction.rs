//! Fig. 16 — training time vs dataset fraction: the serial stack wins at
//! small fractions, NumS at large ones (paper: 5× slower small, 20×
//! faster at full HIGGS). Real execution, scaled rows.

use nums::api::{Session, SessionConfig};
use nums::bench::harness::print_series;
use nums::glm::data::classification_dense;
use nums::glm::{newton_fit, newton_fit_serial};
use nums::util::Stopwatch;

fn main() {
    let fast = std::env::var("NUMS_BENCH_FAST").ok().as_deref() == Some("1");
    let full = if fast { 60_000 } else { 200_000 };
    let d = 28usize;
    let steps = 5;
    let fractions = [0.01f64, 0.05, 0.25, 1.0];

    let mut xs = Vec::new();
    let mut serial_t = Vec::new();
    let mut nums_t = Vec::new();
    let mut nums_model = Vec::new();
    for &frac in &fractions {
        let n = ((full as f64 * frac) as usize).max(256);
        xs.push(format!("{:.0}%", frac * 100.0));

        let (x_d, y_d) = classification_dense(n, d, 0xF16);
        let sw = Stopwatch::start();
        newton_fit_serial(&x_d, &y_d, steps, 0.0).unwrap();
        serial_t.push(sw.secs());

        let mut sess = Session::new(SessionConfig::real_small(1, 8));
        let q = 8usize.min(n / 32).max(1);
        let x = sess.scatter2(&x_d, &[q, 1]);
        let y = sess.scatter2(&y_d, &[q, 1]);
        let sw = Stopwatch::start();
        newton_fit(&mut sess, &x, &y, steps, 0.0).unwrap();
        nums_t.push(sw.secs());

        // this host has 1 core, so measured parallel speedup is bounded at
        // 1x; the modeled 32-worker node carries the paper's comparison
        let mut sim = Session::new(SessionConfig::paper_sim(1, 32));
        let (xs_, ys_) = nums::glm::classification_data(&mut sim, n, d, 32.min(n / 32).max(1), 0xF16);
        nums_model.push(newton_fit(&mut sim, &xs_, &ys_, steps, 0.0).unwrap().sim_secs());
    }

    print_series(
        "Fig 16: train time vs dataset fraction [s]",
        "fraction",
        &xs,
        &[
            ("serial (sklearn-ish, measured)".into(), serial_t.clone()),
            ("NumS (8 workers, measured, 1-core host)".into(), nums_t.clone()),
            ("NumS (modeled 32-worker node)".into(), nums_model.clone()),
        ],
    );
    println!(
        "full set, serial/NumS-modeled-32w = {:.1}x (the parallel-BLAS effect of §8.6)",
        serial_t.last().unwrap() / nums_model.last().unwrap()
    );
    println!(
        "smallest fraction: NumS/serial = {:.2}x (paper: NumS ~5x slower)",
        nums_t[0] / serial_t[0]
    );
    println!(
        "full set: serial/NumS = {:.2}x (paper: NumS ~20x faster at 7.5 GB)",
        serial_t.last().unwrap() / nums_t.last().unwrap()
    );
}
