//! Fig. 10 — dense square matmul weak scaling: NumS (recursive matmul +
//! LSHS) vs SLATE and ScaLAPACK (both SUMMA over MPI) from 2 GB on 1 node
//! to 32 GB on 16 nodes, all on the same modeled network.
//!
//! Expected shape: NumS competitive, improving relatively as k grows
//! (App. A.5: LSHS's bound grows like √k vs SUMMA's 2√k·log√k);
//! SUMMA wins on peak memory (in-place accumulation).
//!
//! Extended section (this repo's perf work): a *real* single-node DGEMM
//! shootout across the kernel tiers — naive triple loop, blocked scalar,
//! and the packed-panel AVX2+FMA microkernel (`linalg::microkernel`) —
//! warmup + best-of-3 per size. On hosts where the Simd tier actually
//! resolves (AVX2+FMA present, `NUMS_KERNEL_TIER` not forcing scalar)
//! the run *asserts* SIMD beats the blocked scalar kernel at the largest
//! size; elsewhere the arm records tier=scalar timings and skips the
//! assertion. All results land in `BENCH_fig10.json`.
//!
//! `cargo bench --bench fig10_dgemm -- --smoke` bounds the sizes for CI.

use nums::bench::harness::{emit_json, print_series, PerfRecord};
use nums::linalg::dense;
use nums::prelude::*;
use nums::util::fmt::human_bytes;
use nums::util::Stopwatch;

/// Warmup once, then best-of-3 wall seconds for `f` on `a·b`.
fn best_of_3(a: &Block, b: &Block, f: &dyn Fn(&Block, &Block) -> Block) -> f64 {
    let _ = f(a, b);
    let mut best = f64::INFINITY;
    for _ in 0..3 {
        let sw = Stopwatch::start();
        let out = f(a, b);
        let secs = sw.secs();
        assert_eq!(out.shape, vec![a.rows(), b.cols()]);
        best = best.min(secs);
    }
    best
}

/// Real DGEMM tier shootout on one n×n block; returns the acceptance
/// violation (if any) so the caller can emit the JSON before failing.
fn tier_shootout(records: &mut Vec<PerfRecord>, smoke: bool) -> Option<String> {
    let sizes: &[usize] = if smoke { &[256] } else { &[512, 1024] };
    let threads = ExecContext::host_default().kernel_threads;
    let simd = KernelTier::resolve(KernelTier::Simd);
    println!(
        "## Fig 10 (ext): real DGEMM kernel tiers (requested simd resolves to {}, {} threads)",
        simd.name(),
        threads
    );

    let mut violation = None;
    for &n in sizes {
        let mut rng = Rng::seed_from_u64(0xF16 ^ n as u64);
        let mut av = vec![0.0; n * n];
        rng.fill_normal(&mut av);
        let mut bv = vec![0.0; n * n];
        rng.fill_normal(&mut bv);
        let a = Block::from_vec(&[n, n], av);
        let b = Block::from_vec(&[n, n], bv);
        let flops = 2.0 * (n as f64).powi(3);

        let arms: Vec<(&str, Box<dyn Fn(&Block, &Block) -> Block>)> = vec![
            ("naive", Box::new(dense::matmul_naive)),
            (
                "scalar",
                Box::new(move |a: &Block, b: &Block| {
                    dense::matmul_tier(a, b, 1.0, threads, KernelTier::Scalar)
                }),
            ),
            (
                "simd",
                Box::new(move |a: &Block, b: &Block| {
                    dense::matmul_tier(a, b, 1.0, threads, simd)
                }),
            ),
        ];
        let mut secs = Vec::new();
        for (name, f) in &arms {
            let s = best_of_3(&a, &b, f.as_ref());
            let g = flops / s / 1e9;
            println!("  {n:>5}  {name:<8} {s:.4}s  {g:8.2} GFLOP/s");
            records.push(PerfRecord {
                op: format!("dgemm_{name}_{n}"),
                bytes: (3 * n * n * 8) as u64,
                secs: s,
                gflops: g,
            });
            secs.push(s);
        }
        println!(
            "  {n:>5}  simd/scalar speedup {:.2}x, scalar/naive {:.2}x",
            secs[1] / secs[2],
            secs[0] / secs[1]
        );
        // acceptance: on capable hosts the packed AVX2+FMA path must beat
        // the blocked scalar kernel at the largest measured size
        if simd == KernelTier::Simd && n == *sizes.last().unwrap() && secs[2] >= secs[1] {
            violation = Some(format!(
                "SIMD tier must beat scalar at {n}x{n}: simd {:.4}s !< scalar {:.4}s",
                secs[2], secs[1]
            ));
        }
    }
    if simd != KernelTier::Simd {
        println!("  (simd tier unavailable on this host/env — assertion skipped)");
    }
    violation
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let cases: &[(usize, usize)] = if smoke {
        &[(1usize, 2usize), (4, 8)]
    } else {
        &[(1usize, 2usize), (2, 4), (4, 8), (8, 16), (16, 32)]
    };
    let mut records = Vec::new();
    let mut xs = Vec::new();
    let mut nums_t = Vec::new();
    let mut slate_t = Vec::new();
    let mut scala_t = Vec::new();
    let mut nums_mem = Vec::new();
    let mut slate_mem = Vec::new();

    for &(nodes, gb) in cases {
        let n = (((gb as f64) * 1e9 / 8.0).sqrt()) as usize;
        xs.push(format!("{gb}GB/{nodes}n"));

        // SLATE: SUMMA at the node-level process grid
        let summa = nums::summa::Summa::new(nodes, n).run(
            NetParams::mpi_testbed(),
            ComputeParams::mpi_testbed(),
            32,
        );
        slate_t.push(summa.report.makespan);
        slate_mem.push(summa.report.max_mem_bytes() as f64);
        // ScaLAPACK: same algorithm, legacy smaller blocks -> more steps;
        // model as SUMMA on a finer (2x) virtual grid when possible
        let scala = if nodes >= 4 {
            nums::summa::Summa::new(nodes, n)
                .run(NetParams::mpi_testbed(), ComputeParams::mpi_testbed(), 32)
                .report
                .makespan
                * 1.08 // extra step overhead from 4-6x smaller tuned blocks (Tab. 2)
        } else {
            summa.report.makespan * 1.05
        };
        scala_t.push(scala);

        // NumS: LSHS over a square-ish node grid; block count tuned per
        // size, as the paper tunes every library (Table 2)
        let mut best_t = f64::INFINITY;
        let mut best_mem = 0.0;
        for g in [4usize, 8, 16, 24, 32] {
            let cfg = nums::api::SessionConfig::paper_sim(nodes, 32)
                .with_node_grid(NodeGrid::square_ish(nodes));
            let mut sess = nums::api::Session::new(cfg);
            let a = sess.zeros(&[n, n], &[g, g]);
            let b = sess.zeros(&[n, n], &[g, g]);
            let mut graph = Graph::new();
            build::matmul(&mut graph, &a, &b);
            let (_, rep) = sess.run(&mut graph).unwrap();
            if rep.sim.makespan < best_t {
                best_t = rep.sim.makespan;
                best_mem = rep.sim.max_mem_bytes() as f64;
            }
        }
        nums_t.push(best_t);
        nums_mem.push(best_mem);
        records.push(PerfRecord {
            op: format!("weak_scaling_{gb}GB_{nodes}n_modeled"),
            bytes: (gb as u64) * 1_000_000_000,
            secs: best_t,
            gflops: 0.0,
        });
    }

    print_series(
        "Fig 10: DGEMM weak scaling [modeled s]",
        "size/nodes",
        &xs,
        &[
            ("NumS (LSHS)".into(), nums_t.clone()),
            ("SLATE (SUMMA)".into(), slate_t.clone()),
            ("ScaLAPACK".into(), scala_t),
        ],
    );
    println!("peak node memory at the largest case:");
    println!(
        "  NumS  {}   SLATE {}  (SUMMA accumulates in place — paper §8.2)",
        human_bytes(*nums_mem.last().unwrap()),
        human_bytes(*slate_mem.last().unwrap())
    );
    let ratio = nums_t.last().unwrap() / slate_t.last().unwrap();
    println!("NumS/SLATE time ratio at the largest case: {ratio:.2} (paper: ~1, competitive)");

    let violation = tier_shootout(&mut records, smoke);
    emit_json("BENCH_fig10.json", &records).expect("write BENCH_fig10.json");
    println!("wrote BENCH_fig10.json ({} records)", records.len());
    // fail only after the perf trajectory is safely on disk
    if let Some(msg) = violation {
        panic!("{msg}");
    }
}
