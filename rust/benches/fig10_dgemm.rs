//! Fig. 10 — dense square matmul weak scaling: NumS (recursive matmul +
//! LSHS) vs SLATE and ScaLAPACK (both SUMMA over MPI) from 2 GB on 1 node
//! to 32 GB on 16 nodes, all on the same modeled network.
//!
//! Expected shape: NumS competitive, improving relatively as k grows
//! (App. A.5: LSHS's bound grows like √k vs SUMMA's 2√k·log√k);
//! SUMMA wins on peak memory (in-place accumulation).

use nums::bench::harness::print_series;
use nums::prelude::*;
use nums::util::fmt::human_bytes;

fn main() {
    let cases = [(1usize, 2usize), (2, 4), (4, 8), (8, 16), (16, 32)];
    let mut xs = Vec::new();
    let mut nums_t = Vec::new();
    let mut slate_t = Vec::new();
    let mut scala_t = Vec::new();
    let mut nums_mem = Vec::new();
    let mut slate_mem = Vec::new();

    for (nodes, gb) in cases {
        let n = (((gb as f64) * 1e9 / 8.0).sqrt()) as usize;
        xs.push(format!("{gb}GB/{nodes}n"));

        // SLATE: SUMMA at the node-level process grid
        let summa = nums::summa::Summa::new(nodes, n).run(
            NetParams::mpi_testbed(),
            ComputeParams::mpi_testbed(),
            32,
        );
        slate_t.push(summa.report.makespan);
        slate_mem.push(summa.report.max_mem_bytes() as f64);
        // ScaLAPACK: same algorithm, legacy smaller blocks -> more steps;
        // model as SUMMA on a finer (2x) virtual grid when possible
        let scala = if nodes >= 4 {
            nums::summa::Summa::new(nodes, n)
                .run(NetParams::mpi_testbed(), ComputeParams::mpi_testbed(), 32)
                .report
                .makespan
                * 1.08 // extra step overhead from 4-6x smaller tuned blocks (Tab. 2)
        } else {
            summa.report.makespan * 1.05
        };
        scala_t.push(scala);

        // NumS: LSHS over a square-ish node grid; block count tuned per
        // size, as the paper tunes every library (Table 2)
        let mut best_t = f64::INFINITY;
        let mut best_mem = 0.0;
        for g in [4usize, 8, 16, 24, 32] {
            let cfg = nums::api::SessionConfig::paper_sim(nodes, 32)
                .with_node_grid(NodeGrid::square_ish(nodes));
            let mut sess = nums::api::Session::new(cfg);
            let a = sess.zeros(&[n, n], &[g, g]);
            let b = sess.zeros(&[n, n], &[g, g]);
            let mut graph = Graph::new();
            build::matmul(&mut graph, &a, &b);
            let (_, rep) = sess.run(&mut graph).unwrap();
            if rep.sim.makespan < best_t {
                best_t = rep.sim.makespan;
                best_mem = rep.sim.max_mem_bytes() as f64;
            }
        }
        nums_t.push(best_t);
        nums_mem.push(best_mem);
    }

    print_series(
        "Fig 10: DGEMM weak scaling [modeled s]",
        "size/nodes",
        &xs,
        &[
            ("NumS (LSHS)".into(), nums_t.clone()),
            ("SLATE (SUMMA)".into(), slate_t.clone()),
            ("ScaLAPACK".into(), scala_t),
        ],
    );
    println!("peak node memory at the largest case:");
    println!(
        "  NumS  {}   SLATE {}  (SUMMA accumulates in place — paper §8.2)",
        human_bytes(*nums_mem.last().unwrap()),
        human_bytes(*slate_mem.last().unwrap())
    );
    let ratio = nums_t.last().unwrap() / slate_t.last().unwrap();
    println!("NumS/SLATE time ratio at 16 nodes: {ratio:.2} (paper: ~1, competitive)");
}
