//! Table 3 — the data-science pipeline, measured for real: load a
//! HIGGS-like CSV, train logistic regression, predict.
//!
//! "Python stack" = serial CSV parse + single-thread dense Newton
//! (Pandas + NumPy/scikit-learn stand-in). "NumS" = parallel byte-range
//! reader + distributed Newton on one fat node. Scaled from the paper's
//! 7.5 GB to keep the bench under a minute; ratios are the comparison.

use nums::api::{Session, SessionConfig};
use nums::glm::serial::accuracy_serial;
use nums::glm::{accuracy, newton_fit, newton_fit_serial};
use nums::util::cli::Args;
use nums::util::fmt::render_table;
use nums::util::Stopwatch;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let fast = std::env::var("NUMS_BENCH_FAST").ok().as_deref() == Some("1");
    let rows = args.usize_or("rows", if fast { 40_000 } else { 150_000 });
    let steps = 6;
    let path = std::env::temp_dir().join("nums_tab03.csv");
    nums::io::higgs::generate_csv(&path, rows, 0x4163).unwrap();
    let mb = std::fs::metadata(&path).unwrap().len() as f64 / (1 << 20) as f64;
    println!("## Table 3: CSV load -> train -> predict ({rows} rows, {mb:.1} MiB)");

    // ---- serial Python-stack stand-in ----
    let sw = Stopwatch::start();
    let dense = nums::io::csv::read_csv_serial(&path).unwrap();
    let t_load_s = sw.secs();
    let (x_d, y_d) = nums::io::higgs::split_label(&dense);
    let sw = Stopwatch::start();
    let serial = newton_fit_serial(&x_d, &y_d, steps, 1e-8).unwrap();
    let t_train_s = sw.secs();
    let sw = Stopwatch::start();
    let acc_s = accuracy_serial(&x_d, &y_d, &serial.beta).unwrap();
    let t_pred_s = sw.secs();

    // ---- NumS pipeline ----
    let mut sess = Session::new(SessionConfig::real_small(1, 8));
    let sw = Stopwatch::start();
    let (raw, _, _) = nums::io::csv::read_csv_parallel(&mut sess, &path, 8).unwrap();
    let t_load_n = sw.secs();
    let dense2 = sess.fetch(&raw).unwrap();
    let (x2, y2) = nums::io::higgs::split_label(&dense2);
    let x = sess.scatter2(&x2, &[8, 1]);
    let y = sess.scatter2(&y2, &[8, 1]);
    let sw = Stopwatch::start();
    let fit = newton_fit(&mut sess, &x, &y, steps, 1e-8).unwrap();
    let t_train_n = sw.secs();
    let sw = Stopwatch::start();
    let acc_n = accuracy(&mut sess, &x, &y, &fit.beta).unwrap();
    let t_pred_n = sw.secs();

    let row = |name: &str, l: f64, t: f64, p: f64| {
        vec![
            name.to_string(),
            format!("{l:.2}"),
            format!("{t:.2}"),
            format!("{p:.2}"),
            format!("{:.2}", l + t + p),
        ]
    };
    println!(
        "{}",
        render_table(
            &["Tool Stack", "Load [s]", "Train [s]", "Predict [s]", "Total [s]"],
            &[
                row("Python stack", t_load_s, t_train_s, t_pred_s),
                row("NumS", t_load_n, t_train_n, t_pred_n),
            ]
        )
    );
    println!("accuracy: serial {acc_s:.4} vs NumS {acc_n:.4}");
    println!(
        "speedup: load {:.1}x, total {:.1}x (paper: 8x load, 8.4x total on 7.5 GB/32 cores;\n\
         this host has 1 core, so measured parallel gains are bounded at ~1x — see the\n\
         modeled 32-worker row of fig16 for the parallelism effect)",
        t_load_s / t_load_n,
        (t_load_s + t_train_s + t_pred_s) / (t_load_n + t_train_n + t_pred_n)
    );
    std::fs::remove_file(&path).ok();
}
