//! Fig. 12 — weak scaling, 1→16 nodes:
//! (a) indirect QR, 64 GB per node: near-perfect scaling;
//! (b) logistic regression (one Newton iteration per measurement), with
//!     the paper's slowdown at 16 nodes from inter-node reductions over
//!     the 20 Gbps network.

use nums::api::{Session, SessionConfig};
use nums::bench::harness::print_series;
use nums::glm::data::classification_data;
use nums::glm::newton_fit;
use nums::linalg::tsqr::indirect_tsqr;

fn main() {
    let nodes_axis = [1usize, 2, 4, 8, 16];
    let d = 256usize;

    // ---- (a) indirect QR, 64 GB per node ----
    let mut xs = Vec::new();
    let mut qr_t = Vec::new();
    let mut qr_eff = Vec::new();
    for &nodes in &nodes_axis {
        let rows = (64e9 * nodes as f64 / (d as f64 * 8.0)) as usize;
        let q = 32 * nodes; // 2 GB blocks
        let mut sess = Session::new(SessionConfig::paper_sim(nodes, 32));
        let x = sess.zeros(&[rows, d], &[q, 1]);
        let res = indirect_tsqr(&mut sess, &x).unwrap();
        xs.push(format!("{nodes}"));
        qr_t.push(res.report.sim.makespan);
        qr_eff.push(qr_t[0] / res.report.sim.makespan);
    }
    print_series(
        "Fig 12a: indirect QR weak scaling (64 GB/node)",
        "nodes",
        &xs,
        &[
            ("time [modeled s]".into(), qr_t),
            ("efficiency t1/tk".into(), qr_eff),
        ],
    );

    // ---- (b) logistic regression weak scaling ----
    let mut lr_t = Vec::new();
    let mut lr_tflops = Vec::new();
    for &nodes in &nodes_axis {
        let rows = ((1u64 << 21) * nodes as u64) as usize;
        let q = 8 * nodes;
        let mut sess = Session::new(SessionConfig::paper_sim(nodes, 32));
        let (x, y) = classification_data(&mut sess, rows, d, q, 12);
        let res = newton_fit(&mut sess, &x, &y, 1, 0.0).unwrap();
        let t = res.sim_secs();
        lr_t.push(t);
        // Newton iteration flops ≈ n d (d + 4)
        let flops = rows as f64 * d as f64 * (d as f64 + 4.0);
        lr_tflops.push(flops / t / 1e12);
    }
    print_series(
        "Fig 12b: logistic regression weak scaling (1 Newton iter)",
        "nodes",
        &xs,
        &[
            ("time [modeled s]".into(), lr_t.clone()),
            ("TFLOP/s".into(), lr_tflops),
        ],
    );
    println!(
        "16-node slowdown vs perfect: {:.2}x (paper sees degradation at 16 nodes, Fig. 12b)",
        lr_t[4] / lr_t[0]
    );
}
