//! Fig. 11 — tall-skinny QR on 16 nodes:
//! (a) direct TSQR: NumS (LSHS) vs Dask (round-robin dynamic scheduling);
//! (b) indirect TSQR: NumS vs Spark MLlib (static schedule, JVM-ish
//!     per-task overhead, Breeze LAPACK kernels).
//!
//! Expected shape: (a) comparable — Dask's peak-tuned single-column
//! partitioning lands data locality by accident (§8.3); (b) NumS faster,
//! the gap explained by system overheads rather than the algorithm.

use nums::api::{Policy, Session, SessionConfig};
use nums::bench::harness::print_series;
use nums::linalg::tsqr::{direct_tsqr, indirect_tsqr};
use nums::prelude::*;

/// Spark-ish runtime: static scheduling (no per-RFC γ) but heavy per-task
/// overhead (JVM serialization + stage launch).
fn spark_params() -> (NetParams, ComputeParams) {
    let net = NetParams {
        gamma: 2e-4, // JVM task-launch latency >= Ray dispatch
        ..NetParams::paper_testbed()
    };
    let compute = ComputeParams {
        task_overhead: 2e-3,
        ..ComputeParams::paper_testbed()
    };
    (net, compute)
}

fn main() {
    let d = 256usize;
    // 64..512 GB-shape inputs, 2 GB row blocks (peak for both, §8.3)
    let sizes_gb = [64usize, 128, 256, 512];
    let block_rows = (2e9 / (d as f64 * 8.0)) as usize;

    let mut xs = Vec::new();
    let (mut nums_dir, mut dask_dir) = (Vec::new(), Vec::new());
    let (mut nums_ind, mut spark_ind) = (Vec::new(), Vec::new());

    for gb in sizes_gb {
        xs.push(format!("{gb}GB"));
        let rows_total = (gb as f64 * 1e9 / (d as f64 * 8.0)) as usize;
        let q = (rows_total / block_rows).max(1);

        // (a) direct: NumS vs Dask-like
        for (policy, mode, out) in [
            (Policy::Lshs, SystemMode::Ray, &mut nums_dir),
            (Policy::RoundRobin, SystemMode::Dask, &mut dask_dir),
        ] {
            let cfg = SessionConfig::paper_sim(16, 32)
                .with_policy(policy)
                .with_mode(mode);
            let mut sess = Session::new(cfg);
            let x = sess.zeros(&[rows_total, d], &[q, 1]);
            let res = direct_tsqr(&mut sess, &x).unwrap();
            out.push(res.report.sim.makespan);
        }

        // (b) indirect: NumS vs Spark-like
        {
            let cfg = SessionConfig::paper_sim(16, 32);
            let mut sess = Session::new(cfg);
            let x = sess.zeros(&[rows_total, d], &[q, 1]);
            let res = indirect_tsqr(&mut sess, &x).unwrap();
            nums_ind.push(res.report.sim.makespan);
        }
        {
            let (net, compute) = spark_params();
            let mut cfg = SessionConfig::paper_sim(16, 32);
            cfg.net = net;
            cfg.compute = compute;
            let mut sess = Session::new(cfg);
            let x = sess.zeros(&[rows_total, d], &[q, 1]);
            let res = indirect_tsqr(&mut sess, &x).unwrap();
            spark_ind.push(res.report.sim.makespan);
        }
    }

    print_series(
        "Fig 11a: direct TSQR [modeled s]",
        "size",
        &xs,
        &[
            ("NumS (LSHS)".into(), nums_dir),
            ("Dask (RR dynamic)".into(), dask_dir),
        ],
    );
    print_series(
        "Fig 11b: indirect TSQR [modeled s]",
        "size",
        &xs,
        &[
            ("NumS (LSHS)".into(), nums_ind),
            ("Spark MLlib (static)".into(), spark_ind),
        ],
    );
}
