//! Lineage-based fault tolerance, end to end through `Session::run`.
//!
//! The correctness contract under test: a run with injected transient
//! faults — and at most one *survivable* whole-node loss — must produce
//! **bit-identical** results (scalar kernel tier) to a fault-free run,
//! with the recovery work reported in `RealReport::recovery_stats` and
//! reconciled against the run trace. An *unsurvivable* loss must fail
//! with a typed [`ExecError::UnrecoverableLoss`] naming the dead
//! lineage, not hang or report a bogus deadlock.

use nums::api::{ops, Session, SessionConfig};
use nums::exec::{ExecError, FaultPlan, NodeLossMode};
use nums::glm::data::classification_data;
use nums::glm::newton_fit;
use nums::metrics::runtime_trace::EventKind;
use nums::util::prop::forall_res;

/// One matmul under a given fault plan; returns (bits, report).
///
/// `None` pins an explicit rate-0 plan rather than leaving the config
/// empty: the CI chaos leg arms `NUMS_FAULT_SEED`/`NUMS_FAULT_RATE` in
/// the environment, and the fault-free oracle must stay fault-free
/// even there (an explicit plan overrides the env arming).
fn run_matmul(
    dims: (usize, usize, usize),
    grids: (usize, usize, usize),
    seed: u64,
    fault: Option<FaultPlan>,
) -> Result<(Vec<u64>, nums::api::RunReport), String> {
    let (m, k, n) = dims;
    let (gm, gk, gn) = grids;
    let cfg = SessionConfig::real_small(2, 2)
        .with_seed(seed)
        .with_fault_plan(fault.unwrap_or_else(|| FaultPlan::new(0, 0.0)));
    let mut sess = Session::new(cfg);
    let a = sess.randn(&[m, k], &[gm, gk]);
    let b = sess.randn(&[k, n], &[gk, gn]);
    let (c, rep) = ops::matmul(&mut sess, &a, &b).map_err(|e| e.to_string())?;
    let host = sess.fetch(&c).map_err(|e| e.to_string())?;
    let bits: Vec<u64> = host.into_vec().iter().map(|v| v.to_bits()).collect();
    Ok((bits, rep))
}

/// Seeded random fault plans over random matmuls: every chaos run must
/// converge to the exact bits of the fault-free oracle, with retries
/// actually exercised somewhere across the case set.
#[test]
fn prop_injected_faults_preserve_bit_identity() {
    use std::cell::Cell;
    let total_retries = Cell::new(0u64);
    let total_injected_runs = Cell::new(0u64);
    forall_res(
        0xFA017,
        10,
        |r| {
            let m = 16 + r.usize(48);
            let k = 16 + r.usize(48);
            let n = 16 + r.usize(48);
            let gm = 1 + r.usize(2);
            let gk = 1 + r.usize(2);
            let gn = 1 + r.usize(2);
            let rate = 0.3 + 0.7 * (r.usize(8) as f64 / 8.0);
            (m, k, n, gm, gk, gn, r.next_u64(), r.next_u64(), rate)
        },
        |&(m, k, n, gm, gk, gn, seed, fseed, rate)| {
            let dims = (m, k, n);
            let grids = (gm.min(m), gk.min(k), gn.min(n));
            let (want, clean_rep) = run_matmul(dims, grids, seed, None)?;
            let clean = clean_rep.real.as_ref().expect("real mode");
            if !clean.recovery_stats.is_zero() {
                return Err(format!(
                    "fault-free run reported recovery work: {:?}",
                    clean.recovery_stats
                ));
            }
            let (got, rep) =
                run_matmul(dims, grids, seed, Some(FaultPlan::new(fseed, rate)))?;
            if got != want {
                return Err(format!(
                    "chaos run (fseed {fseed}, rate {rate}) diverged from oracle"
                ));
            }
            let real = rep.real.as_ref().expect("real mode");
            total_retries.set(total_retries.get() + real.recovery_stats.retries);
            if real.recovery_stats.retries > 0 {
                total_injected_runs.set(total_injected_runs.get() + 1);
                if real.recovery_stats.backoff_secs <= 0.0 {
                    return Err("retries without backoff time".into());
                }
            }
            if real.recovery_stats.node_losses_survived != 0 {
                return Err("rate-based plans must never lose a node".into());
            }
            Ok(())
        },
    );
    assert!(
        total_retries.get() > 0 && total_injected_runs.get() > 0,
        "rates in [0.3, 1.0] over 10 cases must inject at least once \
         ({} retries in {} runs)",
        total_retries.get(),
        total_injected_runs.get()
    );
}

#[test]
fn survivable_node_loss_is_bit_identical_and_reported() {
    let dims = (96, 96, 96);
    let grids = (4, 4, 2);
    let (want, _) = run_matmul(dims, grids, 0xBEEF, None).unwrap();
    // no rate faults: isolate the node-loss path. Stealing stays on —
    // recovery must cope with tasks landing anywhere.
    let plan = FaultPlan::new(0, 0.0).with_node_loss(1, 3, NodeLossMode::Survivable);
    let (got, rep) = run_matmul(dims, grids, 0xBEEF, Some(plan)).unwrap();
    assert_eq!(got, want, "recovered run must be bit-identical");
    let real = rep.real.as_ref().unwrap();
    assert_eq!(
        real.recovery_stats.node_losses_survived, 1,
        "the scheduled loss must fire and be survived"
    );
    assert!(
        !real.recovery_stats.is_zero(),
        "a survived loss is recovery work"
    );
    assert_eq!(real.node_losses.len(), 1);
    assert_eq!(real.node_losses[0].0, 1, "node 1 was the one lost");
}

/// `recovery_stats` must reconcile with the run trace: recomputed bytes
/// equal the sum of `Recompute` event bytes, recompute events match the
/// task counter, and the node-loss event carries the wiped bytes.
#[test]
fn recovery_stats_reconcile_with_trace_events() {
    let cfg = SessionConfig::real_small(2, 2)
        .with_seed(0x7AC3)
        .with_tracing(true)
        .with_fault_plan(
            FaultPlan::new(11, 0.6).with_node_loss(1, 2, NodeLossMode::Survivable),
        );
    let mut sess = Session::new(cfg);
    let a = sess.randn(&[96, 96], &[4, 2]);
    let b = sess.randn(&[96, 96], &[2, 4]);
    let (_, rep) = ops::matmul(&mut sess, &a, &b).unwrap();
    let real = rep.real.as_ref().unwrap();
    let tr = rep.trace().expect("tracing on");

    let recompute_bytes: u64 = tr
        .events
        .iter()
        .filter(|e| e.kind == EventKind::Recompute)
        .map(|e| e.bytes)
        .sum();
    let recompute_events = tr
        .events
        .iter()
        .filter(|e| e.kind == EventKind::Recompute)
        .count() as u64;
    assert_eq!(
        real.recovery_stats.recomputed_bytes, recompute_bytes,
        "stats and trace must agree on recomputed bytes"
    );
    assert_eq!(
        real.recovery_stats.recomputed_tasks, recompute_events,
        "one Recompute event per recomputed task"
    );

    assert_eq!(real.recovery_stats.node_losses_survived, 1);
    let loss_events: Vec<_> = tr
        .events
        .iter()
        .filter(|e| e.kind == EventKind::NodeLoss)
        .collect();
    assert_eq!(loss_events.len(), 1, "exactly one node-loss instant");
    assert_eq!(loss_events[0].node, 1);
    let wiped: u64 = real.node_losses[0].1.iter().map(|&(_, b)| b).sum();
    assert_eq!(loss_events[0].bytes, wiped, "loss event carries wiped bytes");

    // injected failures at rate 0.6 must show up as Fault instants, and
    // every worker-site retry as a Retry instant
    let faults = tr.events.iter().filter(|e| e.kind == EventKind::Fault).count();
    assert!(faults > 0, "rate 0.6 over a 40-task plan must inject");
    let retry_events = tr
        .events
        .iter()
        .filter(|e| e.kind == EventKind::Retry)
        .count() as u64;
    assert_eq!(
        real.recovery_stats.retries, retry_events,
        "stats and trace must agree on retry count"
    );
}

/// Node loss in the middle of an iterative GLM driver: every later
/// iteration replans against the surviving copies, and the final model
/// is bit-identical to the fault-free fit.
#[test]
fn node_loss_mid_glm_recovers_bit_identically() {
    let fit = |fault: Option<FaultPlan>| {
        // explicit rate-0 default: keep the oracle clean under the CI
        // chaos leg's env-armed injection (see `run_matmul`)
        let cfg = SessionConfig::real_small(3, 2)
            .with_seed(0x61F7)
            .with_fault_plan(fault.unwrap_or_else(|| FaultPlan::new(0, 0.0)));
        let mut sess = Session::new(cfg);
        let (x, y) = classification_data(&mut sess, 512, 8, 6, 0x11);
        let res = newton_fit(&mut sess, &x, &y, 4, 0.0).unwrap();
        let beta = sess.fetch(&res.beta).unwrap();
        (
            beta.into_vec().iter().map(|v| v.to_bits()).collect::<Vec<u64>>(),
            res.losses,
        )
    };
    let (want_beta, want_losses) = fit(None);
    // the loss fires mid-fit, a few tasks into whichever run crosses the
    // trigger; rate faults ride along to stress retry during recovery
    let plan = FaultPlan::new(3, 0.4).with_node_loss(2, 5, NodeLossMode::Survivable);
    let (got_beta, got_losses) = fit(Some(plan));
    assert_eq!(got_beta, want_beta, "chaos fit diverged from fault-free fit");
    assert_eq!(got_losses, want_losses, "loss curves must match exactly");
}

/// Wiping a sole-copy external input (Total mode) is not survivable:
/// `Session::run` must return the typed error promptly — naming the dead
/// lineage — instead of deadlocking or panicking.
#[test]
fn total_node_loss_is_a_typed_unrecoverable_error() {
    let cfg = SessionConfig::real_small(2, 2).with_seed(0xDEAD).with_fault_plan(
        FaultPlan::new(0, 0.0).with_node_loss(0, 1, NodeLossMode::Total),
    );
    let mut sess = Session::new(cfg);
    let a = sess.randn(&[64, 64], &[2, 2]);
    let b = sess.randn(&[64, 64], &[2, 2]);
    let err = match ops::matmul(&mut sess, &a, &b) {
        Ok(_) => panic!("total loss of seed data must fail the run"),
        Err(e) => e,
    };
    let typed = err
        .downcast_ref::<ExecError>()
        .expect("typed ExecError must survive the anyhow boundary");
    match typed {
        ExecError::UnrecoverableLoss { lineage } => {
            assert!(!lineage.is_empty(), "error must name the dead lineage");
        }
        other => panic!("want UnrecoverableLoss, got {other:?}"),
    }
    let msg = format!("{err}");
    assert!(
        msg.contains("unrecoverable loss"),
        "message must say what happened: {msg}"
    );
    assert!(
        !msg.contains("deadlock"),
        "a known loss must not masquerade as a deadlock: {msg}"
    );
}
