//! End-to-end scheduling integration: LSHS vs baselines over the §8.1
//! microbenchmark operation set, plus layout invariants.

use nums::api::{ops, Policy, Session, SessionConfig};
use nums::prelude::*;

fn sim_session(policy: Policy, nodes: usize, wpn: usize) -> Session {
    Session::new(SessionConfig::paper_sim(nodes, wpn).with_policy(policy))
}

#[test]
fn ew_zero_communication_under_lshs_any_partitioning() {
    for q in [3usize, 5, 8, 16, 30] {
        let mut sess = sim_session(Policy::Lshs, 4, 4);
        let a = sess.zeros(&[1 << 20, 64], &[q, 1]);
        let b = sess.zeros(&[1 << 20, 64], &[q, 1]);
        let (_, rep) = ops::add(&mut sess, &a, &b).unwrap();
        assert_eq!(rep.transfers, 0, "q={q}: X+Y must be communication-free");
    }
}

#[test]
fn round_robin_pays_for_nondivisible_partitioning() {
    // Fig. 9's divisibility effect: when #blocks % #targets != 0, the
    // round-robin layout misaligns operands and forces transfers.
    let mut sess = sim_session(Policy::RoundRobin, 4, 4);
    let a = sess.zeros(&[1 << 18, 64], &[5, 1]);
    let b = sess.zeros(&[1 << 18, 64], &[5, 1]);
    let (_, rep) = ops::add(&mut sess, &a, &b).unwrap();
    assert!(rep.transfers > 0, "misaligned rr layout must move data");
}

#[test]
fn lshs_beats_baselines_on_inner_product() {
    // Xᵀ@Y on row-partitioned 16-block operands (§8.1's X^T @ Y).
    let run = |policy: Policy| {
        let mut sess = sim_session(policy, 4, 8);
        let x = sess.zeros(&[1 << 20, 64], &[16, 1]);
        let y = sess.zeros(&[1 << 20, 64], &[16, 1]);
        let (_, rep) = ops::matmul(&mut sess, &x.t(), &y).unwrap();
        (rep.sim.makespan, rep.transfer_bytes)
    };
    let (t_lshs, b_lshs) = run(Policy::Lshs);
    let (t_rand, b_rand) = run(Policy::Random);
    let (t_bu, b_bu) = run(Policy::BottomUp);
    assert!(
        b_lshs <= b_rand && b_lshs <= b_bu,
        "LSHS bytes {b_lshs} vs random {b_rand} / bottom-up {b_bu}"
    );
    assert!(
        t_lshs <= t_rand && t_lshs <= t_bu,
        "LSHS time {t_lshs} vs random {t_rand} / bottom-up {t_bu}"
    );
}

#[test]
fn lshs_balances_memory_vs_bottom_up() {
    let peak_imbalance = |policy: Policy| {
        let mut sess = sim_session(policy, 8, 4);
        let x = sess.zeros(&[1 << 20, 64], &[32, 1]);
        let y = sess.zeros(&[1 << 20, 64], &[32, 1]);
        let (_, rep) = ops::matmul(&mut sess, &x.t(), &y).unwrap();
        rep.sim.mem_imbalance()
    };
    let lshs = peak_imbalance(Policy::Lshs);
    let bu = peak_imbalance(Policy::BottomUp);
    assert!(lshs < bu, "LSHS imbalance {lshs:.2} vs bottom-up {bu:.2}");
    assert!(lshs < 1.5, "LSHS should be near-balanced, got {lshs:.2}");
}

#[test]
fn matmul_outputs_follow_hierarchical_layout() {
    // After A@B, output blocks must sit on their layout nodes, so a
    // subsequent element-wise op is again communication-free.
    let mut sess = sim_session(Policy::Lshs, 4, 4);
    let a = sess.zeros(&[4096, 4096], &[4, 4]);
    let b = sess.zeros(&[4096, 4096], &[4, 4]);
    let (c, _) = ops::matmul(&mut sess, &a, &b).unwrap();
    let (d, _) = ops::matmul(&mut sess, &a, &b).unwrap();
    let (_, rep) = ops::add(&mut sess, &c, &d).unwrap();
    assert_eq!(
        rep.transfers, 0,
        "chained ew op after matmul must stay local (hierarchical layout invariant)"
    );
}

#[test]
fn dask_mode_schedules_at_worker_granularity() {
    let cfg = SessionConfig::paper_sim(2, 4)
        .with_policy(Policy::Lshs)
        .with_mode(SystemMode::Dask);
    let mut sess = Session::new(cfg);
    let a = sess.zeros(&[1 << 16, 64], &[8, 1]);
    let b = sess.zeros(&[1 << 16, 64], &[8, 1]);
    // 8 blocks over 8 worker targets: one per worker
    let mut seen: Vec<usize> = a.targets.clone();
    seen.sort_unstable();
    assert_eq!(seen, (0..8).collect::<Vec<_>>());
    let (_, rep) = ops::add(&mut sess, &a, &b).unwrap();
    assert_eq!(rep.transfers, 0);
}

#[test]
fn sum_reduction_tree_is_local_first() {
    // sum over 16 row blocks on 4 nodes: intra-node pairs reduce first, so
    // inter-node transfers are at most k-1 = 3 object moves.
    let mut sess = sim_session(Policy::Lshs, 4, 4);
    let x = sess.zeros(&[1 << 20, 64], &[16, 1]);
    let (_, rep) = ops::sum_axis(&mut sess, &x, 0).unwrap();
    assert!(
        rep.transfers <= 3,
        "locality-paired tree should move <= k-1 blocks, got {}",
        rep.transfers
    );
}

#[test]
fn schedulers_produce_identical_numerics() {
    // Scheduling must never change results — only placement.
    let mut dense: Vec<Block> = Vec::new();
    for policy in [Policy::Lshs, Policy::RoundRobin, Policy::BottomUp, Policy::Random] {
        let mut sess = Session::new(SessionConfig::real_small(3, 2).with_policy(policy));
        let a = sess.randn(&[96, 96], &[3, 3]);
        let b = sess.randn(&[96, 96], &[3, 3]);
        let (c, _) = ops::matmul(&mut sess, &a, &b).unwrap();
        dense.push(sess.fetch(&c).unwrap());
    }
    for other in &dense[1..] {
        assert!(dense[0].max_abs_diff(other) < 1e-12);
    }
}

#[test]
fn softmax_auto_partitioning_is_used() {
    let sess = Session::new(SessionConfig::paper_sim(4, 4));
    // square: near-even 2-D split; tall-skinny: all along rows (§4)
    assert_eq!(sess.auto_grid(&[4096, 4096]), vec![4, 4]);
    assert_eq!(sess.auto_grid(&[1 << 24, 256]), vec![16, 1]);
}
