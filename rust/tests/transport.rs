//! Cross-transport correctness suites: the pluggable block carrier
//! (in-process / shared-memory / loopback-TCP) must be invisible in the
//! results and visible only in *how* bytes move.
//!
//! Contracts under test:
//! * **Bit identity** — every random graph produces the exact bits of
//!   the sequential oracle on all three transports (scalar tier),
//!   including skewed `create_at` placements with stealing on.
//! * **Byte accounting** — per node, `prefetch_bytes +
//!   demand_pull_bytes == net_in` on every transport: the identity
//!   belongs to the `StoreSet` seam, not to any one carrier.
//! * **Failure mapping** — a stalled TCP peer exhausts the bounded
//!   transient retries and is escalated to a dead peer; a *killed* node
//!   process triggers the PR 9 node-loss recovery path and the run
//!   still completes bit-identical to its fault-free twin.

use std::collections::HashMap;
use std::net::{SocketAddr, TcpListener};
use std::sync::Arc;
use std::time::Duration;

use nums::api::ops;
use nums::exec::{FaultPlan, Plan, RealExecutor, RealReport, Task};
use nums::net::{
    serve_node, ShmTransport, TcpTransport, Transport, TransportKind, MAX_LINK_RETRIES,
};
use nums::prelude::*;
use nums::runtime::native;
use nums::store::StoreSet;
use nums::util::prop::forall_res;

const KINDS: [TransportKind; 3] =
    [TransportKind::InProcess, TransportKind::SharedMem, TransportKind::Tcp];

/// In-thread TCP node daemons (real loopback sockets, no child
/// processes) — the executor-level way to put a socket under every
/// transfer. Child-process daemons are exercised by the session-level
/// suites below via the real launcher.
fn spawn_daemons(nodes: usize) -> Vec<SocketAddr> {
    (0..nodes)
        .map(|_| {
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            let addr = listener.local_addr().unwrap();
            std::thread::spawn(move || serve_node(listener));
            addr
        })
        .collect()
}

fn stores_for(kind: TransportKind, nodes: usize) -> StoreSet {
    match kind {
        TransportKind::InProcess => StoreSet::new(nodes),
        TransportKind::SharedMem => StoreSet::with_transport(
            nodes,
            Arc::new(ShmTransport::new().expect("shm dir")),
        ),
        TransportKind::Tcp => StoreSet::with_transport(
            nodes,
            Arc::new(TcpTransport::connect(spawn_daemons(nodes))),
        ),
    }
}

/// Sequential oracle: the plan in order, one thread, no stores.
fn run_sequential(plan: &Plan, seeds: &HashMap<u64, Block>) -> HashMap<u64, Block> {
    let mut env: HashMap<u64, Block> = seeds.clone();
    for t in &plan.tasks {
        let refs: Vec<&Block> = t.inputs.iter().map(|o| &env[o]).collect();
        let outs = native::execute(&t.kernel, &refs).unwrap();
        for ((obj, _), b) in t.outputs.iter().zip(outs) {
            env.insert(*obj, b);
        }
    }
    env
}

/// Per-node `prefetch + demand == net_in` — every cross-node byte
/// accounted exactly once, whichever carrier moved it.
fn check_byte_identity(rep: &RealReport, nodes: usize, label: &str) -> Result<(), String> {
    if rep.prefetch_stats.len() != nodes {
        return Err(format!("{label}: expected {nodes} prefetch stat blocks"));
    }
    for n in 0..nodes {
        let net_in = rep.store_snapshot[n].2;
        let p = &rep.prefetch_stats[n];
        if p.prefetch_bytes + p.demand_pull_bytes != net_in {
            return Err(format!(
                "{label} node {n}: prefetch {} + demand {} != net_in {net_in}",
                p.prefetch_bytes, p.demand_pull_bytes
            ));
        }
    }
    Ok(())
}

/// Random-but-valid plan spec (the `tests/exec_overlap.rs` scheme):
/// kinds decode against earlier outputs, so plans are executable.
#[derive(Debug)]
struct PlanSpec {
    nodes: usize,
    stealing: bool,
    /// All seeds on node 0 (the skewed-`create_at` arm) vs round-robin.
    skewed: bool,
    n_seeds: usize,
    tasks: Vec<(u8, usize, usize, usize)>,
}

const SHAPE: [usize; 2] = [4, 4];

fn decode(spec: &PlanSpec) -> (Plan, HashMap<u64, Block>) {
    let mut rng = Rng::seed_from_u64(0x7A4 ^ spec.tasks.len() as u64);
    let mut seeds = HashMap::new();
    let mut avail: Vec<u64> = Vec::new();
    for s in 0..spec.n_seeds {
        let mut v = vec![0.0; SHAPE[0] * SHAPE[1]];
        rng.fill_normal(&mut v);
        seeds.insert(s as u64, Block::from_vec(&SHAPE, v));
        avail.push(s as u64);
    }
    let mut tasks = Vec::new();
    for (i, &(kind, p1, p2, tgt)) in spec.tasks.iter().enumerate() {
        let out = 1000 + i as u64;
        let (kernel, inputs) = match kind % 5 {
            0 => (Kernel::Ew(BinOp::Add), vec![avail[p1 % avail.len()], avail[p2 % avail.len()]]),
            1 => (Kernel::Ew(BinOp::Mul), vec![avail[p1 % avail.len()], avail[p2 % avail.len()]]),
            2 => (Kernel::Neg, vec![avail[p1 % avail.len()]]),
            3 => (Kernel::Scale(0.5), vec![avail[p1 % avail.len()]]),
            _ => (Kernel::Matmul, vec![avail[p1 % avail.len()], avail[p2 % avail.len()]]),
        };
        let in_shapes = vec![SHAPE.to_vec(); inputs.len()];
        tasks.push(Task {
            kernel,
            inputs,
            in_shapes,
            outputs: vec![(out, SHAPE.to_vec())],
            target: tgt % spec.nodes,
            transfers: vec![],
        });
        avail.push(out);
    }
    (Plan { tasks }, seeds)
}

/// Random graphs × all three transports vs the sequential oracle:
/// bit-identical outputs and the byte-accounting identity, with skewed
/// seed placement and stealing arms folded into the case distribution.
#[test]
fn prop_transports_bit_identical_and_account_bytes() {
    forall_res(
        0x7A45,
        12,
        |r| PlanSpec {
            nodes: 2 + r.usize(2),
            stealing: r.usize(2) == 1,
            skewed: r.usize(2) == 1,
            n_seeds: 2 + r.usize(3),
            tasks: (0..1 + r.usize(12))
                .map(|_| {
                    (r.usize(256) as u8, r.usize(1 << 16), r.usize(1 << 16), r.usize(1 << 16))
                })
                .collect(),
        },
        |spec| {
            let (plan, seeds) = decode(spec);
            let want = run_sequential(&plan, &seeds);
            for kind in KINDS {
                let label = kind.name();
                let topo = Topology::new(spec.nodes, 2, SystemMode::Ray);
                let mut exec = RealExecutor::new(topo, Arc::new(Backend::native()))
                    .with_stealing(spec.stealing)
                    .with_prefetch(true);
                exec.threads_per_node = 2;
                let stores = stores_for(kind, spec.nodes);
                for (obj, b) in &seeds {
                    let home =
                        if spec.skewed { 0 } else { (*obj as usize) % spec.nodes };
                    stores.put(home, *obj, Arc::new(b.clone()));
                }
                let rep = exec
                    .run(&plan, &stores)
                    .map_err(|e| format!("{label}: executor failed: {e}"))?;
                check_byte_identity(&rep, spec.nodes, label)?;
                let consumed: std::collections::HashSet<u64> =
                    plan.tasks.iter().flat_map(|t| t.inputs.iter().copied()).collect();
                for i in 0..plan.tasks.len() {
                    let obj = 1000 + i as u64;
                    if consumed.contains(&obj) {
                        continue; // dead intermediate: GC'd
                    }
                    let got = stores
                        .fetch(obj)
                        .ok_or_else(|| format!("{label}: output {obj} missing"))?;
                    let w = &want[&obj];
                    if got.shape != w.shape
                        || got.buf().iter().zip(w.buf()).any(|(a, b)| a.to_bits() != b.to_bits())
                    {
                        return Err(format!("{label}: output {obj} differs from oracle"));
                    }
                }
                stores.transport().shutdown();
            }
            Ok(())
        },
    );
}

/// The canonical deep skew — every seed and every target on node 0 of
/// 4, stealing on — must stay bit-exact on every carrier, with thieves
/// actually stealing (and therefore pulling over the wire).
#[test]
fn skewed_stealing_arm_holds_on_every_transport() {
    let n = 64usize;
    let k_tasks = 24usize;
    let mut rng = Rng::seed_from_u64(0x5E4A);
    let mut seeds = HashMap::new();
    for i in 0..2 * k_tasks as u64 {
        let mut v = vec![0.0; n * n];
        rng.fill_normal(&mut v);
        seeds.insert(i, Block::from_vec(&[n, n], v));
    }
    let plan = Plan {
        tasks: (0..k_tasks)
            .map(|i| Task {
                kernel: Kernel::Matmul,
                inputs: vec![(2 * i) as u64, (2 * i + 1) as u64],
                in_shapes: vec![vec![n, n], vec![n, n]],
                outputs: vec![(1000 + i as u64, vec![n, n])],
                target: 0,
                transfers: vec![],
            })
            .collect(),
    };
    let want = run_sequential(&plan, &seeds);
    for kind in KINDS {
        let topo = Topology::new(4, 2, SystemMode::Ray);
        let mut exec = RealExecutor::new(topo, Arc::new(Backend::native()))
            .with_stealing(true)
            .with_prefetch(true);
        exec.threads_per_node = 2;
        let stores = stores_for(kind, 4);
        for (obj, b) in &seeds {
            stores.put(0, *obj, Arc::new(b.clone()));
        }
        let rep = exec.run(&plan, &stores).unwrap();
        check_byte_identity(&rep, 4, kind.name()).unwrap();
        let stolen: usize = rep.node_stats.iter().map(|s| s.tasks_stolen).sum();
        assert!(stolen > 0, "{}: deep skew must trigger stealing", kind.name());
        for i in 0..k_tasks {
            let obj = 1000 + i as u64;
            let got = stores.fetch(obj).unwrap();
            assert_eq!(
                got.max_abs_diff(&want[&obj]),
                0.0,
                "{}: output {obj} wrong",
                kind.name()
            );
        }
        stores.transport().shutdown();
    }
}

// --------------------------------------------------------------- session

/// Point the TCP launcher at the real `nums` binary cargo built for
/// this test run. Same value from every test, so the set_var race
/// between parallel tests is benign.
fn arm_node_bin() {
    std::env::set_var("NUMS_NODE_BIN", env!("CARGO_BIN_EXE_nums"));
}

/// One session-level matmul on `kind`; seeds optionally skewed onto one
/// node via `create_at`. Fault plan pinned to rate 0 so the CI chaos
/// leg's env arming can't touch the transport comparison.
fn session_matmul(kind: TransportKind, skew_to: Option<usize>) -> (Vec<u64>, RunReport) {
    if kind == TransportKind::Tcp {
        arm_node_bin();
    }
    let cfg = SessionConfig::real_small(3, 2)
        .with_seed(0x7A55)
        .with_transport(kind)
        .with_fault_plan(FaultPlan::new(0, 0.0));
    let mut sess = Session::new(cfg);
    let (a, b) = match skew_to {
        Some(node) => (
            sess.randn_at(&[96, 96], &[3, 3], node),
            sess.randn_at(&[96, 96], &[3, 3], node),
        ),
        None => (sess.randn(&[96, 96], &[3, 3]), sess.randn(&[96, 96], &[3, 3])),
    };
    let (c, rep) = ops::matmul(&mut sess, &a, &b).unwrap();
    let host = sess.fetch(&c).unwrap();
    let bits = host.into_vec().iter().map(|v| v.to_bits()).collect();
    (bits, rep)
}

use nums::api::RunReport;

/// End to end through `Session::run` on all three carriers — the TCP
/// one through real child node processes via the launcher — identical
/// bits, and the byte identity on each.
#[test]
fn session_results_identical_across_transports_including_real_processes() {
    for skew in [None, Some(1)] {
        let (want, _) = session_matmul(TransportKind::InProcess, skew);
        for kind in [TransportKind::SharedMem, TransportKind::Tcp] {
            let (got, rep) = session_matmul(kind, skew);
            assert_eq!(
                got,
                want,
                "{} (skew {skew:?}) diverged from the in-process oracle",
                kind.name()
            );
            let real = rep.real.as_ref().expect("real mode");
            check_byte_identity(real, 3, kind.name()).unwrap();
        }
    }
}

/// The TCP transport's per-transfer records are *measured*: real bytes
/// over real sockets with nonzero wall time (what `BENCH_net.json`
/// reports instead of the α–β model).
#[test]
fn tcp_transfers_are_measured_not_modeled() {
    arm_node_bin();
    let cfg = SessionConfig::real_small(2, 2)
        .with_seed(0x3E7)
        .with_transport(TransportKind::Tcp)
        .with_fault_plan(FaultPlan::new(0, 0.0));
    let mut sess = Session::new(cfg);
    // all blocks on node 0, so node 1's share of the matmul must pull
    let a = sess.randn_at(&[64, 64], &[2, 2], 0);
    let b = sess.randn_at(&[64, 64], &[2, 2], 0);
    let (_c, rep) = ops::matmul(&mut sess, &a, &b).unwrap();
    let real = rep.real.as_ref().unwrap();
    let moved: u64 = real.store_snapshot.iter().map(|s| s.2).sum();
    assert!(moved > 0, "skewed placement must move bytes");
    let records = sess.stores.transport().records();
    assert!(!records.is_empty(), "TCP transfers must be recorded");
    let rec_bytes: u64 = records.iter().map(|r| r.bytes).sum();
    assert!(rec_bytes >= moved, "records cover at least every accounted byte");
    for r in &records {
        assert!(r.secs > 0.0, "a socket round trip takes measurable time: {r:?}");
        assert!(r.src != r.dst, "local hits never touch the transport");
    }
}

/// Deterministic chaos: kill one node daemon, then run a graph whose
/// inputs all live on the killed node. The first carry observes the
/// death, the executor converts it into the PR 9 node-loss path, and
/// the run completes bit-identical to the fault-free twin.
#[test]
fn killed_tcp_node_process_triggers_node_loss_recovery_bit_identically() {
    let victim = 0usize;
    let (want, _) = session_matmul(TransportKind::InProcess, Some(victim));
    arm_node_bin();
    let cfg = SessionConfig::real_small(3, 2)
        .with_seed(0x7A55)
        .with_transport(TransportKind::Tcp)
        .with_fault_plan(FaultPlan::new(0, 0.0));
    let mut sess = Session::new(cfg);
    let a = sess.randn_at(&[96, 96], &[3, 3], victim);
    let b = sess.randn_at(&[96, 96], &[3, 3], victim);
    // the launcher's chaos hook: SIGKILL the victim's block daemon
    assert!(
        sess.stores.transport().kill_peer(victim),
        "launcher must have a child process to kill"
    );
    let (c, rep) = ops::matmul(&mut sess, &a, &b).unwrap();
    let host = sess.fetch(&c).unwrap();
    let got: Vec<u64> = host.buf().iter().map(|v| v.to_bits()).collect();
    assert_eq!(got, want, "recovered run must match the fault-free twin bit for bit");
    let real = rep.real.as_ref().unwrap();
    assert!(
        real.recovery_stats.node_losses_survived >= 1,
        "the kill must surface as a survived node loss: {:?}",
        real.recovery_stats
    );
    assert!(
        real.node_losses.iter().any(|(n, _)| *n == victim),
        "the recorded loss must name the killed node"
    );
    // the session stays usable on the survivors afterwards
    let (d, _) = ops::add(&mut sess, &c, &c).unwrap();
    let twice = sess.fetch(&d).unwrap();
    assert!(twice
        .buf()
        .iter()
        .zip(host.buf())
        .all(|(t, h)| t.to_bits() == (h + h).to_bits()));
}

/// Timed chaos, mid-GLM: a killer thread takes a node down while a
/// Newton fit is running. Whatever instant the kill lands, the fit must
/// finish with the exact losses and beta of the fault-free twin.
#[test]
fn tcp_node_killed_mid_glm_recovers_bit_identically() {
    use nums::glm::data::classification_data;
    use nums::glm::newton_fit;
    let fit = |kind: TransportKind, kill: bool| {
        if kind == TransportKind::Tcp {
            arm_node_bin();
        }
        let cfg = SessionConfig::real_small(3, 2)
            .with_seed(0x61F7)
            .with_transport(kind)
            .with_fault_plan(FaultPlan::new(0, 0.0));
        let mut sess = Session::new(cfg);
        let (x, y) = classification_data(&mut sess, 384, 8, 6, 0x11);
        let killer = kill.then(|| {
            let transport = Arc::clone(sess.stores.transport());
            std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(30));
                transport.kill_peer(2)
            })
        });
        let res = newton_fit(&mut sess, &x, &y, 4, 0.0).unwrap();
        let beta = sess.fetch(&res.beta).unwrap();
        if let Some(k) = killer {
            assert!(k.join().unwrap(), "killer must have found a child process");
        }
        (
            beta.into_vec().iter().map(|v| v.to_bits()).collect::<Vec<u64>>(),
            res.losses,
        )
    };
    let (want_beta, want_losses) = fit(TransportKind::InProcess, false);
    let (got_beta, got_losses) = fit(TransportKind::Tcp, true);
    assert_eq!(got_beta, want_beta, "mid-GLM kill diverged from fault-free fit");
    assert_eq!(got_losses, want_losses, "loss curves must match exactly");
}

// -------------------------------------------------------------- failures

/// A deliberately stalled peer (accepts, never replies): every carry
/// times out — the *transient* class — so the seam retries exactly
/// `MAX_LINK_RETRIES` times with backoff before escalating the peer to
/// dead; after escalation the driver-side copy is served in-process.
#[test]
fn stalled_peer_exhausts_transient_retries_then_escalates() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::spawn(move || {
        let conns: Vec<_> = listener.incoming().take(8).collect();
        std::thread::sleep(Duration::from_secs(30));
        drop(conns);
    });
    let transport =
        TcpTransport::connect(vec![addr, addr]).with_timeout(Duration::from_millis(50));
    let set = StoreSet::with_transport(2, Arc::new(transport));
    set.put(0, 7, Arc::new(Block::filled(&[2, 2], 1.5)));
    assert_eq!(set.try_transfer(0, 1, 7), None, "stalled link must not deliver");
    assert_eq!(
        set.transport_retries(),
        MAX_LINK_RETRIES as u64,
        "heartbeat timeouts must burn the full transient-retry budget"
    );
    assert_eq!(set.dead_peers().len(), 1, "exhaustion escalates to peer death");
    // post-escalation: the driver-held copy serves in-process (Ray's
    // "driver re-puts its inputs"), so the object is not lost
    assert_eq!(set.try_transfer(0, 1, 7), Some(32));
    assert!(set.contains(1, 7));
}

/// Frame-codec behavior through the public API: partial-read resume
/// yields frames exactly at boundaries, and corruption is a typed
/// rejection — the full no-sockets suite lives in `net::frame`'s unit
/// tests.
#[test]
fn public_frame_codec_resumes_and_rejects() {
    use nums::net::frame::{decode, encode};
    use nums::net::{Frame, FrameDecoder, FrameError, FrameOp};
    let frames = [
        Frame::control(FrameOp::Ping, 0, 0),
        Frame::data(FrameOp::Put, 1, 9, &[2, 2], vec![1.0, -0.0, 3.5, f64::MAX]),
    ];
    let mut wire = Vec::new();
    for f in &frames {
        wire.extend_from_slice(&encode(f));
    }
    let mut dec = FrameDecoder::new();
    let mut out = Vec::new();
    for chunk in wire.chunks(7) {
        let mut fed = dec.feed(chunk).expect("clean stream");
        while let Some(f) = fed {
            out.push(f);
            fed = dec.feed(&[]).expect("clean stream");
        }
    }
    assert_eq!(out.as_slice(), frames.as_slice());
    let mut bad = encode(&frames[1]);
    let last = bad.len() - 1;
    bad[last] ^= 1;
    assert!(matches!(decode(&bad), Err(FrameError::Corrupt { .. })));
}
