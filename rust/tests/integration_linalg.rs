//! TSQR integration (§8.3): correctness at larger scale, scheduling
//! behaviour, weak-scaling shape.

use nums::api::{Policy, Session, SessionConfig};
use nums::linalg::dense;
use nums::linalg::tsqr::{direct_tsqr, indirect_tsqr};

#[test]
fn direct_tsqr_large_block_counts() {
    let mut sess = Session::new(SessionConfig::real_small(4, 2));
    let x = sess.randn(&[1024, 16], &[16, 1]);
    let res = direct_tsqr(&mut sess, &x).unwrap();
    let xd = sess.fetch(&x).unwrap();
    let qd = sess.fetch(&res.q).unwrap();
    let rd = sess.fetch(&res.r).unwrap();
    assert!(dense::matmul(&qd, &rd).max_abs_diff(&xd) < 1e-9);
    let qtq = dense::matmul(&qd.transposed(), &qd);
    assert!(qtq.max_abs_diff(&dense::eye(16)) < 1e-9);
}

#[test]
fn indirect_tsqr_matches_direct_r() {
    let mut s1 = Session::new(SessionConfig::real_small(2, 2));
    let x1 = s1.randn(&[512, 8], &[8, 1]);
    let d = direct_tsqr(&mut s1, &x1).unwrap();
    let mut s2 = Session::new(SessionConfig::real_small(2, 2));
    let x2 = s2.randn(&[512, 8], &[8, 1]);
    let i = indirect_tsqr(&mut s2, &x2).unwrap();
    let rd = s1.fetch(&d.r).unwrap();
    let ri = s2.fetch(&i.r).unwrap();
    assert!(rd.max_abs_diff(&ri) < 1e-8);
}

#[test]
fn tsqr_solves_least_squares() {
    // full pipeline use: solve min ||X b - y|| via R^{-1} Q^T y
    let mut sess = Session::new(SessionConfig::real_small(2, 2));
    let x = sess.randn(&[256, 4], &[4, 1]);
    let res = direct_tsqr(&mut sess, &x).unwrap();
    let xd = sess.fetch(&x).unwrap();
    let qd = sess.fetch(&res.q).unwrap();
    let rd = sess.fetch(&res.r).unwrap();
    // make y = X * [1,2,3,4]
    let truth = nums::store::Block::from_vec(&[4, 1], vec![1., 2., 3., 4.]);
    let y = dense::matmul(&xd, &truth);
    let qty = dense::matmul(&qd.transposed(), &y);
    let sol = dense::solve_upper(&rd, &qty);
    assert!(sol.max_abs_diff(&truth) < 1e-9);
}

#[test]
fn tsqr_weak_scaling_shape_fig12a() {
    // QR weak scaling is near-perfect in the paper (Fig. 12a): doubling
    // nodes and data should keep modeled time within 2x of the 1-node run.
    let mut times = Vec::new();
    for nodes in [1usize, 2, 4, 8] {
        let mut sess = Session::new(SessionConfig::paper_sim(nodes, 8));
        let x = sess.zeros(&[nodes << 18, 256], &[nodes * 4, 1]);
        let res = indirect_tsqr(&mut sess, &x).unwrap();
        times.push(res.report.sim.makespan);
    }
    for (i, t) in times.iter().enumerate() {
        assert!(*t < times[0] * 2.0, "point {i}: {times:?}");
    }
}

#[test]
fn lshs_tsqr_beats_random_placement() {
    let run = |policy: Policy| {
        let mut sess = Session::new(SessionConfig::paper_sim(4, 8).with_policy(policy));
        let x = sess.zeros(&[1 << 20, 256], &[16, 1]);
        indirect_tsqr(&mut sess, &x).unwrap().report.sim.makespan
    };
    let lshs = run(Policy::Lshs);
    let random = run(Policy::Random);
    assert!(lshs <= random, "lshs {lshs} vs random {random}");
}
