//! Memory-manager suites: lifetime GC, replica eviction and
//! spill-to-disk must be pure memory optimizations — results bit-identical
//! with the manager on or off, per-node `peak_bytes` never higher with
//! GC, and budget-constrained runs completing correctly with nonzero
//! spill/read-back traffic reported.

use std::collections::{HashMap, HashSet};
use std::sync::Arc;

use nums::api::ops;
use nums::exec::{Plan, RealExecutor, Task};
use nums::glm::data::classification_data;
use nums::glm::newton_fit;
use nums::prelude::*;
use nums::runtime::native;
use nums::store::{MemoryManager, StoreSet};
use nums::util::prop::forall_res;

/// Sequential oracle: run the plan in order, single process, no stores.
fn run_sequential(plan: &Plan, seeds: &HashMap<u64, Block>) -> HashMap<u64, Block> {
    let mut env: HashMap<u64, Block> = seeds.clone();
    for t in &plan.tasks {
        let refs: Vec<&Block> = t.inputs.iter().map(|o| &env[o]).collect();
        let outs = native::execute(&t.kernel, &refs).unwrap();
        for ((obj, _), b) in t.outputs.iter().zip(outs) {
            env.insert(*obj, b);
        }
    }
    env
}

/// Random-but-valid plan spec (same scheme as `tests/exec_steal.rs`):
/// decoded against earlier outputs so plans are executable and ordered.
#[derive(Debug)]
struct PlanSpec {
    nodes: usize,
    threads_per_node: usize,
    stealing: bool,
    n_seeds: usize,
    tasks: Vec<(u8, usize, usize, usize)>,
}

const SHAPE: [usize; 2] = [4, 4];
const BLOCK_BYTES: u64 = (SHAPE[0] * SHAPE[1] * 8) as u64;

fn decode(spec: &PlanSpec) -> (Plan, HashMap<u64, Block>) {
    let mut rng = Rng::seed_from_u64(0x3E3 ^ spec.tasks.len() as u64);
    let mut seeds = HashMap::new();
    let mut avail: Vec<u64> = Vec::new();
    for s in 0..spec.n_seeds {
        let mut v = vec![0.0; SHAPE[0] * SHAPE[1]];
        rng.fill_normal(&mut v);
        seeds.insert(s as u64, Block::from_vec(&SHAPE, v));
        avail.push(s as u64);
    }
    let mut tasks = Vec::new();
    for (i, &(kind, p1, p2, tgt)) in spec.tasks.iter().enumerate() {
        let out = 1000 + i as u64;
        let (kernel, inputs) = match kind % 5 {
            0 => (Kernel::Ew(BinOp::Add), vec![avail[p1 % avail.len()], avail[p2 % avail.len()]]),
            1 => (Kernel::Ew(BinOp::Mul), vec![avail[p1 % avail.len()], avail[p2 % avail.len()]]),
            2 => (Kernel::Neg, vec![avail[p1 % avail.len()]]),
            3 => (Kernel::Scale(0.5), vec![avail[p1 % avail.len()]]),
            _ => (Kernel::Matmul, vec![avail[p1 % avail.len()], avail[p2 % avail.len()]]),
        };
        let in_shapes = vec![SHAPE.to_vec(); inputs.len()];
        tasks.push(Task {
            kernel,
            inputs,
            in_shapes,
            outputs: vec![(out, SHAPE.to_vec())],
            target: tgt % spec.nodes,
            transfers: vec![],
        });
        avail.push(out);
    }
    (Plan { tasks }, seeds)
}

fn seeded_stores(spec: &PlanSpec, seeds: &HashMap<u64, Block>) -> StoreSet {
    let stores = StoreSet::new(spec.nodes);
    for (obj, b) in seeds {
        stores.put((*obj as usize) % spec.nodes, *obj, Arc::new(b.clone()));
    }
    stores
}

#[test]
fn prop_gc_and_spill_preserve_bit_identity_and_release_intermediates() {
    forall_res(
        0x6C6C,
        25,
        |r| PlanSpec {
            nodes: 1 + r.usize(3),
            threads_per_node: 1 + r.usize(3),
            stealing: r.usize(2) == 1,
            n_seeds: 2 + r.usize(4),
            tasks: (0..1 + r.usize(20))
                .map(|_| (r.usize(256) as u8, r.usize(1 << 16), r.usize(1 << 16), r.usize(1 << 16)))
                .collect(),
        },
        |spec| {
            let (plan, seeds) = decode(spec);
            let want = run_sequential(&plan, &seeds);

            let topo = Topology::new(spec.nodes, 2, SystemMode::Ray);
            let mut exec = RealExecutor::new(topo, Arc::new(Backend::native()))
                .with_stealing(spec.stealing)
                // GC on plus a tight 4-block budget: the worst case
                .with_memory(MemoryManager::new(spec.nodes, Some(4 * BLOCK_BYTES), true));
            exec.threads_per_node = spec.threads_per_node;
            let stores = seeded_stores(spec, &seeds);
            exec.run(&plan, &stores)
                .map_err(|e| format!("managed executor failed: {e}"))?;
            let mgr = exec.memory.as_ref().unwrap();

            let consumed: HashSet<u64> =
                plan.tasks.iter().flat_map(|t| t.inputs.iter().copied()).collect();
            for i in 0..plan.tasks.len() {
                let obj = 1000 + i as u64;
                if consumed.contains(&obj) {
                    // consumed intermediate: refcount GC must have
                    // released it from every store and spill file
                    if mgr.holds(&stores, obj) {
                        return Err(format!("dead intermediate {obj} still held"));
                    }
                    continue;
                }
                // terminal output: implicitly pinned, bit-identical
                let got = mgr
                    .fetch(&stores, obj)
                    .ok_or_else(|| format!("terminal output {obj} missing"))?;
                let w = &want[&obj];
                if got.shape != w.shape {
                    return Err(format!("shape mismatch on {obj}"));
                }
                if got.buf().iter().zip(w.buf()).any(|(a, b)| a.to_bits() != b.to_bits()) {
                    return Err(format!("output {obj} differs from sequential oracle"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_peak_bytes_with_gc_never_higher_than_without() {
    forall_res(
        0x9EA6,
        20,
        |r| PlanSpec {
            nodes: 1 + r.usize(3),
            threads_per_node: 1 + r.usize(2),
            stealing: false, // fixed placement: per-node byte adds identical
            n_seeds: 2 + r.usize(4),
            tasks: (0..2 + r.usize(20))
                .map(|_| (r.usize(256) as u8, r.usize(1 << 16), r.usize(1 << 16), r.usize(1 << 16)))
                .collect(),
        },
        |spec| {
            let (plan, seeds) = decode(spec);
            let run = |managed: bool| {
                let topo = Topology::new(spec.nodes, 2, SystemMode::Ray);
                let mut exec = RealExecutor::new(topo, Arc::new(Backend::native()))
                    .with_stealing(false);
                if managed {
                    exec = exec.with_memory(MemoryManager::new(spec.nodes, None, true));
                }
                exec.threads_per_node = spec.threads_per_node;
                let stores = seeded_stores(spec, &seeds);
                let rep = exec.run(&plan, &stores).unwrap();
                rep.store_snapshot
                    .iter()
                    .map(|&(_, peak, _, _)| peak)
                    .collect::<Vec<u64>>()
            };
            let peak_nogc = run(false);
            let peak_gc = run(true);
            for (n, (g, p)) in peak_gc.iter().zip(&peak_nogc).enumerate() {
                if g > p {
                    return Err(format!("node {n}: GC peak {g} > plain peak {p}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn skewed_matmul_chain_gc_strictly_lowers_peak() {
    // A@B chains targeted at one node: without GC every product stays
    // resident; with GC only the rolling pair lives. Deterministic (one
    // node, one worker), so strict inequality is guaranteed.
    let n = 48usize;
    let chain = 10usize;
    let block_bytes = (n * n * 8) as u64;
    let mut rng = Rng::seed_from_u64(0xC4A1);
    let mut av = vec![0.0; n * n];
    rng.fill_normal(&mut av);
    let mut bv = vec![0.0; n * n];
    rng.fill_normal(&mut bv);
    let plan = Plan {
        tasks: (0..chain)
            .map(|i| Task {
                kernel: Kernel::Matmul,
                inputs: vec![if i == 0 { 0 } else { 99 + i as u64 }, 1],
                in_shapes: vec![vec![n, n], vec![n, n]],
                outputs: vec![(100 + i as u64, vec![n, n])],
                target: 0,
                transfers: vec![],
            })
            .collect(),
    };
    let run = |managed: bool| {
        let topo = Topology::new(1, 1, SystemMode::Ray);
        let mut exec = RealExecutor::new(topo, Arc::new(Backend::native()));
        exec.threads_per_node = 1;
        if managed {
            exec = exec.with_memory(MemoryManager::new(1, None, true));
        }
        let stores = StoreSet::new(1);
        stores.put(0, 0, Arc::new(Block::from_vec(&[n, n], av.clone())));
        stores.put(0, 1, Arc::new(Block::from_vec(&[n, n], bv.clone())));
        let rep = exec.run(&plan, &stores).unwrap();
        let last = 99 + chain as u64;
        let out = match &exec.memory {
            Some(m) => m.fetch(&stores, last).unwrap(),
            None => stores.fetch(last).unwrap(),
        };
        (rep.store_snapshot[0].1, out.as_ref().clone())
    };
    let (peak_plain, out_plain) = run(false);
    let (peak_gc, out_gc) = run(true);
    assert_eq!(out_plain.max_abs_diff(&out_gc), 0.0, "GC changed numerics");
    assert_eq!(peak_plain, (chain as u64 + 2) * block_bytes);
    assert!(
        peak_gc < peak_plain,
        "GC peak {peak_gc} must be strictly below {peak_plain}"
    );
    // rolling working set: 2 seeds + current product + previous product
    assert!(peak_gc <= 4 * block_bytes, "GC peak {peak_gc}");
}

#[test]
fn glm_newton_with_gc_strictly_lowers_session_peak() {
    // acceptance: a multi-iteration GLM shows strictly lower per-node
    // peak_bytes with the memory manager's lifetime GC than without
    let run = |gc: bool| {
        let cfg = SessionConfig::real_small(2, 2)
            .with_stealing(false)
            .with_lifetime_gc(gc);
        let mut sess = Session::new(cfg);
        let (x, y) = classification_data(&mut sess, 512, 8, 8, 17);
        let res = newton_fit(&mut sess, &x, &y, 3, 0.0).unwrap();
        let beta = sess.fetch(&res.beta).unwrap();
        let last_real = res
            .reports
            .last()
            .and_then(|r| r.real.as_ref())
            .expect("real mode");
        let max_peak = last_real
            .store_snapshot
            .iter()
            .map(|&(_, p, _, _)| p)
            .max()
            .unwrap();
        let gc_freed: u64 = res
            .reports
            .iter()
            .filter_map(|r| r.real.as_ref())
            .flat_map(|r| r.mem_stats.iter().map(|m| m.gc_freed_bytes))
            .sum();
        (beta, max_peak, gc_freed)
    };
    let (beta_plain, peak_plain, freed_plain) = run(false);
    let (beta_gc, peak_gc, freed_gc) = run(true);
    assert_eq!(
        beta_plain.max_abs_diff(&beta_gc),
        0.0,
        "lifetime GC changed GLM numerics"
    );
    assert_eq!(freed_plain, 0, "GC off must free nothing");
    assert!(freed_gc > 0, "3 Newton iterations must free intermediates");
    assert!(
        peak_gc < peak_plain,
        "GC peak {peak_gc} must be strictly below {peak_plain}"
    );
}

#[test]
fn constrained_budget_session_completes_with_spill_and_readback() {
    // acceptance: a session whose data exceeds mem_budget_bytes completes
    // correctly and reports nonzero spill/read-back traffic
    let block_bytes = (64 * 32 * 8) as u64; // 16 KiB creation blocks
    let run = |budget: Option<u64>| {
        let mut cfg = SessionConfig::real_small(1, 1).with_stealing(false);
        cfg.mem_budget_bytes = budget;
        let mut sess = Session::new(cfg);
        let x = sess.randn(&[1024, 32], &[16, 1]); // 16 blocks, 256 KiB
        let y = sess.randn(&[1024, 32], &[16, 1]);
        let (out, rep) = ops::add(&mut sess, &x, &y).unwrap();
        let dense = sess.fetch(&out).unwrap();
        (dense, rep.real.unwrap())
    };
    let (want, free_rep) = run(None);
    let (got, tight_rep) = run(Some(4 * block_bytes));
    assert_eq!(want.max_abs_diff(&got), 0.0, "spilling changed results");
    assert_eq!(free_rep.mem_stats.iter().map(|m| m.spilled_bytes).sum::<u64>(), 0);
    let spilled: u64 = tight_rep.mem_stats.iter().map(|m| m.spilled_bytes).sum();
    let readback: u64 = tight_rep.mem_stats.iter().map(|m| m.readback_bytes).sum();
    assert!(spilled > 0, "a 4-block budget over 32 blocks must spill");
    assert!(readback > 0, "spilled operands must be read back for the add");
}

#[test]
fn stolen_input_replicas_are_evicted_under_pressure() {
    // skewed plan + tight budget on a 2-node cluster: thieves accumulate
    // replica copies of node 0's inputs, and pressure must reclaim them
    // via replica eviction (stolen-input cleanup), never losing data
    let n = 32usize;
    let k_tasks = 24usize;
    let block_bytes = (n * n * 8) as u64;
    let mut rng = Rng::seed_from_u64(0xEB1C);
    let mut seeds = HashMap::new();
    for i in 0..2 * k_tasks as u64 {
        let mut v = vec![0.0; n * n];
        rng.fill_normal(&mut v);
        seeds.insert(i, Block::from_vec(&[n, n], v));
    }
    let plan = Plan {
        tasks: (0..k_tasks)
            .map(|i| Task {
                kernel: Kernel::Matmul,
                inputs: vec![(2 * i) as u64, (2 * i + 1) as u64],
                in_shapes: vec![vec![n, n], vec![n, n]],
                outputs: vec![(1000 + i as u64, vec![n, n])],
                target: 0,
                transfers: vec![],
            })
            .collect(),
    };
    let want = run_sequential(&plan, &seeds);
    let topo = Topology::new(2, 2, SystemMode::Ray);
    let mut exec = RealExecutor::new(topo, Arc::new(Backend::native()))
        .with_stealing(true)
        .with_memory(MemoryManager::new(2, Some(8 * block_bytes), true));
    exec.threads_per_node = 2;
    let stores = StoreSet::new(2);
    for (obj, b) in &seeds {
        stores.put(0, *obj, Arc::new(b.clone()));
    }
    let rep = exec.run(&plan, &stores).unwrap();
    let stolen: usize = rep.node_stats.iter().map(|s| s.tasks_stolen).sum();
    assert!(stolen > 0, "skewed plan must trigger stealing");
    let replica_evicted: u64 = rep
        .mem_stats
        .iter()
        .map(|m| m.evicted_replica_bytes)
        .sum();
    assert!(
        replica_evicted > 0,
        "pressure on the thief must reclaim stolen-input replicas: {:?}",
        rep.mem_stats
    );
    // every terminal output still correct
    let mgr = exec.memory.as_ref().unwrap();
    for i in 0..k_tasks {
        let obj = 1000 + i as u64;
        let got = mgr.fetch(&stores, obj).unwrap();
        let w = &want[&obj];
        assert_eq!(got.max_abs_diff(w), 0.0, "output {obj} wrong");
    }
}
