//! Work-stealing real-executor suites: the dependency-counted, stealing
//! executor must be a pure scheduling optimization — outputs bit-identical
//! to sequential plan-order execution for every random graph, node count,
//! thread count, and stealing mode — and must actually steal on skewed
//! plans.

use std::collections::HashMap;
use std::sync::Arc;

use nums::exec::{Plan, RealExecutor, Task};
use nums::prelude::*;
use nums::runtime::native;
use nums::store::StoreSet;
use nums::util::prop::forall_res;

/// Sequential oracle: run the plan in order, single process, no stores.
fn run_sequential(plan: &Plan, seeds: &HashMap<u64, Block>) -> HashMap<u64, Block> {
    let mut env: HashMap<u64, Block> = seeds.clone();
    for t in &plan.tasks {
        let refs: Vec<&Block> = t.inputs.iter().map(|o| &env[o]).collect();
        let outs = native::execute(&t.kernel, &refs).unwrap();
        for ((obj, _), b) in t.outputs.iter().zip(outs) {
            env.insert(*obj, b);
        }
    }
    env
}

/// Random-but-valid plan spec: decoded against `avail` (seed objects plus
/// earlier task outputs), so every generated graph is executable and the
/// plan order is topological.
#[derive(Debug)]
struct PlanSpec {
    nodes: usize,
    workers_per_node: usize,
    threads_per_node: usize,
    stealing: bool,
    n_seeds: usize,
    /// (kernel kind, input pick 1, input pick 2, target pick) per task.
    tasks: Vec<(u8, usize, usize, usize)>,
}

const SHAPE: [usize; 2] = [4, 4];

fn decode(spec: &PlanSpec) -> (Plan, HashMap<u64, Block>) {
    let mut rng = Rng::seed_from_u64(0xB10C ^ spec.tasks.len() as u64);
    let mut seeds = HashMap::new();
    let mut avail: Vec<u64> = Vec::new();
    for s in 0..spec.n_seeds {
        let mut v = vec![0.0; SHAPE[0] * SHAPE[1]];
        rng.fill_normal(&mut v);
        seeds.insert(s as u64, Block::from_vec(&SHAPE, v));
        avail.push(s as u64);
    }
    let mut tasks = Vec::new();
    for (i, &(kind, p1, p2, tgt)) in spec.tasks.iter().enumerate() {
        let out = 1000 + i as u64;
        let (kernel, inputs) = match kind % 5 {
            0 => (Kernel::Ew(BinOp::Add), vec![avail[p1 % avail.len()], avail[p2 % avail.len()]]),
            1 => (Kernel::Ew(BinOp::Mul), vec![avail[p1 % avail.len()], avail[p2 % avail.len()]]),
            2 => (Kernel::Neg, vec![avail[p1 % avail.len()]]),
            3 => (Kernel::Scale(0.5), vec![avail[p1 % avail.len()]]),
            _ => (Kernel::Matmul, vec![avail[p1 % avail.len()], avail[p2 % avail.len()]]),
        };
        let in_shapes = vec![SHAPE.to_vec(); inputs.len()];
        tasks.push(Task {
            kernel,
            inputs,
            in_shapes,
            outputs: vec![(out, SHAPE.to_vec())],
            target: tgt % spec.nodes,
            transfers: vec![],
        });
        avail.push(out);
    }
    (Plan { tasks }, seeds)
}

#[test]
fn prop_stealing_executor_matches_sequential_bit_for_bit() {
    forall_res(
        0x57EA1,
        30,
        |r| PlanSpec {
            nodes: 1 + r.usize(4),
            workers_per_node: 1 + r.usize(3),
            threads_per_node: 1 + r.usize(3),
            stealing: r.usize(2) == 1,
            n_seeds: 2 + r.usize(4),
            tasks: (0..1 + r.usize(24))
                .map(|_| (r.usize(256) as u8, r.usize(1 << 16), r.usize(1 << 16), r.usize(1 << 16)))
                .collect(),
        },
        |spec| {
            let (plan, seeds) = decode(spec);
            let want = run_sequential(&plan, &seeds);

            let topo = Topology::new(spec.nodes, spec.workers_per_node, SystemMode::Ray);
            let mut exec = RealExecutor::new(topo, Arc::new(Backend::native()))
                .with_stealing(spec.stealing);
            exec.threads_per_node = spec.threads_per_node;
            let stores = StoreSet::new(spec.nodes);
            for (obj, b) in &seeds {
                stores.put((*obj as usize) % spec.nodes, *obj, Arc::new(b.clone()));
            }
            let rep = exec
                .run(&plan, &stores)
                .map_err(|e| format!("executor failed: {e}"))?;
            if rep.tasks != plan.tasks.len() {
                return Err(format!("report says {} tasks, plan has {}", rep.tasks, plan.tasks.len()));
            }
            let total_run: usize = rep.node_stats.iter().map(|s| s.tasks_run).sum();
            if total_run != plan.tasks.len() {
                return Err(format!("{total_run} tasks run != {} planned", plan.tasks.len()));
            }
            if !spec.stealing && rep.node_stats.iter().any(|s| s.tasks_stolen > 0) {
                return Err("stole with stealing disabled".into());
            }
            for i in 0..plan.tasks.len() {
                let obj = 1000 + i as u64;
                let got = stores
                    .fetch(obj)
                    .ok_or_else(|| format!("output {obj} missing from every store"))?;
                let w = &want[&obj];
                if got.shape != w.shape {
                    return Err(format!("shape mismatch on {obj}"));
                }
                // bit-identical, not approximately equal
                if got.buf().iter().zip(w.buf()).any(|(a, b)| a.to_bits() != b.to_bits()) {
                    return Err(format!("output {obj} differs from sequential oracle"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn skewed_plan_gets_stolen_by_other_nodes_and_stays_bit_identical() {
    // every task targeted at node 0 of 4 nodes: the canonical worst case
    // for FIFO node-affinity execution
    let nodes = 4usize;
    let n = 128usize;
    let k_tasks = 40usize;
    let mut rng = Rng::seed_from_u64(0x5C3A);
    let mut seeds = HashMap::new();
    for i in 0..2 * k_tasks as u64 {
        let mut v = vec![0.0; n * n];
        rng.fill_normal(&mut v);
        seeds.insert(i, Block::from_vec(&[n, n], v));
    }
    let plan = Plan {
        tasks: (0..k_tasks)
            .map(|i| Task {
                kernel: Kernel::Matmul,
                inputs: vec![(2 * i) as u64, (2 * i + 1) as u64],
                in_shapes: vec![vec![n, n], vec![n, n]],
                outputs: vec![(1000 + i as u64, vec![n, n])],
                target: 0,
                transfers: vec![],
            })
            .collect(),
    };

    let run = |stealing: bool| {
        let topo = Topology::new(nodes, 2, SystemMode::Ray);
        let mut exec =
            RealExecutor::new(topo, Arc::new(Backend::native())).with_stealing(stealing);
        exec.threads_per_node = 2;
        let stores = StoreSet::new(nodes);
        for (obj, b) in &seeds {
            stores.put(0, *obj, Arc::new(b.clone()));
        }
        let rep = exec.run(&plan, &stores).unwrap();
        let outs: Vec<Block> = (0..k_tasks)
            .map(|i| stores.fetch(1000 + i as u64).unwrap().as_ref().clone())
            .collect();
        (rep, outs)
    };

    let (baseline, base_outs) = run(false);
    let (stolen, steal_outs) = run(true);

    // without stealing, node 0 does everything
    assert_eq!(baseline.node_stats[0].tasks_run, k_tasks);
    assert!(baseline.node_stats[1..].iter().all(|s| s.tasks_run == 0));

    // with stealing, at least two other nodes take a nonzero share and
    // pay real bytes for it
    let stealers = stolen.node_stats[1..]
        .iter()
        .filter(|s| s.tasks_stolen > 0)
        .count();
    assert!(
        stealers >= 2,
        "expected >=2 stealing nodes, stats: {:?}",
        stolen.node_stats
    );
    assert!(
        stolen.node_stats.iter().map(|s| s.steal_bytes).sum::<u64>() > 0,
        "stolen tasks must account transfer bytes"
    );
    let total: usize = stolen.node_stats.iter().map(|s| s.tasks_run).sum();
    assert_eq!(total, k_tasks);

    // and the numerics are exactly the same
    for (a, b) in base_outs.iter().zip(&steal_outs) {
        assert_eq!(a.max_abs_diff(b), 0.0, "stealing changed results");
    }
}

#[test]
fn session_reports_steal_counters_through_run() {
    // end-to-end: a real session exposes per-node stats on RunReport
    let mut sess = Session::new(SessionConfig::real_small(2, 2));
    let x = sess.randn(&[256, 32], &[4, 1]);
    let y = sess.randn(&[256, 32], &[4, 1]);
    let (_, rep) = nums::api::ops::add(&mut sess, &x, &y).unwrap();
    let real = rep.real.expect("real mode");
    assert_eq!(real.node_stats.len(), 2);
    let total: usize = real.node_stats.iter().map(|s| s.tasks_run).sum();
    assert_eq!(total, rep.tasks);

    // stealing can be disabled per session
    let mut sess2 = Session::new(SessionConfig::real_small(2, 2).with_stealing(false));
    let x2 = sess2.randn(&[256, 32], &[4, 1]);
    let y2 = sess2.randn(&[256, 32], &[4, 1]);
    let (_, rep2) = nums::api::ops::add(&mut sess2, &x2, &y2).unwrap();
    let real2 = rep2.real.expect("real mode");
    assert!(real2.node_stats.iter().all(|s| s.tasks_stolen == 0));
}
