//! Plan-cache suites.
//!
//! The cache memoizes a *symbolic* plan per canonical graph signature and
//! rebinds it onto fresh object ids on a hit (`scheduler::plan_cache`).
//! The correctness bar is exactness: a cached run must execute a schedule
//! that is semantically identical to plan-order sequential execution
//! (bit-for-bit, kernel by kernel), its ClusterState replay must keep the
//! Eq. 2 accounting identities intact, and a rebind must never reference
//! an object that lifetime GC already released. The suites here check all
//! of that through the public `Session` API only — across multi-run GLM
//! sessions with feedback on, lifetime GC on, and skewed `create_at`
//! layouts — plus signature collision sanity (kernel kind, scale
//! parameter, operand aliasing, and input placement must all miss).

use std::collections::{HashMap, HashSet};

use nums::api::{ops, RunReport, Session, SessionConfig};
use nums::exec::Plan;
use nums::glm::data::{classification_data, classification_dense};
use nums::glm::{newton_fit, newton_fit_serial};
use nums::prelude::*;
use nums::runtime::native;

/// Sequential oracle: run the plan in order, single process, no stores.
fn run_sequential(plan: &Plan, seeds: &HashMap<u64, Block>) -> HashMap<u64, Block> {
    let mut env: HashMap<u64, Block> = seeds.clone();
    for t in &plan.tasks {
        let refs: Vec<&Block> = t.inputs.iter().map(|o| &env[o]).collect();
        let outs = native::execute(&t.kernel, &refs).unwrap();
        for ((obj, _), b) in t.outputs.iter().zip(outs) {
            env.insert(*obj, b);
        }
    }
    env
}

/// The plan's leaf inputs (task inputs the plan itself does not produce),
/// fetched out of the session stores. Leaves are externally-owned arrays,
/// so they are never lifetime-GC'd and must all still be resident — a
/// rebound plan referencing a forgotten object panics right here.
fn plan_seeds(sess: &Session, plan: &Plan) -> HashMap<u64, Block> {
    let produced: HashSet<u64> = plan.produced().map(|(o, _, _)| o).collect();
    let mut seeds = HashMap::new();
    for t in &plan.tasks {
        for &obj in &t.inputs {
            if produced.contains(&obj) || seeds.contains_key(&obj) {
                continue;
            }
            let b = sess
                .stores
                .fetch(obj)
                .unwrap_or_else(|| panic!("plan input {obj} is not resident"));
            seeds.insert(obj, b.as_ref().clone());
        }
    }
    seeds
}

/// One scheduled run's worth of evidence for the oracle/rebind audits.
struct RunTrace {
    rep: RunReport,
    plan: Plan,
    outs: Vec<DistArray>,
}

/// Hand-rolled Newton loop — the same two graphs per iteration that
/// `glm::newton_fit` submits, but keeping every run's report, plan, and
/// output arrays alive so the oracle can replay them afterwards.
fn newton_runs(
    sess: &mut Session,
    x: &DistArray,
    y: &DistArray,
    steps: usize,
) -> (DistArray, Vec<RunTrace>) {
    let d = x.grid.shape[1];
    let mut beta = sess.zeros(&[d, 1], &[1, 1]);
    let mut traces = Vec::new();
    for _ in 0..steps {
        let mut g = Graph::new();
        build::glm_newton(&mut g, x, y, &beta);
        let (outs, rep) = sess.run(&mut g).unwrap();
        let plan = sess.last_plan.clone().unwrap();
        let (grad, hess) = (outs[0].clone(), outs[1].clone());
        traces.push(RunTrace { rep, plan, outs });

        let mut g2 = Graph::new();
        let lh = g2.leaf(hess.single_obj(), &[d, d]);
        let lg = g2.leaf(grad.single_obj(), &[d, 1]);
        let lb = g2.leaf(beta.single_obj(), &[d, 1]);
        let dir = g2.op(Kernel::SolveSpd, vec![(lh, 0), (lg, 0)]);
        let upd = g2.op(Kernel::Ew(BinOp::Sub), vec![(lb, 0), (dir, 0)]);
        g2.add_output(ArrayGrid::new(&[d, 1], &[1, 1]), vec![(upd, 0)]);
        let (outs2, rep2) = sess.run(&mut g2).unwrap();
        let plan2 = sess.last_plan.clone().unwrap();
        beta = outs2[0].clone();
        traces.push(RunTrace {
            rep: rep2,
            plan: plan2,
            outs: outs2,
        });
    }
    (beta, traces)
}

/// Replay every traced plan through the sequential oracle and compare the
/// run's surviving output blocks bit-for-bit against the stores.
fn assert_oracle_exact(sess: &Session, traces: &[RunTrace]) {
    for (i, tr) in traces.iter().enumerate() {
        let seeds = plan_seeds(sess, &tr.plan);
        let env = run_sequential(&tr.plan, &seeds);
        for arr in &tr.outs {
            for &obj in &arr.blocks {
                let got = sess
                    .stores
                    .fetch(obj)
                    .unwrap_or_else(|| panic!("run {i}: output {obj} not resident"));
                let want = &env[&obj];
                assert_eq!(got.shape, want.shape, "run {i}: shape of {obj}");
                assert!(
                    got.buf()
                        .iter()
                        .zip(want.buf())
                        .all(|(a, b)| a.to_bits() == b.to_bits()),
                    "run {i}: output {obj} diverges from the sequential oracle \
                     (hit={})",
                    tr.rep.plan_cache_hit
                );
            }
        }
    }
}

#[test]
fn glm_second_iteration_hits_and_skips_the_search() {
    // acceptance: on a repeated-topology GLM session, iteration 2 reports
    // a cache hit with zero candidate simulations. Stealing off keeps the
    // runs feedback-quiet, so no staleness aging interferes.
    let cfg = SessionConfig::real_small(2, 2).with_stealing(false);
    let mut sess = Session::new(cfg);
    let (x, y) = classification_data(&mut sess, 512, 8, 4, 0xAB);
    let res = newton_fit(&mut sess, &x, &y, 3, 0.0).unwrap();
    assert!(res.reports.len() >= 4, "3 iterations, 2 graphs each");
    assert!(!res.reports[0].plan_cache_hit, "iteration 1 is cold");
    assert!(res.reports[0].simulations > 0, "iteration 1 must search");
    for (i, rep) in res.reports.iter().enumerate().skip(2) {
        assert!(rep.plan_cache_hit, "run {i} (iteration >= 2) must hit");
        assert_eq!(rep.simulations, 0, "run {i}: a hit never simulates");
        assert_eq!(rep.decisions, 0, "run {i}: a hit never decides");
    }
    let (hits, misses, stale) = sess.plan_cache_stats();
    assert_eq!(misses, 2, "exactly the two iteration-1 graphs are cold");
    assert!(hits >= 4, "iterations 2..3 replay both graphs: {hits}");
    assert_eq!(stale, 0, "quiet runs must not age entries");
}

#[test]
fn cached_runs_are_bit_identical_to_the_sequential_oracle() {
    // every run — cold schedules and rebound replays alike, with
    // lifetime GC and feedback at their defaults — must execute exactly
    // the plan's kernel sequence
    let cfg = SessionConfig::real_small(2, 2).with_stealing(false);
    let mut sess = Session::new(cfg);
    let (x, y) = classification_data(&mut sess, 512, 8, 4, 0x11);
    let (_, traces) = newton_runs(&mut sess, &x, &y, 3);
    let hit_runs = traces.iter().filter(|t| t.rep.plan_cache_hit).count();
    assert!(hit_runs >= 4, "iterations 2..3 must replay, got {hit_runs}");
    assert_oracle_exact(&sess, &traces);
}

#[test]
fn skewed_feedback_gc_sessions_stay_oracle_exact_with_cache_on_and_off() {
    // the adversarial property arm: every creation block on node 0
    // (skewed `create_at` layout), stealing on so the executor migrates
    // work and the feedback loop absorbs real drift (which may age cache
    // entries into foreground re-plans — also a correct path), lifetime
    // GC on. Both cache arms must stay bitwise oracle-exact run by run,
    // and their fits may differ only by reduce-order roundoff.
    let mut betas = Vec::new();
    for cache in [true, false] {
        let cfg = SessionConfig::real_small(2, 2).with_plan_cache(cache);
        let mut sess = Session::new(cfg);
        let x = sess.randn_at(&[256, 8], &[4, 1], 0);
        let y = sess.create_at(&[256, 1], &[4, 1], 0, |rng, bs, _| {
            (0..bs.iter().product::<usize>())
                .map(|_| if rng.bool(0.5) { 1.0 } else { 0.0 })
                .collect()
        });
        let (beta, traces) = newton_runs(&mut sess, &x, &y, 3);
        if !cache {
            assert!(
                traces.iter().all(|t| !t.rep.plan_cache_hit),
                "cache off must never report a hit"
            );
        }
        assert_oracle_exact(&sess, &traces);
        betas.push(sess.fetch(&beta).unwrap());
    }
    let scale = betas[0].buf().iter().fold(1.0f64, |m, v| m.max(v.abs()));
    let rel = betas[0].max_abs_diff(&betas[1]) / scale;
    assert!(rel < 1e-6, "cache toggle moved the fit beyond roundoff: {rel:e}");
}

#[test]
fn on_off_and_serial_agree_on_classification_glm() {
    // same data, three solvers: cache-on session, cache-off session, and
    // the dense serial baseline — all within reduce-order roundoff
    let n = 1024;
    let (xd, yd) = classification_dense(n, 8, 0xCD);
    let serial = newton_fit_serial(&xd, &yd, 5, 0.0).unwrap();
    for cache in [true, false] {
        let cfg = SessionConfig::real_small(4, 2).with_plan_cache(cache);
        let mut sess = Session::new(cfg);
        let (x, y) = classification_data(&mut sess, n, 8, 4, 0xCD);
        let res = newton_fit(&mut sess, &x, &y, 5, 0.0).unwrap();
        let beta = sess.fetch(&res.beta).unwrap();
        assert!(
            beta.max_abs_diff(&serial.beta) < 1e-7,
            "cache={cache}: distributed Newton diverges from dense"
        );
    }
}

#[test]
fn signature_collisions_do_not_false_hit() {
    // a false hit replays the wrong plan — wrong math, not just a wrong
    // placement — so every semantically distinct graph must miss.
    // (Stealing off: the repeat-graph *hit* assertions below must not be
    // subject to feedback-driven staleness aging.)
    let mut sess = Session::new(SessionConfig::real_small(2, 2).with_stealing(false));
    let x = sess.randn(&[64, 64], &[2, 1]);
    let y = sess.randn(&[64, 64], &[2, 1]);

    let (_, r1) = ops::add(&mut sess, &x, &y).unwrap();
    assert!(!r1.plan_cache_hit, "first sight is cold");
    let (_, r2) = ops::add(&mut sess, &x, &y).unwrap();
    assert!(r2.plan_cache_hit, "identical graph + placement must hit");
    assert_eq!(r2.simulations, 0);

    let (_, r3) = ops::mul(&mut sess, &x, &y).unwrap();
    assert!(!r3.plan_cache_hit, "kernel kind distinguishes");

    let (_, r4) = ops::add(&mut sess, &x, &x).unwrap();
    assert!(!r4.plan_cache_hit, "operand aliasing (x+x vs x+y) distinguishes");

    let none: [&DistArray; 0] = [];
    let (_, r5) = ops::ew_chain(&mut sess, &x, &none, &[EwStep::Scale(2.0)]).unwrap();
    assert!(!r5.plan_cache_hit);
    let (_, r6) = ops::ew_chain(&mut sess, &x, &none, &[EwStep::Scale(2.0)]).unwrap();
    assert!(r6.plan_cache_hit, "same scale parameter must hit");
    let (_, r7) = ops::ew_chain(&mut sess, &x, &none, &[EwStep::Scale(3.0)]).unwrap();
    assert!(!r7.plan_cache_hit, "scale parameter bits distinguish");

    // same topology, same shapes — but the inputs live elsewhere, so the
    // memoized placements would be wrong
    let x1 = sess.randn_at(&[64, 64], &[2, 1], 1);
    let y1 = sess.randn_at(&[64, 64], &[2, 1], 1);
    let (_, r8) = ops::add(&mut sess, &x1, &y1).unwrap();
    assert!(!r8.plan_cache_hit, "input placement distinguishes");
}

#[test]
fn plan_cache_toggle_is_bit_transparent_for_elementwise_pipelines() {
    // element-wise ops are block-local: placement can never change their
    // numerics, so even across repeated runs (where the cache does alter
    // *how* plans are obtained) the toggle must stay bit-transparent.
    // Stealing off keeps feedback quiet, so the second-run hit assertion
    // is deterministic rather than subject to staleness aging.
    let run = |cache: bool| {
        let cfg = SessionConfig::real_small(2, 2)
            .with_stealing(false)
            .with_plan_cache(cache);
        let mut sess = Session::new(cfg);
        let x = sess.randn_at(&[128, 128], &[4, 4], 0);
        let y = sess.randn_at(&[128, 128], &[4, 4], 0);
        let (a, _) = ops::add(&mut sess, &x, &y).unwrap();
        let (a2, rep) = ops::add(&mut sess, &x, &y).unwrap();
        assert_eq!(rep.plan_cache_hit, cache, "second identical run");
        let (b, _) = ops::mul(&mut sess, &a, &a2).unwrap();
        let (c, _) = ops::neg(&mut sess, &b).unwrap();
        sess.fetch(&c).unwrap()
    };
    let on = run(true);
    let off = run(false);
    assert_eq!(on.max_abs_diff(&off), 0.0, "cache changed elementwise bits");
}

#[test]
fn rebound_plans_after_gc_reference_only_live_objects() {
    // lifetime GC releases dead intermediates during each run and the
    // session forgets them from the load model; a later cache hit rebinds
    // the symbolic plan onto *this* run's inputs, so no rebound task may
    // reference an object any earlier run released
    let cfg = SessionConfig::real_small(2, 2).with_stealing(false);
    let mut sess = Session::new(cfg);
    let (x, y) = classification_data(&mut sess, 512, 8, 4, 0x77);
    let (_, traces) = newton_runs(&mut sess, &x, &y, 4);

    let mut released: HashSet<u64> = HashSet::new();
    let mut audited_hits = 0usize;
    for (i, tr) in traces.iter().enumerate() {
        if let Some(real) = &tr.rep.real {
            released.extend(real.gc_released.iter().copied());
        }
        if !tr.rep.plan_cache_hit {
            continue;
        }
        audited_hits += 1;
        let produced: HashSet<u64> = tr.plan.produced().map(|(o, _, _)| o).collect();
        for t in &tr.plan.tasks {
            for &obj in &t.inputs {
                if produced.contains(&obj) {
                    continue;
                }
                assert!(
                    !released.contains(&obj),
                    "run {i}: rebound plan references GC-released object {obj}"
                );
                assert!(
                    sess.state.size_of(obj) > 0.0,
                    "run {i}: rebound input {obj} missing from the load model"
                );
                assert!(
                    sess.stores.fetch(obj).is_some(),
                    "run {i}: rebound input {obj} not resident in any store"
                );
            }
        }
    }
    assert!(audited_hits >= 6, "iterations 2..4 must replay: {audited_hits}");
    assert!(
        !released.is_empty(),
        "the GLM graphs must produce GC-dead intermediates for this audit \
         to mean anything"
    );
}
