//! Tensor algebra integration (§8.4): distributed vs dense numerics at
//! larger sizes, node-grid sensitivity, LSHS vs round-robin at paper scale.

use nums::api::{ops, Policy, Session, SessionConfig};
use nums::prelude::*;

#[test]
fn mttkrp_correct_over_many_grids() {
    for grid in [[1usize, 1, 1], [2, 1, 1], [2, 2, 2], [4, 2, 1], [3, 2, 2]] {
        let mut sess = Session::new(SessionConfig::real_small(4, 2));
        let x = sess.randn(&[12, 8, 8], &grid);
        let b = sess.randn(&[8, 6], &[grid[1], 1]);
        let c = sess.randn(&[8, 6], &[grid[2], 1]);
        let (out, _) = ops::mttkrp(&mut sess, &x, &b, &c).unwrap();
        let want = nums::tensor::mttkrp_dense(
            &sess.fetch(&x).unwrap(),
            &sess.fetch(&b).unwrap(),
            &sess.fetch(&c).unwrap(),
        );
        assert!(
            sess.fetch(&out).unwrap().max_abs_diff(&want) < 1e-9,
            "grid {grid:?}"
        );
    }
}

#[test]
fn tensordot_correct_over_grids() {
    for (gx, gy) in [([2usize, 2, 2], [2usize, 2, 2]), ([1, 2, 1], [2, 1, 2]), ([3, 1, 2], [1, 2, 1])] {
        let mut sess = Session::new(SessionConfig::real_small(4, 2));
        let x = sess.randn(&[6, 4, 4], &gx);
        let y = sess.randn(&[4, 4, 6], &gy);
        if gx[1] != gy[0] || gx[2] != gy[1] {
            continue; // contract grids must align by construction
        }
        let (out, _) = ops::tensordot(&mut sess, &x, &y).unwrap();
        let want =
            nums::tensor::tensordot_dense(&sess.fetch(&x).unwrap(), &sess.fetch(&y).unwrap());
        assert!(sess.fetch(&out).unwrap().max_abs_diff(&want) < 1e-9);
    }
}

#[test]
fn mttkrp_node_grid_16x1x1_wins() {
    // Fig. 13a: partitioning along J with a 16x1x1 node grid keeps the
    // (j,k) contraction local; a cubic grid must shuffle factors.
    let run = |grid_dims: &[usize]| {
        let cfg = SessionConfig::paper_sim(16, 32)
            .with_node_grid(NodeGrid::new(grid_dims));
        let mut sess = Session::new(cfg);
        let x = sess.zeros(&[512, 512, 512], &[16, 4, 4]);
        let b = sess.zeros(&[512, 100], &[4, 1]);
        let c = sess.zeros(&[512, 100], &[4, 1]);
        let (_, rep) = ops::mttkrp(&mut sess, &x, &b, &c).unwrap();
        rep.sim.makespan
    };
    let linear = run(&[16, 1, 1]);
    let cubic = run(&[4, 2, 2]);
    assert!(
        linear <= cubic * 1.05,
        "16x1x1 {linear:.4}s should not lose to cubic {cubic:.4}s"
    );
}

#[test]
fn lshs_vs_round_robin_mttkrp_paper_scale() {
    // Fig. 13a's headline: LSHS >> dynamic scheduling on MTTKRP.
    let run = |policy: Policy| {
        let cfg = SessionConfig::paper_sim(16, 32)
            .with_policy(policy)
            .with_node_grid(NodeGrid::new(&[16, 1, 1]));
        let mut sess = Session::new(cfg);
        let x = sess.zeros(&[1024, 1024, 1024], &[16, 4, 4]);
        let b = sess.zeros(&[1024, 100], &[4, 1]);
        let c = sess.zeros(&[1024, 100], &[4, 1]);
        let (_, rep) = ops::mttkrp(&mut sess, &x, &b, &c).unwrap();
        (rep.sim.makespan, rep.transfer_bytes)
    };
    let (t_lshs, b_lshs) = run(Policy::Lshs);
    let (t_rr, b_rr) = run(Policy::RoundRobin);
    // time is the headline metric (Fig. 13a). Traffic can tie or slightly
    // favor RR (both must broadcast the factor matrices); print for info.
    eprintln!("mttkrp traffic: lshs {b_lshs} rr {b_rr}");
    assert!(
        t_lshs < t_rr,
        "LSHS {t_lshs:.3}s must beat round-robin {t_rr:.3}s"
    );
}
