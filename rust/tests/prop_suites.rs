//! Property suites over randomized inputs (in-tree `util::prop` driver —
//! proptest is unavailable offline). Each property runs a few hundred
//! seeded cases and panics with the replay seed on failure.

use nums::api::{ops, Policy, Session, SessionConfig};
use nums::grid::{softmax_grid, ArrayGrid, Layout, NodeGrid};
use nums::prelude::*;
use nums::util::prop::{forall, forall_res};

// --------------------------------------------------------------- grids

#[test]
fn prop_grid_flat_coords_roundtrip() {
    forall(
        0x61D1,
        300,
        |r| {
            let ndim = 1 + r.usize(3);
            let shape: Vec<usize> = (0..ndim).map(|_| 1 + r.usize(40)).collect();
            let grid: Vec<usize> = shape.iter().map(|&s| 1 + r.usize(s.min(6))).collect();
            (shape, grid)
        },
        |(shape, grid)| {
            let g = ArrayGrid::new(shape, grid);
            (0..g.num_blocks()).all(|f| g.flat_of(&g.coords_of(f)) == f)
        },
    );
}

#[test]
fn prop_block_extents_tile_shape() {
    forall_res(
        0x61D2,
        300,
        |r| (1 + r.usize(10_000), 1 + r.usize(64)),
        |&(s, g)| {
            let g = g.min(s);
            let a = ArrayGrid::new(&[s], &[g]);
            let total: usize = (0..g).map(|b| a.block_extent(0, b)).sum();
            if total != s {
                return Err(format!("extents sum {total} != {s}"));
            }
            // offsets strictly increasing, last + extent == s
            let last = a.block_offset(0, g - 1) + a.block_extent(0, g - 1);
            if last != s {
                return Err(format!("last block ends at {last} != {s}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_softmax_grid_within_budget() {
    forall_res(
        0x61D3,
        300,
        |r| {
            let ndim = 1 + r.usize(3);
            let shape: Vec<usize> = (0..ndim).map(|_| 1 + r.usize(1 << 20)).collect();
            let p = 1 + r.usize(512);
            (shape, p)
        },
        |(shape, p)| {
            let g = softmax_grid(shape, *p);
            if g.len() != shape.len() {
                return Err("rank mismatch".into());
            }
            for (gi, si) in g.iter().zip(shape) {
                if *gi < 1 || gi > si {
                    return Err(format!("axis grid {gi} out of [1, {si}]"));
                }
            }
            let prod: usize = g.iter().product();
            if prod > (*p).max(1) {
                return Err(format!("{prod} blocks > {p} workers"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_layout_place_matches_paper_formula() {
    forall(
        0x61D4,
        300,
        |r| {
            let g1 = 1 + r.usize(5);
            let g2 = 1 + r.usize(5);
            let i = r.usize(32);
            let j = r.usize(32);
            (g1, g2, i, j)
        },
        |&(g1, g2, i, j)| {
            NodeGrid::new(&[g1, g2]).place(&[i, j]) == (i % g1) * g2 + (j % g2)
        },
    );
}

#[test]
fn prop_layout_balanced_when_divisible() {
    forall_res(
        0x61D5,
        200,
        |r| {
            let g1 = 1 + r.usize(3);
            let g2 = 1 + r.usize(3);
            let m1 = 1 + r.usize(3);
            let m2 = 1 + r.usize(3);
            (g1, g2, m1, m2)
        },
        |&(g1, g2, m1, m2)| {
            // block grid = node grid × multiple -> perfectly even placement
            let layout = Layout::new(NodeGrid::new(&[g1, g2]), 4);
            let blocks = ArrayGrid::new(&[64 * g1 * m1, 64 * g2 * m2], &[g1 * m1, g2 * m2]);
            let placements = layout.place_all(&blocks);
            let mut counts = vec![0usize; g1 * g2];
            for p in &placements {
                counts[p.node] += 1;
            }
            let want = m1 * m2;
            if counts.iter().any(|&c| c != want) {
                return Err(format!("uneven placement {counts:?}, want {want} each"));
            }
            Ok(())
        },
    );
}

// ----------------------------------------------------------- scheduler

/// Random expression over random partitioning; check plan well-formedness:
/// topological order, every transfer source actually holds the object,
/// outputs resolve, and the DES accepts the plan.
#[test]
fn prop_random_expressions_yield_wellformed_plans() {
    forall_res(
        0x5CED,
        120,
        |r| {
            let nodes = 1 + r.usize(8);
            let q = 1 + r.usize(12);
            let op = r.usize(4);
            let policy = match r.usize(4) {
                0 => Policy::Lshs,
                1 => Policy::RoundRobin,
                2 => Policy::BottomUp,
                _ => Policy::Random,
            };
            (nodes, q, op, policy, r.next_u64())
        },
        |&(nodes, q, op, ref policy, seed)| {
            let cfg = SessionConfig::paper_sim(nodes, 4)
                .with_policy(policy.clone())
                .with_seed(seed);
            let mut sess = Session::new(cfg);
            let x = sess.zeros(&[1 << 14, 64], &[q, 1]);
            let y = sess.zeros(&[1 << 14, 64], &[q, 1]);
            let rep = match op {
                0 => ops::add(&mut sess, &x, &y),
                1 => ops::matmul(&mut sess, &x.t(), &y),
                2 => ops::sum_axis(&mut sess, &x, 0),
                _ => ops::matmul(&mut sess, &x, &y.t()),
            }
            .map_err(|e| format!("run failed: {e}"))?;
            let rep = rep.1;
            if rep.sim.makespan <= 0.0 {
                return Err("zero makespan".into());
            }
            if rep.sim.makespan.is_nan() {
                return Err("NaN makespan".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_lshs_never_worse_traffic_than_random() {
    forall_res(
        0x5CEE,
        60,
        |r| (2 + r.usize(7), 2 + r.usize(14), r.next_u64()),
        |&(nodes, q, seed)| {
            let run = |policy: Policy| {
                let cfg = SessionConfig::paper_sim(nodes, 4)
                    .with_policy(policy)
                    .with_seed(seed);
                let mut sess = Session::new(cfg);
                let x = sess.zeros(&[1 << 16, 64], &[q, 1]);
                let y = sess.zeros(&[1 << 16, 64], &[q, 1]);
                let (_, rep) = ops::matmul(&mut sess, &x.t(), &y).unwrap();
                rep.transfer_bytes
            };
            let lshs = run(Policy::Lshs);
            let random = run(Policy::Random);
            if lshs > random {
                return Err(format!("lshs {lshs} > random {random}"));
            }
            Ok(())
        },
    );
}

// --------------------------------------------------------------- fusion

/// Fused chains must match the unfused op-by-op oracle *bit-for-bit*: the
/// same scalar expressions run in the same order, only without task
/// boundaries or materialized intermediates.
#[test]
fn prop_fused_chain_matches_unfused_oracle() {
    forall_res(
        0xF05E,
        40,
        |r| {
            let m = 1 + r.usize(96);
            let q = 1 + r.usize(4);
            let nsteps = 2 + r.usize(5);
            let mut steps = Vec::with_capacity(nsteps);
            for _ in 0..nsteps {
                steps.push(match r.usize(5) {
                    0 => EwStep::Neg,
                    1 => EwStep::Sigmoid,
                    2 => EwStep::Scale(r.range_f64(0.5, 2.0)),
                    3 => EwStep::Bin(match r.usize(3) {
                        0 => BinOp::Add,
                        1 => BinOp::Sub,
                        _ => BinOp::Mul,
                    }),
                    _ => EwStep::BinRev(BinOp::Sub),
                });
            }
            (m, q, steps, r.next_u64())
        },
        |&(m, q, ref steps, seed)| {
            let nbin = steps.iter().filter(|s| s.consumes_input()).count();
            let q = q.min(m);
            let run = |fusion: bool| -> Result<(Vec<f64>, usize, usize), String> {
                let cfg = SessionConfig::real_small(2, 2)
                    .with_seed(seed)
                    .with_fusion(fusion);
                let mut sess = Session::new(cfg);
                let first = sess.randn(&[m, 8], &[q, 1]);
                let rest: Vec<DistArray> =
                    (0..nbin).map(|_| sess.randn(&[m, 8], &[q, 1])).collect();
                let rest_refs: Vec<&DistArray> = rest.iter().collect();
                let (out, rep) = ops::ew_chain(&mut sess, &first, &rest_refs, steps)
                    .map_err(|e| e.to_string())?;
                let host = sess.fetch(&out).map_err(|e| e.to_string())?;
                Ok((host.into_vec(), rep.tasks, rep.fused_ops))
            };
            let (fused, ftasks, fops) = run(true)?;
            let (plain, ptasks, pops) = run(false)?;
            if fused.len() != plain.len() {
                return Err("output length mismatch".into());
            }
            for (i, (a, b)) in fused.iter().zip(&plain).enumerate() {
                if !(a == b || (a.is_nan() && b.is_nan())) {
                    return Err(format!("elem {i}: fused {a} != unfused {b}"));
                }
            }
            if pops != 0 {
                return Err(format!("fusion off but fused_ops = {pops}"));
            }
            if fops == 0 {
                return Err("chain of >= 2 ops fused nothing".into());
            }
            if ftasks >= ptasks {
                return Err(format!("fused plan {ftasks} tasks !< unfused {ptasks}"));
            }
            Ok(())
        },
    );
}

/// Fusion must strictly shrink an element-wise pipeline: a k-op chain on a
/// q-block array goes from k·q tasks to q, and modeled time drops with it.
#[test]
fn prop_fusion_halves_chain_task_count() {
    forall_res(
        0xF05F,
        40,
        |r| (2 + r.usize(6), 1 + r.usize(12), r.next_u64()),
        |&(k, q, seed)| {
            let steps: Vec<EwStep> = (0..k)
                .map(|i| if i % 2 == 0 { EwStep::Neg } else { EwStep::Sigmoid })
                .collect();
            let run = |fusion: bool| {
                let cfg = SessionConfig::paper_sim(4, 4)
                    .with_seed(seed)
                    .with_fusion(fusion);
                let mut sess = Session::new(cfg);
                let x = sess.zeros(&[1 << 12, 16], &[q, 1]);
                let (_, rep) = ops::ew_chain(&mut sess, &x, &[], &steps).unwrap();
                (rep.tasks, rep.sim.makespan)
            };
            let (ftasks, fmake) = run(true);
            let (ptasks, pmake) = run(false);
            if ptasks != k * q {
                return Err(format!("unfused plan {ptasks} != {}", k * q));
            }
            if ftasks != q {
                return Err(format!("fused plan {ftasks} != {q}"));
            }
            if ftasks * 2 > ptasks {
                return Err(format!("fusion saved < 2x: {ftasks} vs {ptasks}"));
            }
            if fmake >= pmake {
                return Err(format!("fused makespan {fmake} !< {pmake}"));
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------- dense kernels

/// The cache-blocked parallel matmul is bit-identical to the naive oracle:
/// every output element accumulates over k in the same ascending order, and
/// threads own disjoint row ranges.
#[test]
fn prop_blocked_matmul_matches_naive() {
    forall_res(
        0xB10C,
        60,
        |r| {
            (
                1 + r.usize(200),
                1 + r.usize(200),
                1 + r.usize(200),
                r.next_u64(),
            )
        },
        |&(m, k, n, seed)| {
            let mut rng = Rng::seed_from_u64(seed);
            let mut av = vec![0.0; m * k];
            rng.fill_normal(&mut av);
            let mut bv = vec![0.0; k * n];
            rng.fill_normal(&mut bv);
            let a = Block::from_vec(&[m, k], av);
            let b = Block::from_vec(&[k, n], bv);
            let got = nums::linalg::dense::matmul(&a, &b);
            let want = nums::linalg::dense::matmul_naive(&a, &b);
            if got.shape != want.shape {
                return Err("shape mismatch".into());
            }
            let d = got.max_abs_diff(&want);
            if d > 0.0 {
                return Err(format!("blocked vs naive diff {d} at {m}x{k}x{n}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_real_and_dense_matmul_agree() {
    forall_res(
        0x5CEF,
        25,
        |r| {
            let m = 8 + r.usize(56);
            let k = 8 + r.usize(56);
            let n = 8 + r.usize(56);
            let gm = 1 + r.usize(3);
            let gk = 1 + r.usize(3);
            let gn = 1 + r.usize(3);
            (m, k, n, gm.min(m), gk.min(k), gn.min(n), r.next_u64())
        },
        |&(m, k, n, gm, gk, gn, seed)| {
            let mut sess =
                Session::new(SessionConfig::real_small(2, 2).with_seed(seed));
            let a = sess.randn(&[m, k], &[gm, gk]);
            let b = sess.randn(&[k, n], &[gk, gn]);
            let (c, _) = ops::matmul(&mut sess, &a, &b).map_err(|e| e.to_string())?;
            let want = nums::linalg::dense::matmul(
                &sess.fetch(&a).unwrap(),
                &sess.fetch(&b).unwrap(),
            );
            let got = sess.fetch(&c).unwrap();
            let d = got.max_abs_diff(&want);
            if d > 1e-9 {
                return Err(format!("max diff {d}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_des_makespan_at_least_critical_compute() {
    // DES sanity: makespan >= total busy time / workers and >= dispatch γ·n.
    forall_res(
        0x5CF0,
        60,
        |r| (1 + r.usize(8), 1 + r.usize(16), r.next_u64()),
        |&(nodes, q, seed)| {
            let cfg = SessionConfig::paper_sim(nodes, 2).with_seed(seed);
            let mut sess = Session::new(cfg);
            let x = sess.zeros(&[1 << 16, 64], &[q, 1]);
            let y = sess.zeros(&[1 << 16, 64], &[q, 1]);
            let (_, rep) = ops::add(&mut sess, &x, &y).unwrap();
            let total_busy: f64 = rep.sim.busy.iter().sum();
            let cap = (nodes * 2) as f64;
            if rep.sim.makespan + 1e-12 < total_busy / cap {
                return Err(format!(
                    "makespan {} < busy/workers {}",
                    rep.sim.makespan,
                    total_busy / cap
                ));
            }
            if rep.sim.makespan + 1e-12 < rep.sim.dispatch_time {
                return Err("makespan below dispatch serialization".into());
            }
            Ok(())
        },
    );
}
