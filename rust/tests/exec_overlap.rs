//! Communication/compute-overlap suites: prefetching and the async spill
//! pipeline must be pure latency optimizations — outputs bit-identical to
//! sequential plan-order execution (and to the prefetch-off executor) for
//! every random graph, node count, thread count, stealing mode and memory
//! budget — and every cross-node byte must be accounted exactly once:
//! per node, `prefetch_bytes + demand_pull_bytes == net_in` (the
//! steal-adjusted transfer bytes the stores themselves counted).

use std::collections::{HashMap, HashSet};
use std::sync::Arc;

use nums::api::ops;
use nums::exec::{Plan, RealExecutor, RealReport, Task};
use nums::prelude::*;
use nums::runtime::native;
use nums::store::{MemoryManager, StoreSet};
use nums::util::prop::forall_res;

/// Sequential oracle: run the plan in order, single process, no stores.
fn run_sequential(plan: &Plan, seeds: &HashMap<u64, Block>) -> HashMap<u64, Block> {
    let mut env: HashMap<u64, Block> = seeds.clone();
    for t in &plan.tasks {
        let refs: Vec<&Block> = t.inputs.iter().map(|o| &env[o]).collect();
        let outs = native::execute(&t.kernel, &refs).unwrap();
        for ((obj, _), b) in t.outputs.iter().zip(outs) {
            env.insert(*obj, b);
        }
    }
    env
}

/// Random-but-valid plan spec (same scheme as `tests/exec_steal.rs`):
/// decoded against earlier outputs so plans are executable and ordered.
#[derive(Debug)]
struct PlanSpec {
    nodes: usize,
    threads_per_node: usize,
    stealing: bool,
    /// Tight 4-block per-node byte budget (eviction/spill churn under
    /// prefetch pressure) vs unlimited.
    budgeted: bool,
    n_seeds: usize,
    tasks: Vec<(u8, usize, usize, usize)>,
}

const SHAPE: [usize; 2] = [4, 4];
const BLOCK_BYTES: u64 = (SHAPE[0] * SHAPE[1] * 8) as u64;

fn decode(spec: &PlanSpec) -> (Plan, HashMap<u64, Block>) {
    let mut rng = Rng::seed_from_u64(0x0E1A ^ spec.tasks.len() as u64);
    let mut seeds = HashMap::new();
    let mut avail: Vec<u64> = Vec::new();
    for s in 0..spec.n_seeds {
        let mut v = vec![0.0; SHAPE[0] * SHAPE[1]];
        rng.fill_normal(&mut v);
        seeds.insert(s as u64, Block::from_vec(&SHAPE, v));
        avail.push(s as u64);
    }
    let mut tasks = Vec::new();
    for (i, &(kind, p1, p2, tgt)) in spec.tasks.iter().enumerate() {
        let out = 1000 + i as u64;
        let (kernel, inputs) = match kind % 5 {
            0 => (Kernel::Ew(BinOp::Add), vec![avail[p1 % avail.len()], avail[p2 % avail.len()]]),
            1 => (Kernel::Ew(BinOp::Mul), vec![avail[p1 % avail.len()], avail[p2 % avail.len()]]),
            2 => (Kernel::Neg, vec![avail[p1 % avail.len()]]),
            3 => (Kernel::Scale(0.5), vec![avail[p1 % avail.len()]]),
            _ => (Kernel::Matmul, vec![avail[p1 % avail.len()], avail[p2 % avail.len()]]),
        };
        let in_shapes = vec![SHAPE.to_vec(); inputs.len()];
        tasks.push(Task {
            kernel,
            inputs,
            in_shapes,
            outputs: vec![(out, SHAPE.to_vec())],
            target: tgt % spec.nodes,
            transfers: vec![],
        });
        avail.push(out);
    }
    (Plan { tasks }, seeds)
}

fn seeded_stores(nodes: usize, seeds: &HashMap<u64, Block>) -> StoreSet {
    let stores = StoreSet::new(nodes);
    for (obj, b) in seeds {
        stores.put((*obj as usize) % nodes, *obj, Arc::new(b.clone()));
    }
    stores
}

/// Per-node `prefetch_bytes + demand_pull_bytes == net_in` — every
/// cross-node byte accounted exactly once, whichever path moved it.
fn check_byte_identity(rep: &RealReport, nodes: usize) -> Result<(), String> {
    if rep.prefetch_stats.len() != nodes {
        return Err(format!(
            "expected {nodes} prefetch stat blocks, got {}",
            rep.prefetch_stats.len()
        ));
    }
    for n in 0..nodes {
        let net_in = rep.store_snapshot[n].2;
        let p = &rep.prefetch_stats[n];
        let accounted = p.prefetch_bytes + p.demand_pull_bytes;
        if accounted != net_in {
            return Err(format!(
                "node {n}: prefetch {} + demand {} = {accounted} != net_in {net_in}",
                p.prefetch_bytes, p.demand_pull_bytes
            ));
        }
    }
    Ok(())
}

#[test]
fn prop_prefetch_preserves_bit_identity_and_accounts_every_byte() {
    forall_res(
        0x0F37C4,
        25,
        |r| PlanSpec {
            nodes: 1 + r.usize(4),
            threads_per_node: 1 + r.usize(3),
            stealing: r.usize(2) == 1,
            budgeted: r.usize(2) == 1,
            n_seeds: 2 + r.usize(4),
            tasks: (0..1 + r.usize(20))
                .map(|_| (r.usize(256) as u8, r.usize(1 << 16), r.usize(1 << 16), r.usize(1 << 16)))
                .collect(),
        },
        |spec| {
            let (plan, seeds) = decode(spec);
            let want = run_sequential(&plan, &seeds);
            let consumed: HashSet<u64> =
                plan.tasks.iter().flat_map(|t| t.inputs.iter().copied()).collect();
            for prefetch in [false, true] {
                let topo = Topology::new(spec.nodes, 2, SystemMode::Ray);
                let budget = if spec.budgeted { Some(4 * BLOCK_BYTES) } else { None };
                let mut exec = RealExecutor::new(topo, Arc::new(Backend::native()))
                    .with_stealing(spec.stealing)
                    .with_prefetch(prefetch)
                    .with_memory(MemoryManager::new(spec.nodes, budget, true));
                exec.threads_per_node = spec.threads_per_node;
                let stores = seeded_stores(spec.nodes, &seeds);
                let rep = exec
                    .run(&plan, &stores)
                    .map_err(|e| format!("prefetch={prefetch}: executor failed: {e}"))?;
                let mgr = exec.memory.as_ref().unwrap();
                for i in 0..plan.tasks.len() {
                    let obj = 1000 + i as u64;
                    if consumed.contains(&obj) {
                        // dead intermediate: GC must have released it even
                        // with prefetch pulls racing the releases
                        if mgr.holds(&stores, obj) {
                            return Err(format!(
                                "prefetch={prefetch}: dead intermediate {obj} still held"
                            ));
                        }
                        continue;
                    }
                    let got = mgr
                        .fetch(&stores, obj)
                        .ok_or_else(|| format!("prefetch={prefetch}: output {obj} missing"))?;
                    let w = &want[&obj];
                    if got.shape != w.shape {
                        return Err(format!("prefetch={prefetch}: shape mismatch on {obj}"));
                    }
                    if got.buf().iter().zip(w.buf()).any(|(a, b)| a.to_bits() != b.to_bits()) {
                        return Err(format!(
                            "prefetch={prefetch}: output {obj} differs from oracle"
                        ));
                    }
                }
                if prefetch {
                    check_byte_identity(&rep, spec.nodes)?;
                } else if !rep.prefetch_stats.is_empty() {
                    return Err("prefetch off must report no prefetch stats".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prefetch_warms_remote_inputs_while_workers_compute() {
    // pipeline: every input lives on node 0, every task targets node 1,
    // one worker per node, stealing off. The first task demand-pulls; the
    // transfer thread moves later inputs while each matmul runs, so most
    // acquisitions are prefetch hits that pay zero bytes on the hot path.
    let n = 128usize;
    let k_tasks = 8usize;
    let mut rng = Rng::seed_from_u64(0xF37);
    let mut seeds = HashMap::new();
    for i in 0..2 * k_tasks as u64 {
        let mut v = vec![0.0; n * n];
        rng.fill_normal(&mut v);
        seeds.insert(i, Block::from_vec(&[n, n], v));
    }
    let plan = Plan {
        tasks: (0..k_tasks)
            .map(|i| Task {
                kernel: Kernel::Matmul,
                inputs: vec![(2 * i) as u64, (2 * i + 1) as u64],
                in_shapes: vec![vec![n, n], vec![n, n]],
                outputs: vec![(1000 + i as u64, vec![n, n])],
                target: 1,
                transfers: vec![],
            })
            .collect(),
    };
    let want = run_sequential(&plan, &seeds);
    let topo = Topology::new(2, 1, SystemMode::Ray);
    let mut exec = RealExecutor::new(topo, Arc::new(Backend::native()))
        .with_stealing(false)
        .with_prefetch(true);
    exec.threads_per_node = 1;
    let stores = StoreSet::new(2);
    for (obj, b) in &seeds {
        stores.put(0, *obj, Arc::new(b.clone()));
    }
    let rep = exec.run(&plan, &stores).unwrap();
    check_byte_identity(&rep, 2).unwrap();
    let p1 = &rep.prefetch_stats[1];
    assert!(
        p1.prefetch_bytes > 0,
        "transfer thread moved nothing: {p1:?}"
    );
    assert!(p1.prefetch_hits > 0, "no acquisition hit a prefetch: {p1:?}");
    // all bytes entered node 1 one way or the other
    assert_eq!(
        rep.store_snapshot[1].2,
        (2 * k_tasks) as u64 * (n * n * 8) as u64
    );
    for i in 0..k_tasks {
        let obj = 1000 + i as u64;
        let got = stores.fetch(obj).unwrap();
        assert_eq!(got.max_abs_diff(&want[&obj]), 0.0, "output {obj} wrong");
    }
}

#[test]
fn stolen_tasks_reroute_prefetches_and_keep_the_byte_identity() {
    // the canonical skew: everything targeted at node 0 of 4 nodes, so
    // thieves batch-steal and re-route queued prefetches to themselves
    let n = 128usize;
    let k_tasks = 40usize;
    let mut rng = Rng::seed_from_u64(0x57E41);
    let mut seeds = HashMap::new();
    for i in 0..2 * k_tasks as u64 {
        let mut v = vec![0.0; n * n];
        rng.fill_normal(&mut v);
        seeds.insert(i, Block::from_vec(&[n, n], v));
    }
    let plan = Plan {
        tasks: (0..k_tasks)
            .map(|i| Task {
                kernel: Kernel::Matmul,
                inputs: vec![(2 * i) as u64, (2 * i + 1) as u64],
                in_shapes: vec![vec![n, n], vec![n, n]],
                outputs: vec![(1000 + i as u64, vec![n, n])],
                target: 0,
                transfers: vec![],
            })
            .collect(),
    };
    let want = run_sequential(&plan, &seeds);
    let topo = Topology::new(4, 2, SystemMode::Ray);
    let mut exec = RealExecutor::new(topo, Arc::new(Backend::native()))
        .with_stealing(true)
        .with_prefetch(true);
    exec.threads_per_node = 2;
    let stores = StoreSet::new(4);
    for (obj, b) in &seeds {
        stores.put(0, *obj, Arc::new(b.clone()));
    }
    let rep = exec.run(&plan, &stores).unwrap();
    // the identity is the reroute correctness claim: every byte a thief
    // pulled — demand on the hot path or re-routed prefetch in the
    // background — is accounted exactly once against its store's net_in
    check_byte_identity(&rep, 4).unwrap();
    let stolen: usize = rep.node_stats.iter().map(|s| s.tasks_stolen).sum();
    assert!(stolen > 0, "skewed plan must trigger stealing");
    for i in 0..k_tasks {
        let obj = 1000 + i as u64;
        let got = stores.fetch(obj).unwrap();
        assert_eq!(got.max_abs_diff(&want[&obj]), 0.0, "output {obj} wrong");
    }
}

#[test]
fn prefetch_racing_eviction_never_deadlocks_or_double_accounts() {
    // tight budget + shared hot inputs: node 1 keeps pulling the same 4
    // seed blocks from node 0 while its budget keeps evicting them. The
    // run must terminate (no livelock between prefetcher and evictor),
    // every byte must be accounted exactly once, and results must match.
    let n = 32usize;
    let k_tasks = 24usize;
    let block_bytes = (n * n * 8) as u64;
    let mut rng = Rng::seed_from_u64(0xEB1C7);
    let mut seeds = HashMap::new();
    for i in 0..4u64 {
        let mut v = vec![0.0; n * n];
        rng.fill_normal(&mut v);
        seeds.insert(i, Block::from_vec(&[n, n], v));
    }
    let plan = Plan {
        tasks: (0..k_tasks)
            .map(|i| Task {
                kernel: Kernel::Ew(BinOp::Add),
                inputs: vec![(i % 4) as u64, ((i + 1) % 4) as u64],
                in_shapes: vec![vec![n, n], vec![n, n]],
                outputs: vec![(1000 + i as u64, vec![n, n])],
                target: 1,
                transfers: vec![],
            })
            .collect(),
    };
    let want = run_sequential(&plan, &seeds);
    let topo = Topology::new(2, 2, SystemMode::Ray);
    let mut exec = RealExecutor::new(topo, Arc::new(Backend::native()))
        .with_stealing(false)
        .with_prefetch(true)
        .with_memory(MemoryManager::new(2, Some(2 * block_bytes), true));
    exec.threads_per_node = 2;
    let stores = StoreSet::new(2);
    for (obj, b) in &seeds {
        stores.put(0, *obj, Arc::new(b.clone()));
    }
    let rep = exec.run(&plan, &stores).unwrap();
    check_byte_identity(&rep, 2).unwrap();
    // pressure really happened on the destination node
    let shed = rep.mem_stats[1].evicted_replica_bytes + rep.mem_stats[1].spilled_bytes;
    assert!(shed > 0, "a 2-block budget must shed load: {:?}", rep.mem_stats);
    let mgr = exec.memory.as_ref().unwrap();
    for i in 0..k_tasks {
        let obj = 1000 + i as u64;
        let got = mgr.fetch(&stores, obj).expect("terminal output");
        assert_eq!(got.max_abs_diff(&want[&obj]), 0.0, "output {obj} wrong");
    }
}

#[test]
fn async_spill_runs_on_transfer_threads_and_preserves_results() {
    // produce-then-fold under a tight budget: with prefetch on, every
    // spill write of the run flows through the transfer thread
    // (async_spill_bytes) and none through a worker; results match the
    // synchronous-spill baseline bit for bit.
    let n = 16usize;
    let k = 8usize;
    let block_bytes = (n * n * 8) as u64;
    let (plan, acc) = nums::bench::harness::produce_fold_plan(k, n);
    let run = |prefetch: bool| {
        let topo = Topology::new(1, 1, SystemMode::Ray);
        let mut exec = RealExecutor::new(topo, Arc::new(Backend::native()))
            .with_prefetch(prefetch)
            .with_memory(MemoryManager::new(1, Some(3 * block_bytes), true));
        exec.threads_per_node = 1;
        let stores = StoreSet::new(1);
        stores.put(0, 1, Arc::new(Block::filled(&[n, n], 1.0)));
        let rep = exec.run(&plan, &stores).unwrap();
        let out = exec
            .memory
            .as_ref()
            .unwrap()
            .fetch(&stores, acc)
            .expect("final output")
            .as_ref()
            .clone();
        (rep, out)
    };
    let (sync_rep, sync_out) = run(false);
    let (async_rep, async_out) = run(true);
    assert_eq!(sync_out.max_abs_diff(&async_out), 0.0, "async spill changed bits");
    assert!(sync_rep.mem_stats[0].spilled_bytes > 0, "baseline must spill");
    let spilled = async_rep.mem_stats[0].spilled_bytes;
    assert!(spilled > 0, "async run must spill too");
    assert_eq!(
        async_rep.prefetch_stats[0].async_spill_bytes, spilled,
        "every spill write of the run must ride the transfer thread"
    );
}

#[test]
fn session_prefetch_flows_counters_and_forgets_dead_bytes() {
    // end-to-end: a real session reports overlap counters, GC'd
    // intermediates leave the scheduler's load model, and the ablation
    // toggle produces bit-identical results
    let run = |prefetch: bool| {
        let mut sess = Session::new(SessionConfig::real_small(2, 2).with_prefetch(prefetch));
        let x = sess.randn(&[128, 128], &[2, 2]);
        let y = sess.randn(&[128, 128], &[2, 2]);
        let (out, rep) = ops::matmul(&mut sess, &x, &y).unwrap();
        let dense = sess.fetch(&out).unwrap();
        let real = rep.real.expect("real mode");
        // the forget hook: every released intermediate is gone from the
        // Eq. 2 load model (later schedules must not count dead bytes)
        assert!(
            !real.gc_released.is_empty(),
            "a 2x2 matmul has partial products to release"
        );
        for &obj in &real.gc_released {
            assert!(
                sess.state.locations_of(obj).is_empty(),
                "released object {obj} still in the load model"
            );
            assert_eq!(sess.state.size_of(obj), 0.0);
        }
        // and the session still schedules/executes correctly afterwards
        let (out2, _) = ops::add(&mut sess, &out, &out).unwrap();
        let dense2 = sess.fetch(&out2).unwrap();
        (dense, dense2, real)
    };
    let (a1, a2, real_off) = run(false);
    let (b1, b2, real_on) = run(true);
    assert_eq!(a1.max_abs_diff(&b1), 0.0, "prefetch changed matmul bits");
    assert_eq!(a2.max_abs_diff(&b2), 0.0, "prefetch changed follow-up bits");
    assert!(real_off.prefetch_stats.is_empty(), "off = no counters");
    assert_eq!(real_on.prefetch_stats.len(), 2, "on = one block per node");
}
