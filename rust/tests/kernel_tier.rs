//! Kernel-tier epsilon suite: the explicit accuracy contract between the
//! packed AVX2+FMA microkernels (`linalg::microkernel`) and the portable
//! scalar tier.
//!
//! The policy (documented in `docs/ARCHITECTURE.md` and the microkernel
//! module doc):
//!
//! * **Scalar tier** is *bit-for-bit* identical to the `matmul_naive`
//!   oracle, across thread budgets, and under fused Scale/Neg epilogues.
//!   Sessions default to it (`SessionConfig::strict_kernels = true`), so
//!   every exact-equality property suite keeps its 0.0-tolerance
//!   contract.
//! * **Simd tier** may differ from scalar only through (a) FMA
//!   contraction of the multiply-adds and (b) the packed panel grouping.
//!   Both are bounded: each output element of an `m×k · k×n` product is a
//!   length-`k` inner product whose FMA-vs-separate-rounding deviation is
//!   at most `k` half-ulps per partial, giving the classical bound
//!   `|simd − scalar| ≤ 4·k·ε·(|A|·|B|)[i,j]` (a ×4 safety factor over
//!   the `γ_k = k·ε/(1−k·ε)` forward-error envelope). These tests assert
//!   that bound element-wise on adversarial shapes: 1×k, k×1, primes,
//!   non-multiples of the 4×8 register tile, and k crossing the KC=256
//!   panel depth.
//! * Element-wise segments (add/sub/mul/div/scale/neg) are *lane-exact*
//!   in the Simd tier (no FMA), so fused element-wise chains stay
//!   bit-identical across tiers — asserted at 0.0 here.

use nums::api::{ops, Session, SessionConfig};
use nums::graph::Graph;
use nums::grid::ArrayGrid;
use nums::linalg::dense;
use nums::runtime::native;
use nums::runtime::{BinOp, EwStep, ExecContext, Kernel, KernelTier};
use nums::store::Block;
use nums::util::rng::Rng;

fn randn(shape: &[usize], seed: u64) -> Block {
    let mut rng = Rng::seed_from_u64(seed);
    let n: usize = shape.iter().product();
    let mut v = vec![0.0; n];
    rng.fill_normal(&mut v);
    Block::from_vec(shape, v)
}

fn abs_block(x: &Block) -> Block {
    Block::from_vec(&x.shape, x.buf().iter().map(|v| v.abs()).collect())
}

/// Element-wise error budget for a k-deep contraction:
/// `4·k·ε·(|A|·|B|)[i,j]` plus a tiny absolute floor for zero products.
fn contraction_bound(a: &Block, b: &Block) -> Block {
    let k = a.shape[1] as f64;
    let mags = dense::matmul_naive(&abs_block(a), &abs_block(b));
    let c = 4.0 * k * f64::EPSILON;
    Block::from_vec(
        &mags.shape,
        mags.buf().iter().map(|m| c * m + 1e-300).collect(),
    )
}

fn assert_within(got: &Block, want: &Block, bound: &Block, label: &str) {
    assert_eq!(got.shape, want.shape, "{label}: shape");
    for (i, ((g, w), e)) in got
        .buf()
        .iter()
        .zip(want.buf())
        .zip(bound.buf())
        .enumerate()
    {
        let d = (g - w).abs();
        assert!(
            d <= *e,
            "{label}: elem {i} differs by {d:e}, bound {e:e} (got {g}, want {w})"
        );
    }
}

/// Adversarial shape set: degenerate rows/cols, primes off the 4×8 tile,
/// and k values straddling the KC=256 packing panel.
const SHAPES: &[(usize, usize, usize)] = &[
    (1, 1, 1),
    (1, 37, 1),
    (1, 7, 9),
    (5, 1, 3),
    (7, 11, 13),
    (4, 256, 8),
    (5, 300, 9),
    (64, 64, 64),
    (65, 257, 33),
];

// ------------------------------------------------------- contraction tiers

#[test]
fn scalar_tier_is_bit_identical_to_naive_oracle() {
    for &(m, k, n) in SHAPES {
        let a = randn(&[m, k], 0x5EED ^ ((m as u64) << 8) ^ k as u64);
        let b = randn(&[k, n], 0xB0B ^ ((n as u64) << 8) ^ k as u64);
        let got = dense::matmul_tier(&a, &b, 1.0, 4, KernelTier::Scalar);
        let want = dense::matmul_naive(&a, &b);
        assert_eq!(
            got.max_abs_diff(&want),
            0.0,
            "scalar tier must equal naive at {m}x{k}x{n}"
        );
    }
}

#[test]
fn simd_tier_stays_within_the_fma_bound_of_scalar() {
    // On hosts without AVX2+FMA (or with NUMS_KERNEL_TIER=scalar) the
    // Simd request degrades to Scalar and the diff is exactly zero —
    // the bound still holds, so the test is meaningful everywhere.
    let tier = KernelTier::resolve(KernelTier::Simd);
    for &(m, k, n) in SHAPES {
        let a = randn(&[m, k], 0xA11CE ^ ((m as u64) << 16) ^ k as u64);
        let b = randn(&[k, n], 0xFACADE ^ ((n as u64) << 16) ^ k as u64);
        let got = dense::matmul_tier(&a, &b, 1.0, 4, tier);
        let want = dense::matmul_tier(&a, &b, 1.0, 1, KernelTier::Scalar);
        assert_within(&got, &want, &contraction_bound(&a, &b), "matmul simd");
    }
}

#[test]
fn simd_tier_is_bit_stable_across_thread_budgets() {
    // determinism contract: the SIMD result is a pure function of the
    // inputs — thread split and panel membership never change any bit
    let tier = KernelTier::resolve(KernelTier::Simd);
    let a = randn(&[400, 300], 0xD00D);
    let b = randn(&[300, 200], 0xF00D);
    let one = dense::matmul_tier(&a, &b, 1.0, 1, tier);
    for budget in [2, 3, 5, 8] {
        let t = dense::matmul_tier(&a, &b, 1.0, budget, tier);
        assert_eq!(one.max_abs_diff(&t), 0.0, "budget {budget} changed bits");
    }
}

#[test]
fn gram_is_exactly_symmetric_in_both_tiers() {
    for tier in [KernelTier::Scalar, KernelTier::resolve(KernelTier::Simd)] {
        let x = randn(&[301, 17], 0x9A9A);
        let g = dense::gram_tier(&x, &x, 1.0, 4, tier);
        for i in 0..17 {
            for j in 0..i {
                assert_eq!(
                    g.at2(i, j),
                    g.at2(j, i),
                    "gram(X,X) asymmetric at ({i},{j}) in {tier:?}"
                );
            }
        }
    }
}

#[test]
fn gram_simd_stays_within_the_fma_bound_of_scalar() {
    let tier = KernelTier::resolve(KernelTier::Simd);
    for &(m, k, n) in &[(1usize, 3usize, 1usize), (37, 5, 4), (257, 13, 9), (300, 26, 26)] {
        // gram contracts over rows: A is m×k, B is m×n, out is k×n
        let a = randn(&[m, k], 0x6AA6 ^ m as u64);
        let b = randn(&[m, n], 0x7BB7 ^ m as u64);
        let got = dense::gram_tier(&a, &b, 1.0, 4, tier);
        let want = dense::gram_tier(&a, &b, 1.0, 1, KernelTier::Scalar);
        let bound = contraction_bound(&abs_block(&a).transposed(), &abs_block(&b));
        assert_within(&got, &want, &bound, "gram simd");
    }
}

// --------------------------------------------------------- fused epilogues

#[test]
fn scaled_contraction_equals_separate_scale_pass_exactly() {
    // the α-epilogue is applied as one multiply per output element — the
    // same operation a separate Scale task would perform, so folding is
    // bit-exact in BOTH tiers (this is what makes epilogue fusion safe
    // under the strict-kernels contract)
    let a = randn(&[9, 40], 0xEE1);
    let b = randn(&[40, 7], 0xEE2);
    for tier in [KernelTier::Scalar, KernelTier::resolve(KernelTier::Simd)] {
        for alpha in [2.5, -1.0, 0.0, -3.75] {
            let fused = dense::matmul_tier(&a, &b, alpha, 2, tier);
            let base = dense::matmul_tier(&a, &b, 1.0, 2, tier);
            let swept = Block::from_vec(
                &base.shape,
                base.buf().iter().map(|v| alpha * v).collect(),
            );
            assert_eq!(
                fused.max_abs_diff(&swept),
                0.0,
                "alpha={alpha} epilogue not exact in {tier:?}"
            );
        }
    }
}

#[test]
fn scaled_kernels_match_their_unfused_pipelines_through_the_backend() {
    let a = randn(&[12, 33], 0xAB1);
    let b = randn(&[33, 8], 0xAB2);
    let ctx = ExecContext::host_default().with_tier(KernelTier::Scalar);
    let fused = native::execute_ctx(&Kernel::ScaledMatmul(-2.0), &[&a, &b], &ctx)
        .unwrap()
        .remove(0);
    let mm = native::execute_ctx(&Kernel::Matmul, &[&a, &b], &ctx)
        .unwrap()
        .remove(0);
    let want = native::execute_ctx(&Kernel::Scale(-2.0), &[&mm], &ctx)
        .unwrap()
        .remove(0);
    assert_eq!(fused.max_abs_diff(&want), 0.0, "ScaledMatmul != Scale∘Matmul");

    let x = randn(&[21, 6], 0xAB3);
    let fused = native::execute_ctx(&Kernel::ScaledGram(0.5), &[&x, &x], &ctx)
        .unwrap()
        .remove(0);
    let gr = native::execute_ctx(&Kernel::Gram, &[&x, &x], &ctx)
        .unwrap()
        .remove(0);
    let want = native::execute_ctx(&Kernel::Scale(0.5), &[&gr], &ctx)
        .unwrap()
        .remove(0);
    assert_eq!(fused.max_abs_diff(&want), 0.0, "ScaledGram != Scale∘Gram");
}

// ------------------------------------------------- element-wise lane-exact

#[test]
fn fused_ew_chains_are_bit_identical_across_tiers() {
    // length crosses the 4096-element fused chunk AND leaves an odd
    // 3-lane tail for the AVX2 segments
    let x = randn(&[3, 2049], 0xC1);
    let y = randn(&[3, 2049], 0xC2);
    let w = randn(&[3, 2049], 0xC4);
    let z = Block::from_vec(
        &[3, 2049],
        randn(&[3, 2049], 0xC3).buf().iter().map(|v| v.abs() + 1.0).collect(),
    );
    let steps = vec![
        EwStep::Neg,
        EwStep::Scale(3.0),
        EwStep::Bin(BinOp::Add),
        EwStep::BinRev(BinOp::Sub),
        EwStep::Bin(BinOp::Div),
        EwStep::Sigmoid,
    ];
    let kernel = Kernel::FusedEw(steps);
    let scalar_ctx = ExecContext::host_default().with_tier(KernelTier::Scalar);
    let simd_ctx = ExecContext::host_default().with_tier(KernelTier::Simd);
    let s = native::execute_ctx(&kernel, &[&x, &y, &w, &z], &scalar_ctx)
        .unwrap()
        .remove(0);
    let v = native::execute_ctx(&kernel, &[&x, &y, &w, &z], &simd_ctx)
        .unwrap()
        .remove(0);
    assert_eq!(
        s.max_abs_diff(&v),
        0.0,
        "element-wise segments must be lane-exact across tiers"
    );
}

#[test]
fn glm_composites_agree_across_tiers_within_tolerance() {
    // GLM inner loops use FMA dot/axpy in the Simd tier: epsilon-bounded,
    // not bit-identical — the same contract the distributed suites use.
    let x = randn(&[64, 7], 0xD1);
    let y = Block::from_vec(
        &[64, 1],
        randn(&[64, 1], 0xD2)
            .buf()
            .iter()
            .map(|v| if *v > 0.0 { 1.0 } else { 0.0 })
            .collect(),
    );
    let beta = Block::from_vec(
        &[7, 1],
        randn(&[7, 1], 0xD3).buf().iter().map(|v| 0.1 * v).collect(),
    );
    let scalar_ctx = ExecContext::host_default().with_tier(KernelTier::Scalar);
    let simd_ctx = ExecContext::host_default().with_tier(KernelTier::Simd);
    let s = native::execute_ctx(&Kernel::NewtonBlock, &[&x, &y, &beta], &scalar_ctx).unwrap();
    let v = native::execute_ctx(&Kernel::NewtonBlock, &[&x, &y, &beta], &simd_ctx).unwrap();
    for (a, b) in s.iter().zip(&v) {
        assert!(
            a.max_abs_diff(b) < 1e-10,
            "NewtonBlock tier divergence {}",
            a.max_abs_diff(b)
        );
    }
}

// ------------------------------------------------------------ session level

#[test]
fn strict_sessions_keep_the_bit_identity_contract() {
    // strict (default) sessions pin workers to the scalar tier: a
    // single-k-block distributed matmul must equal the host-side blocked
    // kernel bit-for-bit, however the output is partitioned (each output
    // block's elements see exactly the full-k scalar accumulation order)
    for (xg, wg) in [([2, 1], [1, 1]), ([1, 1], [1, 2])] {
        let mut sess = Session::new(SessionConfig::real_small(2, 2));
        assert!(sess.cfg.strict_kernels, "strict must be the default");
        let x = sess.randn(&[48, 16], &xg);
        let w = sess.randn(&[16, 5], &wg);
        let (out, _) = ops::matmul(&mut sess, &x, &w).unwrap();
        let got = sess.fetch(&out).unwrap();
        let want = dense::matmul(&sess.fetch(&x).unwrap(), &sess.fetch(&w).unwrap());
        assert_eq!(
            got.max_abs_diff(&want),
            0.0,
            "strict session grids {xg:?}x{wg:?}"
        );
    }
}

#[test]
fn relaxed_sessions_stay_within_the_epsilon_bound() {
    let mut sess =
        Session::new(SessionConfig::real_small(2, 2).with_strict_kernels(false));
    let x = sess.randn(&[48, 16], &[2, 1]);
    let w = sess.randn(&[16, 5], &[1, 1]);
    let (out, _) = ops::matmul(&mut sess, &x, &w).unwrap();
    let got = sess.fetch(&out).unwrap();
    let xa = sess.fetch(&x).unwrap();
    let wa = sess.fetch(&w).unwrap();
    let want = dense::matmul(&xa, &wa);
    assert_within(&got, &want, &contraction_bound(&xa, &wa), "relaxed session");
}

#[test]
fn epilogue_fold_runs_end_to_end_and_stays_exact() {
    // -2·(X @ W) as a graph: the Scale folds into a ScaledMatmul task
    // (reported via fused_ops) and the strict-tier result equals the
    // unfused pipeline bit-for-bit
    let mut sess = Session::new(SessionConfig::real_small(2, 2));
    let x = sess.randn(&[32, 8], &[1, 1]);
    let w = sess.randn(&[8, 4], &[1, 1]);

    let mut g = Graph::new();
    let la = g.leaf(x.obj_at(&[0, 0]), &[32, 8]);
    let lb = g.leaf(w.obj_at(&[0, 0]), &[8, 4]);
    let mm = g.op(Kernel::Matmul, vec![(la, 0), (lb, 0)]);
    let sc = g.op(Kernel::Scale(-2.0), vec![(mm, 0)]);
    g.add_output(ArrayGrid::new(&[32, 4], &[1, 1]), vec![(sc, 0)]);

    let (outs, rep) = sess.run(&mut g).unwrap();
    assert_eq!(rep.fused_ops, 1, "the Scale epilogue should fold");
    assert_eq!(rep.tasks, 1, "one ScaledMatmul task, no separate Scale");
    let got = sess.fetch(&outs[0]).unwrap();
    let base = dense::matmul(&sess.fetch(&x).unwrap(), &sess.fetch(&w).unwrap());
    let want = Block::from_vec(
        &base.shape,
        base.buf().iter().map(|v| -2.0 * v).collect(),
    );
    assert_eq!(got.max_abs_diff(&want), 0.0, "folded epilogue must be exact");
}
