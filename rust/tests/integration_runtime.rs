//! PJRT runtime integration: every AOT artifact executes and matches the
//! native oracle; the composite backend routes correctly.
//!
//! Requires `make artifacts` (skips gracefully when absent so `cargo test`
//! works on a fresh checkout).

use nums::prelude::*;
use nums::runtime::{native, Manifest, PjrtRuntime};
use nums::store::Block;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    if !cfg!(feature = "pjrt") {
        eprintln!("skipping: built without the `pjrt` feature (no xla crate offline)");
        return None;
    }
    let dir = Manifest::default_dir();
    if dir.join("manifest.tsv").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        None
    }
}

fn kernel_for(name: &str) -> Option<Kernel> {
    Some(match name {
        "neg" => Kernel::Neg,
        "sigmoid" => Kernel::Sigmoid,
        "add" => Kernel::Ew(BinOp::Add),
        "sub" => Kernel::Ew(BinOp::Sub),
        "mul" => Kernel::Ew(BinOp::Mul),
        "div" => Kernel::Ew(BinOp::Div),
        "matmul" => Kernel::Matmul,
        "matmul_nt" => Kernel::MatmulNT,
        "gram" => Kernel::Gram,
        "sum_axis0" => Kernel::SumAxis0,
        "sum_axis1" => Kernel::SumAxis1,
        "sum_all" => Kernel::SumAll,
        "glm_mu" => Kernel::GlmMu,
        "glm_grad" => Kernel::GlmGrad,
        "glm_hess" => Kernel::GlmHess,
        "logloss" => Kernel::LogLoss,
        "newton_block" => Kernel::NewtonBlock,
        "lbfgs_block" => Kernel::LbfgsBlock,
        "predict_block" => Kernel::PredictBlock,
        _ => return None,
    })
}

/// Build inputs that respect each kernel's domain (probabilities, labels).
fn inputs_for(entry: &nums::runtime::ManifestEntry, rng: &mut Rng) -> Vec<Block> {
    entry
        .input_shapes
        .iter()
        .enumerate()
        .map(|(i, s)| {
            let n: usize = s.iter().product();
            let mut v = vec![0.0; n];
            rng.fill_normal(&mut v);
            let sigmoid = |v: &mut Vec<f64>| {
                for x in v.iter_mut() {
                    *x = 1.0 / (1.0 + (-*x).exp());
                }
            };
            let binarize = |v: &mut Vec<f64>| {
                for x in v.iter_mut() {
                    *x = if *x > 0.0 { 1.0 } else { 0.0 };
                }
            };
            match (entry.name.as_str(), i) {
                ("logloss", 0) => sigmoid(&mut v),
                ("logloss", 1) => binarize(&mut v),
                ("glm_grad", 1) | ("glm_hess", 1) => sigmoid(&mut v),
                ("glm_grad", 2) => binarize(&mut v),
                ("newton_block", 1) | ("lbfgs_block", 1) => binarize(&mut v),
                ("div", 1) => {
                    for x in v.iter_mut() {
                        *x = x.abs() + 1.0;
                    }
                }
                _ => {}
            }
            Block::from_vec(s, v)
        })
        .collect()
}

#[test]
fn every_artifact_matches_native_oracle() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::new(&dir).expect("pjrt client");
    let manifest = Manifest::load(&dir).unwrap();
    let mut rng = Rng::seed_from_u64(0xA0A0);
    let mut checked = 0;
    for entry in manifest.entries() {
        let Some(kernel) = kernel_for(&entry.name) else { continue };
        let inputs = inputs_for(entry, &mut rng);
        let refs: Vec<&Block> = inputs.iter().collect();
        let got = rt.execute(&kernel, &refs, &ExecContext::host_default()).expect(&entry.name);
        let want = native::execute(&kernel, &refs).unwrap();
        assert_eq!(got.len(), want.len(), "{}", entry.name);
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(g.shape, w.shape);
            let d = nums::util::stats::max_rel_diff(g.buf(), w.buf());
            assert!(d < 1e-8, "{} {:?}: rel diff {d}", entry.name, entry.dims);
        }
        checked += 1;
    }
    assert!(checked >= 40, "only {checked} artifacts checked");
    assert_eq!(rt.exec_count.load(std::sync::atomic::Ordering::Relaxed), checked);
}

#[test]
fn executables_are_cached_across_calls() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::new(&dir).unwrap();
    let mut rng = Rng::seed_from_u64(1);
    let mk = |rng: &mut Rng| {
        let mut v = vec![0.0; 64 * 64];
        rng.fill_normal(&mut v);
        Block::from_vec(&[64, 64], v)
    };
    for _ in 0..5 {
        let (a, b) = (mk(&mut rng), mk(&mut rng));
        rt.execute(&Kernel::Matmul, &[&a, &b], &ExecContext::host_default()).unwrap();
    }
    assert_eq!(rt.compiled_count(), 1, "one executable, five executions");
}

#[test]
fn composite_backend_falls_back_to_native() {
    let Some(dir) = artifacts_dir() else { return };
    let backend = Backend::pjrt(&dir).unwrap();
    // 64x64 add: in the manifest -> PJRT
    let a = Block::filled(&[64, 64], 1.0);
    let b = Block::filled(&[64, 64], 2.0);
    backend.execute(&Kernel::Ew(BinOp::Add), &[&a, &b], &ExecContext::host_default()).unwrap();
    // 7x7 add: not in the manifest -> native
    let c = Block::filled(&[7, 7], 1.0);
    let d = Block::filled(&[7, 7], 2.0);
    backend.execute(&Kernel::Ew(BinOp::Add), &[&c, &d], &ExecContext::host_default()).unwrap();
    // QR: native-only kernel
    let x = Block::filled(&[16, 4], 1.0);
    backend.execute(&Kernel::Qr, &[&x], &ExecContext::host_default()).ok();
    let (pjrt, native) = backend.counters();
    assert_eq!(pjrt, 1);
    assert!(native >= 2);
}

#[test]
fn unsupported_shape_errors_cleanly_on_pure_pjrt() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::new(&dir).unwrap();
    let a = Block::filled(&[3, 3], 1.0);
    let b = Block::filled(&[3, 3], 1.0);
    let err = rt.execute(&Kernel::Ew(BinOp::Add), &[&a, &b], &ExecContext::host_default()).unwrap_err();
    assert!(format!("{err}").contains("no artifact"));
}
