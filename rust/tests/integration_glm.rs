//! GLM integration: distributed Newton/L-BFGS end-to-end on the PJRT
//! backend, equivalence with the serial baseline, driver-aggregation cost.

use nums::api::{Policy, Session, SessionConfig};
use nums::glm::data::{classification_data, classification_dense};
use nums::glm::{accuracy, lbfgs_fit, newton_fit, newton_fit_driver_agg, newton_fit_serial};

#[test]
fn newton_through_aot_artifact_shapes() {
    // 2048x16 blocks exactly match the newton_block_2048x16 artifact.
    let mut sess = Session::new(SessionConfig::real_small(2, 2));
    let (x, y) = classification_data(&mut sess, 4 * 2048, 16, 4, 0xAB);
    let res = newton_fit(&mut sess, &x, &y, 10, 1e-9).unwrap();
    assert!(
        res.losses.last().unwrap() < &(res.losses[0] * 0.01),
        "{:?}",
        res.losses
    );
    assert!(accuracy(&mut sess, &x, &y, &res.beta).unwrap() > 0.99);
    let (pjrt, _) = sess.backend.counters();
    match sess.backend.as_ref() {
        nums::runtime::Backend::Pjrt { .. } => {
            assert!(pjrt > 0, "hot path must hit PJRT artifacts")
        }
        _ => eprintln!("no artifacts available; native-only run"),
    }
}

#[test]
fn distributed_equals_serial_bitwise_ish() {
    let n = 1024;
    let (xd, yd) = classification_dense(n, 8, 0xCD);
    let serial = newton_fit_serial(&xd, &yd, 5, 0.0).unwrap();

    for q in [2usize, 4, 8] {
        let mut sess = Session::new(SessionConfig::real_small(4, 2));
        let (x, y) = classification_data(&mut sess, n, 8, q, 0xCD);
        let dist = newton_fit(&mut sess, &x, &y, 5, 0.0).unwrap();
        let beta = sess.fetch(&dist.beta).unwrap();
        assert!(
            beta.max_abs_diff(&serial.beta) < 1e-7,
            "q={q}: distributed Newton diverges from dense"
        );
        // loss curves agree too
        for (a, b) in dist.losses.iter().zip(&serial.losses) {
            assert!((a - b).abs() / b.abs().max(1.0) < 1e-7);
        }
    }
}

#[test]
fn lbfgs_and_newton_reach_same_optimum() {
    let mut s1 = Session::new(SessionConfig::real_small(2, 2));
    let (x1, y1) = classification_data(&mut s1, 1024, 6, 4, 0xEF);
    let newton = newton_fit(&mut s1, &x1, &y1, 15, 1e-10).unwrap();

    let mut s2 = Session::new(SessionConfig::real_small(2, 2));
    let (x2, y2) = classification_data(&mut s2, 1024, 6, 4, 0xEF);
    let lbfgs = lbfgs_fit(&mut s2, &x2, &y2, 60, 10, 1e-10).unwrap();

    // separable data: compare achieved losses, not parameters
    let ln = *newton.losses.last().unwrap();
    let ll = *lbfgs.losses.last().unwrap();
    assert!(ln < 1.0 && ll < 1.0, "newton {ln}, lbfgs {ll}");
}

#[test]
fn driver_aggregation_is_strictly_worse_at_scale() {
    // paper-scale modeled run: 16 nodes, 256 blocks (2 GB-ish blocks in
    // the paper; the serial driver-side chain grows with block count)
    let mut s1 = Session::new(SessionConfig::paper_sim(16, 32));
    let (x1, y1) = classification_data(&mut s1, 1 << 22, 256, 256, 1);
    let lshs = newton_fit(&mut s1, &x1, &y1, 1, 0.0).unwrap();

    let mut s2 = Session::new(SessionConfig::paper_sim(16, 32));
    let (x2, y2) = classification_data(&mut s2, 1 << 22, 256, 256, 1);
    let agg = newton_fit_driver_agg(&mut s2, &x2, &y2, 1).unwrap();

    assert!(
        agg.sim_secs() > lshs.sim_secs() * 1.3,
        "driver agg {:.4}s vs lshs {:.4}s",
        agg.sim_secs(),
        lshs.sim_secs()
    );
    assert!(agg.transfer_bytes() > lshs.transfer_bytes());
}

#[test]
fn weak_scaling_shape_fig12b() {
    // modeled weak scaling: work per node constant; time should stay
    // within ~2.5x of 1 node through 16 nodes (reductions add log cost;
    // the paper sees degradation only at 16 nodes on 20 Gbps).
    let mut times = Vec::new();
    for nodes in [1usize, 2, 4, 8, 16] {
        let mut sess = Session::new(SessionConfig::paper_sim(nodes, 8));
        let rows_per_node = 1 << 18;
        let (x, y) =
            classification_data(&mut sess, rows_per_node * nodes, 256, nodes * 2, 7);
        let res = newton_fit(&mut sess, &x, &y, 1, 0.0).unwrap();
        times.push(res.sim_secs());
    }
    for (i, t) in times.iter().enumerate() {
        assert!(
            *t < times[0] * 2.5,
            "weak scaling broke at point {i}: {times:?}"
        );
    }
}
