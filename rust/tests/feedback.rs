//! Plan↔runtime feedback suites.
//!
//! Executor level: adaptive batch stealing, priority-ordered prefetching
//! with steal cancellation, and the queued-pull byte budget must all be
//! pure scheduling/latency optimizations — outputs bit-identical to
//! sequential plan-order execution for every random graph, node count,
//! thread count, stealing/prefetch mode and memory budget, with the
//! per-node byte-accounting identity (`prefetch + demand == net_in`)
//! intact even when steals cancel queued pulls mid-flight.
//!
//! Session level: with `SessionConfig::feedback` on, the ClusterState a
//! session plans its *next* run against must contain the load the
//! executor actually observed — unplanned steal/demand traffic in the
//! Eq. 2 network terms, runtime replica copies in the location map —
//! and with it off, the model must contain exactly the load the plans
//! committed, nothing more.

use std::collections::HashMap;
use std::sync::Arc;

use nums::api::{ops, Session, SessionConfig};
use nums::exec::{Plan, RealExecutor, RealReport, Task};
use nums::prelude::*;
use nums::runtime::native;
use nums::store::{MemoryManager, StoreSet};
use nums::util::prop::forall_res;

/// Sequential oracle: run the plan in order, single process, no stores.
fn run_sequential(plan: &Plan, seeds: &HashMap<u64, Block>) -> HashMap<u64, Block> {
    let mut env: HashMap<u64, Block> = seeds.clone();
    for t in &plan.tasks {
        let refs: Vec<&Block> = t.inputs.iter().map(|o| &env[o]).collect();
        let outs = native::execute(&t.kernel, &refs).unwrap();
        for ((obj, _), b) in t.outputs.iter().zip(outs) {
            env.insert(*obj, b);
        }
    }
    env
}

/// Random-but-valid plan spec (same scheme as `tests/exec_overlap.rs`),
/// with a skew knob: when set, every task targets node 0, maximizing
/// batch-steal and prefetch-cancellation traffic.
#[derive(Debug)]
struct PlanSpec {
    nodes: usize,
    threads_per_node: usize,
    stealing: bool,
    prefetch: bool,
    budgeted: bool,
    skewed: bool,
    n_seeds: usize,
    tasks: Vec<(u8, usize, usize, usize)>,
}

const SHAPE: [usize; 2] = [4, 4];
const BLOCK_BYTES: u64 = (SHAPE[0] * SHAPE[1] * 8) as u64;

fn decode(spec: &PlanSpec) -> (Plan, HashMap<u64, Block>) {
    let mut rng = Rng::seed_from_u64(0xFEEDB ^ spec.tasks.len() as u64);
    let mut seeds = HashMap::new();
    let mut avail: Vec<u64> = Vec::new();
    for s in 0..spec.n_seeds {
        let mut v = vec![0.0; SHAPE[0] * SHAPE[1]];
        rng.fill_normal(&mut v);
        seeds.insert(s as u64, Block::from_vec(&SHAPE, v));
        avail.push(s as u64);
    }
    let mut tasks = Vec::new();
    for (i, &(kind, p1, p2, tgt)) in spec.tasks.iter().enumerate() {
        let out = 1000 + i as u64;
        let (kernel, inputs) = match kind % 5 {
            0 => (Kernel::Ew(BinOp::Add), vec![avail[p1 % avail.len()], avail[p2 % avail.len()]]),
            1 => (Kernel::Ew(BinOp::Mul), vec![avail[p1 % avail.len()], avail[p2 % avail.len()]]),
            2 => (Kernel::Neg, vec![avail[p1 % avail.len()]]),
            3 => (Kernel::Scale(0.5), vec![avail[p1 % avail.len()]]),
            _ => (Kernel::Matmul, vec![avail[p1 % avail.len()], avail[p2 % avail.len()]]),
        };
        let in_shapes = vec![SHAPE.to_vec(); inputs.len()];
        tasks.push(Task {
            kernel,
            inputs,
            in_shapes,
            outputs: vec![(out, SHAPE.to_vec())],
            target: if spec.skewed { 0 } else { tgt % spec.nodes },
            transfers: vec![],
        });
        avail.push(out);
    }
    (Plan { tasks }, seeds)
}

/// Per-node `prefetch_bytes + demand_pull_bytes == net_in` for this run.
fn check_byte_identity(rep: &RealReport, nodes: usize) -> Result<(), String> {
    for n in 0..nodes {
        let net_in = rep.store_snapshot[n].2;
        let p = &rep.prefetch_stats[n];
        let accounted = p.prefetch_bytes + p.demand_pull_bytes;
        if accounted != net_in {
            return Err(format!(
                "node {n}: prefetch {} + demand {} = {accounted} != net_in {net_in}",
                p.prefetch_bytes, p.demand_pull_bytes
            ));
        }
    }
    Ok(())
}

#[test]
fn prop_adaptive_steal_and_cancellation_match_sequential_bit_for_bit() {
    forall_res(
        0xADA97,
        25,
        |r| PlanSpec {
            nodes: 1 + r.usize(4),
            threads_per_node: 1 + r.usize(3),
            stealing: r.usize(4) != 0, // bias on: the paths under test
            prefetch: r.usize(4) != 0,
            budgeted: r.usize(2) == 1,
            skewed: r.usize(2) == 1,
            n_seeds: 2 + r.usize(4),
            tasks: (0..1 + r.usize(24))
                .map(|_| (r.usize(256) as u8, r.usize(1 << 16), r.usize(1 << 16), r.usize(1 << 16)))
                .collect(),
        },
        |spec| {
            let (plan, seeds) = decode(spec);
            let want = run_sequential(&plan, &seeds);
            let topo = Topology::new(spec.nodes, 2, SystemMode::Ray);
            let budget = if spec.budgeted { Some(4 * BLOCK_BYTES) } else { None };
            let mut exec = RealExecutor::new(topo, Arc::new(Backend::native()))
                .with_stealing(spec.stealing)
                .with_prefetch(spec.prefetch)
                .with_memory(MemoryManager::new(spec.nodes, budget, true));
            exec.threads_per_node = spec.threads_per_node;
            let stores = StoreSet::new(spec.nodes);
            for (obj, b) in &seeds {
                stores.put((*obj as usize) % spec.nodes, *obj, Arc::new(b.clone()));
            }
            let rep = exec
                .run(&plan, &stores)
                .map_err(|e| format!("executor failed: {e}"))?;
            if spec.prefetch {
                check_byte_identity(&rep, spec.nodes)?;
            }
            // the reconciliation must internally agree with the counters
            for (n, f) in rep.feedback.nodes.iter().enumerate() {
                if f.steal_bytes != rep.node_stats[n].steal_bytes {
                    return Err(format!("node {n}: feedback steal bytes diverge"));
                }
                // an empty plan-transfer list means every inbound byte is
                // unplanned — the reconciliation may never undercount it
                if f.unplanned_in_bytes != rep.store_snapshot[n].2 {
                    return Err(format!(
                        "node {n}: unplanned_in {} != net_in {} on a plan with \
                         no committed transfers",
                        f.unplanned_in_bytes, rep.store_snapshot[n].2
                    ));
                }
            }
            let mgr = exec.memory.as_ref().unwrap();
            let consumed: std::collections::HashSet<u64> =
                plan.tasks.iter().flat_map(|t| t.inputs.iter().copied()).collect();
            for i in 0..plan.tasks.len() {
                let obj = 1000 + i as u64;
                if consumed.contains(&obj) {
                    continue; // dead intermediate, GC-released
                }
                let got = mgr
                    .fetch(&stores, obj)
                    .ok_or_else(|| format!("output {obj} missing"))?;
                let w = &want[&obj];
                if got.shape != w.shape {
                    return Err(format!("shape mismatch on {obj}"));
                }
                if got.buf().iter().zip(w.buf()).any(|(a, b)| a.to_bits() != b.to_bits()) {
                    return Err(format!("output {obj} differs from oracle"));
                }
            }
            Ok(())
        },
    );
}

/// Build one deliberately skewed 2-node session: every creation block on
/// node 0, so the first plan packs node 0 and stealing must migrate.
fn skewed_session(feedback: bool) -> (Session, DistArray, DistArray) {
    let cfg = SessionConfig::real_small(2, 2).with_feedback(feedback);
    let mut sess = Session::new(cfg);
    let x = sess.randn_at(&[256, 256], &[4, 4], 0);
    let y = sess.randn_at(&[256, 256], &[4, 4], 0);
    (sess, x, y)
}

#[test]
fn feedback_absorbs_observed_load_and_off_stays_plan_exact() {
    let run = |feedback: bool| {
        let (mut sess, x, y) = skewed_session(feedback);
        let (out, rep) = ops::matmul(&mut sess, &x, &y).unwrap();
        let dense = sess.fetch(&out).unwrap();
        (sess, dense, rep)
    };
    let (sess_off, out_off, rep_off) = run(false);
    let (sess_on, out_on, rep_on) = run(true);
    // run 1 plans before any feedback exists: identical plans, identical
    // execution order constraints, bit-identical numerics
    assert_eq!(rep_off.tasks, rep_on.tasks);
    assert_eq!(out_off.max_abs_diff(&out_on), 0.0, "first runs must match");

    // OFF: the model's inbound-traffic term is exactly what the plans
    // committed — runtime traffic (steals, demand misses) never enters
    let committed_elems = rep_off.transfer_bytes as f64 / 8.0;
    let off_in: f64 = sess_off.state.net_in.iter().sum();
    assert!(
        (off_in - committed_elems).abs() < 1e-6,
        "feedback off: net_in {off_in} != committed {committed_elems}"
    );

    // ON: everything the executor reconciled is in the model
    let real = rep_on.real.as_ref().expect("real mode");
    let fb = &real.feedback;
    let on_in: f64 = sess_on.state.net_in.iter().sum();
    let unplanned_elems: f64 = fb
        .nodes
        .iter()
        .map(|n| n.unplanned_in_bytes as f64 / 8.0)
        .sum();
    assert!(
        (on_in - (committed_elems + unplanned_elems)).abs() < 1e-6,
        "feedback on: net_in {on_in} != committed {committed_elems} + observed {unplanned_elems}"
    );
    // every still-live runtime replica joined the location map
    for &(obj, node) in &fb.replicas {
        if sess_on.state.size_of(obj) == 0.0 {
            continue; // released after collection: forgotten again
        }
        assert!(
            sess_on
                .state
                .locations_of(obj)
                .iter()
                .any(|&t| sess_on.topo.node_of(t) == node),
            "replica ({obj}, {node}) missing from the load model"
        );
    }
}

#[test]
fn second_plan_uses_runtime_replicas_when_feedback_is_on() {
    let (mut sess, x, y) = skewed_session(true);
    let (_, rep1) = ops::matmul(&mut sess, &x, &y).unwrap();
    let real1 = rep1.real.as_ref().expect("real mode");
    if real1.feedback.replicas.is_empty() {
        eprintln!("skipping: no steal/replica traffic on this host");
        return;
    }
    // acceptance: the second of two identical skewed-layout runs plans
    // against a ClusterState that includes the observed load — every
    // seed-block replica the executor reported is a placement option now
    let mut widened = 0usize;
    for &(obj, node) in &real1.feedback.replicas {
        if sess.state.size_of(obj) == 0.0 {
            continue;
        }
        if sess
            .state
            .locations_of(obj)
            .iter()
            .any(|&t| sess.topo.node_of(t) == node)
        {
            widened += 1;
        }
    }
    assert!(widened > 0, "no replica widened the location map");
    // the second identical run completes and plans from the updated state
    let (out2, rep2) = ops::matmul(&mut sess, &x, &y).unwrap();
    assert_eq!(rep2.tasks, rep1.tasks);
    let dense = sess.fetch(&out2).unwrap();
    assert_eq!(dense.shape, vec![256, 256]);
}

#[test]
fn feedback_toggle_is_bit_transparent_for_elementwise_pipelines() {
    // element-wise ops are block-local: placement can never change their
    // numerics, so across *multiple* runs (where feedback does alter
    // plans) the toggle must stay bit-transparent
    let run = |feedback: bool| {
        let (mut sess, x, y) = skewed_session(feedback);
        let (a, _) = ops::add(&mut sess, &x, &y).unwrap();
        let (b, _) = ops::mul(&mut sess, &a, &x).unwrap();
        let (c, _) = ops::neg(&mut sess, &b).unwrap();
        sess.fetch(&c).unwrap()
    };
    let off = run(false);
    let on = run(true);
    assert_eq!(off.max_abs_diff(&on), 0.0, "feedback changed elementwise bits");
}

#[test]
fn skewed_glm_model_tracks_committed_plus_observed_traffic() {
    // the bench asserts the perf claim (strictly fewer demand pulls in
    // the fig09 feedback ablation, which is timing-sensitive); the test
    // bar is the deterministic wiring: across a whole multi-run Newton
    // fit, the OFF model's inbound term equals exactly the bytes its
    // plans committed, while the ON model equals committed plus every
    // clamped unplanned byte the executor reported — run by run
    let fit = |feedback: bool| {
        let cfg = SessionConfig::real_small(2, 2).with_feedback(feedback);
        let mut sess = Session::new(cfg);
        let x = sess.randn_at(&[512, 8], &[8, 1], 0);
        let y = sess.create_at(&[512, 1], &[8, 1], 0, |rng, bs, _| {
            (0..bs.iter().product::<usize>())
                .map(|_| if rng.bool(0.5) { 1.0 } else { 0.0 })
                .collect()
        });
        let res = nums::glm::newton_fit(&mut sess, &x, &y, 3, 1e-6).unwrap();
        let committed: u64 = res.reports.iter().map(|r| r.transfer_bytes).sum();
        let unplanned: u64 = res
            .reports
            .iter()
            .filter_map(|r| r.real.as_ref())
            .flat_map(|r| r.feedback.nodes.iter())
            .map(|n| n.unplanned_in_bytes)
            .sum();
        let model_in: f64 = sess.state.net_in.iter().sum();
        (committed, unplanned, model_in, *res.losses.last().unwrap())
    };
    let (c_off, _, in_off, loss_off) = fit(false);
    let (c_on, u_on, in_on, loss_on) = fit(true);
    assert!(loss_off.is_finite() && loss_off < 0.8, "off arm diverged: {loss_off}");
    assert!(loss_on.is_finite() && loss_on < 0.8, "on arm diverged: {loss_on}");
    assert!(
        (in_off - c_off as f64 / 8.0).abs() < 1e-6,
        "feedback off: model in {in_off} != committed {} elems",
        c_off as f64 / 8.0
    );
    let want_on = (c_on + u_on) as f64 / 8.0;
    assert!(
        (in_on - want_on).abs() < 1e-6,
        "feedback on: model in {in_on} != committed+observed {want_on} elems"
    );
}
