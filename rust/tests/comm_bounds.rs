//! Appendix A: communication lower bounds, checked against the scheduler's
//! actual transfer decisions on the modeled cluster.
//!
//! The bounds are stated in object-transfer counts/bytes under Ray-mode
//! node-granular placement with caching (a block crosses a given edge at
//! most once). LSHS must attain: 0 for element-wise ops (A.1), log-tree
//! counts for reductions (A.2), and the inner/outer-product counts (A.3,
//! A.4); for square matmul (A.5) it must stay under the SUMMA-style
//! volume.

use nums::api::{ops, Policy, Session, SessionConfig};
use nums::prelude::*;

fn sess(nodes: usize, wpn: usize) -> Session {
    Session::new(SessionConfig::paper_sim(nodes, wpn).with_policy(Policy::Lshs))
}

#[test]
fn a1_elementwise_zero_bound_attained() {
    for (nodes, q) in [(2usize, 8usize), (4, 16), (8, 32), (16, 64)] {
        let mut s = sess(nodes, 4);
        let x = s.zeros(&[1 << 20, 64], &[q, 1]);
        let y = s.zeros(&[1 << 20, 64], &[q, 1]);
        let (_, rep) = ops::add(&mut s, &x, &y).unwrap();
        assert_eq!(rep.transfers, 0, "k={nodes}, p={q}");
        let (_, rep) = ops::neg(&mut s, &x).unwrap();
        assert_eq!(rep.transfers, 0, "unary k={nodes}");
    }
}

#[test]
fn a2_reduction_meets_log_tree_bound() {
    // sum over p row blocks on k nodes: after local reduction, the
    // cross-node tree moves exactly k-1 blocks (log2(k) rounds).
    for (nodes, q) in [(2usize, 16usize), (4, 16), (8, 32)] {
        let mut s = sess(nodes, 8);
        let x = s.zeros(&[1 << 20, 64], &[q, 1]);
        let (_, rep) = ops::sum_axis(&mut s, &x, 0).unwrap();
        assert!(
            rep.transfers <= nodes - 1,
            "k={nodes}: {} transfers > k-1",
            rep.transfers
        );
    }
}

#[test]
fn a3_inner_product_bound() {
    // XᵀY on p co-partitioned row blocks: block products are local; only
    // the reduce tree crosses nodes -> ≤ k-1 transfers of d×d partials.
    let nodes = 8;
    let d = 256usize;
    let mut s = sess(nodes, 4);
    let x = s.zeros(&[1 << 22, d], &[32, 1]);
    let y = s.zeros(&[1 << 22, d], &[32, 1]);
    let (_, rep) = ops::matmul(&mut s, &x.t(), &y).unwrap();
    assert!(
        rep.transfers <= nodes - 1,
        "{} transfers > k-1",
        rep.transfers
    );
    // transferred objects are the small d×d partials, not X blocks
    let max_bytes = (nodes as u64 - 1) * (d * d * 8) as u64;
    assert!(
        rep.transfer_bytes <= max_bytes,
        "{} bytes > {max_bytes}",
        rep.transfer_bytes
    );
}

#[test]
fn a4_outer_product_bound() {
    // X Yᵀ with √p × √p output: every off-diagonal output needs one
    // operand from another node; bound 2(√k−1)·r block sends per node ⇒
    // total ≤ k·2(√k−1)·r. We check the aggregate volume stays within the
    // bound for the node-level grid (r=1 at node granularity).
    let nodes = 4usize;
    let q = 8usize; // row blocks
    let mut s = sess(nodes, 4);
    let x = s.zeros(&[1 << 18, 64], &[q, 1]);
    let y = s.zeros(&[1 << 18, 64], &[q, 1]);
    let (_, rep) = ops::matmul(&mut s, &x, &y.t()).unwrap();
    // total cross-node block moves bounded by blocks × (nodes-1) (each
    // block visits each other node at most once, thanks to caching)
    let bound = (2 * q * (nodes - 1)) as usize;
    assert!(
        rep.transfers <= bound,
        "{} transfers > {bound}",
        rep.transfers
    );
    // caching: re-running the same op must move strictly less
    let (_, rep2) = ops::matmul(&mut s, &x, &y.t()).unwrap();
    assert!(rep2.transfers <= rep.transfers);
}

#[test]
fn a5_square_matmul_under_summa_volume() {
    // A.5: LSHS's lower bound is asymptotically below SUMMA's
    // 2√p·log(√p)·C(n). Check total modeled comm time of the LSHS plan
    // stays below the SUMMA closed form at k=16.
    let nodes = 16usize;
    let n = 1 << 13;
    let side = 4usize;
    let cfg = SessionConfig::paper_sim(nodes, 32)
        .with_node_grid(NodeGrid::new(&[side, side]));
    let mut s = Session::new(cfg);
    let g = 8usize;
    let a = s.zeros(&[n, n], &[g, g]);
    let b = s.zeros(&[n, n], &[g, g]);
    let (_, rep) = ops::matmul(&mut s, &a, &b).unwrap();

    let summa = nums::summa::Summa::new(nodes, n).run(
        NetParams::mpi_testbed(),
        ComputeParams::mpi_testbed(),
        32,
    );
    // bytes actually crossing node boundaries
    let lshs_bytes = rep.sim.transfer_bytes;
    let summa_bytes = summa.report.transfer_bytes;
    assert!(
        (lshs_bytes as f64) < 2.0 * summa_bytes as f64,
        "LSHS volume {lshs_bytes} should be comparable to SUMMA {summa_bytes}"
    );
}

#[test]
fn caching_means_each_block_crosses_an_edge_once() {
    // App. A's standing assumption. Re-using an operand on the same node
    // must not re-transfer it.
    let mut s = sess(2, 2);
    let x = s.zeros(&[1 << 16, 64], &[2, 1]);
    let y = s.zeros(&[1 << 16, 64], &[2, 1]);
    let (_, r1) = ops::matmul(&mut s, &x.t(), &y).unwrap();
    let (_, r2) = ops::matmul(&mut s, &x.t(), &y).unwrap();
    assert!(
        r2.transfer_bytes <= r1.transfer_bytes,
        "cached operands must not increase traffic"
    );
}
