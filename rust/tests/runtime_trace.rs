//! Real-runtime tracing suites: the recorder must be a *pure observer*.
//!
//! Four claims, matching the module contract of
//! `nums::metrics::runtime_trace`:
//!
//! 1. every executed task produces exactly one span, stamped with the
//!    node/worker that really ran it;
//! 2. byte accounting reconciles exactly — per node, fetch-event bytes
//!    split into prefetch + demand equal the store's `net_in` counter,
//!    and span `fetch_bytes` sum to the demand side; spill/readback/GC
//!    event totals equal the run's `NodeMemStats` deltas;
//! 3. tracing on vs off is bit-identical on a random-graph oracle suite
//!    (the recorder may not perturb execution);
//! 4. the folded `series_events` feed the existing Fig. 15 machinery and
//!    the Chrome trace export is valid JSON (round-tripped through
//!    `nums::util::json`).

use std::collections::{HashMap, HashSet};
use std::sync::Arc;

use nums::api::ops;
use nums::exec::{Plan, RealExecutor, Task};
use nums::metrics::runtime_trace::{EventKind, FetchOrigin, RunTrace};
use nums::metrics::{chrome_trace_json, per_node_series, summarize_trace};
use nums::prelude::*;
use nums::runtime::native;
use nums::store::{MemoryManager, StoreSet};
use nums::util::prop::forall_res;

/// Sequential oracle: run the plan in order, single process, no stores.
fn run_sequential(plan: &Plan, seeds: &HashMap<u64, Block>) -> HashMap<u64, Block> {
    let mut env: HashMap<u64, Block> = seeds.clone();
    for t in &plan.tasks {
        let refs: Vec<&Block> = t.inputs.iter().map(|o| &env[o]).collect();
        let outs = native::execute(&t.kernel, &refs).unwrap();
        for ((obj, _), b) in t.outputs.iter().zip(outs) {
            env.insert(*obj, b);
        }
    }
    env
}

/// The canonical skew (same shape as `tests/exec_overlap.rs`): matmuls
/// whose inputs all live on node 0, targeted so the runtime has to move
/// work and bytes.
fn skewed_matmul_plan(k_tasks: usize, n: usize, target: usize) -> (Plan, HashMap<u64, Block>) {
    let mut rng = Rng::seed_from_u64(0x7A0CE);
    let mut seeds = HashMap::new();
    for i in 0..2 * k_tasks as u64 {
        let mut v = vec![0.0; n * n];
        rng.fill_normal(&mut v);
        seeds.insert(i, Block::from_vec(&[n, n], v));
    }
    let plan = Plan {
        tasks: (0..k_tasks)
            .map(|i| Task {
                kernel: Kernel::Matmul,
                inputs: vec![(2 * i) as u64, (2 * i + 1) as u64],
                in_shapes: vec![vec![n, n], vec![n, n]],
                outputs: vec![(1000 + i as u64, vec![n, n])],
                target,
                transfers: vec![],
            })
            .collect(),
    };
    (plan, seeds)
}

fn seeded_stores(nodes: usize, seeds: &HashMap<u64, Block>) -> StoreSet {
    let stores = StoreSet::new(nodes);
    for (obj, b) in seeds {
        stores.put(0, *obj, Arc::new(b.clone()));
    }
    stores
}

/// Per-kind event byte totals (and for fetches, per-origin).
fn event_bytes(tr: &RunTrace) -> HashMap<&'static str, u64> {
    let mut m: HashMap<&'static str, u64> = HashMap::new();
    for e in &tr.events {
        let k = match e.kind {
            EventKind::Fetch(FetchOrigin::Prefetch) => "fetch.prefetch",
            EventKind::Fetch(FetchOrigin::Demand) => "fetch.demand",
            EventKind::Spill => "spill",
            EventKind::SpillReuse => "spill.reuse",
            EventKind::Readback => "readback",
            EventKind::ReplicaEvict => "replica.evict",
            EventKind::GcFree => "gc.free",
            EventKind::Steal => "steal",
            EventKind::PlanCacheHit => "plan.cache.hit",
        };
        *m.entry(k).or_default() += e.bytes;
    }
    m
}

#[test]
fn every_executed_task_gets_exactly_one_span() {
    let k_tasks = 40usize;
    let (plan, seeds) = skewed_matmul_plan(k_tasks, 64, 0);
    let topo = Topology::new(4, 2, SystemMode::Ray);
    let mut exec = RealExecutor::new(topo, Arc::new(Backend::native()))
        .with_stealing(true)
        .with_prefetch(true)
        .with_tracing(true);
    exec.threads_per_node = 2;
    let stores = seeded_stores(4, &seeds);
    let rep = exec.run(&plan, &stores).unwrap();
    let tr = rep.trace.as_ref().expect("tracing was on");

    assert_eq!(tr.dropped_spans, 0, "ring must not wrap at this scale");
    assert_eq!(tr.spans.len(), k_tasks, "one span per executed task");
    let ids: HashSet<usize> = tr.spans.iter().map(|s| s.task).collect();
    assert_eq!(ids.len(), k_tasks, "no task recorded twice");
    assert!(ids.iter().all(|&t| t < k_tasks));

    for sp in &tr.spans {
        assert!(sp.node < 4, "{sp:?}");
        assert_eq!(sp.node, sp.worker / 2, "worker id encodes its node: {sp:?}");
        // monotonic within a span (queue wait can clamp to zero, the
        // rest are taken in order off one epoch)
        assert!(sp.start_t <= sp.fetch_end_t && sp.fetch_end_t <= sp.end_t, "{sp:?}");
        assert!(sp.queue_wait_secs() >= 0.0 && sp.fetch_secs() >= 0.0 && sp.exec_secs() >= 0.0);
        assert!(!sp.kernel.is_empty(), "kernel label resolved in finish()");
        assert!(sp.threads >= 1);
    }

    // migration cross-check: spans, node_stats and the divergence report
    // all describe the same steals
    let stolen_spans = tr.spans.iter().filter(|s| s.stolen).count();
    let stolen_stats: usize = rep.node_stats.iter().map(|s| s.tasks_stolen).sum();
    assert_eq!(stolen_spans, stolen_stats);
    assert!(stolen_spans > 0, "skewed plan must trigger stealing");
    assert_eq!(tr.divergence.migrated_tasks(), stolen_spans);
    let steal_events = tr.events.iter().filter(|e| e.kind == EventKind::Steal).count();
    assert!(steal_events > 0, "steals must leave instant events");

    // per-node task conservation in the divergence report
    let run_total: usize = tr.divergence.nodes.iter().map(|n| n.observed_tasks).sum();
    assert_eq!(run_total, k_tasks);
    assert_eq!(
        tr.divergence.nodes.iter().map(|n| n.planned_tasks).sum::<usize>(),
        k_tasks
    );
}

#[test]
fn fetch_bytes_reconcile_exactly_with_net_in() {
    // pipeline skew: inputs born on node 0, work targeted at node 1 — the
    // transfer thread and the hot path split the inbound bytes, and the
    // trace must account every byte exactly once.
    let k_tasks = 8usize;
    let (plan, seeds) = skewed_matmul_plan(k_tasks, 96, 1);
    let topo = Topology::new(2, 1, SystemMode::Ray);
    let mut exec = RealExecutor::new(topo, Arc::new(Backend::native()))
        .with_stealing(false)
        .with_prefetch(true)
        .with_tracing(true);
    exec.threads_per_node = 1;
    let stores = seeded_stores(2, &seeds);
    let rep = exec.run(&plan, &stores).unwrap();
    let tr = rep.trace.as_ref().unwrap();

    for nd in &tr.divergence.nodes {
        // identity 1: every observed inbound byte is prefetch or demand
        assert_eq!(
            nd.observed_in_bytes,
            nd.prefetch_in_bytes + nd.demand_in_bytes,
            "node {}", nd.node
        );
        // identity 2: fetch events reconcile with the store NIC counter
        // (fresh stores: the snapshot is this run's delta)
        assert_eq!(
            nd.observed_in_bytes, rep.store_snapshot[nd.node].2,
            "node {}: event bytes != net_in", nd.node
        );
        // identity 3: and with the prefetcher's own view of the split
        let p = &rep.prefetch_stats[nd.node];
        assert_eq!(nd.prefetch_in_bytes, p.prefetch_bytes, "node {}", nd.node);
        assert_eq!(nd.demand_in_bytes, p.demand_pull_bytes, "node {}", nd.node);
    }
    // identity 4: span fetch_bytes are exactly the hot-path (demand) side
    let demand_total: u64 = tr
        .divergence
        .nodes
        .iter()
        .map(|n| n.demand_in_bytes)
        .sum();
    assert_eq!(tr.span_fetch_bytes(), demand_total);
    let ev = event_bytes(tr);
    assert_eq!(
        ev.get("fetch.prefetch").copied().unwrap_or(0)
            + ev.get("fetch.demand").copied().unwrap_or(0),
        rep.store_snapshot.iter().map(|s| s.2).sum::<u64>()
    );
    // something actually moved, on both paths or at least one
    assert!(tr.divergence.nodes[1].observed_in_bytes > 0);
}

#[test]
fn spill_events_reconcile_with_mem_stats() {
    // produce-then-fold under a 3-block budget on one node: cold producer
    // outputs spill and come back, lifetime GC releases dead
    // intermediates — and every one of those byte counters must be
    // reproducible from the event stream alone.
    let n = 16usize;
    let k = 8usize;
    let block_bytes = (n * n * 8) as u64;
    let (plan, acc) = nums::bench::harness::produce_fold_plan(k, n);
    let topo = Topology::new(1, 1, SystemMode::Ray);
    let mut exec = RealExecutor::new(topo, Arc::new(Backend::native()))
        .with_prefetch(false)
        .with_memory(MemoryManager::new(1, Some(3 * block_bytes), true))
        .with_tracing(true);
    exec.threads_per_node = 1;
    let stores = StoreSet::new(1);
    stores.put(0, 1, Arc::new(Block::filled(&[n, n], 1.0)));
    let rep = exec.run(&plan, &stores).unwrap();
    let tr = rep.trace.as_ref().unwrap();
    let m = &rep.mem_stats[0];
    assert!(m.spilled_bytes > 0, "a 3-block budget must spill: {m:?}");

    let ev = event_bytes(tr);
    assert_eq!(ev.get("spill").copied().unwrap_or(0), m.spilled_bytes);
    assert_eq!(ev.get("readback").copied().unwrap_or(0), m.readback_bytes);
    assert_eq!(ev.get("spill.reuse").copied().unwrap_or(0), m.spill_reuse_bytes);
    assert_eq!(
        ev.get("replica.evict").copied().unwrap_or(0),
        m.evicted_replica_bytes
    );
    assert_eq!(ev.get("gc.free").copied().unwrap_or(0), m.gc_freed_bytes);
    // the divergence report carries the same spill story
    assert_eq!(tr.divergence.nodes[0].spilled_bytes, m.spilled_bytes);
    assert_eq!(tr.divergence.nodes[0].readback_bytes, m.readback_bytes);
    // and the run still produced the right answer
    let got = exec.memory.as_ref().unwrap().fetch(&stores, acc).unwrap();
    assert_eq!(got.shape, vec![n, n]);
}

#[test]
fn prop_tracing_is_a_pure_observer() {
    // tracing on vs off over random plans: outputs bit-identical, and the
    // off-run must not even allocate a trace
    forall_res(
        0x7 + 0xACE,
        12,
        |r| {
            let n_seeds = 2 + r.usize(4);
            let tasks: Vec<(u8, usize, usize, usize)> = (0..1 + r.usize(16))
                .map(|_| (r.usize(256) as u8, r.usize(1 << 16), r.usize(1 << 16), r.usize(1 << 16)))
                .collect();
            (1 + r.usize(3), r.usize(2) == 1, n_seeds, tasks)
        },
        |&(nodes, stealing, n_seeds, ref task_spec)| {
            const SHAPE: [usize; 2] = [4, 4];
            let mut rng = Rng::seed_from_u64(0x9E2 ^ task_spec.len() as u64);
            let mut seeds = HashMap::new();
            let mut avail: Vec<u64> = Vec::new();
            for s in 0..n_seeds {
                let mut v = vec![0.0; SHAPE[0] * SHAPE[1]];
                rng.fill_normal(&mut v);
                seeds.insert(s as u64, Block::from_vec(&SHAPE, v));
                avail.push(s as u64);
            }
            let mut tasks = Vec::new();
            for (i, &(kind, p1, p2, tgt)) in task_spec.iter().enumerate() {
                let out = 1000 + i as u64;
                let (kernel, inputs) = match kind % 4 {
                    0 => (Kernel::Ew(BinOp::Add), vec![avail[p1 % avail.len()], avail[p2 % avail.len()]]),
                    1 => (Kernel::Ew(BinOp::Mul), vec![avail[p1 % avail.len()], avail[p2 % avail.len()]]),
                    2 => (Kernel::Neg, vec![avail[p1 % avail.len()]]),
                    _ => (Kernel::Scale(0.5), vec![avail[p1 % avail.len()]]),
                };
                let in_shapes = vec![SHAPE.to_vec(); inputs.len()];
                tasks.push(Task {
                    kernel,
                    inputs,
                    in_shapes,
                    outputs: vec![(out, SHAPE.to_vec())],
                    target: tgt % nodes,
                    transfers: vec![],
                });
                avail.push(out);
            }
            let plan = Plan { tasks };
            let want = run_sequential(&plan, &seeds);
            let consumed: HashSet<u64> =
                plan.tasks.iter().flat_map(|t| t.inputs.iter().copied()).collect();
            let mut traced_spans = None;
            for tracing in [false, true] {
                let topo = Topology::new(nodes, 2, SystemMode::Ray);
                let mut exec = RealExecutor::new(topo, Arc::new(Backend::native()))
                    .with_stealing(stealing)
                    .with_prefetch(true)
                    .with_tracing(tracing);
                exec.threads_per_node = 2;
                let stores = StoreSet::new(nodes);
                for (obj, b) in &seeds {
                    stores.put((*obj as usize) % nodes, *obj, Arc::new(b.clone()));
                }
                let rep = exec
                    .run(&plan, &stores)
                    .map_err(|e| format!("tracing={tracing}: {e}"))?;
                if tracing {
                    let tr = rep.trace.as_ref().ok_or("trace missing with tracing on")?;
                    if tr.spans.len() != plan.tasks.len() {
                        return Err(format!(
                            "{} spans for {} tasks",
                            tr.spans.len(),
                            plan.tasks.len()
                        ));
                    }
                    traced_spans = Some(tr.spans.len());
                } else if rep.trace.is_some() {
                    return Err("tracing off must not build a trace".into());
                }
                for i in 0..plan.tasks.len() {
                    let obj = 1000 + i as u64;
                    if consumed.contains(&obj) {
                        continue; // dead intermediate (may be GC'd)
                    }
                    let got = stores
                        .fetch(obj)
                        .ok_or_else(|| format!("tracing={tracing}: output {obj} missing"))?;
                    let w = &want[&obj];
                    if got.buf().iter().zip(w.buf()).any(|(a, b)| a.to_bits() != b.to_bits()) {
                        return Err(format!("tracing={tracing}: output {obj} differs"));
                    }
                }
            }
            traced_spans.ok_or("traced arm never ran".to_string())?;
            Ok(())
        },
    );
}

#[test]
fn series_events_feed_fig15_machinery() {
    // the folded series must plug into the existing per_node_series /
    // summarize_trace pipeline, and its cumulative net_in must agree with
    // the divergence report's observed bytes.
    let k_tasks = 10usize;
    let (plan, seeds) = skewed_matmul_plan(k_tasks, 64, 1);
    let topo = Topology::new(2, 2, SystemMode::Ray);
    let mut exec = RealExecutor::new(topo, Arc::new(Backend::native()))
        .with_stealing(false)
        .with_prefetch(true)
        .with_tracing(true);
    exec.threads_per_node = 2;
    let stores = seeded_stores(2, &seeds);
    let rep = exec.run(&plan, &stores).unwrap();
    let tr = rep.trace.as_ref().unwrap();

    let series = per_node_series(&tr.series_events, 2);
    assert_eq!(series.len(), 2);
    for s in &series {
        // timestamps sorted (total_cmp order)
        assert!(s.t.windows(2).all(|w| w[0] <= w[1]), "node {} unsorted", s.node);
    }
    assert!(series[1].peak_mem() > 0, "executing node accumulated memory");
    for nd in &tr.divergence.nodes {
        assert_eq!(
            series[nd.node].final_net_in(),
            nd.observed_in_bytes,
            "node {}: series net_in must equal observed fetch bytes",
            nd.node
        );
    }
    let sm = summarize_trace(&tr.series_events, 2);
    assert!(sm.max_peak_mem >= series[1].peak_mem());
    assert_eq!(sm.max_net_in, rep.store_snapshot.iter().map(|s| s.2).max().unwrap());
}

#[test]
fn chrome_trace_export_round_trips_through_json_parser() {
    let k_tasks = 6usize;
    let (plan, seeds) = skewed_matmul_plan(k_tasks, 32, 1);
    let topo = Topology::new(2, 1, SystemMode::Ray);
    let mut exec = RealExecutor::new(topo, Arc::new(Backend::native()))
        .with_prefetch(true)
        .with_tracing(true);
    exec.threads_per_node = 1;
    let stores = seeded_stores(2, &seeds);
    let rep = exec.run(&plan, &stores).unwrap();
    let tr = rep.trace.as_ref().unwrap();

    let json = chrome_trace_json(tr);
    let v = nums::util::json::parse(&json).expect("exporter must emit valid JSON");
    let events = v
        .get("traceEvents")
        .and_then(|e| e.as_arr())
        .expect("traceEvents array");
    assert_eq!(events.len(), tr.spans.len() + tr.events.len());
    let mut complete = 0usize;
    let mut instants = 0usize;
    for e in events {
        let ph = e.get("ph").and_then(|p| p.as_str()).expect("ph");
        assert!(e.get("name").and_then(|n| n.as_str()).is_some());
        assert!(e.get("ts").and_then(|t| t.as_f64()).is_some());
        assert!(e.get("pid").and_then(|p| p.as_f64()).is_some());
        match ph {
            "X" => {
                complete += 1;
                assert!(e.get("dur").and_then(|d| d.as_f64()).unwrap() >= 0.0);
            }
            "i" => instants += 1,
            other => panic!("unexpected phase {other}"),
        }
    }
    assert_eq!(complete, tr.spans.len());
    assert_eq!(instants, tr.events.len());
}

#[test]
fn session_trace_carries_plan_cache_hit_and_rolls_up() {
    // end-to-end through the Session: tracing on, same graph twice — the
    // second run replays the cached plan and its trace records that as an
    // instant event; the timing breakdown sees the trace's io rollup.
    let mut sess =
        Session::new(SessionConfig::real_small(2, 2).with_stealing(false).with_tracing(true));
    let x = sess.randn(&[64, 64], &[2, 2]);
    let y = sess.randn(&[64, 64], &[2, 2]);
    let (_, rep1) = ops::add(&mut sess, &x, &y).unwrap();
    let tr1 = rep1.trace().expect("tracing on");
    assert!(!tr1.spans.is_empty());
    assert!(
        !tr1.events.iter().any(|e| e.kind == EventKind::PlanCacheHit),
        "first run is a cache miss"
    );

    let (_, rep2) = ops::add(&mut sess, &x, &y).unwrap();
    assert!(rep2.plan_cache_hit, "identical graph must hit the plan cache");
    let tr2 = rep2.trace().expect("tracing on");
    assert!(
        tr2.events.iter().any(|e| e.kind == EventKind::PlanCacheHit),
        "cache hit must appear in the event stream"
    );
    let b = nums::bench::timing_breakdown(&rep2);
    assert!(b.plan_cache_hit);
    assert_eq!(b.exec_secs, rep2.real.as_ref().unwrap().wall_secs);

    // tracing off: the same session API yields no trace at all
    let mut off = Session::new(SessionConfig::real_small(2, 2));
    let a = off.randn(&[32, 32], &[2, 2]);
    let bb = off.randn(&[32, 32], &[2, 2]);
    let (_, rep) = ops::add(&mut off, &a, &bb).unwrap();
    assert!(rep.trace().is_none());
}
