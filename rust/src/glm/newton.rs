//! Distributed Newton's method for logistic regression (Algorithm 2, §6).
//!
//! Each iteration is two scheduled graphs:
//! 1. the fused per-block `newton_block` tasks + locality-paired Reduce
//!    trees producing g, H and the loss (all landing on node N₀,₀ by the
//!    hierarchical layout, exactly the §6 walk-through), and
//! 2. the update `β ← β − H⁻¹g` as a `SolveSpd` + `Sub` pinned to N₀,₀.
//!
//! In real mode the driver additionally fetches the scalar loss and ‖g‖
//! for the convergence test; in sim mode a fixed step count runs entirely
//! on modeled time.

use anyhow::Result;

use crate::api::{ExecMode, RunReport, Session};
use crate::graph::{build, DistArray, Graph};
use crate::runtime::kernel::{BinOp, Kernel};

pub struct NewtonResult {
    pub beta: DistArray,
    /// Loss per iteration (real mode only; empty in sim mode).
    pub losses: Vec<f64>,
    pub grad_norms: Vec<f64>,
    pub iters: usize,
    pub reports: Vec<RunReport>,
}

/// Total modeled seconds across all iterations.
impl NewtonResult {
    pub fn sim_secs(&self) -> f64 {
        self.reports.iter().map(|r| r.sim.makespan).sum()
    }

    pub fn transfer_bytes(&self) -> u64 {
        self.reports.iter().map(|r| r.transfer_bytes).sum()
    }
}

/// Fit logistic regression with Newton's method.
pub fn newton_fit(
    sess: &mut Session,
    x: &DistArray,
    y: &DistArray,
    steps: usize,
    tol: f64,
) -> Result<NewtonResult> {
    let d = x.grid.shape[1];
    let mut beta = sess.zeros(&[d, 1], &[1, 1]);
    let mut losses = Vec::new();
    let mut grad_norms = Vec::new();
    let mut reports = Vec::new();
    let mut iters = 0;

    for _ in 0..steps {
        iters += 1;
        // graph 1: fused block contributions + reduce trees
        let mut g = Graph::new();
        build::glm_newton(&mut g, x, y, &beta);
        let (outs, rep) = sess.run(&mut g)?;
        reports.push(rep);
        let (grad, hess, loss) = (&outs[0], &outs[1], &outs[2]);

        if sess.cfg.exec == ExecMode::Real {
            losses.push(sess.fetch_scalar(loss)?);
            let gb = sess.fetch(grad)?;
            let norm: f64 = gb.buf().iter().map(|v| v * v).sum::<f64>().sqrt();
            grad_norms.push(norm);
            if norm <= tol {
                // still produce the final beta update? Algorithm 2 returns
                // beta *before* the update when converged.
                break;
            }
        }

        // graph 2: β ← β − H⁻¹ g on node N00
        let mut g2 = Graph::new();
        let lh = g2.leaf(hess.single_obj(), &[d, d]);
        let lg = g2.leaf(grad.single_obj(), &[d, 1]);
        let lb = g2.leaf(beta.single_obj(), &[d, 1]);
        let dir = g2.op(Kernel::SolveSpd, vec![(lh, 0), (lg, 0)]);
        let upd = g2.op(Kernel::Ew(BinOp::Sub), vec![(lb, 0), (dir, 0)]);
        g2.add_output(
            crate::grid::ArrayGrid::new(&[d, 1], &[1, 1]),
            vec![(upd, 0)],
        );
        let (outs2, rep2) = sess.run(&mut g2)?;
        reports.push(rep2);
        beta = outs2.into_iter().next().unwrap();
    }

    Ok(NewtonResult {
        beta,
        losses,
        grad_norms,
        iters,
        reports,
    })
}

/// Accuracy of β on (X, y): fraction of rows with thresholded μ == y.
pub fn accuracy(sess: &mut Session, x: &DistArray, y: &DistArray, beta: &DistArray) -> Result<f64> {
    let mut g = Graph::new();
    build::glm_predict(&mut g, x, beta);
    let (outs, _) = sess.run(&mut g)?;
    let mu = sess.fetch(&outs[0])?;
    let yy = sess.fetch(y)?;
    let n = mu.elems() as usize;
    let correct = mu
        .buf()
        .iter()
        .zip(yy.buf())
        .filter(|(&m, &t)| ((m > 0.5) as u8 as f64) == t)
        .count();
    Ok(correct as f64 / n as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::SessionConfig;
    use crate::glm::data::classification_data;

    #[test]
    fn newton_converges_on_separable_data() {
        let mut sess = Session::new(SessionConfig::real_small(2, 2));
        let (x, y) = classification_data(&mut sess, 512, 4, 4, 11);
        let res = newton_fit(&mut sess, &x, &y, 10, 1e-8).unwrap();
        assert!(res.losses.len() >= 2);
        assert!(
            res.losses.last().unwrap() < &(res.losses[0] * 0.1),
            "loss curve {:?}",
            res.losses
        );
        let acc = accuracy(&mut sess, &x, &y, &res.beta).unwrap();
        assert!(acc > 0.97, "accuracy {acc}");
    }

    #[test]
    fn sim_mode_runs_fixed_steps() {
        let mut sess = Session::new(SessionConfig::paper_sim(4, 4));
        let (x, y) = classification_data(&mut sess, 1 << 14, 16, 8, 3);
        let res = newton_fit(&mut sess, &x, &y, 3, 0.0).unwrap();
        assert_eq!(res.iters, 3);
        assert!(res.sim_secs() > 0.0);
        assert!(res.losses.is_empty()); // no fetch in sim mode
    }
}
