//! Single-node serial Newton baseline (the "NumPy/scikit-learn stack" of
//! §8.6, Fig. 16 / Table 3).
//!
//! Runs Algorithm 2 on dense host blocks with the same native kernels the
//! distributed workers use, but on one thread with no partitioning, no
//! scheduler, and no RFC overhead. At small data this wins (the paper's
//! "5× slower at small scales" side of Fig. 16); at large data the
//! distributed version's parallelism dominates.

use anyhow::Result;

use crate::linalg::dense;
use crate::runtime::{native, Kernel};
use crate::store::Block;
use crate::util::Stopwatch;

pub struct SerialResult {
    pub beta: Block,
    pub losses: Vec<f64>,
    pub iters: usize,
    pub wall_secs: f64,
}

/// Dense Newton fit on a single node.
pub fn newton_fit_serial(x: &Block, y: &Block, steps: usize, tol: f64) -> Result<SerialResult> {
    let sw = Stopwatch::start();
    let d = x.cols();
    let mut beta = Block::zeros(&[d, 1]);
    let mut losses = Vec::new();
    let mut iters = 0;
    for _ in 0..steps {
        iters += 1;
        let outs = native::execute(&Kernel::NewtonBlock, &[x, y, &beta])?;
        let (g, h, loss) = (&outs[0], &outs[1], &outs[2]);
        losses.push(loss.buf()[0]);
        let gnorm: f64 = g.buf().iter().map(|v| v * v).sum::<f64>().sqrt();
        if gnorm <= tol {
            break;
        }
        let dir = dense::solve_spd(h, g, 1e-10);
        for i in 0..d {
            let v = beta.at2(i, 0) - dir.at2(i, 0);
            beta.set2(i, 0, v);
        }
    }
    Ok(SerialResult {
        beta,
        losses,
        iters,
        wall_secs: sw.secs(),
    })
}

/// Serial prediction accuracy.
pub fn accuracy_serial(x: &Block, y: &Block, beta: &Block) -> Result<f64> {
    let mu = native::execute(&Kernel::GlmMu, &[x, beta])?.remove(0);
    let n = mu.elems() as usize;
    let correct = mu
        .buf()
        .iter()
        .zip(y.buf())
        .filter(|(&m, &t)| ((m > 0.5) as u8 as f64) == t)
        .count();
    Ok(correct as f64 / n as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::glm::data::classification_dense;

    #[test]
    fn serial_newton_converges() {
        let (x, y) = classification_dense(512, 4, 77);
        let res = newton_fit_serial(&x, &y, 10, 1e-8).unwrap();
        assert!(res.losses.last().unwrap() < &(res.losses[0] * 0.1));
        assert!(accuracy_serial(&x, &y, &res.beta).unwrap() > 0.97);
    }

    #[test]
    fn serial_matches_distributed_math() {
        use crate::api::{Session, SessionConfig};
        use crate::glm::data::classification_data;
        use crate::glm::newton::newton_fit;
        let (xd, yd) = classification_dense(256, 4, 13);
        let serial = newton_fit_serial(&xd, &yd, 4, 0.0).unwrap();

        let mut sess = Session::new(SessionConfig::real_small(2, 2));
        let (x, y) = classification_data(&mut sess, 256, 4, 4, 13);
        let dist = newton_fit(&mut sess, &x, &y, 4, 0.0).unwrap();
        let beta_dist = sess.fetch(&dist.beta).unwrap();
        assert!(
            serial.beta.max_abs_diff(&beta_dist) < 1e-8,
            "serial vs distributed beta"
        );
    }
}
