//! Generalized linear models (§6, §8.5, §8.6): distributed Newton and
//! L-BFGS for logistic regression, the Dask-ML-style driver-aggregation
//! baseline, the serial single-node baseline, and the synthetic bimodal
//! Gaussian data generator.

pub mod data;
pub mod driver_agg;
pub mod lbfgs;
pub mod newton;
pub mod serial;

pub use data::classification_data;
pub use driver_agg::newton_fit_driver_agg;
pub use lbfgs::lbfgs_fit;
pub use newton::{accuracy, newton_fit};
pub use serial::newton_fit_serial;
