//! Dask-ML-style Newton baseline: driver-side aggregation (§8.5).
//!
//! The paper attributes most of the Fig. 14a gap to Dask ML "aggregating
//! gradient and hessian computations on the driver process". This baseline
//! reproduces that implementation shape: per iteration the per-block
//! (g_i, H_i, loss_i) contributions are *not* tree-reduced on the cluster —
//! every block's partials are pulled to the driver (node 0) and summed
//! there as a serial chain. The modeled cost difference vs. `newton_fit`
//! is therefore O(q) driver-bound transfers + serial adds instead of a
//! log-depth locality-paired reduction.

use anyhow::Result;

use crate::api::{ExecMode, RunReport, Session};
use crate::graph::{build::reduce_chain_pinned, DistArray, Graph};
use crate::runtime::kernel::{BinOp, Kernel};

pub struct DriverAggResult {
    pub beta: DistArray,
    pub losses: Vec<f64>,
    pub iters: usize,
    pub reports: Vec<RunReport>,
}

impl DriverAggResult {
    pub fn sim_secs(&self) -> f64 {
        self.reports.iter().map(|r| r.sim.makespan).sum()
    }

    pub fn transfer_bytes(&self) -> u64 {
        self.reports.iter().map(|r| r.transfer_bytes).sum()
    }
}

/// Newton with driver-side aggregation of g/H/loss and *unfused* per-op
/// block pipelines (Dask ML composes dask-array ops, so every intermediate
/// — μ, μ−y, μ(1−μ), the dataset-sized weighted matrix w⊙X — is
/// materialized as its own task output).
pub fn newton_fit_driver_agg(
    sess: &mut Session,
    x: &DistArray,
    y: &DistArray,
    steps: usize,
) -> Result<DriverAggResult> {
    // Dask ML has no operator-fusion pass, so this baseline pins the
    // session's fusion off for its runs: every intermediate stays its own
    // task/block, exactly the implementation shape §8.5 attributes the
    // gap to. Restored on exit so the session can keep serving fused work.
    let prev = std::mem::replace(&mut sess.cfg.fusion, false);
    let out = driver_agg_inner(sess, x, y, steps);
    sess.cfg.fusion = prev;
    out
}

fn driver_agg_inner(
    sess: &mut Session,
    x: &DistArray,
    y: &DistArray,
    steps: usize,
) -> Result<DriverAggResult> {
    let d = x.grid.shape[1];
    let n = x.grid.shape[0];
    let q = x.grid.grid[0];
    let driver = 0usize;
    let mut beta = sess.zeros(&[d, 1], &[1, 1]);
    let ones = sess.ones(&[n, 1], &[q, 1]);
    let mut losses = Vec::new();
    let mut reports = Vec::new();
    let mut iters = 0;

    for _ in 0..steps {
        iters += 1;
        let mut g = Graph::new();
        // unfused per-block pipeline, aggregated ON THE DRIVER
        let beta_shape = beta.grid.block_shape(&[0, 0]);
        let mut g_terms = Vec::with_capacity(q);
        let mut h_terms = Vec::with_capacity(q);
        let mut l_terms = Vec::with_capacity(q);
        for i in 0..q {
            let xs = x.grid.block_shape(&[i, 0]);
            let ys = y.grid.block_shape(&[i, 0]);
            let lx = g.leaf(x.obj_at(&[i, 0]), &xs);
            let ly = g.leaf(y.obj_at(&[i, 0]), &ys);
            let lone = g.leaf(ones.obj_at(&[i, 0]), &ys);
            let lb = g.leaf(beta.single_obj(), &beta_shape);
            let mu = g.op(Kernel::GlmMu, vec![(lx, 0), (lb, 0)]);
            let c = g.op(Kernel::Ew(BinOp::Sub), vec![(mu, 0), (ly, 0)]);
            let w1 = g.op(Kernel::Ew(BinOp::Sub), vec![(lone, 0), (mu, 0)]);
            let w = g.op(Kernel::Ew(BinOp::Mul), vec![(mu, 0), (w1, 0)]);
            let wx = g.op(Kernel::ColScale, vec![(lx, 0), (w, 0)]); // materialized [m,d]
            let hi = g.op(Kernel::Gram, vec![(lx, 0), (wx, 0)]);
            let gi = g.op(Kernel::Gram, vec![(lx, 0), (c, 0)]);
            let li = g.op(Kernel::LogLoss, vec![(mu, 0), (ly, 0)]);
            g_terms.push((gi, 0));
            h_terms.push((hi, 0));
            l_terms.push((li, 0));
        }
        let gr = reduce_chain_pinned(&mut g, g_terms, driver);
        let hr = reduce_chain_pinned(&mut g, h_terms, driver);
        let lr = reduce_chain_pinned(&mut g, l_terms, driver);
        let gid = g.add_output(crate::grid::ArrayGrid::new(&[d, 1], &[1, 1]), vec![gr]);
        let hid = g.add_output(crate::grid::ArrayGrid::new(&[d, d], &[1, 1]), vec![hr]);
        let lid = g.add_output(crate::grid::ArrayGrid::new(&[1, 1], &[1, 1]), vec![lr]);

        let (outs, rep) = sess.run(&mut g)?;
        reports.push(rep);
        let (grad, hess, loss) = (&outs[gid], &outs[hid], &outs[lid]);
        if sess.cfg.exec == ExecMode::Real {
            losses.push(sess.fetch_scalar(loss)?);
        }

        // update on the driver
        let mut g2 = Graph::new();
        let lh = g2.leaf(hess.single_obj(), &[d, d]);
        let lg = g2.leaf(grad.single_obj(), &[d, 1]);
        let lb = g2.leaf(beta.single_obj(), &[d, 1]);
        let dir = g2.op(Kernel::SolveSpd, vec![(lh, 0), (lg, 0)]);
        let upd = g2.op(Kernel::Ew(BinOp::Sub), vec![(lb, 0), (dir, 0)]);
        g2.set_constraint(dir, driver);
        g2.set_constraint(upd, driver);
        g2.add_output(crate::grid::ArrayGrid::new(&[d, 1], &[1, 1]), vec![(upd, 0)]);
        let (outs2, rep2) = sess.run(&mut g2)?;
        reports.push(rep2);
        beta = outs2.into_iter().next().unwrap();
    }
    Ok(DriverAggResult {
        beta,
        losses,
        iters,
        reports,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::SessionConfig;
    use crate::glm::data::classification_data;
    use crate::glm::newton::newton_fit;

    #[test]
    fn converges_like_newton_but_moves_more() {
        let mut s1 = Session::new(SessionConfig::real_small(4, 2));
        let (x1, y1) = classification_data(&mut s1, 512, 4, 8, 31);
        let base = newton_fit(&mut s1, &x1, &y1, 5, 0.0).unwrap();

        let mut s2 = Session::new(SessionConfig::real_small(4, 2));
        let (x2, y2) = classification_data(&mut s2, 512, 4, 8, 31);
        let agg = newton_fit_driver_agg(&mut s2, &x2, &y2, 5).unwrap();

        // identical math
        let b1 = s1.fetch(&base.beta).unwrap();
        let b2 = s2.fetch(&agg.beta).unwrap();
        assert!(b1.max_abs_diff(&b2) < 1e-8, "betas diverge");
        // strictly more traffic (everything funnels through the driver)
        assert!(
            agg.transfer_bytes() > base.transfer_bytes(),
            "driver-agg {} vs lshs {}",
            agg.transfer_bytes(),
            base.transfer_bytes()
        );
    }

    #[test]
    fn baseline_keeps_unfused_task_structure() {
        // the baseline must pin fusion off during its runs (Dask ML has no
        // fusion pass) and restore the session flag afterwards
        let mut s = Session::new(SessionConfig::real_small(2, 2));
        assert!(s.cfg.fusion);
        let (x, y) = classification_data(&mut s, 128, 4, 4, 5);
        let agg = newton_fit_driver_agg(&mut s, &x, &y, 1).unwrap();
        assert!(s.cfg.fusion, "fusion flag must be restored");
        assert_eq!(
            agg.reports.iter().map(|r| r.fused_ops).sum::<usize>(),
            0,
            "no op of the Dask-ML baseline may be fused away"
        );
    }
}
