//! Distributed L-BFGS for logistic regression (§8.5, the Spark MLlib
//! comparison).
//!
//! The per-iteration cluster work is one `lbfgs_block` graph (fused
//! gradient + loss per block, tree-aggregated). The two-loop recursion and
//! the backtracking Armijo line search run on the driver over the fetched
//! d-vector — exactly how Breeze/Spark structure it (model state on the
//! driver, data-parallel gradient on the cluster). History length and the
//! line-search discipline match the paper's setup (history 10).

use anyhow::Result;

use crate::api::{ExecMode, RunReport, Session};
use crate::graph::{build, DistArray, Graph};
use crate::store::Block;

pub struct LbfgsResult {
    pub beta: Block,
    pub losses: Vec<f64>,
    pub iters: usize,
    pub reports: Vec<RunReport>,
    /// Cluster graphs executed (gradient evaluations incl. line search).
    pub grad_evals: usize,
}

impl LbfgsResult {
    pub fn sim_secs(&self) -> f64 {
        self.reports.iter().map(|r| r.sim.makespan).sum()
    }
}

/// One distributed (gradient, loss) evaluation at `beta`.
fn eval(
    sess: &mut Session,
    x: &DistArray,
    y: &DistArray,
    beta: &Block,
    reports: &mut Vec<RunReport>,
) -> Result<(Vec<f64>, f64)> {
    let d = beta.rows();
    let beta_arr = sess.scatter2(beta, &[1, 1]);
    let mut g = Graph::new();
    build::glm_lbfgs(&mut g, x, y, &beta_arr);
    let (outs, rep) = sess.run(&mut g)?;
    reports.push(rep);
    if sess.cfg.exec == ExecMode::Real {
        let grad = sess.fetch(&outs[0])?;
        let loss = sess.fetch_scalar(&outs[1])?;
        Ok((grad.buf().to_vec(), loss))
    } else {
        // sim mode: modeled time only; drive the math with a surrogate
        Ok((vec![0.0; d], 0.0))
    }
}

fn dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Fit with L-BFGS (history `m`), `steps` outer iterations.
pub fn lbfgs_fit(
    sess: &mut Session,
    x: &DistArray,
    y: &DistArray,
    steps: usize,
    m: usize,
    tol: f64,
) -> Result<LbfgsResult> {
    let d = x.grid.shape[1];
    let mut beta = vec![0.0; d];
    let mut reports = Vec::new();
    let mut losses = Vec::new();
    let mut s_hist: Vec<Vec<f64>> = Vec::new();
    let mut y_hist: Vec<Vec<f64>> = Vec::new();
    let mut grad_evals = 0;

    let (mut grad, mut loss) = eval(
        sess,
        x,
        y,
        &Block::from_vec(&[d, 1], beta.clone()),
        &mut reports,
    )?;
    grad_evals += 1;
    let sim_only = sess.cfg.exec != ExecMode::Real;
    let mut iters = 0;
    for _ in 0..steps {
        iters += 1;
        losses.push(loss);
        let gnorm = dot(&grad, &grad).sqrt();
        if !sim_only && gnorm <= tol {
            break;
        }
        // two-loop recursion
        let mut q = grad.clone();
        let mut alphas = Vec::with_capacity(s_hist.len());
        for i in (0..s_hist.len()).rev() {
            let rho = 1.0 / dot(&y_hist[i], &s_hist[i]).max(1e-300);
            let a = rho * dot(&s_hist[i], &q);
            for (qj, yj) in q.iter_mut().zip(&y_hist[i]) {
                *qj -= a * yj;
            }
            alphas.push((i, a, rho));
        }
        // initial Hessian scaling γ = sᵀy / yᵀy
        let scale = if let (Some(s), Some(yv)) = (s_hist.last(), y_hist.last()) {
            dot(s, yv) / dot(yv, yv).max(1e-300)
        } else {
            1.0
        };
        for qj in q.iter_mut() {
            *qj *= scale;
        }
        for &(i, a, rho) in alphas.iter().rev() {
            let b = rho * dot(&y_hist[i], &q);
            for (qj, sj) in q.iter_mut().zip(&s_hist[i]) {
                *qj += (a - b) * sj;
            }
        }
        let dir: Vec<f64> = q.iter().map(|v| -v).collect();

        // backtracking Armijo line search (each trial = one cluster eval)
        let g_dot_d = dot(&grad, &dir);
        let mut step = 1.0;
        let c1 = 1e-4;
        let mut accepted = false;
        for _ in 0..(if sim_only { 1 } else { 8 }) {
            let trial: Vec<f64> = beta
                .iter()
                .zip(&dir)
                .map(|(b, dd)| b + step * dd)
                .collect();
            let (g_new, l_new) = eval(
                sess,
                x,
                y,
                &Block::from_vec(&[d, 1], trial.clone()),
                &mut reports,
            )?;
            grad_evals += 1;
            if sim_only || l_new <= loss + c1 * step * g_dot_d {
                // accept: update history
                let s_vec: Vec<f64> = trial.iter().zip(&beta).map(|(a, b)| a - b).collect();
                let y_vec: Vec<f64> = g_new.iter().zip(&grad).map(|(a, b)| a - b).collect();
                if sim_only || dot(&s_vec, &y_vec) > 1e-12 {
                    s_hist.push(s_vec);
                    y_hist.push(y_vec);
                    if s_hist.len() > m {
                        s_hist.remove(0);
                        y_hist.remove(0);
                    }
                }
                beta = trial;
                grad = g_new;
                loss = l_new;
                accepted = true;
                break;
            }
            step *= 0.5;
        }
        if !accepted {
            break; // line search failed: stationary enough
        }
    }
    Ok(LbfgsResult {
        beta: Block::from_vec(&[d, 1], beta),
        losses,
        iters,
        reports,
        grad_evals,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::SessionConfig;
    use crate::glm::data::classification_data;

    #[test]
    fn lbfgs_decreases_loss() {
        let mut sess = Session::new(SessionConfig::real_small(2, 2));
        let (x, y) = classification_data(&mut sess, 512, 4, 4, 21);
        let res = lbfgs_fit(&mut sess, &x, &y, 10, 10, 1e-9).unwrap();
        // strongly separable data: one or two steps may suffice
        assert!(res.losses.len() >= 2, "{:?}", res.losses);
        assert!(
            res.losses.last().unwrap() < &(res.losses[0] * 0.5),
            "{:?}",
            res.losses
        );
        assert!(res.grad_evals >= res.iters);
    }

    #[test]
    fn lbfgs_sim_mode_counts_work() {
        let mut sess = Session::new(SessionConfig::paper_sim(4, 4));
        let (x, y) = classification_data(&mut sess, 1 << 13, 8, 8, 2);
        let res = lbfgs_fit(&mut sess, &x, &y, 5, 10, 0.0).unwrap();
        assert_eq!(res.iters, 5);
        assert!(res.sim_secs() > 0.0);
    }
}
