//! Synthetic classification data (§8.5).
//!
//! The paper's logistic-regression benchmarks draw from a bimodal
//! Gaussian: 75% negatives at mean 10 (var 2), 25% positives at mean 30
//! (var 4), 256-dimensional. Rows are generated *position-deterministically*
//! (value = f(seed, global row, col)) so that the X and y arrays agree on
//! class labels regardless of block partitioning or scheduling policy, and
//! so any two sessions with the same seed see identical data.
//!
//! Features are standardized with the mixture's analytic moments
//! (mean 15, std √77.5) to keep Newton well-conditioned — mirroring
//! `python/tests/test_model.py`.

use crate::api::Session;
use crate::graph::DistArray;
use crate::grid::ArrayGrid;
use crate::util::rng::Rng;

pub const NEG_MEAN: f64 = 10.0;
pub const NEG_STD: f64 = std::f64::consts::SQRT_2; // var 2
pub const POS_MEAN: f64 = 30.0;
pub const POS_STD: f64 = 2.0; // var 4
pub const POS_FRAC: f64 = 0.25;

/// Analytic mixture moments used for standardization.
pub const MIX_MEAN: f64 = 0.75 * NEG_MEAN + 0.25 * POS_MEAN; // 15
pub fn mix_std() -> f64 {
    let e2 = 0.75 * (NEG_STD * NEG_STD + NEG_MEAN * NEG_MEAN)
        + 0.25 * (POS_STD * POS_STD + POS_MEAN * POS_MEAN);
    (e2 - MIX_MEAN * MIX_MEAN).sqrt() // sqrt(77.5)
}

/// Class of global row `r` under `seed` (deterministic).
pub fn row_class(seed: u64, row: usize) -> bool {
    let mut rng = Rng::seed_from_u64(seed ^ (row as u64).wrapping_mul(0xA076_1D64_78BD_642F));
    rng.bool(POS_FRAC)
}

/// Feature value for (row, col).
pub fn feature(seed: u64, row: usize, col: usize) -> f64 {
    let pos = row_class(seed, row);
    let mut rng = Rng::seed_from_u64(
        seed ^ (row as u64).wrapping_mul(0xE703_7ED1_A0B4_28DB)
            ^ (col as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
    );
    let raw = if pos {
        rng.normal_ms(POS_MEAN, POS_STD)
    } else {
        rng.normal_ms(NEG_MEAN, NEG_STD)
    };
    (raw - MIX_MEAN) / mix_std()
}

/// Create the distributed design matrix X [n, d] (row-partitioned into
/// `q` blocks) and target y [n, 1].
pub fn classification_data(
    sess: &mut Session,
    n: usize,
    d: usize,
    q: usize,
    seed: u64,
) -> (DistArray, DistArray) {
    let xgrid = ArrayGrid::new(&[n, d], &[q, 1]);
    let xg = xgrid.clone();
    let x = sess.create_with(&[n, d], &[q, 1], move |_, bs, coords| {
        let r0 = xg.block_offset(0, coords[0]);
        let mut out = Vec::with_capacity(bs[0] * bs[1]);
        for i in 0..bs[0] {
            for j in 0..bs[1] {
                out.push(feature(seed, r0 + i, j));
            }
        }
        out
    });
    let yg = xgrid;
    let y = sess.create_with(&[n, 1], &[q, 1], move |_, bs, coords| {
        let r0 = yg.block_offset(0, coords[0]);
        (0..bs[0])
            .map(|i| if row_class(seed, r0 + i) { 1.0 } else { 0.0 })
            .collect()
    });
    (x, y)
}

/// Dense (single-block) version for the serial baselines (Fig. 16).
pub fn classification_dense(n: usize, d: usize, seed: u64) -> (crate::store::Block, crate::store::Block) {
    let mut xv = Vec::with_capacity(n * d);
    let mut yv = Vec::with_capacity(n);
    for r in 0..n {
        for c in 0..d {
            xv.push(feature(seed, r, c));
        }
        yv.push(if row_class(seed, r) { 1.0 } else { 0.0 });
    }
    (
        crate::store::Block::from_vec(&[n, d], xv),
        crate::store::Block::from_vec(&[n, 1], yv),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{ExecMode, SessionConfig};

    #[test]
    fn class_balance_roughly_quarter() {
        let pos = (0..10_000).filter(|&r| row_class(7, r)).count();
        let frac = pos as f64 / 10_000.0;
        assert!((frac - 0.25).abs() < 0.02, "{frac}");
    }

    #[test]
    fn features_standardized() {
        let n = 20_000;
        let mut sum = 0.0;
        let mut sq = 0.0;
        for r in 0..n {
            let v = feature(3, r, 0);
            sum += v;
            sq += v * v;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn partitioning_invariant() {
        // same seed, different block counts -> identical dense data
        let mut s1 = crate::api::Session::new(SessionConfig::real_small(2, 2));
        let mut s2 = crate::api::Session::new(SessionConfig::real_small(2, 2));
        let (x1, y1) = classification_data(&mut s1, 64, 4, 2, 99);
        let (x2, y2) = classification_data(&mut s2, 64, 4, 8, 99);
        assert_eq!(s1.cfg.exec, ExecMode::Real);
        let d1 = s1.fetch(&x1).unwrap();
        let d2 = s2.fetch(&x2).unwrap();
        assert!(d1.max_abs_diff(&d2) < 1e-15);
        let l1 = s1.fetch(&y1).unwrap();
        let l2 = s2.fetch(&y2).unwrap();
        assert!(l1.max_abs_diff(&l2) < 1e-15);
    }

    #[test]
    fn dense_matches_distributed() {
        let mut s = crate::api::Session::new(SessionConfig::real_small(2, 2));
        let (x, _) = classification_data(&mut s, 32, 3, 4, 5);
        let (xd, _) = classification_dense(32, 3, 5);
        assert!(s.fetch(&x).unwrap().max_abs_diff(&xd) < 1e-15);
    }
}
