//! Tensor algebra workloads (§8.4): MTTKRP and double contraction, plus
//! the 3-D sampling helpers the benchmarks use.

use crate::api::Session;
use crate::graph::DistArray;

/// Sample a random 3-D tensor X [i, j, k] over the given block grid.
pub fn random_tensor3(
    sess: &mut Session,
    shape: &[usize; 3],
    grid: &[usize; 3],
) -> DistArray {
    sess.randn(shape.as_slice(), grid.as_slice())
}

/// Sample a factor matrix [rows, f], row-partitioned into `g` blocks.
pub fn random_factor(sess: &mut Session, rows: usize, f: usize, g: usize) -> DistArray {
    sess.randn(&[rows, f], &[g, 1])
}

/// Dense MTTKRP reference: out[i,f] = Σ_{j,k} X[i,j,k] B[j,f] C[k,f].
pub fn mttkrp_dense(
    x: &crate::store::Block,
    b: &crate::store::Block,
    c: &crate::store::Block,
) -> crate::store::Block {
    let (i, j, k) = (x.shape[0], x.shape[1], x.shape[2]);
    let f = b.shape[1];
    let mut out = vec![0.0; i * f];
    let (xb, bb, cb) = (x.buf(), b.buf(), c.buf());
    for a in 0..i {
        for jj in 0..j {
            for kk in 0..k {
                let xv = xb[(a * j + jj) * k + kk];
                for ff in 0..f {
                    out[a * f + ff] += xv * bb[jj * f + ff] * cb[kk * f + ff];
                }
            }
        }
    }
    crate::store::Block::from_vec(&[i, f], out)
}

/// Dense double-contraction reference: out[i,f] = Σ_{j,k} X[i,j,k] Y[j,k,f].
pub fn tensordot_dense(
    x: &crate::store::Block,
    y: &crate::store::Block,
) -> crate::store::Block {
    let (i, j, k) = (x.shape[0], x.shape[1], x.shape[2]);
    let f = y.shape[2];
    let mut out = vec![0.0; i * f];
    let (xb, yb) = (x.buf(), y.buf());
    for a in 0..i {
        for jj in 0..j {
            for kk in 0..k {
                let xv = xb[(a * j + jj) * k + kk];
                for ff in 0..f {
                    out[a * f + ff] += xv * yb[(jj * k + kk) * f + ff];
                }
            }
        }
    }
    crate::store::Block::from_vec(&[i, f], out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{ops, SessionConfig};

    #[test]
    fn distributed_mttkrp_matches_dense() {
        let mut sess = Session::new(SessionConfig::real_small(2, 2));
        let x = random_tensor3(&mut sess, &[8, 6, 4], &[2, 2, 2]);
        let b = random_factor(&mut sess, 6, 5, 2);
        let c = random_factor(&mut sess, 4, 5, 2);
        let (out, _) = ops::mttkrp(&mut sess, &x, &b, &c).unwrap();
        let want = mttkrp_dense(
            &sess.fetch(&x).unwrap(),
            &sess.fetch(&b).unwrap(),
            &sess.fetch(&c).unwrap(),
        );
        assert!(sess.fetch(&out).unwrap().max_abs_diff(&want) < 1e-10);
    }

    #[test]
    fn naive_einsum_matches_fused_mttkrp() {
        use crate::graph::{build, Graph};
        let mut sess = Session::new(SessionConfig::real_small(2, 2));
        let x = random_tensor3(&mut sess, &[8, 6, 4], &[2, 2, 2]);
        let b = random_factor(&mut sess, 6, 5, 2);
        let c = random_factor(&mut sess, 4, 5, 2);
        let mut g = Graph::new();
        build::mttkrp_naive(&mut g, &x, &b, &c);
        let (outs, _) = sess.run(&mut g).unwrap();
        let want = mttkrp_dense(
            &sess.fetch(&x).unwrap(),
            &sess.fetch(&b).unwrap(),
            &sess.fetch(&c).unwrap(),
        );
        assert!(sess.fetch(&outs[0]).unwrap().max_abs_diff(&want) < 1e-10);
    }

    #[test]
    fn distributed_tensordot_matches_dense() {
        let mut sess = Session::new(SessionConfig::real_small(2, 2));
        let x = random_tensor3(&mut sess, &[6, 4, 4], &[2, 2, 1]);
        let y = random_tensor3(&mut sess, &[4, 4, 6], &[2, 1, 2]);
        let (out, _) = ops::tensordot(&mut sess, &x, &y).unwrap();
        let want = tensordot_dense(&sess.fetch(&x).unwrap(), &sess.fetch(&y).unwrap());
        assert!(sess.fetch(&out).unwrap().max_abs_diff(&want) < 1e-10);
    }
}
