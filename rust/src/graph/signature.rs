//! Canonical graph signatures — the plan-cache key.
//!
//! Iterative workloads (logreg §8.3, Newton, tensor factorization)
//! resubmit the *same* graph topology every iteration. Two runs can share
//! a cached plan iff their graphs are **plan-isomorphic**: the scheduler,
//! walking either graph, would face exactly the same sequence of decision
//! problems. [`signature`] condenses everything the scheduler can observe
//! into one 128-bit structural hash:
//!
//! * arena topology — vertex count, per-vertex kind, child `(vertex, out)`
//!   edges in arena order (builders are deterministic, so arena order *is*
//!   canonical order; fusion runs before signing and is itself
//!   deterministic);
//! * kernel identity — enum discriminant plus every numeric parameter's
//!   exact bits (`Scale(α)` vs `Scale(α')` must not collide);
//! * block shapes and placement constraints;
//! * the leaf-object *aliasing pattern* — raw [`ObjectId`]s never enter
//!   the hash (they differ every iteration); instead each distinct leaf
//!   object gets its first-occurrence index, so "same block used twice"
//!   hashes differently from "two distinct blocks";
//! * the placement vector of the graph's inputs — each distinct input's
//!   **primary** location (first entry of [`ClusterState::locations_of`],
//!   the producer) and size. Primaries never move in this system;
//!   *replica* lists deliberately stay out of the hash, because feedback
//!   and committed pulls widen them between iterations and would thrash
//!   the cache on exactly the repeated-topology runs it exists for. A
//!   replica-informed re-plan still happens — via the staleness threshold
//!   in [`crate::scheduler::plan_cache`], not via key churn;
//! * the output structure (grids and root refs).
//!
//! The hash is FNV-1a/128. With a 128-bit digest an accidental collision
//! is not a realistic event; this matters because a collision here would
//! replay a *wrong plan* (wrong kernels/shapes), not merely a suboptimal
//! placement. The willful-collision case (adversarial graphs) is out of
//! scope — the cache is per-session, fed only by this driver's own
//! builders.

use crate::scheduler::ClusterState;
use crate::store::ObjectId;

use super::graph::Graph;
use super::vertex::Vertex;

/// 128-bit FNV-1a accumulator. Also implements [`std::hash::Hasher`]
/// (folding `write` bytes into the same stream, `finish` = low 64 bits)
/// so `#[derive(Hash)]` types like [`crate::runtime::BinOp`] and enum
/// discriminants feed the same digest.
pub struct Fnv128 {
    h: u128,
}

impl Fnv128 {
    const OFFSET: u128 = 0x6c62272e07bb014262b821756295c58d;
    const PRIME: u128 = 0x0000000001000000000000000000013B;

    pub fn new() -> Self {
        Self { h: Self::OFFSET }
    }

    #[inline]
    fn byte(&mut self, b: u8) {
        self.h ^= b as u128;
        self.h = self.h.wrapping_mul(Self::PRIME);
    }

    pub fn u64(&mut self, v: u64) {
        for b in v.to_le_bytes() {
            self.byte(b);
        }
    }

    pub fn usize(&mut self, v: usize) {
        self.u64(v as u64);
    }

    /// Exact bits — `-0.0` vs `0.0` and NaN payloads all distinguish,
    /// which is the right call for a key that guards bit-identical replay.
    pub fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }

    /// Domain separator between hashed sections, so e.g. an empty shape
    /// list followed by `[2]` cannot collide with `[2]` followed by
    /// nothing.
    pub fn tag(&mut self, t: u8) {
        self.byte(t);
    }

    pub fn digest(&self) -> u128 {
        self.h
    }
}

impl Default for Fnv128 {
    fn default() -> Self {
        Self::new()
    }
}

impl std::hash::Hasher for Fnv128 {
    fn finish(&self) -> u64 {
        self.h as u64
    }

    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.byte(b);
        }
    }
}

/// Cache key: equal signature ⇒ plan-isomorphic graphs (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct GraphSignature(pub u128);

fn hash_shape(sig: &mut Fnv128, s: &[usize]) {
    sig.usize(s.len());
    for &d in s {
        sig.usize(d);
    }
}

fn hash_children(sig: &mut Fnv128, children: &[(usize, usize)]) {
    sig.usize(children.len());
    for &(vid, out) in children {
        sig.usize(vid);
        sig.usize(out);
    }
}

fn hash_constraint(sig: &mut Fnv128, c: &Option<usize>) {
    match c {
        Some(t) => {
            sig.tag(1);
            sig.usize(*t);
        }
        None => sig.tag(0),
    }
}

fn hash_ew_step(sig: &mut Fnv128, s: &crate::runtime::EwStep) {
    use crate::runtime::EwStep as E;
    use std::hash::Hash;
    std::mem::discriminant(s).hash(sig);
    match s {
        E::Scale(a) => sig.f64(*a),
        E::Bin(op) | E::BinRev(op) => op.hash(sig),
        E::Neg | E::Sigmoid => {}
    }
}

/// Kernel identity: discriminant + every numeric parameter's exact bits.
/// The match is exhaustive over the parameter-carrying variants *without*
/// a wildcard, so adding a parameterized kernel without extending this
/// function fails to compile instead of silently under-hashing (a false
/// cache hit here replays the wrong math, not just the wrong placement).
fn hash_kernel(sig: &mut Fnv128, k: &crate::runtime::Kernel) {
    use crate::runtime::Kernel as K;
    use std::hash::Hash;
    std::mem::discriminant(k).hash(sig);
    match k {
        K::Scale(a) | K::ScaledMatmul(a) | K::ScaledMatmulNT(a) | K::ScaledGram(a) => {
            sig.f64(*a)
        }
        K::Ew(op) => op.hash(sig),
        K::FusedEw(steps) => {
            sig.usize(steps.len());
            for s in steps {
                hash_ew_step(sig, s);
            }
        }
        K::Neg
        | K::Sigmoid
        | K::Matmul
        | K::MatmulNT
        | K::Gram
        | K::SumAxis0
        | K::SumAxis1
        | K::SumAll
        | K::GlmMu
        | K::GlmGrad
        | K::GlmHess
        | K::LogLoss
        | K::NewtonBlock
        | K::LbfgsBlock
        | K::PredictBlock
        | K::Qr
        | K::StackQr
        | K::SplitTop
        | K::SplitBottom
        | K::InvUpper
        | K::Cholesky
        | K::SolveSpd
        | K::Transpose
        | K::ColScale
        | K::MttkrpTerm
        | K::TensordotJK
        | K::EinsumXB
        | K::EinsumWC => {}
    }
}

/// Compute the canonical signature of a (post-fusion, pre-schedule) graph
/// against the current load model, plus the graph's **canonical input
/// list**: every distinct leaf object in first-occurrence arena order.
///
/// The input list is the rebinding contract: a cached plan stores task
/// inputs as indices into this list, and a later hit substitutes the
/// *new* graph's list positionally. Equal signatures make the positional
/// substitution sound — the aliasing pattern (which positions share an
/// object) is part of the hash.
pub fn signature(graph: &Graph, state: &ClusterState) -> (GraphSignature, Vec<ObjectId>) {
    let mut sig = Fnv128::new();
    let mut inputs: Vec<ObjectId> = Vec::new();
    let mut slot_of = |inputs: &mut Vec<ObjectId>, o: ObjectId| -> usize {
        match inputs.iter().position(|&x| x == o) {
            Some(i) => i,
            None => {
                inputs.push(o);
                inputs.len() - 1
            }
        }
    };

    sig.usize(graph.vertices.len());
    for v in &graph.vertices {
        match v {
            Vertex::Leaf { objs, shapes } => {
                sig.tag(0);
                sig.usize(objs.len());
                for (o, s) in objs.iter().zip(shapes) {
                    sig.usize(slot_of(&mut inputs, *o));
                    hash_shape(&mut sig, s);
                }
            }
            Vertex::Op {
                kernel,
                children,
                constraint,
            } => {
                sig.tag(1);
                hash_kernel(&mut sig, kernel);
                hash_children(&mut sig, children);
                hash_constraint(&mut sig, constraint);
            }
            Vertex::Reduce {
                op,
                children,
                constraint,
            } => {
                use std::hash::Hash;
                sig.tag(2);
                op.hash(&mut sig);
                hash_children(&mut sig, children);
                hash_constraint(&mut sig, constraint);
            }
        }
    }

    sig.tag(3);
    sig.usize(graph.outputs.len());
    for out in &graph.outputs {
        hash_shape(&mut sig, &out.grid.shape);
        hash_shape(&mut sig, &out.grid.grid);
        hash_children(&mut sig, &out.roots);
    }

    // placement vector: primary location + size of each distinct input
    sig.tag(4);
    sig.usize(inputs.len());
    for &o in &inputs {
        match state.locations_of(o).first() {
            Some(&t) => {
                sig.tag(1);
                sig.usize(t);
            }
            None => sig.tag(0),
        }
        sig.f64(state.size_of(o));
    }

    (GraphSignature(sig.digest()), inputs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::build;
    use crate::graph::DistArray;
    use crate::grid::ArrayGrid;
    use crate::net::model::SystemMode;
    use crate::runtime::BinOp;
    use crate::scheduler::Topology;
    use crate::store::IdGen;

    fn state(k: usize) -> ClusterState {
        ClusterState::new(Topology::new(k, 4, SystemMode::Ray))
    }

    fn array(
        st: &mut ClusterState,
        ids: &IdGen,
        shape: &[usize],
        grid: &[usize],
        target: usize,
    ) -> DistArray {
        let g = ArrayGrid::new(shape, grid);
        let blocks: Vec<u64> = (0..g.num_blocks()).map(|_| ids.next()).collect();
        for (f, c) in g.iter_coords().enumerate() {
            st.register(blocks[f], g.block_elems(&c) as f64, target);
        }
        let targets = vec![target; blocks.len()];
        DistArray::new(g, blocks, targets)
    }

    #[test]
    fn same_topology_fresh_ids_same_signature() {
        // the iteration-2 case: structurally identical graph, brand-new
        // ObjectIds, same primaries -> same key
        let make = |st: &mut ClusterState, ids: &IdGen| {
            let a = array(st, ids, &[64, 8], &[4, 1], 0);
            let b = array(st, ids, &[64, 8], &[4, 1], 0);
            let mut g = Graph::new();
            build::binary_ew(&mut g, &a, &b, BinOp::Add);
            signature(&g, st)
        };
        let mut st = state(2);
        let ids = IdGen::default();
        let (s1, in1) = make(&mut st, &ids);
        let (s2, in2) = make(&mut st, &ids);
        assert_eq!(s1, s2);
        assert_ne!(in1, in2, "ids differ even when the signature matches");
        assert_eq!(in1.len(), in2.len());
    }

    #[test]
    fn shape_grid_kernel_constraint_and_placement_all_distinguish() {
        let ids = IdGen::default();
        let base = |st: &mut ClusterState, ids: &IdGen| {
            let a = array(st, ids, &[64, 8], &[4, 1], 0);
            let b = array(st, ids, &[64, 8], &[4, 1], 0);
            (a, b)
        };

        let mut st = state(2);
        let (a, b) = base(&mut st, &ids);
        let mut g = Graph::new();
        build::binary_ew(&mut g, &a, &b, BinOp::Add);
        let (s_add, _) = signature(&g, &st);

        // different kernel
        let mut g2 = Graph::new();
        build::binary_ew(&mut g2, &a, &b, BinOp::Mul);
        let (s_mul, _) = signature(&g2, &st);
        assert_ne!(s_add, s_mul);

        // different block shape (same topology otherwise)
        let mut st3 = state(2);
        let a3 = array(&mut st3, &ids, &[128, 8], &[4, 1], 0);
        let b3 = array(&mut st3, &ids, &[128, 8], &[4, 1], 0);
        let mut g3 = Graph::new();
        build::binary_ew(&mut g3, &a3, &b3, BinOp::Add);
        assert_ne!(signature(&g3, &st3).0, s_add);

        // different grid (8 blocks instead of 4)
        let mut st4 = state(2);
        let a4 = array(&mut st4, &ids, &[64, 8], &[8, 1], 0);
        let b4 = array(&mut st4, &ids, &[64, 8], &[8, 1], 0);
        let mut g4 = Graph::new();
        build::binary_ew(&mut g4, &a4, &b4, BinOp::Add);
        assert_ne!(signature(&g4, &st4).0, s_add);

        // different input placement (primaries on node 1, not 0)
        let mut st5 = state(2);
        let a5 = array(&mut st5, &ids, &[64, 8], &[4, 1], 1);
        let b5 = array(&mut st5, &ids, &[64, 8], &[4, 1], 1);
        let mut g5 = Graph::new();
        build::binary_ew(&mut g5, &a5, &b5, BinOp::Add);
        assert_ne!(signature(&g5, &st5).0, s_add);

        // different constraint on the root op
        let mut g6 = Graph::new();
        build::binary_ew(&mut g6, &a, &b, BinOp::Add);
        for out in 0..g6.outputs.len() {
            let roots: Vec<_> = g6.outputs[out].roots.clone();
            for (vid, _) in roots {
                g6.set_constraint(vid, 1);
            }
        }
        assert_ne!(signature(&g6, &st).0, s_add);
    }

    #[test]
    fn aliasing_pattern_distinguishes() {
        // x+x and x+y are different plans even with identical shapes
        let ids = IdGen::default();
        let mut st = state(2);
        let a = array(&mut st, &ids, &[64, 8], &[4, 1], 0);
        let b = array(&mut st, &ids, &[64, 8], &[4, 1], 0);
        let mut gxx = Graph::new();
        build::binary_ew(&mut gxx, &a, &a, BinOp::Add);
        let mut gxy = Graph::new();
        build::binary_ew(&mut gxy, &a, &b, BinOp::Add);
        assert_ne!(signature(&gxx, &st).0, signature(&gxy, &st).0);
    }

    #[test]
    fn replica_growth_does_not_change_the_key() {
        // feedback/pulls add replicas between iterations; the key must
        // stay stable or iteration 2 would always miss
        let ids = IdGen::default();
        let mut st = state(2);
        let a = array(&mut st, &ids, &[64, 8], &[4, 1], 0);
        let b = array(&mut st, &ids, &[64, 8], &[4, 1], 0);
        let mut g = Graph::new();
        build::binary_ew(&mut g, &a, &b, BinOp::Add);
        let (before, _) = signature(&g, &st);
        for &obj in &a.blocks {
            st.add_replica(obj, 1);
        }
        let (after, _) = signature(&g, &st);
        assert_eq!(before, after);
    }

    #[test]
    fn scale_parameter_bits_distinguish() {
        use crate::runtime::Kernel;
        let mut h1 = Fnv128::new();
        hash_kernel(&mut h1, &Kernel::Scale(2.0));
        let mut h2 = Fnv128::new();
        hash_kernel(&mut h2, &Kernel::Scale(3.0));
        assert_ne!(h1.digest(), h2.digest());
        let mut h3 = Fnv128::new();
        hash_kernel(&mut h3, &Kernel::ScaledMatmul(2.0));
        assert_ne!(h1.digest(), h3.digest(), "variant tag separates kernels");
    }
}
