//! Induced-subgraph builders (Fig. 5): expand array-level operations over
//! [`DistArray`]s into block-level vertices in a [`Graph`].
//!
//! All contractions share one pattern — per output block, a set of product
//! terms over the contracted grid axes plus an n-ary `Reduce` — which is
//! the paper's "recursive" structure (§4, Algorithm 3). Lazy transposes
//! are fused here: `Xᵀ @ Y` lowers to `Gram` block kernels and `X @ Yᵀ` to
//! `MatmulNT`, never materializing a transposed block.

use crate::grid::ArrayGrid;
use crate::runtime::kernel::{BinOp, EwStep, Kernel};

use super::dist::DistArray;
use super::graph::Graph;
use super::vertex::Ref;

/// Element-wise unary operation (Fig. 5a): one op per block.
pub fn unary(g: &mut Graph, a: &DistArray, kernel: Kernel) -> usize {
    assert!(!a.transposed, "unary over transposed view: materialize first");
    assert_eq!(kernel.n_outputs(), 1);
    let roots: Vec<Ref> = a
        .grid
        .iter_coords()
        .map(|c| {
            let leaf = g.leaf(a.obj_at(&c), &a.grid.block_shape(&c));
            (g.op(kernel.clone(), vec![(leaf, 0)]), 0)
        })
        .collect();
    g.add_output(a.grid.clone(), roots)
}

/// Element-wise binary operation (Fig. 5b): grids must match block-for-block.
pub fn binary_ew(g: &mut Graph, a: &DistArray, b: &DistArray, op: BinOp) -> usize {
    assert!(!a.transposed && !b.transposed, "ew over transposed views");
    assert_eq!(a.grid, b.grid, "X+Y requires equal shape and grid (§4)");
    let roots: Vec<Ref> = a
        .grid
        .iter_coords()
        .map(|c| {
            let shape = a.grid.block_shape(&c);
            let la = g.leaf(a.obj_at(&c), &shape);
            let lb = g.leaf(b.obj_at(&c), &shape);
            (g.op(Kernel::Ew(op), vec![(la, 0), (lb, 0)]), 0)
        })
        .collect();
    g.add_output(a.grid.clone(), roots)
}

/// Element-wise expression chain over equal-grid operands: apply `steps`
/// in order starting from `first`, consuming one operand from `rest` per
/// binary step. Emits the *unfused* per-op graph — one vertex per step per
/// block — which `graph::fuse` collapses to one task per block when
/// `SessionConfig::fusion` is on; with fusion off the same builder is the
/// oracle for the ablation and the property suite.
pub fn ew_chain(g: &mut Graph, first: &DistArray, rest: &[&DistArray], steps: &[EwStep]) -> usize {
    assert!(!steps.is_empty(), "empty chain");
    assert!(!first.transposed, "chain over transposed view");
    let binary = steps.iter().filter(|s| s.consumes_input()).count();
    assert_eq!(binary, rest.len(), "one operand per binary step");
    for r in rest {
        assert!(!r.transposed);
        assert_eq!(first.grid, r.grid, "chain operands must share the grid (§4)");
    }
    let roots: Vec<Ref> = first
        .grid
        .iter_coords()
        .map(|c| {
            let shape = first.grid.block_shape(&c);
            let mut acc: Ref = (g.leaf(first.obj_at(&c), &shape), 0);
            let mut next = 0;
            for s in steps {
                acc = match *s {
                    EwStep::Neg => (g.op(Kernel::Neg, vec![acc]), 0),
                    EwStep::Sigmoid => (g.op(Kernel::Sigmoid, vec![acc]), 0),
                    EwStep::Scale(v) => (g.op(Kernel::Scale(v), vec![acc]), 0),
                    EwStep::Bin(op) => {
                        let l = g.leaf(rest[next].obj_at(&c), &shape);
                        next += 1;
                        (g.op(Kernel::Ew(op), vec![acc, (l, 0)]), 0)
                    }
                    EwStep::BinRev(op) => {
                        let l = g.leaf(rest[next].obj_at(&c), &shape);
                        next += 1;
                        (g.op(Kernel::Ew(op), vec![(l, 0), acc]), 0)
                    }
                };
            }
            acc
        })
        .collect();
    g.add_output(first.grid.clone(), roots)
}

/// sum(X, axis) for matrices (Fig. 5c): `ReduceAxis` per block, then a
/// `Reduce(add, ...)` tree along the reduced axis.
pub fn sum_axis(g: &mut Graph, a: &DistArray, axis: usize) -> usize {
    assert!(!a.transposed);
    assert_eq!(a.grid.ndim(), 2, "sum_axis builder is 2-D; see sum_all");
    assert!(axis < 2);
    let kernel = if axis == 0 { Kernel::SumAxis0 } else { Kernel::SumAxis1 };
    let out_grid = a.grid.reduce_axis(axis);
    let mut roots = Vec::with_capacity(out_grid.num_blocks());
    for oc in out_grid.iter_coords() {
        // all input blocks along `axis` contributing to this output block
        let terms: Vec<Ref> = (0..a.grid.grid[axis])
            .map(|b| {
                let mut ic = oc.clone();
                ic[axis] = b;
                let leaf = g.leaf(a.obj_at(&ic), &a.grid.block_shape(&ic));
                (g.op(kernel.clone(), vec![(leaf, 0)]), 0)
            })
            .collect();
        roots.push(reduce_or_single(g, terms));
    }
    g.add_output(out_grid, roots)
}

/// Full reduction sum(X) -> 1x1.
pub fn sum_all(g: &mut Graph, a: &DistArray) -> usize {
    assert!(!a.transposed);
    assert_eq!(a.grid.ndim(), 2);
    let terms: Vec<Ref> = a
        .grid
        .iter_coords()
        .map(|c| {
            let leaf = g.leaf(a.obj_at(&c), &a.grid.block_shape(&c));
            (g.op(Kernel::SumAll, vec![(leaf, 0)]), 0)
        })
        .collect();
    let root = reduce_or_single(g, terms);
    g.add_output(ArrayGrid::new(&[1, 1], &[1, 1]), vec![root])
}

/// Matrix multiplication with lazy-transpose fusion (Fig. 5e / §6):
/// * `A @ B`   -> per-output-block `Matmul` terms reduced over the inner grid
/// * `Aᵀ @ B`  -> `Gram` terms reduced over the (stored) row grid
/// * `A @ Bᵀ`  -> `MatmulNT` terms reduced over the (stored) column grid
pub fn matmul(g: &mut Graph, a: &DistArray, b: &DistArray) -> usize {
    assert_eq!(a.grid.ndim(), 2);
    assert_eq!(b.grid.ndim(), 2);
    match (a.transposed, b.transposed) {
        (false, false) => matmul_nn(g, a, b),
        (true, false) => matmul_tn(g, a, b),
        (false, true) => matmul_nt(g, a, b),
        (true, true) => panic!("Aᵀ @ Bᵀ unsupported: rewrite as (B @ A)ᵀ"),
    }
}

fn matmul_nn(g: &mut Graph, a: &DistArray, b: &DistArray) -> usize {
    assert_eq!(a.grid.shape[1], b.grid.shape[0], "A@B inner dims");
    assert_eq!(a.grid.grid[1], b.grid.grid[0], "A@B inner grids must match");
    let (gm, gk) = (a.grid.grid[0], a.grid.grid[1]);
    let gn = b.grid.grid[1];
    let out_grid = ArrayGrid::new(&[a.grid.shape[0], b.grid.shape[1]], &[gm, gn]);
    let mut roots = Vec::with_capacity(gm * gn);
    for i in 0..gm {
        for j in 0..gn {
            let terms: Vec<Ref> = (0..gk)
                .map(|h| {
                    let la = g.leaf(a.obj_at(&[i, h]), &a.grid.block_shape(&[i, h]));
                    let lb = g.leaf(b.obj_at(&[h, j]), &b.grid.block_shape(&[h, j]));
                    (g.op(Kernel::Matmul, vec![(la, 0), (lb, 0)]), 0)
                })
                .collect();
            roots.push(reduce_or_single(g, terms));
        }
    }
    g.add_output(out_grid, roots)
}

/// Aᵀ @ B with A stored `[q, m]` over grid (gq, gm): the block-wise inner
/// product (App. A.3) — the GLM Hessian/gradient hot-spot.
fn matmul_tn(g: &mut Graph, a: &DistArray, b: &DistArray) -> usize {
    assert_eq!(a.grid.shape[0], b.grid.shape[0], "Aᵀ@B contracted dims");
    assert_eq!(a.grid.grid[0], b.grid.grid[0], "Aᵀ@B row grids must match");
    let (gq, gm) = (a.grid.grid[0], a.grid.grid[1]);
    let gn = b.grid.grid[1];
    let out_grid = ArrayGrid::new(&[a.grid.shape[1], b.grid.shape[1]], &[gm, gn]);
    let mut roots = Vec::with_capacity(gm * gn);
    for i in 0..gm {
        for j in 0..gn {
            let terms: Vec<Ref> = (0..gq)
                .map(|q| {
                    let la = g.leaf(a.obj_at(&[q, i]), &a.grid.block_shape(&[q, i]));
                    let lb = g.leaf(b.obj_at(&[q, j]), &b.grid.block_shape(&[q, j]));
                    (g.op(Kernel::Gram, vec![(la, 0), (lb, 0)]), 0)
                })
                .collect();
            roots.push(reduce_or_single(g, terms));
        }
    }
    g.add_output(out_grid, roots)
}

/// A @ Bᵀ with B stored `[n, c]`: the block-wise outer product (App. A.4).
fn matmul_nt(g: &mut Graph, a: &DistArray, b: &DistArray) -> usize {
    assert_eq!(a.grid.shape[1], b.grid.shape[1], "A@Bᵀ contracted dims");
    assert_eq!(a.grid.grid[1], b.grid.grid[1], "A@Bᵀ column grids must match");
    let (gm, gc) = (a.grid.grid[0], a.grid.grid[1]);
    let gn = b.grid.grid[0];
    let out_grid = ArrayGrid::new(&[a.grid.shape[0], b.grid.shape[0]], &[gm, gn]);
    let mut roots = Vec::with_capacity(gm * gn);
    for i in 0..gm {
        for j in 0..gn {
            let terms: Vec<Ref> = (0..gc)
                .map(|c| {
                    let la = g.leaf(a.obj_at(&[i, c]), &a.grid.block_shape(&[i, c]));
                    let lb = g.leaf(b.obj_at(&[j, c]), &b.grid.block_shape(&[j, c]));
                    (g.op(Kernel::MatmulNT, vec![(la, 0), (lb, 0)]), 0)
                })
                .collect();
            roots.push(reduce_or_single(g, terms));
        }
    }
    g.add_output(out_grid, roots)
}

/// Fused Newton iteration (§6): one `newton_block` task per row block of X,
/// then Reduce trees for g, H and loss. Returns (g, H, loss) output ids.
pub fn glm_newton(
    g: &mut Graph,
    x: &DistArray,
    y: &DistArray,
    beta: &DistArray,
) -> (usize, usize, usize) {
    let (blocks, d) = glm_block_terms(g, x, y, Some(beta), Kernel::NewtonBlock);
    let grad_terms: Vec<Ref> = blocks.iter().map(|&v| (v, 0)).collect();
    let hess_terms: Vec<Ref> = blocks.iter().map(|&v| (v, 1)).collect();
    let loss_terms: Vec<Ref> = blocks.iter().map(|&v| (v, 2)).collect();
    let gr = reduce_or_single(g, grad_terms);
    let hr = reduce_or_single(g, hess_terms);
    let lr = reduce_or_single(g, loss_terms);
    let gid = g.add_output(ArrayGrid::new(&[d, 1], &[1, 1]), vec![gr]);
    let hid = g.add_output(ArrayGrid::new(&[d, d], &[1, 1]), vec![hr]);
    let lid = g.add_output(ArrayGrid::new(&[1, 1], &[1, 1]), vec![lr]);
    (gid, hid, lid)
}

/// Fused L-BFGS step inputs: (gradient, loss) per §8.5.
pub fn glm_lbfgs(g: &mut Graph, x: &DistArray, y: &DistArray, beta: &DistArray) -> (usize, usize) {
    let (blocks, d) = glm_block_terms(g, x, y, Some(beta), Kernel::LbfgsBlock);
    let grad_terms: Vec<Ref> = blocks.iter().map(|&v| (v, 0)).collect();
    let loss_terms: Vec<Ref> = blocks.iter().map(|&v| (v, 1)).collect();
    let gr = reduce_or_single(g, grad_terms);
    let lr = reduce_or_single(g, loss_terms);
    let gid = g.add_output(ArrayGrid::new(&[d, 1], &[1, 1]), vec![gr]);
    let lid = g.add_output(ArrayGrid::new(&[1, 1], &[1, 1]), vec![lr]);
    (gid, lid)
}

/// Per-block prediction mu = sigmoid(X beta): row-partitioned output.
pub fn glm_predict(g: &mut Graph, x: &DistArray, beta: &DistArray) -> usize {
    assert!(!x.transposed);
    let (gq, _) = (x.grid.grid[0], x.grid.grid[1]);
    assert_eq!(x.grid.grid[1], 1, "GLM X must be row-partitioned (q x 1)");
    let beta_shape = beta.grid.block_shape(&[0, 0]);
    let out_grid = ArrayGrid::new(&[x.grid.shape[0], 1], &[gq, 1]);
    let mut roots = Vec::with_capacity(gq);
    for i in 0..gq {
        let xs = x.grid.block_shape(&[i, 0]);
        let lx = g.leaf(x.obj_at(&[i, 0]), &xs);
        let lb = g.leaf(beta.single_obj(), &beta_shape);
        roots.push((g.op(Kernel::PredictBlock, vec![(lx, 0), (lb, 0)]), 0));
    }
    g.add_output(out_grid, roots)
}

fn glm_block_terms(
    g: &mut Graph,
    x: &DistArray,
    y: &DistArray,
    beta: Option<&DistArray>,
    kernel: Kernel,
) -> (Vec<usize>, usize) {
    assert!(!x.transposed && !y.transposed);
    assert_eq!(x.grid.grid[1], 1, "GLM X must be row-partitioned (q x 1)");
    assert_eq!(y.grid.grid[0], x.grid.grid[0], "y must partition like X rows");
    let d = x.grid.shape[1];
    let beta = beta.expect("beta required");
    let beta_shape = beta.grid.block_shape(&[0, 0]);
    let blocks: Vec<usize> = (0..x.grid.grid[0])
        .map(|i| {
            let xs = x.grid.block_shape(&[i, 0]);
            let ys = y.grid.block_shape(&[i, 0]);
            let lx = g.leaf(x.obj_at(&[i, 0]), &xs);
            let ly = g.leaf(y.obj_at(&[i, 0]), &ys);
            let lb = g.leaf(beta.single_obj(), &beta_shape);
            g.op(kernel.clone(), vec![(lx, 0), (ly, 0), (lb, 0)])
        })
        .collect();
    (blocks, d)
}

/// MTTKRP `einsum("ijk,jf,kf->if", X, B, C)` (§8.4): per output row-block,
/// product terms over the (j, k) grid plus a Reduce tree.
pub fn mttkrp(g: &mut Graph, x: &DistArray, bm: &DistArray, cm: &DistArray) -> usize {
    assert_eq!(x.grid.ndim(), 3);
    let (gi, gj, gk) = (x.grid.grid[0], x.grid.grid[1], x.grid.grid[2]);
    assert_eq!(bm.grid.grid[0], gj, "B row grid must match X's j grid");
    assert_eq!(cm.grid.grid[0], gk, "C row grid must match X's k grid");
    assert_eq!(bm.grid.grid[1], 1, "factor matrices are column-unpartitioned");
    assert_eq!(cm.grid.grid[1], 1);
    let f = bm.grid.shape[1];
    let out_grid = ArrayGrid::new(&[x.grid.shape[0], f], &[gi, 1]);
    let mut roots = Vec::with_capacity(gi);
    for i in 0..gi {
        let mut terms: Vec<Ref> = Vec::with_capacity(gj * gk);
        for j in 0..gj {
            for k in 0..gk {
                let xc = [i, j, k];
                let lx = g.leaf(x.obj_at(&xc), &x.grid.block_shape(&xc));
                let lb = g.leaf(bm.obj_at(&[j, 0]), &bm.grid.block_shape(&[j, 0]));
                let lc = g.leaf(cm.obj_at(&[k, 0]), &cm.grid.block_shape(&[k, 0]));
                terms.push((
                    g.op(Kernel::MttkrpTerm, vec![(lx, 0), (lb, 0), (lc, 0)]),
                    0,
                ));
            }
        }
        roots.push(reduce_or_single(g, terms));
    }
    g.add_output(out_grid, roots)
}

/// MTTKRP the way a pairwise-contracting einsum does it (the Dask-Arrays
/// behaviour of Fig. 13a): stage 1 materializes `W[i,k,f] = Σ_j X·B` — an
/// intermediate F× larger than the X slabs — then stage 2 contracts with
/// C. Used as the materializing baseline in `benches/fig13_tensor.rs`.
pub fn mttkrp_naive(g: &mut Graph, x: &DistArray, bm: &DistArray, cm: &DistArray) -> usize {
    assert_eq!(x.grid.ndim(), 3);
    let (gi, gj, gk) = (x.grid.grid[0], x.grid.grid[1], x.grid.grid[2]);
    assert_eq!(bm.grid.grid[0], gj);
    assert_eq!(cm.grid.grid[0], gk);
    let f = bm.grid.shape[1];
    let out_grid = ArrayGrid::new(&[x.grid.shape[0], f], &[gi, 1]);
    let mut roots = Vec::with_capacity(gi);
    for i in 0..gi {
        // stage 1: W[i][k] = Σ_j X[i,j,k] · B[j]   (materialized!)
        let mut w_refs: Vec<Ref> = Vec::with_capacity(gk);
        for k in 0..gk {
            let terms: Vec<Ref> = (0..gj)
                .map(|j| {
                    let xc = [i, j, k];
                    let lx = g.leaf(x.obj_at(&xc), &x.grid.block_shape(&xc));
                    let lb = g.leaf(bm.obj_at(&[j, 0]), &bm.grid.block_shape(&[j, 0]));
                    (g.op(Kernel::EinsumXB, vec![(lx, 0), (lb, 0)]), 0)
                })
                .collect();
            w_refs.push(reduce_or_single(g, terms));
        }
        // stage 2: out[i] = Σ_k W[i][k] · C[k]
        let terms: Vec<Ref> = w_refs
            .into_iter()
            .enumerate()
            .map(|(k, w)| {
                let lc = g.leaf(cm.obj_at(&[k, 0]), &cm.grid.block_shape(&[k, 0]));
                (g.op(Kernel::EinsumWC, vec![w, (lc, 0)]), 0)
            })
            .collect();
        roots.push(reduce_or_single(g, terms));
    }
    g.add_output(out_grid, roots)
}

/// Tensor double contraction `tensordot(X, Y, axes=2)` over (j, k) (§8.4).
pub fn tensordot_jk(g: &mut Graph, x: &DistArray, y: &DistArray) -> usize {
    assert_eq!(x.grid.ndim(), 3);
    assert_eq!(y.grid.ndim(), 3);
    let (gi, gj, gk) = (x.grid.grid[0], x.grid.grid[1], x.grid.grid[2]);
    assert_eq!(y.grid.grid[0], gj, "Y j-grid");
    assert_eq!(y.grid.grid[1], gk, "Y k-grid");
    let gf = y.grid.grid[2];
    let out_grid = ArrayGrid::new(&[x.grid.shape[0], y.grid.shape[2]], &[gi, gf]);
    let mut roots = Vec::with_capacity(gi * gf);
    for i in 0..gi {
        for fb in 0..gf {
            let mut terms: Vec<Ref> = Vec::with_capacity(gj * gk);
            for j in 0..gj {
                for k in 0..gk {
                    let xc = [i, j, k];
                    let yc = [j, k, fb];
                    let lx = g.leaf(x.obj_at(&xc), &x.grid.block_shape(&xc));
                    let ly = g.leaf(y.obj_at(&yc), &y.grid.block_shape(&yc));
                    terms.push((g.op(Kernel::TensordotJK, vec![(lx, 0), (ly, 0)]), 0));
                }
            }
            roots.push(reduce_or_single(g, terms));
        }
    }
    g.add_output(out_grid, roots)
}

/// Serial left-fold reduction pinned to one target — models driver-side
/// aggregation (the Dask-ML baseline of §8.5): every add runs on `target`
/// and every operand is pulled there, with no locality pairing.
pub fn reduce_chain_pinned(g: &mut Graph, terms: Vec<Ref>, target: usize) -> Ref {
    assert!(!terms.is_empty());
    let mut acc = terms[0];
    for &t in &terms[1..] {
        let v = g.op(Kernel::Ew(BinOp::Add), vec![acc, t]);
        g.set_constraint(v, target);
        acc = (v, 0);
    }
    acc
}

/// Wrap terms in a Reduce when there is more than one.
fn reduce_or_single(g: &mut Graph, terms: Vec<Ref>) -> Ref {
    assert!(!terms.is_empty());
    if terms.len() == 1 {
        terms[0]
    } else {
        (g.reduce(BinOp::Add, terms), 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grid::ArrayGrid;

    fn dist(shape: &[usize], grid: &[usize], first_obj: u64) -> DistArray {
        let g = ArrayGrid::new(shape, grid);
        let n = g.num_blocks();
        DistArray::new(
            g,
            (first_obj..first_obj + n as u64).collect(),
            vec![0; n],
        )
    }

    #[test]
    fn ew_graph_shape() {
        let a = dist(&[8, 8], &[2, 2], 0);
        let b = dist(&[8, 8], &[2, 2], 10);
        let mut g = Graph::new();
        let out = binary_ew(&mut g, &a, &b, BinOp::Add);
        assert_eq!(g.outputs[out].roots.len(), 4);
        assert_eq!(g.total_tasks(), 4);
        assert_eq!(g.frontier().len(), 4);
    }

    #[test]
    fn ew_chain_emits_one_vertex_per_step_per_block() {
        let a = dist(&[8, 8], &[2, 2], 0);
        let b = dist(&[8, 8], &[2, 2], 10);
        let mut g = Graph::new();
        let steps = [
            EwStep::Neg,
            EwStep::Bin(BinOp::Add),
            EwStep::Sigmoid,
        ];
        let out = ew_chain(&mut g, &a, &[&b], &steps);
        assert_eq!(g.outputs[out].roots.len(), 4);
        assert_eq!(g.total_tasks(), 4 * 3);
        // ... and the fusion pass collapses each block's chain to one task
        let st = crate::graph::fuse::fuse_elementwise(&mut g);
        assert_eq!(st.chains, 4);
        assert_eq!(st.absorbed, 4 * 2);
        assert_eq!(g.total_tasks(), 4);
    }

    #[test]
    #[should_panic(expected = "one operand per binary step")]
    fn ew_chain_checks_operand_count() {
        let a = dist(&[4, 4], &[1, 1], 0);
        let mut g = Graph::new();
        ew_chain(&mut g, &a, &[], &[EwStep::Bin(BinOp::Add)]);
    }

    #[test]
    fn matmul_graph_structure() {
        // 2x2 grids -> 4 output blocks, each = reduce of 2 matmuls (Fig. 6)
        let a = dist(&[8, 8], &[2, 2], 0);
        let b = dist(&[8, 8], &[2, 2], 10);
        let mut g = Graph::new();
        let out = matmul(&mut g, &a, &b);
        assert_eq!(g.outputs[out].roots.len(), 4);
        // 8 matmuls + 4 reduces of arity 2 = 8 + 4 tasks
        assert_eq!(g.total_tasks(), 12);
    }

    #[test]
    fn gram_fuses_transpose() {
        let x = dist(&[100, 4], &[4, 1], 0);
        let y = dist(&[100, 6], &[4, 1], 10);
        let mut g = Graph::new();
        let out = matmul(&mut g, &x.t(), &y);
        let oref = &g.outputs[out];
        assert_eq!(oref.grid.shape, vec![4, 6]);
        assert_eq!(oref.grid.num_blocks(), 1);
        // 4 gram ops + 3 reduce-adds
        assert_eq!(g.total_tasks(), 7);
    }

    #[test]
    fn outer_product_no_reduce_when_inner_unpartitioned() {
        let x = dist(&[8, 4], &[2, 1], 0);
        let y = dist(&[8, 4], &[2, 1], 10);
        let mut g = Graph::new();
        let out = matmul(&mut g, &x, &y.t());
        let oref = &g.outputs[out];
        assert_eq!(oref.grid.shape, vec![8, 8]);
        assert_eq!(oref.grid.num_blocks(), 4);
        assert_eq!(g.total_tasks(), 4); // no reduces
    }

    #[test]
    fn newton_builder_outputs() {
        let x = dist(&[100, 4], &[4, 1], 0);
        let y = dist(&[100, 1], &[4, 1], 10);
        let beta = dist(&[4, 1], &[1, 1], 20);
        let mut g = Graph::new();
        let (gi, hi, li) = glm_newton(&mut g, &x, &y, &beta);
        assert_eq!(g.outputs[gi].grid.shape, vec![4, 1]);
        assert_eq!(g.outputs[hi].grid.shape, vec![4, 4]);
        assert_eq!(g.outputs[li].grid.shape, vec![1, 1]);
        // 4 newton blocks + 3 reduce trees of (4-1) adds
        assert_eq!(g.total_tasks(), 4 + 3 * 3);
    }

    #[test]
    fn mttkrp_term_count() {
        let x = dist(&[8, 8, 8], &[2, 2, 2], 0);
        let b = dist(&[8, 5], &[2, 1], 100);
        let c = dist(&[8, 5], &[2, 1], 200);
        let mut g = Graph::new();
        let out = mttkrp(&mut g, &x, &b, &c);
        assert_eq!(g.outputs[out].roots.len(), 2);
        // per output row-block: 4 terms + 3 adds
        assert_eq!(g.total_tasks(), 2 * (4 + 3));
    }

    #[test]
    #[should_panic(expected = "equal shape and grid")]
    fn ew_grid_mismatch_panics() {
        let a = dist(&[8, 8], &[2, 2], 0);
        let b = dist(&[8, 8], &[4, 1], 10);
        let mut g = Graph::new();
        binary_ew(&mut g, &a, &b, BinOp::Add);
    }
}
