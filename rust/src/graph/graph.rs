//! The computation graph arena and its frontier (§5).
//!
//! One [`Graph`] holds the vertices induced by an array expression; each
//! output GraphArray is a grid of root references into the arena. A vertex
//! is *on the frontier* when all of its children are leaves (for `Reduce`,
//! when at least two children are leaves — the scheduler peels operand
//! pairs off incrementally, which is how the paper's n-ary Reduce emits
//! n-1 binary ops).

use crate::grid::ArrayGrid;
use crate::runtime::kernel::{BinOp, Kernel};
use crate::store::ObjectId;

use super::vertex::{Ref, Vertex, VertexId};

#[derive(Clone, Debug, Default)]
pub struct Graph {
    pub vertices: Vec<Vertex>,
    /// Output arrays: grid + per-block root reference.
    pub outputs: Vec<GraphArrayRef>,
}

/// One output array of a graph: the grid plus, for each block in row-major
/// order, the root (vertex, output index).
#[derive(Clone, Debug)]
pub struct GraphArrayRef {
    pub grid: ArrayGrid,
    pub roots: Vec<Ref>,
}

impl Graph {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn leaf(&mut self, obj: ObjectId, shape: &[usize]) -> VertexId {
        self.push(Vertex::single_leaf(obj, shape))
    }

    pub fn op(&mut self, kernel: Kernel, children: Vec<Ref>) -> VertexId {
        self.push(Vertex::Op {
            kernel,
            children,
            constraint: None,
        })
    }

    pub fn reduce(&mut self, op: BinOp, children: Vec<Ref>) -> VertexId {
        assert!(children.len() >= 2, "reduce needs >= 2 operands");
        self.push(Vertex::Reduce {
            op,
            children,
            constraint: None,
        })
    }

    pub fn push(&mut self, v: Vertex) -> VertexId {
        self.vertices.push(v);
        self.vertices.len() - 1
    }

    pub fn set_constraint(&mut self, v: VertexId, target: usize) {
        match &mut self.vertices[v] {
            Vertex::Op { constraint, .. } | Vertex::Reduce { constraint, .. } => {
                *constraint = Some(target)
            }
            Vertex::Leaf { .. } => {}
        }
    }

    /// Register an output array; single-output roots use index 0.
    pub fn add_output(&mut self, grid: ArrayGrid, roots: Vec<Ref>) -> usize {
        assert_eq!(grid.num_blocks(), roots.len(), "root count != block count");
        self.outputs.push(GraphArrayRef { grid, roots });
        self.outputs.len() - 1
    }

    pub fn is_leaf(&self, v: VertexId) -> bool {
        self.vertices[v].is_leaf()
    }

    /// Resolve a reference to its object (after scheduling).
    pub fn resolve(&self, r: Ref) -> ObjectId {
        self.vertices[r.0].obj(r.1)
    }

    pub fn ref_shape(&self, r: Ref) -> &[usize] {
        self.vertices[r.0].shape(r.1)
    }

    /// Frontier vertices: ops whose children are all leaves; reduces with
    /// >= 2 leaf children.
    pub fn frontier(&self) -> Vec<VertexId> {
        self.vertices
            .iter()
            .enumerate()
            .filter_map(|(id, v)| match v {
                Vertex::Leaf { .. } => None,
                Vertex::Op { children, .. } => children
                    .iter()
                    .all(|&(c, _)| self.is_leaf(c))
                    .then_some(id),
                Vertex::Reduce { children, .. } => {
                    (children.iter().filter(|&&(c, _)| self.is_leaf(c)).count() >= 2)
                        .then_some(id)
                }
            })
            .collect()
    }

    /// Whether every vertex has been resolved to a leaf.
    pub fn done(&self) -> bool {
        self.vertices.iter().all(|v| v.is_leaf())
    }

    /// Count non-leaf vertices remaining.
    pub fn remaining_ops(&self) -> usize {
        self.vertices.iter().filter(|v| !v.is_leaf()).count()
    }

    /// Total binary tasks the graph will expand to (Reduce of n = n-1).
    pub fn total_tasks(&self) -> usize {
        self.vertices
            .iter()
            .map(|v| match v {
                Vertex::Leaf { .. } => 0,
                Vertex::Op { .. } => 1,
                Vertex::Reduce { children, .. } => children.len() - 1,
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::kernel::Kernel;

    #[test]
    fn frontier_rules() {
        let mut g = Graph::new();
        let a = g.leaf(0, &[2, 2]);
        let b = g.leaf(1, &[2, 2]);
        let c = g.op(Kernel::Matmul, vec![(a, 0), (b, 0)]);
        let d = g.op(Kernel::Neg, vec![(c, 0)]); // child is an op -> not frontier
        assert_eq!(g.frontier(), vec![c]);
        assert!(!g.is_leaf(d));
    }

    #[test]
    fn reduce_frontier_needs_two_leaves() {
        let mut g = Graph::new();
        let a = g.leaf(0, &[2, 2]);
        let b = g.leaf(1, &[2, 2]);
        let op = g.op(Kernel::Neg, vec![(b, 0)]);
        let r = g.reduce(BinOp::Add, vec![(a, 0), (op, 0)]);
        // only one leaf child -> reduce not on frontier yet
        assert_eq!(g.frontier(), vec![op]);
        let _ = r;
    }

    #[test]
    fn task_counting() {
        let mut g = Graph::new();
        let l: Vec<Ref> = (0..4).map(|i| (g.leaf(i, &[2, 2]), 0)).collect();
        let _r = g.reduce(BinOp::Add, l);
        assert_eq!(g.total_tasks(), 3); // n-1 binary adds
    }

    #[test]
    fn resolve_multi_output_leaf() {
        let mut g = Graph::new();
        let v = g.push(Vertex::Leaf {
            objs: vec![10, 11],
            shapes: vec![vec![4, 1], vec![4, 4]],
        });
        assert_eq!(g.resolve((v, 1)), 11);
        assert_eq!(g.ref_shape((v, 0)), &[4, 1]);
    }
}
