//! Graph-level element-wise fusion: App. A.1's chains made cheap, not just
//! communication-free.
//!
//! The hierarchical-layout invariant (§5) already makes element-wise
//! chains *communication-free*: operands of equal shape and grid co-locate
//! block-for-block, so `sigmoid(-X · 2 + Y)` moves zero bytes. But the
//! graph builders still emit one vertex — hence one dispatched task and
//! one materialized output block — per operation. On a q-block array, a
//! k-op chain costs k·q tasks and (k−1)·q intermediate blocks that exist
//! only to feed the next op.
//!
//! [`fuse_elementwise`] collapses such chains after graph construction and
//! before scheduling: every maximal chain of single-consumer `Neg` /
//! `Sigmoid` / `Scale` / `Ew` vertices becomes one [`Kernel::FusedEw`]
//! vertex holding the step program. The scheduler then makes *one*
//! placement decision per chain (the fused vertex keeps the tail's layout
//! constraint), both executors run one task, and the native backend
//! interprets the program in a single pass over one buffer
//! (`runtime::native`) — the chain's intermediates never touch memory.
//!
//! Fusion invariants:
//!
//! * **Semantics** — the fused program applies exactly the same scalar
//!   operations in exactly the same order as the unfused vertices, so
//!   results are bit-for-bit identical (property-checked by
//!   `tests/prop_suites.rs::prop_fused_chain_matches_unfused_oracle`).
//! * **Single consumer** — a vertex is absorbed only if exactly one edge
//!   (counting output roots) references it; fusion never duplicates work
//!   and never removes a block another consumer needs.
//! * **Constraints** — vertices carrying a placement constraint are never
//!   absorbed, so pinned baselines (`glm::driver_agg`'s serial driver-side
//!   fold) keep their task structure; the chain tail's own constraint is
//!   preserved on the fused vertex, upholding the §5 output-layout rule.
//! * **Shape** — element-wise kernels are shape-preserving and `Ew`
//!   requires equal operand shapes, so every input of a fused vertex has
//!   the output's shape; `Kernel::FusedEw::out_shapes` re-asserts this.
//!
//! [`fuse_epilogues`] is the contraction-side companion: a `Scale`/`Neg`
//! chain sitting directly on a `Matmul`/`MatmulNT`/`Gram` output folds
//! into the contraction itself (`Kernel::ScaledMatmul(α)` & co.), so the
//! backend applies α during C-writeback instead of re-traversing the
//! output block in a separate task. At most **one** `Scale` is folded per
//! chain — `α·(c·x)` and `(α·c)·x` round differently, while any number of
//! `Neg`s are exact sign flips — so the folded result stays bit-identical
//! to the unfused pipeline (`α = (−1)^negs · c`). It runs before
//! [`fuse_elementwise`] in `Session::run`; whatever epilogue tail the
//! fold rejects (a second `Scale`, a `Sigmoid`, …) is still fair game for
//! element-wise fusion afterwards.

use crate::runtime::kernel::{BinOp, EwStep, Kernel};

use super::graph::Graph;
use super::vertex::{Ref, Vertex};

/// What [`fuse_elementwise`] did (surfaced as `RunReport::fused_ops`).
#[derive(Clone, Copy, Debug, Default)]
pub struct FuseStats {
    /// Chains rewritten into a single `FusedEw` vertex.
    pub chains: usize,
    /// Interior vertices absorbed — tasks removed from the eventual plan.
    pub absorbed: usize,
}

/// The op-level shape of a fusible vertex.
enum Fusible {
    Unary(EwStep),
    Bin(BinOp),
}

fn fusible(kernel: &Kernel) -> Option<Fusible> {
    match kernel {
        Kernel::Neg => Some(Fusible::Unary(EwStep::Neg)),
        Kernel::Sigmoid => Some(Fusible::Unary(EwStep::Sigmoid)),
        Kernel::Scale(c) => Some(Fusible::Unary(EwStep::Scale(*c))),
        Kernel::Ew(op) => Some(Fusible::Bin(*op)),
        _ => None,
    }
}

type Prog = (Vec<EwStep>, Vec<Ref>);

/// May the chain ending at `r`'s vertex be absorbed into its consumer?
fn absorbable(g: &Graph, consumers: &[usize], progs: &[Option<Prog>], r: Ref) -> bool {
    let c = r.0;
    consumers[c] == 1 && progs[c].is_some() && g.vertices[c].constraint().is_none()
}

/// Collapse chains of element-wise vertices into `FusedEw` programs.
///
/// Runs in one topological sweep (builders push children before parents,
/// so arena order is topological) plus one rewrite sweep; O(V + E).
pub fn fuse_elementwise(g: &mut Graph) -> FuseStats {
    let n = g.vertices.len();

    // Consumer edge count per vertex: op/reduce children plus output roots.
    let mut consumers = vec![0usize; n];
    for v in &g.vertices {
        for &(c, _) in v.children() {
            consumers[c] += 1;
        }
    }
    for out in &g.outputs {
        for &(r, _) in &out.roots {
            consumers[r] += 1;
        }
    }

    // The chain program each fusible vertex would execute as a tail.
    let mut progs: Vec<Option<Prog>> = Vec::with_capacity(n);
    let mut absorbed = vec![false; n];

    for vid in 0..n {
        let parts = match &g.vertices[vid] {
            Vertex::Op { kernel, children, .. } => {
                fusible(kernel).map(|f| (f, children.clone()))
            }
            _ => None,
        };
        let Some((shape, children)) = parts else {
            progs.push(None);
            continue;
        };
        let prog = match shape {
            Fusible::Unary(step) => {
                let child = children[0];
                if absorbable(g, &consumers, &progs, child) {
                    let (mut steps, inputs) = progs[child.0].take().unwrap();
                    absorbed[child.0] = true;
                    steps.push(step);
                    (steps, inputs)
                } else {
                    (vec![step], vec![child])
                }
            }
            Fusible::Bin(op) => {
                let (a, b) = (children[0], children[1]);
                if absorbable(g, &consumers, &progs, a) {
                    let (mut steps, mut inputs) = progs[a.0].take().unwrap();
                    absorbed[a.0] = true;
                    steps.push(EwStep::Bin(op));
                    inputs.push(b);
                    (steps, inputs)
                } else if absorbable(g, &consumers, &progs, b) {
                    // the chain is the RIGHT operand: record the swapped
                    // application so Sub/Div keep their operand order
                    let (mut steps, mut inputs) = progs[b.0].take().unwrap();
                    absorbed[b.0] = true;
                    steps.push(EwStep::BinRev(op));
                    inputs.push(a);
                    (steps, inputs)
                } else {
                    (vec![EwStep::Bin(op)], vec![a, b])
                }
            }
        };
        progs.push(Some(prog));
    }

    // Rewrite sweep: absorbed interiors become inert leaves (nothing
    // references them anymore); tails whose program grew past their own
    // step become FusedEw vertices in place, so output roots stay valid.
    let mut stats = FuseStats::default();
    for vid in 0..n {
        if absorbed[vid] {
            g.vertices[vid] = Vertex::Leaf {
                objs: Vec::new(),
                shapes: Vec::new(),
            };
            stats.absorbed += 1;
            continue;
        }
        if let Some((steps, inputs)) = progs[vid].take() {
            if steps.len() >= 2 {
                let constraint = g.vertices[vid].constraint();
                g.vertices[vid] = Vertex::Op {
                    kernel: Kernel::FusedEw(steps),
                    children: inputs,
                    constraint,
                };
                stats.chains += 1;
            }
        }
    }
    stats
}

/// Fold `Scale`/`Neg` epilogue chains into the contraction they decorate.
///
/// For every unconstrained, single-consumer `Matmul` / `MatmulNT` / `Gram`
/// vertex whose consumer chain is made of unary `Neg` and `Scale` vertices,
/// the chain's top vertex is rewritten in place as the matching
/// `ScaledMatmul(α)` / `ScaledMatmulNT(α)` / `ScaledGram(α)` with
/// `α = (−1)^negs · c`; the contraction and the interior epilogues become
/// inert leaves. Rewriting the *top* in place keeps output roots and any
/// downstream consumer edges valid, exactly like [`fuse_elementwise`].
///
/// Folding rules (all preserve bit-identity with the unfused pipeline —
/// see the module doc):
/// * at most one `Scale` per chain; a second `Scale` ends the chain,
/// * any number of `Neg`s (exact sign flips),
/// * interior chain members must be single-consumer and unconstrained,
/// * the top vertex keeps its own constraint and consumers,
/// * a constrained or multi-consumer contraction is never folded.
///
/// Returns the number of epilogue vertices folded away (tasks removed).
pub fn fuse_epilogues(g: &mut Graph) -> usize {
    let n = g.vertices.len();

    // Sole consuming vertex per vertex, or None when the count isn't
    // exactly one op edge (output roots count as consumers but cannot
    // absorb anything — the root's block must materialize as produced).
    let mut consumers = vec![0usize; n];
    let mut consumer_of: Vec<Option<usize>> = vec![None; n];
    for (vid, v) in g.vertices.iter().enumerate() {
        for &(c, _) in v.children() {
            consumers[c] += 1;
            consumer_of[c] = Some(vid);
        }
    }
    for out in &g.outputs {
        for &(r, _) in &out.roots {
            consumers[r] += 1;
            consumer_of[r] = None;
        }
    }
    for (c, slot) in consumer_of.iter_mut().enumerate() {
        if consumers[c] != 1 {
            *slot = None;
        }
    }

    let inert = || Vertex::Leaf {
        objs: Vec::new(),
        shapes: Vec::new(),
    };

    let mut folded = 0usize;
    for vid in 0..n {
        let base = match &g.vertices[vid] {
            Vertex::Op {
                kernel: kernel @ (Kernel::Matmul | Kernel::MatmulNT | Kernel::Gram),
                constraint: None,
                ..
            } => kernel.clone(),
            _ => continue,
        };

        // Climb the consumer chain while it stays a foldable epilogue.
        // Chains are vertex-disjoint (every link is a unique single
        // consumer), so no vertex is rewritten twice.
        let mut chain: Vec<usize> = Vec::new();
        let mut scale: Option<f64> = None;
        let mut negs = 0usize;
        let mut cur = vid;
        loop {
            // extending past `cur` absorbs it, which a constraint forbids
            // (the contraction itself was already checked above)
            if g.vertices[cur].constraint().is_some() {
                break;
            }
            let Some(next) = consumer_of[cur] else { break };
            match &g.vertices[next] {
                Vertex::Op {
                    kernel: Kernel::Neg, ..
                } => negs += 1,
                Vertex::Op {
                    kernel: Kernel::Scale(c),
                    ..
                } if scale.is_none() => scale = Some(*c),
                _ => break,
            }
            chain.push(next);
            cur = next;
        }
        let Some(&top) = chain.last() else { continue };

        let alpha = if negs % 2 == 1 { -1.0 } else { 1.0 } * scale.unwrap_or(1.0);
        let kernel = match base {
            Kernel::Matmul => Kernel::ScaledMatmul(alpha),
            Kernel::MatmulNT => Kernel::ScaledMatmulNT(alpha),
            Kernel::Gram => Kernel::ScaledGram(alpha),
            _ => unreachable!("guarded by the match above"),
        };
        let children = g.vertices[vid].children().to_vec();
        let constraint = g.vertices[top].constraint();
        g.vertices[top] = Vertex::Op {
            kernel,
            children,
            constraint,
        };
        g.vertices[vid] = inert();
        for &m in &chain[..chain.len() - 1] {
            g.vertices[m] = inert();
        }
        folded += chain.len();
    }
    folded
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grid::ArrayGrid;

    #[test]
    fn collapses_linear_chain_into_one_vertex() {
        let mut g = Graph::new();
        let x = g.leaf(0, &[4, 4]);
        let y = g.leaf(1, &[4, 4]);
        let n1 = g.op(Kernel::Neg, vec![(x, 0)]);
        let s = g.op(Kernel::Scale(2.0), vec![(n1, 0)]);
        let a = g.op(Kernel::Ew(BinOp::Add), vec![(s, 0), (y, 0)]);
        let t = g.op(Kernel::Sigmoid, vec![(a, 0)]);
        g.add_output(ArrayGrid::new(&[4, 4], &[1, 1]), vec![(t, 0)]);
        assert_eq!(g.total_tasks(), 4);

        let st = fuse_elementwise(&mut g);
        assert_eq!(st.chains, 1);
        assert_eq!(st.absorbed, 3);
        assert_eq!(g.total_tasks(), 1);
        match &g.vertices[t] {
            Vertex::Op {
                kernel: Kernel::FusedEw(steps),
                children,
                ..
            } => {
                assert_eq!(
                    steps,
                    &vec![
                        EwStep::Neg,
                        EwStep::Scale(2.0),
                        EwStep::Bin(BinOp::Add),
                        EwStep::Sigmoid,
                    ]
                );
                assert_eq!(children, &vec![(x, 0), (y, 0)]);
            }
            other => panic!("expected fused vertex, got {other:?}"),
        }
        // interiors are inert leaves now
        assert!(g.vertices[n1].is_leaf());
        assert!(g.vertices[s].is_leaf());
        assert!(g.vertices[a].is_leaf());
    }

    #[test]
    fn right_operand_chain_records_swapped_step() {
        // y - (-x): the chain is the right child of the Sub.
        let mut g = Graph::new();
        let x = g.leaf(0, &[2, 2]);
        let y = g.leaf(1, &[2, 2]);
        let nx = g.op(Kernel::Neg, vec![(x, 0)]);
        let sub = g.op(Kernel::Ew(BinOp::Sub), vec![(y, 0), (nx, 0)]);
        g.add_output(ArrayGrid::new(&[2, 2], &[1, 1]), vec![(sub, 0)]);
        fuse_elementwise(&mut g);
        match &g.vertices[sub] {
            Vertex::Op {
                kernel: Kernel::FusedEw(steps),
                children,
                ..
            } => {
                assert_eq!(steps, &vec![EwStep::Neg, EwStep::BinRev(BinOp::Sub)]);
                // chain source first, then the deferred left operand
                assert_eq!(children, &vec![(x, 0), (y, 0)]);
            }
            other => panic!("expected fused vertex, got {other:?}"),
        }
    }

    #[test]
    fn multi_consumer_interior_is_not_absorbed() {
        // mu feeds both branches: it must stay a real materialized op.
        let mut g = Graph::new();
        let x = g.leaf(0, &[4, 1]);
        let mu = g.op(Kernel::Sigmoid, vec![(x, 0)]);
        let a = g.op(Kernel::Neg, vec![(mu, 0)]);
        let b = g.op(Kernel::Scale(3.0), vec![(mu, 0)]);
        let grid = ArrayGrid::new(&[4, 1], &[1, 1]);
        g.add_output(grid.clone(), vec![(a, 0)]);
        g.add_output(grid, vec![(b, 0)]);
        let st = fuse_elementwise(&mut g);
        assert_eq!(st.chains, 0);
        assert_eq!(st.absorbed, 0);
        assert_eq!(g.total_tasks(), 3);
    }

    #[test]
    fn constrained_interior_is_not_absorbed() {
        let mut g = Graph::new();
        let x = g.leaf(0, &[2, 2]);
        let n1 = g.op(Kernel::Neg, vec![(x, 0)]);
        g.set_constraint(n1, 3); // e.g. a pinned driver-side step
        let t = g.op(Kernel::Sigmoid, vec![(n1, 0)]);
        g.add_output(ArrayGrid::new(&[2, 2], &[1, 1]), vec![(t, 0)]);
        let st = fuse_elementwise(&mut g);
        assert_eq!(st.absorbed, 0);
        assert_eq!(g.total_tasks(), 2, "pinned vertex must keep its task");
    }

    #[test]
    fn contraction_boundaries_stop_the_chain() {
        // sigmoid(A @ B): the matmul is not fusible; only chains above it
        // of length >= 2 would collapse (here the sigmoid stays alone).
        let mut g = Graph::new();
        let a = g.leaf(0, &[2, 2]);
        let b = g.leaf(1, &[2, 2]);
        let mm = g.op(Kernel::Matmul, vec![(a, 0), (b, 0)]);
        let s = g.op(Kernel::Sigmoid, vec![(mm, 0)]);
        g.add_output(ArrayGrid::new(&[2, 2], &[1, 1]), vec![(s, 0)]);
        let st = fuse_elementwise(&mut g);
        assert_eq!(st.chains, 0);
        assert_eq!(g.total_tasks(), 2);
    }

    #[test]
    fn reduce_trees_are_untouched() {
        let mut g = Graph::new();
        let terms: Vec<Ref> = (0..4).map(|i| (g.leaf(i, &[2, 2]), 0)).collect();
        let r = g.reduce(BinOp::Add, terms);
        g.add_output(ArrayGrid::new(&[2, 2], &[1, 1]), vec![(r, 0)]);
        let before = g.total_tasks();
        let st = fuse_elementwise(&mut g);
        assert_eq!(st.chains + st.absorbed, 0);
        assert_eq!(g.total_tasks(), before);
    }

    #[test]
    fn epilogue_scale_folds_into_matmul() {
        let mut g = Graph::new();
        let a = g.leaf(0, &[4, 3]);
        let b = g.leaf(1, &[3, 5]);
        let mm = g.op(Kernel::Matmul, vec![(a, 0), (b, 0)]);
        let s = g.op(Kernel::Scale(2.5), vec![(mm, 0)]);
        g.add_output(ArrayGrid::new(&[4, 5], &[1, 1]), vec![(s, 0)]);
        assert_eq!(g.total_tasks(), 2);

        let folded = fuse_epilogues(&mut g);
        assert_eq!(folded, 1);
        assert_eq!(g.total_tasks(), 1);
        match &g.vertices[s] {
            Vertex::Op {
                kernel: Kernel::ScaledMatmul(alpha),
                children,
                ..
            } => {
                assert_eq!(*alpha, 2.5);
                assert_eq!(children, &vec![(a, 0), (b, 0)]);
            }
            other => panic!("expected ScaledMatmul, got {other:?}"),
        }
        assert!(g.vertices[mm].is_leaf(), "contraction absorbed into top");
    }

    #[test]
    fn epilogue_neg_scale_chain_combines_sign_into_alpha() {
        // -(3·(Aᵀ·B)): one Scale plus one Neg → ScaledGram(-3).
        let mut g = Graph::new();
        let a = g.leaf(0, &[6, 2]);
        let b = g.leaf(1, &[6, 4]);
        let gr = g.op(Kernel::Gram, vec![(a, 0), (b, 0)]);
        let s = g.op(Kernel::Scale(3.0), vec![(gr, 0)]);
        let ng = g.op(Kernel::Neg, vec![(s, 0)]);
        g.add_output(ArrayGrid::new(&[2, 4], &[1, 1]), vec![(ng, 0)]);

        let folded = fuse_epilogues(&mut g);
        assert_eq!(folded, 2);
        assert_eq!(g.total_tasks(), 1);
        match &g.vertices[ng] {
            Vertex::Op {
                kernel: Kernel::ScaledGram(alpha),
                ..
            } => assert_eq!(*alpha, -3.0),
            other => panic!("expected ScaledGram, got {other:?}"),
        }
        assert!(g.vertices[gr].is_leaf());
        assert!(g.vertices[s].is_leaf());
    }

    #[test]
    fn second_scale_stops_the_epilogue_chain() {
        // 2·(3·(A·B)): folding both scales would change rounding, so only
        // the inner Scale folds and the outer one survives as a task.
        let mut g = Graph::new();
        let a = g.leaf(0, &[2, 2]);
        let b = g.leaf(1, &[2, 2]);
        let mm = g.op(Kernel::Matmul, vec![(a, 0), (b, 0)]);
        let s1 = g.op(Kernel::Scale(3.0), vec![(mm, 0)]);
        let s2 = g.op(Kernel::Scale(2.0), vec![(s1, 0)]);
        g.add_output(ArrayGrid::new(&[2, 2], &[1, 1]), vec![(s2, 0)]);

        let folded = fuse_epilogues(&mut g);
        assert_eq!(folded, 1);
        assert_eq!(g.total_tasks(), 2);
        assert!(matches!(
            &g.vertices[s1],
            Vertex::Op {
                kernel: Kernel::ScaledMatmul(alpha),
                ..
            } if *alpha == 3.0
        ));
        assert!(matches!(
            &g.vertices[s2],
            Vertex::Op {
                kernel: Kernel::Scale(c),
                ..
            } if *c == 2.0
        ));
    }

    #[test]
    fn multi_consumer_contraction_is_not_folded() {
        // The matmul result is both scaled and an output root: it must
        // materialize, so the Scale stays a separate task.
        let mut g = Graph::new();
        let a = g.leaf(0, &[2, 2]);
        let b = g.leaf(1, &[2, 2]);
        let mm = g.op(Kernel::Matmul, vec![(a, 0), (b, 0)]);
        let s = g.op(Kernel::Scale(2.0), vec![(mm, 0)]);
        let grid = ArrayGrid::new(&[2, 2], &[1, 1]);
        g.add_output(grid.clone(), vec![(mm, 0)]);
        g.add_output(grid, vec![(s, 0)]);

        assert_eq!(fuse_epilogues(&mut g), 0);
        assert_eq!(g.total_tasks(), 2);
    }

    #[test]
    fn constrained_contraction_is_not_folded() {
        let mut g = Graph::new();
        let a = g.leaf(0, &[2, 2]);
        let b = g.leaf(1, &[2, 2]);
        let mm = g.op(Kernel::Matmul, vec![(a, 0), (b, 0)]);
        g.set_constraint(mm, 1); // pinned placement must survive
        let s = g.op(Kernel::Scale(2.0), vec![(mm, 0)]);
        g.add_output(ArrayGrid::new(&[2, 2], &[1, 1]), vec![(s, 0)]);

        assert_eq!(fuse_epilogues(&mut g), 0);
        assert_eq!(g.total_tasks(), 2);
    }

    #[test]
    fn epilogue_top_keeps_its_constraint() {
        let mut g = Graph::new();
        let a = g.leaf(0, &[2, 2]);
        let b = g.leaf(1, &[2, 2]);
        let mm = g.op(Kernel::Matmul, vec![(a, 0), (b, 0)]);
        let s = g.op(Kernel::Scale(2.0), vec![(mm, 0)]);
        g.set_constraint(s, 3);
        g.add_output(ArrayGrid::new(&[2, 2], &[1, 1]), vec![(s, 0)]);

        assert_eq!(fuse_epilogues(&mut g), 1);
        match &g.vertices[s] {
            Vertex::Op {
                kernel: Kernel::ScaledMatmul(_),
                constraint,
                ..
            } => assert_eq!(*constraint, Some(3)),
            other => panic!("expected ScaledMatmul, got {other:?}"),
        }
    }

    #[test]
    fn epilogue_fold_leaves_sigmoid_for_elementwise_fusion() {
        // sigmoid(-(A·B)): the Neg folds into the contraction, the sigmoid
        // does not (it is no α-epilogue) — and afterwards fuse_elementwise
        // has nothing left to collapse (a single sigmoid is not a chain).
        let mut g = Graph::new();
        let a = g.leaf(0, &[2, 2]);
        let b = g.leaf(1, &[2, 2]);
        let mm = g.op(Kernel::Matmul, vec![(a, 0), (b, 0)]);
        let ng = g.op(Kernel::Neg, vec![(mm, 0)]);
        let sg = g.op(Kernel::Sigmoid, vec![(ng, 0)]);
        g.add_output(ArrayGrid::new(&[2, 2], &[1, 1]), vec![(sg, 0)]);

        assert_eq!(fuse_epilogues(&mut g), 1);
        assert!(matches!(
            &g.vertices[ng],
            Vertex::Op {
                kernel: Kernel::ScaledMatmul(alpha),
                ..
            } if *alpha == -1.0
        ));
        let st = fuse_elementwise(&mut g);
        assert_eq!(st.chains, 0);
        assert_eq!(g.total_tasks(), 2);
    }
}
