//! Materialized distributed arrays: grid + per-block objects + placements.
//!
//! A [`DistArray`] is the post-execution form of a GraphArray: every block
//! is an object resident on some placement target. Creation operations
//! (`zeros`, `random`, `read_csv`) produce these eagerly (§4: "creation and
//! manipulation operations execute immediately"); numerical expressions
//! build a [`super::Graph`] over their blocks and execute lazily.

use crate::grid::ArrayGrid;
use crate::store::ObjectId;

#[derive(Clone, Debug)]
pub struct DistArray {
    pub grid: ArrayGrid,
    /// Block object ids in row-major grid order.
    pub blocks: Vec<ObjectId>,
    /// Placement target per block (node id in Ray mode, worker id in Dask
    /// mode) — the scheduler's notion of where the block's primary copy is.
    pub targets: Vec<usize>,
    /// Lazy transpose (2-D only): the blocks are stored untransposed; the
    /// flag is fused into the consuming contraction (§6).
    pub transposed: bool,
}

impl DistArray {
    pub fn new(grid: ArrayGrid, blocks: Vec<ObjectId>, targets: Vec<usize>) -> Self {
        assert_eq!(grid.num_blocks(), blocks.len());
        assert_eq!(blocks.len(), targets.len());
        Self {
            grid,
            blocks,
            targets,
            transposed: false,
        }
    }

    /// Semantic shape (accounting for lazy transpose).
    pub fn shape(&self) -> Vec<usize> {
        if self.transposed {
            assert_eq!(self.grid.ndim(), 2, "lazy transpose is 2-D only");
            vec![self.grid.shape[1], self.grid.shape[0]]
        } else {
            self.grid.shape.clone()
        }
    }

    /// Lazily transposed view (no data movement).
    pub fn t(&self) -> DistArray {
        assert_eq!(self.grid.ndim(), 2, "transpose needs a matrix");
        let mut out = self.clone();
        out.transposed = !out.transposed;
        out
    }

    pub fn obj_at(&self, coords: &[usize]) -> ObjectId {
        self.blocks[self.grid.flat_of(coords)]
    }

    pub fn target_at(&self, coords: &[usize]) -> usize {
        self.targets[self.grid.flat_of(coords)]
    }

    /// Single-block arrays (β, g, H in §6).
    pub fn single_obj(&self) -> ObjectId {
        assert_eq!(self.blocks.len(), 1, "single_obj on multi-block array");
        self.blocks[0]
    }

    pub fn num_blocks(&self) -> usize {
        self.blocks.len()
    }

    pub fn num_elems(&self) -> u64 {
        self.grid.num_elems()
    }

    pub fn bytes(&self) -> u64 {
        self.num_elems() * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arr() -> DistArray {
        let grid = ArrayGrid::new(&[8, 4], &[2, 1]);
        DistArray::new(grid, vec![100, 101], vec![0, 1])
    }

    #[test]
    fn transpose_is_lazy_and_involutive() {
        let a = arr();
        assert_eq!(a.shape(), vec![8, 4]);
        let t = a.t();
        assert!(t.transposed);
        assert_eq!(t.shape(), vec![4, 8]);
        assert_eq!(t.blocks, a.blocks); // no data movement
        assert_eq!(t.t().shape(), vec![8, 4]);
    }

    #[test]
    fn indexing() {
        let a = arr();
        assert_eq!(a.obj_at(&[1, 0]), 101);
        assert_eq!(a.target_at(&[0, 0]), 0);
        assert_eq!(a.bytes(), 8 * 4 * 8);
    }
}
