//! Vertices of a computation graph (§4, Fig. 5).
//!
//! * `Leaf` — materialized (or already-scheduled) block object(s). Fused
//!   kernels (e.g. `newton_block`) produce several objects from one task,
//!   so a leaf carries one object per output and edges reference
//!   `(vertex, output_index)`.
//! * `Op` — a block-level kernel over child references (fixed arity).
//! * `Reduce` — the n-ary `Reduce(add, ...)` vertex: the scheduler pairs
//!   operands by locality and emits n-1 binary tasks (§4).

use crate::runtime::kernel::{BinOp, Kernel};
use crate::store::ObjectId;

pub type VertexId = usize;

/// An edge: which output of which vertex.
pub type Ref = (VertexId, usize);

#[derive(Clone, Debug)]
pub enum Vertex {
    Leaf {
        objs: Vec<ObjectId>,
        shapes: Vec<Vec<usize>>,
    },
    Op {
        kernel: Kernel,
        children: Vec<Ref>,
        /// Pin the op to a placement target (hierarchical-layout rule for
        /// the final op of each output subgraph, §5).
        constraint: Option<usize>,
    },
    Reduce {
        op: BinOp,
        children: Vec<Ref>,
        constraint: Option<usize>,
    },
}

impl Vertex {
    pub fn single_leaf(obj: ObjectId, shape: &[usize]) -> Self {
        Vertex::Leaf {
            objs: vec![obj],
            shapes: vec![shape.to_vec()],
        }
    }

    pub fn is_leaf(&self) -> bool {
        matches!(self, Vertex::Leaf { .. })
    }

    pub fn children(&self) -> &[Ref] {
        match self {
            Vertex::Leaf { .. } => &[],
            Vertex::Op { children, .. } | Vertex::Reduce { children, .. } => children,
        }
    }

    pub fn constraint(&self) -> Option<usize> {
        match self {
            Vertex::Leaf { .. } => None,
            Vertex::Op { constraint, .. } | Vertex::Reduce { constraint, .. } => *constraint,
        }
    }

    /// Object for output `idx`; panics if not a leaf.
    pub fn obj(&self, idx: usize) -> ObjectId {
        match self {
            Vertex::Leaf { objs, .. } => objs[idx],
            _ => panic!("obj() on non-leaf"),
        }
    }

    pub fn shape(&self, idx: usize) -> &[usize] {
        match self {
            Vertex::Leaf { shapes, .. } => &shapes[idx],
            _ => panic!("shape() on non-leaf"),
        }
    }
}
