//! GraphArrays (§4): distributed-array metadata, computation-graph arena,
//! and the induced-subgraph builders of Fig. 5.

pub mod build;
pub mod dist;
pub mod fuse;
#[allow(clippy::module_inception)]
pub mod graph;
pub mod signature;
pub mod vertex;

pub use dist::DistArray;
pub use fuse::{fuse_elementwise, fuse_epilogues, FuseStats};
pub use graph::{Graph, GraphArrayRef};
pub use signature::{signature, GraphSignature};
pub use vertex::{Ref, Vertex, VertexId};
