//! Scheduled block-level tasks and plans.
//!
//! A [`Plan`] is the output of a scheduler walk over a [`crate::graph::Graph`]:
//! a topologically-ordered task sequence with concrete placements and the
//! transfer decisions the scheduler's cluster-state model committed to.
//! Both executors (simulated and real) replay the same plan, so ablations
//! vary exactly one thing: the scheduling policy.

use crate::runtime::kernel::Kernel;
use crate::store::ObjectId;

/// One data movement committed by the scheduler: `obj` from `src` target
/// to the task's target. These are the load model's `PlacementSim::pulls`
/// threaded through the plan — the real executor's prefetcher uses them
/// as source hints to move each task's inputs *before* the task runs
/// (`exec::prefetch`), and the DES charges them as modeled NIC time.
#[derive(Clone, Debug, PartialEq)]
pub struct Transfer {
    pub obj: ObjectId,
    pub src: usize,
    pub elems: u64,
}

impl Transfer {
    /// Bytes this movement puts on both NICs (f64 elements).
    pub fn bytes(&self) -> u64 {
        self.elems * 8
    }
}

#[derive(Clone, Debug)]
pub struct Task {
    pub kernel: Kernel,
    pub inputs: Vec<ObjectId>,
    pub in_shapes: Vec<Vec<usize>>,
    /// (object, shape) per kernel output.
    pub outputs: Vec<(ObjectId, Vec<usize>)>,
    /// Placement target (node in Ray mode, worker in Dask mode).
    pub target: usize,
    /// Inputs that were not resident on `target` when scheduled.
    pub transfers: Vec<Transfer>,
}

impl Task {
    pub fn out_elems(&self) -> u64 {
        self.outputs
            .iter()
            .map(|(_, s)| s.iter().map(|&d| d as u64).product::<u64>())
            .sum()
    }

    pub fn flops(&self) -> f64 {
        self.kernel.flops(&self.in_shapes)
    }

    pub fn ew_elems(&self) -> f64 {
        self.kernel.ew_elems(&self.in_shapes)
    }
}

#[derive(Clone, Debug, Default)]
pub struct Plan {
    pub tasks: Vec<Task>,
}

impl Plan {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    /// Total bytes moved between distinct targets.
    pub fn transfer_bytes(&self) -> u64 {
        self.tasks
            .iter()
            .flat_map(|t| &t.transfers)
            .map(Transfer::bytes)
            .sum()
    }

    /// Number of inter-target transfers.
    pub fn transfer_count(&self) -> usize {
        self.tasks.iter().map(|t| t.transfers.len()).sum()
    }

    /// Every object this plan produces, with its shape and producing
    /// target, in plan order. Plan order is a contract, not a
    /// convenience: the plan cache abstracts produced objects to
    /// positional `Produced(j)` slots and rebinding re-allocates them in
    /// the same order (`crate::scheduler::plan_cache`), so a cached
    /// plan's j-th produced object always corresponds to the j-th entry
    /// of this iterator.
    pub fn produced(&self) -> impl Iterator<Item = (ObjectId, &[usize], usize)> {
        self.tasks.iter().flat_map(|t| {
            t.outputs
                .iter()
                .map(move |(o, s)| (*o, s.as_slice(), t.target))
        })
    }

    /// Tasks per target histogram (for load-balance assertions).
    pub fn tasks_per_target(&self, n_targets: usize) -> Vec<usize> {
        let mut h = vec![0; n_targets];
        for t in &self.tasks {
            h[t.target] += 1;
        }
        h
    }
}
