//! Real threaded execution of a plan.
//!
//! Each simulated node gets a small pool of worker threads and a FIFO task
//! queue (plan order). Tasks wait until their inputs exist (producer
//! notification via condvar), pull missing inputs through the
//! [`StoreSet`] — which accounts real bytes per node — and execute their
//! kernel on the configured [`Backend`] (PJRT artifacts or native). This is
//! the correctness executor: block numerics are real end-to-end.

use std::collections::HashSet;
use std::sync::{Arc, Condvar, Mutex};

use anyhow::{anyhow, Result};

use crate::runtime::Backend;
use crate::scheduler::Topology;
use crate::store::{ObjectId, StoreSet};
use crate::util::Stopwatch;

use super::task::Plan;

#[derive(Clone, Debug, Default)]
pub struct RealReport {
    pub wall_secs: f64,
    pub tasks: usize,
    /// Per-node (resident, peak, net_in, net_out) bytes after execution.
    pub store_snapshot: Vec<(u64, u64, u64, u64)>,
}

struct Shared {
    produced: Mutex<HashSet<ObjectId>>,
    cv: Condvar,
    failed: Mutex<Option<String>>,
}

pub struct RealExecutor {
    pub topo: Topology,
    pub backend: Arc<Backend>,
    /// Worker threads per node (capped: a laptop can't host 512).
    pub threads_per_node: usize,
}

impl RealExecutor {
    pub fn new(topo: Topology, backend: Arc<Backend>) -> Self {
        // cap total threads near the host's cores
        let cap = (16 / topo.nodes).max(1).min(8);
        let threads_per_node = topo.workers_per_node.min(cap).max(1);
        Self {
            topo,
            backend,
            threads_per_node,
        }
    }

    /// Execute the plan over `stores`. All creation-time objects must
    /// already be resident (see `api::Session`).
    pub fn run(&self, plan: &Plan, stores: &StoreSet) -> Result<RealReport> {
        let sw = Stopwatch::start();
        let shared = Arc::new(Shared {
            produced: Mutex::new(HashSet::new()),
            cv: Condvar::new(),
            failed: Mutex::new(None),
        });
        // seed "produced" with everything already in a store
        {
            let mut p = shared.produced.lock().unwrap();
            for t in &plan.tasks {
                for &obj in &t.inputs {
                    if stores.fetch(obj).is_some() {
                        p.insert(obj);
                    }
                }
            }
        }

        // per-node FIFO queues in plan order
        let k = self.topo.nodes;
        let mut queues: Vec<Vec<usize>> = vec![Vec::new(); k];
        for (i, t) in plan.tasks.iter().enumerate() {
            queues[self.topo.node_of(t.target)].push(i);
        }
        let queues: Vec<Arc<Mutex<std::collections::VecDeque<usize>>>> = queues
            .into_iter()
            .map(|v| Arc::new(Mutex::new(v.into_iter().collect())))
            .collect();

        std::thread::scope(|scope| {
            for node in 0..k {
                for _ in 0..self.threads_per_node {
                    let queue = Arc::clone(&queues[node]);
                    let shared = Arc::clone(&shared);
                    let backend = Arc::clone(&self.backend);
                    let topo = self.topo.clone();
                    scope.spawn(move || {
                        loop {
                            if shared.failed.lock().unwrap().is_some() {
                                return;
                            }
                            let idx = match queue.lock().unwrap().pop_front() {
                                Some(i) => i,
                                None => return,
                            };
                            let task = &plan.tasks[idx];
                            let dst_node = topo.node_of(task.target);
                            // wait for all inputs to be produced somewhere
                            {
                                let mut p = shared.produced.lock().unwrap();
                                while !task.inputs.iter().all(|o| p.contains(o)) {
                                    if shared.failed.lock().unwrap().is_some() {
                                        return;
                                    }
                                    let (guard, timeout) = shared
                                        .cv
                                        .wait_timeout(p, std::time::Duration::from_secs(30))
                                        .unwrap();
                                    p = guard;
                                    if timeout.timed_out() {
                                        *shared.failed.lock().unwrap() = Some(format!(
                                            "deadlock: task {idx} ({}) waiting on inputs",
                                            task.kernel
                                        ));
                                        shared.cv.notify_all();
                                        return;
                                    }
                                }
                            }
                            // pull missing inputs to this node (real bytes)
                            for &obj in &task.inputs {
                                if !stores.contains(dst_node, obj) {
                                    match stores.locate(obj, dst_node) {
                                        Some(src) => {
                                            stores.transfer(src, dst_node, obj);
                                        }
                                        None => {
                                            *shared.failed.lock().unwrap() = Some(format!(
                                                "object {obj} vanished (task {idx})"
                                            ));
                                            shared.cv.notify_all();
                                            return;
                                        }
                                    }
                                }
                            }
                            let inputs: Vec<Arc<crate::store::Block>> = task
                                .inputs
                                .iter()
                                .map(|&o| stores.get(dst_node, o).unwrap())
                                .collect();
                            let in_refs: Vec<&crate::store::Block> =
                                inputs.iter().map(|b| b.as_ref()).collect();
                            match backend.execute(&task.kernel, &in_refs) {
                                Ok(outs) => {
                                    for ((obj, _), block) in task.outputs.iter().zip(outs) {
                                        stores.put(dst_node, *obj, Arc::new(block));
                                    }
                                    let mut p = shared.produced.lock().unwrap();
                                    for (obj, _) in &task.outputs {
                                        p.insert(*obj);
                                    }
                                    drop(p);
                                    shared.cv.notify_all();
                                }
                                Err(e) => {
                                    *shared.failed.lock().unwrap() =
                                        Some(format!("task {idx} ({}): {e}", task.kernel));
                                    shared.cv.notify_all();
                                    return;
                                }
                            }
                        }
                    });
                }
            }
        });

        if let Some(err) = shared.failed.lock().unwrap().take() {
            return Err(anyhow!(err));
        }
        Ok(RealReport {
            wall_secs: sw.secs(),
            tasks: plan.len(),
            store_snapshot: stores.snapshot(),
        })
    }
}
