//! Real threaded execution of a plan: dependency-counted ready queues
//! with work stealing.
//!
//! The scheduler decides *placement*; this executor decides *when* each
//! task actually runs. Input counts are precomputed from the plan, so a
//! task enters a ready deque the instant its last input is produced —
//! workers never block waiting for inputs. Each node owns a ready deque
//! (plan order at the front); a saturated node spills newly-ready tasks
//! into a global overflow deque that any idle worker may drain. Workers
//! pop locally first, then take from the overflow, then steal from the
//! back of the most-loaded sibling node's deque. A stolen task pulls its
//! inputs to the thief's node through [`StoreSet::transfer`], so stolen
//! work still pays real bytes — the per-node `(tasks_run, tasks_stolen,
//! steal_bytes)` counters in [`RealReport`] are what the fig09 stealing
//! ablation reports.
//!
//! Stealing is locality-aware and batched: the victim is the sibling
//! whose next-stealable task needs the fewest bytes pulled to the thief's
//! node (scored *outside* the executor's state lock — candidates are
//! snapshotted, the lock dropped while store residency is checked, and
//! the steal re-validated under the lock), and a victim whose deque is
//! deep *relative to the observed mean ready depth* loses half its deque
//! in one steal (`batch_steal_threshold`) so the thief's node (and its
//! own siblings) amortize the migration — near-balanced queues steal
//! singly instead.
//!
//! Communication overlaps compute ([`super::prefetch::Prefetcher`],
//! `RealExecutor::prefetch`, default on): one transfer thread per node
//! pulls the remote inputs of near-ready tasks (unmet deps ≤ 1) in the
//! background, guided by the plan's scheduler-committed transfer
//! decisions, so workers usually find inputs resident and only fall back
//! to demand pulls on a miss. Stolen tasks re-route their prefetches to
//! the thief's node, and the memory manager's spill writes ride the same
//! transfer threads (asynchronous spill with a write-completion barrier).
//! The transfer queues are priority queues ordered by the consumer
//! task's topological depth (next-to-run inputs first), bounded by a
//! lookahead byte budget derived from the memory budget, and a steal
//! cancels the victim's queued pulls for the migrated tasks. Per-node
//! `(prefetch_bytes, prefetch_hits, demand_pull_bytes,
//! async_spill_bytes)` land in [`RealReport::prefetch_stats`].
//!
//! Every run also reconciles plan against observation into a
//! [`RuntimeFeedback`] ([`RealReport::feedback`]): steal migrations,
//! demand-pull misses, spill pressure, unplanned NIC traffic, and the
//! replica copies the runtime materialized. `api::Session` folds it into
//! the scheduler's load model between runs, closing the plan↔runtime
//! loop (`SessionConfig::feedback`).
//!
//! Memory: when the executor owns a [`MemoryManager`]
//! (`RealExecutor::memory`, wired up by `api::Session`), each run first
//! computes plan lifetimes ([`super::lifetime::Lifetimes`]) — consumer
//! refcounts plus output pinning — and the completion path releases dead
//! intermediates everywhere the moment their last consumer finishes.
//! Under a per-node byte budget the manager also evicts replica copies
//! and spills cold primaries to disk, transparently reading them back on
//! access; the per-node spill/readback/eviction counters land in
//! [`RealReport::mem_stats`].
//!
//! Failure modes: a plan referencing an object that no store holds and no
//! task produces (or a dependency cycle) is detected as soon as the
//! executor goes fully idle — nothing running, nothing queued, work left —
//! and fails with a typed [`ExecError`], naming the blocking `ObjectId`s.
//! Parked workers re-check that condition every `deadlock_timeout`
//! (`NUMS_DEADLOCK_TIMEOUT_SECS` overrides), so a missed wakeup can only
//! delay detection, never hang the run; a long-running kernel never trips
//! the watchdog (progress stalls are only fatal once nothing is running).
//! Kernel panics are caught and surfaced as task errors rather than
//! poisoning the worker pool.
//!
//! Fault tolerance ([`super::fault`], [`super::recovery`]): when a
//! [`FaultInjector`] is armed (`RealExecutor::with_faults`; default off =
//! no injector constructed, no hot-path work), deterministic failures are
//! injected at kernel execution, demand transfers, spill I/O (inside the
//! memory manager), and — once per run — a whole-node loss. Transient
//! failures retry in place with bounded exponential backoff; a lost
//! object triggers lineage recovery: the plan is walked backward from the
//! missing `ObjectId` to its producing task and transitively to live
//! inputs, and the minimal recompute subgraph is spliced back into the
//! running dependency counts, placed on surviving nodes. The idle
//! watchdog attempts that same recovery before declaring a deadlock, so
//! a wiped node is a detour, not a panic; only a dead lineage (an object
//! gone from every store that no task produces) escalates, as
//! [`ExecError::UnrecoverableLoss`]. What recovery cost the run lands in
//! [`RealReport::recovery_stats`] and, per wiped node,
//! [`RealReport::node_losses`].

use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::metrics::runtime_trace::{
    EventKind, FetchOrigin, RunRecorder, RunTrace, SpanRing, TaskSpan,
};
use crate::runtime::{Backend, ExecContext, KernelTier};
use crate::scheduler::Topology;
use crate::store::{Block, MemoryManager, NodeMemStats, ObjectId, StoreSet};
use crate::util::Stopwatch;

use std::sync::Arc;

use super::fault::{FaultInjector, FaultSite, NodeLossMode, NodeLossSpec};
use super::feedback::RuntimeFeedback;
use super::lifetime::Lifetimes;
use super::prefetch::{PrefetchStats, Prefetcher};
use super::recovery::{self, ExecError, RecoveryStats, MAX_TRANSIENT_RETRIES};
use super::task::Plan;

/// Per-node load-balance counters for one run.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct NodeExecStats {
    /// Tasks executed by this node's workers (stolen ones included).
    pub tasks_run: usize,
    /// Tasks this node executed whose plan target was another node.
    pub tasks_stolen: usize,
    /// Input bytes pulled cross-node for those stolen tasks.
    pub steal_bytes: u64,
}

#[derive(Clone, Debug, Default)]
pub struct RealReport {
    pub wall_secs: f64,
    pub tasks: usize,
    /// Per-node (resident, peak, net_in, net_out) bytes after execution.
    pub store_snapshot: Vec<(u64, u64, u64, u64)>,
    /// Per-node execution counters (see [`NodeExecStats`]).
    pub node_stats: Vec<NodeExecStats>,
    /// Per-node memory-manager counters for *this run* (spill, read-back,
    /// replica eviction, GC frees). Empty when no manager is attached.
    pub mem_stats: Vec<NodeMemStats>,
    /// Per-node communication-overlap counters (see [`PrefetchStats`]).
    /// Empty when prefetching is disabled. Per node,
    /// `prefetch_bytes + demand_pull_bytes` equals the run's `net_in`
    /// bytes — every cross-node byte is accounted exactly once, to
    /// either the background or the hot path.
    pub prefetch_stats: Vec<PrefetchStats>,
    /// Objects lifetime GC released during this run (dead intermediates),
    /// in completion order. The session uses this to make the
    /// scheduler's load model forget dead bytes
    /// ([`crate::scheduler::ClusterState::forget`]).
    pub gc_released: Vec<ObjectId>,
    /// Observed-vs-planned load for this run: steal migrations, demand
    /// pulls, spill pressure, unplanned NIC traffic and runtime replica
    /// copies. The session folds it into the scheduler's
    /// [`crate::scheduler::ClusterState`] between runs
    /// (`SessionConfig::feedback`, default on).
    pub feedback: RuntimeFeedback,
    /// Full run trace (spans, events, Fig. 15 series, divergence report)
    /// when the executor ran with tracing on; `None` otherwise. See
    /// [`crate::metrics::runtime_trace`].
    pub trace: Option<RunTrace>,
    /// What surviving injected/real faults cost this run: retries,
    /// backoff sleep, lineage-recomputed tasks/bytes, node losses.
    /// All-zero ([`RecoveryStats::is_zero`]) on a fault-free run.
    pub recovery_stats: RecoveryStats,
    /// Whole-node losses this run absorbed: `(node, wiped objects with
    /// their bytes)`. The session uses this to drop the dead copies from
    /// the scheduler's [`crate::scheduler::ClusterState`] so the Eq. 2
    /// accounting stays honest about where data really lives.
    pub node_losses: Vec<(usize, Vec<(ObjectId, u64)>)>,
}

/// `NUMS_DEADLOCK_TIMEOUT_SECS` parsing (non-positive/garbage/absurd -> 30s).
fn parse_deadlock_timeout(v: Option<String>) -> Duration {
    // upper bound keeps Duration::from_secs_f64 from panicking on overflow
    const MAX_SECS: f64 = 1e9;
    v.and_then(|s| s.parse::<f64>().ok())
        .filter(|s| *s > 0.0 && *s <= MAX_SECS)
        .map(Duration::from_secs_f64)
        .unwrap_or(Duration::from_secs(30))
}

/// Mutable run state, guarded by one mutex. Tasks are cheap to enqueue
/// (an index push) and kernels run outside the lock, so a single guard is
/// both simple and uncontended; the condvar only parks *idle* workers —
/// task completion never waits.
struct ExecState {
    /// Per-node ready deques: plan order in at the back, popped at the
    /// front by owners, stolen from the back by siblings.
    ready: Vec<VecDeque<usize>>,
    /// Ready-but-spilled tasks from saturated nodes; any worker may take.
    overflow: VecDeque<usize>,
    /// Unproduced-input count per task (multiplicity counted).
    deps: Vec<usize>,
    /// Objects resident or produced so far (for deadlock diagnostics).
    produced: HashSet<ObjectId>,
    completed: Vec<bool>,
    /// Tasks not yet completed.
    remaining: usize,
    /// Tasks currently executing on some worker.
    running: usize,
    stats: Vec<NodeExecStats>,
    /// Remaining-consumer counts for refcount-releasable intermediates
    /// (empty unless a memory manager with lifetime GC is attached).
    live: HashMap<ObjectId, usize>,
    /// Intermediates lifetime GC released so far (completion order).
    released: Vec<ObjectId>,
    /// Per-task enqueue timestamp (seconds since the trace epoch), for
    /// span queue-wait. Sized `n_tasks` when tracing, empty otherwise.
    ready_at: Vec<f64>,
    /// Tasks re-spliced by lineage recovery, awaiting re-execution; the
    /// completion path pops membership to tally/trace the recompute.
    recovering: HashSet<usize>,
    /// Lineage-recovery tallies (retries/backoff live in `Shared` atomics
    /// — they happen outside this lock).
    recomputed_tasks: u64,
    recomputed_bytes: u64,
    /// Per wiped node: the objects (with bytes) its loss destroyed.
    node_losses: Vec<(usize, Vec<(ObjectId, u64)>)>,
    /// Recovery splices so far — bounds the recover/re-lose loop.
    recovery_rounds: usize,
}

struct Shared {
    state: Mutex<ExecState>,
    cv: Condvar,
    failed: Mutex<Option<ExecError>>,
    /// obj -> consumer task indices (with multiplicity), for every input
    /// that is not pre-resident.
    consumers: HashMap<ObjectId, Vec<usize>>,
    /// Inputs that no store holds and no task produces — a deadlock the
    /// moment any consumer would otherwise become ready.
    never_satisfied: HashSet<ObjectId>,
    /// Node each task's plan target maps to.
    task_node: Vec<usize>,
    /// Per-task (input object, bytes) — locality scoring for steals.
    input_bytes: Vec<Vec<(ObjectId, u64)>>,
    stealing: bool,
    /// Ready-queue length at which a node spills to the overflow.
    spill_threshold: usize,
    /// The run recorder's epoch when tracing is on: `enqueue` stamps
    /// `ready_at` against it (it already holds the state lock, so it
    /// cannot call back into the recorder).
    trace_epoch: Option<std::time::Instant>,
    /// Nodes whose store was wiped by an injected node loss. A dead
    /// node's workers finish the task in hand and exit; its queued work
    /// drains to the overflow for survivors.
    dead: Vec<AtomicBool>,
    /// Fast any-node-dead flag so `pick` only consults the overflow on
    /// the non-stealing path after an actual loss.
    any_dead: AtomicBool,
    /// Transient-failure retries delivered (kernel/transfer sites).
    retries: AtomicU64,
    /// Microseconds slept in retry backoff.
    backoff_us: AtomicU64,
}

/// Floor of the adaptive batch-steal trigger: deques shallower than this
/// are always stolen from one task at a time.
const MIN_BATCH_STEAL: usize = 2;

/// Most recovery splices one run will attempt before a still-vanishing
/// object is declared lost for good — bounds any recover/re-lose loop a
/// pathological environment could otherwise sustain.
const MAX_RECOVERY_ROUNDS: usize = 64;

/// Adaptive batch-steal trigger: a victim loses half its deque in one
/// steal only when its depth is at least twice the mean ready depth per
/// node observed *right now* (never below [`MIN_BATCH_STEAL`]). Deep
/// skew amortizes the migration in one move; near-balanced queues steal
/// singly so a batch steal cannot itself create the next imbalance.
/// Floor division matters: with ceiling, full skew of an odd task count
/// onto one of two nodes would sit exactly one task under the trigger —
/// the canonical case batching exists for. (Replaces the old hardcoded
/// depth-≥-4 rule.)
fn batch_steal_threshold(total_ready: usize, nodes: usize) -> usize {
    (2 * (total_ready / nodes.max(1))).max(MIN_BATCH_STEAL)
}

/// Choose the steal victim among snapshotted `candidates` — `(node,
/// back-of-deque task, deque len)` — as the one whose next-stealable
/// task needs the fewest bytes moved to the thief; ties go to the deeper
/// deque. Runs *without* the executor state lock (the snapshot was taken
/// under it, residency is scored against the stores afterwards, and the
/// steal itself re-validates under the lock), so store locks are never
/// nested inside the state lock.
fn best_victim(
    candidates: &[(usize, usize, usize)],
    missing_bytes: impl Fn(usize) -> u64,
) -> Option<usize> {
    let mut best: Option<(usize, u64, usize)> = None;
    for &(n, task, len) in candidates {
        let miss = missing_bytes(task);
        let better = match best {
            None => true,
            Some((_, bm, bl)) => miss < bm || (miss == bm && len > bl),
        };
        if better {
            best = Some((n, miss, len));
        }
    }
    best.map(|(n, _, _)| n)
}

/// Outcome of one ready-queue poll (see [`Shared::pick`]).
enum Pick {
    /// Run this task now (local front or overflow).
    Run(usize),
    /// Exactly one sibling has stealable work: steal from it directly.
    Steal(usize),
    /// Several candidates: score `(node, back task, len)` residency with
    /// the state lock *dropped*, then steal from the winner.
    Score(Vec<(usize, usize, usize)>),
    /// Nothing to run or steal.
    Idle,
}

/// One completed steal: the tasks migrated from `victim` to the thief.
/// `first` runs immediately; `queued` landed in the thief's deque. The
/// worker uses this (after dropping the state lock) to cancel the
/// victim's queued prefetches and re-route the batch's pulls.
struct StealInfo {
    victim: usize,
    first: usize,
    queued: Vec<usize>,
}

impl Shared {
    fn enqueue(&self, st: &mut ExecState, i: usize) {
        if let Some(epoch) = self.trace_epoch {
            st.ready_at[i] = epoch.elapsed().as_secs_f64();
        }
        let node = self.task_node[i];
        // a dead node's deque would never drain: divert its work to the
        // overflow, which every surviving worker consults after a loss
        if self.is_dead(node)
            || (self.stealing && st.ready[node].len() >= self.spill_threshold)
        {
            st.overflow.push_back(i);
        } else {
            st.ready[node].push_back(i);
        }
    }

    /// Enqueue directly on `node`, bypassing the plan target — lineage
    /// recovery re-placing a recompute task on a surviving node.
    fn enqueue_on(&self, st: &mut ExecState, i: usize, node: usize) {
        if let Some(epoch) = self.trace_epoch {
            st.ready_at[i] = epoch.elapsed().as_secs_f64();
        }
        st.ready[node].push_back(i);
    }

    fn is_dead(&self, node: usize) -> bool {
        self.dead[node].load(Ordering::Relaxed)
    }

    fn mark_dead(&self, node: usize) {
        self.dead[node].store(true, Ordering::SeqCst);
        self.any_dead.store(true, Ordering::SeqCst);
    }

    /// Next move for a worker on `me`: local front, then overflow, then
    /// stealing. With several stealable siblings this returns a
    /// [`Pick::Score`] snapshot instead of scoring inline — the locality
    /// score reads store residency, and store locks must never nest
    /// inside the state lock (the ROADMAP contention wart). A single
    /// candidate (the common deep-skew case) is stolen from directly.
    fn pick(&self, st: &mut ExecState, me: usize) -> Pick {
        if let Some(i) = st.ready[me].pop_front() {
            return Pick::Run(i);
        }
        if !self.stealing {
            // no stealing, but after a node loss the overflow carries the
            // dead node's diverted work: survivors must still drain it
            if self.any_dead.load(Ordering::Relaxed) {
                if let Some(i) = st.overflow.pop_front() {
                    return Pick::Run(i);
                }
            }
            return Pick::Idle;
        }
        if let Some(i) = st.overflow.pop_front() {
            return Pick::Run(i);
        }
        let candidates: Vec<(usize, usize, usize)> = st
            .ready
            .iter()
            .enumerate()
            .filter(|&(n, q)| n != me && !q.is_empty())
            .map(|(n, q)| (n, *q.back().unwrap(), q.len()))
            .collect();
        match candidates.len() {
            0 => Pick::Idle,
            1 => Pick::Steal(candidates[0].0),
            _ => Pick::Score(candidates),
        }
    }

    /// Take work from `victim`'s deque for a thief on `me`: one task, or
    /// — when the victim's depth crosses the adaptive
    /// [`batch_steal_threshold`] — the back half of the deque in one
    /// steal (the earliest of the batch runs now, the rest queue
    /// locally). Returns `None` when the deque drained while the thief
    /// was scoring (the caller re-picks). On success `info` records the
    /// migration so the caller can fix up prefetches after unlocking.
    fn steal_from(
        &self,
        st: &mut ExecState,
        victim: usize,
        me: usize,
        info: &mut Option<StealInfo>,
    ) -> Option<usize> {
        let vlen = st.ready[victim].len();
        if vlen == 0 {
            return None; // raced away while the state lock was dropped
        }
        let total: usize =
            st.ready.iter().map(|q| q.len()).sum::<usize>() + st.overflow.len();
        let first;
        let mut queued = Vec::new();
        if vlen >= batch_steal_threshold(total, st.ready.len()) {
            // deep skew: migrate the back half in one steal, run the
            // earliest of the batch now and queue the rest locally
            let batch: Vec<usize> = st.ready[victim].drain(vlen - vlen / 2..).collect();
            let mut it = batch.into_iter();
            first = it.next()?;
            for t in it {
                queued.push(t);
                st.ready[me].push_back(t);
            }
            if !queued.is_empty() {
                // this node's deque just became stealable: wake workers
                self.cv.notify_all();
            }
        } else {
            first = st.ready[victim].pop_back()?;
        }
        *info = Some(StealInfo {
            victim,
            first,
            queued,
        });
        Some(first)
    }

    fn fail(&self, err: ExecError) {
        let mut f = self.failed.lock().unwrap();
        if f.is_none() {
            *f = Some(err);
        }
        drop(f);
        self.cv.notify_all();
    }

    fn has_failed(&self) -> bool {
        self.failed.lock().unwrap().is_some()
    }
}

/// Inputs of incomplete tasks that nothing has produced yet (deduped, in
/// first-reference order) — the objects a stuck run is blocked on. With
/// `only`, restricts to that set (e.g. the provably-unsatisfiable inputs).
fn missing_inputs(
    plan: &Plan,
    st: &ExecState,
    only: Option<&HashSet<ObjectId>>,
) -> Vec<ObjectId> {
    let mut seen = HashSet::new();
    let mut missing = Vec::new();
    for (i, t) in plan.tasks.iter().enumerate() {
        if st.completed[i] {
            continue;
        }
        for &o in &t.inputs {
            if !st.produced.contains(&o)
                && only.map_or(true, |f| f.contains(&o))
                && seen.insert(o)
            {
                missing.push(o);
            }
        }
    }
    missing
}

/// Total output bytes of task `i` (f64 blocks).
fn out_bytes_of(plan: &Plan, i: usize) -> u64 {
    plan.tasks[i]
        .outputs
        .iter()
        .map(|(_, s)| s.iter().map(|&d| d as u64).product::<u64>() * 8)
        .sum()
}

/// Current resident bytes per node — the load array recovery placement
/// balances against (read without the state lock held).
fn node_loads(stores: &StoreSet, k: usize) -> Vec<u64> {
    (0..k).map(|n| stores.node_bytes(n)).collect()
}

/// Objects a recovery splice must treat as absent: the unavailable roots
/// plus every unavailable output of the recompute subgraph (its internal
/// intermediates). Computed *without* the state lock — `available` reads
/// store/manager state.
fn gone_set(
    plan: &Plan,
    tasks: &[usize],
    roots: &[ObjectId],
    available: &dyn Fn(ObjectId) -> bool,
) -> HashSet<ObjectId> {
    let mut gone: HashSet<ObjectId> =
        roots.iter().copied().filter(|&o| !available(o)).collect();
    for &r in tasks {
        for (o, _) in &plan.tasks[r].outputs {
            if !available(*o) {
                gone.insert(*o);
            }
        }
    }
    gone
}

/// Splice a recompute subgraph back into the running dependency counts.
/// Caller holds the state lock. `gone` objects leave `produced` (so
/// diagnostics, warm pulls, and dependency math stay honest); completed
/// tasks in `tasks` are reset with their unmet-dep counts recomputed
/// against current availability, and immediately-ready ones are placed
/// on surviving nodes by min-load greedy ([`recovery::place_on_survivors`],
/// charging `loads`). Tasks already pending or running are left alone —
/// their outputs are on the way. The normal completion path re-gates
/// everything downstream: a recompute producer finishing decrements its
/// consumers exactly like the first execution did (the `deps > 0` guard
/// makes the re-decrements safe for consumers that already ran).
fn splice_recovery(
    shared: &Shared,
    st: &mut ExecState,
    plan: &Plan,
    tasks: &[usize],
    gone: &HashSet<ObjectId>,
    loads: &mut [u64],
) {
    for &o in gone {
        st.produced.remove(&o);
    }
    let mut reset: Vec<usize> = Vec::new();
    for &r in tasks {
        if !st.completed[r] {
            continue;
        }
        st.completed[r] = false;
        st.remaining += 1;
        st.recovering.insert(r);
        reset.push(r);
    }
    let alive: Vec<bool> = shared
        .dead
        .iter()
        .map(|d| !d.load(Ordering::Relaxed))
        .collect();
    for &r in &reset {
        let need = plan.tasks[r]
            .inputs
            .iter()
            .filter(|o| !st.produced.contains(o))
            .count();
        st.deps[r] = need;
        if need == 0 {
            match recovery::place_on_survivors(out_bytes_of(plan, r), loads, &alive) {
                Some(node) => shared.enqueue_on(st, r, node),
                None => st.overflow.push_back(r),
            }
        }
    }
}

pub struct RealExecutor {
    pub topo: Topology,
    pub backend: Arc<Backend>,
    /// Worker threads per node (sized from the host's cores).
    pub threads_per_node: usize,
    /// How often parked workers re-check the provable-deadlock condition
    /// (nothing running, nothing queued, work left). A stalled-but-stuck
    /// run is declared dead on the first re-check that finds it; running
    /// kernels are never interrupted, however long. 30s default;
    /// `NUMS_DEADLOCK_TIMEOUT_SECS` overrides.
    pub deadlock_timeout: Duration,
    /// Work stealing on/off (off = strict node-affinity FIFO; the
    /// ablation baseline for `SessionConfig::stealing`).
    pub stealing: bool,
    /// Communication/compute overlap on/off: per-node transfer threads
    /// prefetch near-ready tasks' remote inputs and absorb the memory
    /// manager's spill writes (off = every byte is paid synchronously on
    /// the worker hot path; the ablation baseline for
    /// `SessionConfig::prefetch`).
    pub prefetch: bool,
    /// Cluster memory manager: lifetime GC, replica eviction, and
    /// spill-to-disk (`None` = unmanaged, the pre-manager behavior).
    pub memory: Option<MemoryManager>,
    /// Microkernel tier every worker's [`ExecContext`] carries: `Scalar`
    /// is bit-reproducible against the naive oracle, `Simd` dispatches
    /// the packed AVX2+FMA path (epsilon-bounded). Resolved once here —
    /// workers never re-run feature detection.
    pub tier: KernelTier,
    /// Per-task span + runtime-event tracing (default off). Off means
    /// no recorder exists: no timestamps are taken, no ring is
    /// allocated, and results are bit-identical to an untraced run. On,
    /// [`RealReport::trace`] carries the full [`RunTrace`].
    pub tracing: bool,
    /// Deterministic fault injector (default `None` = faults off: no
    /// injector is constructed and every injection site is an `Option`
    /// test, exactly like the tracing recorder). Armed via
    /// [`RealExecutor::with_faults`] from `SessionConfig::fault_plan` or
    /// the `NUMS_FAULT_SEED`/`NUMS_FAULT_RATE` environment overrides.
    pub fault: Option<Arc<FaultInjector>>,
}

impl RealExecutor {
    pub fn new(topo: Topology, backend: Arc<Backend>) -> Self {
        // size the total worker count to the actual host, not a guess
        let hw = crate::runtime::exec_ctx::host_threads();
        let cap = (hw / topo.nodes).max(1).min(8);
        let threads_per_node = topo.workers_per_node.min(cap).max(1);
        let deadlock_timeout =
            parse_deadlock_timeout(std::env::var("NUMS_DEADLOCK_TIMEOUT_SECS").ok());
        Self {
            topo,
            backend,
            threads_per_node,
            deadlock_timeout,
            stealing: true,
            prefetch: true,
            memory: None,
            tier: KernelTier::detect(),
            tracing: false,
            fault: None,
        }
    }

    pub fn with_stealing(mut self, on: bool) -> Self {
        self.stealing = on;
        self
    }

    /// Pin the microkernel tier for every worker (see
    /// [`RealExecutor::tier`]). A `Simd` request still degrades to
    /// `Scalar` when the host lacks AVX2+FMA or `NUMS_KERNEL_TIER=scalar`
    /// is set ([`KernelTier::resolve`]).
    pub fn with_tier(mut self, tier: KernelTier) -> Self {
        self.tier = KernelTier::resolve(tier);
        self
    }

    /// Toggle the communication-overlap pipeline (transfer threads:
    /// input prefetching + async spill writes).
    pub fn with_prefetch(mut self, on: bool) -> Self {
        self.prefetch = on;
        self
    }

    /// Attach a cluster memory manager (lifetime GC + budgeted spill).
    pub fn with_memory(mut self, mgr: MemoryManager) -> Self {
        self.memory = Some(mgr);
        self
    }

    /// Toggle run tracing (see [`RealExecutor::tracing`]).
    pub fn with_tracing(mut self, on: bool) -> Self {
        self.tracing = on;
        self
    }

    /// Arm deterministic fault injection (see [`RealExecutor::fault`]).
    /// `None` leaves faults off — the zero-cost default.
    pub fn with_faults(mut self, plan: Option<super::fault::FaultPlan>) -> Self {
        self.fault = plan.map(|p| Arc::new(FaultInjector::new(&p)));
        self
    }

    /// Execute the plan over `stores`. All creation-time objects must
    /// already be resident (see `api::Session`). No pins: every terminal
    /// output survives, but nothing else is protected from GC/spill.
    pub fn run(&self, plan: &Plan, stores: &StoreSet) -> Result<RealReport> {
        self.run_pinned(plan, stores, &[])
    }

    /// [`RealExecutor::run`] with an explicit pin set: `pins` (the
    /// scheduled graph's output objects) survive the run un-evicted and
    /// un-spilled even when they are also consumed mid-plan.
    pub fn run_pinned(
        &self,
        plan: &Plan,
        stores: &StoreSet,
        pins: &[ObjectId],
    ) -> Result<RealReport> {
        let sw = Stopwatch::start();
        let k = self.topo.nodes;
        let n_tasks = plan.tasks.len();
        // run recorder: exists only when tracing — with it absent, no
        // timestamp is ever taken and no trace branch allocates
        let recorder = self.tracing.then(|| Arc::new(RunRecorder::new(k)));
        let recorder_ref: Option<&RunRecorder> = recorder.as_deref();
        let memory = self.memory.as_ref();
        let mem_start = memory.map(|m| m.stats());
        // NIC baseline for the run's plan-vs-observed reconciliation
        // ([`RuntimeFeedback`]): the store counters are cumulative
        let snap_start = stores.snapshot();
        // only the managed paths read lifetimes: the unmanaged ablation
        // baseline must not pay the analysis walk it is measured against
        let lt = match memory {
            Some(_) => Lifetimes::analyze(plan, pins),
            None => Lifetimes::default(),
        };
        let lt = &lt;

        // --- dependency counting -------------------------------------
        // An input is either produced by some task in this plan, already
        // resident in a store, or permanently missing (counted as an
        // unmet dep so the deadlock path can name it).
        let mut will_produce: HashSet<ObjectId> = HashSet::new();
        for t in &plan.tasks {
            for (o, _) in &t.outputs {
                will_produce.insert(*o);
            }
        }
        let mut deps = vec![0usize; n_tasks];
        let mut consumers: HashMap<ObjectId, Vec<usize>> = HashMap::new();
        let mut produced: HashSet<ObjectId> = HashSet::new();
        let mut never_satisfied: HashSet<ObjectId> = HashSet::new();
        for (i, t) in plan.tasks.iter().enumerate() {
            for &obj in &t.inputs {
                // resident = in some store, or paged out to a spill file
                // the manager can read back (still satisfiable)
                let resident = match memory {
                    Some(m) => m.holds(stores, obj),
                    None => stores.fetch(obj).is_some(),
                };
                if will_produce.contains(&obj) {
                    deps[i] += 1;
                    consumers.entry(obj).or_default().push(i);
                } else if resident {
                    produced.insert(obj);
                } else {
                    // never satisfied -> task stays blocked, deadlock names it
                    deps[i] += 1;
                    consumers.entry(obj).or_default().push(i);
                    never_satisfied.insert(obj);
                }
            }
        }
        let task_node: Vec<usize> = plan
            .tasks
            .iter()
            .map(|t| self.topo.node_of(t.target))
            .collect();
        // locality scoring table, read only by the stealing pick path
        let input_bytes: Vec<Vec<(ObjectId, u64)>> = if self.stealing {
            plan.tasks
                .iter()
                .map(|t| {
                    t.inputs
                        .iter()
                        .zip(&t.in_shapes)
                        .map(|(&o, s)| (o, s.iter().map(|&d| d as u64).product::<u64>() * 8))
                        .collect()
                })
                .collect()
        } else {
            Vec::new()
        };
        let live = match memory {
            Some(m) if m.lifetime_gc => lt.live_counts(),
            _ => HashMap::new(),
        };

        let shared = Shared {
            state: Mutex::new(ExecState {
                ready: vec![VecDeque::new(); k],
                overflow: VecDeque::new(),
                deps,
                produced,
                completed: vec![false; n_tasks],
                remaining: n_tasks,
                running: 0,
                stats: vec![NodeExecStats::default(); k],
                live,
                released: Vec::new(),
                ready_at: vec![0.0; if recorder.is_some() { n_tasks } else { 0 }],
                recovering: HashSet::new(),
                recomputed_tasks: 0,
                recomputed_bytes: 0,
                node_losses: Vec::new(),
                recovery_rounds: 0,
            }),
            cv: Condvar::new(),
            failed: Mutex::new(None),
            consumers,
            never_satisfied,
            task_node,
            input_bytes,
            stealing: self.stealing,
            spill_threshold: (2 * self.threads_per_node).max(2),
            trace_epoch: recorder.as_ref().map(|r| r.epoch()),
            dead: (0..k).map(|_| AtomicBool::new(false)).collect(),
            any_dead: AtomicBool::new(false),
            retries: AtomicU64::new(0),
            backoff_us: AtomicU64::new(0),
        };
        // a peer whose transport endpoint died before this run (e.g. a
        // TCP node process killed between graphs) starts dead: marking
        // it before seeding diverts its work to survivors from task one
        for n in stores.dead_peers() {
            shared.mark_dead(n);
        }
        // link-retry baseline: the delta this run spends is folded into
        // RecoveryStats.retries below
        let transport_retries0 = stores.transport_retries();
        // seed the deques with initially-ready tasks, in plan order
        {
            let mut st = shared.state.lock().unwrap();
            for i in 0..n_tasks {
                if st.deps[i] == 0 {
                    shared.enqueue(&mut st, i);
                }
            }
        }

        let total_workers = k * self.threads_per_node;
        let deadlock_timeout = self.deadlock_timeout;
        let backend = self.backend.as_ref();
        let topo = &self.topo;
        let shared = &shared;
        let will_produce = &will_produce;
        // fault injection: absent = zero cost (every site is an Option
        // test); the manager carries its own handle for the spill sites
        let fault_ref: Option<&FaultInjector> = self.fault.as_deref();
        if let (Some(mgr), Some(fj)) = (memory, &self.fault) {
            mgr.attach_fault(Arc::clone(fj));
        }
        // "is this object in some live store (or spill file) right now?"
        // — the availability oracle the lineage walk leans on. Takes
        // store/manager locks: never call with the state lock held.
        let available = move |o: ObjectId| -> bool {
            match memory {
                Some(m) => m.holds(stores, o),
                None => stores.fetch(o).is_some(),
            }
        };
        let available = &available;

        // --- communication overlap ------------------------------------
        // One transfer thread per node: background input pulls plus the
        // memory manager's async spill writes. The Arc exists because the
        // manager's spill-sink callback outlives this stack frame's
        // borrows (it is detached before the Arc drops). The queued-pull
        // lookahead is capped at half the node byte budget — pulling
        // further ahead than pressure allows only feeds the evictor.
        let pf_budget = memory.and_then(|m| m.budget).map(|b| (b / 2).max(1));
        let prefetcher = self.prefetch.then(|| {
            let mut pf = Prefetcher::new(k, pf_budget);
            if let Some(r) = &recorder {
                pf = pf.with_recorder(Arc::clone(r));
            }
            if let Some(fj) = &self.fault {
                pf = pf.with_fault(Arc::clone(fj));
            }
            Arc::new(pf)
        });
        let prefetcher_ref: Option<&Prefetcher> = prefetcher.as_deref();
        // topological depth per task (plan order is topological): the
        // transfer threads' pull priority — next-to-run inputs move first
        let depth: Vec<u64> = if self.prefetch {
            let mut producer_depth: HashMap<ObjectId, u64> = HashMap::new();
            let mut d = vec![0u64; n_tasks];
            for (i, t) in plan.tasks.iter().enumerate() {
                d[i] = t
                    .inputs
                    .iter()
                    .filter_map(|o| producer_depth.get(o))
                    .max()
                    .map_or(0, |m| m + 1);
                for (o, _) in &t.outputs {
                    producer_depth.insert(*o, d[i]);
                }
            }
            d
        } else {
            Vec::new()
        };
        let depth = &depth;
        if let (Some(mgr), Some(pf)) = (memory, &prefetcher) {
            let pf2 = Arc::clone(pf);
            mgr.attach_spill_sink(Arc::new(move |node| pf2.notify_spill(node)));
        }
        // the manager emits its own events (managed fetches, spills,
        // read-backs, evictions, GC frees) for this run only
        if let (Some(mgr), Some(r)) = (memory, &recorder) {
            mgr.attach_trace(Arc::clone(r));
        }
        let gc_live = memory.map_or(false, |m| m.lifetime_gc);
        // pulling a GC-released intermediate would resurrect dead bytes:
        // the transfer threads check liveness right before moving data
        let wanted = move |o: ObjectId| -> bool {
            !gc_live
                || !lt.evictable(o)
                || shared.state.lock().unwrap().live.contains_key(&o)
        };
        let spill_oracle = move |o: ObjectId| lt.spillable(o);
        // warm-start: near-ready tasks (≤ 1 unmet dep) can have their
        // *available* remote inputs moved before any kernel runs — the
        // unmet input cannot exist yet, so posting it would only send
        // the transfer thread on a guaranteed-miss cluster scan
        if let Some(pf) = prefetcher_ref {
            if k > 1 {
                let mut warm: Vec<(usize, ObjectId)> = Vec::new();
                {
                    let st = shared.state.lock().unwrap();
                    for i in 0..n_tasks {
                        if st.deps[i] > 1 {
                            continue;
                        }
                        for &obj in &plan.tasks[i].inputs {
                            if st.produced.contains(&obj) {
                                warm.push((i, obj));
                            }
                        }
                    }
                }
                for (i, obj) in warm {
                    pf.request_pull(
                        shared.task_node[i],
                        obj,
                        transfer_hint(plan, topo, i, obj),
                        depth[i],
                        input_bytes_of(plan, i, obj),
                        i,
                    );
                }
            }
        }

        // whole-node loss: wipe the node's store per the spec's mode, mark
        // it dead (its workers finish the task in hand and exit, its
        // queued work drains to the overflow), and proactively splice the
        // recompute subgraph for every wiped object someone still needs.
        // Runs on whichever worker's completion crossed the trigger —
        // never with the state lock held on entry.
        let handle_node_loss = move |spec: NodeLossSpec| {
            shared.mark_dead(spec.node);
            shared.cv.notify_all(); // dead node's parked workers wake to exit
            // objects nothing in the plan consumes = terminal results
            let consumed: HashSet<ObjectId> = plan
                .tasks
                .iter()
                .flat_map(|t| t.inputs.iter().copied())
                .collect();
            let spare = |o: ObjectId| -> bool {
                match spec.mode {
                    NodeLossMode::Total => false,
                    NodeLossMode::Survivable => {
                        // pinned outputs, terminal results, and sole-copy
                        // externals (no lineage — modeling data the
                        // driver can re-put) survive; everything else is
                        // recomputable and fair game
                        lt.is_pinned(o)
                            || pins.contains(&o)
                            || (will_produce.contains(&o) && !consumed.contains(&o))
                            || (!will_produce.contains(&o)
                                && !(0..k)
                                    .any(|n| n != spec.node && stores.contains(n, o)))
                    }
                }
            };
            let lost: Vec<(ObjectId, u64)> = match memory {
                Some(m) => m.wipe_node(stores, spec.node, &spare),
                None => stores
                    .objects(spec.node)
                    .into_iter()
                    .filter(|&o| !spare(o))
                    .filter_map(|o| {
                        stores.remove(spec.node, o).map(|b| (o, b.bytes()))
                    })
                    .collect(),
            };
            let lost_bytes: u64 = lost.iter().map(|&(_, b)| b).sum();
            if let Some(r) = recorder_ref {
                r.event(spec.node, None, None, lost_bytes, EventKind::NodeLoss);
            }
            // a wiped object with a surviving replica is not gone; of the
            // truly gone, only those an incomplete task still needs are
            // worth recomputing now (the lazy vanish path backstops any
            // this snapshot misses)
            let gone_objs: Vec<ObjectId> = lost
                .iter()
                .map(|&(o, _)| o)
                .filter(|&o| !available(o))
                .collect();
            let completed_snap: Vec<bool> =
                shared.state.lock().unwrap().completed.clone();
            let needed: Vec<ObjectId> = gone_objs
                .iter()
                .copied()
                .filter(|o| {
                    shared
                        .consumers
                        .get(o)
                        .map_or(false, |cs| cs.iter().any(|&c| !completed_snap[c]))
                })
                .collect();
            let redo = if needed.is_empty() {
                Vec::new()
            } else {
                match recovery::plan_recompute(plan, &needed, available) {
                    Ok(t) => t,
                    Err(e) => {
                        shared.fail(e);
                        return;
                    }
                }
            };
            let gone = gone_set(plan, &redo, &gone_objs, available);
            let mut loads = node_loads(stores, k);
            let mut st = shared.state.lock().unwrap();
            // the dead node's queued work goes to survivors
            while let Some(t) = st.ready[spec.node].pop_front() {
                st.overflow.push_back(t);
            }
            st.node_losses.push((spec.node, lost));
            for &o in &gone_objs {
                st.produced.remove(&o);
            }
            if !redo.is_empty() {
                st.recovery_rounds += 1;
                splice_recovery(shared, &mut st, plan, &redo, &gone, &mut loads);
            }
            drop(st);
            shared.cv.notify_all();
        };
        let handle_node_loss = &handle_node_loss;

        std::thread::scope(|scope| {
            if let Some(pf) = prefetcher_ref {
                for node in 0..k {
                    let wanted = &wanted;
                    let spill_oracle = &spill_oracle;
                    scope.spawn(move || {
                        pf.serve(node, stores, memory, spill_oracle, wanted)
                    });
                }
            }
            let mut workers = Vec::with_capacity(total_workers);
            for node in 0..k {
                for wk in 0..self.threads_per_node {
                    let stealing = self.stealing;
                    let tier = self.tier;
                    let worker_id = node * self.threads_per_node + wk;
                    workers.push(scope.spawn(move || {
                        let me = node;
                        let ctx =
                            ExecContext::shared(total_workers, me, stealing).with_tier(tier);
                        // span ring: sized once here — pushing a span on
                        // the hot path allocates nothing (the kernel
                        // label stays empty until post-run resolution)
                        let mut ring: Option<SpanRing<TaskSpan>> =
                            recorder_ref.map(|_| SpanRing::new(n_tasks));
                        'work: loop {
                            if shared.has_failed() {
                                break 'work;
                            }
                            // transport-detected peer deaths (a killed
                            // TCP node process, a link that never came
                            // back) are converted into the scheduled
                            // node-loss path here, exactly once each
                            while let Some(n) = stores.take_dead_peer() {
                                handle_node_loss(NodeLossSpec {
                                    node: n,
                                    after_tasks: 0,
                                    mode: NodeLossMode::Survivable,
                                });
                            }
                            if shared.is_dead(me) {
                                // this node's store was wiped: pick up
                                // nothing new here (survivors drain the
                                // diverted work)
                                break 'work;
                            }
                            let mut st = shared.state.lock().unwrap();
                            if st.remaining == 0 {
                                drop(st);
                                shared.cv.notify_all();
                                break 'work;
                            }
                            let mut steal_info: Option<StealInfo> = None;
                            let picked = match shared.pick(&mut st, me) {
                                Pick::Run(i) => Some(i),
                                Pick::Steal(v) => {
                                    shared.steal_from(&mut st, v, me, &mut steal_info)
                                }
                                Pick::Score(cands) => {
                                    // lock-ordering fix (ROADMAP): score
                                    // store residency with the state lock
                                    // dropped; the steal re-validates
                                    drop(st);
                                    let victim = best_victim(&cands, |t| {
                                        shared.input_bytes[t]
                                            .iter()
                                            .filter(|&&(o, _)| !stores.contains(me, o))
                                            .map(|&(_, b)| b)
                                            .sum()
                                    });
                                    st = shared.state.lock().unwrap();
                                    let got = victim.and_then(|v| {
                                        shared.steal_from(&mut st, v, me, &mut steal_info)
                                    });
                                    if got.is_none() {
                                        // the snapshot went stale while the
                                        // lock was down: re-pick, don't park
                                        drop(st);
                                        continue;
                                    }
                                    got
                                }
                                Pick::Idle => None,
                            };
                            let Some(idx) = picked else {
                                // idle. Provably stuck? (nothing queued
                                // anywhere, nothing running, work left)
                                let all_empty = st.overflow.is_empty()
                                    && st.ready.iter().all(|q| q.is_empty());
                                if st.running == 0 && all_empty {
                                    // recovery trigger first, panic second:
                                    // a stuck run whose missing inputs
                                    // still have lineage is a recompute,
                                    // not a deadlock
                                    if st.recovery_rounds < MAX_RECOVERY_ROUNDS {
                                        let stuck = missing_inputs(plan, &st, None);
                                        drop(st);
                                        // never-satisfiable inputs were never
                                        // present — that is the provable
                                        // deadlock below, not a loss with
                                        // lineage to walk
                                        let lost: Vec<ObjectId> = stuck
                                            .into_iter()
                                            .filter(|&o| {
                                                !shared.never_satisfied.contains(&o)
                                                    && !available(o)
                                            })
                                            .collect();
                                        let mut spliced = false;
                                        if !lost.is_empty() {
                                            match recovery::plan_recompute(
                                                plan, &lost, available,
                                            ) {
                                                Ok(redo) if !redo.is_empty() => {
                                                    let gone = gone_set(
                                                        plan, &redo, &lost,
                                                        available,
                                                    );
                                                    let mut loads =
                                                        node_loads(stores, k);
                                                    let mut st2 =
                                                        shared.state.lock().unwrap();
                                                    st2.recovery_rounds += 1;
                                                    splice_recovery(
                                                        shared, &mut st2, plan,
                                                        &redo, &gone, &mut loads,
                                                    );
                                                    drop(st2);
                                                    shared.cv.notify_all();
                                                    spliced = true;
                                                }
                                                Ok(_) => {}
                                                Err(e) => {
                                                    shared.fail(e);
                                                    break 'work;
                                                }
                                            }
                                        }
                                        if spliced {
                                            continue;
                                        }
                                        // nothing recoverable: re-confirm the
                                        // stuck condition before declaring death
                                        st = shared.state.lock().unwrap();
                                        let still_stuck = st.remaining > 0
                                            && st.running == 0
                                            && st.overflow.is_empty()
                                            && st.ready.iter().all(|q| q.is_empty());
                                        if !still_stuck {
                                            drop(st);
                                            continue;
                                        }
                                    }
                                    let never = missing_inputs(
                                        plan,
                                        &st,
                                        Some(&shared.never_satisfied),
                                    );
                                    let err = if never.is_empty() {
                                        // every missing input has a producer,
                                        // yet nothing can run: a cycle
                                        let all = missing_inputs(plan, &st, None);
                                        ExecError::Deadlock {
                                            plan_tasks: n_tasks,
                                            missing: all,
                                            cycle: true,
                                        }
                                    } else {
                                        ExecError::Deadlock {
                                            plan_tasks: n_tasks,
                                            missing: never,
                                            cycle: false,
                                        }
                                    };
                                    drop(st);
                                    shared.fail(err);
                                    break 'work;
                                }
                                // park until something completes; the timeout
                                // is only a re-check heartbeat — a running
                                // kernel, however slow, is never declared dead
                                let (g, _timeout) = shared
                                    .cv
                                    .wait_timeout(st, deadlock_timeout)
                                    .unwrap();
                                drop(g);
                                continue;
                            };
                            // span timestamps: ready_at was stamped at
                            // enqueue (batch-stolen tasks keep theirs)
                            let ready_t = recorder_ref
                                .map_or(0.0, |_| st.ready_at.get(idx).copied().unwrap_or(0.0));
                            st.running += 1;
                            drop(st);
                            let start_t = recorder_ref.map_or(0.0, |r| r.now());
                            if let (Some(r), Some(si)) = (recorder_ref, &steal_info) {
                                r.event(
                                    me,
                                    Some(si.victim),
                                    None,
                                    (si.queued.len() + 1) as u64,
                                    EventKind::Steal,
                                );
                            }
                            if let (Some(pf), Some(si)) = (prefetcher_ref, &steal_info) {
                                // the migrated tasks' pulls toward the
                                // victim are dead weight now: withdraw
                                // exactly their interest (a job with no
                                // surviving requester is dropped
                                // unexecuted and never accounts a byte;
                                // other tasks' requests are untouched) ...
                                for &t in si.queued.iter().chain(std::iter::once(&si.first)) {
                                    for &obj in &plan.tasks[t].inputs {
                                        pf.cancel_pull(si.victim, obj, t);
                                    }
                                }
                                // ... then re-route the still-queued batch
                                // here, skipping inputs already resident on
                                // this node (those are cancelled outright,
                                // not re-queued)
                                for &t in &si.queued {
                                    post_prefetch(pf, plan, topo, me, t, depth[t], Some(stores));
                                }
                            }

                            let task = &plan.tasks[idx];
                            let stolen = shared.task_node[idx] != me;
                            // collect inputs on this node (real bytes; a
                            // stolen task pays its cross-node transfers;
                            // the manager pages spilled inputs back in)
                            let mut moved = 0u64;
                            let mut hits: u32 = 0;
                            let mut vanished = None;
                            let mut inputs: Vec<Arc<Block>> =
                                Vec::with_capacity(task.inputs.len());
                            for &obj in &task.inputs {
                                // injected transfer fault: the pull "fails"
                                // before any byte moves; backoff and re-ask —
                                // the injector's per-key cap guarantees the
                                // bounded retry wins, and only then does the
                                // real (exactly-once-accounted) pull below run
                                if let Some(fj) = fault_ref {
                                    let mut attempt = 0u32;
                                    while !stores.contains(me, obj)
                                        && fj.should_fail(FaultSite::Transfer, obj)
                                    {
                                        if let Some(r) = recorder_ref {
                                            r.event(
                                                me,
                                                None,
                                                Some(obj),
                                                0,
                                                EventKind::Fault,
                                            );
                                        }
                                        let d = recovery::backoff_delay(attempt);
                                        shared.backoff_us.fetch_add(
                                            d.as_micros() as u64,
                                            Ordering::Relaxed,
                                        );
                                        shared.retries.fetch_add(1, Ordering::Relaxed);
                                        std::thread::sleep(d);
                                        if let Some(r) = recorder_ref {
                                            r.event(
                                                me,
                                                None,
                                                Some(obj),
                                                0,
                                                EventKind::Retry,
                                            );
                                        }
                                        attempt += 1;
                                    }
                                }
                                let before = moved;
                                let got = match memory {
                                    Some(mgr) => {
                                        // the manager emits the fetch event
                                        // itself (it knows the source node)
                                        let (b, m) =
                                            mgr.acquire(stores, me, obj, &|o| lt.spillable(o));
                                        moved += m;
                                        b
                                    }
                                    None => {
                                        if !stores.contains(me, obj) {
                                            if let Some(src) = stores.locate(obj, me) {
                                                // try_transfer, not transfer:
                                                // with remote sources a copy
                                                // that vanished (or a link that
                                                // died) mid-pull must surface
                                                // as a recoverable loss — the
                                                // vanish path below — never as
                                                // a panic
                                                if let Some(n) =
                                                    stores.try_transfer(src, me, obj)
                                                {
                                                    moved += n;
                                                    if n > 0 {
                                                        if let Some(r) = recorder_ref {
                                                            r.event(
                                                                me,
                                                                Some(src),
                                                                Some(obj),
                                                                n,
                                                                EventKind::Fetch(
                                                                    FetchOrigin::Demand,
                                                                ),
                                                            );
                                                        }
                                                    }
                                                }
                                            }
                                        }
                                        stores.get(me, obj)
                                    }
                                };
                                match got {
                                    Some(b) => {
                                        if let Some(pf) = prefetcher_ref {
                                            // resident without paying bytes,
                                            // and a prefetch completed here:
                                            // the overlap did its job
                                            if moved == before
                                                && pf.was_prefetched(me, obj)
                                            {
                                                pf.add_hit(me);
                                                hits += 1;
                                            }
                                        }
                                        inputs.push(b)
                                    }
                                    None => {
                                        vanished = Some(obj);
                                        break;
                                    }
                                }
                            }
                            let fetch_end_t = recorder_ref.map_or(0.0, |r| r.now());
                            if let Some(pf) = prefetcher_ref {
                                if moved > 0 {
                                    pf.add_demand(me, moved);
                                }
                            }
                            if let Some(obj) = vanished {
                                // an input disappeared between readiness and
                                // collection — a wiped node, a corrupt spill
                                // readback, a lost sole copy. Lineage
                                // recovery: re-gate this task on the object's
                                // producer and splice the minimal recompute
                                // subgraph. `running` stays held until the
                                // splice lands (or the failure is recorded),
                                // so a parked worker's heartbeat can never
                                // see running==0 mid-recovery and declare a
                                // bogus deadlock.
                                drop(inputs);
                                // a transport-detected peer death may be
                                // *why* the input vanished: wipe and
                                // splice for it first, so the
                                // availability check below sees the
                                // post-loss world, not a stale one
                                while let Some(n) = stores.take_dead_peer() {
                                    handle_node_loss(NodeLossSpec {
                                        node: n,
                                        after_tasks: 0,
                                        mode: NodeLossMode::Survivable,
                                    });
                                }
                                if available(obj) {
                                    // raced back into residency (late
                                    // readback/transfer): just retry the task
                                    let mut st = shared.state.lock().unwrap();
                                    st.running -= 1;
                                    shared.enqueue(&mut st, idx);
                                    drop(st);
                                    shared.cv.notify_all();
                                    continue 'work;
                                }
                                match recovery::plan_recompute(
                                    plan,
                                    &[obj],
                                    available,
                                ) {
                                    Err(e) => {
                                        shared.fail(e);
                                        shared.state.lock().unwrap().running -= 1;
                                        break 'work;
                                    }
                                    Ok(redo) => {
                                        let gone = gone_set(
                                            plan, &redo, &[obj], available,
                                        );
                                        let mut loads = node_loads(stores, k);
                                        let mut st = shared.state.lock().unwrap();
                                        if st.recovery_rounds >= MAX_RECOVERY_ROUNDS {
                                            drop(st);
                                            shared.fail(ExecError::ObjectLost {
                                                obj,
                                                task: idx,
                                            });
                                            shared.state.lock().unwrap().running -= 1;
                                            break 'work;
                                        }
                                        st.recovery_rounds += 1;
                                        // re-gate this task on the missing
                                        // object: its producer's completion
                                        // decrements this extra dep through
                                        // the normal consumer path
                                        st.deps[idx] += 1;
                                        splice_recovery(
                                            shared, &mut st, plan, &redo, &gone,
                                            &mut loads,
                                        );
                                        st.running -= 1;
                                        drop(st);
                                        shared.cv.notify_all();
                                        continue 'work;
                                    }
                                }
                            }
                            let in_refs: Vec<&Block> =
                                inputs.iter().map(|b| b.as_ref()).collect();
                            // injected kernel fault: fails *before* the
                            // kernel runs (no partial side effects to undo),
                            // retried in place with bounded backoff. Real
                            // kernel panics below are NOT retried — a
                            // deterministic panic would just panic again.
                            let mut injected_failure: Option<Result<Vec<Block>>> = None;
                            if let Some(fj) = fault_ref {
                                let mut attempt = 0u32;
                                while fj.should_fail(FaultSite::Kernel, idx as u64) {
                                    if let Some(r) = recorder_ref {
                                        r.event(me, None, None, 0, EventKind::Fault);
                                    }
                                    if attempt >= MAX_TRANSIENT_RETRIES {
                                        injected_failure = Some(Err(anyhow!(
                                            "injected kernel fault exhausted \
                                             {MAX_TRANSIENT_RETRIES} retries"
                                        )));
                                        break;
                                    }
                                    let d = recovery::backoff_delay(attempt);
                                    shared.backoff_us.fetch_add(
                                        d.as_micros() as u64,
                                        Ordering::Relaxed,
                                    );
                                    shared.retries.fetch_add(1, Ordering::Relaxed);
                                    std::thread::sleep(d);
                                    if let Some(r) = recorder_ref {
                                        r.event(me, None, None, 0, EventKind::Retry);
                                    }
                                    attempt += 1;
                                }
                            }
                            // catch kernel panics (e.g. cholesky on an
                            // indefinite block): a panicking task must fail
                            // the run, not leave `running` pinned and the
                            // pool hung
                            let executed = if let Some(err) = injected_failure {
                                err
                            } else {
                                std::panic::catch_unwind(
                                    std::panic::AssertUnwindSafe(|| {
                                        backend.execute(&task.kernel, &in_refs, &ctx)
                                    }),
                                )
                                .unwrap_or_else(|p| {
                                    let why = p
                                        .downcast_ref::<String>()
                                        .cloned()
                                        .or_else(|| {
                                            p.downcast_ref::<&str>().map(|s| s.to_string())
                                        })
                                        .unwrap_or_else(|| "kernel panicked".into());
                                    Err(anyhow!("panic: {why}"))
                                })
                            };
                            match executed {
                                Ok(outs) => {
                                    for ((obj, _), block) in task.outputs.iter().zip(outs) {
                                        let block = Arc::new(block);
                                        match memory {
                                            Some(mgr) => mgr.insert(
                                                stores,
                                                me,
                                                *obj,
                                                block,
                                                &|o| lt.spillable(o),
                                            ),
                                            None => stores.put(me, *obj, block),
                                        }
                                    }
                                    // outputs are visible: close the span.
                                    // `String::new()` does not allocate —
                                    // the label resolves in finish()
                                    if let (Some(r), Some(ring)) =
                                        (recorder_ref, ring.as_mut())
                                    {
                                        ring.push(TaskSpan {
                                            task: idx,
                                            node: me,
                                            worker: worker_id,
                                            stolen,
                                            threads: ctx.kernel_threads,
                                            tier,
                                            prefetch_hits: hits,
                                            ready_t,
                                            start_t,
                                            fetch_end_t,
                                            end_t: r.now(),
                                            fetch_bytes: moved,
                                            kernel: String::new(),
                                        });
                                    }
                                    let mut st = shared.state.lock().unwrap();
                                    st.completed[idx] = true;
                                    st.remaining -= 1;
                                    st.running -= 1;
                                    st.stats[me].tasks_run += 1;
                                    if stolen {
                                        st.stats[me].tasks_stolen += 1;
                                        st.stats[me].steal_bytes += moved;
                                    }
                                    // a lineage-recovery re-execution: tally
                                    // it (and trace it, after unlocking) so
                                    // recovery_stats reconcile with the
                                    // recompute trace events byte-for-byte
                                    let recovered = st.recovering.remove(&idx);
                                    let re_bytes = if recovered {
                                        out_bytes_of(plan, idx)
                                    } else {
                                        0
                                    };
                                    if recovered {
                                        st.recomputed_tasks += 1;
                                        st.recomputed_bytes += re_bytes;
                                    }
                                    let completed_now = n_tasks - st.remaining;
                                    // tasks brought within ≤ 1 unmet dep:
                                    // their available inputs can start
                                    // moving now (the still-unmet one
                                    // cannot exist yet — not posted)
                                    let mut warm: Vec<(usize, ObjectId)> = Vec::new();
                                    for (obj, _) in &task.outputs {
                                        st.produced.insert(*obj);
                                        if let Some(cs) = shared.consumers.get(obj) {
                                            for &c in cs {
                                                // guard: a malformed plan with two
                                                // producers of one object must not
                                                // underflow the count — the first
                                                // producer releases the consumer
                                                // (matching the old produced-set
                                                // executor), later ones are no-ops
                                                if st.deps[c] > 0 {
                                                    st.deps[c] -= 1;
                                                    if st.deps[c] == 0 {
                                                        shared.enqueue(&mut st, c);
                                                    }
                                                    if prefetcher_ref.is_some()
                                                        && k > 1
                                                        && st.deps[c] <= 1
                                                    {
                                                        for &inp in
                                                            &plan.tasks[c].inputs
                                                        {
                                                            if st.produced
                                                                .contains(&inp)
                                                            {
                                                                warm.push((c, inp));
                                                            }
                                                        }
                                                    }
                                                }
                                            }
                                        }
                                    }
                                    // lifetime GC: inputs whose last
                                    // consumer just finished are dead
                                    let mut dead: Vec<ObjectId> = Vec::new();
                                    for &obj in &task.inputs {
                                        if let Some(c) = st.live.get_mut(&obj) {
                                            *c -= 1;
                                            if *c == 0 {
                                                st.live.remove(&obj);
                                                dead.push(obj);
                                            }
                                        }
                                    }
                                    st.released.extend_from_slice(&dead);
                                    drop(st);
                                    shared.cv.notify_all();
                                    if recovered {
                                        if let Some(r) = recorder_ref {
                                            r.event(
                                                me,
                                                None,
                                                None,
                                                re_bytes,
                                                EventKind::Recompute,
                                            );
                                        }
                                    }
                                    if let Some(pf) = prefetcher_ref {
                                        for &(c, obj) in &warm {
                                            // never feed a wiped node's store
                                            if shared.is_dead(shared.task_node[c]) {
                                                continue;
                                            }
                                            pf.request_pull(
                                                shared.task_node[c],
                                                obj,
                                                transfer_hint(plan, topo, c, obj),
                                                depth[c],
                                                input_bytes_of(plan, c, obj),
                                                c,
                                            );
                                        }
                                    }
                                    if let Some(mgr) = memory {
                                        // outside the state lock: release
                                        // takes manager + store locks
                                        for obj in dead {
                                            mgr.release(stores, obj);
                                        }
                                    }
                                    // scheduled whole-node loss: fires on the
                                    // completion that crosses the trigger
                                    if let Some(fj) = fault_ref {
                                        if let Some(spec) =
                                            fj.take_node_loss(completed_now)
                                        {
                                            handle_node_loss(spec);
                                        }
                                    }
                                }
                                Err(e) => {
                                    // fail first, then release `running`
                                    // (same masking hazard as above)
                                    shared.fail(ExecError::TaskFailed {
                                        task: idx,
                                        kernel: format!("{}", task.kernel),
                                        reason: e.to_string(),
                                    });
                                    shared.state.lock().unwrap().running -= 1;
                                    break 'work;
                                }
                            }
                        }
                        // one drain per worker, after the last task
                        if let (Some(r), Some(ring)) = (recorder_ref, ring.take()) {
                            r.drain_spans(ring);
                        }
                    }));
                }
            }
            // join the workers first, then stop the transfer threads:
            // serve() drains its whole queue before exiting, so the scope
            // join below is the async-spill write-completion barrier. A
            // worker panic (an executor bug, not a kernel panic — those
            // are caught) is re-raised only after the transfer threads
            // are told to stop, so the scope can still close.
            let mut panicked = None;
            for w in workers {
                if let Err(p) = w.join() {
                    panicked.get_or_insert(p);
                }
            }
            if let Some(pf) = prefetcher_ref {
                pf.shutdown();
            }
            if let Some(p) = panicked {
                std::panic::resume_unwind(p);
            }
        });
        // execution (workers + transfer threads) is over: sample the wall
        // clock before teardown/reconciliation bookkeeping, so ablation
        // wall times measure execution, not feedback collection
        let wall_secs = sw.secs();

        // overlap teardown: the transfer threads are gone, so detach the
        // spill sink (back to synchronous writes) and finalize any spill
        // entry that slipped past the drain
        if prefetcher_ref.is_some() {
            if let Some(mgr) = memory {
                mgr.detach_spill_sink();
                mgr.sweep_pending_spills(stores);
            }
        }
        if let Some(err) = shared.failed.lock().unwrap().take() {
            if let (Some(mgr), true) = (memory, recorder.is_some()) {
                mgr.detach_trace();
            }
            if let (Some(mgr), true) = (memory, self.fault.is_some()) {
                mgr.detach_fault();
            }
            // the typed ExecError rides the anyhow boundary as a payload:
            // Session::run callers can downcast_ref::<ExecError>() it back
            return Err(err.into());
        }
        let (stats, released, recovery_stats, node_losses) = {
            let st = shared.state.lock().unwrap();
            let rs = RecoveryStats {
                // injected-fault retries plus transient link retries the
                // transport spent this run: one retry economy
                retries: shared.retries.load(Ordering::Relaxed)
                    + (stores.transport_retries() - transport_retries0),
                backoff_secs: shared.backoff_us.load(Ordering::Relaxed) as f64 / 1e6,
                recomputed_tasks: st.recomputed_tasks,
                recomputed_bytes: st.recomputed_bytes,
                node_losses_survived: st.node_losses.len() as u64,
            };
            (st.stats.clone(), st.released.clone(), rs, st.node_losses.clone())
        };
        if let (Some(mgr), true) = (memory, self.fault.is_some()) {
            mgr.detach_fault();
        }
        if let Some(mgr) = memory {
            // a prefetch racing a release can resurrect a dead
            // intermediate as a replica; with the transfer threads
            // quiesced, a second release is deterministic and final
            for &obj in &released {
                mgr.release(stores, obj);
            }
        }
        // the re-release above still emitted (a resurrected replica freed
        // there is part of this run); from here on the manager is silent,
        // so event byte totals match this run's `mem_stats` exactly
        if let (Some(mgr), true) = (memory, recorder.is_some()) {
            mgr.detach_trace();
        }
        let mem_stats = match (memory, mem_start) {
            (Some(m), Some(s0)) => m
                .stats()
                .iter()
                .zip(&s0)
                .map(|(now, start)| now.delta(start))
                .collect(),
            _ => Vec::new(),
        };
        let prefetch_stats = prefetcher_ref.map(|p| p.stats()).unwrap_or_default();
        // reconcile plan vs observation (steals, demand misses, spill
        // pressure, replicas) so the session can feed the next plan
        let store_snapshot = stores.snapshot();
        let replicas = memory
            .map(|m| m.resident_replicas(stores))
            .unwrap_or_default();
        let feedback = RuntimeFeedback::collect(
            plan,
            &self.topo,
            &snap_start,
            &store_snapshot,
            &stats,
            &prefetch_stats,
            &mem_stats,
            replicas,
        );
        let trace = recorder.as_ref().map(|r| r.finish(plan, &self.topo));
        Ok(RealReport {
            wall_secs,
            tasks: plan.len(),
            store_snapshot,
            node_stats: stats,
            mem_stats,
            prefetch_stats,
            gc_released: released,
            feedback,
            trace,
            recovery_stats,
            node_losses,
        })
    }
}

/// Source-node hint for pulling input `obj` of task `i`: the
/// scheduler's committed transfer decision ([`crate::exec::Transfer`]),
/// whose `src` is a placement target, mapped to its physical node.
fn transfer_hint(plan: &Plan, topo: &Topology, i: usize, obj: ObjectId) -> Option<usize> {
    plan.tasks[i]
        .transfers
        .iter()
        .find(|tr| tr.obj == obj)
        .map(|tr| topo.node_of(tr.src))
}

/// Bytes of input `obj` of task `i` (first matching input position).
fn input_bytes_of(plan: &Plan, i: usize, obj: ObjectId) -> u64 {
    let t = &plan.tasks[i];
    t.inputs
        .iter()
        .position(|&o| o == obj)
        .map(|p| t.in_shapes[p].iter().map(|&d| d as u64).product::<u64>() * 8)
        .unwrap_or(0)
}

/// Queue background pulls for every input of a *ready* task `i` toward
/// `node` at priority `prio` (used when a batch steal migrates queued
/// tasks to a thief — deps == 0, so every input exists somewhere). With
/// `stores`, inputs already resident at `node` are skipped outright;
/// already-requested inputs are deduped by the prefetcher.
fn post_prefetch(
    pf: &Prefetcher,
    plan: &Plan,
    topo: &Topology,
    node: usize,
    i: usize,
    prio: u64,
    stores: Option<&StoreSet>,
) {
    let t = &plan.tasks[i];
    for (&obj, shape) in t.inputs.iter().zip(&t.in_shapes) {
        if stores.map_or(false, |s| s.contains(node, obj)) {
            continue;
        }
        let bytes = shape.iter().map(|&d| d as u64).product::<u64>() * 8;
        pf.request_pull(node, obj, transfer_hint(plan, topo, i, obj), prio, bytes, i);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::task::{Plan, Task};
    use crate::net::model::SystemMode;
    use crate::runtime::kernel::{BinOp, Kernel};
    use crate::store::Block;

    #[test]
    fn deadlock_error_names_the_blocking_objects() {
        let topo = Topology::new(1, 1, SystemMode::Ray);
        let mut ex = RealExecutor::new(topo, Arc::new(Backend::native()));
        ex.deadlock_timeout = Duration::from_millis(50);
        let stores = StoreSet::new(1);
        stores.put(0, 7, Arc::new(Block::from_vec(&[1, 1], vec![1.0])));
        // input 99 is never produced -> provable deadlock, named
        let plan = Plan {
            tasks: vec![Task {
                kernel: Kernel::Ew(BinOp::Add),
                inputs: vec![7, 99],
                in_shapes: vec![vec![1, 1], vec![1, 1]],
                outputs: vec![(100, vec![1, 1])],
                target: 0,
                transfers: vec![],
            }],
        };
        let msg = format!("{}", ex.run(&plan, &stores).unwrap_err());
        assert!(msg.contains("deadlock"), "{msg}");
        assert!(msg.contains("[99]"), "must name the missing input: {msg}");
        assert!(msg.contains("NUMS_DEADLOCK_TIMEOUT_SECS"), "{msg}");
    }

    #[test]
    fn timeout_env_override_parses() {
        assert_eq!(
            parse_deadlock_timeout(Some("0.25".into())),
            Duration::from_millis(250)
        );
        assert_eq!(
            parse_deadlock_timeout(Some("-3".into())),
            Duration::from_secs(30)
        );
        assert_eq!(
            parse_deadlock_timeout(Some("nope".into())),
            Duration::from_secs(30)
        );
        // absurdly large values must not overflow Duration construction
        assert_eq!(
            parse_deadlock_timeout(Some("1e30".into())),
            Duration::from_secs(30)
        );
        assert_eq!(parse_deadlock_timeout(None), Duration::from_secs(30));
    }

    #[test]
    fn dependency_chain_executes_in_order() {
        // a -> b -> c across 2 nodes: dependency counting must release
        // each task only after its producer completes
        let topo = Topology::new(2, 2, SystemMode::Ray);
        let ex = RealExecutor::new(topo, Arc::new(Backend::native()));
        let stores = StoreSet::new(2);
        stores.put(0, 1, Arc::new(Block::from_vec(&[1, 1], vec![2.0])));
        let mk = |inputs: Vec<u64>, out: u64, target: usize| Task {
            kernel: Kernel::Scale(3.0),
            inputs,
            in_shapes: vec![vec![1, 1]],
            outputs: vec![(out, vec![1, 1])],
            target,
            transfers: vec![],
        };
        let plan = Plan {
            tasks: vec![mk(vec![1], 10, 0), mk(vec![10], 11, 1), mk(vec![11], 12, 0)],
        };
        let rep = ex.run(&plan, &stores).unwrap();
        assert_eq!(rep.tasks, 3);
        let out = stores.fetch(12).unwrap();
        assert_eq!(out.buf(), &[2.0 * 27.0]);
        let total: usize = rep.node_stats.iter().map(|s| s.tasks_run).sum();
        assert_eq!(total, 3);
    }

    #[test]
    fn kernel_panic_fails_the_run_instead_of_hanging() {
        let topo = Topology::new(1, 2, SystemMode::Ray);
        let ex = RealExecutor::new(topo, Arc::new(Backend::native()));
        let stores = StoreSet::new(1);
        // indefinite matrix: the cholesky kernel asserts (panics)
        let mut m = Block::zeros(&[2, 2]);
        m.set2(0, 0, 1.0);
        m.set2(1, 1, -1.0);
        stores.put(0, 1, Arc::new(m));
        let plan = Plan {
            tasks: vec![Task {
                kernel: Kernel::Cholesky,
                inputs: vec![1],
                in_shapes: vec![vec![2, 2]],
                outputs: vec![(2, vec![2, 2])],
                target: 0,
                transfers: vec![],
            }],
        };
        let err = format!("{}", ex.run(&plan, &stores).unwrap_err());
        assert!(err.contains("panic"), "{err}");
        assert!(err.contains("Cholesky"), "{err}");
    }

    #[test]
    fn best_victim_prefers_local_inputs_then_depth() {
        // three snapshotted candidates (node, back task, deque len); the
        // missing-bytes oracle says task 20 is fully resident on the thief
        let cands = [(1usize, 11usize, 2usize), (2, 20, 1), (3, 32, 3)];
        let miss = |t: usize| match t {
            20 => 0u64,
            _ => 800,
        };
        assert_eq!(best_victim(&cands, miss), Some(2));
        // equal misses: the deeper deque wins
        assert_eq!(best_victim(&cands, |_| 64), Some(3));
        // nothing to steal
        assert_eq!(best_victim(&[], |_| 0u64), None);
    }

    #[test]
    fn batch_steal_threshold_tracks_observed_imbalance() {
        // canonical skew: 40 ready tasks on 4 nodes -> mean 10, batch at 20
        assert_eq!(batch_steal_threshold(40, 4), 20);
        // near-balanced: 4 tasks per node -> threshold above any deque, so
        // steals stay single-task (the old hardcoded 4 would batch here)
        assert_eq!(batch_steal_threshold(16, 4), 8);
        // odd full skew on 2 nodes must still batch: threshold ≤ vlen
        // (ceiling division would put it at vlen + 1 and never batch)
        assert_eq!(batch_steal_threshold(7, 2), 6);
        assert!(batch_steal_threshold(7, 2) <= 7);
        // tiny skew: everything on one node still batches early
        assert_eq!(batch_steal_threshold(3, 4), 2);
        // floor: never below MIN_BATCH_STEAL, even when almost empty
        assert_eq!(batch_steal_threshold(0, 4), MIN_BATCH_STEAL);
        assert_eq!(batch_steal_threshold(1, 1), MIN_BATCH_STEAL);
    }

    #[test]
    fn managed_run_releases_dead_intermediates_and_lowers_peak() {
        // chain seeded(1) -> 10 -> 11 -> ... on one node: without GC every
        // intermediate stays resident; with GC only ~2 blocks live at once
        let chain_len = 8usize;
        let n = 32usize;
        let mk_plan = || Plan {
            tasks: (0..chain_len)
                .map(|i| Task {
                    kernel: Kernel::Scale(1.5),
                    inputs: vec![if i == 0 { 1 } else { 9 + i as u64 }],
                    in_shapes: vec![vec![n, n]],
                    outputs: vec![(10 + i as u64, vec![n, n])],
                    target: 0,
                    transfers: vec![],
                })
                .collect(),
        };
        let run = |managed: bool| {
            let topo = Topology::new(1, 1, SystemMode::Ray);
            let mut ex = RealExecutor::new(topo, Arc::new(Backend::native()));
            ex.threads_per_node = 1;
            if managed {
                ex = ex.with_memory(crate::store::MemoryManager::new(1, None, true));
            }
            let stores = StoreSet::new(1);
            stores.put(0, 1, Arc::new(Block::filled(&[n, n], 2.0)));
            let rep = ex.run(&mk_plan(), &stores).unwrap();
            let last = 9 + chain_len as u64;
            let out = match &ex.memory {
                Some(m) => m.fetch(&stores, last).unwrap(),
                None => stores.fetch(last).unwrap(),
            };
            // pinned terminal outputs must stay resident (not just
            // recoverable from a spill file)
            let terminal_resident = stores.contains(0, last);
            (rep, out.as_ref().clone(), terminal_resident)
        };
        let (plain, out_plain, _) = run(false);
        let (managed, out_managed, terminal_resident) = run(true);
        assert_eq!(out_plain.max_abs_diff(&out_managed), 0.0);
        let block_bytes = (n * n * 8) as u64;
        // unmanaged: seed + all chain outputs resident at peak
        assert_eq!(plain.store_snapshot[0].1, (chain_len as u64 + 1) * block_bytes);
        // managed: seed (external, never released) + at most two chain
        // blocks (current input + output) at any instant
        assert!(
            managed.store_snapshot[0].1 <= 3 * block_bytes,
            "GC peak {} > 3 blocks",
            managed.store_snapshot[0].1
        );
        assert!(managed.store_snapshot[0].1 < plain.store_snapshot[0].1);
        let freed: u64 = managed.mem_stats.iter().map(|s| s.gc_freed_bytes).sum();
        assert_eq!(freed, (chain_len as u64 - 1) * block_bytes);
        assert!(terminal_resident, "pinned terminal output was paged out");
    }

    #[test]
    fn managed_run_with_budget_spills_and_reads_back() {
        // 6 producers then a consumption fold: under a 3-block budget the
        // cold producer outputs spill and are read back for the adds
        let n = 16usize;
        let k = 6usize;
        let block_bytes = (n * n * 8) as u64;
        let (plan, acc) = crate::bench::harness::produce_fold_plan(k, n);
        let run = |budget: Option<u64>| {
            let topo = Topology::new(1, 1, SystemMode::Ray);
            let mut ex = RealExecutor::new(topo, Arc::new(Backend::native()));
            ex.threads_per_node = 1;
            ex = ex.with_memory(crate::store::MemoryManager::new(1, budget, true));
            let stores = StoreSet::new(1);
            stores.put(0, 1, Arc::new(Block::filled(&[n, n], 1.0)));
            let rep = ex.run(&plan, &stores).unwrap();
            let out = ex
                .memory
                .as_ref()
                .unwrap()
                .fetch(&stores, acc)
                .expect("final output must be fetchable");
            (rep, out.as_ref().clone())
        };
        let (free_rep, free_out) = run(None);
        let (tight_rep, tight_out) = run(Some(3 * block_bytes));
        assert_eq!(free_out.max_abs_diff(&tight_out), 0.0, "spill changed numerics");
        assert_eq!(free_rep.mem_stats[0].spilled_bytes, 0);
        assert!(
            tight_rep.mem_stats[0].spilled_bytes > 0,
            "a 3-block budget over a 6-producer plan must spill"
        );
        assert!(
            tight_rep.mem_stats[0].readback_bytes > 0,
            "consumed spilled inputs must be read back"
        );
        // the budget held for resident bytes (peak includes the seed)
        assert!(tight_rep.store_snapshot[0].1 <= 4 * block_bytes);
    }

    #[test]
    fn no_stealing_keeps_node_affinity() {
        let topo = Topology::new(2, 1, SystemMode::Ray);
        let ex = RealExecutor::new(topo, Arc::new(Backend::native())).with_stealing(false);
        let stores = StoreSet::new(2);
        for i in 0..8u64 {
            stores.put(0, i, Arc::new(Block::from_vec(&[1, 1], vec![i as f64])));
        }
        // all tasks target node 0: without stealing node 1 must run none
        let plan = Plan {
            tasks: (0..8u64)
                .map(|i| Task {
                    kernel: Kernel::Neg,
                    inputs: vec![i],
                    in_shapes: vec![vec![1, 1]],
                    outputs: vec![(100 + i, vec![1, 1])],
                    target: 0,
                    transfers: vec![],
                })
                .collect(),
        };
        let rep = ex.run(&plan, &stores).unwrap();
        assert_eq!(rep.node_stats[0].tasks_run, 8);
        assert_eq!(rep.node_stats[1].tasks_run, 0);
        assert!(rep.node_stats.iter().all(|s| s.tasks_stolen == 0));
    }

    fn chain_plan(len: usize, target: usize) -> Plan {
        Plan {
            tasks: (0..len)
                .map(|i| Task {
                    kernel: Kernel::Scale(3.0),
                    inputs: vec![if i == 0 { 1 } else { 9 + i as u64 }],
                    in_shapes: vec![vec![1, 1]],
                    outputs: vec![(10 + i as u64, vec![1, 1])],
                    target,
                    transfers: vec![],
                })
                .collect(),
        }
    }

    #[test]
    fn injected_transient_faults_retry_to_the_fault_free_result() {
        use crate::exec::fault::FaultPlan;
        let run = |plan_cfg: Option<FaultPlan>| {
            let topo = Topology::new(2, 1, SystemMode::Ray);
            let ex = RealExecutor::new(topo, Arc::new(Backend::native()))
                .with_faults(plan_cfg);
            let stores = StoreSet::new(2);
            stores.put(0, 1, Arc::new(Block::from_vec(&[1, 1], vec![2.0])));
            let plan = chain_plan(3, 0);
            let rep = ex.run(&plan, &stores).unwrap();
            (rep, stores.fetch(12).unwrap().as_ref().clone())
        };
        let (clean, clean_out) = run(None);
        assert!(clean.recovery_stats.is_zero(), "fault-free run must cost nothing");
        // rate 1.0: every kernel/transfer decision fails (twice, per the
        // injector cap) and is retried through backoff
        let (chaos, chaos_out) = run(Some(FaultPlan::new(11, 1.0)));
        assert!(chaos.recovery_stats.retries > 0, "rate-1.0 chaos must retry");
        assert!(chaos.recovery_stats.backoff_secs > 0.0);
        assert_eq!(chaos.recovery_stats.node_losses_survived, 0);
        assert_eq!(
            chaos_out.max_abs_diff(&clean_out),
            0.0,
            "injected transients changed numerics"
        );
    }

    #[test]
    fn survivable_node_loss_recovers_by_lineage_recompute() {
        use crate::exec::fault::{FaultPlan, NodeLossMode};
        // 5-task chain pinned to node 1, seed on node 0; after 2
        // completions node 1 dies and its intermediates are wiped. The
        // lineage walk must rebuild the missing prefix on node 0 and the
        // run must finish with the exact fault-free result.
        let topo = Topology::new(2, 1, SystemMode::Ray);
        let fp = FaultPlan::new(0, 0.0).with_node_loss(1, 2, NodeLossMode::Survivable);
        let ex = RealExecutor::new(topo, Arc::new(Backend::native()))
            .with_stealing(false)
            .with_faults(Some(fp));
        let stores = StoreSet::new(2);
        stores.put(0, 1, Arc::new(Block::from_vec(&[1, 1], vec![2.0])));
        let plan = chain_plan(5, 1);
        let rep = ex.run(&plan, &stores).unwrap();
        assert_eq!(rep.recovery_stats.node_losses_survived, 1);
        assert!(
            rep.recovery_stats.recomputed_tasks > 0,
            "wiped intermediates must be recomputed, got {:?}",
            rep.recovery_stats
        );
        assert_eq!(rep.node_losses.len(), 1);
        assert_eq!(rep.node_losses[0].0, 1, "node 1 was the one lost");
        let out = stores.fetch(14).unwrap();
        assert_eq!(out.buf(), &[2.0 * 243.0], "recovery changed the result");
    }

    #[test]
    fn total_node_loss_of_a_sole_copy_input_is_a_typed_unrecoverable_loss() {
        use crate::exec::fault::{FaultPlan, NodeLossMode};
        // the external seed lives on the node that dies in Total mode:
        // no lineage can rebuild it — typed error, not a deadlock hang
        let topo = Topology::new(2, 1, SystemMode::Ray);
        let fp = FaultPlan::new(0, 0.0).with_node_loss(0, 1, NodeLossMode::Total);
        let mut ex = RealExecutor::new(topo, Arc::new(Backend::native()))
            .with_stealing(false)
            .with_faults(Some(fp));
        ex.deadlock_timeout = Duration::from_millis(50);
        let stores = StoreSet::new(2);
        stores.put(0, 1, Arc::new(Block::from_vec(&[1, 1], vec![2.0])));
        let plan = chain_plan(5, 0);
        let err = ex.run(&plan, &stores).unwrap_err();
        let typed = err
            .downcast_ref::<ExecError>()
            .expect("typed error must survive the anyhow boundary");
        assert!(
            matches!(typed, ExecError::UnrecoverableLoss { .. }),
            "expected UnrecoverableLoss, got {typed:?}"
        );
        assert!(err.to_string().contains("unrecoverable loss"), "{err}");
    }
}
