//! Real threaded execution of a plan.
//!
//! Each simulated node gets a small pool of worker threads and a FIFO task
//! queue (plan order). Tasks wait until their inputs exist (producer
//! notification via condvar), pull missing inputs through the
//! [`StoreSet`] — which accounts real bytes per node — and execute their
//! kernel on the configured [`Backend`] (PJRT artifacts or native). This is
//! the correctness executor: block numerics are real end-to-end.

use std::collections::HashSet;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::runtime::Backend;
use crate::scheduler::Topology;
use crate::store::{ObjectId, StoreSet};
use crate::util::Stopwatch;

use super::task::Plan;

#[derive(Clone, Debug, Default)]
pub struct RealReport {
    pub wall_secs: f64,
    pub tasks: usize,
    /// Per-node (resident, peak, net_in, net_out) bytes after execution.
    pub store_snapshot: Vec<(u64, u64, u64, u64)>,
}

struct Shared {
    produced: Mutex<HashSet<ObjectId>>,
    cv: Condvar,
    failed: Mutex<Option<String>>,
}

/// `NUMS_DEADLOCK_TIMEOUT_SECS` parsing (non-positive/garbage/absurd -> 30s).
fn parse_deadlock_timeout(v: Option<String>) -> Duration {
    // upper bound keeps Duration::from_secs_f64 from panicking on overflow
    const MAX_SECS: f64 = 1e9;
    v.and_then(|s| s.parse::<f64>().ok())
        .filter(|s| *s > 0.0 && *s <= MAX_SECS)
        .map(Duration::from_secs_f64)
        .unwrap_or(Duration::from_secs(30))
}

pub struct RealExecutor {
    pub topo: Topology,
    pub backend: Arc<Backend>,
    /// Worker threads per node (capped: a laptop can't host 512).
    pub threads_per_node: usize,
    /// How long a task may wait on its inputs before the run is declared
    /// deadlocked. Defaults to 30s; `NUMS_DEADLOCK_TIMEOUT_SECS` overrides
    /// (long single-kernel workloads legitimately exceed 30s).
    pub deadlock_timeout: Duration,
}

impl RealExecutor {
    pub fn new(topo: Topology, backend: Arc<Backend>) -> Self {
        // cap total threads near the host's cores
        let cap = (16 / topo.nodes).max(1).min(8);
        let threads_per_node = topo.workers_per_node.min(cap).max(1);
        let deadlock_timeout =
            parse_deadlock_timeout(std::env::var("NUMS_DEADLOCK_TIMEOUT_SECS").ok());
        // tell the blocked dense kernels how many workers will call them
        // concurrently, so kernel-internal parallelism divides the host's
        // cores instead of multiplying into oversubscription
        crate::linalg::dense::set_parallelism_hint(topo.nodes * threads_per_node);
        Self {
            topo,
            backend,
            threads_per_node,
            deadlock_timeout,
        }
    }

    /// Execute the plan over `stores`. All creation-time objects must
    /// already be resident (see `api::Session`).
    pub fn run(&self, plan: &Plan, stores: &StoreSet) -> Result<RealReport> {
        let sw = Stopwatch::start();
        let shared = Arc::new(Shared {
            produced: Mutex::new(HashSet::new()),
            cv: Condvar::new(),
            failed: Mutex::new(None),
        });
        // seed "produced" with everything already in a store
        {
            let mut p = shared.produced.lock().unwrap();
            for t in &plan.tasks {
                for &obj in &t.inputs {
                    if stores.fetch(obj).is_some() {
                        p.insert(obj);
                    }
                }
            }
        }

        // per-node FIFO queues in plan order
        let k = self.topo.nodes;
        let mut queues: Vec<Vec<usize>> = vec![Vec::new(); k];
        for (i, t) in plan.tasks.iter().enumerate() {
            queues[self.topo.node_of(t.target)].push(i);
        }
        let queues: Vec<Arc<Mutex<std::collections::VecDeque<usize>>>> = queues
            .into_iter()
            .map(|v| Arc::new(Mutex::new(v.into_iter().collect())))
            .collect();

        let deadlock_timeout = self.deadlock_timeout;
        std::thread::scope(|scope| {
            for node in 0..k {
                for _ in 0..self.threads_per_node {
                    let queue = Arc::clone(&queues[node]);
                    let shared = Arc::clone(&shared);
                    let backend = Arc::clone(&self.backend);
                    let topo = self.topo.clone();
                    scope.spawn(move || {
                        loop {
                            if shared.failed.lock().unwrap().is_some() {
                                return;
                            }
                            let idx = match queue.lock().unwrap().pop_front() {
                                Some(i) => i,
                                None => return,
                            };
                            let task = &plan.tasks[idx];
                            let dst_node = topo.node_of(task.target);
                            // wait for all inputs to be produced somewhere
                            {
                                let mut p = shared.produced.lock().unwrap();
                                while !task.inputs.iter().all(|o| p.contains(o)) {
                                    if shared.failed.lock().unwrap().is_some() {
                                        return;
                                    }
                                    let (guard, timeout) = shared
                                        .cv
                                        .wait_timeout(p, deadlock_timeout)
                                        .unwrap();
                                    p = guard;
                                    if timeout.timed_out() {
                                        let missing: Vec<ObjectId> = task
                                            .inputs
                                            .iter()
                                            .copied()
                                            .filter(|o| !p.contains(o))
                                            .collect();
                                        *shared.failed.lock().unwrap() = Some(format!(
                                            "deadlock: task {idx} ({}) timed out after \
                                             {:.1}s waiting on input objects {missing:?} \
                                             (raise NUMS_DEADLOCK_TIMEOUT_SECS for long kernels)",
                                            task.kernel,
                                            deadlock_timeout.as_secs_f64()
                                        ));
                                        shared.cv.notify_all();
                                        return;
                                    }
                                }
                            }
                            // pull missing inputs to this node (real bytes)
                            for &obj in &task.inputs {
                                if !stores.contains(dst_node, obj) {
                                    match stores.locate(obj, dst_node) {
                                        Some(src) => {
                                            stores.transfer(src, dst_node, obj);
                                        }
                                        None => {
                                            *shared.failed.lock().unwrap() = Some(format!(
                                                "object {obj} vanished (task {idx})"
                                            ));
                                            shared.cv.notify_all();
                                            return;
                                        }
                                    }
                                }
                            }
                            let inputs: Vec<Arc<crate::store::Block>> = task
                                .inputs
                                .iter()
                                .map(|&o| stores.get(dst_node, o).unwrap())
                                .collect();
                            let in_refs: Vec<&crate::store::Block> =
                                inputs.iter().map(|b| b.as_ref()).collect();
                            match backend.execute(&task.kernel, &in_refs) {
                                Ok(outs) => {
                                    for ((obj, _), block) in task.outputs.iter().zip(outs) {
                                        stores.put(dst_node, *obj, Arc::new(block));
                                    }
                                    let mut p = shared.produced.lock().unwrap();
                                    for (obj, _) in &task.outputs {
                                        p.insert(*obj);
                                    }
                                    drop(p);
                                    shared.cv.notify_all();
                                }
                                Err(e) => {
                                    *shared.failed.lock().unwrap() =
                                        Some(format!("task {idx} ({}): {e}", task.kernel));
                                    shared.cv.notify_all();
                                    return;
                                }
                            }
                        }
                    });
                }
            }
        });

        if let Some(err) = shared.failed.lock().unwrap().take() {
            return Err(anyhow!(err));
        }
        Ok(RealReport {
            wall_secs: sw.secs(),
            tasks: plan.len(),
            store_snapshot: stores.snapshot(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::task::{Plan, Task};
    use crate::net::model::SystemMode;
    use crate::runtime::kernel::{BinOp, Kernel};
    use crate::store::Block;

    #[test]
    fn deadlock_error_names_the_blocking_objects() {
        let topo = Topology::new(1, 1, SystemMode::Ray);
        let mut ex = RealExecutor::new(topo, Arc::new(Backend::native()));
        ex.deadlock_timeout = Duration::from_millis(50);
        let stores = StoreSet::new(1);
        stores.put(0, 7, Arc::new(Block::from_vec(&[1, 1], vec![1.0])));
        // input 99 is never produced -> the wait must time out and say so
        let plan = Plan {
            tasks: vec![Task {
                kernel: Kernel::Ew(BinOp::Add),
                inputs: vec![7, 99],
                in_shapes: vec![vec![1, 1], vec![1, 1]],
                outputs: vec![(100, vec![1, 1])],
                target: 0,
                transfers: vec![],
            }],
        };
        let msg = format!("{}", ex.run(&plan, &stores).unwrap_err());
        assert!(msg.contains("deadlock"), "{msg}");
        assert!(msg.contains("[99]"), "must name the missing input: {msg}");
        assert!(msg.contains("NUMS_DEADLOCK_TIMEOUT_SECS"), "{msg}");
    }

    #[test]
    fn timeout_env_override_parses() {
        assert_eq!(
            parse_deadlock_timeout(Some("0.25".into())),
            Duration::from_millis(250)
        );
        assert_eq!(
            parse_deadlock_timeout(Some("-3".into())),
            Duration::from_secs(30)
        );
        assert_eq!(
            parse_deadlock_timeout(Some("nope".into())),
            Duration::from_secs(30)
        );
        // absurdly large values must not overflow Duration construction
        assert_eq!(
            parse_deadlock_timeout(Some("1e30".into())),
            Duration::from_secs(30)
        );
        assert_eq!(parse_deadlock_timeout(None), Duration::from_secs(30));
    }
}
