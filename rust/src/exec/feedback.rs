//! Runtime feedback: what the executor observed that the planner never
//! committed.
//!
//! LSHS plans by *simulating* load (Eq. 2, §5) — but the real executor
//! makes placement-relevant decisions after the plan is fixed: work
//! stealing migrates tasks to other nodes (and pulls their inputs there),
//! prefetch misses turn into demand pulls, and budget pressure spills
//! primaries to disk. None of that appears in the scheduler's
//! [`crate::scheduler::ClusterState`] unless it is fed back, so on a
//! session's *next* `schedule()` the simulation would diverge further and
//! further from where load actually landed — exactly the gap that makes
//! purely reactive schedulers (Dask-style re-planning) pay extra network
//! traffic.
//!
//! [`RuntimeFeedback`] closes the loop. After each run the executor
//! reconciles the plan against observation:
//!
//! * **unplanned traffic** — per node, the real store NIC deltas minus
//!   the bytes the plan's committed [`crate::exec::Transfer`]s account
//!   for. Steal pulls, eviction re-pulls, and every other byte the
//!   simulation never saw, clamped at zero (a committed transfer that
//!   turned out to be unnecessary is not *negative* traffic);
//! * **steal migrations** — per-node stolen task counts and the input
//!   bytes thieves pulled ([`crate::exec::NodeExecStats`]);
//! * **demand-pull misses** — hot-path bytes from
//!   [`crate::exec::PrefetchStats`] (with prefetch disabled, every
//!   inbound byte is a demand pull);
//! * **spill pressure** — bytes the memory manager paged out under the
//!   byte budget ([`crate::store::NodeMemStats`]): the planner
//!   oversubscribed that node's memory;
//! * **runtime replicas** — objects that now hold a copy on a node the
//!   plan never placed them on (sorted for determinism). Registering
//!   these in the load model both corrects the Eq. 2 memory term and
//!   *expands the next plan's placement options* — LSHS only considers
//!   targets that hold some input copy, so without this the planner can
//!   never discover that stolen work warmed another node.
//!
//! `api::Session` folds the feedback into its `ClusterState` between
//! runs via [`crate::scheduler::ClusterState::absorb_feedback`], gated by
//! `SessionConfig::feedback` (default on; off is the ablation baseline
//! measured in `benches/fig09_micro.rs`).

use crate::scheduler::Topology;
use crate::store::{NodeMemStats, ObjectId};

use super::prefetch::PrefetchStats;
use super::real_exec::NodeExecStats;
use super::task::Plan;

/// One node's observed-vs-planned load for a single run.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct NodeFeedback {
    /// Tasks this node ran whose plan target was another node.
    pub tasks_stolen: usize,
    /// Input bytes pulled cross-node for those stolen tasks.
    pub steal_bytes: u64,
    /// Bytes pulled on the worker hot path (prefetch misses and stolen
    /// task inputs; with prefetch off, all inbound bytes).
    pub demand_pull_bytes: u64,
    /// Bytes the memory manager paged out to disk on this node — the
    /// planner's Eq. 2 memory term undercounted this node's working set.
    pub spilled_bytes: u64,
    /// Real inbound NIC bytes beyond what the plan's committed transfers
    /// predicted for this node (clamped at zero).
    pub unplanned_in_bytes: u64,
    /// Real outbound NIC bytes beyond the plan's committed transfers
    /// (clamped at zero).
    pub unplanned_out_bytes: u64,
}

/// Everything one real run observed that the plan did not commit; see the
/// module docs for the feedback semantics of each part.
#[derive(Clone, Debug, Default)]
pub struct RuntimeFeedback {
    /// Per physical node, observed-vs-planned load.
    pub nodes: Vec<NodeFeedback>,
    /// `(object, node)` copies the runtime materialized on nodes the plan
    /// never placed them on, still resident at run end. Sorted by
    /// `(object, node)` so absorbing them is deterministic.
    pub replicas: Vec<(ObjectId, usize)>,
}

impl RuntimeFeedback {
    /// Magnitude of the load-model drift this feedback causes when
    /// absorbed, in f64 elements: the unplanned NIC traffic and spill
    /// pressure [`crate::scheduler::ClusterState::absorb_feedback`] folds
    /// into the Eq. 2 terms (replicas widen *options* but do not move the
    /// objective's committed loads, so they are not counted). The plan
    /// cache ages its entries by this amount — enough drift means a
    /// memoized argmin is no longer trustworthy and the next lookup
    /// re-plans.
    pub fn pressure_elems(&self) -> f64 {
        self.nodes
            .iter()
            .map(|n| {
                (n.unplanned_in_bytes + n.unplanned_out_bytes + n.spilled_bytes) as f64 / 8.0
            })
            .sum()
    }

    /// Bytes the plan's committed transfers put on each node's NICs:
    /// per-node `(in, out)`, with same-node movements skipped exactly as
    /// the stores skip them. Shared with the divergence report
    /// ([`crate::metrics::runtime_trace`]) so "planned" means the same
    /// thing in both reconciliations.
    pub(crate) fn planned_nic_bytes(plan: &Plan, topo: &Topology) -> Vec<(u64, u64)> {
        let mut nic = vec![(0u64, 0u64); topo.nodes];
        for t in &plan.tasks {
            let dst = topo.node_of(t.target);
            for tr in &t.transfers {
                let src = topo.node_of(tr.src);
                if src == dst {
                    continue;
                }
                nic[dst].0 += tr.bytes();
                nic[src].1 += tr.bytes();
            }
        }
        nic
    }

    /// Reconcile one run: store snapshots before/after (the
    /// `(resident, peak, net_in, net_out)` tuples of
    /// [`crate::store::StoreSet::snapshot`]), the run's per-node executor
    /// and overlap counters, the per-run memory-manager deltas, and the
    /// replica copies still resident at run end.
    #[allow(clippy::too_many_arguments)]
    pub fn collect(
        plan: &Plan,
        topo: &Topology,
        snap_before: &[(u64, u64, u64, u64)],
        snap_after: &[(u64, u64, u64, u64)],
        node_stats: &[NodeExecStats],
        prefetch_stats: &[PrefetchStats],
        mem_stats: &[NodeMemStats],
        mut replicas: Vec<(ObjectId, usize)>,
    ) -> Self {
        let planned = Self::planned_nic_bytes(plan, topo);
        let nodes = (0..topo.nodes)
            .map(|n| {
                let in_delta = snap_after[n].2.saturating_sub(snap_before[n].2);
                let out_delta = snap_after[n].3.saturating_sub(snap_before[n].3);
                NodeFeedback {
                    tasks_stolen: node_stats.get(n).map_or(0, |s| s.tasks_stolen),
                    steal_bytes: node_stats.get(n).map_or(0, |s| s.steal_bytes),
                    demand_pull_bytes: prefetch_stats
                        .get(n)
                        .map_or(in_delta, |p| p.demand_pull_bytes),
                    spilled_bytes: mem_stats.get(n).map_or(0, |m| m.spilled_bytes),
                    unplanned_in_bytes: in_delta.saturating_sub(planned[n].0),
                    unplanned_out_bytes: out_delta.saturating_sub(planned[n].1),
                }
            })
            .collect();
        replicas.sort_unstable();
        replicas.dedup();
        Self { nodes, replicas }
    }

    /// True when the run behaved exactly as planned — nothing to absorb.
    pub fn is_quiet(&self) -> bool {
        self.replicas.is_empty()
            && self.nodes.iter().all(|n| {
                n.tasks_stolen == 0
                    && n.steal_bytes == 0
                    && n.spilled_bytes == 0
                    && n.unplanned_in_bytes == 0
                    && n.unplanned_out_bytes == 0
            })
    }

    /// Total hot-path demand bytes across nodes (ablation headline).
    pub fn total_demand_bytes(&self) -> u64 {
        self.nodes.iter().map(|n| n.demand_pull_bytes).sum()
    }

    /// Total stolen-input bytes across nodes (ablation headline).
    pub fn total_steal_bytes(&self) -> u64 {
        self.nodes.iter().map(|n| n.steal_bytes).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::task::{Task, Transfer};
    use crate::net::model::SystemMode;
    use crate::runtime::kernel::{BinOp, Kernel};

    fn plan_with_transfer() -> Plan {
        Plan {
            tasks: vec![Task {
                kernel: Kernel::Ew(BinOp::Add),
                inputs: vec![1, 2],
                in_shapes: vec![vec![4, 4], vec![4, 4]],
                outputs: vec![(3, vec![4, 4])],
                target: 1,
                // one committed pull: obj 1, node 0 -> node 1, 16 elems
                transfers: vec![Transfer {
                    obj: 1,
                    src: 0,
                    elems: 16,
                }],
            }],
        }
    }

    #[test]
    fn unplanned_traffic_is_observed_minus_committed() {
        let topo = Topology::new(2, 1, SystemMode::Ray);
        let plan = plan_with_transfer();
        // node 1 really received 128 B planned + 256 B unplanned; node 0
        // really sent the same 384 B
        let before = vec![(0, 0, 0, 0), (0, 0, 0, 0)];
        let after = vec![(0, 0, 0, 384), (0, 0, 384, 0)];
        let stats = vec![NodeExecStats::default(); 2];
        let fb = RuntimeFeedback::collect(
            &plan, &topo, &before, &after, &stats, &[], &[], vec![],
        );
        assert_eq!(fb.nodes[1].unplanned_in_bytes, 384 - 128);
        assert_eq!(fb.nodes[0].unplanned_out_bytes, 384 - 128);
        assert_eq!(fb.nodes[0].unplanned_in_bytes, 0);
        // no prefetch stats: every inbound byte is a demand pull
        assert_eq!(fb.nodes[1].demand_pull_bytes, 384);
        assert!(!fb.is_quiet());
    }

    #[test]
    fn planned_traffic_is_quiet() {
        let topo = Topology::new(2, 1, SystemMode::Ray);
        let plan = plan_with_transfer();
        let before = vec![(0, 0, 0, 0), (0, 0, 0, 0)];
        // exactly the committed 128 B moved
        let after = vec![(0, 0, 0, 128), (0, 0, 128, 0)];
        let stats = vec![NodeExecStats::default(); 2];
        let pf = vec![PrefetchStats::default(); 2];
        let fb = RuntimeFeedback::collect(
            &plan, &topo, &before, &after, &stats, &pf, &[], vec![],
        );
        assert!(fb.is_quiet(), "{fb:?}");
        assert_eq!(fb.total_demand_bytes(), 0);
    }

    #[test]
    fn replicas_are_sorted_and_deduped() {
        let topo = Topology::new(2, 1, SystemMode::Ray);
        let plan = Plan::default();
        let snap = vec![(0, 0, 0, 0), (0, 0, 0, 0)];
        let fb = RuntimeFeedback::collect(
            &plan,
            &topo,
            &snap,
            &snap,
            &[],
            &[],
            &[],
            vec![(9, 1), (2, 0), (9, 1), (2, 1)],
        );
        assert_eq!(fb.replicas, vec![(2, 0), (2, 1), (9, 1)]);
        assert!(!fb.is_quiet(), "replicas count as feedback");
    }

    #[test]
    fn dask_mode_aggregates_transfers_per_physical_node() {
        // worker targets 0,1 share node 0; a worker-to-worker transfer on
        // the same node must not count as NIC traffic
        let topo = Topology::new(2, 2, SystemMode::Dask);
        let plan = Plan {
            tasks: vec![Task {
                kernel: Kernel::Neg,
                inputs: vec![1],
                in_shapes: vec![vec![2, 2]],
                outputs: vec![(2, vec![2, 2])],
                target: 1, // worker 1, node 0
                transfers: vec![Transfer {
                    obj: 1,
                    src: 0, // worker 0, node 0: same physical node
                    elems: 4,
                }],
            }],
        };
        let nic = RuntimeFeedback::planned_nic_bytes(&plan, &topo);
        assert_eq!(nic, vec![(0, 0), (0, 0)]);
    }
}
