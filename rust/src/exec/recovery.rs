//! Lineage-based recovery: typed executor errors, retry/backoff policy,
//! and the recompute-subgraph walk.
//!
//! This is the survival half of the resilience story
//! ([`crate::exec::fault`] is the failure half, and the Ray lineage
//! model the paper leans on is the blueprint): the plan *is* the
//! lineage. Every produced object names its producing task, so when an
//! object is lost — a wiped node, a corrupt spill file, an evicted
//! sole copy — the executor walks the plan backward from each missing
//! `ObjectId` to its producer and transitively to live inputs
//! ([`plan_recompute`]), yielding a minimal recompute subgraph in plan
//! order that can be spliced back into the running executor's
//! dependency counts. Placement of recomputed tasks goes to surviving
//! nodes by the same min-load greedy the Eq. 2 memory term encodes
//! ([`place_on_survivors`]); the session afterwards reconciles the
//! `ClusterState` so planning stays honest about where copies really
//! live.
//!
//! Transient failures (injected kernel faults, failed pulls, spill I/O)
//! never reach the lineage walk: they retry in place with bounded
//! exponential backoff ([`backoff_delay`], [`MAX_TRANSIENT_RETRIES`]).
//! Only loss of data escalates; and loss of data *without* lineage — a
//! pre-resident input no task produces, gone from every store —
//! escalates to [`ExecError::UnrecoverableLoss`] naming the dead
//! lineage chain, instead of deadlocking the pool.
//!
//! The real transport layer ([`crate::net::transport`]) maps its
//! failures onto these same two classes, which is the payoff of keeping
//! this machinery transport-agnostic: a **transient** link failure
//! (heartbeat/read timeout, corrupt frame, I/O hiccup) retries inside
//! `StoreSet::try_transfer` with the mirror-image backoff policy
//! (`net::link_backoff`); **peer-process death** (connection refused or
//! reset, a killed node daemon, transient retries exhausting) marks the
//! peer dead on the `StoreSet`, and the executor reaps that flag into
//! the identical node-loss path a scheduled
//! [`crate::exec::fault::NodeLossSpec`] takes — wipe, divert, lineage
//! recompute via [`plan_recompute`].

use std::collections::{HashMap, HashSet};
use std::fmt;
use std::time::Duration;

use crate::store::ObjectId;

use super::task::Plan;

/// What recovering from injected/real faults cost one run. All zeros
/// (the [`RecoveryStats::is_zero`] check) on a fault-free run.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RecoveryStats {
    /// Transient failures that were retried (kernel, transfer, spill).
    pub retries: u64,
    /// Total wall-clock spent sleeping in retry backoff.
    pub backoff_secs: f64,
    /// Tasks re-executed through lineage recovery.
    pub recomputed_tasks: u64,
    /// Output bytes those re-executions produced.
    pub recomputed_bytes: u64,
    /// Whole-node losses the run survived.
    pub node_losses_survived: u64,
}

impl RecoveryStats {
    pub fn is_zero(&self) -> bool {
        self.retries == 0
            && self.backoff_secs == 0.0
            && self.recomputed_tasks == 0
            && self.recomputed_bytes == 0
            && self.node_losses_survived == 0
    }
}

/// Typed real-executor failure, returned through `Session::run` (the
/// vendored `anyhow` shim keeps the original value downcastable). The
/// `Display` wording deliberately preserves the diagnostic strings the
/// stringy error paths used to emit, so existing message-matching
/// callers and tests keep working.
#[derive(Clone, Debug, PartialEq)]
pub enum ExecError {
    /// Nothing running, nothing queued, tasks left — and no recovery
    /// possible. `missing` names the blocking inputs; `cycle` is true
    /// when every missing input has a producer (a dependency cycle).
    Deadlock {
        plan_tasks: usize,
        missing: Vec<ObjectId>,
        cycle: bool,
    },
    /// A kernel failed (panic or kernel error) beyond retry.
    TaskFailed {
        task: usize,
        kernel: String,
        reason: String,
    },
    /// An input vanished mid-collection and lineage recovery could not
    /// be attempted or did not apply.
    ObjectLost { obj: ObjectId, task: usize },
    /// An object is gone from every store and has no producing task —
    /// the lineage walk dead-ends. The chain runs from the object the
    /// executor needed to the unproducible ancestor.
    UnrecoverableLoss { lineage: Vec<ObjectId> },
    /// A spill file could not be written after retries.
    SpillIo { obj: ObjectId, reason: String },
}

impl fmt::Display for ExecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ExecError::Deadlock { plan_tasks, missing, cycle } => {
                if *cycle {
                    write!(
                        f,
                        "deadlock: dependency cycle among plan tasks; unproduced inputs \
                         {missing:?} (idle re-check window: NUMS_DEADLOCK_TIMEOUT_SECS)"
                    )
                } else {
                    write!(
                        f,
                        "deadlock: {plan_tasks}-task plan is incomplete and blocked on \
                         input objects {missing:?} that no store holds and no task \
                         produces (idle re-check window: NUMS_DEADLOCK_TIMEOUT_SECS)"
                    )
                }
            }
            ExecError::TaskFailed { task, kernel, reason } => {
                write!(f, "task {task} ({kernel}): {reason}")
            }
            ExecError::ObjectLost { obj, task } => {
                write!(f, "object {obj} vanished (task {task})")
            }
            ExecError::UnrecoverableLoss { lineage } => {
                write!(
                    f,
                    "unrecoverable loss: dead lineage chain {lineage:?} — object \
                     {} is gone from every store and no task produces it",
                    lineage.last().copied().unwrap_or_default()
                )
            }
            ExecError::SpillIo { obj, reason } => {
                write!(f, "spill I/O failed for object {obj}: {reason}")
            }
        }
    }
}

impl std::error::Error for ExecError {}

/// Most in-place retries any transient failure site attempts before
/// escalating. The injector's per-key cap
/// ([`crate::exec::fault::MAX_INJECTIONS_PER_KEY`]) is strictly below
/// this, so injected transients always succeed within the budget.
pub const MAX_TRANSIENT_RETRIES: u32 = 4;

/// Bounded exponential backoff for transient-failure retries: 100 µs
/// doubling per attempt, capped at 5 ms — long enough to let a racing
/// writer finish, short enough that chaos CI stays fast.
pub fn backoff_delay(attempt: u32) -> Duration {
    let us = 100u64 << attempt.min(6);
    Duration::from_micros(us.min(5_000))
}

/// Walk the plan's lineage backward from each of `missing` to live
/// data: returns the minimal recompute subgraph as plan-order task
/// indices (ascending = topological, since plans are topologically
/// ordered). `available` answers "is this object in some live store
/// right now". Objects that are available are live leaves; objects
/// with a producer recurse into that producer's inputs; an object
/// that is neither available nor produced dead-ends the walk with
/// [`ExecError::UnrecoverableLoss`].
pub fn plan_recompute(
    plan: &Plan,
    missing: &[ObjectId],
    available: &dyn Fn(ObjectId) -> bool,
) -> Result<Vec<usize>, ExecError> {
    let mut producer: HashMap<ObjectId, usize> = HashMap::new();
    for (i, t) in plan.tasks.iter().enumerate() {
        for (o, _) in &t.outputs {
            producer.insert(*o, i);
        }
    }

    let mut tasks: HashSet<usize> = HashSet::new();
    for &root in missing {
        // chain of objects from the needed root down to the current
        // frame — reported verbatim on a dead end
        let mut chain: Vec<ObjectId> = Vec::new();
        // DFS over (object, lineage depth); depth prunes the chain back
        // to the fork point when the walk pops a sibling
        let mut stack: Vec<(ObjectId, usize)> = vec![(root, 0)];
        while let Some((obj, depth)) = stack.pop() {
            chain.truncate(depth);
            chain.push(obj);
            if depth > 0 && available(obj) {
                continue; // live leaf: recompute reads it directly
            }
            match producer.get(&obj) {
                Some(&t) => {
                    if !tasks.insert(t) {
                        continue; // producer already in the subgraph
                    }
                    for &inp in &plan.tasks[t].inputs {
                        stack.push((inp, depth + 1));
                    }
                }
                None => {
                    if depth == 0 && available(obj) {
                        // raced back into residency; nothing to do
                        chain.pop();
                        continue;
                    }
                    return Err(ExecError::UnrecoverableLoss { lineage: chain });
                }
            }
        }
    }

    let mut out: Vec<usize> = tasks.into_iter().collect();
    out.sort_unstable();
    Ok(out)
}

/// Greedy min-load placement of one recompute task over surviving
/// nodes: the runtime-side analogue of the Eq. 2 memory term —
/// `ClusterState` is not reachable from worker threads, so recovery
/// balances on projected resident bytes and charges its choice into
/// `load` so successive placements spread. Returns `None` when no node
/// survives.
pub fn place_on_survivors(bytes: u64, load: &mut [u64], alive: &[bool]) -> Option<usize> {
    let node = (0..load.len())
        .filter(|&n| alive[n])
        .min_by_key(|&n| (load[n], n))?;
    load[node] = load[node].saturating_add(bytes);
    Some(node)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::task::Task;
    use crate::runtime::Kernel;

    fn task(inputs: &[ObjectId], out: ObjectId) -> Task {
        Task {
            kernel: Kernel::Neg,
            inputs: inputs.to_vec(),
            in_shapes: inputs.iter().map(|_| vec![2, 2]).collect(),
            outputs: vec![(out, vec![2, 2])],
            target: 0,
            transfers: vec![],
        }
    }

    /// 1 -> 10 -> 11 -> 12 (chain), with 2 joining at task 1.
    fn chain_plan() -> Plan {
        Plan {
            tasks: vec![task(&[1], 10), task(&[10, 2], 11), task(&[11], 12)],
        }
    }

    #[test]
    fn recompute_walks_transitively_to_live_inputs() {
        let plan = chain_plan();
        // 12 lost, 11 also lost, 10 still live, leaves 1/2 live
        let live: HashSet<ObjectId> = [1, 2, 10].into_iter().collect();
        let got = plan_recompute(&plan, &[12], &|o| live.contains(&o)).unwrap();
        assert_eq!(got, vec![1, 2], "rebuild 11 then 12; 10 is a live leaf");
    }

    #[test]
    fn recompute_is_minimal_when_the_object_is_directly_rebuildable() {
        let plan = chain_plan();
        let live: HashSet<ObjectId> = [1, 2, 10, 11].into_iter().collect();
        let got = plan_recompute(&plan, &[12], &|o| live.contains(&o)).unwrap();
        assert_eq!(got, vec![2]);
    }

    #[test]
    fn recompute_dedupes_shared_ancestors_across_roots() {
        let plan = chain_plan();
        let live: HashSet<ObjectId> = [1, 2].into_iter().collect();
        let got = plan_recompute(&plan, &[11, 12], &|o| live.contains(&o)).unwrap();
        assert_eq!(got, vec![0, 1, 2], "whole chain, each task once, plan order");
    }

    #[test]
    fn dead_lineage_is_a_typed_unrecoverable_loss() {
        let plan = chain_plan();
        // external input 2 is gone and nothing produces it
        let live: HashSet<ObjectId> = [1, 10].into_iter().collect();
        let err = plan_recompute(&plan, &[11], &|o| live.contains(&o)).unwrap_err();
        match &err {
            ExecError::UnrecoverableLoss { lineage } => {
                assert_eq!(lineage.first(), Some(&11), "chain starts at the need");
                assert_eq!(lineage.last(), Some(&2), "chain ends at the dead end");
            }
            other => panic!("expected UnrecoverableLoss, got {other:?}"),
        }
        let msg = err.to_string();
        assert!(msg.contains("unrecoverable loss"), "{msg}");
        assert!(msg.contains("no task produces"), "{msg}");
    }

    #[test]
    fn display_preserves_legacy_diagnostics() {
        let d = ExecError::Deadlock { plan_tasks: 3, missing: vec![99], cycle: false };
        let s = d.to_string();
        assert!(s.contains("deadlock"));
        assert!(s.contains("[99]"));
        assert!(s.contains("NUMS_DEADLOCK_TIMEOUT_SECS"));

        let c = ExecError::Deadlock { plan_tasks: 3, missing: vec![7], cycle: true };
        assert!(c.to_string().contains("dependency cycle"));

        let t = ExecError::TaskFailed {
            task: 4,
            kernel: "Cholesky".into(),
            reason: "panic: not positive definite".into(),
        };
        let s = t.to_string();
        assert!(s.contains("task 4 (Cholesky)"));
        assert!(s.contains("panic"));

        let v = ExecError::ObjectLost { obj: 8, task: 2 };
        assert_eq!(v.to_string(), "object 8 vanished (task 2)");
    }

    #[test]
    fn typed_error_survives_the_anyhow_boundary() {
        fn run() -> anyhow::Result<()> {
            Err(ExecError::ObjectLost { obj: 5, task: 1 })?
        }
        let e = run().unwrap_err();
        assert!(e.to_string().contains("vanished"));
        let typed = e.downcast_ref::<ExecError>().expect("payload preserved");
        assert_eq!(*typed, ExecError::ObjectLost { obj: 5, task: 1 });
    }

    #[test]
    fn placement_spreads_over_min_load_survivors() {
        let mut load = vec![100, 0, 50, 0];
        let alive = vec![false, true, true, true];
        assert_eq!(place_on_survivors(40, &mut load, &alive), Some(1));
        assert_eq!(place_on_survivors(40, &mut load, &alive), Some(3));
        assert_eq!(place_on_survivors(40, &mut load, &alive), Some(1), "40 < 50");
        assert_eq!(load, vec![100, 80, 50, 40]);
        let none_alive = vec![false; 4];
        assert_eq!(place_on_survivors(1, &mut load, &none_alive), None);
    }

    #[test]
    fn backoff_is_bounded_and_monotone() {
        let mut prev = Duration::ZERO;
        for a in 0..16 {
            let d = backoff_delay(a);
            assert!(d >= prev);
            assert!(d <= Duration::from_millis(5));
            prev = d;
        }
        assert_eq!(backoff_delay(0), Duration::from_micros(100));
    }
}
