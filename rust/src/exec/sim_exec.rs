//! Discrete-event simulated execution (the paper-scale executor).
//!
//! Replays a [`Plan`] against the α–β–γ model of §7: the driver dispatches
//! one RFC per γ; each node has `r` worker slots; each node's NIC has one
//! inbound and one outbound channel (bytes can be sent and received in
//! parallel, matching App. A's assumption); inter-node transfers cost
//! `C(n)`, Dask intra-node worker-to-worker `D(n)`, and every Ray task pays
//! the object-store write `R(out)` plus a fixed RFC overhead (Fig. 8b).
//!
//! Blocks are phantom: this executor runs terabyte-shaped workloads (§8's
//! grids) in milliseconds of wall time while producing modeled seconds,
//! per-node load traces (Fig. 15) and byte counters.

use std::collections::HashMap;

use crate::net::model::{ComputeParams, NetParams, SystemMode};
use crate::store::ObjectId;

use super::task::Plan;
use crate::scheduler::Topology;

/// One sampled point of a node's load over modeled time (Fig. 15 traces).
#[derive(Clone, Copy, Debug)]
pub struct TraceEvent {
    pub t: f64,
    pub node: usize,
    /// Cumulative resident bytes on the node after this event.
    pub mem_bytes: u64,
    /// Cumulative bytes received.
    pub net_in_bytes: u64,
    /// Cumulative bytes sent.
    pub net_out_bytes: u64,
}

#[derive(Clone, Debug, Default)]
pub struct SimReport {
    /// Modeled end-to-end execution time (seconds).
    pub makespan: f64,
    /// Driver dispatch serialization time = γ · #tasks.
    pub dispatch_time: f64,
    /// Per-node resident bytes at the end of the plan (intermediates that
    /// were last consumed mid-plan are GC'd, like Ray/Dask refcounting).
    pub mem_bytes: Vec<u64>,
    /// Per-node high-water mark.
    pub peak_mem_bytes: Vec<u64>,
    /// Per-node cumulative NIC traffic.
    pub net_in_bytes: Vec<u64>,
    pub net_out_bytes: Vec<u64>,
    /// Per-node busy (compute) seconds.
    pub busy: Vec<f64>,
    /// Inter-node transfers performed.
    pub transfers: usize,
    /// Total bytes moved between nodes.
    pub transfer_bytes: u64,
    /// Bytes that overflowed node object stores onto disk.
    pub spilled_bytes: u64,
    /// Modeled seconds lost to spilling.
    pub spill_secs: f64,
    /// Load trace for Fig. 15.
    pub events: Vec<TraceEvent>,
    pub tasks: usize,
}

impl SimReport {
    pub fn max_mem_bytes(&self) -> u64 {
        self.peak_mem_bytes
            .iter()
            .chain(self.mem_bytes.iter())
            .copied()
            .max()
            .unwrap_or(0)
    }

    pub fn max_net_in_bytes(&self) -> u64 {
        self.net_in_bytes.iter().copied().max().unwrap_or(0)
    }

    /// Load-imbalance ratio: max node peak mem / mean node peak mem.
    pub fn mem_imbalance(&self) -> f64 {
        let peaks = if self.peak_mem_bytes.is_empty() {
            &self.mem_bytes
        } else {
            &self.peak_mem_bytes
        };
        let mean = peaks.iter().sum::<u64>() as f64 / peaks.len().max(1) as f64;
        self.max_mem_bytes() as f64 / mean.max(1.0)
    }
}

pub struct SimExecutor {
    pub topo: Topology,
    pub net: NetParams,
    pub compute: ComputeParams,
    /// Record Fig. 15-style trace events (costs memory on huge plans).
    pub record_trace: bool,
}

impl SimExecutor {
    pub fn new(topo: Topology, net: NetParams, compute: ComputeParams) -> Self {
        Self {
            topo,
            net,
            compute,
            record_trace: false,
        }
    }

    /// Disk-time penalty for adding `bytes` to a store: the portion beyond
    /// capacity pays disk bandwidth (object spilling, §8.1/§8.4).
    ///
    /// Ray mode: one shared-memory store per node (capacity
    /// `mem_capacity`). Dask mode: workers are separate processes with
    /// per-worker heaps (`mem_capacity / r` each) — the §2/§3 asymmetry
    /// that makes Dask spill long before a Ray node would.
    fn spill_penalty(&self, rep: &mut SimReport, mem_target: &mut [f64], target: usize, bytes: u64) -> f64 {
        let cap = match self.topo.mode {
            SystemMode::Ray => self.compute.mem_capacity,
            SystemMode::Dask => self.compute.mem_capacity / self.topo.workers_per_node as f64,
        };
        if cap.is_infinite() {
            return 0.0;
        }
        let before = mem_target[target];
        let after = before + bytes as f64;
        mem_target[target] = after;
        let overflow = (after - cap).max(0.0) - (before - cap).max(0.0);
        if overflow <= 0.0 {
            return 0.0;
        }
        rep.spilled_bytes += overflow as u64;
        let secs = overflow / self.compute.disk_rate;
        rep.spill_secs += secs;
        secs
    }

    /// Disk read-back time for a task whose inputs live on an over-capacity
    /// store: the spilled fraction of the input bytes must come off disk.
    fn spill_readback(&self, rep: &mut SimReport, mem_target: &[f64], task: &super::task::Task) -> f64 {
        let cap = match self.topo.mode {
            SystemMode::Ray => self.compute.mem_capacity,
            SystemMode::Dask => self.compute.mem_capacity / self.topo.workers_per_node as f64,
        };
        if cap.is_infinite() {
            return 0.0;
        }
        let resident = mem_target[task.target];
        if resident <= cap {
            return 0.0;
        }
        let ratio = ((resident - cap) / resident).clamp(0.0, 1.0);
        let in_bytes: f64 = task
            .in_shapes
            .iter()
            .map(|s| s.iter().map(|&d| d as f64).product::<f64>() * 8.0)
            .sum();
        let secs = in_bytes * ratio / self.compute.disk_rate;
        rep.spill_secs += secs;
        secs
    }

    /// Simulate the plan. `initial` lists pre-resident objects:
    /// (object, target, bytes) from creation ops.
    pub fn run(&self, plan: &Plan, initial: &[(ObjectId, usize, u64)]) -> SimReport {
        let k = self.topo.nodes;
        let r = self.topo.workers_per_node;
        let mut rep = SimReport {
            mem_bytes: vec![0; k],
            peak_mem_bytes: vec![0; k],
            net_in_bytes: vec![0; k],
            net_out_bytes: vec![0; k],
            busy: vec![0.0; k],
            tasks: plan.len(),
            ..Default::default()
        };

        // plan-local GC: an object produced by this plan whose last use is
        // also in this plan is released after that use (Ray/Dask reference
        // counting frees expression temporaries; named outputs survive).
        let mut produced_at: HashMap<ObjectId, usize> = HashMap::new();
        let mut last_use: HashMap<ObjectId, usize> = HashMap::new();
        for (idx, t) in plan.tasks.iter().enumerate() {
            for (obj, _) in &t.outputs {
                produced_at.insert(*obj, idx);
            }
            for obj in &t.inputs {
                last_use.insert(*obj, idx);
            }
        }
        // obj -> placement targets holding a copy (for release accounting)
        let mut holdings: HashMap<ObjectId, Vec<usize>> = HashMap::new();

        // worker slots: Ray mode -> any of r slots per node; Dask mode ->
        // the task's worker is fixed by its target.
        let mut slot_free: Vec<Vec<f64>> = vec![vec![0.0; r]; k];
        let mut nic_in_free = vec![0.0; k];
        let mut nic_out_free = vec![0.0; k];
        // one spill disk per node, serialized like the NICs
        let mut disk_free = vec![0.0f64; k];
        // object -> ready time per node
        let mut ready: HashMap<ObjectId, HashMap<usize, f64>> = HashMap::new();
        // object -> bytes
        let mut size: HashMap<ObjectId, u64> = HashMap::new();
        // resident bytes per placement target (per-worker heaps in Dask
        // mode; == per-node in Ray mode) for the spilling model
        let mut mem_target = vec![0.0f64; self.topo.targets()];

        for &(obj, target, bytes) in initial {
            let node = self.topo.node_of(target);
            ready.entry(obj).or_default().insert(node, 0.0);
            size.insert(obj, bytes);
            rep.mem_bytes[node] += bytes;
            rep.peak_mem_bytes[node] = rep.peak_mem_bytes[node].max(rep.mem_bytes[node]);
            mem_target[target] += bytes as f64;
            holdings.entry(obj).or_default().push(target);
        }
        if self.record_trace {
            for node in 0..k {
                rep.events.push(TraceEvent {
                    t: 0.0,
                    node,
                    mem_bytes: rep.mem_bytes[node],
                    net_in_bytes: 0,
                    net_out_bytes: 0,
                });
            }
        }

        let mut clock_dispatch = 0.0;
        for (task_idx, task) in plan.tasks.iter().enumerate() {
            clock_dispatch += self.net.gamma;
            let dst_node = self.topo.node_of(task.target);

            // --- satisfy inputs ---
            let mut deps_ready = 0.0f64;
            for tr in &task.transfers {
                let src_node = self.topo.node_of(tr.src);
                let bytes = tr.elems * 8;
                // App. A caching assumption: a block crosses into a node at
                // most once; later consumers on the same node read the
                // object-store copy.
                if let Some(&t_cached) = ready.get(&tr.obj).and_then(|m| m.get(&dst_node)) {
                    deps_ready = deps_ready.max(t_cached);
                    continue;
                }
                let src_ready = ready
                    .get(&tr.obj)
                    .and_then(|m| m.get(&src_node))
                    .copied()
                    .unwrap_or(0.0);
                let arrive = if src_node == dst_node {
                    // Dask worker-to-worker on the same node: D(n), no NIC
                    let t = src_ready + self.net.intra_dask.time(bytes);
                    rep.transfers += 1;
                    t
                } else {
                    let start = src_ready
                        .max(nic_out_free[src_node])
                        .max(nic_in_free[dst_node]);
                    let mut end = start + self.net.inter.time(bytes);
                    nic_out_free[src_node] = end;
                    nic_in_free[dst_node] = end;
                    let spill = self.spill_penalty(&mut rep, &mut mem_target, task.target, bytes);
                    if spill > 0.0 {
                        let ds = disk_free[dst_node].max(start);
                        disk_free[dst_node] = ds + spill;
                        end = end.max(ds + spill);
                    }
                    rep.net_out_bytes[src_node] += bytes;
                    rep.net_in_bytes[dst_node] += bytes;
                    rep.mem_bytes[dst_node] += bytes;
                    rep.peak_mem_bytes[dst_node] =
                        rep.peak_mem_bytes[dst_node].max(rep.mem_bytes[dst_node]);
                    holdings.entry(tr.obj).or_default().push(task.target);
                    rep.transfers += 1;
                    rep.transfer_bytes += bytes;
                    if self.record_trace {
                        rep.events.push(TraceEvent {
                            t: end,
                            node: dst_node,
                            mem_bytes: rep.mem_bytes[dst_node],
                            net_in_bytes: rep.net_in_bytes[dst_node],
                            net_out_bytes: rep.net_out_bytes[dst_node],
                        });
                        rep.events.push(TraceEvent {
                            t: end,
                            node: src_node,
                            mem_bytes: rep.mem_bytes[src_node],
                            net_in_bytes: rep.net_in_bytes[src_node],
                            net_out_bytes: rep.net_out_bytes[src_node],
                        });
                    }
                    end
                };
                ready.entry(tr.obj).or_default().insert(dst_node, arrive);
                deps_ready = deps_ready.max(arrive);
            }
            // local inputs: ready when produced on this node
            for &obj in &task.inputs {
                if let Some(t) = ready.get(&obj).and_then(|m| m.get(&dst_node)) {
                    deps_ready = deps_ready.max(*t);
                }
            }

            // --- pick a worker slot ---
            let slot = match self.topo.mode {
                SystemMode::Ray => {
                    // least-loaded slot on the node (local scheduler's job)
                    let mut best = 0;
                    for s in 1..r {
                        if slot_free[dst_node][s] < slot_free[dst_node][best] {
                            best = s;
                        }
                    }
                    best
                }
                SystemMode::Dask => self.topo.worker_of(task.target).unwrap(),
            };

            let start = clock_dispatch.max(deps_ready).max(slot_free[dst_node][slot]);
            let compute = if task.kernel.is_contraction() {
                task.flops() / self.compute.flops
            } else {
                task.ew_elems() / self.compute.ew_rate
            };
            let out_bytes = task.out_elems() * 8;
            // RFC overhead + object-store write of the outputs (R(n))
            let overhead = self.compute.task_overhead
                + match self.topo.mode {
                    SystemMode::Ray => self.net.intra_ray.time(out_bytes),
                    SystemMode::Dask => 0.0,
                };
            let mut end = start + compute + overhead;
            // object spilling: store overflow (outputs) plus read-back of
            // inputs resident on an over-capacity store, serialized on the
            // node's disk
            let mut spill = self.spill_penalty(&mut rep, &mut mem_target, task.target, out_bytes);
            spill += self.spill_readback(&mut rep, &mem_target, task);
            if spill > 0.0 {
                let ds = disk_free[dst_node].max(start);
                disk_free[dst_node] = ds + spill;
                end = end.max(ds + spill);
            }
            slot_free[dst_node][slot] = end;
            rep.busy[dst_node] += compute + overhead;
            rep.mem_bytes[dst_node] += out_bytes;
            rep.peak_mem_bytes[dst_node] =
                rep.peak_mem_bytes[dst_node].max(rep.mem_bytes[dst_node]);
            for (obj, shape) in &task.outputs {
                let bytes: u64 = shape.iter().map(|&d| d as u64).product::<u64>() * 8;
                ready.entry(*obj).or_default().insert(dst_node, end);
                size.insert(*obj, bytes);
                holdings.entry(*obj).or_default().push(task.target);
            }
            // GC: release plan-local temporaries after their last use
            for &obj in &task.inputs {
                if last_use.get(&obj) == Some(&task_idx) && produced_at.contains_key(&obj) {
                    let bytes = size.get(&obj).copied().unwrap_or(0);
                    if let Some(targets) = holdings.remove(&obj) {
                        for t in targets {
                            let node = self.topo.node_of(t);
                            mem_target[t] = (mem_target[t] - bytes as f64).max(0.0);
                            rep.mem_bytes[node] = rep.mem_bytes[node].saturating_sub(bytes);
                        }
                    }
                }
            }
            if self.record_trace {
                rep.events.push(TraceEvent {
                    t: end,
                    node: dst_node,
                    mem_bytes: rep.mem_bytes[dst_node],
                    net_in_bytes: rep.net_in_bytes[dst_node],
                    net_out_bytes: rep.net_out_bytes[dst_node],
                });
            }
            rep.makespan = rep.makespan.max(end);
        }
        rep.dispatch_time = clock_dispatch;
        rep
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::task::{Task, Transfer};
    use crate::runtime::kernel::{BinOp, Kernel};

    fn topo(k: usize, r: usize) -> Topology {
        Topology::new(k, r, SystemMode::Ray)
    }

    fn ew_task(target: usize, inputs: Vec<ObjectId>, out: ObjectId, transfers: Vec<Transfer>) -> Task {
        Task {
            kernel: Kernel::Ew(BinOp::Add),
            in_shapes: vec![vec![100, 100]; inputs.len()],
            inputs,
            outputs: vec![(out, vec![100, 100])],
            target,
            transfers,
        }
    }

    #[test]
    fn gamma_serializes_dispatch() {
        let net = NetParams {
            gamma: 1.0,
            ..NetParams::paper_testbed()
        };
        let ex = SimExecutor::new(topo(2, 2), net, ComputeParams::paper_testbed());
        let plan = Plan {
            tasks: (0..4)
                .map(|i| ew_task(i % 2, vec![i as u64], 100 + i as u64, vec![]))
                .collect(),
        };
        let initial: Vec<(ObjectId, usize, u64)> =
            (0..4).map(|i| (i as u64, (i % 2) as usize, 80_000)).collect();
        let rep = ex.run(&plan, &initial);
        // 4 tasks * γ=1s dispatch dominates
        assert!(rep.makespan >= 4.0, "makespan {}", rep.makespan);
        assert!((rep.dispatch_time - 4.0).abs() < 1e-9);
    }

    #[test]
    fn transfers_occupy_nics_and_count_bytes() {
        let ex = SimExecutor::new(
            topo(2, 1),
            NetParams::paper_testbed(),
            ComputeParams::paper_testbed(),
        );
        let t = ew_task(
            1,
            vec![0, 1],
            100,
            vec![Transfer {
                obj: 0,
                src: 0,
                elems: 10_000,
            }],
        );
        let rep = ex.run(
            &Plan { tasks: vec![t] },
            &[(0, 0, 80_000), (1, 1, 80_000)],
        );
        assert_eq!(rep.transfers, 1);
        assert_eq!(rep.transfer_bytes, 80_000);
        assert_eq!(rep.net_out_bytes[0], 80_000);
        assert_eq!(rep.net_in_bytes[1], 80_000);
        // transfer time must appear in the makespan
        let c = NetParams::paper_testbed().inter.time(80_000);
        assert!(rep.makespan >= c);
    }

    #[test]
    fn parallel_nodes_beat_one_node() {
        let ex = SimExecutor::new(
            topo(4, 1),
            NetParams::mpi_testbed(), // γ=0 so compute dominates
            ComputeParams::paper_testbed(),
        );
        let mk_plan = |spread: bool| Plan {
            tasks: (0..8)
                .map(|i| {
                    let target = if spread { i % 4 } else { 0 };
                    Task {
                        kernel: Kernel::Matmul,
                        inputs: vec![i as u64, 100 + i as u64],
                        in_shapes: vec![vec![512, 512], vec![512, 512]],
                        outputs: vec![(200 + i as u64, vec![512, 512])],
                        target,
                        transfers: vec![],
                    }
                })
                .collect(),
        };
        let initial: Vec<_> = (0..8)
            .flat_map(|i| {
                let t = i % 4;
                vec![(i as u64, t, 1u64 << 21), (100 + i as u64, t, 1u64 << 21)]
            })
            .collect();
        let spread = ex.run(&mk_plan(true), &initial);
        let initial0: Vec<_> = initial.iter().map(|&(o, _, b)| (o, 0, b)).collect();
        let piled = ex.run(&mk_plan(false), &initial0);
        assert!(
            spread.makespan * 2.0 < piled.makespan,
            "spread {} vs piled {}",
            spread.makespan,
            piled.makespan
        );
    }

    #[test]
    fn fused_chain_is_cheaper_than_unfused_in_the_model() {
        // Three Negs as a task chain vs one FusedEw[3] task: same math, but
        // the fused plan pays one dispatch γ, one task overhead and one
        // object-store write instead of three, and the chain's
        // intermediates never hit the bandwidth term.
        use crate::runtime::kernel::EwStep;
        let ex = SimExecutor::new(
            topo(1, 1),
            NetParams::paper_testbed(),
            ComputeParams::paper_testbed(),
        );
        let shape = vec![512, 512];
        let mk = |kernel: Kernel, inputs: Vec<ObjectId>, out: ObjectId| Task {
            in_shapes: vec![shape.clone(); inputs.len()],
            inputs,
            outputs: vec![(out, shape.clone())],
            target: 0,
            transfers: vec![],
            kernel,
        };
        let unfused = Plan {
            tasks: vec![
                mk(Kernel::Neg, vec![0], 100),
                mk(Kernel::Neg, vec![100], 101),
                mk(Kernel::Neg, vec![101], 102),
            ],
        };
        let fused = Plan {
            tasks: vec![mk(
                Kernel::FusedEw(vec![EwStep::Neg, EwStep::Neg, EwStep::Neg]),
                vec![0],
                200,
            )],
        };
        let initial = [(0u64, 0usize, 512 * 512 * 8u64)];
        let ru = ex.run(&unfused, &initial);
        let rf = ex.run(&fused, &initial);
        assert_eq!(ru.tasks, 3);
        assert_eq!(rf.tasks, 1);
        assert!(
            rf.makespan < ru.makespan,
            "fused {} !< unfused {}",
            rf.makespan,
            ru.makespan
        );
        // and the chain's intermediates never became resident objects
        assert!(rf.max_mem_bytes() < ru.max_mem_bytes());
    }

    #[test]
    fn trace_events_recorded_when_enabled() {
        let mut ex = SimExecutor::new(
            topo(2, 1),
            NetParams::paper_testbed(),
            ComputeParams::paper_testbed(),
        );
        ex.record_trace = true;
        let plan = Plan {
            tasks: vec![ew_task(0, vec![0], 10, vec![])],
        };
        let rep = ex.run(&plan, &[(0, 0, 800)]);
        assert!(rep.events.len() >= 3); // 2 initial + 1 task
        assert!(rep.events.iter().all(|e| e.t >= 0.0));
    }
}
