//! Plan execution: the discrete-event simulated executor (paper-scale,
//! modeled time) and the real threaded executor (actual numerics via the
//! kernel backends).
//!
//! The real executor is dependency-counted and work-stealing: per-task
//! input counts are precomputed from the plan, task completion enqueues
//! newly-ready consumers onto per-node ready deques (plus a global
//! overflow for saturated nodes), and idle workers steal from the
//! most-loaded sibling node — pulling the stolen task's inputs through
//! the object stores so stolen work pays real transfer bytes. There are
//! no condvar waits on the hot path; the condvar only parks fully idle
//! workers, which re-check for a provable deadlock (nothing running,
//! nothing queued, work left) on a `deadlock_timeout` heartbeat and fail
//! the run naming the blocking `ObjectId`s. Kernel parallelism is granted
//! per task via [`crate::runtime::ExecContext`] — no process-global
//! parallelism state exists. Communication overlaps compute: per-node
//! transfer threads ([`prefetch::Prefetcher`]) pull near-ready tasks'
//! remote inputs in the background — in topological-depth priority
//! order, under a lookahead byte budget — and absorb the memory
//! manager's spill writes, so workers mostly find inputs resident and
//! never block on file I/O.
//!
//! The executor is fault-tolerant: an optional deterministic
//! [`fault::FaultInjector`] fails kernels, transfers, and spill I/O at
//! seeded sites (plus at most one scheduled whole-node loss), and the
//! [`recovery`] module walks plan lineage backward from any lost
//! `ObjectId` to rebuild the minimal recompute subgraph on surviving
//! nodes — transient faults retry with bounded backoff, and chaos runs
//! must converge to the bit-identical fault-free result.
//!
//! Each run also produces a [`feedback::RuntimeFeedback`]: the
//! reconciliation of plan against observation (steal migrations, demand
//! pulls, spill pressure, runtime replicas) that the session folds back
//! into the scheduler's load model, so the *next* plan's Eq. 2
//! simulation sees where load actually landed.

pub mod fault;
pub mod feedback;
pub mod lifetime;
pub mod prefetch;
pub mod real_exec;
pub mod recovery;
pub mod sim_exec;
pub mod task;

pub use fault::{FaultInjector, FaultPlan, FaultSite, NodeLossMode, NodeLossSpec};
pub use feedback::{NodeFeedback, RuntimeFeedback};
pub use lifetime::Lifetimes;
pub use prefetch::{PrefetchStats, Prefetcher};
pub use real_exec::{NodeExecStats, RealExecutor, RealReport};
pub use recovery::{ExecError, RecoveryStats};
pub use sim_exec::{SimExecutor, SimReport, TraceEvent};
pub use task::{Plan, Task, Transfer};
