//! Plan execution: the discrete-event simulated executor (paper-scale,
//! modeled time) and the real threaded executor (actual numerics via the
//! kernel backends).

pub mod real_exec;
pub mod sim_exec;
pub mod task;

pub use real_exec::{RealExecutor, RealReport};
pub use sim_exec::{SimExecutor, SimReport, TraceEvent};
pub use task::{Plan, Task, Transfer};
