//! Communication/compute overlap: per-node transfer threads.
//!
//! The paper's whole argument (§5, Eq. 2) is that execution time on
//! task-based systems is dominated by data movement, not FLOPs — yet a
//! demand-pull executor pays every cross-node input transfer
//! synchronously on the worker hot path. LSHS already committed every
//! transfer at plan time (`PlacementSim::pulls` land in
//! [`crate::exec::Task::transfers`]), so the executor has perfect
//! foreknowledge of what will move where. This module spends that
//! knowledge: one transfer thread per node drains a queue of *pull* jobs
//! (move an input to the node that will run its consumer) and *spill
//! sweep* jobs (complete the memory manager's queued asynchronous spill
//! writes), so by the time a worker dequeues a task its remote inputs are
//! usually resident and spill file I/O never blocks a kernel.
//!
//! The pull queue is a **priority queue**, not a FIFO: jobs are ordered
//! by the consumer task's topological depth in the plan (ties broken
//! first-come-first-served), so the inputs of the *next-to-run* tasks
//! move before the inputs of work that is many dependency levels away.
//! Spill sweeps always run before pulls — finishing a queued spill frees
//! memory, pulling consumes it. Queued pulls are also bounded by a
//! **byte budget** (the executor derives it from
//! `SessionConfig::mem_budget_bytes`): a request that would push the
//! queued-pull backlog past the budget is declined (and un-deduped, so
//! the demand path or a later, shorter queue can still fetch it) — there
//! is no point pulling blocks that memory pressure would immediately
//! evict.
//!
//! Protocol with [`crate::exec::RealExecutor`]:
//!
//! * a task whose unmet-dependency count drops to ≤ 1 has its inputs
//!   posted to its target node's queue at its topo-depth priority (the
//!   plan's `Transfer::src` is the locate hint); requests are deduped
//!   per `(node, object)` by *requester-task set* — one queued job
//!   serves every interested task, re-registering the same
//!   `(task, object)` is idempotent (warm triggers fire more than once
//!   per consumer), and cancellation is per requester;
//! * a *stolen* task first **cancels** its queued pulls on the victim's
//!   node ([`Prefetcher::cancel_pull`]): if no other task on the victim
//!   still wants the object, the queued job is dropped at pop time and
//!   never moves (or accounts) a byte. The thief then re-posts only the
//!   inputs not already resident on its own node, so batched steals warm
//!   up behind the first task without re-pulling what they already have;
//! * workers never wait on a prefetch — a miss simply falls back to the
//!   demand pull they always did, and the racing double-pull is resolved
//!   (and accounted once) under the destination store lock;
//! * a pull for an object that is not yet available (producer still
//!   running) or no longer wanted (lifetime GC released it) is dropped
//!   and un-deduped so a later warm trigger may re-request it.
//!
//! Per-node counters land in [`crate::exec::RealReport::prefetch_stats`]:
//! `prefetch_bytes` (moved by transfer threads) + `demand_pull_bytes`
//! (moved on the worker hot path) add up to exactly the node's
//! `net_in_bytes` for the run — the property suites in
//! `tests/exec_overlap.rs` and `tests/feedback.rs` assert that identity
//! (cancelled and declined pulls never account bytes, because they never
//! move any) — while `prefetch_hits` counts worker input acquisitions
//! satisfied by a completed prefetch and `async_spill_bytes` counts
//! spill-file bytes written off the hot path.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, HashSet};
use std::sync::{Arc, Condvar, Mutex};

use crate::metrics::runtime_trace::{EventKind, FetchOrigin, RunRecorder};
use crate::store::{MemoryManager, ObjectId, StoreSet};

use super::fault::{FaultInjector, FaultSite};

/// Per-node communication-overlap counters for one run.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct PrefetchStats {
    /// Bytes pulled to this node by its transfer thread (background).
    pub prefetch_bytes: u64,
    /// Worker input acquisitions that found the object resident thanks
    /// to a completed prefetch (no bytes paid on the hot path).
    pub prefetch_hits: u64,
    /// Bytes pulled to this node on the worker hot path (prefetch miss,
    /// stolen-task pulls, or prefetch disabled paths).
    pub demand_pull_bytes: u64,
    /// Spill-file bytes written by this node's transfer thread (the
    /// memory manager's asynchronous spill pipeline).
    pub async_spill_bytes: u64,
}

/// One queued background pull. Min-ordered by `(prio, seq)`: the
/// executor passes the consumer task's topological depth as `prio`, so
/// next-to-run inputs move first and equal depths stay FIFO.
#[derive(Debug, PartialEq, Eq, PartialOrd, Ord)]
struct PullJob {
    prio: u64,
    seq: u64,
    obj: ObjectId,
    bytes: u64,
    /// Source node the scheduler's load model committed to
    /// (`Transfer::src`), short-circuiting the locate scan.
    hint: Option<usize>,
}

enum Job {
    Pull(PullJob),
    /// Complete the memory manager's queued spill writes for this node.
    SpillSweep,
}

struct QueueState {
    /// Min-heap of queued pulls (`Reverse` turns the max-heap around).
    pulls: BinaryHeap<Reverse<PullJob>>,
    /// Outstanding spill sweeps; always served before any pull.
    sweeps: usize,
    /// Bytes represented by the queued pulls (the budget gate).
    queued_bytes: u64,
    seq: u64,
    shutdown: bool,
}

struct NodeQueue {
    q: Mutex<QueueState>,
    cv: Condvar,
}

#[derive(Default)]
struct Track {
    /// obj -> the *requester task ids* with an outstanding interest
    /// (queued, in flight, or completed). Tracking requesters — not a
    /// bare count — makes registration idempotent per `(task, object)`
    /// (warm triggers legitimately fire more than once for the same
    /// consumer, and a task may list the same input twice), and makes
    /// [`Prefetcher::cancel_pull`] surgical: a steal removes exactly the
    /// migrated task's interest, never another task's. The empty→nonempty
    /// transition queues the single shared job; an entry emptied by
    /// cancellation makes the queued job stale (skipped at pop time).
    requested: HashMap<ObjectId, HashSet<usize>>,
    /// Objects whose pull completed with the object resident here.
    done: HashSet<ObjectId>,
}

/// Per-run transfer-thread coordinator: one priority job queue, dedup
/// table and counter block per node. The executor spawns one `serve`
/// loop per node inside its worker scope and calls
/// [`Prefetcher::shutdown`] after the workers join — `serve` drains its
/// remaining queue (the async-spill write barrier) before exiting, so by
/// the time the scope closes every queued transfer and spill write has
/// completed.
pub struct Prefetcher {
    queues: Vec<NodeQueue>,
    track: Vec<Mutex<Track>>,
    stats: Vec<Mutex<PrefetchStats>>,
    /// Cap on each node's queued-pull backlog, in bytes (`None` =
    /// unbounded). Derived from the session's memory budget so the
    /// pipeline never runs further ahead than pressure allows.
    byte_budget: Option<u64>,
    /// Run recorder for fetch events (`None` when tracing is off). Only
    /// consulted after a transfer actually moved bytes — the
    /// nothing-to-do early returns in `pull` never touch it.
    recorder: Option<Arc<RunRecorder>>,
    /// Deterministic fault injector ([`FaultSite::Transfer`]): an
    /// injected background-pull failure drops the job before any byte
    /// moves — the demand path (which retries with backoff) covers the
    /// object, so the byte identity `prefetch + demand == net_in` holds
    /// under chaos. `None` (the default) costs one `Option` test.
    fault: Option<Arc<FaultInjector>>,
}

impl Prefetcher {
    pub fn new(num_nodes: usize, byte_budget: Option<u64>) -> Self {
        Self {
            queues: (0..num_nodes)
                .map(|_| NodeQueue {
                    q: Mutex::new(QueueState {
                        pulls: BinaryHeap::new(),
                        sweeps: 0,
                        queued_bytes: 0,
                        seq: 0,
                        shutdown: false,
                    }),
                    cv: Condvar::new(),
                })
                .collect(),
            track: (0..num_nodes).map(|_| Mutex::new(Track::default())).collect(),
            stats: (0..num_nodes)
                .map(|_| Mutex::new(PrefetchStats::default()))
                .collect(),
            byte_budget,
            recorder: None,
            fault: None,
        }
    }

    /// Attach a run recorder: every background pull that moves bytes
    /// emits a `Fetch(Prefetch)` event.
    pub fn with_recorder(mut self, r: Arc<RunRecorder>) -> Self {
        self.recorder = Some(r);
        self
    }

    /// Arm deterministic fault injection on background pulls (chaos
    /// runs; mirrors [`Prefetcher::with_recorder`]).
    pub fn with_fault(mut self, f: Arc<FaultInjector>) -> Self {
        self.fault = Some(f);
        self
    }

    pub fn num_nodes(&self) -> usize {
        self.queues.len()
    }

    /// Bytes currently queued (not yet executed) on `node`'s pull queue —
    /// introspection for tests and the budget gate.
    pub fn queued_pull_bytes(&self, node: usize) -> u64 {
        self.queues[node].q.lock().unwrap().queued_bytes
    }

    /// Queue a background pull of `obj` (`bytes` large) to `node`, at
    /// priority `prio` (lower = sooner; the executor passes the consumer
    /// task's topological depth), on behalf of consumer task `requester`.
    /// Requests are deduped per `(node, object)` by requester-task set —
    /// registering the same `(task, object)` twice is idempotent — and
    /// only the empty→nonempty transition queues a job. The request is
    /// *declined* — the whole registration is dropped, so the demand
    /// path or a later re-request (against a shorter queue) covers it —
    /// when the node's queued-pull backlog would exceed the byte budget,
    /// or after shutdown.
    pub fn request_pull(
        &self,
        node: usize,
        obj: ObjectId,
        hint: Option<usize>,
        prio: u64,
        bytes: u64,
        requester: usize,
    ) {
        {
            let mut t = self.track[node].lock().unwrap();
            let reqs = t.requested.entry(obj).or_default();
            let first = reqs.is_empty();
            reqs.insert(requester);
            if !first {
                return; // a queued/in-flight/completed job covers this too
            }
        }
        let nq = &self.queues[node];
        let mut q = nq.q.lock().unwrap();
        let mut declined = q.shutdown
            || self
                .byte_budget
                .map_or(false, |b| q.queued_bytes + bytes > b);
        if declined && !q.shutdown {
            // over budget: the backlog may be padded with cancelled jobs
            // (their bytes stay charged until popped) or with pulls for
            // much deeper consumers than this one — reclaim both before
            // giving up, so cancellations can't starve the budget and a
            // next-to-run input always outranks far-future work
            declined = !self.make_room(node, &mut q, prio, bytes);
        }
        if declined {
            drop(q);
            // drop the registration outright (ours and any racer's that
            // piggybacked on it): no job exists, so a surviving entry
            // would permanently swallow every later request for this
            // object — the demand path covers the racer
            self.unrequest(node, obj);
            return;
        }
        q.seq += 1;
        let seq = q.seq;
        q.queued_bytes += bytes;
        q.pulls.push(Reverse(PullJob {
            prio,
            seq,
            obj,
            bytes,
            hint,
        }));
        drop(q);
        nq.cv.notify_one();
    }

    /// Withdraw `requester`'s interest in `obj`'s pull to `node` (a steal
    /// moved that consumer elsewhere). When the last interested task
    /// withdraws, the queued job is cancelled: it is skipped at pop time
    /// and never moves or accounts a byte. Removing an absent requester
    /// is a no-op, so cancelling a task whose warm trigger never fired —
    /// or one whose request was already declined — is harmless and can
    /// never cancel another task's pull.
    pub fn cancel_pull(&self, node: usize, obj: ObjectId, requester: usize) {
        let mut t = self.track[node].lock().unwrap();
        if let Some(reqs) = t.requested.get_mut(&obj) {
            reqs.remove(&requester);
            if reqs.is_empty() {
                t.requested.remove(&obj);
            }
        }
    }

    /// Wake `node`'s transfer thread to complete queued spill writes.
    /// Always enqueued (even mid-shutdown-drain): a pending spill entry
    /// must be finalized or swept, never silently forgotten. Sweeps run
    /// before any queued pull — completing a spill frees memory, a pull
    /// consumes it.
    pub fn notify_spill(&self, node: usize) {
        let nq = &self.queues[node];
        let mut q = nq.q.lock().unwrap();
        q.sweeps += 1;
        drop(q);
        nq.cv.notify_one();
    }

    /// Has a completed prefetch made `obj` resident on `node`? (Hit
    /// accounting on the worker acquire path.)
    pub fn was_prefetched(&self, node: usize, obj: ObjectId) -> bool {
        self.track[node].lock().unwrap().done.contains(&obj)
    }

    /// Worker-side counters: bytes pulled on the hot path.
    pub fn add_demand(&self, node: usize, bytes: u64) {
        self.stats[node].lock().unwrap().demand_pull_bytes += bytes;
    }

    /// Worker-side counters: an input served by a completed prefetch.
    pub fn add_hit(&self, node: usize) {
        self.stats[node].lock().unwrap().prefetch_hits += 1;
    }

    pub fn stats(&self) -> Vec<PrefetchStats> {
        self.stats.iter().map(|s| s.lock().unwrap().clone()).collect()
    }

    /// Tell every transfer thread to drain its queue and exit. Called
    /// after the worker threads join; the scope join after this call is
    /// the pipeline's write-completion barrier.
    pub fn shutdown(&self) {
        for nq in &self.queues {
            nq.q.lock().unwrap().shutdown = true;
            nq.cv.notify_all();
        }
    }

    /// Try to free backlog budget for an incoming `(prio, bytes)` pull:
    /// drop jobs cancelled while queued (no live requester — their bytes
    /// are still charged until popped), then evict queued jobs whose
    /// consumers are *strictly deeper* than the incoming one, deepest
    /// first (their registrations are dropped so they can re-request
    /// later; the demand path covers them meanwhile). Returns whether
    /// `bytes` now fits. Caller holds the queue lock; the track lock is
    /// taken inside it — the same queue→track order `take_job` uses.
    fn make_room(
        &self,
        node: usize,
        q: &mut QueueState,
        prio: u64,
        bytes: u64,
    ) -> bool {
        let Some(budget) = self.byte_budget else { return true };
        let mut t = self.track[node].lock().unwrap();
        let mut jobs: Vec<PullJob> = q.pulls.drain().map(|Reverse(j)| j).collect();
        // pass 1 — shed stale (cancelled) jobs: they would never execute
        jobs.retain(|j| t.requested.contains_key(&j.obj));
        let mut total: u64 = jobs.iter().map(|j| j.bytes).sum();
        // pass 2 — evict deepest-first while the newcomer still won't
        // fit. Skipped entirely for a request no amount of eviction can
        // admit (bytes > budget): wiping other tasks' prefetches for
        // zero gain would only convert them into demand pulls.
        if bytes <= budget {
            jobs.sort_unstable();
            while total + bytes > budget
                && jobs.last().map_or(false, |j| j.prio > prio)
            {
                let evicted = jobs.pop().unwrap();
                total -= evicted.bytes;
                t.requested.remove(&evicted.obj);
            }
        }
        q.queued_bytes = total;
        q.pulls.extend(jobs.into_iter().map(Reverse));
        total + bytes <= budget
    }

    fn mark_done(&self, node: usize, obj: ObjectId) {
        self.track[node].lock().unwrap().done.insert(obj);
    }

    fn unrequest(&self, node: usize, obj: ObjectId) {
        self.track[node].lock().unwrap().requested.remove(&obj);
    }

    /// Dequeue the next job for `node`, or `None` at shutdown with an
    /// empty queue. Blocks while idle. Spill sweeps first; then queued
    /// pulls in `(prio, seq)` order, lazily discarding cancelled jobs
    /// (no live requester) and — after shutdown — all pulls (workers
    /// have joined; only spill writes still matter).
    fn take_job(&self, node: usize) -> Option<Job> {
        let nq = &self.queues[node];
        let mut q = nq.q.lock().unwrap();
        loop {
            if q.sweeps > 0 {
                q.sweeps -= 1;
                return Some(Job::SpillSweep);
            }
            if let Some(Reverse(job)) = q.pulls.pop() {
                q.queued_bytes -= job.bytes;
                if q.shutdown {
                    continue; // nobody left to consume the pull
                }
                if !self.track[node]
                    .lock()
                    .unwrap()
                    .requested
                    .contains_key(&job.obj)
                {
                    continue; // cancelled while queued: never touches bytes
                }
                return Some(Job::Pull(job));
            }
            if q.shutdown {
                return None;
            }
            q = nq.cv.wait(q).unwrap();
        }
    }

    /// Transfer-thread body for `node`: drains jobs until shutdown *and*
    /// an empty queue. `spillable` is the run's lifetime-pass pin oracle
    /// (what the manager may page out); `wanted` reports whether an
    /// object still has pending consumers (a pull of a GC-released
    /// intermediate would resurrect dead bytes, so it is dropped).
    pub fn serve(
        &self,
        node: usize,
        stores: &StoreSet,
        memory: Option<&MemoryManager>,
        spillable: &(dyn Fn(ObjectId) -> bool + Sync),
        wanted: &(dyn Fn(ObjectId) -> bool + Sync),
    ) {
        while let Some(job) = self.take_job(node) {
            match job {
                Job::Pull(j) => {
                    self.pull(node, j.obj, j.hint, stores, memory, spillable, wanted)
                }
                Job::SpillSweep => {
                    if let Some(m) = memory {
                        let written = m.process_pending_spills(stores, node);
                        if written > 0 {
                            self.stats[node].lock().unwrap().async_spill_bytes += written;
                        }
                    }
                }
            }
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn pull(
        &self,
        node: usize,
        obj: ObjectId,
        hint: Option<usize>,
        stores: &StoreSet,
        memory: Option<&MemoryManager>,
        spillable: &(dyn Fn(ObjectId) -> bool + Sync),
        wanted: &(dyn Fn(ObjectId) -> bool + Sync),
    ) {
        if stores.contains(node, obj) {
            // already local (placement, a demand pull, or an earlier pull
            // that marked itself): nothing moved, so deliberately NOT
            // marked done — prefetch_hits must only credit acquisitions
            // this thread actually made resident
            return;
        }
        if !wanted(obj) {
            // released mid-queue: pulling would resurrect dead bytes
            self.unrequest(node, obj);
            return;
        }
        if stores.peer_dead(node) {
            // the destination's transport endpoint is gone: its work is
            // being diverted to survivors, so a background pull *to* it
            // would be wasted bytes at best and a livelock at worst
            self.unrequest(node, obj);
            return;
        }
        if let Some(fj) = &self.fault {
            if fj.should_fail(FaultSite::Transfer, obj) {
                // injected transfer fault: the pull dies before moving a
                // byte, exactly like a decline — un-dedup so the demand
                // path (or a later warm trigger) recovers the object
                if let Some(r) = &self.recorder {
                    r.event(node, None, Some(obj), 0, EventKind::Fault);
                }
                self.unrequest(node, obj);
                return;
            }
        }
        let (landed, bytes) = match memory {
            Some(m) => {
                // the manager emits the fetch event itself, tagged with
                // this origin (it knows the actual source node)
                let (b, n) =
                    m.acquire_tagged(stores, node, obj, spillable, FetchOrigin::Prefetch);
                (b.is_some(), n)
            }
            None => {
                let src = stores.locate(obj, hint.unwrap_or(node));
                match src.and_then(|s| stores.try_transfer(s, node, obj)) {
                    Some(n) => {
                        if n > 0 {
                            if let Some(r) = &self.recorder {
                                r.event(
                                    node,
                                    src,
                                    Some(obj),
                                    n,
                                    EventKind::Fetch(FetchOrigin::Prefetch),
                                );
                            }
                        }
                        (true, n)
                    }
                    None => (false, 0),
                }
            }
        };
        if bytes > 0 {
            // counted even when the pull then lost its copy to eviction:
            // the traffic happened, and the per-node byte identity
            // (prefetch + demand == net_in) must see it
            self.stats[node].lock().unwrap().prefetch_bytes += bytes;
        }
        if landed {
            self.mark_done(node, obj);
        } else {
            // producer not finished yet, or the object is gone: let a
            // later warm trigger (or the demand path) handle it
            self.unrequest(node, obj);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::Block;
    use std::sync::Arc;

    fn yes(_: ObjectId) -> bool {
        true
    }

    /// Bounded poll (≤ 5s) so a lost wakeup fails loudly, never hangs CI.
    fn wait_for(cond: impl Fn() -> bool, what: &str) {
        for _ in 0..50_000 {
            if cond() {
                return;
            }
            std::thread::sleep(std::time::Duration::from_micros(100));
        }
        panic!("timed out waiting for {what}");
    }

    #[test]
    fn pull_moves_remote_object_and_counts_bytes() {
        let stores = StoreSet::new(2);
        stores.put(0, 7, Arc::new(Block::filled(&[4, 4], 2.0)));
        let pf = Prefetcher::new(2, None);
        std::thread::scope(|s| {
            s.spawn(|| pf.serve(1, &stores, None, &yes, &yes));
            pf.request_pull(1, 7, Some(0), 0, 128, 100);
            wait_for(|| stores.contains(1, 7), "prefetch of object 7");
            // another requester: deduped away, no second transfer
            pf.request_pull(1, 7, None, 0, 128, 101);
            // shutdown drains whatever is still queued before serve exits
            pf.shutdown();
        });
        assert!(pf.was_prefetched(1, 7));
        assert_eq!(pf.stats()[1].prefetch_bytes, 128);
        assert_eq!(stores.snapshot()[1].2, 128, "exactly one transfer");
        assert_eq!(pf.queued_pull_bytes(1), 0, "executed job left the backlog");
    }

    #[test]
    fn unavailable_pull_is_dropped_and_rerequestable() {
        let stores = StoreSet::new(2);
        stores.put(0, 50, Arc::new(Block::filled(&[2, 2], 5.0)));
        let pf = Prefetcher::new(2, None);
        std::thread::scope(|s| {
            s.spawn(|| pf.serve(1, &stores, None, &yes, &yes));
            pf.request_pull(1, 42, None, 0, 32, 100); // exists nowhere yet
            pf.request_pull(1, 50, Some(0), 1, 32, 101); // deeper marker behind it
            wait_for(|| stores.contains(1, 50), "marker pull");
            // 42 was processed first (lower priority value) and dropped
            assert!(!pf.was_prefetched(1, 42));
            assert_eq!(pf.stats()[1].prefetch_bytes, 32);
            // the drop un-deduped it: once the object exists, a
            // re-request goes through instead of being swallowed
            stores.put(0, 42, Arc::new(Block::filled(&[2, 2], 1.0)));
            pf.request_pull(1, 42, Some(0), 0, 32, 102);
            wait_for(|| stores.contains(1, 42), "re-requested pull");
            pf.shutdown();
        });
        assert!(pf.was_prefetched(1, 42));
    }

    #[test]
    fn unwanted_pull_is_skipped() {
        let stores = StoreSet::new(2);
        stores.put(0, 9, Arc::new(Block::filled(&[2, 2], 3.0)));
        let pf = Prefetcher::new(2, None);
        fn no(_: ObjectId) -> bool {
            false
        }
        std::thread::scope(|s| {
            s.spawn(|| pf.serve(1, &stores, None, &yes, &no));
            pf.request_pull(1, 9, Some(0), 0, 32, 100);
            pf.shutdown();
        });
        assert!(!stores.contains(1, 9), "dead objects must not be pulled");
    }

    #[test]
    fn pulls_dequeue_in_topo_depth_order_then_fifo() {
        // queue before any server runs, then drain with take_job directly
        let pf = Prefetcher::new(1, None);
        pf.request_pull(0, 30, None, 3, 8, 1);
        pf.request_pull(0, 10, None, 1, 8, 2);
        pf.request_pull(0, 11, None, 1, 8, 3);
        pf.request_pull(0, 20, None, 2, 8, 4);
        assert_eq!(pf.queued_pull_bytes(0), 32);
        let mut order = Vec::new();
        for _ in 0..4 {
            match pf.take_job(0) {
                Some(Job::Pull(j)) => order.push(j.obj),
                other => panic!(
                    "expected a pull, got {:?}",
                    matches!(other, Some(Job::SpillSweep))
                ),
            }
        }
        // depth order, FIFO within equal depth
        assert_eq!(order, vec![10, 11, 20, 30]);
        assert_eq!(pf.queued_pull_bytes(0), 0);
    }

    #[test]
    fn spill_sweeps_preempt_queued_pulls() {
        let pf = Prefetcher::new(1, None);
        pf.request_pull(0, 1, None, 0, 8, 1);
        pf.notify_spill(0);
        assert!(matches!(pf.take_job(0), Some(Job::SpillSweep)));
        assert!(matches!(pf.take_job(0), Some(Job::Pull(_))));
    }

    #[test]
    fn byte_budget_declines_and_undedups_overflowing_requests() {
        let stores = StoreSet::new(2);
        stores.put(0, 1, Arc::new(Block::filled(&[4, 4], 1.0))); // 128 B
        stores.put(0, 2, Arc::new(Block::filled(&[4, 4], 2.0))); // 128 B
        let pf = Prefetcher::new(2, Some(128));
        pf.request_pull(1, 1, Some(0), 0, 128, 100);
        // backlog full: this request is declined, not queued
        pf.request_pull(1, 2, Some(0), 0, 128, 101);
        assert_eq!(pf.queued_pull_bytes(1), 128, "second pull must be declined");
        std::thread::scope(|s| {
            s.spawn(|| pf.serve(1, &stores, None, &yes, &yes));
            wait_for(|| stores.contains(1, 1), "budgeted pull");
            // declined = un-deduped: once the backlog drained, the same
            // object can be requested again and goes through
            wait_for(|| pf.queued_pull_bytes(1) == 0, "backlog drain");
            pf.request_pull(1, 2, Some(0), 0, 128, 101);
            wait_for(|| stores.contains(1, 2), "re-requested declined pull");
            pf.shutdown();
        });
        // the declined attempt never moved bytes; the two executed pulls
        // account exactly their traffic
        assert_eq!(pf.stats()[1].prefetch_bytes, 256);
        assert_eq!(stores.snapshot()[1].2, 256);
    }

    #[test]
    fn cancelled_jobs_release_their_budget_on_the_next_request() {
        // obj 1 fills the budget, then is cancelled; its bytes are still
        // charged (lazy) — but a new request must reclaim them instead of
        // being declined against a phantom backlog
        let pf = Prefetcher::new(1, Some(128));
        pf.request_pull(0, 1, None, 0, 128, 7);
        pf.cancel_pull(0, 1, 7);
        assert_eq!(pf.queued_pull_bytes(0), 128, "stale bytes charged lazily");
        pf.request_pull(0, 2, None, 0, 128, 8);
        assert_eq!(
            pf.queued_pull_bytes(0),
            128,
            "stale job pruned, live job admitted"
        );
        match pf.take_job(0) {
            Some(Job::Pull(j)) => assert_eq!(j.obj, 2, "only the live job remains"),
            _ => panic!("expected the admitted pull"),
        }
        assert_eq!(pf.queued_pull_bytes(0), 0);
    }

    #[test]
    fn shallower_requests_evict_deeper_queued_pulls() {
        // far-future (depth 9) work fills the budget; a next-to-run
        // (depth 0) input must displace it, and the evicted registration
        // is dropped so the deep task can re-request later
        let pf = Prefetcher::new(1, Some(128));
        pf.request_pull(0, 1, None, 9, 128, 7);
        pf.request_pull(0, 2, None, 0, 128, 8);
        match pf.take_job(0) {
            Some(Job::Pull(j)) => assert_eq!(j.obj, 2, "depth-0 displaced depth-9"),
            _ => panic!("expected the shallow pull"),
        }
        assert_eq!(pf.queued_pull_bytes(0), 0);
        // the evicted deep pull was un-deduped: it can come back
        pf.request_pull(0, 1, None, 9, 128, 7);
        assert_eq!(pf.queued_pull_bytes(0), 128);
        // but an equal-depth request never evicts (strictly-deeper rule)
        pf.request_pull(0, 3, None, 9, 128, 9);
        assert_eq!(pf.queued_pull_bytes(0), 128, "equal depth must not evict");
    }

    #[test]
    fn cancelled_pulls_never_move_or_account_bytes() {
        let stores = StoreSet::new(2);
        stores.put(0, 5, Arc::new(Block::filled(&[4, 4], 5.0)));
        stores.put(0, 6, Arc::new(Block::filled(&[4, 4], 6.0)));
        let pf = Prefetcher::new(2, None);
        // obj 5 queued at depth 0 (pops first), then cancelled; the depth-9
        // marker behind it proves the queue was really drained past it
        pf.request_pull(1, 5, Some(0), 0, 128, 7);
        pf.request_pull(1, 6, Some(0), 9, 128, 8);
        pf.cancel_pull(1, 5, 7);
        std::thread::scope(|s| {
            s.spawn(|| pf.serve(1, &stores, None, &yes, &yes));
            wait_for(|| stores.contains(1, 6), "marker pull");
            pf.shutdown();
        });
        assert!(!stores.contains(1, 5), "cancelled pull must not move data");
        assert_eq!(pf.stats()[1].prefetch_bytes, 128, "only the marker counted");
        assert_eq!(stores.snapshot()[1].2, 128);
        assert!(!pf.was_prefetched(1, 5));
    }

    #[test]
    fn requester_set_survives_other_tasks_cancel() {
        let stores = StoreSet::new(2);
        stores.put(0, 5, Arc::new(Block::filled(&[4, 4], 5.0)));
        let pf = Prefetcher::new(2, None);
        // two consumer tasks on node 1 want obj 5; task 7 re-registers
        // (idempotent: warm triggers fire more than once per consumer)
        // and is then stolen away — task 8's interest must survive
        pf.request_pull(1, 5, Some(0), 0, 128, 7);
        pf.request_pull(1, 5, Some(0), 0, 128, 7);
        pf.request_pull(1, 5, Some(0), 2, 128, 8);
        pf.cancel_pull(1, 5, 7);
        // cancelling an absent requester must not touch task 8's interest
        pf.cancel_pull(1, 5, 99);
        std::thread::scope(|s| {
            s.spawn(|| pf.serve(1, &stores, None, &yes, &yes));
            wait_for(|| stores.contains(1, 5), "surviving requester's pull");
            pf.shutdown();
        });
        assert!(pf.was_prefetched(1, 5));
        assert_eq!(pf.stats()[1].prefetch_bytes, 128);
    }

    #[test]
    fn double_registration_then_one_cancel_fully_cancels() {
        // the same (task, object) registered twice is ONE interest: a
        // single cancel (the task was stolen) must kill the queued job
        let stores = StoreSet::new(2);
        stores.put(0, 5, Arc::new(Block::filled(&[4, 4], 5.0)));
        stores.put(0, 6, Arc::new(Block::filled(&[4, 4], 6.0)));
        let pf = Prefetcher::new(2, None);
        pf.request_pull(1, 5, Some(0), 0, 128, 7);
        pf.request_pull(1, 5, Some(0), 0, 128, 7); // duplicate warm trigger
        pf.request_pull(1, 6, Some(0), 9, 128, 8); // drain marker
        pf.cancel_pull(1, 5, 7);
        std::thread::scope(|s| {
            s.spawn(|| pf.serve(1, &stores, None, &yes, &yes));
            wait_for(|| stores.contains(1, 6), "marker pull");
            pf.shutdown();
        });
        assert!(!stores.contains(1, 5), "stale job must not execute");
        assert_eq!(pf.stats()[1].prefetch_bytes, 128, "only the marker counted");
    }
}
