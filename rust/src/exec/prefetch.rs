//! Communication/compute overlap: per-node transfer threads.
//!
//! The paper's whole argument (§5, Eq. 2) is that execution time on
//! task-based systems is dominated by data movement, not FLOPs — yet a
//! demand-pull executor pays every cross-node input transfer
//! synchronously on the worker hot path. LSHS already committed every
//! transfer at plan time (`PlacementSim::pulls` land in
//! [`crate::exec::Task::transfers`]), so the executor has perfect
//! foreknowledge of what will move where. This module spends that
//! knowledge: one transfer thread per node drains a queue of *pull* jobs
//! (move an input to the node that will run its consumer) and *spill
//! sweep* jobs (complete the memory manager's queued asynchronous spill
//! writes), so by the time a worker dequeues a task its remote inputs are
//! usually resident and spill file I/O never blocks a kernel.
//!
//! Protocol with [`crate::exec::RealExecutor`]:
//!
//! * a task whose unmet-dependency count drops to ≤ 1 has its inputs
//!   posted to its target node's queue (the plan's `Transfer::src` is the
//!   locate hint); duplicates are deduped per `(node, object)`;
//! * a *stolen* task re-routes: the thief posts the stolen task's inputs
//!   to its own queue, so batched steals warm up behind the first task;
//! * workers never wait on a prefetch — a miss simply falls back to the
//!   demand pull they always did, and the racing double-pull is resolved
//!   (and accounted once) under the destination store lock;
//! * a pull for an object that is not yet available (producer still
//!   running) or no longer wanted (lifetime GC released it) is dropped
//!   and un-deduped so a later warm trigger may re-request it.
//!
//! Per-node counters land in [`crate::exec::RealReport::prefetch_stats`]:
//! `prefetch_bytes` (moved by transfer threads) + `demand_pull_bytes`
//! (moved on the worker hot path) add up to exactly the node's
//! `net_in_bytes` for the run — the property suite in
//! `tests/exec_overlap.rs` asserts that identity — while `prefetch_hits`
//! counts worker input acquisitions satisfied by a completed prefetch and
//! `async_spill_bytes` counts spill-file bytes written off the hot path.

use std::collections::{HashSet, VecDeque};
use std::sync::{Condvar, Mutex};

use crate::store::{MemoryManager, ObjectId, StoreSet};

/// Per-node communication-overlap counters for one run.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct PrefetchStats {
    /// Bytes pulled to this node by its transfer thread (background).
    pub prefetch_bytes: u64,
    /// Worker input acquisitions that found the object resident thanks
    /// to a completed prefetch (no bytes paid on the hot path).
    pub prefetch_hits: u64,
    /// Bytes pulled to this node on the worker hot path (prefetch miss,
    /// stolen-task pulls, or prefetch disabled paths).
    pub demand_pull_bytes: u64,
    /// Spill-file bytes written by this node's transfer thread (the
    /// memory manager's asynchronous spill pipeline).
    pub async_spill_bytes: u64,
}

enum Job {
    /// Move `obj` to this queue's node. `hint` is the source node the
    /// scheduler's load model committed to (`Transfer::src`), used to
    /// short-circuit the locate scan on unmanaged stores.
    Pull { obj: ObjectId, hint: Option<usize> },
    /// Complete the memory manager's queued spill writes for this node.
    SpillSweep,
}

struct QueueState {
    jobs: VecDeque<Job>,
    shutdown: bool,
}

struct NodeQueue {
    q: Mutex<QueueState>,
    cv: Condvar,
}

#[derive(Default)]
struct Track {
    /// Objects with a queued or completed pull (request dedup).
    requested: HashSet<ObjectId>,
    /// Objects whose pull completed with the object resident here.
    done: HashSet<ObjectId>,
}

/// Per-run transfer-thread coordinator: one job queue, dedup table and
/// counter block per node. The executor spawns one `serve` loop per node
/// inside its worker scope and calls [`Prefetcher::shutdown`] after the
/// workers join — `serve` drains its remaining queue (the async-spill
/// write barrier) before exiting, so by the time the scope closes every
/// queued transfer and spill write has completed.
pub struct Prefetcher {
    queues: Vec<NodeQueue>,
    track: Vec<Mutex<Track>>,
    stats: Vec<Mutex<PrefetchStats>>,
}

impl Prefetcher {
    pub fn new(num_nodes: usize) -> Self {
        Self {
            queues: (0..num_nodes)
                .map(|_| NodeQueue {
                    q: Mutex::new(QueueState {
                        jobs: VecDeque::new(),
                        shutdown: false,
                    }),
                    cv: Condvar::new(),
                })
                .collect(),
            track: (0..num_nodes).map(|_| Mutex::new(Track::default())).collect(),
            stats: (0..num_nodes)
                .map(|_| Mutex::new(PrefetchStats::default()))
                .collect(),
        }
    }

    pub fn num_nodes(&self) -> usize {
        self.queues.len()
    }

    /// Queue a background pull of `obj` to `node` (deduped; dropped after
    /// shutdown — the demand path covers whatever never got queued).
    pub fn request_pull(&self, node: usize, obj: ObjectId, hint: Option<usize>) {
        {
            let mut t = self.track[node].lock().unwrap();
            if !t.requested.insert(obj) {
                return;
            }
        }
        let nq = &self.queues[node];
        let mut q = nq.q.lock().unwrap();
        if q.shutdown {
            return;
        }
        q.jobs.push_back(Job::Pull { obj, hint });
        drop(q);
        nq.cv.notify_one();
    }

    /// Wake `node`'s transfer thread to complete queued spill writes.
    /// Always enqueued (even mid-shutdown-drain): a pending spill entry
    /// must be finalized or swept, never silently forgotten.
    pub fn notify_spill(&self, node: usize) {
        let nq = &self.queues[node];
        let mut q = nq.q.lock().unwrap();
        q.jobs.push_back(Job::SpillSweep);
        drop(q);
        nq.cv.notify_one();
    }

    /// Has a completed prefetch made `obj` resident on `node`? (Hit
    /// accounting on the worker acquire path.)
    pub fn was_prefetched(&self, node: usize, obj: ObjectId) -> bool {
        self.track[node].lock().unwrap().done.contains(&obj)
    }

    /// Worker-side counters: bytes pulled on the hot path.
    pub fn add_demand(&self, node: usize, bytes: u64) {
        self.stats[node].lock().unwrap().demand_pull_bytes += bytes;
    }

    /// Worker-side counters: an input served by a completed prefetch.
    pub fn add_hit(&self, node: usize) {
        self.stats[node].lock().unwrap().prefetch_hits += 1;
    }

    pub fn stats(&self) -> Vec<PrefetchStats> {
        self.stats.iter().map(|s| s.lock().unwrap().clone()).collect()
    }

    /// Tell every transfer thread to drain its queue and exit. Called
    /// after the worker threads join; the scope join after this call is
    /// the pipeline's write-completion barrier.
    pub fn shutdown(&self) {
        for nq in &self.queues {
            nq.q.lock().unwrap().shutdown = true;
            nq.cv.notify_all();
        }
    }

    fn mark_done(&self, node: usize, obj: ObjectId) {
        self.track[node].lock().unwrap().done.insert(obj);
    }

    fn unrequest(&self, node: usize, obj: ObjectId) {
        self.track[node].lock().unwrap().requested.remove(&obj);
    }

    /// Transfer-thread body for `node`: drains jobs until shutdown *and*
    /// an empty queue. `spillable` is the run's lifetime-pass pin oracle
    /// (what the manager may page out); `wanted` reports whether an
    /// object still has pending consumers (a pull of a GC-released
    /// intermediate would resurrect dead bytes, so it is dropped).
    pub fn serve(
        &self,
        node: usize,
        stores: &StoreSet,
        memory: Option<&MemoryManager>,
        spillable: &(dyn Fn(ObjectId) -> bool + Sync),
        wanted: &(dyn Fn(ObjectId) -> bool + Sync),
    ) {
        loop {
            let job = {
                let nq = &self.queues[node];
                let mut q = nq.q.lock().unwrap();
                loop {
                    if let Some(j) = q.jobs.pop_front() {
                        // the drain barrier exists for spill writes; a
                        // pull whose consumers have all exited (shutdown
                        // = workers joined) would move bytes nobody
                        // reads — discard it
                        if q.shutdown && matches!(j, Job::Pull { .. }) {
                            continue;
                        }
                        break Some(j);
                    }
                    if q.shutdown {
                        break None;
                    }
                    q = nq.cv.wait(q).unwrap();
                }
            };
            let Some(job) = job else { return };
            match job {
                Job::Pull { obj, hint } => {
                    self.pull(node, obj, hint, stores, memory, spillable, wanted)
                }
                Job::SpillSweep => {
                    if let Some(m) = memory {
                        let written = m.process_pending_spills(stores, node);
                        if written > 0 {
                            self.stats[node].lock().unwrap().async_spill_bytes += written;
                        }
                    }
                }
            }
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn pull(
        &self,
        node: usize,
        obj: ObjectId,
        hint: Option<usize>,
        stores: &StoreSet,
        memory: Option<&MemoryManager>,
        spillable: &(dyn Fn(ObjectId) -> bool + Sync),
        wanted: &(dyn Fn(ObjectId) -> bool + Sync),
    ) {
        if stores.contains(node, obj) {
            // already local (placement, a demand pull, or an earlier pull
            // that marked itself): nothing moved, so deliberately NOT
            // marked done — prefetch_hits must only credit acquisitions
            // this thread actually made resident
            return;
        }
        if !wanted(obj) {
            // released mid-queue: pulling would resurrect dead bytes
            self.unrequest(node, obj);
            return;
        }
        let (landed, bytes) = match memory {
            Some(m) => {
                let (b, n) = m.acquire(stores, node, obj, spillable);
                (b.is_some(), n)
            }
            None => match stores
                .locate(obj, hint.unwrap_or(node))
                .and_then(|src| stores.try_transfer(src, node, obj))
            {
                Some(n) => (true, n),
                None => (false, 0),
            },
        };
        if bytes > 0 {
            // counted even when the pull then lost its copy to eviction:
            // the traffic happened, and the per-node byte identity
            // (prefetch + demand == net_in) must see it
            self.stats[node].lock().unwrap().prefetch_bytes += bytes;
        }
        if landed {
            self.mark_done(node, obj);
        } else {
            // producer not finished yet, or the object is gone: let a
            // later warm trigger (or the demand path) handle it
            self.unrequest(node, obj);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::Block;
    use std::sync::Arc;

    fn yes(_: ObjectId) -> bool {
        true
    }

    /// Bounded poll (≤ 5s) so a lost wakeup fails loudly, never hangs CI.
    fn wait_for(cond: impl Fn() -> bool, what: &str) {
        for _ in 0..50_000 {
            if cond() {
                return;
            }
            std::thread::sleep(std::time::Duration::from_micros(100));
        }
        panic!("timed out waiting for {what}");
    }

    #[test]
    fn pull_moves_remote_object_and_counts_bytes() {
        let stores = StoreSet::new(2);
        stores.put(0, 7, Arc::new(Block::filled(&[4, 4], 2.0)));
        let pf = Prefetcher::new(2);
        std::thread::scope(|s| {
            s.spawn(|| pf.serve(1, &stores, None, &yes, &yes));
            pf.request_pull(1, 7, Some(0));
            wait_for(|| stores.contains(1, 7), "prefetch of object 7");
            // duplicate request: deduped away, no second transfer
            pf.request_pull(1, 7, None);
            // shutdown drains whatever is still queued before serve exits
            pf.shutdown();
        });
        assert!(pf.was_prefetched(1, 7));
        assert_eq!(pf.stats()[1].prefetch_bytes, 128);
        assert_eq!(stores.snapshot()[1].2, 128, "exactly one transfer");
    }

    #[test]
    fn unavailable_pull_is_dropped_and_rerequestable() {
        let stores = StoreSet::new(2);
        stores.put(0, 50, Arc::new(Block::filled(&[2, 2], 5.0)));
        let pf = Prefetcher::new(2);
        std::thread::scope(|s| {
            s.spawn(|| pf.serve(1, &stores, None, &yes, &yes));
            pf.request_pull(1, 42, None); // exists nowhere yet
            pf.request_pull(1, 50, Some(0)); // FIFO marker behind it
            wait_for(|| stores.contains(1, 50), "marker pull");
            // 42 was processed (FIFO) and dropped, not completed
            assert!(!pf.was_prefetched(1, 42));
            assert_eq!(pf.stats()[1].prefetch_bytes, 32);
            // the drop un-deduped it: once the object exists, a
            // re-request goes through instead of being swallowed
            stores.put(0, 42, Arc::new(Block::filled(&[2, 2], 1.0)));
            pf.request_pull(1, 42, Some(0));
            wait_for(|| stores.contains(1, 42), "re-requested pull");
            pf.shutdown();
        });
        assert!(pf.was_prefetched(1, 42));
    }

    #[test]
    fn unwanted_pull_is_skipped() {
        let stores = StoreSet::new(2);
        stores.put(0, 9, Arc::new(Block::filled(&[2, 2], 3.0)));
        let pf = Prefetcher::new(2);
        fn no(_: ObjectId) -> bool {
            false
        }
        std::thread::scope(|s| {
            s.spawn(|| pf.serve(1, &stores, None, &yes, &no));
            pf.request_pull(1, 9, Some(0));
            pf.shutdown();
        });
        assert!(!stores.contains(1, 9), "dead objects must not be pulled");
    }
}
