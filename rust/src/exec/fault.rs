//! Deterministic fault injection for the real executor.
//!
//! The paper leans on Ray's lineage-based resilience for its cloud
//! claims; this module supplies the *failure half* of that story so the
//! recovery half ([`crate::exec::recovery`]) has something real to
//! survive. A [`FaultInjector`] — seeded through
//! `SessionConfig::fault_plan` or the `NUMS_FAULT_SEED` /
//! `NUMS_FAULT_RATE` environment variables — decides failures at the
//! five real failure sites of the runtime ([`FaultSite`]): kernel
//! execution, demand-pull/prefetch transfer, spill write, spill
//! readback, and whole-node loss.
//!
//! Two properties make injected chaos usable as a *correctness* tool:
//!
//! * **Determinism independent of thread interleaving.** Each decision
//!   hashes `(seed, site, key)` with the same FNV-1a used by plan
//!   signatures and compares against a rate threshold — never a shared
//!   counter, so the same plan under the same seed fails at the same
//!   sites no matter how workers interleave.
//! * **Bounded per-site failures.** Any one `(site, key)` pair injects
//!   at most [`MAX_INJECTIONS_PER_KEY`] failures, so every transient
//!   fault is survivable by bounded retry *by construction* — chaos
//!   runs must converge to the bit-identical fault-free result, not
//!   livelock.
//!
//! Default off = zero cost: when no plan is configured, no injector is
//! constructed and every site's check is an `Option` test against
//! `None`, exactly like the tracing recorder.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::graph::signature::Fnv128;

/// Where a fault can be injected — the five real failure sites.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FaultSite {
    /// Kernel execution (key = task index in plan order). Injected
    /// *before* the kernel runs, so a retried task has no partial
    /// side effects to undo.
    Kernel,
    /// A cross-node pull — demand or prefetch (key = object id).
    Transfer,
    /// Writing a spill file (key = object id).
    SpillWrite,
    /// Reading a spill file back (key = object id).
    SpillRead,
    /// Whole-node loss (keyed/configured by [`NodeLossSpec`], not rate).
    NodeLoss,
}

/// How much of a node's store a node-loss event wipes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NodeLossMode {
    /// Wipe the node's unpinned *plan-produced* blocks plus anything
    /// with another live copy; spare lifetime-pinned outputs and
    /// sole-copy external inputs (modeling data the driver can re-put).
    /// Everything lost is recomputable from lineage.
    Survivable,
    /// Wipe every unpinned block, including sole-copy inputs with no
    /// producing task — exercising the unrecoverable-loss error path.
    Total,
}

/// A scheduled whole-node loss: after `after_tasks` tasks complete,
/// node `node`'s store is wiped per `mode` and its workers stop picking
/// up new work (they finish the task in hand and exit).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct NodeLossSpec {
    pub node: usize,
    pub after_tasks: usize,
    pub mode: NodeLossMode,
}

/// The session-level fault configuration. `rate` is the per-decision
/// injection probability in `[0, 1]`; `node_loss` schedules at most one
/// whole-node loss per run.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultPlan {
    pub seed: u64,
    pub rate: f64,
    pub node_loss: Option<NodeLossSpec>,
}

impl FaultPlan {
    pub fn new(seed: u64, rate: f64) -> Self {
        Self { seed, rate, node_loss: None }
    }

    pub fn with_node_loss(mut self, node: usize, after_tasks: usize, mode: NodeLossMode) -> Self {
        self.node_loss = Some(NodeLossSpec { node, after_tasks, mode });
        self
    }

    /// Read `NUMS_FAULT_SEED` / `NUMS_FAULT_RATE` from the environment.
    /// Either variable alone is enough to arm injection (`seed` defaults
    /// to 0, `rate` to 0.05); node loss is never env-triggered — a wiped
    /// node needs test-specific survivability reasoning, so it stays an
    /// explicit `SessionConfig::fault_plan` decision.
    pub fn from_env() -> Option<Self> {
        let seed = std::env::var("NUMS_FAULT_SEED").ok().and_then(|v| v.parse::<u64>().ok());
        let rate = std::env::var("NUMS_FAULT_RATE").ok().and_then(|v| v.parse::<f64>().ok());
        if seed.is_none() && rate.is_none() {
            return None;
        }
        Some(Self {
            seed: seed.unwrap_or(0),
            rate: rate.unwrap_or(0.05).clamp(0.0, 1.0),
            node_loss: None,
        })
    }
}

/// Most injected failures any one `(site, key)` pair will see: retry
/// loops with more attempts than this are guaranteed to make progress.
pub const MAX_INJECTIONS_PER_KEY: u32 = 2;

/// The armed injector. One per run; shared by workers, the transfer
/// thread, and the memory manager via `Arc`.
pub struct FaultInjector {
    seed: u64,
    /// Threshold in hash space: a decision fires when
    /// `hash(seed, site, key) < threshold`.
    threshold: u64,
    /// Injections already delivered per (site, key) — the retry bound.
    delivered: Mutex<HashMap<(FaultSite, u64), u32>>,
    /// Injected-failure counter (all sites), for reports/tests.
    injected: AtomicUsize,
    node_loss: Option<NodeLossSpec>,
    /// Set once the scheduled node loss has fired.
    node_loss_fired: AtomicBool,
}

impl FaultInjector {
    pub fn new(plan: &FaultPlan) -> Self {
        let rate = plan.rate.clamp(0.0, 1.0);
        // map the probability onto u64 hash space; rate 1.0 saturates
        let threshold = if rate >= 1.0 {
            u64::MAX
        } else {
            (rate * u64::MAX as f64) as u64
        };
        Self {
            seed: plan.seed,
            threshold,
            delivered: Mutex::new(HashMap::new()),
            injected: AtomicUsize::new(0),
            node_loss: plan.node_loss,
            node_loss_fired: AtomicBool::new(false),
        }
    }

    fn hash(&self, site: FaultSite, key: u64) -> u64 {
        let mut h = Fnv128::new();
        h.u64(self.seed);
        h.tag(match site {
            FaultSite::Kernel => 1,
            FaultSite::Transfer => 2,
            FaultSite::SpillWrite => 3,
            FaultSite::SpillRead => 4,
            FaultSite::NodeLoss => 5,
        });
        h.u64(key);
        h.digest() as u64
    }

    /// Should this `(site, key)` decision fail *this time*? Deterministic
    /// in `(seed, site, key)` for the first [`MAX_INJECTIONS_PER_KEY`]
    /// asks; always `false` afterwards, so bounded retries always win.
    pub fn should_fail(&self, site: FaultSite, key: u64) -> bool {
        if self.threshold == 0 {
            return false;
        }
        if self.hash(site, key) >= self.threshold {
            return false;
        }
        let mut d = self.delivered.lock().unwrap();
        let n = d.entry((site, key)).or_insert(0);
        if *n >= MAX_INJECTIONS_PER_KEY {
            return false;
        }
        *n += 1;
        drop(d);
        self.injected.fetch_add(1, Ordering::Relaxed);
        true
    }

    /// Total failures injected so far (all sites).
    pub fn injected(&self) -> usize {
        self.injected.load(Ordering::Relaxed)
    }

    /// The scheduled node loss, if any.
    pub fn node_loss(&self) -> Option<NodeLossSpec> {
        self.node_loss
    }

    /// Called by the executor with the completed-task count; returns the
    /// spec exactly once, when the trigger point is reached.
    pub fn take_node_loss(&self, completed_tasks: usize) -> Option<NodeLossSpec> {
        let spec = self.node_loss?;
        if completed_tasks < spec.after_tasks {
            return None;
        }
        if self.node_loss_fired.swap(true, Ordering::SeqCst) {
            return None;
        }
        Some(spec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decisions_are_deterministic_and_interleaving_free() {
        let plan = FaultPlan::new(42, 0.5);
        let a = FaultInjector::new(&plan);
        let b = FaultInjector::new(&plan);
        // same (site, key) stream, different ask orders: identical verdicts
        let keys: Vec<u64> = (0..200).collect();
        let fwd: Vec<bool> = keys.iter().map(|&k| a.should_fail(FaultSite::Kernel, k)).collect();
        let rev: Vec<bool> = keys
            .iter()
            .rev()
            .map(|&k| b.should_fail(FaultSite::Kernel, k))
            .collect();
        let rev_fwd: Vec<bool> = rev.into_iter().rev().collect();
        assert_eq!(fwd, rev_fwd);
        assert!(fwd.iter().any(|&f| f), "rate 0.5 over 200 keys must fire");
        assert!(!fwd.iter().all(|&f| f), "rate 0.5 must not fire everywhere");
    }

    #[test]
    fn sites_hash_independently() {
        let inj = FaultInjector::new(&FaultPlan::new(7, 0.5));
        let kernel: Vec<bool> = (0..64).map(|k| inj.should_fail(FaultSite::Kernel, k)).collect();
        let spill: Vec<bool> = (0..64).map(|k| inj.should_fail(FaultSite::SpillRead, k)).collect();
        assert_ne!(kernel, spill, "site tag must decorrelate the decision streams");
    }

    #[test]
    fn per_key_injections_are_capped() {
        let inj = FaultInjector::new(&FaultPlan::new(1, 1.0));
        // rate 1.0: every key fails, but only MAX_INJECTIONS_PER_KEY times
        let mut fails = 0;
        for _ in 0..10 {
            if inj.should_fail(FaultSite::Transfer, 99) {
                fails += 1;
            }
        }
        assert_eq!(fails, MAX_INJECTIONS_PER_KEY);
        assert_eq!(inj.injected(), MAX_INJECTIONS_PER_KEY as usize);
        // a fresh key gets its own budget
        assert!(inj.should_fail(FaultSite::Transfer, 100));
    }

    #[test]
    fn rate_zero_never_fires() {
        let inj = FaultInjector::new(&FaultPlan::new(5, 0.0));
        assert!((0..1000).all(|k| !inj.should_fail(FaultSite::Kernel, k)));
        assert_eq!(inj.injected(), 0);
    }

    #[test]
    fn node_loss_fires_exactly_once_at_the_trigger() {
        let plan = FaultPlan::new(3, 0.0).with_node_loss(1, 4, NodeLossMode::Survivable);
        let inj = FaultInjector::new(&plan);
        assert!(inj.take_node_loss(0).is_none());
        assert!(inj.take_node_loss(3).is_none());
        let spec = inj.take_node_loss(4).expect("fires at the trigger point");
        assert_eq!(spec.node, 1);
        assert_eq!(spec.mode, NodeLossMode::Survivable);
        assert!(inj.take_node_loss(5).is_none(), "fires once");
    }

    #[test]
    fn env_plan_parses_and_clamps() {
        // from_env reads real process env; exercise the parse/clamp logic
        // through explicit construction instead of mutating global state.
        let p = FaultPlan { seed: 9, rate: 7.0, node_loss: None };
        let inj = FaultInjector::new(&p);
        assert!(inj.should_fail(FaultSite::Kernel, 0), "clamped rate 1.0 always fires");
    }
}
