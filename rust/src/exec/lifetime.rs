//! Plan lifetime analysis: per-object consumer refcounts and pinning.
//!
//! Ray and Dask free task outputs by distributed reference counting; the
//! sim executor models that (`sim_exec.rs` releases plan-local
//! temporaries after their last use). This pass gives the real executor
//! the same information ahead of time: one walk over the [`Plan`] counts,
//! for every `ObjectId`, how many task inputs consume it (with
//! multiplicity — a task reading the same block twice holds two
//! references), records which objects the plan itself produces, and pins
//! the objects that must survive the run:
//!
//! * explicit pins — the scheduled graph's output blocks, passed in by
//!   the session (`RealExecutor::run_pinned`);
//! * implicit pins — produced objects no task in the plan consumes
//!   (terminal results a direct executor caller will read).
//!
//! During execution the completion path decrements the counts; when an
//! *evictable* object (produced here, not pinned) hits zero the executor
//! releases it everywhere via the memory manager, so per-node
//! `peak_bytes` reflects the schedule's true working set. Objects the
//! plan did not produce (session arrays from earlier runs) are never
//! refcount-released — but they are *spillable* under a byte budget,
//! exactly like Ray's object store pages out cold primaries.

use std::collections::{HashMap, HashSet};

use crate::store::ObjectId;

use super::task::Plan;

/// Immutable result of the pre-execution lifetime pass.
#[derive(Clone, Debug, Default)]
pub struct Lifetimes {
    /// obj -> number of consuming task inputs in the plan (multiplicity).
    consumers: HashMap<ObjectId, usize>,
    /// Objects some task in the plan produces.
    produced: HashSet<ObjectId>,
    /// Objects that must survive the run (graph outputs + terminals).
    pinned: HashSet<ObjectId>,
}

impl Lifetimes {
    /// Analyze `plan`, pinning `pins` (the scheduled graph's outputs) in
    /// addition to the implicit terminal pins.
    pub fn analyze(plan: &Plan, pins: &[ObjectId]) -> Self {
        let mut consumers: HashMap<ObjectId, usize> = HashMap::new();
        let mut produced: HashSet<ObjectId> = HashSet::new();
        for t in &plan.tasks {
            for &o in &t.inputs {
                *consumers.entry(o).or_insert(0) += 1;
            }
            for (o, _) in &t.outputs {
                produced.insert(*o);
            }
        }
        let mut pinned: HashSet<ObjectId> = pins.iter().copied().collect();
        // an output nothing in-plan consumes is a terminal result: a
        // refcount of zero must read "kept", never "dead on arrival"
        for &o in &produced {
            if !consumers.contains_key(&o) {
                pinned.insert(o);
            }
        }
        Self {
            consumers,
            produced,
            pinned,
        }
    }

    /// May this object be refcount-released once its count hits zero?
    /// Only plan-produced, unpinned intermediates qualify; external
    /// session arrays are owned by the driver, not this run.
    pub fn evictable(&self, id: ObjectId) -> bool {
        self.produced.contains(&id) && !self.pinned.contains(&id)
    }

    /// May this object be paged out to disk under memory pressure?
    /// Everything except pinned run outputs (which the driver reads right
    /// after the run — keeping them resident keeps gathers off the disk).
    pub fn spillable(&self, id: ObjectId) -> bool {
        !self.pinned.contains(&id)
    }

    pub fn is_pinned(&self, id: ObjectId) -> bool {
        self.pinned.contains(&id)
    }

    /// Remaining-consumer count the executor should start from.
    pub fn refcount(&self, id: ObjectId) -> usize {
        self.consumers.get(&id).copied().unwrap_or(0)
    }

    /// Initial live-count table for the executor's completion path:
    /// evictable objects only (nothing else is ever released).
    pub fn live_counts(&self) -> HashMap<ObjectId, usize> {
        self.consumers
            .iter()
            .filter(|(&o, _)| self.evictable(o))
            .map(|(&o, &c)| (o, c))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::task::Task;
    use crate::runtime::kernel::{BinOp, Kernel};

    fn task(inputs: Vec<ObjectId>, out: ObjectId) -> Task {
        Task {
            kernel: Kernel::Ew(BinOp::Add),
            in_shapes: vec![vec![2, 2]; inputs.len()],
            inputs,
            outputs: vec![(out, vec![2, 2])],
            target: 0,
            transfers: vec![],
        }
    }

    #[test]
    fn refcounts_count_multiplicity_and_pins_protect() {
        // 1,2 external; 10 = 1+2; 11 = 10+10 (double ref); 12 = 11+2
        let plan = Plan {
            tasks: vec![
                task(vec![1, 2], 10),
                task(vec![10, 10], 11),
                task(vec![11, 2], 12),
            ],
        };
        let lt = Lifetimes::analyze(&plan, &[12]);
        assert_eq!(lt.refcount(10), 2, "same-task double read = two refs");
        assert_eq!(lt.refcount(11), 1);
        assert_eq!(lt.refcount(2), 2);
        // externals are spillable but never evictable
        assert!(!lt.evictable(1) && !lt.evictable(2));
        assert!(lt.spillable(1));
        // intermediates are both
        assert!(lt.evictable(10) && lt.evictable(11));
        // the pinned output is neither evictable nor spillable
        assert!(lt.is_pinned(12));
        assert!(!lt.evictable(12) && !lt.spillable(12));
        // live table carries only the evictable intermediates
        let live = lt.live_counts();
        assert_eq!(live.len(), 2);
        assert_eq!(live[&10], 2);
    }

    #[test]
    fn unconsumed_outputs_are_implicitly_pinned() {
        let plan = Plan {
            tasks: vec![task(vec![1, 2], 10), task(vec![1, 2], 11)],
        };
        let lt = Lifetimes::analyze(&plan, &[]);
        assert!(lt.is_pinned(10) && lt.is_pinned(11));
        assert!(!lt.evictable(10));
        assert!(lt.live_counts().is_empty());
    }
}
