//! Logical block partitioning of dense n-dimensional arrays (§4).
//!
//! An [`ArrayGrid`] is the paper's *array grid*: `shape` gives the global
//! dimensions and `grid` the number of blocks along each axis. Block `b`
//! along an axis of extent `s` split into `g` blocks has extent
//! `ceil(s/g)` for the first `s % g` blocks when the split is uneven
//! (NumS uses near-even splits; our tests pin the exact rule).

use std::fmt;

/// Multi-dimensional block coordinates.
pub type Coords = Vec<usize>;

#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct ArrayGrid {
    /// Global array dimensions.
    pub shape: Vec<usize>,
    /// Blocks along each axis (same rank as `shape`).
    pub grid: Vec<usize>,
}

impl ArrayGrid {
    pub fn new(shape: &[usize], grid: &[usize]) -> Self {
        assert_eq!(
            shape.len(),
            grid.len(),
            "shape rank {} != grid rank {}",
            shape.len(),
            grid.len()
        );
        for (axis, (&s, &g)) in shape.iter().zip(grid).enumerate() {
            assert!(g >= 1, "axis {axis}: grid must be >= 1");
            assert!(
                g <= s.max(1),
                "axis {axis}: more blocks ({g}) than elements ({s})"
            );
        }
        Self {
            shape: shape.to_vec(),
            grid: grid.to_vec(),
        }
    }

    /// Rank of the array.
    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    /// Total number of blocks.
    pub fn num_blocks(&self) -> usize {
        self.grid.iter().product()
    }

    /// Total number of elements.
    pub fn num_elems(&self) -> u64 {
        self.shape.iter().map(|&s| s as u64).product()
    }

    /// Extent of block `b` along `axis`: near-even split where the first
    /// `shape % grid` blocks get one extra element.
    pub fn block_extent(&self, axis: usize, b: usize) -> usize {
        let s = self.shape[axis];
        let g = self.grid[axis];
        assert!(b < g, "block {b} out of range on axis {axis} (grid {g})");
        let base = s / g;
        let rem = s % g;
        if b < rem {
            base + 1
        } else {
            base
        }
    }

    /// Offset of block `b` along `axis` in global element coordinates.
    pub fn block_offset(&self, axis: usize, b: usize) -> usize {
        let s = self.shape[axis];
        let g = self.grid[axis];
        let base = s / g;
        let rem = s % g;
        if b < rem {
            (base + 1) * b
        } else {
            base * b + rem
        }
    }

    /// Shape of the block at `coords`.
    pub fn block_shape(&self, coords: &[usize]) -> Vec<usize> {
        assert_eq!(coords.len(), self.ndim());
        coords
            .iter()
            .enumerate()
            .map(|(axis, &b)| self.block_extent(axis, b))
            .collect()
    }

    /// Element count of the block at `coords`.
    pub fn block_elems(&self, coords: &[usize]) -> u64 {
        self.block_shape(coords).iter().map(|&s| s as u64).product()
    }

    /// Convert a flat block index (row-major over the grid) to coordinates.
    pub fn coords_of(&self, mut flat: usize) -> Coords {
        assert!(flat < self.num_blocks());
        let mut coords = vec![0; self.ndim()];
        for axis in (0..self.ndim()).rev() {
            coords[axis] = flat % self.grid[axis];
            flat /= self.grid[axis];
        }
        coords
    }

    /// Convert block coordinates to a flat row-major index.
    pub fn flat_of(&self, coords: &[usize]) -> usize {
        assert_eq!(coords.len(), self.ndim());
        let mut flat = 0;
        for (axis, &c) in coords.iter().enumerate() {
            assert!(c < self.grid[axis], "coord {c} out of grid on axis {axis}");
            flat = flat * self.grid[axis] + c;
        }
        flat
    }

    /// Iterate all block coordinates in row-major order.
    pub fn iter_coords(&self) -> impl Iterator<Item = Coords> + '_ {
        (0..self.num_blocks()).map(|f| self.coords_of(f))
    }

    /// Grid for the result of reducing along `axis` (the axis collapses to
    /// a single block of extent 1, matching the kernels' keepdims outputs).
    pub fn reduce_axis(&self, axis: usize) -> ArrayGrid {
        assert!(axis < self.ndim());
        let mut shape = self.shape.clone();
        let mut grid = self.grid.clone();
        shape[axis] = 1;
        grid[axis] = 1;
        ArrayGrid::new(&shape, &grid)
    }

    /// Whether this grid evenly divides the array (no remainder blocks).
    pub fn is_even(&self) -> bool {
        self.shape
            .iter()
            .zip(&self.grid)
            .all(|(&s, &g)| s % g == 0)
    }
}

impl fmt::Display for ArrayGrid {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "ArrayGrid(shape={:?}, grid={:?})", self.shape, self.grid)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_example_256_4x4() {
        // §4: A = random((256,256),(4,4)) -> 16 blocks of 64x64.
        let g = ArrayGrid::new(&[256, 256], &[4, 4]);
        assert_eq!(g.num_blocks(), 16);
        for c in g.iter_coords() {
            assert_eq!(g.block_shape(&c), vec![64, 64]);
        }
    }

    #[test]
    fn uneven_split_first_blocks_bigger() {
        let g = ArrayGrid::new(&[10], &[3]);
        assert_eq!(
            (0..3).map(|b| g.block_extent(0, b)).collect::<Vec<_>>(),
            vec![4, 3, 3]
        );
        assert_eq!(
            (0..3).map(|b| g.block_offset(0, b)).collect::<Vec<_>>(),
            vec![0, 4, 7]
        );
    }

    #[test]
    fn extents_tile_exactly() {
        for (s, g) in [(17, 4), (100, 7), (64, 64), (5, 1)] {
            let a = ArrayGrid::new(&[s], &[g]);
            let total: usize = (0..g).map(|b| a.block_extent(0, b)).sum();
            assert_eq!(total, s);
            // offsets are cumulative extents
            let mut off = 0;
            for b in 0..g {
                assert_eq!(a.block_offset(0, b), off);
                off += a.block_extent(0, b);
            }
        }
    }

    #[test]
    fn flat_coords_roundtrip() {
        let g = ArrayGrid::new(&[30, 20, 10], &[3, 2, 5]);
        for f in 0..g.num_blocks() {
            assert_eq!(g.flat_of(&g.coords_of(f)), f);
        }
    }

    #[test]
    fn reduce_axis_grid() {
        let g = ArrayGrid::new(&[256, 128], &[4, 2]);
        let r = g.reduce_axis(0);
        assert_eq!(r.shape, vec![1, 128]);
        assert_eq!(r.grid, vec![1, 2]);
    }

    #[test]
    #[should_panic(expected = "more blocks")]
    fn rejects_overpartitioning() {
        ArrayGrid::new(&[4], &[5]);
    }
}
