//! Hierarchical data layout (§4) and automatic partitioning.
//!
//! Creation operations map logical blocks to physical placements in two
//! levels: node via the [`NodeGrid`] cyclic rule, then worker round-robin
//! within each node. Along matching axes, operands with equal shape/grid
//! co-locate block-for-block, which is what buys zero-communication
//! element-wise operations (App. A.1).
//!
//! When the user gives no grid, NumS partitions `p^{σ(shape)}` using the
//! softmax of the array's dimensions (§4): tall-skinny arrays split along
//! the tall axis, square arrays split evenly.

use super::array_grid::ArrayGrid;
use super::node_grid::NodeGrid;
use crate::util::stats::softmax;

/// A physical placement: node id plus worker index within the node.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Placement {
    pub node: usize,
    pub worker: usize,
}

/// Hierarchical layout engine for one cluster shape.
#[derive(Clone, Debug)]
pub struct Layout {
    pub node_grid: NodeGrid,
    /// Workers per node (`r` in the paper).
    pub workers_per_node: usize,
}

impl Layout {
    pub fn new(node_grid: NodeGrid, workers_per_node: usize) -> Self {
        assert!(workers_per_node >= 1);
        Self {
            node_grid,
            workers_per_node,
        }
    }

    /// Node for a block (the cyclic §4 rule).
    pub fn node_of(&self, block_coords: &[usize]) -> usize {
        self.node_grid.place(block_coords)
    }

    /// Full placement for every block of `grid`, round-robining workers
    /// within each node in row-major block order (Fig. 4a).
    pub fn place_all(&self, grid: &ArrayGrid) -> Vec<Placement> {
        let mut next_worker = vec![0usize; self.node_grid.num_nodes()];
        grid.iter_coords()
            .map(|c| {
                let node = self.node_of(&c);
                let worker = next_worker[node] % self.workers_per_node;
                next_worker[node] += 1;
                Placement { node, worker }
            })
            .collect()
    }

    /// Placement of a single block, consistent with `place_all` ordering.
    pub fn place_block(&self, grid: &ArrayGrid, coords: &[usize]) -> Placement {
        let flat = grid.flat_of(coords);
        let node = self.node_of(coords);
        // worker index = how many earlier blocks landed on the same node
        let mut earlier = 0;
        for f in 0..flat {
            if self.node_of(&grid.coords_of(f)) == node {
                earlier += 1;
            }
        }
        Placement {
            node,
            worker: earlier % self.workers_per_node,
        }
    }
}

/// Automatic partitioning `p^{σ(shape)}` (§4): factor the worker count `p`
/// into the array's rank weighted by the softmax of its dimensions, then
/// repair rounding so the block count is ≥1 per axis, ≤ the axis extent,
/// and the total ≤ p (never more blocks than workers along the softmax
/// weighting; callers can always over-partition explicitly).
pub fn softmax_grid(shape: &[usize], p: usize) -> Vec<usize> {
    assert!(!shape.is_empty());
    let p = p.max(1);
    let sm = softmax(&shape.iter().map(|&s| s as f64).collect::<Vec<_>>());
    let pf = p as f64;
    let mut grid: Vec<usize> = sm
        .iter()
        .zip(shape)
        .map(|(&w, &s)| (pf.powf(w).round() as usize).clamp(1, s.max(1)))
        .collect();
    // Repair: shrink the largest axis while the product exceeds p.
    loop {
        let prod: usize = grid.iter().product();
        if prod <= p {
            break;
        }
        let (argmax, _) = grid
            .iter()
            .enumerate()
            .max_by_key(|(_, &g)| g)
            .expect("nonempty");
        if grid[argmax] == 1 {
            break;
        }
        grid[argmax] -= 1;
    }
    grid
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_grid_square_matrix() {
        // §4 example: p=16 workers, square-ish shape -> (4,4)-ish split.
        let g = softmax_grid(&[256, 256], 16);
        assert_eq!(g, vec![4, 4]);
    }

    #[test]
    fn softmax_grid_tall_skinny() {
        // tall-skinny: all weight on the tall axis.
        let g = softmax_grid(&[31_250_000, 256], 16);
        assert_eq!(g, vec![16, 1]);
    }

    #[test]
    fn softmax_grid_paper_3d_example() {
        // §4: p=16, near-balanced first two dims of a 3-d array -> (4,4,1).
        let g = softmax_grid(&[256, 256, 4], 16);
        assert_eq!(g, vec![4, 4, 1]);
    }

    #[test]
    fn softmax_grid_never_exceeds_extent() {
        let g = softmax_grid(&[3, 1_000_000], 64);
        assert!(g[0] <= 3);
        assert!(g.iter().product::<usize>() <= 64);
    }

    #[test]
    fn place_all_round_robins_workers() {
        // Fig. 4a: 4x4 blocks on a 2x2 node grid with 4 workers/node.
        let layout = Layout::new(NodeGrid::new(&[2, 2]), 4);
        let grid = ArrayGrid::new(&[256, 256], &[4, 4]);
        let placements = layout.place_all(&grid);
        assert_eq!(placements.len(), 16);
        // each node receives exactly 4 blocks, workers 0..4 each once
        for node in 0..4 {
            let mut workers: Vec<usize> = placements
                .iter()
                .filter(|p| p.node == node)
                .map(|p| p.worker)
                .collect();
            workers.sort_unstable();
            assert_eq!(workers, vec![0, 1, 2, 3], "node {node}");
        }
        // Fig. 4 worked example: A_{2,3} -> node 1, worker 3.
        let p23 = placements[grid.flat_of(&[2, 3])];
        assert_eq!(p23.node, 1);
        assert_eq!(p23.worker, 3);
    }

    #[test]
    fn place_block_matches_place_all() {
        let layout = Layout::new(NodeGrid::new(&[2, 2]), 3);
        let grid = ArrayGrid::new(&[90, 90], &[5, 4]);
        let all = layout.place_all(&grid);
        for (f, c) in grid.iter_coords().enumerate() {
            assert_eq!(layout.place_block(&grid, &c), all[f]);
        }
    }

    #[test]
    fn equal_grids_colocate() {
        // The zero-communication invariant for element-wise ops (App. A.1).
        let layout = Layout::new(NodeGrid::new(&[4, 1]), 8);
        let g = ArrayGrid::new(&[1024, 64], &[16, 1]);
        assert_eq!(layout.place_all(&g), layout.place_all(&g));
    }
}
