//! Node grids and the hierarchical block→node mapping of §4.
//!
//! A [`NodeGrid`] is the user-defined multi-dimensional coordinate space
//! for cluster nodes (e.g. `2×2` for 4 nodes, `16×1×1` for MTTKRP). The
//! paper's placement rule for a 2-D grid `g1×g2` is
//! `ℓ = (i % g1)·g2 + j % g2`; we generalize to n dimensions by reducing
//! each block coordinate modulo the grid and flattening row-major.

#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct NodeGrid {
    pub dims: Vec<usize>,
}

impl NodeGrid {
    pub fn new(dims: &[usize]) -> Self {
        assert!(!dims.is_empty(), "node grid needs >= 1 dim");
        assert!(dims.iter().all(|&d| d >= 1));
        Self { dims: dims.to_vec() }
    }

    /// Total number of nodes.
    pub fn num_nodes(&self) -> usize {
        self.dims.iter().product()
    }

    /// Map block coordinates to a node id (the paper's cyclic rule).
    /// Block coordinate ranks above the grid rank are folded into the last
    /// grid axis; missing trailing coordinates are treated as 0 — this lets
    /// one node grid serve operand arrays of different rank (e.g. X (q×1)
    /// and β (1×1) on an r×1 grid, §6).
    pub fn place(&self, block_coords: &[usize]) -> usize {
        let g = self.dims.len();
        let mut node = 0;
        for (axis, &dim) in self.dims.iter().enumerate() {
            let mut c = block_coords.get(axis).copied().unwrap_or(0);
            if axis == g - 1 {
                // fold any extra block-coordinate rank into the last axis
                for (extra_axis, &extra) in block_coords.iter().enumerate().skip(g) {
                    let _ = extra_axis;
                    c = c.wrapping_add(extra);
                }
            }
            node = node * dim + (c % dim);
        }
        node
    }

    /// Node-grid coordinates of a node id (row-major inverse).
    pub fn coords_of(&self, mut node: usize) -> Vec<usize> {
        assert!(node < self.num_nodes());
        let mut out = vec![0; self.dims.len()];
        for axis in (0..self.dims.len()).rev() {
            out[axis] = node % self.dims[axis];
            node /= self.dims[axis];
        }
        out
    }

    /// A 1-D grid over `k` nodes (the default when the user gives none).
    pub fn linear(k: usize) -> Self {
        Self::new(&[k])
    }

    /// Near-square 2-D factoring of `k` (used by DGEMM benches).
    pub fn square_ish(k: usize) -> Self {
        let mut a = (k as f64).sqrt() as usize;
        while a > 1 && k % a != 0 {
            a -= 1;
        }
        Self::new(&[a.max(1), k / a.max(1)])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_formula_2x2() {
        // §4: for grid g1×g2, A_{i,j} goes to node (i%g1)*g2 + j%g2.
        let g = NodeGrid::new(&[2, 2]);
        let expect = |i: usize, j: usize| (i % 2) * 2 + (j % 2);
        for i in 0..4 {
            for j in 0..4 {
                assert_eq!(g.place(&[i, j]), expect(i, j), "({i},{j})");
            }
        }
        // Fig. 4: A_{2,3} -> node 1 (coords (0,1)).
        assert_eq!(g.place(&[2, 3]), 1);
    }

    #[test]
    fn rank_mismatch_tolerated() {
        let g = NodeGrid::new(&[4, 1]);
        // 1-D block coords on a 2-D grid: trailing treated as 0.
        assert_eq!(g.place(&[3]), 3 * 1);
        // 3-D block coords on a 2-D grid: extra rank folds into last axis.
        let g2 = NodeGrid::new(&[2, 2]);
        assert!(g2.place(&[1, 1, 5]) < 4);
    }

    #[test]
    fn square_ish_factors() {
        assert_eq!(NodeGrid::square_ish(16).dims, vec![4, 4]);
        assert_eq!(NodeGrid::square_ish(8).dims, vec![2, 4]);
        assert_eq!(NodeGrid::square_ish(1).dims, vec![1, 1]);
        assert_eq!(NodeGrid::square_ish(7).dims, vec![1, 7]);
    }

    #[test]
    fn coords_roundtrip() {
        let g = NodeGrid::new(&[2, 3, 4]);
        for n in 0..g.num_nodes() {
            let c = g.coords_of(n);
            assert_eq!(g.place(&c), n);
        }
    }

    #[test]
    fn balanced_over_nodes_when_grid_divides() {
        // 4x4 blocks over 2x2 nodes: each node holds exactly 4 blocks.
        let g = NodeGrid::new(&[2, 2]);
        let mut counts = [0usize; 4];
        for i in 0..4 {
            for j in 0..4 {
                counts[g.place(&[i, j])] += 1;
            }
        }
        assert_eq!(counts, [4, 4, 4, 4]);
    }
}
