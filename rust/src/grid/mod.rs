//! Array grids, node grids, and the hierarchical data layout (§4).

pub mod array_grid;
pub mod layout;
pub mod node_grid;

pub use array_grid::{ArrayGrid, Coords};
pub use layout::{softmax_grid, Layout, Placement};
pub use node_grid::NodeGrid;
