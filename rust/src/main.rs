//! `nums` CLI — the leader entrypoint.
//!
//! Subcommands:
//!   info                       show artifacts manifest + cluster presets
//!   validate                   cross-check PJRT artifacts vs the native oracle
//!   logreg  [--n --d --q ...]  run distributed Newton logistic regression
//!                              (--transport inproc|shm|tcp selects the block
//!                              carrier; tcp launches `nums node` peers)
//!   dgemm   [--n --nodes]      NumS recursive matmul vs SUMMA (modeled)
//!   node    [--idx N]          TCP-transport block daemon: binds loopback,
//!                              prints `NUMS-NODE-READY <addr>`, serves
//!                              checksummed block frames until Quit
//!   bench --list               list figure benches (run via `cargo bench`)

use anyhow::{anyhow, Result};
use nums::prelude::*;
use nums::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let cmd = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("info");
    match cmd {
        "info" => info(&args),
        "validate" => validate(&args),
        "logreg" => logreg(&args),
        "dgemm" => dgemm(&args),
        "node" => node(&args),
        "bench" => {
            println!("figure benches run via `cargo bench`:");
            for b in [
                "fig08_overheads",
                "fig09_micro",
                "tab02_blocksize",
                "fig10_dgemm",
                "fig11_tsqr",
                "fig12_scaling",
                "fig13_tensor",
                "fig14_logreg",
                "fig15_ablation",
                "tab03_datasci",
                "fig16_fraction",
                "net_transport",
            ] {
                println!("  cargo bench --bench {b}");
            }
            Ok(())
        }
        other => {
            eprintln!("unknown subcommand {other:?}; try: info|validate|logreg|dgemm|node|bench");
            std::process::exit(2);
        }
    }
}

fn info(_args: &Args) -> Result<()> {
    let dir = nums::runtime::Manifest::default_dir();
    match nums::runtime::Manifest::load(&dir) {
        Ok(m) => {
            println!("artifacts: {} entries in {:?}", m.len(), dir);
            let mut names: Vec<String> = m
                .entries()
                .map(|e| format!("{} {:?}", e.name, e.dims))
                .collect();
            names.sort();
            for n in names {
                println!("  {n}");
            }
        }
        Err(e) => println!("no artifacts manifest ({e}); run `make artifacts`"),
    }
    Ok(())
}

/// Execute every PJRT-supported artifact against the native oracle.
fn validate(args: &Args) -> Result<()> {
    let dir = nums::runtime::Manifest::default_dir();
    let backend = Backend::pjrt(&dir)?;
    let manifest = nums::runtime::Manifest::load(&dir)?;
    let mut rng = Rng::seed_from_u64(args.u64_or("seed", 7));
    let mut checked = 0;
    let mut worst: f64 = 0.0;
    for entry in manifest.entries() {
        let kernel = match kernel_for(&entry.name) {
            Some(k) => k,
            None => continue,
        };
        let inputs: Vec<Block> = entry
            .input_shapes
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let n: usize = s.iter().product();
                let mut v = vec![0.0; n];
                rng.fill_normal(&mut v);
                // keep GLM probability inputs in (0,1)
                if entry.name == "logloss" && i == 0 {
                    for x in v.iter_mut() {
                        *x = 1.0 / (1.0 + (-*x).exp());
                    }
                }
                if (entry.name == "logloss" && i == 1)
                    || ((entry.name == "newton_block" || entry.name == "lbfgs_block") && i == 1)
                    || (entry.name == "glm_grad" && i == 2)
                {
                    for x in v.iter_mut() {
                        *x = if *x > 0.0 { 1.0 } else { 0.0 };
                    }
                }
                if entry.name == "glm_grad" && i == 1 || entry.name == "glm_hess" && i == 1 {
                    for x in v.iter_mut() {
                        *x = 1.0 / (1.0 + (-*x).exp());
                    }
                }
                Block::from_vec(s, v)
            })
            .collect();
        let refs: Vec<&Block> = inputs.iter().collect();
        let got = backend.execute(&kernel, &refs, &nums::runtime::ExecContext::host_default())?;
        let want = nums::runtime::native::execute(&kernel, &refs)?;
        for (gb, wb) in got.iter().zip(&want) {
            let d = nums::util::stats::max_rel_diff(gb.buf(), wb.buf());
            worst = worst.max(d);
            assert!(
                d < 1e-8,
                "{} {:?}: pjrt vs native rel diff {d}",
                entry.name,
                entry.dims
            );
        }
        checked += 1;
    }
    let (hits, _) = backend.counters();
    println!("validated {checked} artifacts via PJRT ({hits} executions), worst rel diff {worst:.3e}");
    Ok(())
}

fn kernel_for(name: &str) -> Option<Kernel> {
    Some(match name {
        "neg" => Kernel::Neg,
        "sigmoid" => Kernel::Sigmoid,
        "add" => Kernel::Ew(BinOp::Add),
        "sub" => Kernel::Ew(BinOp::Sub),
        "mul" => Kernel::Ew(BinOp::Mul),
        "div" => Kernel::Ew(BinOp::Div),
        "matmul" => Kernel::Matmul,
        "matmul_nt" => Kernel::MatmulNT,
        "gram" => Kernel::Gram,
        "sum_axis0" => Kernel::SumAxis0,
        "sum_axis1" => Kernel::SumAxis1,
        "sum_all" => Kernel::SumAll,
        "glm_mu" => Kernel::GlmMu,
        "glm_grad" => Kernel::GlmGrad,
        "glm_hess" => Kernel::GlmHess,
        "logloss" => Kernel::LogLoss,
        "newton_block" => Kernel::NewtonBlock,
        "lbfgs_block" => Kernel::LbfgsBlock,
        "predict_block" => Kernel::PredictBlock,
        _ => return None,
    })
}

fn logreg(args: &Args) -> Result<()> {
    let n = args.usize_or("n", 1 << 15);
    let d = args.usize_or("d", 32);
    let q = args.usize_or("q", 8);
    let nodes = args.usize_or("nodes", 4);
    let wpn = args.usize_or("workers", 4);
    let steps = args.usize_or("steps", 8);
    let policy = nums::api::Policy::parse(args.str_or("policy", "lshs"))?;
    let mut cfg = SessionConfig::real_small(nodes, wpn).with_policy(policy);
    // explicit flag wins; otherwise real_small already honored
    // NUMS_TRANSPORT from the environment
    let t = args.str_or("transport", "");
    if !t.is_empty() {
        cfg = cfg.with_transport(
            TransportKind::parse(t).ok_or_else(|| anyhow!("--transport {t:?}: expected inproc|shm|tcp"))?,
        );
    }
    println!("transport={}", cfg.transport.name());
    let mut sess = Session::new(cfg);
    let (x, y) = nums::glm::classification_data(&mut sess, n, d, q, args.u64_or("seed", 1));
    let res = nums::glm::newton_fit(&mut sess, &x, &y, steps, 1e-8)?;
    println!("policy={} iters={} losses={:?}", sess.policy_name(), res.iters, res.losses);
    let acc = nums::glm::accuracy(&mut sess, &x, &y, &res.beta)?;
    println!(
        "accuracy={acc:.4} sim_secs={:.3} transfer_bytes={}",
        res.sim_secs(),
        res.transfer_bytes()
    );
    Ok(())
}

/// TCP-transport block daemon (one per simulated node, its own OS
/// process). Binds an ephemeral loopback port, prints the rendezvous
/// line the launcher ([`nums::net::TcpTransport::launch`]) parses, and
/// serves checksummed block frames until an orderly `Quit` — or until
/// the chaos suite kills the process, which is the point.
fn node(args: &Args) -> Result<()> {
    let _idx = args.usize_or("idx", 0); // diagnostics only
    let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    println!("{}{addr}", nums::net::READY_PREFIX);
    use std::io::Write;
    std::io::stdout().flush()?;
    nums::net::serve_node(listener)?;
    Ok(())
}

fn dgemm(args: &Args) -> Result<()> {
    let nodes = args.usize_or("nodes", 16);
    let n = args.usize_or("n", 16384);
    let wpn = args.usize_or("workers", 32);
    // SUMMA (SLATE stand-in)
    let summa = nums::summa::Summa::new(nodes, n).run(
        NetParams::mpi_testbed(),
        ComputeParams::mpi_testbed(),
        wpn,
    );
    println!(
        "SUMMA       n={n} nodes={nodes}: modeled {:.3}s ({} tasks)",
        summa.report.makespan, summa.tasks
    );
    // NumS recursive matmul via LSHS (simulated)
    let side = (nodes as f64).sqrt() as usize;
    let cfg = SessionConfig::paper_sim(nodes, wpn)
        .with_node_grid(NodeGrid::new(&[side, nodes / side]));
    let mut sess = Session::new(cfg);
    let g = side * 2;
    let a = sess.zeros(&[n, n], &[g, g]);
    let b = sess.zeros(&[n, n], &[g, g]);
    let mut graph = Graph::new();
    build::matmul(&mut graph, &a, &b);
    let (_, rep) = sess.run(&mut graph)?;
    println!(
        "NumS (LSHS) n={n} nodes={nodes}: modeled {:.3}s ({} tasks, {} transfers)",
        rep.sim.makespan, rep.tasks, rep.transfers
    );
    Ok(())
}
