//! Benchmark harness (criterion is unavailable offline) and the workload
//! drivers that regenerate every table and figure of §8.

pub mod harness;

pub use harness::{emit_json, timing_breakdown, Bench, Measurement, PerfRecord, TimingBreakdown};
