//! Tiny timing harness used by `rust/benches/*` (`harness = false`).
//!
//! Follows the paper's measurement protocol (§8): repeat each trial,
//! drop the best and worst, report the trimmed mean. `NUMS_BENCH_FAST=1`
//! shrinks repetitions for CI-style smoke runs.

use crate::exec::{Plan, RealReport, Task};
use crate::runtime::kernel::{BinOp, Kernel};
use crate::store::ObjectId;
use crate::util::fmt::{human_secs, render_table};
use crate::util::stats::Summary;
use crate::util::Stopwatch;

#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub samples: Vec<f64>,
}

impl Measurement {
    pub fn paper_mean(&self) -> f64 {
        Summary::paper_mean(&self.samples)
    }

    pub fn summary(&self) -> Summary {
        Summary::from_samples(&self.samples)
    }
}

pub struct Bench {
    pub title: String,
    pub trials: usize,
    pub measurements: Vec<Measurement>,
}

impl Bench {
    pub fn new(title: &str) -> Self {
        let fast = std::env::var("NUMS_BENCH_FAST").ok().as_deref() == Some("1");
        Self {
            title: title.to_string(),
            trials: if fast { 3 } else { 7 },
            measurements: Vec::new(),
        }
    }

    /// Time `f` for `self.trials` trials (plus one warmup).
    pub fn time(&mut self, name: &str, mut f: impl FnMut()) -> f64 {
        f(); // warmup
        let mut samples = Vec::with_capacity(self.trials);
        for _ in 0..self.trials {
            let sw = Stopwatch::start();
            f();
            samples.push(sw.secs());
        }
        let m = Measurement {
            name: name.to_string(),
            samples,
        };
        let mean = m.paper_mean();
        self.measurements.push(m);
        mean
    }

    /// Record an externally-computed value (modeled seconds, bytes, ...).
    pub fn record(&mut self, name: &str, value: f64) {
        self.measurements.push(Measurement {
            name: name.to_string(),
            samples: vec![value],
        });
    }

    /// Render all measurements as a table.
    pub fn report(&self) -> String {
        let rows: Vec<Vec<String>> = self
            .measurements
            .iter()
            .map(|m| {
                let s = m.summary();
                vec![
                    m.name.clone(),
                    human_secs(m.paper_mean()),
                    human_secs(s.min),
                    human_secs(s.max),
                    format!("{}", s.n),
                ]
            })
            .collect();
        format!(
            "## {}\n{}",
            self.title,
            render_table(&["case", "mean(trim)", "min", "max", "n"], &rows)
        )
    }
}

/// One machine-readable perf datapoint for cross-PR trajectory tracking.
#[derive(Clone, Debug, PartialEq)]
pub struct PerfRecord {
    /// Operation label, e.g. `matmul_blocked_1024`.
    pub op: String,
    /// Bytes the operation touches (inputs + outputs).
    pub bytes: u64,
    /// Wall (or modeled) seconds.
    pub secs: f64,
    /// Achieved GFLOP/s (0 for bandwidth-bound ops).
    pub gflops: f64,
}

/// Write records as a JSON array (hand-rolled: no serde offline). Benches
/// emit `BENCH_<fig>.json` next to the working directory so future PRs can
/// diff perf against this one.
pub fn emit_json(path: &str, records: &[PerfRecord]) -> std::io::Result<()> {
    let mut s = String::from("[\n");
    for (i, r) in records.iter().enumerate() {
        s.push_str(&format!(
            "  {{\"op\": \"{}\", \"bytes\": {}, \"secs\": {:.9}, \"gflops\": {:.6}}}{}\n",
            r.op.replace('"', "'"),
            r.bytes,
            r.secs,
            r.gflops,
            if i + 1 < records.len() { "," } else { "" }
        ));
    }
    s.push(']');
    s.push('\n');
    std::fs::write(path, s)
}

/// One-line per-node load-balance summary of a real run:
/// `node0: 12 run (3 stolen, 1.2 KB) | node1: ...` — what the fig09
/// stealing ablation prints next to wall time.
pub fn steal_summary(report: &RealReport) -> String {
    report
        .node_stats
        .iter()
        .enumerate()
        .map(|(n, s)| {
            format!(
                "node{n}: {} run ({} stolen, {} B)",
                s.tasks_run, s.tasks_stolen, s.steal_bytes
            )
        })
        .collect::<Vec<_>>()
        .join(" | ")
}

/// One-line per-node memory summary of a real run:
/// `node0: peak 1.2 MB (spilled 0 B, readback 0 B, repl-evict 0 B, gc 384 KB) | ...`
/// — what the fig09/fig15 memory ablations print next to wall time.
pub fn mem_summary(report: &RealReport) -> String {
    use crate::util::fmt::human_bytes;
    report
        .store_snapshot
        .iter()
        .enumerate()
        .map(|(n, &(_, peak, _, _))| {
            let m = report.mem_stats.get(n).cloned().unwrap_or_default();
            format!(
                "node{n}: peak {} (spilled {}, readback {}, repl-evict {}, gc {})",
                human_bytes(peak as f64),
                human_bytes(m.spilled_bytes as f64),
                human_bytes(m.readback_bytes as f64),
                human_bytes(m.evicted_replica_bytes as f64),
                human_bytes(m.gc_freed_bytes as f64),
            )
        })
        .collect::<Vec<_>>()
        .join(" | ")
}

/// One-line per-node communication-overlap summary of a real run:
/// `node0: pf 1.2 MB (3 hits), demand 64 KB, async-spill 0 B | ...` —
/// what the fig09 prefetch ablation prints next to wall time.
pub fn prefetch_summary(report: &RealReport) -> String {
    use crate::util::fmt::human_bytes;
    if report.prefetch_stats.is_empty() {
        return "prefetch off".into();
    }
    report
        .prefetch_stats
        .iter()
        .enumerate()
        .map(|(n, p)| {
            format!(
                "node{n}: pf {} ({} hits), demand {}, async-spill {}",
                human_bytes(p.prefetch_bytes as f64),
                p.prefetch_hits,
                human_bytes(p.demand_pull_bytes as f64),
                human_bytes(p.async_spill_bytes as f64),
            )
        })
        .collect::<Vec<_>>()
        .join(" | ")
}

/// One-line planning-cost summary of one `Session::run`:
/// `hit=true sims=0 dec=0 sched=12.0µs (search 8.0µs) cache 3h/1m` —
/// what the fig09 plan-cache ablation and the fig14 smoke arm print per
/// iteration. `sims` is the run's candidate-placement simulation count
/// (0 on a cache hit), `sched` the full fusion+signature+search-or-rebind
/// wall time and `search` the part the cache amortizes.
pub fn planning_summary(rep: &crate::api::RunReport) -> String {
    format!(
        "hit={:<5} sims={} dec={} sched={} (search {}) cache {}h/{}m",
        rep.plan_cache_hit,
        rep.simulations,
        rep.decisions,
        human_secs(rep.schedule_secs),
        human_secs(rep.search_secs),
        rep.plan_cache_hits,
        rep.plan_cache_misses,
    )
}

/// Uniform three-way wall-time split of one `Session::run`, so every
/// bench row can report the same `{plan, exec, io}` breakdown no matter
/// which backend (sim or real) or tracing mode produced it.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TimingBreakdown {
    /// Scheduling wall time: fusion + signature + search-or-rebind
    /// (`RunReport::schedule_secs`).
    pub plan_secs: f64,
    /// The part of `plan_secs` the plan cache amortizes
    /// (`RunReport::search_secs`).
    pub search_secs: f64,
    /// Real-executor wall seconds, or the modeled makespan in sim mode.
    pub exec_secs: f64,
    /// Input-fetch seconds summed over task spans (tracing on; 0 without
    /// a trace). Fetches overlap across workers, so on wide runs this can
    /// exceed `exec_secs` — it is aggregate fetch *work*, not wall time.
    pub io_secs: f64,
    /// Cross-node input bytes observed by task spans (0 without a trace).
    pub io_bytes: u64,
    /// Whether this run replayed a cached plan.
    pub plan_cache_hit: bool,
    /// Session-cumulative plan-cache hit / miss counters.
    pub plan_cache_hits: u64,
    pub plan_cache_misses: u64,
}

impl TimingBreakdown {
    /// One-line rendering: `plan 12.00 us (search 8.00 us, miss, cache
    /// 0h/1m) | exec 3.00 ms | io 400.00 us (1.00 KiB)`.
    pub fn summary(&self) -> String {
        use crate::util::fmt::human_bytes;
        format!(
            "plan {} (search {}, {}, cache {}h/{}m) | exec {} | io {} ({})",
            human_secs(self.plan_secs),
            human_secs(self.search_secs),
            if self.plan_cache_hit { "hit" } else { "miss" },
            self.plan_cache_hits,
            self.plan_cache_misses,
            human_secs(self.exec_secs),
            human_secs(self.io_secs),
            human_bytes(self.io_bytes as f64),
        )
    }
}

/// Fold one run report into the uniform `{plan, exec, io}` breakdown.
/// `io` comes from the run trace's task spans when tracing was on; an
/// untraced run reports `io = 0` rather than guessing from NIC counters,
/// so the column always means the same thing.
pub fn timing_breakdown(rep: &crate::api::RunReport) -> TimingBreakdown {
    let exec_secs = rep.real.as_ref().map_or(rep.sim.makespan, |r| r.wall_secs);
    let (io_secs, io_bytes) = rep.trace().map_or((0.0, 0), |t| {
        (
            t.spans.iter().map(|s| s.fetch_secs()).sum(),
            t.span_fetch_bytes(),
        )
    });
    TimingBreakdown {
        plan_secs: rep.schedule_secs,
        search_secs: rep.search_secs,
        exec_secs,
        io_secs,
        io_bytes,
        plan_cache_hit: rep.plan_cache_hit,
        plan_cache_hits: rep.plan_cache_hits,
        plan_cache_misses: rep.plan_cache_misses,
    }
}

/// One-line per-node plan↔runtime feedback summary of a real run:
/// `node0: stolen 3 (1.2 KB), demand 64 KB, unplanned in 64 KB / out 0 B | ...`
/// — what the fig09 feedback ablation prints next to wall time.
pub fn feedback_summary(report: &RealReport) -> String {
    use crate::util::fmt::human_bytes;
    report
        .feedback
        .nodes
        .iter()
        .enumerate()
        .map(|(n, f)| {
            format!(
                "node{n}: stolen {} ({}), demand {}, unplanned in {} / out {}",
                f.tasks_stolen,
                human_bytes(f.steal_bytes as f64),
                human_bytes(f.demand_pull_bytes as f64),
                human_bytes(f.unplanned_in_bytes as f64),
                human_bytes(f.unplanned_out_bytes as f64),
            )
        })
        .collect::<Vec<_>>()
        .join(" | ")
}

/// Max per-node peak resident bytes of a real run (the paper's headline
/// "memory load" axis).
pub fn max_peak_bytes(report: &RealReport) -> u64 {
    report
        .store_snapshot
        .iter()
        .map(|&(_, peak, _, _)| peak)
        .max()
        .unwrap_or(0)
}

/// The canonical budget-pressure plan: `k_prod` Scale "producers" off one
/// seed block (object 1), then a binary fold of Adds that consumes every
/// producer output *late* — so under a tight `mem_budget_bytes` the cold
/// producer outputs spill to disk and are read back for the folds.
/// Returns the plan and the final fold output's object id. Shared by the
/// fig09 memory ablation and the executor's budget test so the bench
/// measures exactly the topology the test verifies.
pub fn produce_fold_plan(k_prod: usize, n: usize) -> (Plan, ObjectId) {
    assert!(k_prod >= 2);
    let shape = vec![n, n];
    let mut tasks: Vec<Task> = (0..k_prod)
        .map(|i| Task {
            kernel: Kernel::Scale((i + 1) as f64),
            inputs: vec![1],
            in_shapes: vec![shape.clone()],
            outputs: vec![(10 + i as u64, shape.clone())],
            target: 0,
            transfers: vec![],
        })
        .collect();
    let mut acc = 10u64;
    for (j, i) in (1..k_prod).enumerate() {
        let out = 100 + j as u64;
        tasks.push(Task {
            kernel: Kernel::Ew(BinOp::Add),
            inputs: vec![acc, 10 + i as u64],
            in_shapes: vec![shape.clone(), shape.clone()],
            outputs: vec![(out, shape.clone())],
            target: 0,
            transfers: vec![],
        });
        acc = out;
    }
    (Plan { tasks }, acc)
}

/// One GC-ablation GLM arm, shared by the fig09 memory ablation and the
/// fig15 real-executor section so the two figures cannot diverge: a real
/// session (stealing off for placement determinism) fits `steps` Newton
/// iterations with lifetime GC on or off. Returns wall seconds and the
/// final run's [`RealReport`] (whose `store_snapshot` carries the
/// session-cumulative per-node peaks).
pub fn glm_mem_run(
    nodes: usize,
    workers: usize,
    rows: usize,
    d: usize,
    q: usize,
    steps: usize,
    gc: bool,
) -> (f64, RealReport) {
    use crate::api::{Session, SessionConfig};
    use crate::glm::{classification_data, newton_fit};
    let cfg = SessionConfig::real_small(nodes, workers)
        .with_stealing(false)
        .with_lifetime_gc(gc);
    let mut sess = Session::new(cfg);
    let (x, y) = classification_data(&mut sess, rows, d, q, 15);
    let sw = Stopwatch::start();
    let res = newton_fit(&mut sess, &x, &y, steps, 0.0).unwrap();
    let secs = sw.secs();
    let last = res
        .reports
        .last()
        .and_then(|r| r.real.clone())
        .expect("real mode");
    (secs, last)
}

/// Print a paper-style series table: label column + one column per point.
pub fn print_series(title: &str, x_label: &str, xs: &[String], rows: &[(String, Vec<f64>)]) {
    println!("## {title}");
    let mut header = vec![x_label];
    let xrefs: Vec<&str> = xs.iter().map(|s| s.as_str()).collect();
    header.extend(xrefs);
    let table_rows: Vec<Vec<String>> = rows
        .iter()
        .map(|(name, vals)| {
            let mut r = vec![name.clone()];
            r.extend(vals.iter().map(|v| format!("{v:.4}")));
            r
        })
        .collect();
    println!("{}", render_table(&header, &table_rows));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_collects_trials() {
        let mut b = Bench::new("t");
        b.trials = 3;
        let mean = b.time("noop", || {});
        assert!(mean >= 0.0);
        assert_eq!(b.measurements[0].samples.len(), 3);
        assert!(b.report().contains("noop"));
    }

    #[test]
    fn steal_summary_formats_per_node() {
        let mut rep = RealReport::default();
        rep.node_stats = vec![
            crate::exec::NodeExecStats {
                tasks_run: 5,
                tasks_stolen: 2,
                steal_bytes: 128,
            },
            crate::exec::NodeExecStats::default(),
        ];
        let s = steal_summary(&rep);
        assert!(s.contains("node0: 5 run (2 stolen, 128 B)"), "{s}");
        assert!(s.contains("node1: 0 run"), "{s}");
    }

    #[test]
    fn mem_summary_formats_per_node() {
        let mut rep = RealReport::default();
        rep.store_snapshot = vec![(0, 2048, 0, 0), (512, 512, 0, 0)];
        rep.mem_stats = vec![
            crate::store::NodeMemStats {
                spilled_bytes: 1024,
                readback_bytes: 1024,
                evicted_replica_bytes: 0,
                gc_freed_bytes: 256,
                spill_reuse_bytes: 0,
            },
            crate::store::NodeMemStats::default(),
        ];
        let s = mem_summary(&rep);
        assert!(s.contains("node0: peak 2.00 KiB"), "{s}");
        assert!(s.contains("spilled 1.00 KiB"), "{s}");
        assert!(s.contains("node1: peak 512 B"), "{s}");
        assert_eq!(max_peak_bytes(&rep), 2048);
        // mem_stats may be absent (no manager): still renders
        rep.mem_stats.clear();
        assert!(mem_summary(&rep).contains("node0"));
    }

    #[test]
    fn prefetch_summary_formats_per_node() {
        let mut rep = RealReport::default();
        assert_eq!(prefetch_summary(&rep), "prefetch off");
        rep.prefetch_stats = vec![
            crate::exec::PrefetchStats {
                prefetch_bytes: 2048,
                prefetch_hits: 3,
                demand_pull_bytes: 512,
                async_spill_bytes: 0,
            },
            crate::exec::PrefetchStats::default(),
        ];
        let s = prefetch_summary(&rep);
        assert!(s.contains("node0: pf 2.00 KiB (3 hits)"), "{s}");
        assert!(s.contains("demand 512 B"), "{s}");
        assert!(s.contains("node1: pf 0 B"), "{s}");
    }

    #[test]
    fn feedback_summary_formats_per_node() {
        let mut rep = RealReport::default();
        rep.feedback.nodes = vec![
            crate::exec::NodeFeedback {
                tasks_stolen: 3,
                steal_bytes: 1024,
                demand_pull_bytes: 2048,
                unplanned_in_bytes: 2048,
                ..Default::default()
            },
            crate::exec::NodeFeedback::default(),
        ];
        let s = feedback_summary(&rep);
        assert!(s.contains("node0: stolen 3 (1.00 KiB)"), "{s}");
        assert!(s.contains("demand 2.00 KiB"), "{s}");
        assert!(s.contains("node1: stolen 0"), "{s}");
    }

    #[test]
    fn planning_summary_formats_hit_and_counters() {
        let mut rep = crate::api::RunReport::default();
        rep.plan_cache_hit = true;
        rep.simulations = 0;
        rep.decisions = 0;
        rep.plan_cache_hits = 3;
        rep.plan_cache_misses = 1;
        let s = planning_summary(&rep);
        assert!(s.contains("hit=true"), "{s}");
        assert!(s.contains("sims=0"), "{s}");
        assert!(s.contains("cache 3h/1m"), "{s}");
    }

    #[test]
    fn timing_breakdown_sim_run_uses_makespan() {
        let mut rep = crate::api::RunReport::default();
        rep.schedule_secs = 0.002;
        rep.search_secs = 0.001;
        rep.sim.makespan = 1.5;
        rep.plan_cache_misses = 1;
        let b = timing_breakdown(&rep);
        assert_eq!(b.plan_secs, 0.002);
        assert_eq!(b.exec_secs, 1.5);
        assert_eq!(b.io_secs, 0.0);
        assert_eq!(b.io_bytes, 0);
        assert!(!b.plan_cache_hit);
        let s = b.summary();
        assert!(s.contains("plan 2.00 ms"), "{s}");
        assert!(s.contains("miss, cache 0h/1m"), "{s}");
        assert!(s.contains("io 0.0 ns (0 B)"), "{s}");
    }

    #[test]
    fn timing_breakdown_real_run_rolls_up_spans() {
        use crate::metrics::runtime_trace::{RunTrace, TaskSpan};
        use crate::runtime::KernelTier;
        let span = |task: usize, fetch: f64, bytes: u64| TaskSpan {
            task,
            node: 0,
            worker: 0,
            stolen: false,
            threads: 1,
            tier: KernelTier::Scalar,
            prefetch_hits: 0,
            ready_t: 0.0,
            start_t: 0.0,
            fetch_end_t: fetch,
            end_t: fetch + 1.0,
            fetch_bytes: bytes,
            kernel: String::new(),
        };
        let mut real = RealReport::default();
        real.wall_secs = 2.5;
        let mut tr = RunTrace::default();
        tr.spans = vec![span(0, 0.25, 1024), span(1, 0.5, 512)];
        real.trace = Some(tr);
        let mut rep = crate::api::RunReport::default();
        rep.sim.makespan = 99.0; // must be ignored: real wall wins
        rep.real = Some(real);
        rep.plan_cache_hit = true;
        rep.plan_cache_hits = 2;
        let b = timing_breakdown(&rep);
        assert_eq!(b.exec_secs, 2.5);
        assert!((b.io_secs - 0.75).abs() < 1e-12, "{}", b.io_secs);
        assert_eq!(b.io_bytes, 1536);
        let s = b.summary();
        assert!(s.contains("hit, cache 2h/0m"), "{s}");
        assert!(s.contains("1.50 KiB"), "{s}");
    }

    #[test]
    fn emit_json_is_wellformed() {
        let path = std::env::temp_dir().join(format!(
            "nums_bench_{}.json",
            std::process::id()
        ));
        let path = path.to_string_lossy().to_string();
        let recs = vec![
            PerfRecord {
                op: "matmul_blocked_1024".into(),
                bytes: 3 * 1024 * 1024 * 8,
                secs: 0.125,
                gflops: 17.18,
            },
            PerfRecord {
                op: "ew_chain_fused".into(),
                bytes: 1 << 20,
                secs: 0.001,
                gflops: 0.0,
            },
        ];
        emit_json(&path, &recs).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert!(text.starts_with("[\n"));
        assert!(text.trim_end().ends_with(']'));
        assert!(text.contains("\"op\": \"matmul_blocked_1024\""));
        assert!(text.contains("\"gflops\": 17.180000"));
        assert_eq!(text.matches('{').count(), 2);
        assert_eq!(text.matches("},").count(), 1, "one record separator");
    }
}
