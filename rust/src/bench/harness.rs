//! Tiny timing harness used by `rust/benches/*` (`harness = false`).
//!
//! Follows the paper's measurement protocol (§8): repeat each trial,
//! drop the best and worst, report the trimmed mean. `NUMS_BENCH_FAST=1`
//! shrinks repetitions for CI-style smoke runs.

use crate::exec::RealReport;
use crate::util::fmt::{human_secs, render_table};
use crate::util::stats::Summary;
use crate::util::Stopwatch;

#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub samples: Vec<f64>,
}

impl Measurement {
    pub fn paper_mean(&self) -> f64 {
        Summary::paper_mean(&self.samples)
    }

    pub fn summary(&self) -> Summary {
        Summary::from_samples(&self.samples)
    }
}

pub struct Bench {
    pub title: String,
    pub trials: usize,
    pub measurements: Vec<Measurement>,
}

impl Bench {
    pub fn new(title: &str) -> Self {
        let fast = std::env::var("NUMS_BENCH_FAST").ok().as_deref() == Some("1");
        Self {
            title: title.to_string(),
            trials: if fast { 3 } else { 7 },
            measurements: Vec::new(),
        }
    }

    /// Time `f` for `self.trials` trials (plus one warmup).
    pub fn time(&mut self, name: &str, mut f: impl FnMut()) -> f64 {
        f(); // warmup
        let mut samples = Vec::with_capacity(self.trials);
        for _ in 0..self.trials {
            let sw = Stopwatch::start();
            f();
            samples.push(sw.secs());
        }
        let m = Measurement {
            name: name.to_string(),
            samples,
        };
        let mean = m.paper_mean();
        self.measurements.push(m);
        mean
    }

    /// Record an externally-computed value (modeled seconds, bytes, ...).
    pub fn record(&mut self, name: &str, value: f64) {
        self.measurements.push(Measurement {
            name: name.to_string(),
            samples: vec![value],
        });
    }

    /// Render all measurements as a table.
    pub fn report(&self) -> String {
        let rows: Vec<Vec<String>> = self
            .measurements
            .iter()
            .map(|m| {
                let s = m.summary();
                vec![
                    m.name.clone(),
                    human_secs(m.paper_mean()),
                    human_secs(s.min),
                    human_secs(s.max),
                    format!("{}", s.n),
                ]
            })
            .collect();
        format!(
            "## {}\n{}",
            self.title,
            render_table(&["case", "mean(trim)", "min", "max", "n"], &rows)
        )
    }
}

/// One machine-readable perf datapoint for cross-PR trajectory tracking.
#[derive(Clone, Debug, PartialEq)]
pub struct PerfRecord {
    /// Operation label, e.g. `matmul_blocked_1024`.
    pub op: String,
    /// Bytes the operation touches (inputs + outputs).
    pub bytes: u64,
    /// Wall (or modeled) seconds.
    pub secs: f64,
    /// Achieved GFLOP/s (0 for bandwidth-bound ops).
    pub gflops: f64,
}

/// Write records as a JSON array (hand-rolled: no serde offline). Benches
/// emit `BENCH_<fig>.json` next to the working directory so future PRs can
/// diff perf against this one.
pub fn emit_json(path: &str, records: &[PerfRecord]) -> std::io::Result<()> {
    let mut s = String::from("[\n");
    for (i, r) in records.iter().enumerate() {
        s.push_str(&format!(
            "  {{\"op\": \"{}\", \"bytes\": {}, \"secs\": {:.9}, \"gflops\": {:.6}}}{}\n",
            r.op.replace('"', "'"),
            r.bytes,
            r.secs,
            r.gflops,
            if i + 1 < records.len() { "," } else { "" }
        ));
    }
    s.push(']');
    s.push('\n');
    std::fs::write(path, s)
}

/// One-line per-node load-balance summary of a real run:
/// `node0: 12 run (3 stolen, 1.2 KB) | node1: ...` — what the fig09
/// stealing ablation prints next to wall time.
pub fn steal_summary(report: &RealReport) -> String {
    report
        .node_stats
        .iter()
        .enumerate()
        .map(|(n, s)| {
            format!(
                "node{n}: {} run ({} stolen, {} B)",
                s.tasks_run, s.tasks_stolen, s.steal_bytes
            )
        })
        .collect::<Vec<_>>()
        .join(" | ")
}

/// Print a paper-style series table: label column + one column per point.
pub fn print_series(title: &str, x_label: &str, xs: &[String], rows: &[(String, Vec<f64>)]) {
    println!("## {title}");
    let mut header = vec![x_label];
    let xrefs: Vec<&str> = xs.iter().map(|s| s.as_str()).collect();
    header.extend(xrefs);
    let table_rows: Vec<Vec<String>> = rows
        .iter()
        .map(|(name, vals)| {
            let mut r = vec![name.clone()];
            r.extend(vals.iter().map(|v| format!("{v:.4}")));
            r
        })
        .collect();
    println!("{}", render_table(&header, &table_rows));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_collects_trials() {
        let mut b = Bench::new("t");
        b.trials = 3;
        let mean = b.time("noop", || {});
        assert!(mean >= 0.0);
        assert_eq!(b.measurements[0].samples.len(), 3);
        assert!(b.report().contains("noop"));
    }

    #[test]
    fn steal_summary_formats_per_node() {
        let mut rep = RealReport::default();
        rep.node_stats = vec![
            crate::exec::NodeExecStats {
                tasks_run: 5,
                tasks_stolen: 2,
                steal_bytes: 128,
            },
            crate::exec::NodeExecStats::default(),
        ];
        let s = steal_summary(&rep);
        assert!(s.contains("node0: 5 run (2 stolen, 128 B)"), "{s}");
        assert!(s.contains("node1: 0 run"), "{s}");
    }

    #[test]
    fn emit_json_is_wellformed() {
        let path = std::env::temp_dir().join(format!(
            "nums_bench_{}.json",
            std::process::id()
        ));
        let path = path.to_string_lossy().to_string();
        let recs = vec![
            PerfRecord {
                op: "matmul_blocked_1024".into(),
                bytes: 3 * 1024 * 1024 * 8,
                secs: 0.125,
                gflops: 17.18,
            },
            PerfRecord {
                op: "ew_chain_fused".into(),
                bytes: 1 << 20,
                secs: 0.001,
                gflops: 0.0,
            },
        ];
        emit_json(&path, &recs).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert!(text.starts_with("[\n"));
        assert!(text.trim_end().ends_with(']'));
        assert!(text.contains("\"op\": \"matmul_blocked_1024\""));
        assert!(text.contains("\"gflops\": 17.180000"));
        assert_eq!(text.matches('{').count(), 2);
        assert_eq!(text.matches("},").count(), 1, "one record separator");
    }
}
