//! Loopback-TCP transport: one OS process per node, length-prefixed
//! checksum-trailed [`frame`]s over `std::net`, heartbeats, and a
//! launcher/rendezvous protocol.
//!
//! Topology: the driver process keeps the per-node stores (and the
//! worker threads — kernels still execute in the driver); each node
//! additionally gets a **block daemon**, a separate OS process running
//! [`serve_node`] (the `nums node` subcommand). A transfer `src → dst`
//! is carried as: heartbeat `src`'s daemon (`Ping`/`Pong` — the bytes
//! notionally leave src's NIC, so a dead source must fail the
//! transfer), then `Put` the payload frame to `dst`'s daemon, then
//! `Get` it back and re-decode. Every transferred byte therefore
//! crosses a real process boundary over a real (loopback) socket
//! twice, which is what makes the per-transfer latency/bandwidth in
//! `BENCH_net.json` measured rather than modeled.
//!
//! Rendezvous: a node process binds `127.0.0.1:0`, prints
//! `NUMS-NODE-READY <addr>` on stdout, and serves frames.
//! [`TcpTransport::launch`] spawns one child per node and reads that
//! line back — no ports to pre-agree on, nothing listens beyond
//! localhost.
//!
//! Failure mapping: read/connect timeouts surface as
//! [`TransportError::Timeout`] (transient — `StoreSet` retries with
//! backoff); resets, refused connections, clean EOFs, and torn frames
//! surface as [`TransportError::PeerDead`], which the executor turns
//! into its PR 9 node-loss recovery. A checksum-mismatched frame is
//! [`TransportError::Corrupt`] and the connection is dropped — framing
//! is lost, and corrupt payloads must never be served.

use std::collections::HashMap;
use std::io::{BufRead, BufReader};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::Path;
use std::process::{Child, Command, Stdio};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::frame::{self, Frame, FrameError, FrameOp};
use super::transport::{Transport, TransportError, TransportKind, TransferRecord, TransportMetrics};
use crate::store::{Block, ObjectId};

/// Rendezvous line prefix a node process prints once it is listening.
pub const READY_PREFIX: &str = "NUMS-NODE-READY ";

/// Default per-frame read/connect timeout; override with
/// `NUMS_NET_TIMEOUT_MS`. Generous next to loopback RTTs (µs) so slow
/// CI never times out spuriously, small enough that a stalled peer is
/// detected promptly.
pub fn default_timeout() -> Duration {
    let ms = std::env::var("NUMS_NET_TIMEOUT_MS")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .unwrap_or(2_000);
    Duration::from_millis(ms.max(1))
}

/// The block daemon: serve frames on `listener` until a `Quit` frame
/// arrives. Blocks live in a plain map — this process *is* the node's
/// memory for transfer purposes; killing it loses them, which is
/// exactly the failure the chaos suite injects. Connections are served
/// sequentially (the driver multiplexes one connection per node); a
/// dropped connection returns to `accept`, so a reconnecting driver
/// finds its blocks still here.
pub fn serve_node(listener: TcpListener) -> std::io::Result<()> {
    let mut blocks: HashMap<ObjectId, (Vec<usize>, Vec<f64>)> = HashMap::new();
    for stream in listener.incoming() {
        let mut stream = match stream {
            Ok(s) => s,
            Err(_) => continue,
        };
        let _ = stream.set_nodelay(true);
        if serve_conn(&mut stream, &mut blocks) {
            return Ok(()); // orderly Quit
        }
    }
    Ok(())
}

/// Serve one driver connection; returns true on `Quit`.
fn serve_conn(
    stream: &mut TcpStream,
    blocks: &mut HashMap<ObjectId, (Vec<usize>, Vec<f64>)>,
) -> bool {
    loop {
        let req = match frame::read_frame(stream) {
            Ok(f) => f,
            // disconnect, torn frame, or corruption: drop the
            // connection (framing is gone) and await a reconnect
            Err(_) => return false,
        };
        let reply = match req.op {
            FrameOp::Put => {
                blocks.insert(req.obj, (req.shape, req.payload));
                Frame::control(FrameOp::Ack, req.node, req.obj)
            }
            FrameOp::Get => match blocks.get(&req.obj) {
                Some((shape, payload)) => {
                    Frame::data(FrameOp::Data, req.node, req.obj, shape, payload.clone())
                }
                None => Frame::control(FrameOp::NotFound, req.node, req.obj),
            },
            FrameOp::Ping => Frame::control(FrameOp::Pong, req.node, req.obj),
            FrameOp::Quit => return true,
            // a reply opcode arriving at the server is a desync
            _ => return false,
        };
        if frame::write_frame(stream, &reply).is_err() {
            return false;
        }
    }
}

fn classify(node: usize, e: FrameError) -> TransportError {
    use std::io::ErrorKind as K;
    if e.is_timeout() {
        return TransportError::Timeout { node };
    }
    match e {
        FrameError::Corrupt { .. } => TransportError::Corrupt { node, obj: 0 },
        // a torn frame or connection-class I/O error means the peer
        // process went away mid-conversation
        FrameError::Truncated { .. } => TransportError::PeerDead { node },
        FrameError::Io { kind, .. }
            if matches!(
                kind,
                K::UnexpectedEof
                    | K::ConnectionReset
                    | K::ConnectionAborted
                    | K::BrokenPipe
                    | K::ConnectionRefused
                    | K::NotConnected
            ) =>
        {
            TransportError::PeerDead { node }
        }
        FrameError::Io { msg, .. } => TransportError::Io { node, reason: msg },
        other => TransportError::Io { node, reason: other.to_string() },
    }
}

/// Driver-side TCP carrier: one lazily-(re)connected, mutex-guarded
/// stream per node daemon (per-link serialization — one NIC per node),
/// plus the launcher's child handles for chaos kills and teardown.
pub struct TcpTransport {
    addrs: Vec<SocketAddr>,
    conns: Vec<Mutex<Option<TcpStream>>>,
    children: Mutex<Vec<Option<Child>>>,
    timeout: Duration,
    metrics: TransportMetrics,
}

impl TcpTransport {
    /// Attach to already-running daemons (tests run in-thread servers
    /// through this; the launcher path is [`TcpTransport::launch`]).
    pub fn connect(addrs: Vec<SocketAddr>) -> Self {
        let n = addrs.len();
        Self {
            addrs,
            conns: (0..n).map(|_| Mutex::new(None)).collect(),
            children: Mutex::new((0..n).map(|_| None).collect()),
            timeout: default_timeout(),
            metrics: TransportMetrics::default(),
        }
    }

    /// Spawn `nodes` block-daemon processes from `bin` (the `nums`
    /// binary; each runs `nums node --idx i`) and rendezvous on their
    /// `NUMS-NODE-READY` lines. On any failure the already-spawned
    /// children are killed before returning the error.
    pub fn launch(nodes: usize, bin: &Path) -> std::io::Result<Self> {
        let mut children: Vec<Option<Child>> = Vec::with_capacity(nodes);
        let mut addrs: Vec<SocketAddr> = Vec::with_capacity(nodes);
        for i in 0..nodes {
            let spawned = Command::new(bin)
                .arg("node")
                .arg("--idx")
                .arg(i.to_string())
                .stdin(Stdio::null())
                .stdout(Stdio::piped())
                .spawn();
            let mut child = match spawned {
                Ok(c) => c,
                Err(e) => {
                    kill_all(&mut children);
                    return Err(e);
                }
            };
            match rendezvous(&mut child) {
                Ok(addr) => {
                    addrs.push(addr);
                    children.push(Some(child));
                }
                Err(e) => {
                    let _ = child.kill();
                    let _ = child.wait();
                    kill_all(&mut children);
                    return Err(e);
                }
            }
        }
        let n = nodes;
        Ok(Self {
            addrs,
            conns: (0..n).map(|_| Mutex::new(None)).collect(),
            children: Mutex::new(children),
            timeout: default_timeout(),
            metrics: TransportMetrics::default(),
        })
    }

    pub fn with_timeout(mut self, d: Duration) -> Self {
        self.timeout = d;
        self
    }

    pub fn addr(&self, node: usize) -> SocketAddr {
        self.addrs[node]
    }

    /// One framed request/reply on `node`'s connection. Any failure
    /// drops the cached stream so the next attempt reconnects.
    fn rpc(&self, node: usize, req: &Frame) -> Result<Frame, TransportError> {
        let mut guard = self.conns[node].lock().unwrap();
        if guard.is_none() {
            let stream = TcpStream::connect_timeout(&self.addrs[node], self.timeout)
                .map_err(|e| match e.kind() {
                    std::io::ErrorKind::TimedOut | std::io::ErrorKind::WouldBlock => {
                        TransportError::Timeout { node }
                    }
                    _ => TransportError::PeerDead { node },
                })?;
            let _ = stream.set_nodelay(true);
            let _ = stream.set_read_timeout(Some(self.timeout));
            let _ = stream.set_write_timeout(Some(self.timeout));
            *guard = Some(stream);
        }
        let stream = guard.as_mut().unwrap();
        let out = frame::write_frame(stream, req)
            .and_then(|_| frame::read_frame(stream));
        match out {
            Ok(f) => Ok(f),
            Err(e) => {
                *guard = None; // poisoned framing: force a reconnect
                Err(classify(node, e))
            }
        }
    }

    /// Kill `node`'s daemon process (chaos hook). Also drops the cached
    /// connection so the next carry observes the death immediately.
    pub fn kill_node(&self, node: usize) -> bool {
        let killed = match self.children.lock().unwrap()[node].take() {
            Some(mut c) => {
                let _ = c.kill();
                let _ = c.wait();
                true
            }
            None => false,
        };
        *self.conns[node].lock().unwrap() = None;
        killed
    }
}

fn rendezvous(child: &mut Child) -> std::io::Result<SocketAddr> {
    let stdout = child.stdout.take().ok_or_else(|| {
        std::io::Error::new(std::io::ErrorKind::Other, "node child has no stdout")
    })?;
    let mut lines = BufReader::new(stdout).lines();
    for line in &mut lines {
        let line = line?;
        if let Some(rest) = line.strip_prefix(READY_PREFIX) {
            let addr = rest.trim().parse::<SocketAddr>().map_err(|e| {
                std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("bad rendezvous line {rest:?}: {e}"),
                )
            })?;
            // keep draining stdout in the background so the child never
            // blocks on a full pipe
            std::thread::spawn(move || for _ in lines {});
            return Ok(addr);
        }
    }
    Err(std::io::Error::new(
        std::io::ErrorKind::UnexpectedEof,
        "node child exited before NUMS-NODE-READY",
    ))
}

fn kill_all(children: &mut [Option<Child>]) {
    for c in children.iter_mut() {
        if let Some(mut c) = c.take() {
            let _ = c.kill();
            let _ = c.wait();
        }
    }
}

impl Transport for TcpTransport {
    fn kind(&self) -> TransportKind {
        TransportKind::Tcp
    }

    fn carry(
        &self,
        src: usize,
        dst: usize,
        id: ObjectId,
        block: &Arc<Block>,
    ) -> Result<Arc<Block>, TransportError> {
        let t0 = Instant::now();
        // heartbeat the source: its daemon embodies the sending node,
        // so a killed source process must fail transfers out of it even
        // though the payload is relayed from the driver-held store copy
        if src != dst {
            self.ping(src)?;
        }
        let nd = u16::try_from(dst).unwrap_or(u16::MAX);
        let put = Frame::data(FrameOp::Put, nd, id, &block.shape, block.buf().to_vec());
        match self.rpc(dst, &put)? {
            Frame { op: FrameOp::Ack, .. } => {}
            other => {
                return Err(TransportError::Io {
                    node: dst,
                    reason: format!("expected Ack, got {:?}", other.op),
                })
            }
        }
        let got = self.rpc(dst, &Frame::control(FrameOp::Get, nd, id))?;
        match got.op {
            FrameOp::Data => {
                // frame decode already verified the checksum trailer;
                // shape/length mismatches still mean a desynced peer
                if got.obj != id
                    || got.shape != block.shape
                    || got.payload.len() * 8 != block.bytes() as usize
                {
                    return Err(TransportError::Corrupt { node: dst, obj: id });
                }
                let b = Arc::new(Block::from_vec(&got.shape, got.payload));
                self.metrics.record(src, dst, b.bytes(), t0.elapsed().as_secs_f64());
                Ok(b)
            }
            FrameOp::NotFound => {
                // daemon restarted between Put and Get: retryable
                Err(TransportError::Io { node: dst, reason: "put/get lost".into() })
            }
            other => Err(TransportError::Io {
                node: dst,
                reason: format!("expected Data, got {other:?}"),
            }),
        }
    }

    fn ping(&self, node: usize) -> Result<Duration, TransportError> {
        let t0 = Instant::now();
        let nd = u16::try_from(node).unwrap_or(u16::MAX);
        match self.rpc(node, &Frame::control(FrameOp::Ping, nd, 0))? {
            Frame { op: FrameOp::Pong, .. } => Ok(t0.elapsed()),
            other => Err(TransportError::Io {
                node,
                reason: format!("expected Pong, got {:?}", other.op),
            }),
        }
    }

    fn records(&self) -> Vec<TransferRecord> {
        self.metrics.snapshot()
    }

    fn kill_peer(&self, node: usize) -> bool {
        self.kill_node(node)
    }

    fn shutdown(&self) {
        for node in 0..self.addrs.len() {
            // orderly quit; a dead/killed daemon just errors out here
            let nd = u16::try_from(node).unwrap_or(u16::MAX);
            let _ = self.rpc(node, &Frame::control(FrameOp::Quit, nd, 0));
            *self.conns[node].lock().unwrap() = None;
        }
        let mut children = self.children.lock().unwrap();
        for slot in children.iter_mut() {
            if let Some(mut c) = slot.take() {
                // Quit should have ended it; bounded wait, then kill
                let mut done = false;
                for _ in 0..50 {
                    if matches!(c.try_wait(), Ok(Some(_))) {
                        done = true;
                        break;
                    }
                    std::thread::sleep(Duration::from_millis(10));
                }
                if !done {
                    let _ = c.kill();
                    let _ = c.wait();
                }
            }
        }
    }
}

impl Drop for TcpTransport {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// In-thread daemon (real sockets, no child process): enough for
    /// protocol tests; process-boundary tests live in tests/transport.rs
    /// where the launcher can spawn the real `nums` binary.
    fn spawn_daemon() -> SocketAddr {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        std::thread::spawn(move || serve_node(listener));
        addr
    }

    fn blk(vals: &[f64]) -> Arc<Block> {
        Arc::new(Block::from_vec(&[vals.len()], vals.to_vec()))
    }

    #[test]
    fn carry_roundtrips_bits_through_real_sockets() {
        let addrs = vec![spawn_daemon(), spawn_daemon()];
        let t = TcpTransport::connect(addrs);
        let b = blk(&[1.0, -0.0, 3.5e-300, f64::MAX]);
        let c = t.carry(0, 1, 77, &b).unwrap();
        assert!(!Arc::ptr_eq(&b, &c));
        for (x, y) in b.buf().iter().zip(c.buf()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        let rec = t.records();
        assert_eq!(rec.len(), 1);
        assert_eq!(rec[0].bytes, 32);
        assert!(rec[0].secs > 0.0, "a real socket round trip takes time");
        // heartbeat answers with a measured RTT
        assert!(t.ping(1).unwrap() > Duration::ZERO);
        t.shutdown();
    }

    #[test]
    fn dead_peer_is_typed_not_hung() {
        // bind, learn the port, drop the listener: connects are refused
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap()
        };
        let t = TcpTransport::connect(vec![addr]).with_timeout(Duration::from_millis(200));
        match t.ping(0) {
            Err(TransportError::PeerDead { node: 0 }) => {}
            other => panic!("expected PeerDead, got {other:?}"),
        }
    }

    #[test]
    fn stalled_peer_times_out_as_transient() {
        // a listener that accepts and then never replies
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        std::thread::spawn(move || {
            let conns: Vec<_> = listener.incoming().take(2).collect();
            std::thread::sleep(Duration::from_secs(30));
            drop(conns);
        });
        let t = TcpTransport::connect(vec![addr]).with_timeout(Duration::from_millis(100));
        match t.ping(0) {
            Err(e @ TransportError::Timeout { node: 0 }) => {
                assert!(e.is_transient(), "heartbeat timeout must map to transient retry")
            }
            other => panic!("expected Timeout, got {other:?}"),
        }
    }
}
