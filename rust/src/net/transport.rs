//! The pluggable block-transfer layer behind
//! [`crate::store::StoreSet::try_transfer`].
//!
//! Every cross-node byte in the real executor — worker demand pulls,
//! prefetcher background pulls, memory-manager acquires — funnels
//! through one seam: `StoreSet::try_transfer`. A [`Transport`] is the
//! physical carrier under that seam. Three implementations exist:
//!
//! * [`InProcessTransport`] — today's behavior and the sequential
//!   oracle: the `Arc<Block>` is cloned between per-node stores, no
//!   serialization, no failure modes. The default; every pre-existing
//!   test runs unchanged on it.
//! * [`ShmTransport`] — the block round-trips through a
//!   `/dev/shm`-backed file using the spill codec (chunked LE f64 +
//!   FNV-1a-128 checksum trailer) from [`crate::store::memory`], so the
//!   destination observes a genuinely re-decoded copy.
//! * [`crate::net::TcpTransport`] — length-prefixed
//!   [`crate::net::frame`] frames over loopback TCP to one OS process
//!   per node, with heartbeats ([`Transport::ping`]).
//!
//! Failure mapping (the payoff of building PR 9's recovery machinery
//! transport-agnostic): a **transient** carry failure — connection
//! lost, heartbeat/read timeout, corrupt frame — is retried in place by
//! `StoreSet` with bounded backoff ([`MAX_LINK_RETRIES`],
//! [`link_backoff`] — the same policy as
//! `exec::recovery::backoff_delay`, duplicated here because `store`
//! cannot depend on `exec`). A **peer-death** failure (or transient
//! retries exhausting) marks the node dead on the `StoreSet`; the real
//! executor reaps that flag into its node-loss path — wipe, divert,
//! lineage recompute — exactly as if a `FaultPlan` had scheduled the
//! loss. Byte accounting stays in `StoreSet`, so the
//! `prefetch + demand == net_in` identity holds on every transport.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::store::memory::{read_spill, write_spill};
use crate::store::{Block, ObjectId};

/// Which carrier a session uses. Selected by
/// `SessionConfig::transport` / the `NUMS_TRANSPORT` env var.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TransportKind {
    /// Arc-clone between in-process stores (default; the oracle).
    #[default]
    InProcess,
    /// Blocks hand off via checksummed `/dev/shm`-backed files.
    SharedMem,
    /// Framed loopback TCP to one OS process per node.
    Tcp,
}

impl TransportKind {
    pub fn name(&self) -> &'static str {
        match self {
            TransportKind::InProcess => "in-process",
            TransportKind::SharedMem => "shm",
            TransportKind::Tcp => "tcp",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        Some(match s.trim().to_ascii_lowercase().as_str() {
            "" | "inproc" | "in-process" | "inprocess" | "local" => TransportKind::InProcess,
            "shm" | "sharedmem" | "shared-mem" | "shared-memory" => TransportKind::SharedMem,
            "tcp" => TransportKind::Tcp,
            _ => return None,
        })
    }

    /// `NUMS_TRANSPORT` env selection, defaulting to in-process. An
    /// unknown value panics loudly — a typo silently falling back to
    /// in-process would fake every "runs on a real transport" claim.
    pub fn from_env() -> Self {
        match std::env::var("NUMS_TRANSPORT") {
            Ok(v) => Self::parse(&v)
                .unwrap_or_else(|| panic!("NUMS_TRANSPORT={v:?}: expected inproc|shm|tcp")),
            Err(_) => TransportKind::InProcess,
        }
    }
}

/// Typed carry failure. [`TransportError::is_transient`] splits the
/// retry-in-place class from the node-loss class.
#[derive(Clone, Debug, PartialEq)]
pub enum TransportError {
    /// The peer process is gone (connection refused/reset, clean EOF,
    /// or a killed child). Maps to the executor's node-loss recovery.
    PeerDead { node: usize },
    /// Heartbeat or read timed out — the link may recover; retried.
    Timeout { node: usize },
    /// The frame/file arrived but failed its checksum — never served;
    /// retried (a re-send re-encodes), then escalated.
    Corrupt { node: usize, obj: ObjectId },
    /// Any other I/O failure on the link; retried, then escalated.
    Io { node: usize, reason: String },
}

impl TransportError {
    /// Transient failures retry in place with [`link_backoff`];
    /// non-transient ones (peer death) go straight to node loss.
    pub fn is_transient(&self) -> bool {
        !matches!(self, TransportError::PeerDead { .. })
    }

    /// The node whose link/process failed.
    pub fn node(&self) -> usize {
        match *self {
            TransportError::PeerDead { node }
            | TransportError::Timeout { node }
            | TransportError::Corrupt { node, .. }
            | TransportError::Io { node, .. } => node,
        }
    }
}

impl std::fmt::Display for TransportError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TransportError::PeerDead { node } => write!(f, "node {node}: peer process dead"),
            TransportError::Timeout { node } => write!(f, "node {node}: link timeout"),
            TransportError::Corrupt { node, obj } => {
                write!(f, "node {node}: corrupt frame for object {obj}")
            }
            TransportError::Io { node, reason } => write!(f, "node {node}: link I/O: {reason}"),
        }
    }
}

impl std::error::Error for TransportError {}

/// In-place retries `StoreSet` grants a transient link failure before
/// escalating to node loss. Mirrors
/// `exec::recovery::MAX_TRANSIENT_RETRIES`.
pub const MAX_LINK_RETRIES: u32 = 4;

/// Bounded exponential backoff between link retries: 100 µs doubling,
/// capped at 5 ms — the same curve as `exec::recovery::backoff_delay`
/// (duplicated: `store`/`net` cannot depend on `exec`).
pub fn link_backoff(attempt: u32) -> Duration {
    let us = 100u64 << attempt.min(6);
    Duration::from_micros(us.min(5_000))
}

/// One measured transfer: real wall-clock, real bytes — what
/// `BENCH_net.json` reports instead of the α–β model.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TransferRecord {
    pub src: usize,
    pub dst: usize,
    pub bytes: u64,
    pub secs: f64,
}

/// Shared per-transport metrics sink. Lock cost is negligible next to
/// the file/socket I/O it measures.
#[derive(Default)]
pub struct TransportMetrics {
    records: Mutex<Vec<TransferRecord>>,
}

impl TransportMetrics {
    pub fn record(&self, src: usize, dst: usize, bytes: u64, secs: f64) {
        self.records.lock().unwrap().push(TransferRecord { src, dst, bytes, secs });
    }

    pub fn snapshot(&self) -> Vec<TransferRecord> {
        self.records.lock().unwrap().clone()
    }
}

/// The carrier contract. `carry` moves one block's payload from `src`
/// to `dst` and returns the block *as observed at the destination* —
/// for in-process that is the same `Arc`; for shm/TCP it is re-decoded
/// from the wire/file representation (and therefore proves the codec
/// round-trip bit-exact on every transfer). Implementations must be
/// safe to call from many worker/transfer threads at once.
pub trait Transport: Send + Sync {
    fn kind(&self) -> TransportKind;

    fn carry(
        &self,
        src: usize,
        dst: usize,
        id: ObjectId,
        block: &Arc<Block>,
    ) -> Result<Arc<Block>, TransportError>;

    /// Heartbeat: is `node`'s carrier endpoint alive? In-process and
    /// shm peers are this process — always alive, zero RTT.
    fn ping(&self, _node: usize) -> Result<Duration, TransportError> {
        Ok(Duration::ZERO)
    }

    /// Measured per-transfer records (empty when metrics are off).
    fn records(&self) -> Vec<TransferRecord> {
        Vec::new()
    }

    /// Chaos hook: forcibly kill `node`'s carrier endpoint, returning
    /// whether anything was killed. Only the TCP transport has a
    /// process to kill.
    fn kill_peer(&self, _node: usize) -> bool {
        false
    }

    /// Orderly teardown (kills/quits node processes where they exist).
    fn shutdown(&self) {}
}

/// Today's behavior, verbatim: the destination store receives the same
/// `Arc<Block>` the source holds. Metrics are off by default so the
/// hot path stays free of clocks and locks; the net-transport bench
/// turns them on to get per-transfer baselines.
#[derive(Default)]
pub struct InProcessTransport {
    metrics: Option<TransportMetrics>,
}

impl InProcessTransport {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_metrics() -> Self {
        Self { metrics: Some(TransportMetrics::default()) }
    }
}

impl Transport for InProcessTransport {
    fn kind(&self) -> TransportKind {
        TransportKind::InProcess
    }

    fn carry(
        &self,
        src: usize,
        dst: usize,
        _id: ObjectId,
        block: &Arc<Block>,
    ) -> Result<Arc<Block>, TransportError> {
        match &self.metrics {
            None => Ok(Arc::clone(block)),
            Some(m) => {
                let t0 = Instant::now();
                let b = Arc::clone(block);
                m.record(src, dst, b.bytes(), t0.elapsed().as_secs_f64());
                Ok(b)
            }
        }
    }

    fn records(&self) -> Vec<TransferRecord> {
        self.metrics.as_ref().map(|m| m.snapshot()).unwrap_or_default()
    }
}

/// Distinguishes concurrent shm files (and directories across
/// transports in one process).
static SHM_SEQ: AtomicU64 = AtomicU64::new(0);

/// Blocks hand off through checksummed files on a shared-memory
/// filesystem: the payload is encoded with the spill codec
/// ([`crate::store::memory`]'s chunked LE f64 + FNV-1a-128 trailer),
/// fsync-free, then re-decoded for the destination store and the file
/// unlinked. `/dev/shm` when present (Linux: a tmpfs, so the round
/// trip is two memory copies through the page cache, the closest file
/// analogue of Ray's plasma hand-off); the OS temp dir otherwise.
pub struct ShmTransport {
    dir: PathBuf,
    seq: AtomicU64,
    metrics: TransportMetrics,
}

impl ShmTransport {
    pub fn new() -> std::io::Result<Self> {
        let shm = PathBuf::from("/dev/shm");
        let base = if shm.is_dir() { shm } else { std::env::temp_dir() };
        let dir = base.join(format!(
            "nums-shm-{}-{}",
            std::process::id(),
            SHM_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::create_dir_all(&dir)?;
        Ok(Self { dir, seq: AtomicU64::new(0), metrics: TransportMetrics::default() })
    }

    /// Where the block files land (tests assert cleanup).
    pub fn dir(&self) -> &std::path::Path {
        &self.dir
    }
}

impl Drop for ShmTransport {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.dir);
    }
}

impl Transport for ShmTransport {
    fn kind(&self) -> TransportKind {
        TransportKind::SharedMem
    }

    fn carry(
        &self,
        src: usize,
        dst: usize,
        id: ObjectId,
        block: &Arc<Block>,
    ) -> Result<Arc<Block>, TransportError> {
        let t0 = Instant::now();
        let path = self.dir.join(format!(
            "b{id}-{src}-{dst}-{}.blk",
            self.seq.fetch_add(1, Ordering::Relaxed)
        ));
        if let Err(e) = write_spill(&path, block.buf()) {
            let _ = std::fs::remove_file(&path);
            return Err(TransportError::Io { node: src, reason: e.to_string() });
        }
        let decoded = read_spill(&path, block.bytes());
        let _ = std::fs::remove_file(&path);
        match decoded {
            // truncation/checksum failure surfaces typed, never as data
            None => Err(TransportError::Corrupt { node: dst, obj: id }),
            Some(data) => {
                let b = Arc::new(Block::from_vec(&block.shape, data));
                self.metrics.record(src, dst, b.bytes(), t0.elapsed().as_secs_f64());
                Ok(b)
            }
        }
    }

    fn records(&self) -> Vec<TransferRecord> {
        self.metrics.snapshot()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blk(vals: &[f64]) -> Arc<Block> {
        Arc::new(Block::from_vec(&[vals.len(), 1], vals.to_vec()))
    }

    #[test]
    fn kind_parses_and_env_defaults() {
        assert_eq!(TransportKind::parse("inproc"), Some(TransportKind::InProcess));
        assert_eq!(TransportKind::parse("SHM"), Some(TransportKind::SharedMem));
        assert_eq!(TransportKind::parse("tcp"), Some(TransportKind::Tcp));
        assert_eq!(TransportKind::parse("carrier-pigeon"), None);
        assert_eq!(TransportKind::default(), TransportKind::InProcess);
    }

    #[test]
    fn in_process_carry_is_the_same_allocation() {
        let t = InProcessTransport::new();
        let b = blk(&[1.0, 2.0, 3.0]);
        let c = t.carry(0, 1, 7, &b).unwrap();
        assert!(Arc::ptr_eq(&b, &c), "in-process must not copy");
        assert!(t.records().is_empty(), "metrics off by default");
        let tm = InProcessTransport::with_metrics();
        tm.carry(0, 1, 7, &b).unwrap();
        assert_eq!(tm.records().len(), 1);
        assert_eq!(tm.records()[0].bytes, 24);
    }

    #[test]
    fn shm_carry_redecodes_bit_identically_and_cleans_up() {
        let t = ShmTransport::new().unwrap();
        let vals = [1.5, -0.0, f64::MIN_POSITIVE, 3.25e300];
        let b = blk(&vals);
        let c = t.carry(0, 1, 9, &b).unwrap();
        assert!(!Arc::ptr_eq(&b, &c), "shm must round-trip through the codec");
        for (x, y) in b.buf().iter().zip(c.buf()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        assert_eq!(c.shape, b.shape);
        let rec = t.records();
        assert_eq!(rec.len(), 1);
        assert_eq!((rec[0].src, rec[0].dst, rec[0].bytes), (0, 1, 32));
        assert!(rec[0].secs >= 0.0);
        // block files are unlinked after each carry
        assert_eq!(std::fs::read_dir(t.dir()).unwrap().count(), 0);
    }

    #[test]
    fn error_classes_split_transient_from_node_loss() {
        assert!(TransportError::Timeout { node: 1 }.is_transient());
        assert!(TransportError::Corrupt { node: 1, obj: 2 }.is_transient());
        assert!(TransportError::Io { node: 1, reason: "x".into() }.is_transient());
        assert!(!TransportError::PeerDead { node: 1 }.is_transient());
        assert_eq!(TransportError::Timeout { node: 3 }.node(), 3);
    }

    #[test]
    fn link_backoff_is_bounded_and_monotone() {
        let mut prev = Duration::ZERO;
        for a in 0..12 {
            let d = link_backoff(a);
            assert!(d >= prev && d <= Duration::from_millis(5));
            prev = d;
        }
    }
}
