//! The α–β–γ communication model of §7 / Appendix A.

pub mod model;

pub use model::{LinkParams, NetParams};
