//! Networking: the α–β–γ communication *model* of §7 / Appendix A, and
//! the *real* transport layer — pluggable in-process / shared-memory /
//! loopback-TCP block carriers behind `StoreSet::try_transfer`, with a
//! checksummed wire format and a node-process launcher.

pub mod frame;
pub mod model;
pub mod tcp;
pub mod transport;

pub use frame::{Frame, FrameDecoder, FrameError, FrameOp};
pub use model::{LinkParams, NetParams};
pub use tcp::{serve_node, TcpTransport, READY_PREFIX};
pub use transport::{
    link_backoff, InProcessTransport, ShmTransport, TransferRecord, Transport, TransportError,
    TransportKind, TransportMetrics, MAX_LINK_RETRIES,
};
