//! α–β–γ communication-cost model (paper §7, Appendix A).
//!
//! * `C(n) = α + β·n` — inter-node transfer of `n` bytes.
//! * `R(n) = α' + β'·n` — implicit intra-node cost on Ray (shared-memory
//!   object store: workers pay a constant put/get overhead, no copy over
//!   TCP).
//! * `D(n) = α'' + β''·n` — intra-node worker-to-worker transfer on Dask
//!   (TCP loopback between worker processes).
//! * `γ` — driver dispatch latency per remote function call (RFC).
//!
//! The paper assumes `α ≫ α'' > α'` and `β ≫ β'' > β'`; the presets below
//! satisfy those orderings and are calibrated to the §8 testbed
//! (16 × r5.16xlarge over 20 Gbps).

/// One channel's latency/inverse-bandwidth pair. Times are seconds, sizes
/// bytes.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkParams {
    /// Latency α in seconds.
    pub alpha: f64,
    /// Inverse bandwidth β in seconds/byte.
    pub beta: f64,
}

impl LinkParams {
    pub const fn new(alpha: f64, beta: f64) -> Self {
        Self { alpha, beta }
    }

    /// Transfer time for `bytes` bytes.
    #[inline]
    pub fn time(&self, bytes: u64) -> f64 {
        self.alpha + self.beta * bytes as f64
    }

    /// A zero-cost link (used to express "no communication").
    pub const ZERO: LinkParams = LinkParams::new(0.0, 0.0);
}

/// Which distributed-system flavour the cluster emulates. Ray places at
/// node granularity over a shared-memory store; Dask places at worker
/// granularity and pays `D(n)` for intra-node transfers (§3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SystemMode {
    Ray,
    Dask,
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NetParams {
    /// Inter-node channel `C(n)`.
    pub inter: LinkParams,
    /// Ray intra-node implicit cost `R(n)` (object-store put/get).
    pub intra_ray: LinkParams,
    /// Dask intra-node worker-to-worker cost `D(n)`.
    pub intra_dask: LinkParams,
    /// Driver dispatch latency γ per RFC, seconds.
    pub gamma: f64,
}

impl NetParams {
    /// Calibrated to the paper's testbed: 20 Gbps inter-node (≈2.5 GB/s),
    /// shared-memory store ≈20 GB/s effective with small put/get constant,
    /// TCP loopback ≈5 GB/s, and a driver that dispatches ≈10⁴ RFCs/s
    /// (Fig. 8a measures γ of this order on Ray).
    pub fn paper_testbed() -> Self {
        Self {
            inter: LinkParams::new(200e-6, 1.0 / 2.5e9),
            intra_ray: LinkParams::new(20e-6, 1.0 / 20e9),
            intra_dask: LinkParams::new(60e-6, 1.0 / 5e9),
            gamma: 100e-6,
        }
    }

    /// An MPI-style runtime (SLATE/ScaLAPACK, §8.2): same physical network,
    /// no central driver (γ = 0), no object-store overhead (R = 0 — ranks
    /// address their buffers directly).
    pub fn mpi_testbed() -> Self {
        Self {
            inter: LinkParams::new(200e-6, 1.0 / 2.5e9),
            intra_ray: LinkParams::ZERO,
            intra_dask: LinkParams::ZERO,
            gamma: 0.0,
        }
    }

    /// Localhost "cluster" for real-execution runs: per-node stores live in
    /// one address space; modeled times are kept for reporting but the real
    /// executor measures wall-clock.
    pub fn localhost() -> Self {
        Self {
            inter: LinkParams::new(20e-6, 1.0 / 8e9),
            intra_ray: LinkParams::new(2e-6, 1.0 / 40e9),
            intra_dask: LinkParams::new(6e-6, 1.0 / 16e9),
            gamma: 10e-6,
        }
    }

    /// Intra-node cost under the given system mode.
    #[inline]
    pub fn intra(&self, mode: SystemMode) -> LinkParams {
        match mode {
            SystemMode::Ray => self.intra_ray,
            SystemMode::Dask => self.intra_dask,
        }
    }

    /// Sanity orderings the paper assumes (App. A): α ≫ α'' > α',
    /// β ≫ β'' > β'. Used by tests and asserted when loading custom params.
    pub fn orderings_hold(&self) -> bool {
        self.inter.alpha >= self.intra_dask.alpha
            && self.intra_dask.alpha >= self.intra_ray.alpha
            && self.inter.beta >= self.intra_dask.beta
            && self.intra_dask.beta >= self.intra_ray.beta
    }
}

/// Per-worker compute-rate model used by the simulated executor to convert
/// kernel FLOP/byte counts into seconds. Defaults approximate one
/// single-threaded Skylake-SP core (§8: NumS pins BLAS to one thread).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ComputeParams {
    /// Dense-FLOP rate for contraction kernels, FLOP/s.
    pub flops: f64,
    /// Element throughput for element-wise/reduction kernels, elems/s.
    pub ew_rate: f64,
    /// Fixed per-task overhead of an RFC *on the worker* (deserialize args,
    /// store output). This is the `R(n)` constant part Fig. 8b measures.
    pub task_overhead: f64,
    /// Object-store capacity per node, bytes. Resident bytes beyond this
    /// spill to disk (§8.1/§8.4 observe "object spilling" on Ray when too
    /// many large objects land on few nodes).
    pub mem_capacity: f64,
    /// Disk bandwidth paid by spilled bytes, bytes/s.
    pub disk_rate: f64,
}

impl ComputeParams {
    pub fn paper_testbed() -> Self {
        Self {
            flops: 30e9,
            ew_rate: 1.5e9,
            task_overhead: 300e-6,
            // r5.16xlarge: 512 GB RAM, 312 GB configured as object store
            mem_capacity: 312e9,
            disk_rate: 1.5e9,
        }
    }

    pub fn mpi_testbed() -> Self {
        Self {
            flops: 30e9,
            ew_rate: 1.5e9,
            task_overhead: 0.0,
            // HPC jobs are sized to memory; SLATE never spills
            mem_capacity: f64::INFINITY,
            disk_rate: 1.5e9,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn link_time_is_affine() {
        let l = LinkParams::new(1e-3, 1e-9);
        assert!((l.time(0) - 1e-3).abs() < 1e-15);
        assert!((l.time(1_000_000_000) - 1.001).abs() < 1e-9);
    }

    #[test]
    fn presets_satisfy_paper_orderings() {
        assert!(NetParams::paper_testbed().orderings_hold());
        assert!(NetParams::localhost().orderings_hold());
        assert!(NetParams::mpi_testbed().orderings_hold());
    }

    #[test]
    fn ray_cheaper_than_dask_intra_node() {
        let p = NetParams::paper_testbed();
        for bytes in [0u64, 1 << 10, 1 << 20, 1 << 30] {
            assert!(p.intra_ray.time(bytes) < p.intra_dask.time(bytes));
            assert!(p.intra_dask.time(bytes) < p.inter.time(bytes).max(1e-30) + 1.0);
        }
    }

    #[test]
    fn mpi_has_no_dispatch_latency() {
        assert_eq!(NetParams::mpi_testbed().gamma, 0.0);
        assert_eq!(ComputeParams::mpi_testbed().task_overhead, 0.0);
    }

    #[test]
    fn mode_selects_channel() {
        let p = NetParams::paper_testbed();
        assert_eq!(p.intra(SystemMode::Ray), p.intra_ray);
        assert_eq!(p.intra(SystemMode::Dask), p.intra_dask);
    }
}
