//! Length-prefixed, checksum-trailed block frames — the wire format every
//! non-in-process [`crate::net::Transport`] speaks.
//!
//! A frame is:
//!
//! ```text
//! magic "NBF1" (4) | op (1) | ndim (1) | node (2 LE) | obj (8 LE)
//!   | payload elems (8 LE)                              = 24-byte header
//! shape dims (ndim × 8 LE)
//! payload (elems × 8, f64 LE)
//! FNV-1a-128 trailer (16 LE)                             = integrity
//! ```
//!
//! The trailer hashes the *semantic* content — op, node, object id,
//! shape, and payload as exact f64 bits via [`Fnv128::f64`] — the same
//! convention as the spill-file codec in [`crate::store::memory`], so a
//! frame that decodes is bit-identical to the frame that was encoded.
//! Control frames (`Get`/`Ack`/`Ping`/…) carry no shape or payload but
//! still end in a trailer: a corrupted length field on a control frame
//! is caught, never silently resynchronized.
//!
//! Decoding never returns bad data silently: every failure is a typed
//! [`FrameError`] — truncation, bad magic, unknown op, an implausible
//! length, or a checksum mismatch. [`FrameDecoder`] is the incremental
//! (partial-read resume) face of the same parser: feed it bytes as they
//! arrive and it yields a frame exactly when one is complete.

use std::io::{Read, Write};

use crate::graph::signature::Fnv128;
use crate::store::ObjectId;

/// Frame magic: "NumS Block Frame v1".
pub const MAGIC: [u8; 4] = *b"NBF1";

/// Fixed header size in bytes.
pub const HEADER_BYTES: usize = 24;

/// Trailer (FNV-1a-128 digest) size in bytes.
pub const TRAILER_BYTES: usize = 16;

/// Upper bound on payload elements (2 GiB of f64) and on rank. A frame
/// whose header claims more is rejected before any allocation — a
/// corrupt length field must not become an OOM.
pub const MAX_PAYLOAD_ELEMS: u64 = 1 << 28;
const MAX_NDIM: u8 = 8;

/// Frame opcode. `Put`/`Data` carry a block; the rest are control.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum FrameOp {
    /// Driver → node: store this block.
    Put = 1,
    /// Driver → node: send me this object.
    Get = 2,
    /// Node → driver: the requested block.
    Data = 3,
    /// Node → driver: object not held here.
    NotFound = 4,
    /// Node → driver: `Put` landed.
    Ack = 5,
    /// Heartbeat request.
    Ping = 6,
    /// Heartbeat reply.
    Pong = 7,
    /// Orderly shutdown of the node process.
    Quit = 8,
}

impl FrameOp {
    fn from_u8(v: u8) -> Option<Self> {
        Some(match v {
            1 => FrameOp::Put,
            2 => FrameOp::Get,
            3 => FrameOp::Data,
            4 => FrameOp::NotFound,
            5 => FrameOp::Ack,
            6 => FrameOp::Ping,
            7 => FrameOp::Pong,
            8 => FrameOp::Quit,
            _ => return None,
        })
    }
}

/// One decoded frame. `shape`/`payload` are empty on control frames.
#[derive(Clone, Debug, PartialEq)]
pub struct Frame {
    pub op: FrameOp,
    /// Logical node the frame concerns (diagnostics; the socket already
    /// identifies the peer).
    pub node: u16,
    pub obj: ObjectId,
    pub shape: Vec<usize>,
    pub payload: Vec<f64>,
}

impl Frame {
    /// A payload-less frame (`Get`/`Ack`/`Ping`/…).
    pub fn control(op: FrameOp, node: u16, obj: ObjectId) -> Self {
        Frame { op, node, obj, shape: Vec::new(), payload: Vec::new() }
    }

    /// A block-carrying frame (`Put`/`Data`).
    pub fn data(op: FrameOp, node: u16, obj: ObjectId, shape: &[usize], payload: Vec<f64>) -> Self {
        Frame { op, node, obj, shape: shape.to_vec(), payload }
    }

    /// Payload bytes (the block bytes a transfer accounts).
    pub fn payload_bytes(&self) -> u64 {
        self.payload.len() as u64 * 8
    }
}

/// Typed decode failure. Truncation is an error for one-shot
/// [`decode`]; the incremental [`FrameDecoder`] treats it as
/// "need more bytes" instead.
#[derive(Clone, Debug, PartialEq)]
pub enum FrameError {
    /// Fewer bytes than a complete frame; `needed` is the total frame
    /// size once known (0 while even the header is short).
    Truncated { needed: usize, have: usize },
    /// First four bytes are not [`MAGIC`].
    BadMagic([u8; 4]),
    /// Unknown opcode byte.
    BadOp(u8),
    /// Header claims an implausible payload or rank.
    TooLarge { elems: u64, ndim: u8 },
    /// Checksum trailer mismatch — the bytes arrived, but wrong.
    Corrupt { expect: u128, got: u128 },
    /// Underlying stream error (blocking [`read_frame`] only).
    Io { kind: std::io::ErrorKind, msg: String },
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Truncated { needed, have } => {
                write!(f, "truncated frame: have {have} bytes, need {needed}")
            }
            FrameError::BadMagic(m) => write!(f, "bad frame magic {m:?}"),
            FrameError::BadOp(op) => write!(f, "unknown frame op {op}"),
            FrameError::TooLarge { elems, ndim } => {
                write!(f, "implausible frame header: {elems} elems, ndim {ndim}")
            }
            FrameError::Corrupt { expect, got } => {
                write!(f, "frame checksum mismatch: expect {expect:032x}, got {got:032x}")
            }
            FrameError::Io { kind, msg } => write!(f, "frame I/O ({kind:?}): {msg}"),
        }
    }
}

impl std::error::Error for FrameError {}

impl FrameError {
    /// Read/connect timed out — the transient (retryable) failure class.
    pub fn is_timeout(&self) -> bool {
        matches!(
            self,
            FrameError::Io { kind: std::io::ErrorKind::WouldBlock, .. }
                | FrameError::Io { kind: std::io::ErrorKind::TimedOut, .. }
        )
    }
}

fn digest_of(op: FrameOp, node: u16, obj: ObjectId, shape: &[usize], payload: &[f64]) -> u128 {
    let mut sum = Fnv128::new();
    sum.tag(op as u8);
    sum.u64(node as u64);
    sum.u64(obj);
    sum.usize(shape.len());
    for &d in shape {
        sum.usize(d);
    }
    sum.tag(0x7C); // domain separator: shape | payload
    for &v in payload {
        sum.f64(v);
    }
    sum.digest()
}

/// Encode a frame to its wire bytes.
pub fn encode(f: &Frame) -> Vec<u8> {
    let mut out = Vec::with_capacity(
        HEADER_BYTES + f.shape.len() * 8 + f.payload.len() * 8 + TRAILER_BYTES,
    );
    out.extend_from_slice(&MAGIC);
    out.push(f.op as u8);
    out.push(f.shape.len() as u8);
    out.extend_from_slice(&f.node.to_le_bytes());
    out.extend_from_slice(&f.obj.to_le_bytes());
    out.extend_from_slice(&(f.payload.len() as u64).to_le_bytes());
    for &d in &f.shape {
        out.extend_from_slice(&(d as u64).to_le_bytes());
    }
    for &v in &f.payload {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out.extend_from_slice(&digest_of(f.op, f.node, f.obj, &f.shape, &f.payload).to_le_bytes());
    out
}

fn le_u64(b: &[u8]) -> u64 {
    u64::from_le_bytes(b[..8].try_into().unwrap())
}

/// One-shot decode from a byte buffer. Returns the frame and the number
/// of bytes consumed, or a typed error — [`FrameError::Truncated`] when
/// the buffer ends mid-frame (the incremental decoder's resume signal).
pub fn decode(buf: &[u8]) -> Result<(Frame, usize), FrameError> {
    if buf.len() < HEADER_BYTES {
        // magic/op are validated as soon as their bytes exist, so a
        // garbage prefix fails fast instead of waiting for "more data"
        if buf.len() >= 4 && buf[..4] != MAGIC {
            return Err(FrameError::BadMagic(buf[..4].try_into().unwrap()));
        }
        if buf.len() >= 5 && FrameOp::from_u8(buf[4]).is_none() {
            return Err(FrameError::BadOp(buf[4]));
        }
        return Err(FrameError::Truncated { needed: HEADER_BYTES, have: buf.len() });
    }
    if buf[..4] != MAGIC {
        return Err(FrameError::BadMagic(buf[..4].try_into().unwrap()));
    }
    let op = FrameOp::from_u8(buf[4]).ok_or(FrameError::BadOp(buf[4]))?;
    let ndim = buf[5];
    let node = u16::from_le_bytes([buf[6], buf[7]]);
    let obj = le_u64(&buf[8..16]);
    let elems = le_u64(&buf[16..24]);
    if elems > MAX_PAYLOAD_ELEMS || ndim > MAX_NDIM {
        return Err(FrameError::TooLarge { elems, ndim });
    }
    let total = HEADER_BYTES + ndim as usize * 8 + elems as usize * 8 + TRAILER_BYTES;
    if buf.len() < total {
        return Err(FrameError::Truncated { needed: total, have: buf.len() });
    }
    let mut at = HEADER_BYTES;
    let mut shape = Vec::with_capacity(ndim as usize);
    for _ in 0..ndim {
        shape.push(le_u64(&buf[at..at + 8]) as usize);
        at += 8;
    }
    let mut payload = Vec::with_capacity(elems as usize);
    for _ in 0..elems {
        payload.push(f64::from_le_bytes(buf[at..at + 8].try_into().unwrap()));
        at += 8;
    }
    let got = u128::from_le_bytes(buf[at..at + 16].try_into().unwrap());
    let expect = digest_of(op, node, obj, &shape, &payload);
    if got != expect {
        return Err(FrameError::Corrupt { expect, got });
    }
    Ok((Frame { op, node, obj, shape, payload }, total))
}

/// Incremental decoder: accumulate bytes from any number of partial
/// reads and yield each frame exactly when complete. `Ok(None)` means
/// "feed me more"; errors are the same typed rejections as [`decode`]
/// (and are sticky — a corrupted stream has lost framing, so the
/// connection must be dropped, not resynchronized).
#[derive(Default)]
pub struct FrameDecoder {
    buf: Vec<u8>,
}

impl FrameDecoder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Bytes currently buffered (a partially-received frame).
    pub fn pending(&self) -> usize {
        self.buf.len()
    }

    pub fn feed(&mut self, bytes: &[u8]) -> Result<Option<Frame>, FrameError> {
        self.buf.extend_from_slice(bytes);
        match decode(&self.buf) {
            Ok((frame, used)) => {
                self.buf.drain(..used);
                Ok(Some(frame))
            }
            Err(FrameError::Truncated { .. }) => Ok(None),
            Err(e) => Err(e),
        }
    }
}

fn io_err(e: std::io::Error) -> FrameError {
    FrameError::Io { kind: e.kind(), msg: e.to_string() }
}

/// Write one frame to a blocking stream.
pub fn write_frame(w: &mut impl Write, f: &Frame) -> Result<(), FrameError> {
    w.write_all(&encode(f)).map_err(io_err)?;
    w.flush().map_err(io_err)
}

/// Read one frame from a blocking stream. An EOF mid-frame is
/// [`FrameError::Truncated`]; an EOF before any byte of the frame is
/// `Io{kind: UnexpectedEof}` (a cleanly closed peer, not a torn frame).
pub fn read_frame(r: &mut impl Read) -> Result<Frame, FrameError> {
    let mut header = [0u8; HEADER_BYTES];
    let mut got = 0usize;
    while got < HEADER_BYTES {
        match r.read(&mut header[got..]) {
            Ok(0) if got == 0 => {
                return Err(FrameError::Io {
                    kind: std::io::ErrorKind::UnexpectedEof,
                    msg: "peer closed".into(),
                })
            }
            Ok(0) => return Err(FrameError::Truncated { needed: HEADER_BYTES, have: got }),
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(io_err(e)),
        }
    }
    // header-side validation before trusting the length fields
    if header[..4] != MAGIC {
        return Err(FrameError::BadMagic(header[..4].try_into().unwrap()));
    }
    let op = FrameOp::from_u8(header[4]).ok_or(FrameError::BadOp(header[4]))?;
    let ndim = header[5];
    let elems = le_u64(&header[16..24]);
    if elems > MAX_PAYLOAD_ELEMS || ndim > MAX_NDIM {
        return Err(FrameError::TooLarge { elems, ndim });
    }
    let _ = op; // full parse (incl. checksum) goes through `decode`
    let body = ndim as usize * 8 + elems as usize * 8 + TRAILER_BYTES;
    let mut buf = Vec::with_capacity(HEADER_BYTES + body);
    buf.extend_from_slice(&header);
    buf.resize(HEADER_BYTES + body, 0);
    let mut at = HEADER_BYTES;
    while at < buf.len() {
        match r.read(&mut buf[at..]) {
            Ok(0) => return Err(FrameError::Truncated { needed: buf.len(), have: at }),
            Ok(n) => at += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(io_err(e)),
        }
    }
    decode(&buf).map(|(f, _)| f)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn roundtrip(f: &Frame) {
        let bytes = encode(f);
        let (back, used) = decode(&bytes).expect("decode");
        assert_eq!(used, bytes.len());
        assert_eq!(&back, f);
    }

    #[test]
    fn control_and_data_frames_roundtrip() {
        roundtrip(&Frame::control(FrameOp::Ping, 3, 0));
        roundtrip(&Frame::control(FrameOp::Get, 1, 42));
        roundtrip(&Frame::data(FrameOp::Put, 2, 7, &[2, 3], vec![1.0, -0.0, f64::MIN, 4.5, 5.0, 6.0]));
        roundtrip(&Frame::data(FrameOp::Data, 0, 9, &[0], vec![]));
    }

    #[test]
    fn random_payloads_roundtrip_bit_exactly() {
        let mut rng = Rng::seed_from_u64(0xF3A);
        for case in 0..50u64 {
            let n = (case as usize % 97) + 1;
            let mut v = vec![0.0; n];
            rng.fill_normal(&mut v);
            let f = Frame::data(FrameOp::Data, (case % 7) as u16, case, &[n, 1], v);
            let bytes = encode(&f);
            let (back, _) = decode(&bytes).expect("decode");
            // exact bits, not approximate equality
            for (a, b) in f.payload.iter().zip(&back.payload) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
            assert_eq!(back.shape, f.shape);
        }
    }

    #[test]
    fn truncation_is_a_typed_error_at_every_cut() {
        let bytes = encode(&Frame::data(FrameOp::Put, 1, 5, &[2, 2], vec![1.0, 2.0, 3.0, 4.0]));
        for cut in 0..bytes.len() {
            match decode(&bytes[..cut]) {
                Err(FrameError::Truncated { needed, have }) => {
                    assert_eq!(have, cut);
                    assert!(needed > cut);
                }
                other => panic!("cut {cut}: expected Truncated, got {other:?}"),
            }
        }
    }

    #[test]
    fn corrupt_trailer_and_corrupt_payload_are_rejected() {
        let f = Frame::data(FrameOp::Data, 0, 1, &[3], vec![1.0, 2.0, 3.0]);
        let clean = encode(&f);
        // flip one bit everywhere after the length-bearing header: every
        // such corruption must surface as Corrupt (never silent data)
        for at in [HEADER_BYTES, HEADER_BYTES + 8, clean.len() - 1, clean.len() - 16] {
            let mut bad = clean.clone();
            bad[at] ^= 0x40;
            match decode(&bad) {
                Err(FrameError::Corrupt { expect, got }) => assert_ne!(expect, got),
                other => panic!("byte {at}: expected Corrupt, got {other:?}"),
            }
        }
    }

    #[test]
    fn bad_magic_bad_op_and_too_large_are_typed() {
        let mut bytes = encode(&Frame::control(FrameOp::Ping, 0, 0));
        bytes[0] = b'X';
        assert!(matches!(decode(&bytes), Err(FrameError::BadMagic(_))));
        // garbage prefix fails fast even before a full header arrives
        assert!(matches!(decode(b"XYZW"), Err(FrameError::BadMagic(_))));

        let mut bytes = encode(&Frame::control(FrameOp::Ping, 0, 0));
        bytes[4] = 0xEE;
        assert!(matches!(decode(&bytes), Err(FrameError::BadOp(0xEE))));

        let mut bytes = encode(&Frame::control(FrameOp::Ping, 0, 0));
        bytes[16..24].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(matches!(decode(&bytes), Err(FrameError::TooLarge { .. })));
    }

    #[test]
    fn incremental_decoder_resumes_across_partial_reads() {
        let frames = vec![
            Frame::control(FrameOp::Ping, 0, 0),
            Frame::data(FrameOp::Put, 1, 8, &[2, 2], vec![9.0, 8.0, 7.0, 6.0]),
            Frame::control(FrameOp::Ack, 1, 8),
        ];
        let mut wire = Vec::new();
        for f in &frames {
            wire.extend_from_slice(&encode(f));
        }
        // feed one byte at a time: frames pop out exactly at boundaries
        let mut dec = FrameDecoder::new();
        let mut out = Vec::new();
        for &b in &wire {
            if let Some(f) = dec.feed(&[b]).expect("clean stream") {
                out.push(f);
            }
        }
        assert_eq!(out, frames);
        assert_eq!(dec.pending(), 0);

        // and in arbitrary chunk sizes
        let mut dec = FrameDecoder::new();
        let mut out = Vec::new();
        for chunk in wire.chunks(13) {
            let mut fed = dec.feed(chunk).expect("clean stream");
            while let Some(f) = fed {
                out.push(f);
                fed = dec.feed(&[]).expect("clean stream");
            }
        }
        assert_eq!(out, frames);
    }

    #[test]
    fn blocking_reader_roundtrips_and_types_eof() {
        let f = Frame::data(FrameOp::Data, 2, 11, &[2], vec![1.5, -2.5]);
        let bytes = encode(&f);
        let mut cur = std::io::Cursor::new(bytes.clone());
        assert_eq!(read_frame(&mut cur).unwrap(), f);
        // clean EOF at a frame boundary
        match read_frame(&mut cur) {
            Err(FrameError::Io { kind, .. }) => {
                assert_eq!(kind, std::io::ErrorKind::UnexpectedEof)
            }
            other => panic!("expected clean-EOF Io, got {other:?}"),
        }
        // EOF mid-frame is a torn frame
        let mut cur = std::io::Cursor::new(bytes[..bytes.len() - 3].to_vec());
        assert!(matches!(read_frame(&mut cur), Err(FrameError::Truncated { .. })));
    }
}
