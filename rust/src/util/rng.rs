//! Deterministic PRNG substrate (the `rand` crate is unavailable offline).
//!
//! `SplitMix64` seeds `Xoshiro256StarStar`, the same construction the
//! reference `rand`/`xoshiro` crates use. Every stochastic component of the
//! system (LSHS frontier sampling, synthetic data, baselines, property
//! tests) draws from an explicitly seeded instance so all experiments are
//! reproducible; benches print their seeds.

/// SplitMix64: used to expand a single `u64` seed into a full state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256** — fast, high-quality, 256-bit state general-purpose PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)`. Uses rejection to avoid modulo bias.
    #[inline]
    pub fn usize(&mut self, n: usize) -> usize {
        assert!(n > 0, "usize(0) is empty");
        let n64 = n as u64;
        let zone = u64::MAX - (u64::MAX % n64);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n64) as usize;
            }
        }
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box–Muller (cached second value not kept: keeps
    /// the generator state trivially clonable/serializable).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    pub fn bool(&mut self, p_true: f64) -> bool {
        self.f64() < p_true
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize(xs.len())]
    }

    /// Fill a buffer with standard normals.
    pub fn fill_normal(&mut self, out: &mut [f64]) {
        for v in out.iter_mut() {
            *v = self.normal();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seed_from_u64(1);
        let mut b = Rng::seed_from_u64(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seed_from_u64(7);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v), "{v}");
        }
    }

    #[test]
    fn usize_bounds_and_coverage() {
        let mut r = Rng::seed_from_u64(9);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.usize(7);
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(11);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.normal();
            sum += v;
            sq += v * v;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(3);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }
}
