//! Minimal JSON reader — just enough to validate the trace exporter's
//! output and the bench JSON in tests. The offline image vendors no
//! serde; this is a strict recursive-descent parser over the full JSON
//! grammar (objects, arrays, strings with escapes, numbers, literals)
//! with a depth limit. It is a *validator-reader*: no serialization,
//! no zero-copy tricks, no spans.

use std::collections::BTreeMap;

/// Parsed JSON value. Object keys are sorted (BTreeMap) — fine for
/// lookup-and-assert use; insertion order is not preserved.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    /// Object member lookup (`None` on non-objects or missing keys).
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Nesting deeper than this is rejected (stack safety on adversarial
/// input; real trace/bench files nest 3–4 levels).
const MAX_DEPTH: usize = 64;

/// Parse a complete JSON document. Trailing non-whitespace is an error.
pub fn parse(s: &str) -> Result<Value, String> {
    let b = s.as_bytes();
    let mut p = Parser { b, i: 0 };
    p.skip_ws();
    let v = p.value(0)?;
    p.skip_ws();
    if p.i != b.len() {
        return Err(format!("trailing bytes at offset {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while let Some(&c) = self.b.get(self.i) {
            if c == b' ' || c == b'\t' || c == b'\n' || c == b'\r' {
                self.i += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at offset {}, found {:?}",
                c as char,
                self.i,
                self.peek().map(|x| x as char)
            ))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Value, String> {
        if depth > MAX_DEPTH {
            return Err("nesting too deep".into());
        }
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(depth),
            Some(b'[') => self.array(depth),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!(
                "unexpected {:?} at offset {}",
                other.map(|x| x as char),
                self.i
            )),
        }
    }

    fn literal(&mut self, lit: &str, v: Value) -> Result<Value, String> {
        if self.b[self.i..].starts_with(lit.as_bytes()) {
            self.i += lit.len();
            Ok(v)
        } else {
            Err(format!("bad literal at offset {}", self.i))
        }
    }

    fn object(&mut self, depth: usize) -> Result<Value, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value(depth + 1)?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at offset {}", self.i)),
            }
        }
    }

    fn array(&mut self, depth: usize) -> Result<Value, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(v));
        }
        loop {
            v.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at offset {}", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let cp = self.hex4()?;
                            // surrogate pair handling: a high surrogate
                            // must be followed by \uDC00..\uDFFF.
                            if (0xD800..0xDC00).contains(&cp) {
                                self.i += 1; // step past the high escape's last digit
                                if self.peek() != Some(b'\\') {
                                    return Err("lone high surrogate".into());
                                }
                                self.i += 1;
                                if self.peek() != Some(b'u') {
                                    return Err("lone high surrogate".into());
                                }
                                let lo = self.hex4()?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    return Err("bad low surrogate".into());
                                }
                                let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                out.push(
                                    char::from_u32(c).ok_or("bad surrogate pair")?,
                                );
                                self.i += 1; // step past the low escape's last digit
                                continue;
                            } else if (0xDC00..0xE000).contains(&cp) {
                                return Err("lone low surrogate".into());
                            }
                            out.push(char::from_u32(cp).ok_or("bad \\u escape")?);
                            self.i += 1;
                            continue;
                        }
                        other => {
                            return Err(format!(
                                "bad escape {:?} at offset {}",
                                other.map(|x| x as char),
                                self.i
                            ))
                        }
                    }
                    self.i += 1;
                }
                Some(c) if c < 0x20 => {
                    return Err(format!("raw control byte in string at {}", self.i))
                }
                Some(_) => {
                    // consume one UTF-8 scalar (input is &str, so valid)
                    let s = &self.b[self.i..];
                    let ch_len = match s[0] {
                        c if c < 0x80 => 1,
                        c if c >= 0xF0 => 4,
                        c if c >= 0xE0 => 3,
                        _ => 2,
                    };
                    out.push_str(std::str::from_utf8(&s[..ch_len]).map_err(|e| e.to_string())?);
                    self.i += ch_len;
                }
            }
        }
    }

    /// Reads the 4 hex digits after a `\u`, leaving `i` on the last
    /// digit (caller advances).
    fn hex4(&mut self) -> Result<u32, String> {
        // self.i is on 'u'
        let start = self.i + 1;
        let end = start + 4;
        if end > self.b.len() {
            return Err("truncated \\u escape".into());
        }
        let mut v = 0u32;
        for &c in &self.b[start..end] {
            v = v * 16
                + match c {
                    b'0'..=b'9' => (c - b'0') as u32,
                    b'a'..=b'f' => (c - b'a' + 10) as u32,
                    b'A'..=b'F' => (c - b'A' + 10) as u32,
                    _ => return Err(format!("bad hex digit at {}", self.i)),
                };
        }
        self.i = end - 1;
        Ok(v)
    }

    fn number(&mut self) -> Result<Value, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        let digits = |p: &mut Self| {
            let s = p.i;
            while matches!(p.peek(), Some(c) if c.is_ascii_digit()) {
                p.i += 1;
            }
            p.i > s
        };
        if !digits(self) {
            return Err(format!("bad number at offset {start}"));
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            if !digits(self) {
                return Err(format!("bad fraction at offset {start}"));
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            if !digits(self) {
                return Err(format!("bad exponent at offset {start}"));
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).map_err(|e| e.to_string())?;
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|e| format!("{e} at offset {start}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse(" true ").unwrap(), Value::Bool(true));
        assert_eq!(parse("false").unwrap(), Value::Bool(false));
        assert_eq!(parse("-12.5e2").unwrap(), Value::Num(-1250.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parses_nested_structures() {
        let v = parse(r#"{"a":[1,2,{"b":null}],"c":{"d":"e"}}"#).unwrap();
        let arr = v.get("a").and_then(|a| a.as_arr()).unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[2].get("b"), Some(&Value::Null));
        assert_eq!(v.get("c").and_then(|c| c.get("d")).and_then(|d| d.as_str()), Some("e"));
    }

    #[test]
    fn string_escapes_round_trip() {
        let v = parse(r#""q\"b\\n\n\tAé""#).unwrap();
        assert_eq!(v.as_str(), Some("q\"b\\n\n\tA\u{e9}"));
    }

    #[test]
    fn surrogate_pairs_decode() {
        let v = parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("\u{1F600}"));
        assert!(parse(r#""\ud83d""#).is_err(), "lone high surrogate rejected");
        assert!(parse(r#""\ude00""#).is_err(), "lone low surrogate rejected");
    }

    #[test]
    fn rejects_malformed_input() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("1 2").is_err(), "trailing bytes rejected");
        assert!(parse("01").is_err() || parse("01").is_ok()); // leading zeros tolerated either way
        assert!(parse("nul").is_err());
        let deep = "[".repeat(100) + &"]".repeat(100);
        assert!(parse(&deep).is_err(), "depth limit enforced");
    }

    #[test]
    fn utf8_passthrough() {
        let v = parse("\"héllo — ✓\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo — ✓"));
    }
}
