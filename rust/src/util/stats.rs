//! Small statistics helpers for benches and reports.

/// Summary statistics over a sample of measurements.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
}

impl Summary {
    pub fn from_samples(samples: &[f64]) -> Self {
        assert!(!samples.is_empty(), "empty sample");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Self {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile_sorted(&sorted, 0.50),
            p95: percentile_sorted(&sorted, 0.95),
        }
    }

    /// The paper's protocol (§8): drop best and worst trial, average the rest.
    pub fn paper_mean(samples: &[f64]) -> f64 {
        if samples.len() <= 2 {
            return samples.iter().sum::<f64>() / samples.len() as f64;
        }
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let trimmed = &sorted[1..sorted.len() - 1];
        trimmed.iter().sum::<f64>() / trimmed.len() as f64
    }
}

pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (pos - lo as f64) * (sorted[hi] - sorted[lo])
    }
}

/// Numerically-stable softmax (shift by max).
pub fn softmax(xs: &[f64]) -> Vec<f64> {
    let m = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let exps: Vec<f64> = xs.iter().map(|x| (x - m).exp()).collect();
    let z: f64 = exps.iter().sum();
    exps.iter().map(|e| e / z).collect()
}

/// Relative error |a-b| / max(|b|, eps).
pub fn rel_err(a: f64, b: f64) -> f64 {
    (a - b).abs() / b.abs().max(1e-300)
}

pub fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f64::max)
}

pub fn max_rel_diff(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs() / y.abs().max(1e-12))
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::from_samples(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn paper_mean_trims_extremes() {
        // 12 trials, best and worst dropped (paper §8 protocol)
        let mut xs = vec![10.0; 10];
        xs.push(1000.0); // cold start
        xs.push(0.1);
        assert!((Summary::paper_mean(&xs) - 10.0).abs() < 1e-12);
    }

    #[test]
    fn softmax_sums_to_one_and_orders() {
        let s = softmax(&[1.0, 2.0, 3.0]);
        assert!((s.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(s[0] < s[1] && s[1] < s[2]);
    }

    #[test]
    fn softmax_handles_huge_inputs() {
        // raw exp would overflow; shifted softmax must not.
        let s = softmax(&[31_250_000.0, 256.0]);
        assert!((s[0] - 1.0).abs() < 1e-12);
        assert!(s[1] < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert!((percentile_sorted(&sorted, 0.5) - 5.0).abs() < 1e-12);
    }
}
