//! Minimal command-line argument parser (the `clap` crate is unavailable
//! offline). Supports `--flag`, `--key value`, `--key=value` and positional
//! arguments, with typed getters and defaults.

use std::collections::HashMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    flags: HashMap<String, String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Self {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(body.to_string(), v);
                } else {
                    out.flags.insert(body.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects a float, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        match self.get(key) {
            None => default,
            Some("true") | Some("1") | Some("yes") => true,
            Some("false") | Some("0") | Some("no") => false,
            Some(v) => panic!("--{key} expects a bool, got {v:?}"),
        }
    }

    /// Parse `--key a,b,c` into a vec.
    pub fn list_or(&self, key: &str, default: &[&str]) -> Vec<String> {
        match self.get(key) {
            Some(v) => v.split(',').map(|s| s.trim().to_string()).collect(),
            None => default.iter().map(|s| s.to_string()).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn positional_and_flags() {
        let a = parse(&["bench", "--nodes", "16", "--verbose", "--mode=sim"]);
        assert_eq!(a.positional, vec!["bench"]);
        assert_eq!(a.usize_or("nodes", 1), 16);
        assert!(a.bool_or("verbose", false));
        assert_eq!(a.str_or("mode", "real"), "sim");
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.usize_or("nodes", 4), 4);
        assert_eq!(a.f64_or("gamma", 0.5), 0.5);
        assert!(!a.has("x"));
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = parse(&["--fast"]);
        assert!(a.bool_or("fast", false));
    }

    #[test]
    fn list_parsing() {
        let a = parse(&["--figs", "9,10, 11"]);
        assert_eq!(a.list_or("figs", &[]), vec!["9", "10", "11"]);
    }
}
