//! Plain-text table rendering for bench output (paper-style rows).

/// Render a table with a header row; columns are right-aligned except the
/// first (label) column.
pub fn render_table(header: &[&str], rows: &[Vec<String>]) -> String {
    let ncol = header.len();
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        assert_eq!(row.len(), ncol, "row arity mismatch");
        for (i, cell) in row.iter().enumerate() {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: Vec<String>, widths: &[usize]| -> String {
        let mut line = String::new();
        for (i, cell) in cells.iter().enumerate() {
            if i == 0 {
                line.push_str(&format!("{:<w$}", cell, w = widths[i]));
            } else {
                line.push_str(&format!("  {:>w$}", cell, w = widths[i]));
            }
        }
        line.push('\n');
        line
    };
    out.push_str(&fmt_row(
        header.iter().map(|s| s.to_string()).collect(),
        &widths,
    ));
    let total: usize = widths.iter().sum::<usize>() + 2 * (ncol - 1);
    out.push_str(&"-".repeat(total));
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row.clone(), &widths));
    }
    out
}

/// Humanize a duration in seconds: "1.23 ms", "4.56 s", ...
pub fn human_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.2} s", s)
    }
}

/// Humanize a byte count.
pub fn human_bytes(b: f64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut v = b;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{v:.0} {}", UNITS[u])
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_aligns() {
        let t = render_table(
            &["name", "time"],
            &[
                vec!["a".into(), "1.0".into()],
                vec!["longer".into(), "22.5".into()],
            ],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("name"));
        assert!(lines[3].starts_with("longer"));
    }

    #[test]
    fn humanize() {
        assert_eq!(human_secs(0.5), "500.00 ms");
        assert_eq!(human_secs(2.0), "2.00 s");
        assert_eq!(human_bytes(1536.0), "1.50 KiB");
        assert_eq!(human_bytes(3.0), "3 B");
    }
}
