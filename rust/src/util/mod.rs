//! Zero-dependency utility substrate: PRNG, CLI parsing, statistics,
//! property testing, table formatting. These replace `rand`, `clap`,
//! `criterion`'s stats and `proptest`, none of which are available in the
//! offline build image (see README.md, "Offline build").

pub mod cli;
pub mod fmt;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;

/// Wall-clock stopwatch used by benches and the real executor.
pub struct Stopwatch(std::time::Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Self(std::time::Instant::now())
    }

    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::start()
    }
}
