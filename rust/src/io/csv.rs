//! CSV substrate (§8.6, Table 3): a serial reader (the Pandas stand-in)
//! and a parallel byte-range reader (NumS's `read_csv`).
//!
//! The parallel reader splits the file into byte ranges aligned to line
//! boundaries, parses each range on a worker task, and scatters the
//! resulting row blocks with the session's data layout — eliminating the
//! serial parse that dominates the Python stack's load time.

use std::io::{BufRead, BufReader, Read, Seek, SeekFrom, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::api::Session;
use crate::graph::DistArray;
use crate::grid::ArrayGrid;
use crate::store::Block;

/// Write a numeric matrix as CSV (no header).
pub fn write_csv(block: &Block, path: impl AsRef<Path>) -> Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path.as_ref())?);
    let (m, n) = (block.rows(), block.cols());
    for i in 0..m {
        let mut line = String::with_capacity(n * 12);
        for j in 0..n {
            if j > 0 {
                line.push(',');
            }
            line.push_str(&format!("{}", block.at2(i, j)));
        }
        line.push('\n');
        f.write_all(line.as_bytes())?;
    }
    Ok(())
}

/// Serial CSV reader (the Pandas `read_csv` baseline).
pub fn read_csv_serial(path: impl AsRef<Path>) -> Result<Block> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("open {:?}", path.as_ref()))?;
    let reader = BufReader::new(f);
    let mut data: Vec<f64> = Vec::new();
    let mut cols = 0usize;
    let mut rows = 0usize;
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let mut c = 0;
        for tok in line.split(',') {
            data.push(tok.trim().parse::<f64>().with_context(|| format!("parse {tok:?}"))?);
            c += 1;
        }
        if rows == 0 {
            cols = c;
        } else if c != cols {
            bail!("ragged CSV: row {rows} has {c} fields, want {cols}");
        }
        rows += 1;
    }
    if rows == 0 {
        bail!("empty CSV");
    }
    Ok(Block::from_vec(&[rows, cols], data))
}

/// Parse one byte range (already line-aligned) into rows.
fn parse_range(bytes: &[u8]) -> Result<(Vec<f64>, usize, usize)> {
    let text = std::str::from_utf8(bytes).context("CSV is not UTF-8")?;
    let mut data = Vec::new();
    let mut rows = 0;
    let mut cols = 0;
    for line in text.lines() {
        if line.trim().is_empty() {
            continue;
        }
        let mut c = 0;
        for tok in line.split(',') {
            data.push(tok.trim().parse::<f64>()?);
            c += 1;
        }
        if rows == 0 {
            cols = c;
        } else if c != cols {
            bail!("ragged CSV inside range");
        }
        rows += 1;
    }
    Ok((data, rows, cols))
}

/// Split `[0, len)` into `parts` ranges aligned to `\n` boundaries.
pub fn line_aligned_ranges(path: impl AsRef<Path>, parts: usize) -> Result<Vec<(u64, u64)>> {
    let mut f = std::fs::File::open(path.as_ref())?;
    let len = f.metadata()?.len();
    if len == 0 {
        bail!("empty file");
    }
    let mut cuts = vec![0u64];
    for p in 1..parts {
        let guess = len * p as u64 / parts as u64;
        f.seek(SeekFrom::Start(guess))?;
        let mut buf = Vec::new();
        let mut byte = [0u8; 1];
        let mut pos = guess;
        // scan to the next newline
        loop {
            let n = f.read(&mut byte)?;
            if n == 0 {
                break;
            }
            pos += 1;
            if byte[0] == b'\n' {
                break;
            }
            buf.push(byte[0]);
        }
        if pos < len && pos > *cuts.last().unwrap() {
            cuts.push(pos);
        }
    }
    cuts.push(len);
    cuts.dedup();
    Ok(cuts.windows(2).map(|w| (w[0], w[1])).collect())
}

/// Serial read + hand-off: parse the whole file on the driver (the
/// Pandas-style path) and adopt the result as a single-block array
/// resident on `target`, without re-partitioning. Returns the array plus
/// (rows, cols). Use [`read_csv_parallel`] when the data should land
/// partitioned across the cluster.
pub fn read_csv_adopt(
    sess: &mut Session,
    path: impl AsRef<Path>,
    target: usize,
) -> Result<(DistArray, usize, usize)> {
    let dense = read_csv_serial(path)?;
    let (rows, cols) = (dense.rows(), dense.cols());
    let arr = sess.adopt_block(dense, target);
    Ok((arr, rows, cols))
}

/// Parallel CSV reader: one parse task per byte range, scattered into a
/// row-partitioned [`DistArray`] using the session's layout. Returns the
/// array plus (rows, cols).
pub fn read_csv_parallel(
    sess: &mut Session,
    path: impl AsRef<Path>,
    parts: usize,
) -> Result<(DistArray, usize, usize)> {
    let path = path.as_ref();
    let ranges = line_aligned_ranges(path, parts)?;
    // parse ranges on threads (the "worker tasks")
    let parsed: Vec<(Vec<f64>, usize, usize)> = std::thread::scope(|scope| {
        let handles: Vec<_> = ranges
            .iter()
            .map(|&(a, b)| {
                scope.spawn(move || -> Result<(Vec<f64>, usize, usize)> {
                    let mut f = std::fs::File::open(path)?;
                    f.seek(SeekFrom::Start(a))?;
                    let mut buf = vec![0u8; (b - a) as usize];
                    f.read_exact(&mut buf)?;
                    parse_range(&buf)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect::<Result<Vec<_>>>()
    })?;

    let cols = parsed
        .iter()
        .find(|p| p.1 > 0)
        .map(|p| p.2)
        .context("no rows parsed")?;
    let total_rows: usize = parsed.iter().map(|p| p.1).sum();

    // Assemble in range order, then scatter with the near-even grid the
    // session would use for this shape. (Block boundaries need not match
    // byte-range boundaries.)
    let mut all = Vec::with_capacity(total_rows * cols);
    for (data, r, c) in &parsed {
        if *r > 0 {
            assert_eq!(*c, cols);
            all.extend_from_slice(data);
        }
    }
    let dense = Block::from_vec(&[total_rows, cols], all);
    let q = parts.min(total_rows).max(1);
    let arr = sess.scatter2(&dense, &[q, 1]);
    let _ = ArrayGrid::new(&[total_rows, cols], &[q, 1]);
    Ok((arr, total_rows, cols))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::SessionConfig;
    use crate::util::rng::Rng;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("nums_csv_{}_{name}", std::process::id()))
    }

    fn random_block(m: usize, n: usize, seed: u64) -> Block {
        let mut rng = Rng::seed_from_u64(seed);
        let mut v = vec![0.0; m * n];
        rng.fill_normal(&mut v);
        Block::from_vec(&[m, n], v)
    }

    #[test]
    fn roundtrip_serial() {
        let b = random_block(37, 5, 1);
        let p = tmp("rt");
        write_csv(&b, &p).unwrap();
        let back = read_csv_serial(&p).unwrap();
        assert_eq!(back.shape, b.shape);
        assert!(back.max_abs_diff(&b) < 1e-12);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn parallel_matches_serial() {
        let b = random_block(101, 4, 2);
        let p = tmp("par");
        write_csv(&b, &p).unwrap();
        let serial = read_csv_serial(&p).unwrap();
        let mut sess = Session::new(SessionConfig::real_small(2, 2));
        let (arr, rows, cols) = read_csv_parallel(&mut sess, &p, 7).unwrap();
        assert_eq!((rows, cols), (101, 4));
        let dense = sess.fetch(&arr).unwrap();
        assert!(dense.max_abs_diff(&serial) < 1e-12);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn adopt_reader_matches_serial() {
        let b = random_block(23, 6, 5);
        let p = tmp("adopt");
        write_csv(&b, &p).unwrap();
        let mut sess = Session::new(SessionConfig::real_small(2, 2));
        let (arr, rows, cols) = read_csv_adopt(&mut sess, &p, 1).unwrap();
        assert_eq!((rows, cols), (23, 6));
        assert_eq!(arr.shape(), vec![23, 6]);
        assert_eq!(arr.num_blocks(), 1);
        let dense = sess.fetch(&arr).unwrap();
        assert!(dense.max_abs_diff(&b) < 1e-12);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn ranges_cover_file_exactly() {
        let b = random_block(50, 3, 3);
        let p = tmp("ranges");
        write_csv(&b, &p).unwrap();
        let len = std::fs::metadata(&p).unwrap().len();
        for parts in [1, 2, 3, 8, 64] {
            let rs = line_aligned_ranges(&p, parts).unwrap();
            assert_eq!(rs[0].0, 0);
            assert_eq!(rs.last().unwrap().1, len);
            for w in rs.windows(2) {
                assert_eq!(w[0].1, w[1].0, "gap between ranges");
            }
        }
        std::fs::remove_file(p).ok();
    }
}
