//! Synthetic HIGGS-like dataset (§8.6): 28 features + binary label, CSV.
//!
//! The real HIGGS dataset (7.5 GB, 11M rows) is not available offline; this
//! generator reproduces its schema (label column first, 28 continuous
//! features) with the bimodal class structure of §8.5 so that the
//! Table 3 / Fig. 16 pipelines (load CSV → train → predict) exercise the
//! identical code paths at a configurable scale.

use std::path::Path;

use anyhow::Result;

use crate::glm::data::{feature, row_class};
use crate::store::Block;

pub const HIGGS_FEATURES: usize = 28;

/// Generate `rows` rows of HIGGS-like CSV: `label,f1,...,f28`.
pub fn generate_csv(path: impl AsRef<Path>, rows: usize, seed: u64) -> Result<()> {
    use std::io::Write;
    let mut f = std::io::BufWriter::new(std::fs::File::create(path.as_ref())?);
    for r in 0..rows {
        let label = if row_class(seed, r) { 1.0 } else { 0.0 };
        let mut line = String::with_capacity(HIGGS_FEATURES * 12 + 4);
        line.push_str(&format!("{label}"));
        for c in 0..HIGGS_FEATURES {
            line.push_str(&format!(",{:.6}", feature(seed, r, c)));
        }
        line.push('\n');
        f.write_all(line.as_bytes())?;
    }
    Ok(())
}

/// Split a loaded HIGGS matrix (label first) into (X, y) dense blocks.
pub fn split_label(data: &Block) -> (Block, Block) {
    let (m, n) = (data.rows(), data.cols());
    assert!(n >= 2);
    let mut x = Vec::with_capacity(m * (n - 1));
    let mut y = Vec::with_capacity(m);
    for i in 0..m {
        y.push(data.at2(i, 0));
        for j in 1..n {
            x.push(data.at2(i, j));
        }
    }
    (
        Block::from_vec(&[m, n - 1], x),
        Block::from_vec(&[m, 1], y),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::csv::read_csv_serial;

    #[test]
    fn generates_parseable_csv_with_labels() {
        let p = std::env::temp_dir().join(format!("nums_higgs_{}", std::process::id()));
        generate_csv(&p, 200, 5).unwrap();
        let data = read_csv_serial(&p).unwrap();
        assert_eq!(data.shape, vec![200, HIGGS_FEATURES + 1]);
        let (x, y) = split_label(&data);
        assert_eq!(x.shape, vec![200, HIGGS_FEATURES]);
        assert!(y.buf().iter().all(|&v| v == 0.0 || v == 1.0));
        let pos = y.buf().iter().filter(|&&v| v == 1.0).count();
        assert!(pos > 20 && pos < 90, "class balance off: {pos}/200");
        std::fs::remove_file(p).ok();
    }
}
