//! I/O substrate: CSV readers (serial and parallel) and the synthetic
//! HIGGS generator (§8.6).

pub mod csv;
pub mod higgs;
