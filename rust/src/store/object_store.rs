//! Per-node shared-memory object stores (§3) and the cluster-wide set.
//!
//! In Ray every worker on a node reads task outputs from the node's
//! shared-memory store without copies; our real executor reproduces that
//! with one store per simulated node holding `Arc<Block>`s. Transfers
//! between nodes clone the Arc into the destination store and account the
//! bytes — the byte counters are what the Fig. 15 ablation reports.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::block::Block;
use crate::net::transport::{
    link_backoff, InProcessTransport, Transport, TransportError, MAX_LINK_RETRIES,
};

pub type ObjectId = u64;

#[derive(Default)]
pub struct ObjectStore {
    objects: HashMap<ObjectId, Arc<Block>>,
    /// Resident bytes now.
    pub bytes: u64,
    /// High-water mark (the paper's "memory load" per node).
    pub peak_bytes: u64,
    /// Cumulative bytes received from other nodes.
    pub net_in_bytes: u64,
    /// Cumulative bytes sent to other nodes.
    pub net_out_bytes: u64,
}

impl ObjectStore {
    pub fn put(&mut self, id: ObjectId, block: Arc<Block>) {
        let sz = block.bytes();
        // a re-put replaces the old block: swap its size out of the
        // resident count instead of silently keeping the stale figure
        match self.objects.insert(id, block) {
            Some(old) => self.bytes = self.bytes - old.bytes() + sz,
            None => self.bytes += sz,
        }
        self.peak_bytes = self.peak_bytes.max(self.bytes);
    }

    pub fn get(&self, id: ObjectId) -> Option<Arc<Block>> {
        self.objects.get(&id).cloned()
    }

    pub fn contains(&self, id: ObjectId) -> bool {
        self.objects.contains_key(&id)
    }

    pub fn remove(&mut self, id: ObjectId) -> Option<Arc<Block>> {
        let removed = self.objects.remove(&id);
        if let Some(b) = &removed {
            self.bytes -= b.bytes();
        }
        removed
    }

    pub fn len(&self) -> usize {
        self.objects.len()
    }

    pub fn is_empty(&self) -> bool {
        self.objects.is_empty()
    }

    /// Every object id resident in this store (unordered).
    pub fn ids(&self) -> Vec<ObjectId> {
        self.objects.keys().copied().collect()
    }
}

/// All node stores of a simulated cluster. Thread-safe: the real executor
/// runs node queues concurrently.
///
/// Cross-node byte movement goes through the pluggable [`Transport`]
/// (in-process Arc clone by default; shm files or loopback-TCP node
/// processes otherwise). The accounting stays here regardless of
/// carrier, which is what keeps `prefetch + demand == net_in` an
/// invariant of the *seam* rather than of any one transport.
pub struct StoreSet {
    stores: Vec<Mutex<ObjectStore>>,
    transport: Arc<dyn Transport>,
    /// Per-node "the carrier's endpoint for this node is gone" flags,
    /// set when a carry fails non-transiently (or retries exhaust).
    peer_dead: Vec<AtomicBool>,
    /// Claimed by the executor's reaper so each death is converted into
    /// node-loss recovery exactly once.
    peer_reaped: Vec<AtomicBool>,
    /// Fast guard: the hot transfer path checks one atomic, not N.
    any_dead: AtomicBool,
    /// Transient link retries spent (folded into `RecoveryStats`).
    transport_retries: AtomicU64,
}

impl StoreSet {
    pub fn new(num_nodes: usize) -> Self {
        Self::with_transport(num_nodes, Arc::new(InProcessTransport::new()))
    }

    pub fn with_transport(num_nodes: usize, transport: Arc<dyn Transport>) -> Self {
        Self {
            stores: (0..num_nodes).map(|_| Mutex::new(ObjectStore::default())).collect(),
            transport,
            peer_dead: (0..num_nodes).map(|_| AtomicBool::new(false)).collect(),
            peer_reaped: (0..num_nodes).map(|_| AtomicBool::new(false)).collect(),
            any_dead: AtomicBool::new(false),
            transport_retries: AtomicU64::new(0),
        }
    }

    /// The carrier under the transfer seam.
    pub fn transport(&self) -> &Arc<dyn Transport> {
        &self.transport
    }

    /// Has `node`'s carrier endpoint died (killed TCP peer)?
    pub fn peer_dead(&self, node: usize) -> bool {
        self.any_dead.load(Ordering::Acquire) && self.peer_dead[node].load(Ordering::Acquire)
    }

    /// Record that `node`'s carrier endpoint is gone. The executor's
    /// reaper picks this up via [`StoreSet::take_dead_peer`] and runs
    /// node-loss recovery.
    pub fn mark_peer_dead(&self, node: usize) {
        self.peer_dead[node].store(true, Ordering::Release);
        self.any_dead.store(true, Ordering::Release);
    }

    /// Claim one not-yet-reaped dead peer (exactly-once per death), or
    /// `None`. Cheap when nothing has died.
    pub fn take_dead_peer(&self) -> Option<usize> {
        if !self.any_dead.load(Ordering::Acquire) {
            return None;
        }
        (0..self.stores.len()).find(|&n| {
            self.peer_dead[n].load(Ordering::Acquire)
                && self.peer_reaped[n]
                    .compare_exchange(false, true, Ordering::AcqRel, Ordering::Acquire)
                    .is_ok()
        })
    }

    /// All peers currently flagged dead (reaped or not).
    pub fn dead_peers(&self) -> Vec<usize> {
        if !self.any_dead.load(Ordering::Acquire) {
            return Vec::new();
        }
        (0..self.stores.len()).filter(|&n| self.peer_dead[n].load(Ordering::Acquire)).collect()
    }

    /// Transient link retries spent so far (monotonic).
    pub fn transport_retries(&self) -> u64 {
        self.transport_retries.load(Ordering::Relaxed)
    }

    pub fn num_nodes(&self) -> usize {
        self.stores.len()
    }

    pub fn put(&self, node: usize, id: ObjectId, block: Arc<Block>) {
        self.stores[node].lock().unwrap().put(id, block);
    }

    pub fn get(&self, node: usize, id: ObjectId) -> Option<Arc<Block>> {
        self.stores[node].lock().unwrap().get(id)
    }

    pub fn contains(&self, node: usize, id: ObjectId) -> bool {
        self.stores[node].lock().unwrap().contains(id)
    }

    /// Resident bytes on one node right now.
    pub fn node_bytes(&self, node: usize) -> u64 {
        self.stores[node].lock().unwrap().bytes
    }

    /// Drop an object from one node's store (eviction/spill bookkeeping
    /// is the memory manager's job; this just removes the copy).
    pub fn remove(&self, node: usize, id: ObjectId) -> Option<Arc<Block>> {
        self.stores[node].lock().unwrap().remove(id)
    }

    /// Locate any node holding `id` (preferring `hint` first).
    pub fn locate(&self, id: ObjectId, hint: usize) -> Option<usize> {
        if self.contains(hint, id) {
            return Some(hint);
        }
        (0..self.stores.len()).find(|&n| n != hint && self.contains(n, id))
    }

    /// Transfer `id` from `src` to `dst`, accounting bytes on both NICs.
    /// No-op (and no accounting) if already resident at `dst`. The
    /// residency check happens under the destination lock, so two workers
    /// racing to pull the same object account its bytes exactly once.
    /// Panics if `src` does not hold the object; the memory manager uses
    /// [`StoreSet::try_transfer`] instead, because under a byte budget a
    /// source copy can be legitimately paged out mid-pull.
    pub fn transfer(&self, src: usize, dst: usize, id: ObjectId) -> u64 {
        self.try_transfer(src, dst, id)
            .unwrap_or_else(|| panic!("transfer: object {id} not on node {src}"))
    }

    /// [`StoreSet::transfer`], but `None` (instead of a panic) when the
    /// source no longer holds the object — or when the link to either
    /// endpoint is down. The payload is moved by the [`Transport`]:
    /// transient carry failures (timeout, corrupt frame, I/O hiccup)
    /// retry in place up to [`MAX_LINK_RETRIES`] times with
    /// [`link_backoff`]; peer death (or retries exhausting) marks the
    /// peer dead and returns `None`, which callers already treat as
    /// "object unavailable" → the recovery path.
    pub fn try_transfer(&self, src: usize, dst: usize, id: ObjectId) -> Option<u64> {
        if src == dst || self.contains(dst, id) {
            return Some(0);
        }
        if self.peer_dead(dst) {
            return None; // a dead node can't receive; recovery will re-place
        }
        let block = self.get(src, id)?;
        let carried = if self.peer_dead(src) {
            // the source *process* is gone but the driver-side store
            // still holds a (spared) copy — e.g. a lineage root the
            // node-loss wipe deliberately kept. Serve it in-process,
            // Ray's "driver re-puts its own inputs" move.
            Arc::clone(&block)
        } else {
            let mut attempt: u32 = 0;
            loop {
                match self.transport.carry(src, dst, id, &block) {
                    Ok(b) => break b,
                    Err(e) if e.is_transient() && attempt < MAX_LINK_RETRIES => {
                        self.transport_retries.fetch_add(1, Ordering::Relaxed);
                        std::thread::sleep(link_backoff(attempt));
                        attempt += 1;
                    }
                    Err(e) => {
                        // peer death, or a link that never came back:
                        // flag it; the executor reaps this into the
                        // PR 9 node-loss path
                        self.mark_peer_dead(e.node());
                        return None;
                    }
                }
            }
        };
        let sz = carried.bytes();
        {
            let mut d = self.stores[dst].lock().unwrap();
            if d.contains(id) {
                return Some(0); // lost the race: the other puller accounted it
            }
            d.net_in_bytes += sz;
            d.put(id, carried);
        }
        let mut s = self.stores[src].lock().unwrap();
        s.net_out_bytes += sz;
        Some(sz)
    }

    /// Snapshot (bytes, peak, net_in, net_out) for each node.
    pub fn snapshot(&self) -> Vec<(u64, u64, u64, u64)> {
        self.stores
            .iter()
            .map(|s| {
                let s = s.lock().unwrap();
                (s.bytes, s.peak_bytes, s.net_in_bytes, s.net_out_bytes)
            })
            .collect()
    }

    /// Fetch a block wherever it lives (driver-side gather).
    pub fn fetch(&self, id: ObjectId) -> Option<Arc<Block>> {
        for s in &self.stores {
            if let Some(b) = s.lock().unwrap().get(id) {
                return Some(b);
            }
        }
        None
    }

    /// Every object id resident on `node` right now (unordered snapshot;
    /// fault-tolerance node wipes enumerate a store through this).
    pub fn objects(&self, node: usize) -> Vec<ObjectId> {
        self.stores[node].lock().unwrap().ids()
    }
}

/// Monotonic object-id allocator shared by the driver.
#[derive(Default)]
pub struct IdGen(std::sync::atomic::AtomicU64);

impl IdGen {
    pub fn next(&self) -> ObjectId {
        self.0.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blk(n: usize) -> Arc<Block> {
        Arc::new(Block::zeros(&[n, 1]))
    }

    #[test]
    fn put_get_tracks_bytes_and_peak() {
        let mut s = ObjectStore::default();
        s.put(1, blk(10)); // 80 bytes
        s.put(2, blk(5)); // 40 bytes
        assert_eq!(s.bytes, 120);
        s.remove(1);
        assert_eq!(s.bytes, 40);
        assert_eq!(s.peak_bytes, 120);
    }

    #[test]
    fn duplicate_put_not_double_counted() {
        let mut s = ObjectStore::default();
        let b = blk(10);
        s.put(1, b.clone());
        s.put(1, b);
        assert_eq!(s.bytes, 80);
    }

    #[test]
    fn reput_with_different_size_adjusts_byte_count() {
        let mut s = ObjectStore::default();
        s.put(1, blk(10)); // 80 bytes
        s.put(1, blk(30)); // replaced by 240 bytes
        assert_eq!(s.bytes, 240, "old size must be swapped out, not kept");
        assert_eq!(s.peak_bytes, 240);
        s.put(1, blk(5)); // shrink to 40 bytes
        assert_eq!(s.bytes, 40);
        assert_eq!(s.peak_bytes, 240);
        s.remove(1);
        assert_eq!(s.bytes, 0, "remove must free the *current* size");
    }

    #[test]
    fn try_transfer_reports_missing_source() {
        let set = StoreSet::new(2);
        assert_eq!(set.try_transfer(0, 1, 42), None);
        set.put(0, 42, blk(4));
        assert_eq!(set.try_transfer(0, 1, 42), Some(32));
        // already at dst: accounted once
        assert_eq!(set.try_transfer(0, 1, 42), Some(0));
    }

    #[test]
    fn transfer_accounts_both_ends() {
        let set = StoreSet::new(2);
        set.put(0, 7, blk(16)); // 128 bytes
        let moved = set.transfer(0, 1, 7);
        assert_eq!(moved, 128);
        assert!(set.contains(1, 7));
        assert!(set.contains(0, 7)); // source keeps its copy (Ray caching)
        let snap = set.snapshot();
        assert_eq!(snap[0].3, 128); // node0 out
        assert_eq!(snap[1].2, 128); // node1 in
        // second transfer is a no-op (already cached at dst)
        assert_eq!(set.transfer(0, 1, 7), 0);
        assert_eq!(set.snapshot()[1].2, 128);
    }

    #[test]
    fn locate_prefers_hint() {
        let set = StoreSet::new(3);
        set.put(2, 9, blk(1));
        assert_eq!(set.locate(9, 2), Some(2));
        assert_eq!(set.locate(9, 0), Some(2));
        assert_eq!(set.locate(42, 0), None);
    }

    #[test]
    fn idgen_monotonic() {
        let g = IdGen::default();
        let a = g.next();
        let b = g.next();
        assert!(b > a);
    }

    /// Fails transiently `flakes` times, then carries in-process.
    struct FlakyTransport {
        flakes: std::sync::atomic::AtomicU32,
    }

    impl Transport for FlakyTransport {
        fn kind(&self) -> crate::net::TransportKind {
            crate::net::TransportKind::InProcess
        }
        fn carry(
            &self,
            _src: usize,
            dst: usize,
            _id: ObjectId,
            block: &Arc<Block>,
        ) -> Result<Arc<Block>, TransportError> {
            if self.flakes.fetch_update(Ordering::SeqCst, Ordering::SeqCst, |v| v.checked_sub(1))
                .is_ok()
            {
                return Err(TransportError::Timeout { node: dst });
            }
            Ok(Arc::clone(block))
        }
    }

    /// Every carry reports the destination process dead.
    struct DeadTransport;

    impl Transport for DeadTransport {
        fn kind(&self) -> crate::net::TransportKind {
            crate::net::TransportKind::Tcp
        }
        fn carry(
            &self,
            _src: usize,
            dst: usize,
            _id: ObjectId,
            _block: &Arc<Block>,
        ) -> Result<Arc<Block>, TransportError> {
            Err(TransportError::PeerDead { node: dst })
        }
    }

    #[test]
    fn transient_carry_failures_retry_then_succeed() {
        let set = StoreSet::with_transport(
            2,
            Arc::new(FlakyTransport { flakes: std::sync::atomic::AtomicU32::new(3) }),
        );
        set.put(0, 7, blk(4));
        assert_eq!(set.try_transfer(0, 1, 7), Some(32));
        assert_eq!(set.transport_retries(), 3);
        assert!(set.dead_peers().is_empty());
    }

    #[test]
    fn exhausted_transient_retries_escalate_to_dead_peer() {
        let set = StoreSet::with_transport(
            2,
            Arc::new(FlakyTransport { flakes: std::sync::atomic::AtomicU32::new(u32::MAX) }),
        );
        set.put(0, 7, blk(4));
        assert_eq!(set.try_transfer(0, 1, 7), None);
        assert_eq!(set.transport_retries(), crate::net::MAX_LINK_RETRIES as u64);
        assert!(set.peer_dead(1));
    }

    #[test]
    fn dead_peer_fails_transfers_and_is_reaped_exactly_once() {
        let set = StoreSet::with_transport(2, Arc::new(DeadTransport));
        set.put(0, 7, blk(4));
        assert_eq!(set.try_transfer(0, 1, 7), None, "carry to a dead peer must fail");
        assert!(set.peer_dead(1));
        assert_eq!(set.dead_peers(), vec![1]);
        // the reaper claims each death exactly once
        assert_eq!(set.take_dead_peer(), Some(1));
        assert_eq!(set.take_dead_peer(), None);
        // byte counters untouched by the failed attempt
        let snap = set.snapshot();
        assert_eq!((snap[1].2, snap[0].3), (0, 0));
        // a flagged-dead destination short-circuits without carrying
        assert_eq!(set.try_transfer(0, 1, 7), None);
    }

    #[test]
    fn dead_source_with_driver_copy_serves_in_process() {
        let set = StoreSet::with_transport(2, Arc::new(DeadTransport));
        set.put(0, 7, blk(4));
        set.mark_peer_dead(0);
        // src process is gone but the driver-side store kept a spared
        // copy: the pull still lands (and is accounted) without touching
        // the dead carrier
        assert_eq!(set.try_transfer(0, 1, 7), Some(32));
        assert!(set.contains(1, 7));
        let snap = set.snapshot();
        assert_eq!((snap[1].2, snap[0].3), (32, 32));
    }
}
