//! Dense array blocks — the objects of the task-based system (§3).
//!
//! A block is either *real* (f64 buffer, row-major) or *phantom* (shape
//! only). Phantom blocks back `ExecMode::Sim`, which runs paper-scale
//! workloads (terabyte shapes) without materializing terabytes: LSHS and
//! the DES only ever consume sizes and locations.

use std::fmt;

/// Per-thread buffer pool for kernel scratch and task outputs.
///
/// Every block task used to allocate its output (`vec![0.0; m*n]`) and any
/// scratch (the Newton μ vector, fused-chain accumulators) fresh from the
/// allocator. This pool recycles the `Vec<f64>` backing stores instead:
/// kernels request buffers via [`pool::alloc_zeroed`]/[`pool::alloc_copy`]
/// and hand transient ones back with [`pool::recycle`]. It is thread-local
/// — the real executor runs one pool per worker thread — so the task hot
/// path takes no locks. Buffers that become stored `Block`s leave the pool
/// permanently (they are owned by the object store); only per-task scratch
/// cycles, which is where the allocator pressure was.
pub mod pool {
    use std::cell::RefCell;

    /// Keep at most this many free buffers per thread.
    const MAX_POOLED: usize = 16;
    /// Never pool buffers above this element count (bounds resident waste).
    const MAX_ELEMS: usize = 1 << 23;
    /// Cap on the *summed* capacity of all pooled buffers per thread
    /// (32 MiB of f64) — a count bound alone would let sixteen large
    /// scratch vectors pin ~1 GiB per worker thread.
    const MAX_TOTAL_ELEMS: usize = 1 << 22;

    thread_local! {
        static FREE: RefCell<Vec<Vec<f64>>> = RefCell::new(Vec::new());
    }

    /// Smallest pooled buffer with adequate capacity. Over-sized buffers
    /// (> 4·n) are left pooled: a stored `Block` keeps its backing
    /// capacity forever, so handing a huge recycled buffer to a tiny
    /// allocation would pin the waste in the object store.
    fn take(n: usize) -> Option<Vec<f64>> {
        let max_cap = n.saturating_mul(4).max(64);
        FREE.with(|p| {
            let mut p = p.borrow_mut();
            let mut best: Option<usize> = None;
            for (i, v) in p.iter().enumerate() {
                if v.capacity() >= n && v.capacity() <= max_cap {
                    let better = match best {
                        Some(b) => v.capacity() < p[b].capacity(),
                        None => true,
                    };
                    if better {
                        best = Some(i);
                    }
                }
            }
            best.map(|i| p.swap_remove(i))
        })
    }

    /// A zeroed buffer of exactly `n` elements.
    pub fn alloc_zeroed(n: usize) -> Vec<f64> {
        match take(n) {
            Some(mut v) => {
                v.clear();
                v.resize(n, 0.0);
                v
            }
            None => vec![0.0; n],
        }
    }

    /// A buffer initialized as a copy of `src`.
    pub fn alloc_copy(src: &[f64]) -> Vec<f64> {
        match take(src.len()) {
            Some(mut v) => {
                v.clear();
                v.extend_from_slice(src);
                v
            }
            None => src.to_vec(),
        }
    }

    /// Return a transient buffer to the pool (dropped if the pool is full
    /// or the buffer is oversized). Safe to call from any drop context:
    /// if this thread's pool has already been torn down (TLS destruction
    /// order), the buffer is simply freed.
    pub fn recycle(v: Vec<f64>) {
        if v.capacity() == 0 || v.capacity() > MAX_ELEMS {
            return;
        }
        let _ = FREE.try_with(|p| {
            let mut p = p.borrow_mut();
            let pooled: usize = p.iter().map(|b| b.capacity()).sum();
            if p.len() < MAX_POOLED && pooled + v.capacity() <= MAX_TOTAL_ELEMS {
                let mut v = v;
                v.clear();
                p.push(v);
            }
        });
    }

    /// (free buffer count, total pooled capacity in elements).
    pub fn stats() -> (usize, usize) {
        FREE.with(|p| {
            let p = p.borrow();
            (p.len(), p.iter().map(|v| v.capacity()).sum())
        })
    }
}

#[derive(Clone, PartialEq)]
pub enum BlockData {
    Real(Vec<f64>),
    Phantom,
}

#[derive(Clone, PartialEq)]
pub struct Block {
    pub shape: Vec<usize>,
    pub data: BlockData,
}

impl Block {
    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Self {
            shape: shape.to_vec(),
            data: BlockData::Real(vec![0.0; n]),
        }
    }

    pub fn filled(shape: &[usize], v: f64) -> Self {
        let n: usize = shape.iter().product();
        Self {
            shape: shape.to_vec(),
            data: BlockData::Real(vec![v; n]),
        }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f64>) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(data.len(), n, "data len {} != shape {:?}", data.len(), shape);
        Self {
            shape: shape.to_vec(),
            data: BlockData::Real(data),
        }
    }

    /// A shape-only block for simulated execution.
    pub fn phantom(shape: &[usize]) -> Self {
        Self {
            shape: shape.to_vec(),
            data: BlockData::Phantom,
        }
    }

    pub fn is_phantom(&self) -> bool {
        matches!(self.data, BlockData::Phantom)
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    pub fn elems(&self) -> u64 {
        self.shape.iter().map(|&s| s as u64).product()
    }

    /// Logical size in bytes (f64), real or phantom.
    pub fn bytes(&self) -> u64 {
        self.elems() * 8
    }

    /// Borrow the buffer; panics on phantom blocks (executors must never
    /// mix modes — that's a bug, not a recoverable condition).
    pub fn buf(&self) -> &[f64] {
        match &self.data {
            BlockData::Real(v) => v,
            BlockData::Phantom => panic!("buf() on phantom block {:?}", self.shape),
        }
    }

    pub fn buf_mut(&mut self) -> &mut [f64] {
        match &mut self.data {
            BlockData::Real(v) => v,
            BlockData::Phantom => panic!("buf_mut() on phantom block"),
        }
    }

    pub fn into_vec(mut self) -> Vec<f64> {
        // swap the buffer out so the pool-recycling Drop sees a phantom
        match std::mem::replace(&mut self.data, BlockData::Phantom) {
            BlockData::Real(v) => v,
            BlockData::Phantom => panic!("into_vec() on phantom block"),
        }
    }

    /// 2-D accessor (row-major).
    #[inline]
    pub fn at2(&self, i: usize, j: usize) -> f64 {
        debug_assert_eq!(self.ndim(), 2);
        self.buf()[i * self.shape[1] + j]
    }

    #[inline]
    pub fn set2(&mut self, i: usize, j: usize, v: f64) {
        debug_assert_eq!(self.ndim(), 2);
        let cols = self.shape[1];
        self.buf_mut()[i * cols + j] = v;
    }

    /// Number of rows/cols of a 2-D block.
    pub fn rows(&self) -> usize {
        assert_eq!(self.ndim(), 2, "rows() on {:?}", self.shape);
        self.shape[0]
    }

    pub fn cols(&self) -> usize {
        assert_eq!(self.ndim(), 2, "cols() on {:?}", self.shape);
        self.shape[1]
    }

    /// Copy a contiguous row range (2-D).
    pub fn slice_rows(&self, r0: usize, r1: usize) -> Block {
        assert_eq!(self.ndim(), 2);
        let cols = self.shape[1];
        Block::from_vec(
            &[r1 - r0, cols],
            self.buf()[r0 * cols..r1 * cols].to_vec(),
        )
    }

    /// Vertically stack two 2-D blocks.
    pub fn vstack(&self, other: &Block) -> Block {
        assert_eq!(self.ndim(), 2);
        assert_eq!(self.cols(), other.cols());
        let mut data = Vec::with_capacity(self.buf().len() + other.buf().len());
        data.extend_from_slice(self.buf());
        data.extend_from_slice(other.buf());
        Block::from_vec(&[self.rows() + other.rows(), self.cols()], data)
    }

    /// Transposed copy of a 2-D block.
    pub fn transposed(&self) -> Block {
        assert_eq!(self.ndim(), 2);
        let (m, n) = (self.rows(), self.cols());
        let src = self.buf();
        let mut out = vec![0.0; m * n];
        for i in 0..m {
            for j in 0..n {
                out[j * m + i] = src[i * n + j];
            }
        }
        Block::from_vec(&[n, m], out)
    }

    /// Max |a - b| against another block.
    pub fn max_abs_diff(&self, other: &Block) -> f64 {
        assert_eq!(self.shape, other.shape);
        crate::util::stats::max_abs_diff(self.buf(), other.buf())
    }
}

/// Pool-aware drop: a dying block's backing buffer goes back to this
/// thread's pool instead of the allocator, so stored task outputs —
/// released by lifetime GC, eviction, or store teardown — feed the next
/// task's allocation (the other half of the `pool` story; kernels
/// already recycle their scratch explicitly). The pool's size caps bound
/// the resident waste; oversized or capacity-less buffers free as usual.
impl Drop for Block {
    fn drop(&mut self) {
        if let BlockData::Real(v) = &mut self.data {
            pool::recycle(std::mem::take(v));
        }
    }
}

impl fmt::Debug for Block {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match &self.data {
            BlockData::Phantom => write!(f, "Block(phantom, shape={:?})", self.shape),
            BlockData::Real(v) => {
                let preview: Vec<f64> = v.iter().take(4).cloned().collect();
                write!(
                    f,
                    "Block(shape={:?}, data={:?}{})",
                    self.shape,
                    preview,
                    if v.len() > 4 { ", ..." } else { "" }
                )
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes() {
        let b = Block::zeros(&[4, 8]);
        assert_eq!(b.elems(), 32);
        assert_eq!(b.bytes(), 256);
        let p = Block::phantom(&[1_000_000, 1_000]);
        assert_eq!(p.bytes(), 8_000_000_000);
        assert!(p.is_phantom());
    }

    #[test]
    fn accessors() {
        let mut b = Block::zeros(&[2, 3]);
        b.set2(1, 2, 5.0);
        assert_eq!(b.at2(1, 2), 5.0);
        assert_eq!(b.at2(0, 0), 0.0);
    }

    #[test]
    fn transpose_involution() {
        let b = Block::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let t = b.transposed();
        assert_eq!(t.shape, vec![3, 2]);
        assert_eq!(t.at2(2, 1), 6.0);
        assert_eq!(t.transposed(), b);
    }

    #[test]
    fn stack_and_slice() {
        let a = Block::from_vec(&[1, 2], vec![1., 2.]);
        let b = Block::from_vec(&[2, 2], vec![3., 4., 5., 6.]);
        let s = a.vstack(&b);
        assert_eq!(s.shape, vec![3, 2]);
        assert_eq!(s.slice_rows(1, 3), b);
    }

    #[test]
    #[should_panic(expected = "phantom")]
    fn phantom_buf_panics() {
        Block::phantom(&[2, 2]).buf();
    }

    #[test]
    fn pool_recycles_capacity() {
        // run on a dedicated thread: the pool is thread-local and other
        // tests on this thread may already have seeded it
        std::thread::spawn(|| {
            let v = pool::alloc_zeroed(100);
            assert_eq!(v.len(), 100);
            assert!(v.iter().all(|&x| x == 0.0));
            let cap = v.capacity();
            pool::recycle(v);
            assert_eq!(pool::stats().0, 1);
            // close-enough size: the pooled buffer is reused
            let w = pool::alloc_zeroed(40);
            assert!(w.capacity() >= cap, "pooled buffer must be reused");
            assert_eq!(pool::stats().0, 0);
            pool::recycle(w);
            // far smaller request: the big buffer must stay pooled (a
            // stored Block would pin its capacity forever)
            let tiny = pool::alloc_copy(&[1.0, 2.0, 3.0]);
            assert_eq!(tiny, vec![1.0, 2.0, 3.0]);
            assert!(tiny.capacity() < cap, "over-sized reuse must be refused");
            assert_eq!(pool::stats().0, 1);
        })
        .join()
        .unwrap();
    }

    #[test]
    fn dropping_a_block_recycles_its_buffer() {
        std::thread::spawn(|| {
            let b = Block::from_vec(&[8, 8], vec![1.0; 64]);
            drop(b);
            assert_eq!(pool::stats().0, 1, "dropped block must feed the pool");
            // and the recycled buffer comes back zeroed
            let v = pool::alloc_zeroed(64);
            assert!(v.iter().all(|&x| x == 0.0));
            assert_eq!(pool::stats().0, 0);
            // into_vec opts out: the caller owns the buffer, nothing pooled
            let w = Block::from_vec(&[2, 2], vec![2.0; 4]).into_vec();
            assert_eq!(w, vec![2.0; 4]);
            assert_eq!(pool::stats().0, 0);
        })
        .join()
        .unwrap();
    }

    #[test]
    fn pool_zeroes_reused_buffers() {
        std::thread::spawn(|| {
            pool::recycle(vec![9.0; 64]);
            let v = pool::alloc_zeroed(32);
            assert_eq!(v.len(), 32);
            assert!(v.iter().all(|&x| x == 0.0), "stale data must be cleared");
        })
        .join()
        .unwrap();
    }
}
