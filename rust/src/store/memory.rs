//! Distributed memory manager: replica-aware eviction and real
//! spill-to-disk for the real executor's per-node object stores.
//!
//! Half of LSHS's objective (Eq. 2) is minimizing the *maximum memory
//! load* on any node — §8.1's headline is 4× less memory than Ray's
//! dynamic scheduler. The sim executor models that with refcount GC and
//! an object-spilling penalty; this module gives the real executor the
//! same machinery with actual bytes and actual disk I/O, so per-node
//! `peak_bytes` measures scheduling quality rather than total allocation.
//!
//! Mapping to the paper's §8.1 terms:
//!
//! * **memory load** — `ObjectStore::bytes` / `peak_bytes` per node; the
//!   manager's evictions and spills are what make the real-run peak
//!   comparable to the sim trace of Fig. 15.
//! * **object spilling** — when a `put` would push a node's store past
//!   `budget` bytes (`SessionConfig::mem_budget_bytes`), the coldest
//!   unpinned blocks are written to per-node temp files
//!   (`NodeMemStats::spilled_bytes`) and transparently read back on the
//!   next access (`readback_bytes`) — the real-execution counterpart of
//!   the DES `spill_penalty`/`spill_readback` model, so the two can be
//!   diffed. With a spill sink attached (the real executor's per-node
//!   transfer threads, [`crate::exec::Prefetcher`]) the file write is
//!   *asynchronous*: the victim leaves the store immediately, its block
//!   is parked on the spill entry (`pending`) until the transfer thread
//!   completes the write, and every reader checks the entry first — so
//!   `acquire` can never observe a half-written file. A spill file is
//!   kept until its object is released or re-put; re-spilling an object
//!   whose on-disk copy is still current skips the write entirely
//!   (`spill_reuse_bytes`).
//! * **replicas** — a cross-node pull (work stealing, remote inputs)
//!   leaves a copy on the destination. The manager registers that copy as
//!   a *replica* whose primary lives elsewhere; replicas of still-live
//!   objects are the first thing evicted under pressure
//!   (`evicted_replica_bytes`) since dropping them never loses data.
//! * **reference counting** — [`crate::exec::Lifetimes`] computes plan
//!   consumer refcounts; the executor calls [`MemoryManager::release`]
//!   when an intermediate's count hits zero, which evicts it from every
//!   node and deletes its spill file (`gc_freed_bytes`).
//!
//! Lock order: one manager node lock at a time, store locks strictly
//! inside manager node locks, and the executor's state lock never held
//! across a manager call that takes locks — so the three lock families
//! (exec → store, manager → store) cannot form a cycle.

use std::collections::{HashMap, HashSet};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

use crate::exec::fault::{FaultInjector, FaultSite, MAX_INJECTIONS_PER_KEY};
use crate::graph::signature::Fnv128;
use crate::metrics::runtime_trace::{EventKind, FetchOrigin, RunRecorder};

use super::block::Block;
use super::object_store::{ObjectId, StoreSet};

/// Per-node memory-management counters for one run (all cumulative; the
/// executor reports per-run deltas via [`NodeMemStats::delta`]).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct NodeMemStats {
    /// Bytes written to this node's spill files (sync or async writes).
    pub spilled_bytes: u64,
    /// Bytes restored from spill on access: disk reads, plus restores of
    /// a still-pending block whose async write had not finished yet.
    pub readback_bytes: u64,
    /// Bytes reclaimed by evicting replica copies (primary elsewhere).
    pub evicted_replica_bytes: u64,
    /// Bytes reclaimed by lifetime GC (dead intermediates).
    pub gc_freed_bytes: u64,
    /// Bytes shed by re-spilling an unchanged object whose on-disk copy
    /// was still current — no file write happened (spill-file reuse).
    pub spill_reuse_bytes: u64,
}

impl NodeMemStats {
    /// Counters accumulated since `earlier` (same node, later snapshot).
    pub fn delta(&self, earlier: &NodeMemStats) -> NodeMemStats {
        NodeMemStats {
            spilled_bytes: self.spilled_bytes.saturating_sub(earlier.spilled_bytes),
            readback_bytes: self.readback_bytes.saturating_sub(earlier.readback_bytes),
            evicted_replica_bytes: self
                .evicted_replica_bytes
                .saturating_sub(earlier.evicted_replica_bytes),
            gc_freed_bytes: self.gc_freed_bytes.saturating_sub(earlier.gc_freed_bytes),
            spill_reuse_bytes: self
                .spill_reuse_bytes
                .saturating_sub(earlier.spill_reuse_bytes),
        }
    }
}

/// A primary block with a spill copy: raw little-endian f64 data in
/// `path` once `on_disk`, shape kept in memory. While an asynchronous
/// write is queued the block itself is parked in `pending` — readers use
/// it directly, which is what makes a half-written `path` unobservable.
/// The entry survives read-back (the file stays current until the object
/// is released or re-put), so a later re-spill of the unchanged object
/// reuses the file instead of rewriting it.
#[derive(Debug)]
struct Spilled {
    path: PathBuf,
    shape: Vec<usize>,
    bytes: u64,
    /// In-memory copy awaiting its async write (`None` once on disk).
    pending: Option<Arc<Block>>,
    /// `path` holds a complete, current copy of the object.
    on_disk: bool,
}

/// Callback the real executor installs so budget pressure can hand spill
/// writes to the per-node transfer threads instead of blocking a worker:
/// invoked with the node id whenever async spill work is queued.
pub type SpillSink = Arc<dyn Fn(usize) + Send + Sync>;

/// Per-node manager state (one mutex per node, like the stores).
#[derive(Default)]
struct NodeMem {
    /// LRU clock: bumped on every touch; smallest = coldest.
    clock: u64,
    /// Resident ids this manager placed, by last access tick.
    last_touch: HashMap<ObjectId, u64>,
    /// Resident ids whose primary copy lives on another node.
    replicas: HashSet<ObjectId>,
    /// Spill copies of primaries (replicas are evicted, never spilled —
    /// their primary still holds the data). An entry means "a current
    /// copy exists outside the store": parked in memory awaiting its
    /// async write, on disk while the object is paged out, or on disk
    /// as the *clean* twin of a read-back resident object (kept so a
    /// re-spill is free).
    spilled: HashMap<ObjectId, Spilled>,
    stats: NodeMemStats,
}

impl NodeMem {
    fn touch(&mut self, id: ObjectId) {
        self.clock += 1;
        let c = self.clock;
        self.last_touch.insert(id, c);
    }

    fn forget(&mut self, id: ObjectId) {
        self.last_touch.remove(&id);
        self.replicas.remove(&id);
    }
}

/// Distinguishes spill-dir names across managers within one process.
static SPILL_SEQ: AtomicU64 = AtomicU64::new(0);

/// Attempts [`MemoryManager::acquire`] makes before declaring an object
/// unobtainable (bounds eviction/pull livelock under absurd budgets).
const MAX_ACQUIRE_ATTEMPTS: usize = 64;

/// Cluster-wide memory manager owned by [`crate::exec::RealExecutor`].
pub struct MemoryManager {
    /// Per-node resident-byte budget; `None` = unlimited (no spilling;
    /// replica eviction and lifetime GC still run).
    pub budget: Option<u64>,
    /// Whether the executor should run plan-lifetime GC through this
    /// manager (`SessionConfig::lifetime_gc`).
    pub lifetime_gc: bool,
    nodes: Vec<Mutex<NodeMem>>,
    spill_root: PathBuf,
    /// False when the spill directory could not be created: pressure then
    /// falls back to replica eviction only.
    spill_ok: bool,
    /// Async spill sink (the executor's transfer threads). `None` =
    /// synchronous writes, the standalone/creation-time behavior.
    sink: Mutex<Option<SpillSink>>,
    /// Run recorder for memory events (spills, read-backs, evictions, GC
    /// frees, managed fetches). Attached per traced run by the executor,
    /// like the spill sink. Every emission site already holds a node
    /// lock and just did real work (disk I/O, cross-node copy, free);
    /// the recorder's sink mutex is a leaf lock, so no ordering cycle.
    trace: Mutex<Option<Arc<RunRecorder>>>,
    /// Deterministic fault injector for the spill I/O sites
    /// ([`FaultSite::SpillWrite`] / [`FaultSite::SpillRead`]). Attached
    /// per chaos run by the executor, like the trace recorder; `None`
    /// (the default) keeps every site a plain `Option` test.
    fault: Mutex<Option<Arc<FaultInjector>>>,
}

impl MemoryManager {
    pub fn new(num_nodes: usize, budget: Option<u64>, lifetime_gc: bool) -> Self {
        let spill_root = std::env::temp_dir().join(format!(
            "nums-spill-{}-{}",
            std::process::id(),
            SPILL_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        let spill_ok = std::fs::create_dir_all(&spill_root).is_ok();
        Self {
            budget,
            lifetime_gc,
            nodes: (0..num_nodes).map(|_| Mutex::new(NodeMem::default())).collect(),
            spill_root,
            spill_ok,
            sink: Mutex::new(None),
            trace: Mutex::new(None),
            fault: Mutex::new(None),
        }
    }

    /// Route spill writes through `sink` (the executor's per-node
    /// transfer threads) for the duration of a run. The executor must
    /// guarantee every notification is eventually followed by a
    /// [`MemoryManager::process_pending_spills`] on that node.
    pub fn attach_spill_sink(&self, sink: SpillSink) {
        *self.sink.lock().unwrap() = Some(sink);
    }

    /// Back to synchronous spill writes (run teardown). Callers should
    /// [`MemoryManager::sweep_pending_spills`] afterwards so no entry is
    /// left parked in memory.
    pub fn detach_spill_sink(&self) {
        *self.sink.lock().unwrap() = None;
    }

    /// Route this run's memory events to `r` (the executor attaches the
    /// recorder for a traced run, mirroring the spill sink).
    pub fn attach_trace(&self, r: Arc<RunRecorder>) {
        *self.trace.lock().unwrap() = Some(r);
    }

    /// Stop emitting events (run teardown).
    pub fn detach_trace(&self) {
        *self.trace.lock().unwrap() = None;
    }

    /// Arm deterministic fault injection at the spill I/O sites for the
    /// duration of a chaos run (the executor attaches its injector here,
    /// mirroring the trace recorder).
    pub fn attach_fault(&self, f: Arc<FaultInjector>) {
        *self.fault.lock().unwrap() = Some(f);
    }

    /// Disarm spill-site fault injection (run teardown).
    pub fn detach_fault(&self) {
        *self.fault.lock().unwrap() = None;
    }

    /// Should this spill-site operation fail now? Always `false` with no
    /// injector attached. Clones the Arc out so the injector's internal
    /// lock is never taken under `fault`'s.
    fn inject(&self, site: FaultSite, key: u64) -> bool {
        let f = self.fault.lock().unwrap().clone();
        match f {
            Some(f) => f.should_fail(site, key),
            None => false,
        }
    }

    /// Emit one memory event if a recorder is attached. Clones the Arc
    /// out so the recorder's sink lock is never taken under `trace`'s.
    fn emit(
        &self,
        node: usize,
        src: Option<usize>,
        obj: Option<ObjectId>,
        bytes: u64,
        kind: EventKind,
    ) {
        let r = self.trace.lock().unwrap().clone();
        if let Some(r) = r {
            r.event(node, src, obj, bytes, kind);
        }
    }

    pub fn num_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// The per-node temp directory spill files live in (tests).
    pub fn spill_dir(&self) -> &Path {
        &self.spill_root
    }

    /// Cumulative per-node counters.
    pub fn stats(&self) -> Vec<NodeMemStats> {
        self.nodes
            .iter()
            .map(|n| n.lock().unwrap().stats.clone())
            .collect()
    }

    fn spill_path(&self, node: usize, id: ObjectId) -> PathBuf {
        self.spill_root.join(format!("n{node}_o{id}.bin"))
    }

    /// Insert a primary block on `node` (task output or creation data),
    /// then shed load until the node is back under budget. Replica
    /// copies are registered by [`MemoryManager::acquire`]'s pull path,
    /// not here. `spillable` says which ids may be paged out (pinned run
    /// outputs may not — the driver reads them after the run).
    pub fn insert(
        &self,
        stores: &StoreSet,
        node: usize,
        id: ObjectId,
        block: Arc<Block>,
        spillable: &dyn Fn(ObjectId) -> bool,
    ) {
        let mut nm = self.nodes[node].lock().unwrap();
        // a re-put supersedes any spill copy for this id: drop the entry
        // (clean bit, pending block and all) and delete the stale file.
        // An async write still in flight detects the superseded entry at
        // finalize time (Arc identity mismatch) and deletes its output.
        if let Some(sp) = nm.spilled.remove(&id) {
            let _ = std::fs::remove_file(&sp.path);
        }
        stores.put(node, id, block);
        nm.touch(id);
        // producing a fresh copy makes this node a primary holder
        nm.replicas.remove(&id);
        self.enforce_budget(stores, node, &mut nm, spillable);
    }

    /// Shed resident bytes on `node` until it fits the budget: evict the
    /// coldest replicas first (free — a primary copy exists elsewhere),
    /// then spill the coldest unpinned primaries to disk. Pinned ids and
    /// blocks the manager has never touched (raw `StoreSet::put`s that
    /// were never `acquire`d — a first acquire registers them) are never
    /// victims; if only those remain, the node stays over budget.
    /// Callers that hand a block to a kernel clone its `Arc` before
    /// calling this, so spilling even the hottest id never invalidates
    /// in-flight work.
    fn enforce_budget(
        &self,
        stores: &StoreSet,
        node: usize,
        nm: &mut MutexGuard<'_, NodeMem>,
        spillable: &dyn Fn(ObjectId) -> bool,
    ) {
        let Some(budget) = self.budget else { return };
        if stores.node_bytes(node) <= budget {
            return;
        }
        // One coldest-first snapshot suffices: we hold the node lock, so
        // no new candidates can appear while shedding.
        let mut order: Vec<(u64, ObjectId)> = nm
            .last_touch
            .iter()
            .map(|(&o, &c)| (c, o))
            .collect();
        order.sort_unstable();
        // pass 1 — coldest replicas: eviction loses nothing
        for &(_, o) in &order {
            if stores.node_bytes(node) <= budget {
                return;
            }
            if !nm.replicas.contains(&o) {
                continue;
            }
            if let Some(b) = stores.remove(node, o) {
                nm.stats.evicted_replica_bytes += b.bytes();
                self.emit(node, None, Some(o), b.bytes(), EventKind::ReplicaEvict);
            }
            nm.forget(o);
        }
        if !self.spill_ok {
            return;
        }
        let sink = self.sink.lock().unwrap().clone();
        let mut queued = false;
        // pass 2 — coldest spillable primaries -> disk (async when a sink
        // is attached: the victim leaves the store now, the file write
        // happens on a transfer thread)
        for &(_, o) in &order {
            if stores.node_bytes(node) <= budget {
                break;
            }
            if !spillable(o) || !nm.last_touch.contains_key(&o) {
                continue;
            }
            let Some(b) = stores.get(node, o) else {
                nm.forget(o); // stale entry (removed behind our back)
                continue;
            };
            if b.is_phantom() {
                nm.forget(o); // sim blocks carry no data to page out
                continue;
            }
            // a current spill copy may already exist: parked in memory
            // (in-flight async write) or clean on disk. The `on_disk`
            // bit is trustworthy — a failed read-back clears it — so
            // shedding the resident copy costs nothing and no file is
            // rewritten (spill-file reuse).
            let spill_copy = nm
                .spilled
                .get(&o)
                .map(|sp| (sp.pending.is_some() || sp.on_disk, sp.path.clone()));
            if let Some((usable, stale_path)) = spill_copy {
                if usable {
                    stores.remove(node, o);
                    nm.stats.spill_reuse_bytes += b.bytes();
                    self.emit(node, None, Some(o), b.bytes(), EventKind::SpillReuse);
                    nm.forget(o);
                    continue;
                }
                // dead entry (read failed, write never completed):
                // discard it and fall through to a fresh write
                let _ = std::fs::remove_file(&stale_path);
                nm.spilled.remove(&o);
            }
            let path = self.spill_path(node, o);
            match &sink {
                Some(_) => {
                    // async: park the block on the entry, free the store
                    // immediately, let the transfer thread write the file
                    stores.remove(node, o);
                    nm.spilled.insert(
                        o,
                        Spilled {
                            path,
                            shape: b.shape.clone(),
                            bytes: b.bytes(),
                            pending: Some(b),
                            on_disk: false,
                        },
                    );
                    nm.forget(o);
                    queued = true;
                }
                None => {
                    // injected write faults are transient by construction
                    // (per-key cap below the attempt bound), so retrying
                    // here keeps budget/peak accounting identical under
                    // chaos; a *real* disk error still aborts the shed.
                    let mut wrote = false;
                    for _ in 0..=MAX_INJECTIONS_PER_KEY {
                        if self.inject(FaultSite::SpillWrite, o) {
                            self.emit(node, None, Some(o), b.bytes(), EventKind::Fault);
                            continue;
                        }
                        wrote = write_spill(&path, b.buf()).is_ok();
                        break;
                    }
                    if !wrote {
                        return; // disk trouble: keep the block resident
                    }
                    stores.remove(node, o);
                    nm.stats.spilled_bytes += b.bytes();
                    self.emit(node, None, Some(o), b.bytes(), EventKind::Spill);
                    nm.spilled.insert(
                        o,
                        Spilled {
                            path,
                            shape: b.shape.clone(),
                            bytes: b.bytes(),
                            pending: None,
                            on_disk: true,
                        },
                    );
                    nm.forget(o);
                }
            }
        }
        if queued {
            if let Some(notify) = &sink {
                notify(node);
            }
        }
        // snapshot exhausted while still over budget: everything left is
        // pinned, unmanaged, or already spilled — stay over, soft budget
    }

    /// Complete `node`'s queued asynchronous spill writes; returns the
    /// bytes written. Runs on the executor's transfer thread (or inline
    /// from [`MemoryManager::sweep_pending_spills`] at teardown). Each
    /// file write happens outside the node lock; at finalize time the
    /// entry must still hold the very block that was written (Arc
    /// identity), otherwise the entry was superseded or released
    /// mid-write and the stale file is deleted instead.
    pub fn process_pending_spills(&self, stores: &StoreSet, node: usize) -> u64 {
        let mut written = 0u64;
        loop {
            let next = {
                let nm = self.nodes[node].lock().unwrap();
                nm.spilled.iter().find_map(|(&o, sp)| {
                    sp.pending
                        .as_ref()
                        .map(|b| (o, sp.path.clone(), Arc::clone(b), sp.bytes))
                })
            };
            let Some((obj, path, block, bytes)) = next else {
                return written;
            };
            // injected write faults retry inline (the per-key cap bounds
            // the loop); only a real disk error reaches the reinstate
            // path below, so chaos runs keep the post-run budget intact.
            let mut ok = false;
            for _ in 0..=MAX_INJECTIONS_PER_KEY {
                if self.inject(FaultSite::SpillWrite, obj) {
                    self.emit(node, None, Some(obj), bytes, EventKind::Fault);
                    continue;
                }
                ok = write_spill(&path, block.buf()).is_ok();
                break;
            }
            let mut nm = self.nodes[node].lock().unwrap();
            match nm.spilled.get_mut(&obj) {
                Some(sp)
                    if sp
                        .pending
                        .as_ref()
                        .map_or(false, |b| Arc::ptr_eq(b, &block)) =>
                {
                    if ok {
                        sp.pending = None;
                        sp.on_disk = true;
                        nm.stats.spilled_bytes += bytes;
                        self.emit(node, None, Some(obj), bytes, EventKind::Spill);
                        written += bytes;
                    } else {
                        // disk trouble: reinstate the block (over budget
                        // beats losing the only copy — same policy as the
                        // synchronous path)
                        nm.spilled.remove(&obj);
                        stores.put(node, obj, block);
                        nm.touch(obj);
                    }
                }
                _ => {
                    // superseded (re-put) or released mid-write: whatever
                    // we just wrote is stale
                    let _ = std::fs::remove_file(&path);
                }
            }
        }
    }

    /// Inline [`MemoryManager::process_pending_spills`] over every node —
    /// run-teardown safety net so no entry stays parked in memory.
    pub fn sweep_pending_spills(&self, stores: &StoreSet) -> u64 {
        (0..self.nodes.len())
            .map(|n| self.process_pending_spills(stores, n))
            .sum()
    }

    /// Restore a spilled block into `node`'s store. Caller holds the
    /// node lock; returns `None` if the id has no spill copy here or the
    /// file is unreadable (the entry survives a failed read, so a
    /// transient error can be retried). The entry itself is *kept*: a
    /// pending async write completes into a clean on-disk copy, and a
    /// clean copy makes the next re-spill of the unchanged object free.
    fn readback_locked(
        &self,
        stores: &StoreSet,
        node: usize,
        nm: &mut MutexGuard<'_, NodeMem>,
        id: ObjectId,
    ) -> Option<Arc<Block>> {
        let (path, shape, bytes, pending) = {
            let sp = nm.spilled.get(&id)?;
            (sp.path.clone(), sp.shape.clone(), sp.bytes, sp.pending.clone())
        };
        let block = match pending {
            // async write still in flight: the parked block *is* the
            // object — no disk involved, and never a half-written file
            Some(b) => b,
            None => {
                // injected readback fault: behave exactly like an
                // unreadable file (clear the clean bit, return None).
                // acquire's outer loop retries; the per-key cap (2) sits
                // inside its 3-consecutive-total-miss abort window, so a
                // sole local spill copy always comes back.
                if self.inject(FaultSite::SpillRead, id) {
                    self.emit(node, None, Some(id), bytes, EventKind::Fault);
                    if let Some(sp) = nm.spilled.get_mut(&id) {
                        sp.on_disk = false;
                    }
                    return None;
                }
                match read_spill(&path, bytes) {
                    Some(data) => {
                        // a fresh successful read re-earns the clean bit (a
                        // transient earlier failure may have cleared it)
                        if let Some(sp) = nm.spilled.get_mut(&id) {
                            sp.on_disk = true;
                        }
                        Arc::new(Block::from_vec(&shape, data))
                    }
                    None => {
                        // unreadable file: clear the clean bit so the
                        // spill-reuse path never trusts this copy with the
                        // only resident bytes (retries may still succeed)
                        if let Some(sp) = nm.spilled.get_mut(&id) {
                            sp.on_disk = false;
                        }
                        return None;
                    }
                }
            }
        };
        stores.put(node, id, block.clone());
        nm.stats.readback_bytes += bytes;
        self.emit(node, None, Some(id), bytes, EventKind::Readback);
        nm.touch(id);
        Some(block)
    }

    /// Obtain `id` on `node` for kernel input: resident copy, spill
    /// read-back, or cross-node pull (registering the new copy as a
    /// replica). Returns the block (`None` when no store and no spill
    /// file holds the object) plus the bytes moved over the "NIC" — the
    /// bytes are reported even on failure, because a pull that succeeded
    /// and then lost its copy to eviction still put real traffic on the
    /// network (the executor's byte-accounting identity depends on it).
    pub fn acquire(
        &self,
        stores: &StoreSet,
        node: usize,
        id: ObjectId,
        spillable: &dyn Fn(ObjectId) -> bool,
    ) -> (Option<Arc<Block>>, u64) {
        self.acquire_tagged(stores, node, id, spillable, FetchOrigin::Demand)
    }

    /// [`MemoryManager::acquire`] with an explicit fetch origin for the
    /// run trace: the worker hot path acquires as `Demand`, the transfer
    /// threads as `Prefetch`. A fetch event is emitted only when a
    /// cross-node transfer actually moved bytes, so event totals match
    /// the stores' `net_in` accounting exactly.
    pub fn acquire_tagged(
        &self,
        stores: &StoreSet,
        node: usize,
        id: ObjectId,
        spillable: &dyn Fn(ObjectId) -> bool,
        origin: FetchOrigin,
    ) -> (Option<Arc<Block>>, u64) {
        let mut moved = 0u64;
        // consecutive scans that found the object nowhere: a transient
        // total miss can happen while a copy is between homes — e.g. a
        // replica evicted on one node between our store and spill checks
        // while the primary moves on another — but it cannot persist
        // across scans, so a few repeats conclude "gone" without burning
        // all MAX_ACQUIRE_ATTEMPTS on lock traffic
        let mut total_misses = 0usize;
        for _ in 0..MAX_ACQUIRE_ATTEMPTS {
            {
                let mut nm = self.nodes[node].lock().unwrap();
                if let Some(b) = stores.get(node, id) {
                    nm.touch(id);
                    return (Some(b), moved);
                }
                if nm.spilled.contains_key(&id) {
                    if let Some(b) = self.readback_locked(stores, node, &mut nm, id) {
                        self.enforce_budget(stores, node, &mut nm, spillable);
                        return (Some(b), moved);
                    }
                    // unreadable local spill file: fall through — a live
                    // copy may still exist on another node
                }
            }
            // remote copy: resident or spilled on some other node. A miss
            // here retries rather than aborting immediately: eviction can
            // remove a node's replica between our per-node store and
            // spill checks while another node still holds (or is about to
            // re-hold) a copy, so one unlucky sweep can transiently see
            // neither.
            let Some(src) = (0..self.nodes.len()).find(|&n| {
                n != node
                    && (stores.contains(n, id)
                        || self.nodes[n].lock().unwrap().spilled.contains_key(&id))
            }) else {
                total_misses += 1;
                if total_misses >= 3 {
                    return (None, moved); // nowhere, repeatedly: gone
                }
                std::thread::yield_now();
                continue;
            };
            total_misses = 0;
            {
                let mut nms = self.nodes[src].lock().unwrap();
                if !stores.contains(src, id) {
                    // un-spill at the source so the transfer can read it.
                    // Deliberately no enforce_budget here: shedding at the
                    // source could page this very object straight back out
                    // (when everything else there is pinned) and livelock
                    // the pull; the source sheds on its own next insert.
                    if self.readback_locked(stores, src, &mut nms, id).is_none() {
                        continue; // lost a race or bad file: rescan
                    }
                }
            }
            match stores.try_transfer(src, node, id) {
                Some(n) => {
                    moved += n;
                    if n > 0 {
                        self.emit(node, Some(src), Some(id), n, EventKind::Fetch(origin));
                    }
                    let mut nm = self.nodes[node].lock().unwrap();
                    if let Some(b) = stores.get(node, id) {
                        nm.replicas.insert(id);
                        nm.touch(id);
                        self.enforce_budget(stores, node, &mut nm, spillable);
                        return (Some(b), moved);
                    }
                    // evicted between transfer and get (budget thrash): retry
                }
                None => continue, // source lost the copy mid-flight: rescan
            }
        }
        (None, moved)
    }

    /// Whether any node holds `id`, resident or spilled (dependency
    /// counting must not call a paged-out input "missing").
    pub fn holds(&self, stores: &StoreSet, id: ObjectId) -> bool {
        (0..self.nodes.len()).any(|n| {
            stores.contains(n, id) || self.nodes[n].lock().unwrap().spilled.contains_key(&id)
        })
    }

    /// Driver-side gather: fetch `id` wherever it lives. Spilled blocks
    /// are read from disk without changing residency (a gather should not
    /// trigger pressure on the node it reads from), and deliberately do
    /// not count toward `readback_bytes` — that counter measures
    /// budget-induced executor read-backs, which the ablations report.
    pub fn fetch(&self, stores: &StoreSet, id: ObjectId) -> Option<Arc<Block>> {
        // two passes: a concurrent read-back clears the spilled entry
        // before the store copy appears, so a single store-then-spill
        // sweep can transiently see neither
        for _ in 0..2 {
            if let Some(b) = stores.fetch(id) {
                return Some(b);
            }
            for n in 0..self.nodes.len() {
                let nm = self.nodes[n].lock().unwrap();
                let found = nm
                    .spilled
                    .get(&id)
                    .map(|sp| (sp.pending.clone(), sp.path.clone(), sp.shape.clone(), sp.bytes));
                drop(nm);
                if let Some((pending, path, shape, bytes)) = found {
                    // an in-flight async write: the parked block is the
                    // object (the file may be half-written — never read it)
                    if let Some(b) = pending {
                        return Some(b);
                    }
                    if let Some(data) = read_spill(&path, bytes) {
                        return Some(Arc::new(Block::from_vec(&shape, data)));
                    }
                }
            }
        }
        None
    }

    /// Replica copies currently resident, as sorted `(object, node)`
    /// pairs — objects whose primary lives on another node but which a
    /// cross-node pull (work stealing, prefetch, demand miss) left a copy
    /// of here. This is the location part of the executor's
    /// [`crate::exec::RuntimeFeedback`]: the planner never committed
    /// these copies, so without feedback its location map (and therefore
    /// its placement option set) cannot know about them. Sorted so
    /// absorbing the list is deterministic across runs.
    pub fn resident_replicas(&self, stores: &StoreSet) -> Vec<(ObjectId, usize)> {
        let mut out = Vec::new();
        for n in 0..self.nodes.len() {
            // lock order: store reads strictly inside the manager node lock
            let nm = self.nodes[n].lock().unwrap();
            for &id in &nm.replicas {
                if stores.contains(n, id) {
                    out.push((id, n));
                }
            }
        }
        out.sort_unstable();
        out
    }

    /// Refcount release: the object is dead — evict every resident copy
    /// and delete any spill file. The executor calls this when lifetime
    /// analysis says the last consumer completed.
    pub fn release(&self, stores: &StoreSet, id: ObjectId) {
        for n in 0..self.nodes.len() {
            let mut nm = self.nodes[n].lock().unwrap();
            let resident = stores.remove(n, id);
            if let Some(b) = &resident {
                nm.stats.gc_freed_bytes += b.bytes();
                self.emit(n, None, Some(id), b.bytes(), EventKind::GcFree);
            }
            if let Some(sp) = nm.spilled.remove(&id) {
                let _ = std::fs::remove_file(&sp.path);
                // a clean-on-disk copy of a *resident* object is the same
                // bytes twice — count the free once
                if resident.is_none() {
                    nm.stats.gc_freed_bytes += sp.bytes;
                    self.emit(n, None, Some(id), sp.bytes, EventKind::GcFree);
                }
            }
            nm.forget(id);
        }
    }

    /// Whole-node loss: drop every resident object and spill copy on
    /// `node` except those `spare` keeps (lifetime-pinned results,
    /// sole-copy external inputs the driver could re-seed — the
    /// executor's survivability policy, not ours). Returns the lost
    /// `(object, block bytes)` pairs, sorted, so the executor can walk
    /// lineage for exactly what vanished. Replica/LRU bookkeeping for
    /// the wiped ids is cleared; spared ids keep theirs.
    pub fn wipe_node(
        &self,
        stores: &StoreSet,
        node: usize,
        spare: &dyn Fn(ObjectId) -> bool,
    ) -> Vec<(ObjectId, u64)> {
        let mut lost: Vec<(ObjectId, u64)> = Vec::new();
        let mut nm = self.nodes[node].lock().unwrap();
        for o in stores.objects(node) {
            if spare(o) {
                continue;
            }
            if let Some(b) = stores.remove(node, o) {
                lost.push((o, b.bytes()));
            }
            nm.forget(o);
        }
        let spilled_ids: Vec<ObjectId> = nm.spilled.keys().copied().collect();
        for o in spilled_ids {
            if spare(o) {
                continue;
            }
            if let Some(sp) = nm.spilled.remove(&o) {
                let _ = std::fs::remove_file(&sp.path);
                // a clean on-disk twin of a just-wiped resident copy is
                // the same object — count its bytes once
                if !lost.iter().any(|&(id, _)| id == o) {
                    lost.push((o, sp.bytes));
                }
            }
            nm.forget(o);
        }
        lost.sort_unstable();
        lost
    }
}

impl Drop for MemoryManager {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.spill_root);
    }
}

/// Elements per encode chunk: the spill path runs exactly when the node
/// is over its memory budget, so the transient encode buffer must stay
/// O(chunk), never a second full copy of the block.
const SPILL_CHUNK_ELEMS: usize = 1 << 15; // 256 KiB of f64

/// Trailing checksum size: every spill file ends with the 16-byte LE
/// FNV-1a-128 digest of its data (hashed as exact f64 bits via
/// [`Fnv128::f64`]), so silent corruption — not just truncation — is
/// caught at read-back and routed into lineage recovery instead of
/// feeding wrong bits to a kernel. `Spilled::bytes` and all spill
/// counters stay *block* bytes; the trailer is a file-format detail.
const SPILL_TRAILER_BYTES: u64 = 16;

pub(crate) fn write_spill(path: &Path, data: &[f64]) -> std::io::Result<()> {
    use std::io::Write;
    let file = std::fs::File::create(path)?;
    let mut w = std::io::BufWriter::new(file);
    let mut buf = Vec::with_capacity(SPILL_CHUNK_ELEMS.min(data.len()) * 8);
    let mut sum = Fnv128::new();
    for chunk in data.chunks(SPILL_CHUNK_ELEMS) {
        buf.clear();
        for v in chunk {
            buf.extend_from_slice(&v.to_le_bytes());
            sum.f64(*v);
        }
        w.write_all(&buf)?;
    }
    w.write_all(&sum.digest().to_le_bytes())?;
    w.flush()
}

/// Chunked decode for the same reason as [`write_spill`]: the read-back
/// happens on a node already near its budget, so the transient raw-byte
/// buffer stays O(chunk) instead of a full second copy of the block.
/// Returns `None` on truncation *or* a checksum-trailer mismatch — the
/// caller treats both as an unreadable file (and, under fault
/// tolerance, recovers the object from lineage).
pub(crate) fn read_spill(path: &Path, bytes: u64) -> Option<Vec<f64>> {
    use std::io::Read;
    let mut file = std::fs::File::open(path).ok()?;
    if file.metadata().ok()?.len() != bytes + SPILL_TRAILER_BYTES {
        return None; // truncated or clobbered spill file
    }
    let mut out = Vec::with_capacity((bytes / 8) as usize);
    let mut buf = vec![0u8; (SPILL_CHUNK_ELEMS * 8).min(bytes.max(8) as usize)];
    let mut sum = Fnv128::new();
    let mut remaining = bytes as usize;
    while remaining > 0 {
        let take = remaining.min(buf.len());
        file.read_exact(&mut buf[..take]).ok()?;
        for c in buf[..take].chunks_exact(8) {
            let v = f64::from_le_bytes(c.try_into().unwrap());
            sum.f64(v);
            out.push(v);
        }
        remaining -= take;
    }
    let mut trailer = [0u8; SPILL_TRAILER_BYTES as usize];
    file.read_exact(&mut trailer).ok()?;
    if u128::from_le_bytes(trailer) != sum.digest() {
        return None; // bit rot: corrupt data must never reach a kernel
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blk(n: usize, fill: f64) -> Arc<Block> {
        Arc::new(Block::filled(&[n, 1], fill))
    }

    fn all(_: ObjectId) -> bool {
        true
    }
    const ALL: &dyn Fn(ObjectId) -> bool = &all;

    #[test]
    fn insert_spills_coldest_beyond_budget_and_acquire_reads_back() {
        let stores = StoreSet::new(1);
        // budget = 2 blocks of 80 bytes
        let mgr = MemoryManager::new(1, Some(160), true);
        for id in 0..4u64 {
            mgr.insert(&stores, 0, id, blk(10, id as f64), ALL);
        }
        // residency never exceeded the budget; the two coldest spilled
        assert!(stores.node_bytes(0) <= 160);
        let st = &mgr.stats()[0];
        assert_eq!(st.spilled_bytes, 160, "two 80-byte blocks paged out");
        assert!(!stores.contains(0, 0) && !stores.contains(0, 1));
        // acquire a spilled block: read back bit-identically
        let (b, moved) = mgr.acquire(&stores, 0, 0, ALL);
        let b = b.unwrap();
        assert_eq!(moved, 0, "read-back is disk, not network");
        assert!(b.buf().iter().all(|&v| v == 0.0));
        assert_eq!(b.shape, vec![10, 1]);
        assert_eq!(mgr.stats()[0].readback_bytes, 80);
    }

    #[test]
    fn spill_roundtrip_preserves_exact_bits() {
        let stores = StoreSet::new(1);
        let mgr = MemoryManager::new(1, Some(80), true);
        let mut rng = crate::util::rng::Rng::seed_from_u64(0x5B111);
        let mut v = vec![0.0; 10];
        rng.fill_normal(&mut v);
        let original = v.clone();
        mgr.insert(&stores, 0, 1, Arc::new(Block::from_vec(&[10, 1], v)), ALL);
        // a second insert pushes object 1 to disk
        mgr.insert(&stores, 0, 2, blk(10, 2.0), ALL);
        assert!(!stores.contains(0, 1), "object 1 must have spilled");
        let (b, _) = mgr.acquire(&stores, 0, 1, ALL);
        let b = b.unwrap();
        for (a, w) in b.buf().iter().zip(&original) {
            assert_eq!(a.to_bits(), w.to_bits(), "spill round-trip changed bits");
        }
    }

    #[test]
    fn pinned_blocks_never_spill() {
        let stores = StoreSet::new(1);
        let mgr = MemoryManager::new(1, Some(80), true);
        let pinned = |o: ObjectId| o != 7; // 7 is pinned (not spillable)
        mgr.insert(&stores, 0, 7, blk(10, 7.0), &pinned);
        mgr.insert(&stores, 0, 8, blk(10, 8.0), &pinned);
        // 8 (the only spillable block) pages out even though 7 is colder
        assert!(stores.contains(0, 7), "pinned block evicted");
        assert!(!stores.contains(0, 8));
    }

    #[test]
    fn replicas_evicted_before_any_spill_and_primary_survives() {
        let stores = StoreSet::new(2);
        let mgr = MemoryManager::new(2, Some(160), true);
        mgr.insert(&stores, 0, 1, blk(10, 1.0), ALL);
        // pull object 1 to node 1: now a replica there
        let (b, moved) = mgr.acquire(&stores, 1, 1, ALL);
        assert!(b.is_some());
        assert_eq!(moved, 80, "cross-node pull pays bytes");
        assert!(stores.contains(1, 1));
        // pressure node 1 past its budget: the replica goes first, free
        mgr.insert(&stores, 1, 2, blk(10, 2.0), ALL);
        mgr.insert(&stores, 1, 3, blk(10, 3.0), ALL);
        let st = &mgr.stats()[1];
        assert_eq!(st.evicted_replica_bytes, 80, "replica evicted, not spilled");
        assert_eq!(st.spilled_bytes, 0);
        assert!(!stores.contains(1, 1), "replica gone from node 1");
        assert!(stores.contains(0, 1), "primary intact on node 0");
        // and the object is still acquirable on node 1 (re-pull)
        let (b, moved2) = mgr.acquire(&stores, 1, 1, ALL);
        assert_eq!(moved2, 80);
        assert_eq!(b.unwrap().buf()[0], 1.0);
    }

    #[test]
    fn release_evicts_everywhere_and_deletes_spill_files() {
        let stores = StoreSet::new(2);
        let mgr = MemoryManager::new(2, Some(80), true);
        mgr.insert(&stores, 0, 1, blk(10, 1.0), ALL);
        mgr.insert(&stores, 0, 2, blk(10, 2.0), ALL); // spills 1
        assert!(mgr.holds(&stores, 1));
        let spill_file = mgr.spill_path(0, 1);
        assert!(spill_file.exists(), "spill file must be on disk");
        mgr.release(&stores, 1);
        mgr.release(&stores, 2);
        assert!(!mgr.holds(&stores, 1));
        assert!(!spill_file.exists(), "release must delete the spill file");
        assert_eq!(stores.node_bytes(0), 0);
        assert!(mgr.stats()[0].gc_freed_bytes >= 160);
    }

    #[test]
    fn fetch_reads_spilled_blocks_without_changing_residency() {
        let stores = StoreSet::new(1);
        let mgr = MemoryManager::new(1, Some(80), true);
        mgr.insert(&stores, 0, 1, blk(10, 4.5), ALL);
        mgr.insert(&stores, 0, 2, blk(10, 2.0), ALL); // spills 1
        let b = mgr.fetch(&stores, 1).expect("spilled block fetchable");
        assert!(b.buf().iter().all(|&v| v == 4.5));
        assert!(!stores.contains(0, 1), "gather must not re-admit the block");
        assert!(mgr.fetch(&stores, 99).is_none());
    }

    #[test]
    fn no_budget_means_no_spill() {
        let stores = StoreSet::new(1);
        let mgr = MemoryManager::new(1, None, true);
        for id in 0..16u64 {
            mgr.insert(&stores, 0, id, blk(100, id as f64), ALL);
        }
        let st = &mgr.stats()[0];
        assert_eq!(st.spilled_bytes, 0);
        assert_eq!(stores.node_bytes(0), 16 * 800);
    }

    #[test]
    fn stats_delta_subtracts() {
        let a = NodeMemStats {
            spilled_bytes: 100,
            readback_bytes: 50,
            evicted_replica_bytes: 10,
            gc_freed_bytes: 7,
            spill_reuse_bytes: 5,
        };
        let b = NodeMemStats {
            spilled_bytes: 40,
            readback_bytes: 50,
            evicted_replica_bytes: 0,
            gc_freed_bytes: 7,
            spill_reuse_bytes: 5,
        };
        let d = a.delta(&b);
        assert_eq!(d.spilled_bytes, 60);
        assert_eq!(d.readback_bytes, 0);
        assert_eq!(d.evicted_replica_bytes, 10);
        assert_eq!(d.gc_freed_bytes, 0);
        assert_eq!(d.spill_reuse_bytes, 0);
    }

    #[test]
    fn respill_of_unchanged_object_reuses_the_file() {
        // budget = 1 block: objects 1 and 2 keep displacing each other.
        // Each must be *written* exactly once; later spills of the same
        // unchanged object just drop the resident copy (clean bit).
        let stores = StoreSet::new(1);
        let mgr = MemoryManager::new(1, Some(80), true);
        mgr.insert(&stores, 0, 1, blk(10, 1.0), ALL);
        mgr.insert(&stores, 0, 2, blk(10, 2.0), ALL); // writes 1
        assert_eq!(mgr.stats()[0].spilled_bytes, 80);
        let spill_file = mgr.spill_path(0, 1);
        assert!(spill_file.exists());
        // read 1 back: 2 pages out (first write for 2), and 1's file is
        // kept — its resident copy is now clean
        let (b1, _) = mgr.acquire(&stores, 0, 1, ALL);
        assert_eq!(b1.unwrap().buf()[0], 1.0);
        assert!(spill_file.exists(), "read-back must keep the spill file");
        assert_eq!(mgr.stats()[0].spilled_bytes, 160, "2 paged out, one write");
        // read 2 back: 1 is re-spilled, but its file is current — no write
        let (b2, _) = mgr.acquire(&stores, 0, 2, ALL);
        assert_eq!(b2.unwrap().buf()[0], 2.0);
        let st = &mgr.stats()[0];
        assert_eq!(st.spilled_bytes, 160, "unchanged object must not rewrite");
        assert_eq!(st.spill_reuse_bytes, 80, "re-spill of 1 reused its file");
        // and the reused copy still reads back bit-correct
        let (b1b, _) = mgr.acquire(&stores, 0, 1, ALL);
        assert_eq!(b1b.unwrap().buf()[0], 1.0);
    }

    #[test]
    fn reput_invalidates_the_clean_spill_copy() {
        let stores = StoreSet::new(1);
        let mgr = MemoryManager::new(1, Some(80), true);
        mgr.insert(&stores, 0, 1, blk(10, 1.0), ALL);
        mgr.insert(&stores, 0, 2, blk(10, 2.0), ALL); // writes 1
        mgr.acquire(&stores, 0, 1, ALL).0.unwrap(); // 1 clean-resident
        // new contents for 1: the old file must die with the clean bit
        mgr.insert(&stores, 0, 1, blk(10, 9.0), ALL);
        // pressure 1 out again: this must be a fresh write, not a reuse
        mgr.acquire(&stores, 0, 2, ALL).0.unwrap();
        let (b, _) = mgr.acquire(&stores, 0, 1, ALL);
        assert_eq!(b.unwrap().buf()[0], 9.0, "stale spill file served after re-put");
        assert_eq!(mgr.stats()[0].spill_reuse_bytes, 0);
    }

    #[test]
    fn async_spill_parks_pending_blocks_until_swept() {
        // sink attached but never serviced: victims leave the store
        // instantly, data stays readable from the pending entry, and the
        // write-completion sweep finalizes files + counters
        let stores = StoreSet::new(1);
        let mgr = MemoryManager::new(1, Some(80), true);
        let notified = Arc::new(std::sync::atomic::AtomicUsize::new(0));
        let n2 = Arc::clone(&notified);
        mgr.attach_spill_sink(Arc::new(move |_node| {
            n2.fetch_add(1, Ordering::Relaxed);
        }));
        mgr.insert(&stores, 0, 1, blk(10, 1.0), ALL);
        mgr.insert(&stores, 0, 2, blk(10, 2.0), ALL); // queues 1
        assert!(notified.load(Ordering::Relaxed) >= 1, "sink must be notified");
        assert!(!stores.contains(0, 1), "victim leaves the store immediately");
        assert_eq!(mgr.stats()[0].spilled_bytes, 0, "write not performed yet");
        assert!(
            !mgr.spill_path(0, 1).exists(),
            "no file before the transfer thread runs"
        );
        // acquire while pending: served from the parked block, no disk
        let (b, moved) = mgr.acquire(&stores, 0, 1, ALL);
        assert_eq!(moved, 0);
        assert_eq!(b.unwrap().buf()[0], 1.0);
        // the barrier: sweep completes whatever write is still queued
        // (re-acquiring 1 displaced 2, so 2 is pending now)
        let written = mgr.sweep_pending_spills(&stores);
        assert!(written > 0, "sweep must perform the queued writes");
        assert_eq!(mgr.stats()[0].spilled_bytes, written);
        mgr.detach_spill_sink();
        let (b2, _) = mgr.acquire(&stores, 0, 2, ALL);
        assert_eq!(b2.unwrap().buf()[0], 2.0, "swept file must read back correctly");
    }

    #[test]
    fn corrupt_spill_file_is_rejected_not_served() {
        let stores = StoreSet::new(1);
        let mgr = MemoryManager::new(1, Some(80), true);
        mgr.insert(&stores, 0, 1, blk(10, 1.5), ALL);
        mgr.insert(&stores, 0, 2, blk(10, 2.0), ALL); // writes 1
        let path = mgr.spill_path(0, 1);
        assert!(path.exists());
        // flip one data byte in place: length still matches, so only the
        // checksum trailer can catch it
        let mut raw = std::fs::read(&path).unwrap();
        raw[3] ^= 0xFF;
        std::fs::write(&path, &raw).unwrap();
        let (b, _) = mgr.acquire(&stores, 0, 1, ALL);
        assert!(b.is_none(), "corrupt bits must never reach a caller");
        assert!(mgr.fetch(&stores, 1).is_none(), "gather must reject them too");
    }

    #[test]
    fn injected_spill_faults_are_survived_by_bounded_retry() {
        use crate::exec::fault::{FaultInjector, FaultPlan};
        let stores = StoreSet::new(1);
        let mgr = MemoryManager::new(1, Some(80), true);
        // rate 1.0: every spill write and readback fails
        // MAX_INJECTIONS_PER_KEY times before the real I/O happens
        mgr.attach_fault(Arc::new(FaultInjector::new(&FaultPlan::new(5, 1.0))));
        mgr.insert(&stores, 0, 1, blk(10, 4.0), ALL);
        mgr.insert(&stores, 0, 2, blk(10, 2.0), ALL); // spills 1 (with retries)
        assert_eq!(mgr.stats()[0].spilled_bytes, 80, "write must land despite faults");
        assert!(!stores.contains(0, 1));
        let (b, _) = mgr.acquire(&stores, 0, 1, ALL);
        assert_eq!(
            b.expect("readback retries fit the total-miss window").buf()[0],
            4.0
        );
        mgr.detach_fault();
    }

    #[test]
    fn wipe_node_drops_unspared_copies_and_reports_bytes() {
        let stores = StoreSet::new(2);
        let mgr = MemoryManager::new(2, Some(160), true);
        mgr.insert(&stores, 0, 1, blk(10, 1.0), ALL); // resident
        mgr.insert(&stores, 0, 2, blk(10, 2.0), ALL); // resident
        mgr.insert(&stores, 0, 3, blk(10, 3.0), ALL); // spills the coldest (1)
        assert!(!stores.contains(0, 1), "1 must be on disk");
        let spare = |o: ObjectId| o == 2;
        let lost = mgr.wipe_node(&stores, 0, &spare);
        assert_eq!(lost, vec![(1, 80), (3, 80)], "sorted (object, bytes) pairs");
        assert!(stores.contains(0, 2), "spared object survives");
        assert!(!mgr.holds(&stores, 1), "spill copy wiped with the node");
        assert!(!mgr.spill_path(0, 1).exists(), "spill file deleted");
        assert!(!stores.contains(0, 3));
        // node 1 untouched
        assert_eq!(mgr.wipe_node(&stores, 1, &|_| false), vec![]);
    }

    #[test]
    fn release_of_pending_spill_drops_the_parked_block() {
        let stores = StoreSet::new(1);
        let mgr = MemoryManager::new(1, Some(80), true);
        mgr.attach_spill_sink(Arc::new(|_| {}));
        mgr.insert(&stores, 0, 1, blk(10, 1.0), ALL);
        mgr.insert(&stores, 0, 2, blk(10, 2.0), ALL); // queues 1
        mgr.release(&stores, 1);
        assert!(!mgr.holds(&stores, 1));
        // the queued write finds its entry gone and must not leave a file
        assert_eq!(mgr.sweep_pending_spills(&stores), 0);
        assert!(!mgr.spill_path(0, 1).exists());
        assert_eq!(mgr.stats()[0].gc_freed_bytes, 80);
    }
}
