//! Blocks and per-node object stores — the object substrate of §3.

pub mod block;
pub mod object_store;

pub use block::{Block, BlockData};
pub use object_store::{IdGen, ObjectId, ObjectStore, StoreSet};
