//! Blocks, per-node object stores, and the distributed memory manager —
//! the object substrate of §3 plus the §8.1 memory-load machinery.

pub mod block;
pub mod memory;
pub mod object_store;

pub use block::{Block, BlockData};
pub use memory::{MemoryManager, NodeMemStats};
pub use object_store::{IdGen, ObjectId, ObjectStore, StoreSet};
