//! Load Simulated Hierarchical Scheduling — Algorithm 1 (§5).
//!
//! LSHS is a greedy local tree search: while the graph has a frontier,
//! sample a frontier vertex, simulate each placement option against the
//! cluster-state load model, take the option minimizing the Eq. 2
//! objective, and transition the graph. The final operation of every
//! output block is pinned to the hierarchical data layout, so every
//! GraphArray the system produces is again hierarchically laid out —
//! that invariant is what makes element-wise chains communication-free
//! (App. A.1).

use crate::exec::task::Plan;
use crate::graph::vertex::Vertex;
use crate::graph::Graph;
use crate::grid::{ArrayGrid, Layout, NodeGrid};
use crate::store::IdGen;
use crate::util::rng::Rng;

use super::{
    commit_op, commit_reduce_pair, location_union_into, op_view, reduce_leaf_positions,
    ClusterState, PlacementScratch, Scheduler, Topology,
};

pub struct Lshs {
    pub layout: Layout,
    topo: Topology,
    rng: Rng,
    /// Placement decisions made (for perf reports).
    pub decisions: u64,
    /// Candidate simulations evaluated.
    pub simulations: u64,
    /// Reusable inner-loop buffers: candidate-simulation scratch and the
    /// placement-option set. The frontier loop runs
    /// `decisions × options` simulations per graph; with these held here,
    /// none of them allocates (the per-decision *commit* still builds its
    /// owned `Task`, which outlives the search).
    scratch: PlacementScratch,
    options_buf: Vec<usize>,
}

impl Lshs {
    pub fn new(node_grid: NodeGrid, topo: Topology, seed: u64) -> Self {
        assert_eq!(node_grid.num_nodes(), topo.nodes, "node grid vs cluster");
        Self {
            layout: Layout::new(node_grid, topo.workers_per_node),
            topo,
            rng: Rng::seed_from_u64(seed),
            decisions: 0,
            simulations: 0,
            scratch: PlacementScratch::default(),
            options_buf: Vec::new(),
        }
    }

    /// Pin the root op of every output block to its hierarchical-layout
    /// target (the paper's transition-function invariant, §5).
    fn pin_outputs(&self, graph: &mut Graph) {
        // single flat pass: no per-output intermediate Vec, no grid clone
        let mut pins: Vec<(usize, usize)> = Vec::new();
        for out in &graph.outputs {
            for (flat, &(vid, _)) in out.roots.iter().enumerate() {
                let coords = out.grid.coords_of(flat);
                let p = self.layout.place_block(&out.grid, &coords);
                pins.push((vid, self.topo.target_of(p)));
            }
        }
        for (vid, target) in pins {
            graph.set_constraint(vid, target);
        }
    }

    /// Choose the best placement among `options` for an op producing
    /// `out_elems`, by simulating each (Algorithm 1's inner loop). An
    /// associated fn over explicitly-passed scratch/counter so the caller
    /// can hold `options` borrowed from `self.options_buf` at the same
    /// time; `placement_cost_into` keeps every candidate allocation-free.
    fn best_target(
        state: &ClusterState,
        options: &[usize],
        inputs: &[crate::store::ObjectId],
        out_elems: f64,
        scratch: &mut PlacementScratch,
        simulations: &mut u64,
    ) -> usize {
        debug_assert!(!options.is_empty());
        let mut best = options[0];
        let mut best_cost = f64::INFINITY;
        for &t in options {
            *simulations += 1;
            let cost = state.placement_cost_into(t, inputs, out_elems, scratch);
            if cost < best_cost {
                best_cost = cost;
                best = t;
            }
        }
        best
    }

    /// Locality-aware operand pairing for a Reduce (§4): prefer two leaf
    /// operands on the same target, then two on the same physical node,
    /// else the first two leaves.
    fn choose_pair(
        &self,
        graph: &Graph,
        state: &ClusterState,
        vid: usize,
    ) -> (usize, usize) {
        let positions = reduce_leaf_positions(graph, vid);
        debug_assert!(positions.len() >= 2);
        let children = graph.vertices[vid].children();
        let primary = |pos: usize| -> usize {
            let obj = graph.resolve(children[pos]);
            state.locations_of(obj).first().copied().unwrap_or(0)
        };
        // same target
        for (ai, &a) in positions.iter().enumerate() {
            for &b in positions.iter().skip(ai + 1) {
                if primary(a) == primary(b) {
                    return (a, b);
                }
            }
        }
        // same physical node
        for (ai, &a) in positions.iter().enumerate() {
            for &b in positions.iter().skip(ai + 1) {
                if state.topo.same_node(primary(a), primary(b)) {
                    return (a, b);
                }
            }
        }
        (positions[0], positions[1])
    }
}

impl Scheduler for Lshs {
    fn name(&self) -> String {
        "lshs".into()
    }

    fn search_stats(&self) -> (u64, u64) {
        (self.decisions, self.simulations)
    }

    fn place_creation(&mut self, grid: &ArrayGrid, state: &mut ClusterState) -> Vec<usize> {
        // Hierarchical data layout (§4): cyclic over the node grid, round
        // robin over workers within each node.
        let _ = state;
        self.layout
            .place_all(grid)
            .into_iter()
            .map(|p| self.topo.target_of(p))
            .collect()
    }

    fn schedule(
        &mut self,
        graph: &mut Graph,
        state: &mut ClusterState,
        ids: &IdGen,
        plan: &mut Plan,
    ) {
        self.pin_outputs(graph);
        // Incremental frontier:
        // rescanning every vertex per step is O(V²); instead track the
        // candidate set and wake parents when a child resolves to a leaf.
        let eligible = |graph: &Graph, v: usize| -> bool {
            match &graph.vertices[v] {
                Vertex::Leaf { .. } => false,
                Vertex::Op { children, .. } => {
                    children.iter().all(|&(c, _)| graph.is_leaf(c))
                }
                Vertex::Reduce { children, .. } => {
                    children.iter().filter(|&&(c, _)| graph.is_leaf(c)).count() >= 2
                }
            }
        };
        // parent edges (built once)
        let mut parents: Vec<Vec<usize>> = vec![Vec::new(); graph.vertices.len()];
        for (vid, v) in graph.vertices.iter().enumerate() {
            for &(c, _) in v.children() {
                parents[c].push(vid);
            }
        }
        let mut frontier: Vec<usize> = (0..graph.vertices.len())
            .filter(|&v| eligible(graph, v))
            .collect();
        let mut in_list = vec![false; graph.vertices.len()];
        for &v in &frontier {
            in_list[v] = true;
        }

        loop {
            // Algorithm 1: sample a frontier vertex (skip stale entries).
            let vid = loop {
                if frontier.is_empty() {
                    break None;
                }
                let idx = self.rng.usize(frontier.len());
                let v = frontier[idx];
                if eligible(graph, v) {
                    break Some((idx, v));
                }
                in_list[v] = false;
                frontier.swap_remove(idx);
            };
            let Some((idx, vid)) = vid else { break };
            match &graph.vertices[vid] {
                Vertex::Op { .. } => {
                    let view = op_view(graph, vid);
                    let out_elems: f64 = view
                        .kernel
                        .out_shapes(&view.in_shapes)
                        .iter()
                        .map(|s| s.iter().map(|&d| d as f64).product::<f64>())
                        .sum();
                    match view.constraint {
                        Some(c) => {
                            self.options_buf.clear();
                            self.options_buf.push(c);
                        }
                        None => {
                            location_union_into(state, &view.inputs, &mut self.options_buf);
                            if self.options_buf.is_empty() {
                                self.options_buf.push(0);
                            }
                        }
                    }
                    let target = Self::best_target(
                        state,
                        &self.options_buf,
                        &view.inputs,
                        out_elems,
                        &mut self.scratch,
                        &mut self.simulations,
                    );
                    self.decisions += 1;
                    commit_op(graph, state, ids, plan, vid, target);
                    // vid is now a leaf: retire it, wake eligible parents
                    in_list[vid] = false;
                    frontier.swap_remove(idx);
                    for &p in &parents[vid] {
                        if !in_list[p] && eligible(graph, p) {
                            in_list[p] = true;
                            frontier.push(p);
                        }
                    }
                }
                Vertex::Reduce { children, constraint, .. } => {
                    let constraint = *constraint;
                    let final_pair = children.len() == 2;
                    let (pa, pb) = self.choose_pair(graph, state, vid);
                    let (ca, cb) = {
                        let ch = graph.vertices[vid].children();
                        (ch[pa], ch[pb])
                    };
                    // stack pair, not a heap Vec: one reduce step is
                    // always binary
                    let inputs = [graph.resolve(ca), graph.resolve(cb)];
                    let elems: f64 = graph
                        .ref_shape(ca)
                        .iter()
                        .map(|&d| d as f64)
                        .product();
                    match (final_pair, constraint) {
                        (true, Some(c)) => {
                            self.options_buf.clear();
                            self.options_buf.push(c);
                        }
                        _ => {
                            location_union_into(state, &inputs, &mut self.options_buf);
                            if self.options_buf.is_empty() {
                                self.options_buf.push(0);
                            }
                        }
                    }
                    let target = Self::best_target(
                        state,
                        &self.options_buf,
                        &inputs,
                        elems,
                        &mut self.scratch,
                        &mut self.simulations,
                    );
                    self.decisions += 1;
                    commit_reduce_pair(graph, state, ids, plan, vid, pa, pb, target);
                    // commit may have grown the arena (new leaf vertex)
                    if parents.len() < graph.vertices.len() {
                        parents.resize(graph.vertices.len(), Vec::new());
                        in_list.resize(graph.vertices.len(), false);
                    }
                    if graph.is_leaf(vid) {
                        // reduce collapsed: retire and wake parents
                        in_list[vid] = false;
                        frontier.swap_remove(idx);
                        for &p in &parents[vid] {
                            if !in_list[p] && eligible(graph, p) {
                                in_list[p] = true;
                                frontier.push(p);
                            }
                        }
                    }
                    // otherwise the reduce stays sampled (still >= 2 leaves
                    // or will be lazily retired on next sample)
                }
                Vertex::Leaf { .. } => unreachable!("leaf on frontier"),
            }
        }
        debug_assert!(graph.done(), "LSHS terminated with unresolved vertices");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{build, DistArray};
    use crate::net::model::SystemMode;
    use crate::runtime::kernel::{BinOp, EwStep};
    use crate::store::IdGen;

    fn setup(k: usize) -> (Lshs, ClusterState, IdGen) {
        let topo = Topology::new(k, 4, SystemMode::Ray);
        let lshs = Lshs::new(NodeGrid::linear(k), topo.clone(), 42);
        (lshs, ClusterState::new(topo), IdGen::default())
    }

    fn create(
        sched: &mut Lshs,
        state: &mut ClusterState,
        ids: &IdGen,
        shape: &[usize],
        grid: &[usize],
    ) -> DistArray {
        let g = ArrayGrid::new(shape, grid);
        let targets = sched.place_creation(&g, state);
        let blocks: Vec<u64> = (0..g.num_blocks()).map(|_| ids.next()).collect();
        for (f, c) in g.iter_coords().enumerate() {
            state.register(blocks[f], g.block_elems(&c) as f64, targets[f]);
        }
        DistArray::new(g, blocks, targets)
    }

    #[test]
    fn elementwise_is_communication_free() {
        // App. A.1: equal shape+grid operands co-locate -> zero transfers.
        let (mut sched, mut state, ids) = setup(4);
        let a = create(&mut sched, &mut state, &ids, &[1024, 64], &[8, 1]);
        let b = create(&mut sched, &mut state, &ids, &[1024, 64], &[8, 1]);
        let mut graph = crate::graph::Graph::new();
        build::binary_ew(&mut graph, &a, &b, BinOp::Add);
        let mut plan = Plan::new();
        sched.schedule(&mut graph, &mut state, &ids, &mut plan);
        assert_eq!(plan.len(), 8);
        assert_eq!(plan.transfer_count(), 0, "X+Y must move zero bytes");
    }

    #[test]
    fn fused_chain_is_one_placement_decision_per_block() {
        // A 3-op chain over an 8-block array: after fusion the scheduler
        // sees one vertex per block — one decision, one task, zero bytes
        // moved (the fused vertex inherits the App. A.1 layout alignment).
        let (mut sched, mut state, ids) = setup(4);
        let a = create(&mut sched, &mut state, &ids, &[1024, 64], &[8, 1]);
        let b = create(&mut sched, &mut state, &ids, &[1024, 64], &[8, 1]);
        let mut graph = crate::graph::Graph::new();
        build::ew_chain(
            &mut graph,
            &a,
            &[&b],
            &[EwStep::Neg, EwStep::Bin(BinOp::Add), EwStep::Sigmoid],
        );
        crate::graph::fuse::fuse_elementwise(&mut graph);
        let mut plan = Plan::new();
        sched.schedule(&mut graph, &mut state, &ids, &mut plan);
        assert_eq!(plan.len(), 8, "one fused task per block");
        assert_eq!(sched.decisions, 8, "one placement decision per block");
        assert_eq!(plan.transfer_count(), 0, "chains stay communication-free");
    }

    #[test]
    fn matmul_terminates_and_balances() {
        let (mut sched, mut state, ids) = setup(2);
        let a = create(&mut sched, &mut state, &ids, &[64, 64], &[2, 2]);
        let b = create(&mut sched, &mut state, &ids, &[64, 64], &[2, 2]);
        let mut graph = crate::graph::Graph::new();
        build::matmul(&mut graph, &a, &b);
        let mut plan = Plan::new();
        sched.schedule(&mut graph, &mut state, &ids, &mut plan);
        assert!(graph.done());
        assert_eq!(plan.len(), 12); // 8 matmul + 4 reduce-adds
        let per = plan.tasks_per_target(2);
        assert!(per[0] > 0 && per[1] > 0, "both nodes used: {per:?}");
    }

    #[test]
    fn deterministic_given_seed() {
        let run = || {
            let (mut sched, mut state, ids) = setup(4);
            let a = create(&mut sched, &mut state, &ids, &[64, 64], &[4, 4]);
            let b = create(&mut sched, &mut state, &ids, &[64, 64], &[4, 4]);
            let mut graph = crate::graph::Graph::new();
            build::matmul(&mut graph, &a, &b);
            let mut plan = Plan::new();
            sched.schedule(&mut graph, &mut state, &ids, &mut plan);
            plan.tasks
                .iter()
                .map(|t| t.target)
                .collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn outputs_land_on_layout_targets() {
        let (mut sched, mut state, ids) = setup(4);
        let a = create(&mut sched, &mut state, &ids, &[512, 8], &[4, 1]);
        let y = create(&mut sched, &mut state, &ids, &[512, 1], &[4, 1]);
        let beta = create(&mut sched, &mut state, &ids, &[8, 1], &[1, 1]);
        let mut graph = crate::graph::Graph::new();
        build::glm_newton(&mut graph, &a, &y, &beta);
        let mut plan = Plan::new();
        sched.schedule(&mut graph, &mut state, &ids, &mut plan);
        // g, H, loss are single-block outputs -> block (0,0) -> node 0 (§6)
        for out in &graph.outputs {
            let obj = graph.resolve(out.roots[0]);
            assert!(
                state.locations_of(obj).contains(&0),
                "output must satisfy hierarchical layout"
            );
        }
    }
}
