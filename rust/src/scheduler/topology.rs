//! Placement-target topology: node-granular on Ray, worker-granular on
//! Dask (§3, Fig. 3).

use crate::grid::{Layout, Placement};
use crate::net::model::SystemMode;

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Topology {
    pub nodes: usize,
    pub workers_per_node: usize,
    pub mode: SystemMode,
}

impl Topology {
    pub fn new(nodes: usize, workers_per_node: usize, mode: SystemMode) -> Self {
        assert!(nodes >= 1 && workers_per_node >= 1);
        Self {
            nodes,
            workers_per_node,
            mode,
        }
    }

    /// Number of placement targets the scheduler chooses among.
    pub fn targets(&self) -> usize {
        match self.mode {
            SystemMode::Ray => self.nodes,
            SystemMode::Dask => self.nodes * self.workers_per_node,
        }
    }

    /// Physical node of a placement target.
    pub fn node_of(&self, target: usize) -> usize {
        match self.mode {
            SystemMode::Ray => target,
            SystemMode::Dask => target / self.workers_per_node,
        }
    }

    /// Worker index within the node, when the mode distinguishes workers.
    pub fn worker_of(&self, target: usize) -> Option<usize> {
        match self.mode {
            SystemMode::Ray => None,
            SystemMode::Dask => Some(target % self.workers_per_node),
        }
    }

    pub fn same_node(&self, a: usize, b: usize) -> bool {
        self.node_of(a) == self.node_of(b)
    }

    /// Convert a hierarchical-layout placement to a target id.
    pub fn target_of(&self, p: Placement) -> usize {
        match self.mode {
            SystemMode::Ray => p.node,
            SystemMode::Dask => p.node * self.workers_per_node + p.worker,
        }
    }

    /// Total workers (`p` in the paper).
    pub fn total_workers(&self) -> usize {
        self.nodes * self.workers_per_node
    }

    /// Layout helper bound to this topology's worker count.
    pub fn layout(&self, node_grid: crate::grid::NodeGrid) -> Layout {
        Layout::new(node_grid, self.workers_per_node)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grid::NodeGrid;

    #[test]
    fn ray_targets_are_nodes() {
        let t = Topology::new(4, 8, SystemMode::Ray);
        assert_eq!(t.targets(), 4);
        assert_eq!(t.node_of(3), 3);
        assert_eq!(t.worker_of(3), None);
        assert_eq!(t.target_of(Placement { node: 2, worker: 5 }), 2);
    }

    #[test]
    fn dask_targets_are_workers() {
        let t = Topology::new(4, 8, SystemMode::Dask);
        assert_eq!(t.targets(), 32);
        assert_eq!(t.node_of(17), 2);
        assert_eq!(t.worker_of(17), Some(1));
        assert!(t.same_node(16, 23));
        assert!(!t.same_node(15, 16));
        assert_eq!(t.target_of(Placement { node: 2, worker: 5 }), 21);
    }

    #[test]
    fn layout_roundtrip() {
        let t = Topology::new(4, 2, SystemMode::Dask);
        let layout = t.layout(NodeGrid::new(&[2, 2]));
        assert_eq!(layout.workers_per_node, 2);
    }
}
