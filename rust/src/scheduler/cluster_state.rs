//! Cluster state: the load model LSHS simulates placements against (§5.1).
//!
//! `S` is the paper's k×3 matrix — memory, network-in, network-out per
//! placement target, counted in *elements* as in the paper — and `M` the
//! object→locations map. The objective (Eq. 2) is
//! `max_j S[j,mem] + max_j S[j,in] + max_j S[j,out]` after simulating the
//! candidate action; [`ClusterState::placement_cost`] evaluates it without
//! mutating (the LSHS inner loop), and [`ClusterState::apply`] commits.
//!
//! In Dask mode targets are workers and same-physical-node transfers are
//! discounted by `intra_discount` (the paper's footnote 1 coefficient);
//! Ray-mode targets are nodes, where intra-node movement is free via the
//! shared-memory store.
//!
//! The model is kept honest against the real executor from both sides:
//! [`ClusterState::forget`] removes objects the runtime's lifetime GC
//! freed, and [`ClusterState::absorb_feedback`] folds in the load the
//! runtime created that the plan never committed — steal traffic, spill
//! pressure, and the replica copies stolen work left behind
//! ([`crate::exec::RuntimeFeedback`]).

use std::collections::HashMap;

use crate::net::model::SystemMode;
use crate::store::ObjectId;

use super::topology::Topology;

#[derive(Clone, Debug)]
pub struct ClusterState {
    pub topo: Topology,
    pub mem: Vec<f64>,
    pub net_in: Vec<f64>,
    pub net_out: Vec<f64>,
    /// M: object -> targets holding a copy (first = producer).
    locations: HashMap<ObjectId, Vec<usize>>,
    /// object -> elements.
    sizes: HashMap<ObjectId, f64>,
    /// Dask footnote-1 coefficient for same-node worker-to-worker loads.
    pub intra_discount: f64,
    // cached maxima so the objective is O(1) per candidate
    max_mem: f64,
    max_in: f64,
    max_out: f64,
}

/// The load delta a placement would cause (reused by `apply`).
#[derive(Clone, Debug, Default)]
pub struct PlacementSim {
    /// (obj, src, charged elems, raw elems) per missing input.
    pub pulls: Vec<(ObjectId, usize, f64, u64)>,
    pub cost: f64,
}

/// Reusable buffers for [`ClusterState::placement_cost_into`]. The LSHS
/// inner loop evaluates `options × decisions` candidates per graph; with
/// a scratch held by the scheduler, none of them touches the allocator —
/// the buffers grow to the widest candidate once and are cleared (not
/// freed) between evaluations.
#[derive(Clone, Debug, Default)]
pub struct PlacementScratch {
    /// (obj, src, charged elems, raw elems) per missing input of the most
    /// recent simulation — same layout as [`PlacementSim::pulls`].
    pub pulls: Vec<(ObjectId, usize, f64, u64)>,
    /// Per-source accumulated outbound charge within one simulation.
    src_extra: Vec<(usize, f64)>,
}

impl ClusterState {
    pub fn new(topo: Topology) -> Self {
        let n = topo.targets();
        Self {
            topo,
            mem: vec![0.0; n],
            net_in: vec![0.0; n],
            net_out: vec![0.0; n],
            locations: HashMap::new(),
            sizes: HashMap::new(),
            intra_discount: 0.25,
            max_mem: 0.0,
            max_in: 0.0,
            max_out: 0.0,
        }
    }

    pub fn targets(&self) -> usize {
        self.mem.len()
    }

    /// Register a creation-time object resident at `target`.
    pub fn register(&mut self, obj: ObjectId, elems: f64, target: usize) {
        self.mem[target] += elems;
        self.max_mem = self.max_mem.max(self.mem[target]);
        self.locations.entry(obj).or_default().push(target);
        self.sizes.insert(obj, elems);
    }

    pub fn locations_of(&self, obj: ObjectId) -> &[usize] {
        self.locations
            .get(&obj)
            .map(|v| v.as_slice())
            .unwrap_or(&[])
    }

    /// Drop a dead object from the load model: the real executor's
    /// lifetime GC freed it, so later `schedule()` calls on this session
    /// must not count its bytes in the Eq. 2 memory term. Every copy's
    /// elements leave `mem` and the cached maximum is recomputed (the
    /// network terms stay — they model cumulative traffic, which really
    /// happened). No-op for unknown ids.
    pub fn forget(&mut self, obj: ObjectId) {
        let Some(elems) = self.sizes.remove(&obj) else { return };
        if let Some(locs) = self.locations.remove(&obj) {
            // one entry per copy: placement_cost never pulls to a target
            // already in the list, so entries are distinct
            for t in locs {
                self.mem[t] -= elems;
            }
        }
        self.max_mem = self.mem.iter().cloned().fold(0.0, f64::max);
    }

    /// Drop only `node`'s copies of `obj` from the load model — the
    /// fault-tolerance counterpart of [`ClusterState::forget`], used when
    /// a node loss wiped that node's store but other copies (or a
    /// lineage recompute) keep the object alive. If this would empty the
    /// location list the object is removed outright (same as `forget`):
    /// `placement_cost` panics on a tracked object with no locations, so
    /// a sole-copy loss must leave the model consistent — the session
    /// re-registers the object when recovery re-materializes it. No-op
    /// for unknown ids.
    pub fn forget_copies_on(&mut self, obj: ObjectId, node: usize) {
        let Some(&elems) = self.sizes.get(&obj) else { return };
        let Some(locs) = self.locations.get_mut(&obj) else {
            self.sizes.remove(&obj);
            return;
        };
        let before = locs.len();
        locs.retain(|&t| t != node);
        let dropped = before - locs.len();
        if dropped > 0 {
            self.mem[node] -= elems * dropped as f64;
        }
        if locs.is_empty() {
            self.locations.remove(&obj);
            self.sizes.remove(&obj);
        }
        self.max_mem = self.mem.iter().cloned().fold(0.0, f64::max);
    }

    pub fn size_of(&self, obj: ObjectId) -> f64 {
        *self.sizes.get(&obj).unwrap_or(&0.0)
    }

    /// Eq. 2 objective at the current state.
    pub fn objective(&self) -> f64 {
        self.max_mem + self.max_in + self.max_out
    }

    /// Discount factor for moving data `src -> dst`.
    fn charge_factor(&self, src: usize, dst: usize) -> f64 {
        if src == dst {
            0.0
        } else if self.topo.mode == SystemMode::Dask && self.topo.same_node(src, dst) {
            self.intra_discount
        } else {
            1.0
        }
    }

    /// Simulate placing an op with `inputs` at `target`, producing
    /// `out_elems` elements. Returns the Eq. 2 objective after the
    /// simulated transition plus the transfer decisions; does not mutate.
    ///
    /// Allocating convenience wrapper around
    /// [`ClusterState::placement_cost_into`] — the per-decision commit
    /// path and tests use this; the LSHS candidate loop uses the scratch
    /// variant directly so candidates never hit the allocator.
    pub fn placement_cost(&self, target: usize, inputs: &[ObjectId], out_elems: f64) -> PlacementSim {
        let mut scratch = PlacementScratch::default();
        let cost = self.placement_cost_into(target, inputs, out_elems, &mut scratch);
        PlacementSim {
            pulls: std::mem::take(&mut scratch.pulls),
            cost,
        }
    }

    /// [`ClusterState::placement_cost`] writing into caller-owned scratch:
    /// returns the Eq. 2 objective; the committed-transfer decisions land
    /// in `scratch.pulls` (cleared first). Zero heap allocation once the
    /// scratch has warmed to the widest candidate.
    pub fn placement_cost_into(
        &self,
        target: usize,
        inputs: &[ObjectId],
        out_elems: f64,
        scratch: &mut PlacementScratch,
    ) -> f64 {
        scratch.pulls.clear();
        scratch.src_extra.clear();
        let mut dst_mem = self.mem[target] + out_elems;
        let mut dst_in = self.net_in[target];
        let mut src_out_max: f64 = 0.0;
        for &obj in inputs {
            let locs = self.locations_of(obj);
            if locs.contains(&target) {
                continue;
            }
            let elems = self.size_of(obj);
            // choose the source with the least projected net_out;
            // src net_out accumulation must account for several pulls from
            // the same source within this one placement
            let src = *locs
                .iter()
                .min_by(|&&a, &&b| {
                    let ea = self.net_out[a] + extra(&scratch.src_extra, a);
                    let eb = self.net_out[b] + extra(&scratch.src_extra, b);
                    ea.partial_cmp(&eb).unwrap().then(a.cmp(&b))
                })
                .unwrap_or_else(|| panic!("object {obj} has no location"));
            let f = self.charge_factor(src, target);
            let charged = elems * f;
            dst_mem += elems; // the copy becomes resident regardless of mode
            dst_in += charged;
            bump(&mut scratch.src_extra, src, charged);
            src_out_max = src_out_max.max(self.net_out[src] + extra(&scratch.src_extra, src));
            scratch.pulls.push((obj, src, charged, elems as u64));
        }
        self.max_mem.max(dst_mem) + self.max_in.max(dst_in) + self.max_out.max(src_out_max)
    }

    /// Commit a simulated placement: move inputs, account the output.
    pub fn apply(
        &mut self,
        target: usize,
        sim: &PlacementSim,
        outputs: &[(ObjectId, f64)],
    ) {
        for &(obj, src, charged, raw) in &sim.pulls {
            self.net_out[src] += charged;
            self.max_out = self.max_out.max(self.net_out[src]);
            self.net_in[target] += charged;
            self.max_in = self.max_in.max(self.net_in[target]);
            self.mem[target] += raw as f64;
            self.locations.entry(obj).or_default().push(target);
        }
        for &(obj, elems) in outputs {
            self.register(obj, elems, target);
        }
        self.max_mem = self.max_mem.max(self.mem[target]);
    }

    /// Commit a *rebound* cached task into the load model
    /// ([`crate::scheduler::plan_cache`]): exactly what
    /// [`ClusterState::apply`] would have committed had the scheduler
    /// planned this task now — each committed transfer charges
    /// `elems × charge_factor(src, target)` on both NICs (block sizes are
    /// whole element counts, so `elems as f64` reproduces the original
    /// charge bit-for-bit), the pulled copy joins the target's memory
    /// term and location list, and every output registers at the target.
    ///
    /// Two deviations from `apply`, both deliberate: a pull whose object
    /// is *already* resident at the target (a runtime replica absorbed
    /// since the plan was captured) still charges the NIC terms — the
    /// plan commits the transfer, and model-vs-plan accounting identities
    /// are asserted on that basis — but does not duplicate the location
    /// entry or double-count resident memory (`forget` relies on distinct
    /// entries). And a pull of an object the model no longer tracks (a
    /// defensive case; live plan inputs are never collected) skips the
    /// memory/location side entirely.
    pub fn replay_task(&mut self, task: &crate::exec::task::Task) {
        for tr in &task.transfers {
            let charged = tr.elems as f64 * self.charge_factor(tr.src, task.target);
            self.net_out[tr.src] += charged;
            self.max_out = self.max_out.max(self.net_out[tr.src]);
            self.net_in[task.target] += charged;
            self.max_in = self.max_in.max(self.net_in[task.target]);
            if self.sizes.contains_key(&tr.obj) {
                let locs = self.locations.entry(tr.obj).or_default();
                if !locs.contains(&task.target) {
                    locs.push(task.target);
                    self.mem[task.target] += tr.elems as f64;
                }
            }
        }
        for (obj, shape) in &task.outputs {
            let elems: f64 = shape.iter().map(|&d| d as f64).product();
            self.register(*obj, elems, task.target);
        }
        self.max_mem = self.max_mem.max(self.mem[task.target]);
    }

    /// Record that the runtime materialized a copy of `obj` on physical
    /// `node` that planning never committed (a steal pull, a demand
    /// miss, a prefetch to a thief). The copy joins the location map —
    /// expanding the next plan's placement options, since LSHS only
    /// considers targets holding some input copy — and its elements join
    /// the node's memory term, exactly as [`ClusterState::apply`] counts
    /// a committed pull. In Dask mode the copy is booked on the node's
    /// first worker target (feedback is per physical node; the store
    /// that holds it is node-shared anyway). No-op for objects the model
    /// no longer tracks (forgotten/dead) or already-known locations.
    pub fn add_replica(&mut self, obj: ObjectId, node: usize) {
        if self
            .locations_of(obj)
            .iter()
            .any(|&l| self.topo.node_of(l) == node)
        {
            return;
        }
        let Some(&elems) = self.sizes.get(&obj) else { return };
        let Some(t) = (0..self.targets()).find(|&t| self.topo.node_of(t) == node) else {
            return;
        };
        self.locations.entry(obj).or_default().push(t);
        self.mem[t] += elems;
        self.max_mem = self.max_mem.max(self.mem[t]);
    }

    /// Fold one real run's [`crate::exec::RuntimeFeedback`] into the load
    /// model, so the next `schedule()`'s Eq. 2 simulation starts from
    /// where load *actually* landed instead of where the last plan said
    /// it would:
    ///
    /// * unplanned NIC traffic (steal pulls, eviction re-pulls) joins the
    ///   cumulative `net_in`/`net_out` terms, spread over the node's
    ///   targets — traffic-hot nodes repel further load;
    /// * spill pressure joins the memory term as phantom elements: the
    ///   planner oversubscribed that node, and the Eq. 2 max-memory
    ///   objective should keep seeing the oversubscription it caused;
    /// * runtime replicas join the location map ([`ClusterState::add_replica`]).
    ///
    /// Byte counters convert at 8 bytes/element (f64), matching how every
    /// other model term is counted. Gated by `SessionConfig::feedback`.
    pub fn absorb_feedback(&mut self, fb: &crate::exec::RuntimeFeedback) {
        for (node, nf) in fb.nodes.iter().enumerate().take(self.topo.nodes) {
            let targets: Vec<usize> = (0..self.targets())
                .filter(|&t| self.topo.node_of(t) == node)
                .collect();
            if targets.is_empty() {
                continue;
            }
            let per = targets.len() as f64;
            let in_share = nf.unplanned_in_bytes as f64 / 8.0 / per;
            let out_share = nf.unplanned_out_bytes as f64 / 8.0 / per;
            let spill_share = nf.spilled_bytes as f64 / 8.0 / per;
            for &t in &targets {
                self.net_in[t] += in_share;
                self.net_out[t] += out_share;
                self.mem[t] += spill_share;
                self.max_in = self.max_in.max(self.net_in[t]);
                self.max_out = self.max_out.max(self.net_out[t]);
                self.max_mem = self.max_mem.max(self.mem[t]);
            }
        }
        for &(obj, node) in &fb.replicas {
            self.add_replica(obj, node);
        }
    }

    /// Per-physical-node (mem, in, out) aggregation for reporting (Fig. 15).
    pub fn per_node_loads(&self) -> Vec<(f64, f64, f64)> {
        let mut out = vec![(0.0, 0.0, 0.0); self.topo.nodes];
        for t in 0..self.targets() {
            let n = self.topo.node_of(t);
            out[n].0 += self.mem[t];
            out[n].1 += self.net_in[t];
            out[n].2 += self.net_out[t];
        }
        out
    }
}

fn extra(v: &[(usize, f64)], key: usize) -> f64 {
    v.iter().find(|(k, _)| *k == key).map(|(_, e)| *e).unwrap_or(0.0)
}

fn bump(v: &mut Vec<(usize, f64)>, key: usize, delta: f64) {
    if let Some(e) = v.iter_mut().find(|(k, _)| *k == key) {
        e.1 += delta;
    } else {
        v.push((key, delta));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ray_topo(k: usize) -> Topology {
        Topology::new(k, 4, SystemMode::Ray)
    }

    #[test]
    fn colocated_inputs_cost_nothing_extra() {
        let mut s = ClusterState::new(ray_topo(2));
        s.register(1, 100.0, 0);
        s.register(2, 100.0, 0);
        let local = s.placement_cost(0, &[1, 2], 100.0);
        let remote = s.placement_cost(1, &[1, 2], 100.0);
        assert!(local.pulls.is_empty());
        assert!(remote.pulls.len() == 2);
        assert!(local.cost < remote.cost, "{} vs {}", local.cost, remote.cost);
    }

    #[test]
    fn apply_updates_maxima_and_locations() {
        let mut s = ClusterState::new(ray_topo(2));
        s.register(1, 50.0, 0);
        let sim = s.placement_cost(1, &[1], 10.0);
        s.apply(1, &sim, &[(2, 10.0)]);
        assert_eq!(s.net_out[0], 50.0);
        assert_eq!(s.net_in[1], 50.0);
        assert_eq!(s.mem[1], 60.0); // copy + output
        assert!(s.locations_of(1).contains(&1));
        assert_eq!(s.locations_of(2), &[1]);
        assert!((s.objective() - (60.0 + 50.0 + 50.0)).abs() < 1e-9);
    }

    #[test]
    fn cached_copy_avoids_second_transfer() {
        let mut s = ClusterState::new(ray_topo(2));
        s.register(1, 50.0, 0);
        let sim = s.placement_cost(1, &[1], 0.0);
        s.apply(1, &sim, &[]);
        // object now cached on node 1: placing there again pulls nothing
        let again = s.placement_cost(1, &[1], 0.0);
        assert!(again.pulls.is_empty());
    }

    #[test]
    fn source_selection_balances_net_out() {
        let mut s = ClusterState::new(ray_topo(3));
        // object 1 available on nodes 0 and 1; node 0 already loaded
        s.register(1, 10.0, 0);
        s.net_out[0] = 100.0;
        s.max_out = 100.0;
        let sim0 = s.placement_cost(2, &[1], 0.0);
        assert_eq!(sim0.pulls[0].1, 0); // only location
        s.locations.entry(1).or_default().push(1);
        let sim1 = s.placement_cost(2, &[1], 0.0);
        assert_eq!(sim1.pulls[0].1, 1); // cheaper source chosen
    }

    #[test]
    fn forget_removes_every_copy_and_lowers_the_memory_term() {
        let mut s = ClusterState::new(ray_topo(2));
        s.register(1, 50.0, 0);
        s.register(2, 30.0, 0);
        // pull object 1 to node 1: two copies in the model
        let sim = s.placement_cost(1, &[1], 10.0);
        s.apply(1, &sim, &[(3, 10.0)]);
        assert_eq!(s.mem[0], 80.0);
        assert_eq!(s.mem[1], 60.0);
        s.forget(1);
        assert_eq!(s.mem[0], 30.0, "primary copy forgotten");
        assert_eq!(s.mem[1], 10.0, "replica copy forgotten");
        assert!(s.locations_of(1).is_empty());
        assert_eq!(s.size_of(1), 0.0);
        // the cached maximum follows the decrements, so the next
        // placement decision sees the real (lower) load
        let after = s.placement_cost(0, &[2], 0.0);
        assert!((after.cost - (30.0 + 50.0 + 50.0)).abs() < 1e-9);
        // unknown ids are a no-op
        s.forget(99);
        assert_eq!(s.mem[0], 30.0);
    }

    #[test]
    fn forget_copies_on_drops_one_node_and_keeps_the_rest_consistent() {
        let mut s = ClusterState::new(ray_topo(2));
        s.register(1, 50.0, 0);
        s.add_replica(1, 1);
        assert_eq!(s.mem[0], 50.0);
        assert_eq!(s.mem[1], 50.0);
        // node 1 lost: its copy leaves the model, node 0's stays
        s.forget_copies_on(1, 1);
        assert_eq!(s.locations_of(1), &[0]);
        assert_eq!(s.mem[1], 0.0);
        assert_eq!(s.mem[0], 50.0);
        assert_eq!(s.size_of(1), 50.0, "object still tracked");
        // a consumer placed on node 1 must now pull again — and the
        // surviving location list is non-empty, so placement_cost is safe
        assert_eq!(s.placement_cost(1, &[1], 0.0).pulls.len(), 1);
        // losing the last copy removes the object outright
        s.forget_copies_on(1, 0);
        assert!(s.locations_of(1).is_empty());
        assert_eq!(s.size_of(1), 0.0);
        assert_eq!(s.mem[0], 0.0);
        // unknown ids are a no-op
        s.forget_copies_on(99, 0);
        assert_eq!(s.mem[0], 0.0);
    }

    #[test]
    fn add_replica_expands_locations_and_memory_once() {
        let mut s = ClusterState::new(ray_topo(2));
        s.register(1, 50.0, 0);
        s.add_replica(1, 1);
        assert_eq!(s.locations_of(1), &[0, 1]);
        assert_eq!(s.mem[1], 50.0);
        // idempotent: the copy is already known
        s.add_replica(1, 1);
        assert_eq!(s.locations_of(1), &[0, 1]);
        assert_eq!(s.mem[1], 50.0);
        // unknown (forgotten/dead) objects are a no-op
        s.add_replica(99, 1);
        assert_eq!(s.mem[1], 50.0);
        // a consumer placed on node 1 now pulls nothing
        assert!(s.placement_cost(1, &[1], 0.0).pulls.is_empty());
        // and forget() unwinds the replica copy too
        s.forget(1);
        assert_eq!(s.mem[1], 0.0);
        assert!(s.locations_of(1).is_empty());
    }

    #[test]
    fn absorb_feedback_charges_unplanned_traffic_and_spill_pressure() {
        use crate::exec::{NodeFeedback, RuntimeFeedback};
        let mut s = ClusterState::new(ray_topo(2));
        s.register(1, 100.0, 0);
        let fb = RuntimeFeedback {
            nodes: vec![
                NodeFeedback {
                    unplanned_out_bytes: 800, // 100 elems left node 0
                    spilled_bytes: 400,       // 50 elems paged out there
                    ..Default::default()
                },
                NodeFeedback {
                    tasks_stolen: 3,
                    steal_bytes: 800,
                    demand_pull_bytes: 800,
                    unplanned_in_bytes: 800, // 100 elems arrived at node 1
                    ..Default::default()
                },
            ],
            replicas: vec![(1, 1)],
        };
        s.absorb_feedback(&fb);
        assert_eq!(s.net_out[0], 100.0);
        assert_eq!(s.net_in[1], 100.0);
        assert_eq!(s.mem[0], 150.0, "spill pressure joins the memory term");
        assert_eq!(s.mem[1], 100.0, "replica elems counted on the thief");
        assert_eq!(s.locations_of(1), &[0, 1]);
        // the cached maxima moved with the terms
        assert!((s.objective() - (150.0 + 100.0 + 100.0)).abs() < 1e-9);
    }

    #[test]
    fn absorb_feedback_spreads_over_dask_worker_targets() {
        use crate::exec::{NodeFeedback, RuntimeFeedback};
        let topo = Topology::new(2, 2, SystemMode::Dask); // 4 worker targets
        let mut s = ClusterState::new(topo);
        s.register(7, 40.0, 0); // worker 0, node 0
        let fb = RuntimeFeedback {
            nodes: vec![
                NodeFeedback::default(),
                NodeFeedback {
                    unplanned_in_bytes: 1600, // 200 elems over 2 workers
                    ..Default::default()
                },
            ],
            replicas: vec![(7, 1)],
        };
        s.absorb_feedback(&fb);
        assert_eq!(s.net_in[2], 100.0);
        assert_eq!(s.net_in[3], 100.0);
        // the replica books on node 1's first worker target
        assert_eq!(s.locations_of(7), &[0, 2]);
        assert_eq!(s.mem[2], 40.0);
    }

    #[test]
    fn placement_cost_into_matches_the_allocating_wrapper() {
        let mut s = ClusterState::new(ray_topo(3));
        s.register(1, 50.0, 0);
        s.register(2, 30.0, 1);
        s.register(3, 20.0, 2);
        let mut scratch = PlacementScratch::default();
        for target in 0..3 {
            let sim = s.placement_cost(target, &[1, 2, 3], 10.0);
            let cost = s.placement_cost_into(target, &[1, 2, 3], 10.0, &mut scratch);
            assert_eq!(sim.cost.to_bits(), cost.to_bits());
            assert_eq!(sim.pulls, scratch.pulls);
        }
        // scratch is cleared between candidates, not accumulated
        let _ = s.placement_cost_into(0, &[1], 0.0, &mut scratch);
        assert!(scratch.pulls.is_empty(), "local input -> no pulls left over");
    }

    #[test]
    fn replay_task_reproduces_apply_accounting() {
        use crate::exec::task::{Task, Transfer};
        use crate::runtime::Kernel;
        let mut s = ClusterState::new(ray_topo(2));
        s.register(1, 50.0, 0);

        // the original schedule: pull obj 1 to target 1, produce obj 2
        let mut original = s.clone();
        let sim = original.placement_cost(1, &[1], 10.0);
        original.apply(1, &sim, &[(2, 10.0)]);

        // the cached-plan replay of the identical decision
        let mut replayed = s.clone();
        replayed.replay_task(&Task {
            kernel: Kernel::Neg,
            inputs: vec![1],
            in_shapes: vec![vec![50, 1]],
            outputs: vec![(2, vec![10, 1])],
            target: 1,
            transfers: vec![Transfer { obj: 1, src: 0, elems: 50 }],
        });

        assert_eq!(original.mem, replayed.mem);
        assert_eq!(original.net_in, replayed.net_in);
        assert_eq!(original.net_out, replayed.net_out);
        assert_eq!(original.objective().to_bits(), replayed.objective().to_bits());
        assert_eq!(original.locations_of(1), replayed.locations_of(1));
        assert_eq!(original.locations_of(2), replayed.locations_of(2));

        // a replica absorbed since capture: NIC terms still charge (the
        // plan committed the transfer) but the copy is not double-counted
        let mut with_replica = s.clone();
        with_replica.add_replica(1, 1);
        let mem_before = with_replica.mem[1];
        with_replica.replay_task(&Task {
            kernel: Kernel::Neg,
            inputs: vec![1],
            in_shapes: vec![vec![50, 1]],
            outputs: vec![(2, vec![10, 1])],
            target: 1,
            transfers: vec![Transfer { obj: 1, src: 0, elems: 50 }],
        });
        assert_eq!(with_replica.net_in[1], 50.0);
        assert_eq!(with_replica.mem[1], mem_before + 10.0, "copy counted once");
        assert_eq!(with_replica.locations_of(1), &[0, 1], "no duplicate entry");
    }

    #[test]
    fn dask_mode_discounts_same_node() {
        let topo = Topology::new(2, 2, SystemMode::Dask); // 4 worker targets
        let mut s = ClusterState::new(topo);
        s.register(1, 100.0, 0); // worker 0 (node 0)
        // worker 1 is on node 0 -> discounted; worker 2 is node 1 -> full
        let same = s.placement_cost(1, &[1], 0.0);
        let cross = s.placement_cost(2, &[1], 0.0);
        assert!((same.pulls[0].2 - 25.0).abs() < 1e-9);
        assert!((cross.pulls[0].2 - 100.0).abs() < 1e-9);
    }
}
