//! Plan cache: amortize LSHS planning across repeated graph topologies.
//!
//! Iterative drivers (Newton, L-BFGS, tensor factorization) submit the
//! same graph shape every iteration, and every iteration pays the full
//! local search — `decisions × options × inputs` candidate simulations.
//! This module memoizes the *outcome* of that search, keyed by the
//! canonical [`GraphSignature`](crate::graph::GraphSignature): same
//! signature ⇒ the scheduler would face an isomorphic decision problem,
//! so the previous plan is a valid (and, modulo staleness, equally good)
//! schedule for the new graph.
//!
//! A cached plan cannot store concrete [`ObjectId`]s — every iteration's
//! graph carries fresh ones. [`PlanCache::capture`] therefore *abstracts*
//! a freshly-scheduled plan into symbolic [`Slot`]s: task inputs become
//! `Input(i)` (position in the graph's canonical input list, see
//! [`crate::graph::signature::signature`]) or `Produced(j)` (the j-th
//! object the plan itself creates). On a hit, [`CachedPlan::rebind`] runs
//! the abstraction backwards: `Input` slots map to *this* run's input
//! objects, `Produced` slots to brand-new ids from the session's
//! [`IdGen`], and every task is replayed into the [`ClusterState`]
//! exactly as [`ClusterState::apply`] would have committed it — so Eq. 2
//! accounting, lifetime analysis, feedback reconciliation, and the sim
//! executor all see a plan indistinguishable from a freshly-scheduled
//! one. The graph's output roots are rewritten to leaves over the
//! remapped objects, which is all `Session::run` needs downstream (pins
//! and output materialization go through `Graph::resolve`).
//!
//! **Correctness vs optimality.** A hit is always *correct*: kernels and
//! reduce pairings are frozen in the plan, so results are bit-identical
//! to executing the original schedule (the bit-identity invariant —
//! reduction shape is fixed at plan time). What can rot is *cost*: the
//! load model drifts as feedback absorbs steal traffic and spill
//! pressure. Each entry therefore carries a staleness score — the
//! feedback magnitude (in elements) absorbed since the entry was planned,
//! relative to the plan's own data scale. When the ratio crosses
//! [`PlanCache::STALE_RATIO`], the next lookup declines the hit and the
//! session re-plans in the foreground (synchronously — the jit-tier
//! idiom without threads), replacing the entry.

use std::collections::HashMap;

use crate::exec::task::{Plan, Task, Transfer};
use crate::graph::{Graph, GraphSignature, Vertex, VertexId};
use crate::runtime::Kernel;
use crate::store::{IdGen, ObjectId};

use super::ClusterState;

/// Symbolic object reference inside a cached plan.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Slot {
    /// Index into the graph's canonical input list (distinct leaf objects
    /// in first-occurrence arena order).
    Input(u32),
    /// The j-th object produced by the plan itself, in task/output order.
    Produced(u32),
}

/// One committed transfer, with the moved object abstracted to a slot.
#[derive(Clone, Debug)]
struct SymTransfer {
    obj: Slot,
    src: usize,
    elems: u64,
}

/// One task with all object ids abstracted to slots. Output slots are
/// implicit: a task producing `k` outputs owns the next `k` `Produced`
/// indices in plan order.
#[derive(Clone, Debug)]
struct SymTask {
    kernel: Kernel,
    inputs: Vec<Slot>,
    in_shapes: Vec<Vec<usize>>,
    out_shapes: Vec<Vec<usize>>,
    target: usize,
    transfers: Vec<SymTransfer>,
}

/// A memoized schedule for one graph signature.
#[derive(Clone, Debug)]
pub struct CachedPlan {
    n_inputs: usize,
    n_produced: usize,
    tasks: Vec<SymTask>,
    /// Output root vertices of the scheduled graph: `(vertex id, objs,
    /// shapes)` — replayed onto the new graph so `Graph::resolve` works.
    root_leaves: Vec<(VertexId, Vec<Slot>, Vec<Vec<usize>>)>,
    /// Elements the plan touches (outputs + transfers): the denominator
    /// of the staleness ratio.
    planned_elems: f64,
    /// Feedback elements absorbed by the load model since this entry was
    /// planned (unplanned traffic + spill pressure).
    stale_elems: f64,
}

impl CachedPlan {
    /// Rebind this symbolic plan onto concrete objects: `inputs` is the
    /// new graph's canonical input list (positional contract with the
    /// signature), fresh output ids come from `ids`, concrete tasks are
    /// appended to `plan`, every placement/transfer is replayed into
    /// `state`, and the new graph's output roots are rewritten to leaves.
    pub fn rebind(
        &self,
        inputs: &[ObjectId],
        ids: &IdGen,
        graph: &mut Graph,
        state: &mut ClusterState,
        plan: &mut Plan,
    ) {
        assert_eq!(
            inputs.len(),
            self.n_inputs,
            "signature match implies an equal canonical input list"
        );
        let fresh: Vec<ObjectId> = (0..self.n_produced).map(|_| ids.next()).collect();
        let resolve = |s: Slot| -> ObjectId {
            match s {
                Slot::Input(i) => inputs[i as usize],
                Slot::Produced(j) => fresh[j as usize],
            }
        };
        let mut next_out = 0usize;
        for st in &self.tasks {
            let outputs: Vec<(ObjectId, Vec<usize>)> = st
                .out_shapes
                .iter()
                .map(|s| {
                    let o = fresh[next_out];
                    next_out += 1;
                    (o, s.clone())
                })
                .collect();
            let task = Task {
                kernel: st.kernel.clone(),
                inputs: st.inputs.iter().map(|&s| resolve(s)).collect(),
                in_shapes: st.in_shapes.clone(),
                outputs,
                target: st.target,
                transfers: st
                    .transfers
                    .iter()
                    .map(|tr| Transfer {
                        obj: resolve(tr.obj),
                        src: tr.src,
                        elems: tr.elems,
                    })
                    .collect(),
            };
            state.replay_task(&task);
            plan.tasks.push(task);
        }
        debug_assert_eq!(next_out, self.n_produced);
        for (vid, slots, shapes) in &self.root_leaves {
            graph.vertices[*vid] = Vertex::Leaf {
                objs: slots.iter().map(|&s| resolve(s)).collect(),
                shapes: shapes.clone(),
            };
        }
    }
}

/// Session-owned plan memo (see module docs). Bounded FIFO capacity;
/// counters are cumulative over the session.
#[derive(Debug)]
pub struct PlanCache {
    entries: HashMap<GraphSignature, CachedPlan>,
    /// Insertion order, for capacity eviction.
    order: Vec<GraphSignature>,
    capacity: usize,
    /// Re-plan when `stale_elems > STALE_RATIO × planned_elems`.
    stale_ratio: f64,
    pub hits: u64,
    pub misses: u64,
    /// Hits declined because the entry went stale (each one re-plans and
    /// replaces the entry in the foreground).
    pub stale_replans: u64,
}

impl PlanCache {
    /// Default capacity: iterative drivers cycle through a handful of
    /// topologies; 128 is far above any workload in the repo while
    /// bounding a pathological signature-churn session.
    pub const CAPACITY: usize = 128;
    /// Default staleness threshold: once the absorbed feedback magnitude
    /// reaches half the plan's own data scale, the load model has drifted
    /// enough that the memoized argmin is no longer trustworthy.
    pub const STALE_RATIO: f64 = 0.5;

    pub fn new(capacity: usize, stale_ratio: f64) -> Self {
        Self {
            entries: HashMap::new(),
            order: Vec::new(),
            capacity: capacity.max(1),
            stale_ratio,
            hits: 0,
            misses: 0,
            stale_replans: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Count a lookup: `true` ⇒ a fresh entry exists and the caller
    /// should [`CachedPlan::rebind`] it ([`PlanCache::get`]); `false` ⇒
    /// schedule from scratch and [`PlanCache::insert`] the result. A
    /// stale entry is evicted here and reported as a miss (plus
    /// `stale_replans`), so the caller's miss path *is* the foreground
    /// re-plan.
    pub fn lookup(&mut self, sig: GraphSignature) -> bool {
        match self.entries.get(&sig) {
            Some(e) if e.stale_elems <= self.stale_ratio * e.planned_elems.max(1.0) => {
                self.hits += 1;
                true
            }
            Some(_) => {
                self.entries.remove(&sig);
                self.order.retain(|&s| s != sig);
                self.stale_replans += 1;
                self.misses += 1;
                false
            }
            None => {
                self.misses += 1;
                false
            }
        }
    }

    pub fn get(&self, sig: GraphSignature) -> Option<&CachedPlan> {
        self.entries.get(&sig)
    }

    pub fn insert(&mut self, sig: GraphSignature, entry: CachedPlan) {
        if self.entries.insert(sig, entry).is_none() {
            self.order.push(sig);
            if self.order.len() > self.capacity {
                let evict = self.order.remove(0);
                self.entries.remove(&evict);
            }
        }
    }

    /// Charge absorbed runtime feedback against every cached entry:
    /// `elems` is the magnitude (in f64 elements) of unplanned traffic
    /// and spill pressure the load model just absorbed. Entries planned
    /// against the pre-drift model grow stale together.
    pub fn note_feedback(&mut self, elems: f64) {
        if elems <= 0.0 {
            return;
        }
        for e in self.entries.values_mut() {
            e.stale_elems += elems;
        }
    }

    /// Abstract a freshly-scheduled plan into a cacheable symbolic form.
    /// `inputs` is the canonical input list the signature returned for
    /// this graph (computed pre-schedule); `graph` is the post-schedule
    /// graph (every vertex a leaf). Returns `None` if the plan references
    /// an object outside `inputs ∪ produced` — an uncacheable plan, never
    /// expected from the in-tree schedulers, but a wrong cache entry
    /// would be a correctness bug so this is a hard gate, not an assert.
    pub fn capture(inputs: &[ObjectId], graph: &Graph, plan: &Plan) -> Option<CachedPlan> {
        let mut slot_of: HashMap<ObjectId, Slot> = inputs
            .iter()
            .enumerate()
            .map(|(i, &o)| (o, Slot::Input(i as u32)))
            .collect();
        let mut produced = 0u32;
        let mut tasks = Vec::with_capacity(plan.tasks.len());
        let mut planned_elems = 0.0f64;
        for t in &plan.tasks {
            let ins: Option<Vec<Slot>> =
                t.inputs.iter().map(|o| slot_of.get(o).copied()).collect();
            let transfers: Option<Vec<SymTransfer>> = t
                .transfers
                .iter()
                .map(|tr| {
                    slot_of.get(&tr.obj).map(|&s| SymTransfer {
                        obj: s,
                        src: tr.src,
                        elems: tr.elems,
                    })
                })
                .collect();
            let (ins, transfers) = (ins?, transfers?);
            planned_elems += t.out_elems() as f64;
            planned_elems += t.transfers.iter().map(|tr| tr.elems as f64).sum::<f64>();
            let mut out_shapes = Vec::with_capacity(t.outputs.len());
            for (o, s) in &t.outputs {
                slot_of.insert(*o, Slot::Produced(produced));
                produced += 1;
                out_shapes.push(s.clone());
            }
            tasks.push(SymTask {
                kernel: t.kernel.clone(),
                inputs: ins,
                in_shapes: t.in_shapes.clone(),
                out_shapes,
                target: t.target,
                transfers,
            });
        }
        let mut root_leaves = Vec::new();
        let mut seen: Vec<VertexId> = Vec::new();
        for out in &graph.outputs {
            for &(vid, _) in &out.roots {
                if seen.contains(&vid) {
                    continue;
                }
                seen.push(vid);
                let (objs, shapes) = match &graph.vertices[vid] {
                    Vertex::Leaf { objs, shapes } => (objs, shapes),
                    // scheduling rewrites every output root to a leaf; a
                    // non-leaf root means the plan is not replayable
                    _ => return None,
                };
                let slots: Option<Vec<Slot>> =
                    objs.iter().map(|o| slot_of.get(o).copied()).collect();
                root_leaves.push((vid, slots?, shapes.clone()));
            }
        }
        Some(CachedPlan {
            n_inputs: inputs.len(),
            n_produced: produced as usize,
            tasks,
            root_leaves,
            planned_elems,
            stale_elems: 0.0,
        })
    }
}

impl Default for PlanCache {
    fn default() -> Self {
        Self::new(Self::CAPACITY, Self::STALE_RATIO)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{build, signature::signature, DistArray};
    use crate::grid::{ArrayGrid, NodeGrid};
    use crate::net::model::SystemMode;
    use crate::runtime::BinOp;
    use crate::scheduler::{Lshs, Scheduler, Topology};

    fn setup(k: usize) -> (Lshs, ClusterState, IdGen) {
        let topo = Topology::new(k, 4, SystemMode::Ray);
        let lshs = Lshs::new(NodeGrid::linear(k), topo.clone(), 42);
        (lshs, ClusterState::new(topo), IdGen::default())
    }

    fn create(
        sched: &mut Lshs,
        state: &mut ClusterState,
        ids: &IdGen,
        shape: &[usize],
        grid: &[usize],
    ) -> DistArray {
        let g = ArrayGrid::new(shape, grid);
        let targets = sched.place_creation(&g, state);
        let blocks: Vec<u64> = (0..g.num_blocks()).map(|_| ids.next()).collect();
        for (f, c) in g.iter_coords().enumerate() {
            state.register(blocks[f], g.block_elems(&c) as f64, targets[f]);
        }
        DistArray::new(g, blocks, targets)
    }

    #[test]
    fn capture_rebind_roundtrip_preserves_structure_and_accounting() {
        let (mut sched, mut state, ids) = setup(2);
        let a = create(&mut sched, &mut state, &ids, &[64, 64], &[2, 2]);
        let b = create(&mut sched, &mut state, &ids, &[64, 64], &[2, 2]);

        // iteration 1: schedule for real, capture
        let mut g1 = crate::graph::Graph::new();
        build::matmul(&mut g1, &a, &b);
        let (_, inputs1) = signature(&g1, &state);
        let mut plan1 = Plan::new();
        sched.schedule(&mut g1, &mut state, &ids, &mut plan1);
        let cached = PlanCache::capture(&inputs1, &g1, &plan1).expect("cacheable");

        // iteration 2: identical topology over the same inputs, rebound
        let mut g2 = crate::graph::Graph::new();
        build::matmul(&mut g2, &a, &b);
        let (_, inputs2) = signature(&g2, &state);
        let mut state2 = state.clone();
        let mut plan2 = Plan::new();
        cached.rebind(&inputs2, &ids, &mut g2, &mut state2, &mut plan2);

        assert_eq!(plan2.len(), plan1.len());
        assert_eq!(plan2.transfer_count(), plan1.transfer_count());
        assert_eq!(plan2.transfer_bytes(), plan1.transfer_bytes());
        for (t1, t2) in plan1.tasks.iter().zip(&plan2.tasks) {
            assert_eq!(t1.kernel, t2.kernel);
            assert_eq!(t1.target, t2.target);
            assert_eq!(t1.in_shapes, t2.in_shapes);
            // fresh ids, never recycled
            for ((o1, s1), (o2, s2)) in t1.outputs.iter().zip(&t2.outputs) {
                assert_ne!(o1, o2);
                assert_eq!(s1, s2);
            }
        }
        // the rebound graph resolves its outputs to the fresh ids
        for out in &g2.outputs {
            for &r in &out.roots {
                let obj = g2.resolve(r);
                assert!(
                    plan2.tasks.iter().any(|t| t.outputs.iter().any(|(o, _)| *o == obj)),
                    "output root must resolve to a rebound plan output"
                );
            }
        }
        // replay accounted the outputs at their targets (primary = the
        // producing target; later replayed pulls may add replicas)
        for t in &plan2.tasks {
            for (o, s) in &t.outputs {
                let elems: f64 = s.iter().map(|&d| d as f64).product();
                assert_eq!(state2.locations_of(*o).first(), Some(&t.target));
                assert_eq!(state2.size_of(*o), elems);
            }
        }
    }

    #[test]
    fn lookup_counts_and_staleness_evict() {
        let (mut sched, mut state, ids) = setup(2);
        let a = create(&mut sched, &mut state, &ids, &[64, 8], &[4, 1]);
        let b = create(&mut sched, &mut state, &ids, &[64, 8], &[4, 1]);
        let mut g = crate::graph::Graph::new();
        build::binary_ew(&mut g, &a, &b, BinOp::Add);
        let (sig, inputs) = signature(&g, &state);
        let mut plan = Plan::new();
        sched.schedule(&mut g, &mut state, &ids, &mut plan);
        let entry = PlanCache::capture(&inputs, &g, &plan).unwrap();
        let planned = entry.planned_elems;
        assert!(planned > 0.0);

        let mut cache = PlanCache::default();
        assert!(!cache.lookup(sig), "cold cache misses");
        cache.insert(sig, entry);
        assert!(cache.lookup(sig), "warm cache hits");
        assert_eq!((cache.hits, cache.misses, cache.stale_replans), (1, 1, 0));

        // small feedback: still fresh
        cache.note_feedback(planned * 0.1);
        assert!(cache.lookup(sig));
        // large feedback: crosses the ratio, entry evicted, miss reported
        cache.note_feedback(planned * PlanCache::STALE_RATIO);
        assert!(!cache.lookup(sig), "stale entry declines the hit");
        assert_eq!(cache.stale_replans, 1);
        assert!(cache.get(sig).is_none(), "stale entry evicted");
    }

    #[test]
    fn capacity_evicts_oldest_insertion() {
        let (mut sched, mut state, ids) = setup(2);
        let mut cache = PlanCache::new(2, PlanCache::STALE_RATIO);
        let mut sigs = Vec::new();
        for n in [1usize, 2, 3] {
            let a = create(&mut sched, &mut state, &ids, &[64 * n, 8], &[4, 1]);
            let b = create(&mut sched, &mut state, &ids, &[64 * n, 8], &[4, 1]);
            let mut g = crate::graph::Graph::new();
            build::binary_ew(&mut g, &a, &b, BinOp::Add);
            let (sig, inputs) = signature(&g, &state);
            let mut plan = Plan::new();
            sched.schedule(&mut g, &mut state, &ids, &mut plan);
            cache.insert(sig, PlanCache::capture(&inputs, &g, &plan).unwrap());
            sigs.push(sig);
        }
        assert_eq!(cache.len(), 2);
        assert!(cache.get(sigs[0]).is_none(), "oldest entry evicted");
        assert!(cache.get(sigs[1]).is_some());
        assert!(cache.get(sigs[2]).is_some());
    }
}
