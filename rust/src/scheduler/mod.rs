//! Operator schedulers: LSHS (§5) and the dynamic-scheduler baselines the
//! paper ablates against (§8.1, Fig. 9/15).
//!
//! All schedulers share the same contract: walk a [`Graph`]'s frontier,
//! choose a placement target per block-level operation, emit [`Task`]s into
//! a [`Plan`] and update the [`ClusterState`] load model. The transition
//! helpers here implement the graph rewriting (op vertex → leaf, Reduce
//! pair → new leaf) so that policies differ only in *where* they place.

pub mod baselines;
pub mod cluster_state;
pub mod lshs;
pub mod plan_cache;
pub mod topology;

pub use cluster_state::{ClusterState, PlacementScratch};
pub use lshs::Lshs;
pub use plan_cache::{CachedPlan, PlanCache};
pub use topology::Topology;

use crate::exec::task::{Plan, Task, Transfer};
use crate::graph::vertex::{Vertex, VertexId};
use crate::graph::Graph;
use crate::grid::ArrayGrid;
use crate::runtime::kernel::Kernel;
use crate::store::{IdGen, ObjectId};

pub trait Scheduler {
    fn name(&self) -> String;

    /// Placement targets for the blocks of a newly-created array
    /// (creation ops execute immediately, §4).
    fn place_creation(&mut self, grid: &ArrayGrid, state: &mut ClusterState) -> Vec<usize>;

    /// Schedule every operation of `graph`, emitting tasks into `plan`.
    fn schedule(&mut self, graph: &mut Graph, state: &mut ClusterState, ids: &IdGen, plan: &mut Plan);

    /// Cumulative `(placement decisions, candidate simulations)` over
    /// this scheduler's lifetime. `Session::run` reports the per-run
    /// delta — which is how a plan-cache hit proves it skipped the local
    /// search (`simulations == 0`). Baselines place without simulating
    /// and keep the default.
    fn search_stats(&self) -> (u64, u64) {
        (0, 0)
    }
}

/// Resolved view of an op vertex ready for placement.
pub(crate) struct OpView {
    pub kernel: Kernel,
    pub inputs: Vec<ObjectId>,
    pub in_shapes: Vec<Vec<usize>>,
    pub constraint: Option<usize>,
}

pub(crate) fn op_view(graph: &Graph, vid: VertexId) -> OpView {
    match &graph.vertices[vid] {
        Vertex::Op {
            kernel,
            children,
            constraint,
        } => OpView {
            kernel: kernel.clone(),
            inputs: children.iter().map(|&r| graph.resolve(r)).collect(),
            in_shapes: children
                .iter()
                .map(|&r| graph.ref_shape(r).to_vec())
                .collect(),
            constraint: *constraint,
        },
        _ => panic!("op_view on non-op vertex"),
    }
}

/// Execute an `Op` vertex at `target`: emit the task, update state, rewrite
/// the vertex into a leaf.
pub(crate) fn commit_op(
    graph: &mut Graph,
    state: &mut ClusterState,
    ids: &IdGen,
    plan: &mut Plan,
    vid: VertexId,
    target: usize,
) {
    let view = op_view(graph, vid);
    let out_shapes = view.kernel.out_shapes(&view.in_shapes);
    let objs: Vec<ObjectId> = out_shapes.iter().map(|_| ids.next()).collect();
    let out_elems: f64 = out_shapes
        .iter()
        .map(|s| s.iter().map(|&d| d as f64).product::<f64>())
        .sum();
    let sim = state.placement_cost(target, &view.inputs, out_elems);
    let out_pairs: Vec<(ObjectId, f64)> = objs
        .iter()
        .zip(&out_shapes)
        .map(|(&o, s)| (o, s.iter().map(|&d| d as f64).product::<f64>()))
        .collect();
    state.apply(target, &sim, &out_pairs);
    plan.tasks.push(Task {
        kernel: view.kernel,
        inputs: view.inputs,
        in_shapes: view.in_shapes,
        outputs: objs.iter().cloned().zip(out_shapes.clone()).collect(),
        target,
        transfers: sim
            .pulls
            .iter()
            .map(|&(obj, src, _, raw)| Transfer {
                obj,
                src,
                elems: raw,
            })
            .collect(),
    });
    graph.vertices[vid] = Vertex::Leaf {
        objs,
        shapes: out_shapes,
    };
}

/// Leaf children (positions within the child list) of a Reduce vertex.
pub(crate) fn reduce_leaf_positions(graph: &Graph, vid: VertexId) -> Vec<usize> {
    match &graph.vertices[vid] {
        Vertex::Reduce { children, .. } => children
            .iter()
            .enumerate()
            .filter(|&(_, &(c, _))| graph.is_leaf(c))
            .map(|(i, _)| i)
            .collect(),
        _ => panic!("reduce_leaf_positions on non-reduce"),
    }
}

/// Execute one binary step of a `Reduce` vertex: combine the children at
/// positions `pa`/`pb` with the reduce op at `target`; rewrite.
pub(crate) fn commit_reduce_pair(
    graph: &mut Graph,
    state: &mut ClusterState,
    ids: &IdGen,
    plan: &mut Plan,
    vid: VertexId,
    pa: usize,
    pb: usize,
    target: usize,
) {
    assert_ne!(pa, pb);
    let (op, ra, rb) = match &graph.vertices[vid] {
        Vertex::Reduce { op, children, .. } => (*op, children[pa], children[pb]),
        _ => panic!("commit_reduce_pair on non-reduce"),
    };
    let shape = graph.ref_shape(ra).to_vec();
    assert_eq!(
        shape,
        graph.ref_shape(rb).to_vec(),
        "reduce operands must have equal dimension (§4)"
    );
    let inputs = vec![graph.resolve(ra), graph.resolve(rb)];
    let out_obj = ids.next();
    let elems: f64 = shape.iter().map(|&d| d as f64).product();
    let sim = state.placement_cost(target, &inputs, elems);
    state.apply(target, &sim, &[(out_obj, elems)]);
    plan.tasks.push(Task {
        kernel: Kernel::Ew(op),
        inputs: inputs.clone(),
        in_shapes: vec![shape.clone(), shape.clone()],
        outputs: vec![(out_obj, shape.clone())],
        target,
        transfers: sim
            .pulls
            .iter()
            .map(|&(obj, src, _, raw)| Transfer {
                obj,
                src,
                elems: raw,
            })
            .collect(),
    });
    // rewrite: drop the pair, append the new leaf
    let new_leaf = graph.push(Vertex::Leaf {
        objs: vec![out_obj],
        shapes: vec![shape],
    });
    match &mut graph.vertices[vid] {
        Vertex::Reduce { children, .. } => {
            let (hi, lo) = (pa.max(pb), pa.min(pb));
            children.remove(hi);
            children.remove(lo);
            children.push((new_leaf, 0));
            if children.len() == 1 {
                let last = children[0];
                let objs = vec![graph.resolve(last)];
                let shapes = vec![graph.ref_shape(last).to_vec()];
                graph.vertices[vid] = Vertex::Leaf { objs, shapes };
            }
        }
        _ => unreachable!(),
    }
}

/// Current locations union for a set of objects (deduped, order-stable),
/// written into a caller-owned buffer (cleared first) — the LSHS frontier
/// loop reuses one buffer across decisions so the candidate set never
/// allocates once warmed.
pub(crate) fn location_union_into(
    state: &ClusterState,
    objs: &[ObjectId],
    out: &mut Vec<usize>,
) {
    out.clear();
    for &o in objs {
        for &t in state.locations_of(o) {
            if !out.contains(&t) {
                out.push(t);
            }
        }
    }
}
