//! Baseline dynamic schedulers the paper ablates LSHS against (§8).
//!
//! * [`RoundRobin`] — Dask-like: independent (creation) tasks round-robin
//!   over workers (the Fig. 2 pathology), dependent tasks on the target
//!   holding the most input bytes (Dask's `decide_worker` locality rule),
//!   reduce operands paired in construction order (the "reduction tree
//!   constructed before physical mapping is known" behaviour of §8.4).
//! * [`BottomUp`] — Ray-without-LSHS: the driver's local scheduler keeps
//!   work on the driver-adjacent node until its load saturates, then
//!   spills to the least-loaded node ("Ray executes the majority of
//!   submitted tasks on a single node", §8.5/Fig. 15).
//! * [`RandomPlace`] — uniform-random placement, a pure-noise control.

use crate::exec::task::Plan;
use crate::graph::vertex::Vertex;
use crate::graph::Graph;
use crate::grid::ArrayGrid;
use crate::store::IdGen;
use crate::util::rng::Rng;

use super::{
    commit_op, commit_reduce_pair, op_view, reduce_leaf_positions, ClusterState, Scheduler,
};

// ---------------------------------------------------------------- RoundRobin

pub struct RoundRobin {
    next: usize,
    /// Tasks assigned per target (Dask's `decide_worker` occupancy
    /// tie-break: without it, greedy locality + caching collapses whole
    /// workloads onto one worker).
    assigned: Vec<usize>,
}

impl RoundRobin {
    pub fn new() -> Self {
        Self {
            next: 0,
            assigned: Vec::new(),
        }
    }

    /// Target owning the most input bytes; ties broken by occupancy.
    fn most_data_target(&mut self, state: &ClusterState, inputs: &[u64]) -> usize {
        if self.assigned.len() != state.targets() {
            self.assigned = vec![0; state.targets()];
        }
        let mut best = 0usize;
        let mut best_key = (-1.0f64, usize::MAX);
        for t in 0..state.targets() {
            let mut bytes = 0.0;
            for &obj in inputs {
                if state.locations_of(obj).contains(&t) {
                    bytes += state.size_of(obj);
                }
            }
            let key = (bytes, self.assigned[t]);
            if key.0 > best_key.0 || (key.0 == best_key.0 && key.1 < best_key.1) {
                best_key = key;
                best = t;
            }
        }
        self.assigned[best] += 1;
        best
    }
}

impl Default for RoundRobin {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler for RoundRobin {
    fn name(&self) -> String {
        "round-robin".into()
    }

    fn place_creation(&mut self, grid: &ArrayGrid, state: &mut ClusterState) -> Vec<usize> {
        let k = state.targets();
        (0..grid.num_blocks())
            .map(|_| {
                let t = self.next % k;
                self.next += 1;
                t
            })
            .collect()
    }

    fn schedule(
        &mut self,
        graph: &mut Graph,
        state: &mut ClusterState,
        ids: &IdGen,
        plan: &mut Plan,
    ) {
        loop {
            let frontier = graph.frontier();
            if frontier.is_empty() {
                break;
            }
            // deterministic order: first frontier vertex
            let vid = frontier[0];
            match &graph.vertices[vid] {
                Vertex::Op { .. } => {
                    let view = op_view(graph, vid);
                    let target = self.most_data_target(state, &view.inputs);
                    commit_op(graph, state, ids, plan, vid, target);
                }
                Vertex::Reduce { .. } => {
                    // naive pairing: first two leaves in construction order
                    let pos = reduce_leaf_positions(graph, vid);
                    let (pa, pb) = (pos[0], pos[1]);
                    let ch = graph.vertices[vid].children();
                    let inputs = vec![graph.resolve(ch[pa]), graph.resolve(ch[pb])];
                    let target = self.most_data_target(state, &inputs);
                    commit_reduce_pair(graph, state, ids, plan, vid, pa, pb, target);
                }
                Vertex::Leaf { .. } => unreachable!(),
            }
        }
    }
}

// ------------------------------------------------------------------ BottomUp

pub struct BottomUp {
    /// Node the driver process is attached to.
    pub driver_target: usize,
    /// Spill multiplier: stay local while mem[driver] <= spill * mean(mem).
    pub spill_factor: f64,
}

impl BottomUp {
    pub fn new() -> Self {
        Self {
            driver_target: 0,
            spill_factor: 4.0,
        }
    }

    fn pick(&self, state: &ClusterState) -> usize {
        let mean = state.mem.iter().sum::<f64>() / state.mem.len() as f64;
        if state.mem[self.driver_target] <= self.spill_factor * mean.max(1.0) {
            self.driver_target
        } else {
            // forward to the centralized scheduler: least memory load
            (0..state.targets())
                .min_by(|&a, &b| state.mem[a].partial_cmp(&state.mem[b]).unwrap())
                .unwrap()
        }
    }
}

impl Default for BottomUp {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler for BottomUp {
    fn name(&self) -> String {
        "bottom-up".into()
    }

    fn place_creation(&mut self, grid: &ArrayGrid, state: &mut ClusterState) -> Vec<usize> {
        // §2: "when a local scheduler is presented with a collection of
        // tasks which have no dependencies, it distributes tasks to reduce
        // overall load" — creation spreads by least memory, with no notion
        // of operand co-location (the Fig. 2 pathology's other half).
        let mut projected = state.mem.clone();
        let per_block = grid.num_elems() as f64 / grid.num_blocks() as f64;
        (0..grid.num_blocks())
            .map(|_| {
                let t = (0..projected.len())
                    .min_by(|&a, &b| projected[a].partial_cmp(&projected[b]).unwrap())
                    .unwrap();
                projected[t] += per_block;
                t
            })
            .collect()
    }

    fn schedule(
        &mut self,
        graph: &mut Graph,
        state: &mut ClusterState,
        ids: &IdGen,
        plan: &mut Plan,
    ) {
        loop {
            let frontier = graph.frontier();
            if frontier.is_empty() {
                break;
            }
            let vid = frontier[0];
            match &graph.vertices[vid] {
                Vertex::Op { .. } => {
                    let target = self.pick(state);
                    commit_op(graph, state, ids, plan, vid, target);
                }
                Vertex::Reduce { .. } => {
                    let pos = reduce_leaf_positions(graph, vid);
                    let target = self.pick(state);
                    commit_reduce_pair(graph, state, ids, plan, vid, pos[0], pos[1], target);
                }
                Vertex::Leaf { .. } => unreachable!(),
            }
        }
    }
}

// --------------------------------------------------------------- RandomPlace

pub struct RandomPlace {
    rng: Rng,
}

impl RandomPlace {
    pub fn new(seed: u64) -> Self {
        Self {
            rng: Rng::seed_from_u64(seed),
        }
    }
}

impl Scheduler for RandomPlace {
    fn name(&self) -> String {
        "random".into()
    }

    fn place_creation(&mut self, grid: &ArrayGrid, state: &mut ClusterState) -> Vec<usize> {
        let k = state.targets();
        (0..grid.num_blocks()).map(|_| self.rng.usize(k)).collect()
    }

    fn schedule(
        &mut self,
        graph: &mut Graph,
        state: &mut ClusterState,
        ids: &IdGen,
        plan: &mut Plan,
    ) {
        loop {
            let frontier = graph.frontier();
            if frontier.is_empty() {
                break;
            }
            let vid = frontier[0];
            let k = state.targets();
            match &graph.vertices[vid] {
                Vertex::Op { .. } => {
                    let target = self.rng.usize(k);
                    commit_op(graph, state, ids, plan, vid, target);
                }
                Vertex::Reduce { .. } => {
                    let pos = reduce_leaf_positions(graph, vid);
                    let target = self.rng.usize(k);
                    commit_reduce_pair(graph, state, ids, plan, vid, pos[0], pos[1], target);
                }
                Vertex::Leaf { .. } => unreachable!(),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{build, DistArray};
    use crate::net::model::SystemMode;
    use crate::runtime::kernel::BinOp;
    use crate::scheduler::Topology;

    fn create(
        sched: &mut dyn Scheduler,
        state: &mut ClusterState,
        ids: &IdGen,
        shape: &[usize],
        grid: &[usize],
    ) -> DistArray {
        let g = ArrayGrid::new(shape, grid);
        let targets = sched.place_creation(&g, state);
        let blocks: Vec<u64> = (0..g.num_blocks()).map(|_| ids.next()).collect();
        for (f, c) in g.iter_coords().enumerate() {
            state.register(blocks[f], g.block_elems(&c) as f64, targets[f]);
        }
        DistArray::new(g, blocks, targets)
    }

    #[test]
    fn round_robin_interleaves_operands_causing_transfers() {
        // The Fig. 2 pathology: A's and B's blocks land on different targets,
        // so X+Y must move data — unlike LSHS (zero transfers).
        let topo = Topology::new(2, 1, SystemMode::Ray);
        let mut state = ClusterState::new(topo);
        let ids = IdGen::default();
        let mut sched = RoundRobin::new();
        let a = create(&mut sched, &mut state, &ids, &[64, 8], &[4, 1]);
        let b = create(&mut sched, &mut state, &ids, &[64, 8], &[4, 1]);
        // creation order: a0 t0, a1 t1, a2 t0, a3 t1 | b0 t0, b1 t1 ...
        // a_i and b_i land together here; stagger by creating odd counts
        let mut graph = Graph::new();
        build::binary_ew(&mut graph, &a, &b, BinOp::Add);
        let mut plan = Plan::new();
        sched.schedule(&mut graph, &mut state, &ids, &mut plan);
        // with 4 blocks over 2 targets and aligned rr, operands coincide;
        // the pathology appears when block counts aren't divisible — §8.1
        let topo = Topology::new(2, 1, SystemMode::Ray);
        let mut state = ClusterState::new(topo);
        let mut sched = RoundRobin::new();
        let a = create(&mut sched, &mut state, &ids, &[96, 8], &[3, 1]);
        let b = create(&mut sched, &mut state, &ids, &[96, 8], &[3, 1]);
        let mut graph = Graph::new();
        build::binary_ew(&mut graph, &a, &b, BinOp::Add);
        let mut plan = Plan::new();
        sched.schedule(&mut graph, &mut state, &ids, &mut plan);
        assert!(
            plan.transfer_count() > 0,
            "odd partitioning must force transfers under round-robin"
        );
    }

    #[test]
    fn bottom_up_spreads_creation_but_concentrates_compute() {
        use crate::graph::build;
        use crate::runtime::kernel::BinOp;
        let topo = Topology::new(4, 1, SystemMode::Ray);
        let mut state = ClusterState::new(topo);
        let ids = IdGen::default();
        let mut sched = BottomUp::new();
        // creation distributes (the paper's §2 description of Ray)
        let a = create(&mut sched, &mut state, &ids, &[512, 8], &[8, 1]);
        let b = create(&mut sched, &mut state, &ids, &[512, 8], &[8, 1]);
        for t in 0..4 {
            assert!(a.targets.iter().filter(|&&x| x == t).count() >= 1);
        }
        // ...but dependent compute piles on the driver node, pulling data
        let mut graph = Graph::new();
        build::binary_ew(&mut graph, &a, &b, BinOp::Add);
        let mut plan = Plan::new();
        sched.schedule(&mut graph, &mut state, &ids, &mut plan);
        let per = plan.tasks_per_target(4);
        assert!(
            per[0] > per[1] + per[2] + per[3],
            "driver should dominate: {per:?}"
        );
        assert!(plan.transfer_count() > 0, "pathology requires transfers");
    }

    #[test]
    fn random_is_deterministic_per_seed() {
        let topo = Topology::new(4, 1, SystemMode::Ray);
        let mut s1 = ClusterState::new(topo.clone());
        let mut s2 = ClusterState::new(topo);
        let g = ArrayGrid::new(&[64, 8], &[8, 1]);
        let t1 = RandomPlace::new(7).place_creation(&g, &mut s1);
        let t2 = RandomPlace::new(7).place_creation(&g, &mut s2);
        assert_eq!(t1, t2);
    }
}
