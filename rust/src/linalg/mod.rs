//! Linear algebra: the dense factorization substrate and distributed
//! TSQR algorithms (§8.3).

pub mod dense;
pub mod microkernel;
pub mod tsqr;
