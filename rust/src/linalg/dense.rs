//! Dense factorization substrate (the LAPACK the paper's workers call).
//!
//! The image's PJRT CPU client cannot run LAPACK custom-calls lowered by
//! `jnp.linalg.*`, so the factorization kernels (QR for TSQR §8.3, Cholesky
//! and SPD solves for Newton §6) are implemented here from scratch and
//! exposed as block kernels through `runtime::native`.
//!
//! All routines are f64, row-major on [`Block`]s, and validated against
//! reconstruction/identity properties in the tests below plus property
//! suites in `rust/tests/prop_suites.rs`.

use crate::runtime::{ExecContext, KernelTier};
use crate::store::block::pool;
use crate::store::Block;

use super::microkernel;

/// Depth of the B panel kept hot across a row sweep (KC·NC·8 B ≈ L2-sized).
const KC: usize = 256;
/// Width of the B panel.
const NC: usize = 512;
/// Register tile: rows of C accumulated per inner sweep, so each B element
/// loaded from cache feeds MR fused multiply-adds.
const MR: usize = 4;
/// Below this many FLOPs a kernel stays single-threaded (keeps small-block
/// numerics bit-stable and avoids spawn overhead on the task hot path).
const PAR_THRESHOLD: f64 = 3.2e7;

/// Worker threads for a blocked kernel of `flops` total work over `rows`
/// independent row slices, given the caller's thread `budget` (from an
/// [`ExecContext`] — there is no process-global parallelism state).
pub(crate) fn kernel_threads(flops: f64, rows: usize, budget: usize) -> usize {
    if flops < PAR_THRESHOLD || rows < 2 {
        return 1;
    }
    budget.clamp(1, rows)
}

/// Ceiling division (rows per thread chunk).
pub(crate) fn div_up(a: usize, b: usize) -> usize {
    a / b + usize::from(a % b != 0)
}

/// Tier-dispatched `α · (A @ B)` — the entry `runtime::native` routes
/// every Matmul/MatmulNT task through.
///
/// * [`KernelTier::Simd`] runs the packed-panel AVX2+FMA microkernel
///   ([`microkernel::matmul_packed`]), applying α during the final
///   panel's C-writeback.
/// * [`KernelTier::Scalar`] keeps the bit-stable blocked kernel
///   ([`matmul_with`], bit-identical to [`matmul_naive`]) and applies α
///   as one sweep over the output — exactly what an unfused `Scale`
///   (or, at α = −1, `Neg`) task computes, so folded epilogues change no
///   bits in the strict tier.
pub fn matmul_tier(a: &Block, b: &Block, alpha: f64, budget: usize, tier: KernelTier) -> Block {
    match tier {
        KernelTier::Simd => microkernel::matmul_packed(a, b, alpha, budget),
        KernelTier::Scalar => {
            let mut out = matmul_with(a, b, budget);
            if alpha != 1.0 {
                for v in out.buf_mut() {
                    *v *= alpha;
                }
            }
            out
        }
    }
}

/// Tier-dispatched `α · (Aᵀ @ B)` (see [`matmul_tier`]). The Simd tier
/// reuses the packed-panel path — Aᵀ strips are copied contiguously out
/// of A's rows instead of the scalar kernel's per-row strided updates.
pub fn gram_tier(a: &Block, b: &Block, alpha: f64, budget: usize, tier: KernelTier) -> Block {
    match tier {
        KernelTier::Simd => microkernel::gram_packed(a, b, alpha, budget),
        KernelTier::Scalar => {
            let mut out = gram_with(a, b, budget);
            if alpha != 1.0 {
                for v in out.buf_mut() {
                    *v *= alpha;
                }
            }
            out
        }
    }
}

/// C = A · B with a whole-host thread budget (standalone callers: driver
/// math, benches, tests). Executors use [`matmul_with`] with their
/// per-worker [`ExecContext`] budget.
pub fn matmul(a: &Block, b: &Block) -> Block {
    matmul_with(a, b, ExecContext::host_default().kernel_threads)
}

/// C = A · B — cache-blocked, register-tiled, parallel over row panels,
/// using at most `budget` threads.
///
/// Loop order keeps a KC×NC panel of B resident in L2 while MR rows of C
/// accumulate in registers; k is consumed in ascending order for every
/// output element, so results are bit-identical to [`matmul_naive`] (and
/// across thread counts — threads own disjoint row ranges).
pub fn matmul_with(a: &Block, b: &Block, budget: usize) -> Block {
    let (m, ka) = (a.rows(), a.cols());
    let (kb, n) = (b.rows(), b.cols());
    assert_eq!(ka, kb, "matmul {:?} x {:?}", a.shape, b.shape);
    let mut out = pool::alloc_zeroed(m * n);
    if m == 0 || n == 0 || ka == 0 {
        return Block::from_vec(&[m, n], out);
    }
    let (ab, bb) = (a.buf(), b.buf());
    let threads = kernel_threads(2.0 * m as f64 * ka as f64 * n as f64, m, budget);
    if threads <= 1 {
        matmul_rows(ab, bb, &mut out, 0, m, ka, n);
    } else {
        let rows_per = div_up(m, threads);
        std::thread::scope(|scope| {
            for (t, chunk) in out.chunks_mut(rows_per * n).enumerate() {
                let r0 = t * rows_per;
                let r1 = r0 + chunk.len() / n;
                scope.spawn(move || matmul_rows(ab, bb, chunk, r0, r1, ka, n));
            }
        });
    }
    Block::from_vec(&[m, n], out)
}

/// Blocked kernel over absolute rows `[r0, r1)`; `c` holds exactly those
/// rows (row `i` lives at chunk offset `(i - r0) * n`).
fn matmul_rows(ab: &[f64], bb: &[f64], c: &mut [f64], r0: usize, r1: usize, k: usize, n: usize) {
    let mut kk = 0;
    while kk < k {
        let kend = (kk + KC).min(k);
        let mut jj = 0;
        while jj < n {
            let jend = (jj + NC).min(n);
            let mut i = r0;
            while i + MR <= r1 {
                let base = (i - r0) * n;
                let (r01, r23) = c[base..base + MR * n].split_at_mut(2 * n);
                let (row0, row1) = r01.split_at_mut(n);
                let (row2, row3) = r23.split_at_mut(n);
                let c0 = &mut row0[jj..jend];
                let c1 = &mut row1[jj..jend];
                let c2 = &mut row2[jj..jend];
                let c3 = &mut row3[jj..jend];
                for dk in kk..kend {
                    let a0 = ab[i * k + dk];
                    let a1 = ab[(i + 1) * k + dk];
                    let a2 = ab[(i + 2) * k + dk];
                    let a3 = ab[(i + 3) * k + dk];
                    let brow = &bb[dk * n + jj..dk * n + jend];
                    if a0 != 0.0 && a1 != 0.0 && a2 != 0.0 && a3 != 0.0 {
                        // fast path: one B load feeds four accumulators
                        for (jx, &bv) in brow.iter().enumerate() {
                            c0[jx] += a0 * bv;
                            c1[jx] += a1 * bv;
                            c2[jx] += a2 * bv;
                            c3[jx] += a3 * bv;
                        }
                        continue;
                    }
                    // some row has a zero multiplier: skip per row exactly
                    // like the naive oracle (0·inf would otherwise mint NaNs
                    // the oracle never produces)
                    if a0 != 0.0 {
                        for (cv, &bv) in c0.iter_mut().zip(brow) {
                            *cv += a0 * bv;
                        }
                    }
                    if a1 != 0.0 {
                        for (cv, &bv) in c1.iter_mut().zip(brow) {
                            *cv += a1 * bv;
                        }
                    }
                    if a2 != 0.0 {
                        for (cv, &bv) in c2.iter_mut().zip(brow) {
                            *cv += a2 * bv;
                        }
                    }
                    if a3 != 0.0 {
                        for (cv, &bv) in c3.iter_mut().zip(brow) {
                            *cv += a3 * bv;
                        }
                    }
                }
                i += MR;
            }
            while i < r1 {
                let base = (i - r0) * n;
                let crow = &mut c[base + jj..base + jend];
                for dk in kk..kend {
                    let aik = ab[i * k + dk];
                    if aik == 0.0 {
                        continue;
                    }
                    let brow = &bb[dk * n + jj..dk * n + jend];
                    for (cv, &bv) in crow.iter_mut().zip(brow) {
                        *cv += aik * bv;
                    }
                }
                i += 1;
            }
            jj = jend;
        }
        kk = kend;
    }
}

/// C = A · B, the seed's naive i-k-j triple loop. Kept as the oracle the
/// blocked kernel is property-checked against and as the ablation baseline
/// in `benches/fig09_micro.rs`.
pub fn matmul_naive(a: &Block, b: &Block) -> Block {
    let (m, ka) = (a.rows(), a.cols());
    let (kb, n) = (b.rows(), b.cols());
    assert_eq!(ka, kb, "matmul {:?} x {:?}", a.shape, b.shape);
    let mut out = vec![0.0; m * n];
    let (ab, bb) = (a.buf(), b.buf());
    for i in 0..m {
        let arow = &ab[i * ka..(i + 1) * ka];
        let orow = &mut out[i * n..(i + 1) * n];
        for (k, &aik) in arow.iter().enumerate() {
            if aik == 0.0 {
                continue;
            }
            let brow = &bb[k * n..(k + 1) * n];
            for j in 0..n {
                orow[j] += aik * brow[j];
            }
        }
    }
    Block::from_vec(&[m, n], out)
}

/// C = Aᵀ · B computed *without materializing Aᵀ* — a streaming rank-1
/// accumulation over the shared row dimension. This is the GLM hot path
/// (Xᵀ·v with X tall-skinny): the old route transposed the full X block
/// per task; this one reads X and B once, accumulates into the small p×q
/// output, and parallelizes over row ranges with a deterministic in-order
/// partial reduction.
pub fn gram(a: &Block, b: &Block) -> Block {
    gram_with(a, b, ExecContext::host_default().kernel_threads)
}

/// C = Aᵀ · B with an explicit thread budget (see [`gram`]).
pub fn gram_with(a: &Block, b: &Block, budget: usize) -> Block {
    let (m, p) = (a.rows(), a.cols());
    let (m2, q) = (b.rows(), b.cols());
    assert_eq!(m, m2, "gram {:?}ᵀ x {:?}", a.shape, b.shape);
    let (ab, bb) = (a.buf(), b.buf());
    let threads = kernel_threads(2.0 * m as f64 * p as f64 * q as f64, m, budget);
    if threads <= 1 {
        return Block::from_vec(&[p, q], gram_rows(ab, bb, 0, m, p, q));
    }
    let rows_per = div_up(m, threads);
    let partials: Vec<Vec<f64>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let r0 = t * rows_per;
                let r1 = ((t + 1) * rows_per).min(m);
                scope.spawn(move || gram_rows(ab, bb, r0, r1, p, q))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let mut out = pool::alloc_zeroed(p * q);
    for part in &partials {
        for (o, v) in out.iter_mut().zip(part) {
            *o += *v;
        }
    }
    Block::from_vec(&[p, q], out)
}

fn gram_rows(ab: &[f64], bb: &[f64], r0: usize, r1: usize, p: usize, q: usize) -> Vec<f64> {
    let mut out = vec![0.0; p * q];
    for i in r0..r1 {
        let ar = &ab[i * p..(i + 1) * p];
        let br = &bb[i * q..(i + 1) * q];
        for (x, &av) in ar.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let orow = &mut out[x * q..(x + 1) * q];
            for (o, &bv) in orow.iter_mut().zip(br) {
                *o += av * bv;
            }
        }
    }
    out
}

/// Thin (reduced) Householder QR: X[m,n] with m >= n -> (Q[m,n], R[n,n]),
/// R upper-triangular with non-negative diagonal (canonical form, so
/// TSQR trees produce comparable R factors).
pub fn householder_qr(x: &Block) -> (Block, Block) {
    let (m, n) = (x.rows(), x.cols());
    assert!(m >= n, "thin QR needs m >= n, got {m}x{n}");
    let mut r = x.buf().to_vec(); // working copy, becomes R in top n rows
    let mut vs: Vec<Vec<f64>> = Vec::with_capacity(n); // Householder vectors

    for k in 0..n {
        // build v for column k below (and including) the diagonal
        let mut norm2 = 0.0;
        for i in k..m {
            let v = r[i * n + k];
            norm2 += v * v;
        }
        let norm = norm2.sqrt();
        let x0 = r[k * n + k];
        let alpha = if x0 >= 0.0 { -norm } else { norm };
        let mut v = vec![0.0; m - k];
        if norm > 0.0 {
            v[0] = x0 - alpha;
            for i in (k + 1)..m {
                v[i - k] = r[i * n + k];
            }
            let vnorm2: f64 = v.iter().map(|t| t * t).sum();
            if vnorm2 > 0.0 {
                // apply H = I - 2 v v^T / (v^T v) to the trailing matrix
                for j in k..n {
                    let mut dot = 0.0;
                    for i in k..m {
                        dot += v[i - k] * r[i * n + j];
                    }
                    let scale = 2.0 * dot / vnorm2;
                    for i in k..m {
                        r[i * n + j] -= scale * v[i - k];
                    }
                }
            }
        }
        vs.push(v);
        // zero the column explicitly for numerical hygiene
        for i in (k + 1)..m {
            r[i * n + k] = 0.0;
        }
    }

    // sign-canonicalize: make diag(R) >= 0 by flipping rows of R (and the
    // corresponding columns of Q later via the flips vector)
    let mut flips = vec![1.0; n];
    for k in 0..n {
        if r[k * n + k] < 0.0 {
            flips[k] = -1.0;
            for j in k..n {
                r[k * n + j] = -r[k * n + j];
            }
        }
    }

    // form thin Q by applying the Householder reflectors to I[m,n]
    let mut q = vec![0.0; m * n];
    for (j, fj) in flips.iter().enumerate() {
        q[j * n + j] = *fj; // column j of (I * flip)
    }
    for k in (0..n).rev() {
        let v = &vs[k];
        let vnorm2: f64 = v.iter().map(|t| t * t).sum();
        if vnorm2 == 0.0 {
            continue;
        }
        for j in 0..n {
            let mut dot = 0.0;
            for i in k..m {
                dot += v[i - k] * q[i * n + j];
            }
            let scale = 2.0 * dot / vnorm2;
            for i in k..m {
                q[i * n + j] -= scale * v[i - k];
            }
        }
    }

    let r_top = Block::from_vec(&[n, n], r[..n * n].to_vec());
    (Block::from_vec(&[m, n], q), r_top)
}

/// Cholesky factor L (lower) of an SPD matrix A = L Lᵀ.
pub fn cholesky(a: &Block) -> Block {
    let n = a.rows();
    assert_eq!(n, a.cols(), "cholesky needs square");
    let src = a.buf();
    let mut l = vec![0.0; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut sum = src[i * n + j];
            for k in 0..j {
                sum -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                assert!(sum > 0.0, "matrix not positive definite at {i} (sum={sum})");
                l[i * n + j] = sum.sqrt();
            } else {
                l[i * n + j] = sum / l[j * n + j];
            }
        }
    }
    Block::from_vec(&[n, n], l)
}

/// Solve L y = b (forward substitution), L lower-triangular.
pub fn solve_lower(l: &Block, b: &Block) -> Block {
    let n = l.rows();
    assert_eq!(b.rows(), n);
    let m = b.cols();
    let (lb, bb) = (l.buf(), b.buf());
    let mut y = bb.to_vec();
    for c in 0..m {
        for i in 0..n {
            let mut v = y[i * m + c];
            for k in 0..i {
                v -= lb[i * n + k] * y[k * m + c];
            }
            y[i * m + c] = v / lb[i * n + i];
        }
    }
    Block::from_vec(&[n, m], y)
}

/// Solve U x = b (back substitution), U upper-triangular.
pub fn solve_upper(u: &Block, b: &Block) -> Block {
    let n = u.rows();
    assert_eq!(b.rows(), n);
    let m = b.cols();
    let (ub, bb) = (u.buf(), b.buf());
    let mut x = bb.to_vec();
    for c in 0..m {
        for i in (0..n).rev() {
            let mut v = x[i * m + c];
            for k in (i + 1)..n {
                v -= ub[i * n + k] * x[k * m + c];
            }
            x[i * m + c] = v / ub[i * n + i];
        }
    }
    Block::from_vec(&[n, m], x)
}

/// Solve the SPD system A x = b via Cholesky (the Newton step H⁻¹g, §6).
/// A tiny ridge keeps near-singular Hessians factorable, matching the
/// Python reference (`model.newton_solve_ref`).
pub fn solve_spd(a: &Block, b: &Block, ridge: f64) -> Block {
    let n = a.rows();
    let mut a2 = a.clone();
    for i in 0..n {
        let v = a2.at2(i, i) + ridge;
        a2.set2(i, i, v);
    }
    let l = cholesky(&a2);
    let y = solve_lower(&l, b);
    // L^T x = y: solve with U = L^T
    solve_upper(&l.transposed(), &y)
}

/// Inverse of an upper-triangular matrix (indirect TSQR's R⁻¹, §8.3).
pub fn inv_upper(u: &Block) -> Block {
    let n = u.rows();
    assert_eq!(n, u.cols());
    let mut eye = Block::zeros(&[n, n]);
    for i in 0..n {
        eye.set2(i, i, 1.0);
    }
    solve_upper(u, &eye)
}

/// Frobenius norm.
pub fn fro_norm(a: &Block) -> f64 {
    a.buf().iter().map(|v| v * v).sum::<f64>().sqrt()
}

/// Identity block.
pub fn eye(n: usize) -> Block {
    let mut b = Block::zeros(&[n, n]);
    for i in 0..n {
        b.set2(i, i, 1.0);
    }
    b
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randn(shape: &[usize], seed: u64) -> Block {
        let mut rng = Rng::seed_from_u64(seed);
        let n: usize = shape.iter().product();
        let mut v = vec![0.0; n];
        rng.fill_normal(&mut v);
        Block::from_vec(shape, v)
    }

    #[test]
    fn matmul_identity() {
        let a = randn(&[5, 5], 1);
        assert!(matmul(&a, &eye(5)).max_abs_diff(&a) < 1e-12);
        assert!(matmul(&eye(5), &a).max_abs_diff(&a) < 1e-12);
    }

    #[test]
    fn blocked_matches_naive_across_shapes() {
        // ragged sizes hit every tile-remainder path (MR, KC, NC edges)
        for (m, k, n, seed) in [
            (1, 1, 1, 20),
            (3, 7, 5, 21),
            (4, 256, 512, 22),
            (5, 257, 513, 23),
            (67, 300, 129, 24),
            (130, 64, 33, 25),
        ] {
            let a = randn(&[m, k], seed);
            let b = randn(&[k, n], seed + 100);
            let got = matmul(&a, &b);
            let want = matmul_naive(&a, &b);
            assert_eq!(got.shape, want.shape);
            assert_eq!(
                got.max_abs_diff(&want),
                0.0,
                "blocked must be bit-identical at {m}x{k}x{n}"
            );
        }
    }

    #[test]
    fn blocked_matches_naive_above_parallel_threshold() {
        // 2·300³ = 5.4e7 FLOPs > PAR_THRESHOLD: exercises the threaded path,
        // which still owns disjoint rows -> bit-identical.
        let a = randn(&[300, 300], 30);
        let b = randn(&[300, 300], 31);
        assert_eq!(matmul(&a, &b).max_abs_diff(&matmul_naive(&a, &b)), 0.0);
    }

    #[test]
    fn gram_matches_transpose_matmul() {
        let x = randn(&[40, 7], 40);
        let y = randn(&[40, 9], 41);
        let got = gram(&x, &y);
        let want = matmul_naive(&x.transposed(), &y);
        assert_eq!(got.shape, vec![7, 9]);
        assert!(got.max_abs_diff(&want) < 1e-12);
    }

    #[test]
    fn gram_parallel_matches_serial() {
        // 2·4000·64·64 = 3.3e7 FLOPs > PAR_THRESHOLD: threaded partials,
        // reduced in deterministic range order.
        let x = randn(&[4000, 64], 42);
        let y = randn(&[4000, 64], 43);
        let got = gram(&x, &y);
        let want = matmul_naive(&x.transposed(), &y);
        assert!(got.max_abs_diff(&want) < 1e-9);
    }

    #[test]
    fn gram_self_product_is_exactly_symmetric() {
        // every (i,j)/(j,i) pair runs the same i-ascending accumulation
        // and f64 multiplication commutes, so Xᵀ·X symmetry is exact in
        // the scalar kernel (the packed tier asserts the same in
        // `microkernel::tests`)
        let x = randn(&[200, 13], 90);
        let g = gram(&x, &x);
        for i in 0..13 {
            for j in 0..13 {
                assert_eq!(g.at2(i, j), g.at2(j, i), "exact symmetry at ({i},{j})");
            }
        }
    }

    #[test]
    fn tier_dispatch_scalar_is_bit_identical_to_blocked() {
        let a = randn(&[9, 33], 91);
        let b = randn(&[33, 14], 92);
        let plain = matmul_tier(&a, &b, 1.0, 1, KernelTier::Scalar);
        assert_eq!(plain.max_abs_diff(&matmul(&a, &b)), 0.0);
        // α in the scalar tier is one sweep — identical to a Scale pass
        let scaled = matmul_tier(&a, &b, -2.0, 1, KernelTier::Scalar);
        let mut want = matmul(&a, &b);
        for v in want.buf_mut() {
            *v *= -2.0;
        }
        assert_eq!(scaled.max_abs_diff(&want), 0.0);

        let x = randn(&[50, 7], 93);
        let y = randn(&[50, 5], 94);
        let g = gram_tier(&x, &y, 1.0, 1, KernelTier::Scalar);
        assert_eq!(g.max_abs_diff(&gram(&x, &y)), 0.0);
    }

    #[test]
    fn zero_dim_matmul_is_well_formed() {
        let a = Block::zeros(&[2, 0]);
        let b = Block::zeros(&[0, 3]);
        let c = matmul(&a, &b);
        assert_eq!(c.shape, vec![2, 3]);
        assert!(c.buf().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn qr_reconstructs() {
        for (m, n, seed) in [(8, 8, 2), (20, 5, 3), (64, 16, 4), (5, 1, 5)] {
            let x = randn(&[m, n], seed);
            let (q, r) = householder_qr(&x);
            assert_eq!(q.shape, vec![m, n]);
            assert_eq!(r.shape, vec![n, n]);
            let back = matmul(&q, &r);
            assert!(back.max_abs_diff(&x) < 1e-10, "reconstruction {m}x{n}");
            // orthonormal columns
            let qtq = matmul(&q.transposed(), &q);
            assert!(qtq.max_abs_diff(&eye(n)) < 1e-10, "Q^T Q != I");
            // upper-triangular with non-negative diagonal
            for i in 0..n {
                assert!(r.at2(i, i) >= 0.0);
                for j in 0..i {
                    assert!(r.at2(i, j).abs() < 1e-12);
                }
            }
        }
    }

    #[test]
    fn cholesky_reconstructs() {
        let x = randn(&[12, 6], 7);
        let a = matmul(&x.transposed(), &x); // SPD (whp)
        let l = cholesky(&a);
        assert!(matmul(&l, &l.transposed()).max_abs_diff(&a) < 1e-9);
    }

    #[test]
    fn spd_solve_matches_direct() {
        let x = randn(&[20, 5], 8);
        let a = matmul(&x.transposed(), &x);
        let b = randn(&[5, 2], 9);
        let sol = solve_spd(&a, &b, 0.0);
        assert!(matmul(&a, &sol).max_abs_diff(&b) < 1e-8);
    }

    #[test]
    fn inv_upper_is_inverse() {
        let x = randn(&[10, 4], 10);
        let (_, r) = householder_qr(&x);
        let rinv = inv_upper(&r);
        assert!(matmul(&r, &rinv).max_abs_diff(&eye(4)) < 1e-9);
    }

    #[test]
    fn triangular_solves() {
        let x = randn(&[6, 6], 11);
        let a = matmul(&x.transposed(), &x);
        let l = cholesky(&a);
        let b = randn(&[6, 1], 12);
        let y = solve_lower(&l, &b);
        assert!(matmul(&l, &y).max_abs_diff(&b) < 1e-10);
        let z = solve_upper(&l.transposed(), &y);
        assert!(matmul(&a, &z).max_abs_diff(&b) < 1e-8);
    }

    #[test]
    #[should_panic(expected = "not positive definite")]
    fn cholesky_rejects_indefinite() {
        let mut a = eye(3);
        a.set2(2, 2, -1.0);
        cholesky(&a);
    }
}
